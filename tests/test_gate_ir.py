"""Gate IR + Verilog front-end + the paper's §6.3 worked examples."""
import numpy as np
import pytest

from repro.core.gate_ir import CONST0, CONST1, LogicGraph, OpCode, random_graph
from repro.core.levelize import levelize
from repro.core.spec import CompileSpec
from repro.core.scheduler import compile_graph, execute_program_np
from repro.core.verilog import emit_verilog, parse_verilog


def all_patterns(n):
    return ((np.arange(2 ** n)[:, None] >> np.arange(n)[None, :]) & 1
            ).astype(bool)


def test_g1_paper_example():
    """Paper Fig. 4 / Table 2: 4-input AND via three 2-input ANDs."""
    g = LogicGraph(4, name="g1")
    w1 = g.add_gate(OpCode.AND, g.input_wire(0), g.input_wire(1))
    w2 = g.add_gate(OpCode.AND, g.input_wire(2), g.input_wire(3))
    out = g.add_gate(OpCode.AND, w1, w2)
    g.set_outputs([out])
    lv = levelize(g)
    assert lv.depth == 2
    assert list(lv.histogram()) == [2, 1]
    # schedule on 2 units: 2 sub-kernels, second one half-NOP (paper: [AND,NOP])
    prog = compile_graph(g, CompileSpec(n_unit=2, optimize="none"))
    assert prog.n_steps == 2
    assert prog.opcode[0].tolist() == [int(OpCode.AND)] * 2
    assert prog.opcode[1].tolist() == [int(OpCode.AND), int(OpCode.NOP)]
    X = all_patterns(4)
    expected = X.all(axis=1, keepdims=True)
    assert (g.evaluate(X) == expected).all()
    assert (execute_program_np(prog, X) == expected).all()


def test_g2_paper_example():
    """Paper Fig. 5 / Table 3: the 4-input, 3-level function g2."""
    g = LogicGraph(4, name="g2")  # inputs a,b,c,d -> wires 2..5
    a, b, c, d = (g.input_wire(i) for i in range(4))
    w1 = g.add_gate(OpCode.XOR, b, c)
    w2 = g.add_gate(OpCode.XOR, b, a)
    w3 = g.add_gate(OpCode.AND, d, a)
    w4 = g.add_gate(OpCode.OR, d, c)
    w5 = g.add_gate(OpCode.XOR, w1, w3)
    w6 = g.add_gate(OpCode.AND, w2, w4)
    out = g.add_gate(OpCode.AND, w6, w5)
    g.set_outputs([out])
    lv = levelize(g)
    assert lv.depth == 3
    assert list(lv.histogram()) == [4, 2, 1]
    # two units (paper): level1 -> 2 sub-kernels, levels 2,3 -> 1 each = 4
    prog = compile_graph(g, CompileSpec(n_unit=2, optimize="none"))
    assert prog.n_steps == 4  # paper: "completed within ... 4 cycles"
    X = all_patterns(4)
    av, bv, cv, dv = X.T
    expected = (((bv ^ av) & (dv | cv)) & ((bv ^ cv) ^ (dv & av)))[:, None]
    assert (g.evaluate(X) == expected).all()
    assert (execute_program_np(prog, X) == expected).all()


def test_constants_and_unary():
    g = LogicGraph(1)
    n = g.add_gate(OpCode.NOT, g.input_wire(0))
    o = g.add_gate(OpCode.OR, n, CONST1)
    x = g.add_gate(OpCode.XOR, o, CONST0)
    g.set_outputs([n, o, x])
    X = np.array([[0], [1]], dtype=bool)
    out = g.evaluate(X)
    assert (out[:, 0] == ~X[:, 0]).all()
    assert out[:, 1].all() and out[:, 2].all()


def test_topological_enforcement():
    g = LogicGraph(2)
    with pytest.raises(ValueError):
        g.add_gate(OpCode.AND, 0, 99)


def test_verilog_roundtrip(rng):
    for _ in range(5):
        g = random_graph(rng, 6, 60, 4)
        g2 = parse_verilog(emit_verilog(g))
        X = rng.integers(0, 2, (64, 6)).astype(bool)
        assert (g.evaluate(X) == g2.evaluate(X)).all()


def test_verilog_expressions():
    src = """
    // comment
    module m(a, b, c, y, z);
      input a, b, c; output y, z; wire w1;
      and g0 (w1, a, b);
      assign y = ~(w1 ^ c) | (a & 1'b1);
      nor g1 (z, w1, c);
    endmodule
    """
    g = parse_verilog(src)
    X = ((np.arange(8)[:, None] >> np.arange(3)) & 1).astype(bool)
    a, b, c = X.T
    w1 = a & b
    out = g.evaluate(X)
    assert (out[:, 0] == (~(w1 ^ c) | a)).all()
    assert (out[:, 1] == ~(w1 | c)).all()


def test_out_of_order_netlist():
    src = """
    module m(a, b, y);
      input a, b; output y; wire w1, w2;
      and g1 (y, w1, w2);      // uses wires defined later
      not g2 (w1, a);
      or  g3 (w2, a, b);
    endmodule
    """
    g = parse_verilog(src)
    X = np.array([[0, 0], [0, 1], [1, 0], [1, 1]], dtype=bool)
    a, b = X.T
    assert (g.evaluate(X)[:, 0] == (~a & (a | b))).all()
