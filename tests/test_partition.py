"""FFCL partitioning: equivalence, budget, pipelining integration."""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev)")
from hypothesis import given, settings, strategies as st

from repro.core.gate_ir import random_graph
from repro.core.partition import (compile_partitions, duplication_factor,
                                  execute_partitions, output_cones,
                                  partition)
from repro.core.simulator import simulate_pipeline
from repro.core.spec import CompileSpec
from repro.kernels.logic_dsp import logic_infer_bits


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10 ** 6), st.sampled_from([40, 120, 10 ** 6]))
def test_partition_equivalence(seed, max_gates):
    rng = np.random.default_rng(seed)
    g = random_graph(rng, 10, 250, 12, locality=64)
    parts = partition(g, max_gates)
    # every output appears exactly once
    idx = sorted(i for p in parts for i in p.output_indices)
    assert idx == list(range(g.n_outputs))
    X = rng.integers(0, 2, (80, 10)).astype(bool)
    assert (execute_partitions(parts, X) == g.evaluate(X)).all()


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10 ** 6))
def test_partition_respects_budget(seed):
    rng = np.random.default_rng(seed)
    g = random_graph(rng, 8, 300, 16, locality=32)
    cones = output_cones(g)
    biggest = max(len(c) for c in cones)
    budget = max(biggest, 60)   # budget must admit the largest single cone
    parts = partition(g, budget)
    for p in parts:
        assert p.graph.n_gates <= budget


def test_partition_through_kernel(rng):
    """Partitioned execution through the Pallas fabric == monolithic."""
    g = random_graph(rng, 12, 400, 20, locality=48)
    parts = partition(g, 150)
    assert len(parts) >= 2
    progs = compile_partitions(parts, CompileSpec(n_unit=16))

    def kernel_exec(graph, x):
        prog = progs[[p.graph is graph for p in parts].index(True)]
        return logic_infer_bits(prog, x)

    X = rng.integers(0, 2, (64, 12)).astype(bool)
    got = execute_partitions(parts, X, executor=kernel_exec)
    assert (got == g.evaluate(X)).all()
    # buffer budget actually shrank vs the monolithic program
    from repro.core.scheduler import compile_graph
    mono = compile_graph(g, CompileSpec(n_unit=16, optimize="none"))
    assert max(p.n_addr for p in progs) < mono.n_addr


def test_duplication_vs_pipelining_tradeoff(rng):
    """The split costs duplicated gates but the modules pipeline (paper
    eq. 2); the simulator quantifies both sides."""
    g = random_graph(rng, 16, 600, 24, locality=64)
    parts = partition(g, 250)
    dup = duplication_factor(g, parts)
    # duplication bounded by the partition count (every part <= whole graph)
    assert 1.0 <= dup <= len(parts)
    progs = compile_partitions(parts, n_unit=32)
    sim = simulate_pipeline(progs, n_input_vectors=4096)
    assert sim.total_cycles > 0
    assert len(sim.timeline) == 2 * len(progs)
