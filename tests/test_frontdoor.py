"""Serving front door: admission, deadlines, shedding, faults, tenancy.

Every degradation path the front door specifies (DESIGN.md §9) is
exercised here with seeded-deterministic fault injection, plus the
SlotTable-under-cancellation property suite and the ProgramCache
thread-safety hammer.  The deterministic sections always run; the
hypothesis sections widen the random coverage when hypothesis is
installed (requirements-dev.txt).  ``REPRO_FRONTDOOR_STRESS=1`` (the
dedicated CI job) scales the overload integration test up.
"""
import asyncio
import os
import threading

import numpy as np
import pytest

from repro.core.errors import (PermanentCompileError, TransientCompileError,
                               is_transient)
from repro.core.gate_ir import random_graph
from repro.core.spec import CompileSpec
from repro.serve import (FaultPolicy, FrontDoor, LogicEngine, Priority,
                         ProgramCache, RequestRejected, SHED_CODES,
                         SlotTable, TrafficPattern, build_trace, run_trace)
from repro.serve.traffic import interarrivals

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:           # tier-1 containers may lack hypothesis
    HAVE_HYPOTHESIS = False

STRESS = os.environ.get("REPRO_FRONTDOOR_STRESS") == "1"


def _graph(rng, n_in=12, n_gates=200, n_out=8):
    return random_graph(rng, n_in, n_gates, n_out, locality=48)


def _door(**kw):
    kw.setdefault("spec", CompileSpec(n_unit=16))
    kw.setdefault("capacity", 64)
    kw.setdefault("default_deadline_s", 10.0)
    return FrontDoor(**kw)


def _run(coro):
    return asyncio.run(asyncio.wait_for(coro, timeout=90))


async def _warm(door, tenants, rng, waves=4):
    """Compile + jit + wave-window warmup per tenant."""
    for _ in range(waves):
        for name, g in tenants:
            bits = rng.integers(0, 2, (16, g.n_inputs)).astype(bool)
            out = await door.submit(name, bits, deadline_s=60.0)
            assert (out == g.evaluate(bits)).all()
    door.reset_metrics()


# ---------------------------------------------------------------------------
# basic lifecycle + tenancy isolation
# ---------------------------------------------------------------------------

def test_multi_tenant_parity_and_isolation(rng):
    """Two tenants share one engine/cache; every result is bit-exact
    against its OWN tenant's oracle — never another tenant's bits."""
    g_a, g_b = _graph(rng), _graph(rng, n_in=10, n_gates=150, n_out=6)

    async def go():
        door = _door()
        door.register("a", g_a)
        door.register("b", g_b)
        async with door:
            reqs = []
            for i in range(12):
                g, name = ((g_a, "a") if i % 2 == 0 else (g_b, "b"))
                bits = rng.integers(0, 2, (7 + i, g.n_inputs)).astype(bool)
                reqs.append((name, g, bits))
            outs = await asyncio.gather(
                *(door.submit(n, bits) for n, _, bits in reqs))
            for (name, g, bits), out in zip(reqs, outs):
                assert out.shape == (bits.shape[0], g.n_outputs)
                assert (out == g.evaluate(bits)).all(), \
                    f"tenant {name} got foreign bits"
        m = door.metrics()
        assert m["completed"] == 12 and m["shed"] == 0
        assert m["engine"]["cache_entries"] == 2    # one entry per tenant

    _run(go())


def test_unknown_tenant_and_bad_shape_are_caller_errors(rng):
    g = _graph(rng)

    async def go():
        door = _door()
        door.register("a", g)
        async with door:
            with pytest.raises(KeyError):
                await door.submit("nope", np.zeros((2, g.n_inputs), bool))
            with pytest.raises(ValueError):
                await door.submit("a", np.zeros((2, g.n_inputs + 1), bool))
            # empty request completes trivially, no admission consumed
            out = await door.submit("a", np.zeros((0, g.n_inputs), bool))
            assert out.shape == (0, g.n_outputs)
        assert door.metrics()["offered"] == 0

    _run(go())


def test_duplicate_tenant_rejected(rng):
    door = _door()
    door.register("a", _graph(rng))
    with pytest.raises(ValueError):
        door.register("a", _graph(rng))


# ---------------------------------------------------------------------------
# shedding: bounded queue, priorities, deadlines
# ---------------------------------------------------------------------------

def test_queue_full_sheds_with_machine_readable_reason(rng):
    g = _graph(rng, n_gates=400)

    async def go():
        door = _door(max_queue=2)
        door.register("a", g)
        # don't start the loop: the queue can only fill
        coros = [door.submit("a", rng.integers(0, 2, (8, g.n_inputs))
                             .astype(bool)) for _ in range(6)]
        tasks = [asyncio.create_task(c) for c in coros]
        await asyncio.sleep(0)          # let admissions run
        results = await asyncio.gather(*tasks, return_exceptions=True)
        shed = [r for r in results if isinstance(r, RequestRejected)]
        assert shed, "overflow must shed"
        for exc in shed:
            d = exc.reason.to_dict()
            assert d["code"] in SHED_CODES
            assert d["code"] == "queue_full" and d["tenant"] == "a"
        ok = [r for r in results if isinstance(r, np.ndarray)]
        assert len(ok) + len(shed) == 6          # nothing hangs
        await door.stop(drain=True)

    _run(go())


def test_high_priority_displaces_batch(rng):
    g = _graph(rng)

    async def go():
        door = _door(max_queue=2)
        door.register("a", g)
        bits = rng.integers(0, 2, (4, g.n_inputs)).astype(bool)
        # all three tasks are created before the event loop runs any of
        # them: admissions land back-to-back (the dispatcher task,
        # lazily created by the first submit, is scheduled after), so
        # the HIGH arrival sees a full queue of BATCH work
        batch = [asyncio.create_task(
            door.submit("a", bits, priority=Priority.BATCH))
            for _ in range(2)]
        high = asyncio.create_task(
            door.submit("a", bits, priority=Priority.HIGH))
        results = await asyncio.gather(*batch, high, return_exceptions=True)
        codes = [r.reason.code for r in results
                 if isinstance(r, RequestRejected)]
        assert codes == ["displaced"], codes
        assert isinstance(results[2], np.ndarray)   # HIGH was served
        await door.stop(drain=True)

    _run(go())


def test_expired_work_dropped_before_dispatch(rng):
    """A request whose deadline passes while queued is rejected
    pre-dispatch (deadline_expired) — the engine never sees it."""
    g = _graph(rng)

    async def go():
        door = _door()
        door.register("a", g)
        bits = rng.integers(0, 2, (4, g.n_inputs)).astype(bool)
        with pytest.raises(RequestRejected) as ei:
            await door.submit("a", bits, deadline_s=0.0)
        assert ei.value.reason.code == "deadline_expired"
        assert door.engine.invocations == 0
        m = door.metrics()
        assert m["deadline_misses"] == 1 and m["completed"] == 0
        await door.stop(drain=True)

    _run(go())


def test_projected_wait_sheds_infeasible_deadlines(rng):
    g = _graph(rng)

    async def go():
        door = _door(max_queue=512, capacity=64)
        door.register("a", g)
        await _warm(door, [("a", g)], rng)
        assert door.wave_s is not None
        # a deadline far below one wave of queueing with a full backlog
        # must shed at the door, carrying the projected wait
        blocker = [asyncio.create_task(door.submit(
            "a", rng.integers(0, 2, (64, g.n_inputs)).astype(bool)))
            for _ in range(12)]
        await asyncio.sleep(0)
        with pytest.raises(RequestRejected) as ei:
            await door.submit(
                "a", rng.integers(0, 2, (64, g.n_inputs)).astype(bool),
                deadline_s=min(1e-4, door.wave_s / 10))
        reason = ei.value.reason
        assert reason.code == "deadline_infeasible"
        assert reason.projected_wait_s > 0
        assert "projected_wait_s" in reason.to_dict()
        await asyncio.gather(*blocker)
        await door.stop(drain=True)

    _run(go())


# ---------------------------------------------------------------------------
# fault injection: drop / delay / fail-compile / evict
# ---------------------------------------------------------------------------

def test_injected_drop_sheds(rng):
    g = _graph(rng)

    async def go():
        door = _door(fault_policy=FaultPolicy(seed=0, drop_rate=1.0))
        door.register("a", g)
        with pytest.raises(RequestRejected) as ei:
            await door.submit("a",
                              rng.integers(0, 2, (4, g.n_inputs))
                              .astype(bool))
        assert ei.value.reason.code == "injected_drop"
        assert door.fault_policy.injected["drop"] == 1
        await door.stop(drain=True)

    _run(go())


def test_transient_compile_failure_retried_to_success(rng):
    """compile_fail_first=2: dispatch 1 and retry 1 fail, retry 2
    compiles — the request completes, with the retry trail visible."""
    g = _graph(rng)

    async def go():
        door = _door(fault_policy=FaultPolicy(seed=0, compile_fail_first=2),
                     max_retries=3, backoff_s=0.001)
        door.register("a", g)
        bits = rng.integers(0, 2, (6, g.n_inputs)).astype(bool)
        out = await door.submit("a", bits)
        assert (out == g.evaluate(bits)).all()
        m = door.metrics()
        assert m["retries"] == 2
        assert m["engine"]["cache_compile_failures"] == 2
        assert m["faults_injected"]["compile_fail"] == 2
        await door.stop(drain=True)

    _run(go())


def test_retries_exhausted_sheds_with_reason(rng):
    g = _graph(rng)

    async def go():
        door = _door(fault_policy=FaultPolicy(seed=0, compile_fail_rate=1.0),
                     max_retries=2, backoff_s=0.001)
        door.register("a", g)
        with pytest.raises(RequestRejected) as ei:
            await door.submit("a", rng.integers(0, 2, (4, g.n_inputs))
                              .astype(bool))
        assert ei.value.reason.code == "retries_exhausted"
        assert "TransientCompileError" in ei.value.reason.detail
        await door.stop(drain=True)

    _run(go())


def test_permanent_compile_failure_sheds_immediately(rng):
    """A non-retryable failure must not burn the retry budget."""
    g = _graph(rng)

    async def go():
        door = _door(max_retries=5)
        door.register("a", g)

        def hook(graph, spec):
            raise PermanentCompileError("fabric limit exceeded")
        door.engine.cache.compiler.fault_hook = hook
        door._compile_faults_armed = False   # hook fires regardless
        with pytest.raises(RequestRejected) as ei:
            await door.submit("a", rng.integers(0, 2, (4, g.n_inputs))
                              .astype(bool))
        assert ei.value.reason.code == "compile_failed"
        assert door.metrics()["retries"] == 0
        await door.stop(drain=True)

    _run(go())


def test_error_taxonomy_classification():
    assert is_transient(TransientCompileError("x"))
    assert not is_transient(PermanentCompileError("x"))
    assert not is_transient(ValueError("x"))
    assert TransientCompileError.retryable
    assert not PermanentCompileError.retryable


def test_eviction_storm_mid_flight_recovers(rng):
    """evict_rate=1: every wave is preceded by an LRU eviction, so every
    wave recompiles mid-flight — results stay bit-exact and nothing
    wedges (the paper-scale 'recompile storm')."""
    g_a, g_b = _graph(rng), _graph(rng, n_in=10, n_gates=150, n_out=6)

    async def go():
        door = _door(fault_policy=FaultPolicy(seed=3, evict_rate=1.0))
        door.register("a", g_a)
        door.register("b", g_b)
        async with door:
            for i in range(4):
                for name, g in (("a", g_a), ("b", g_b)):
                    bits = rng.integers(0, 2, (5 + i, g.n_inputs)) \
                        .astype(bool)
                    out = await door.submit(name, bits)
                    assert (out == g.evaluate(bits)).all()
        assert door.fault_policy.injected["evict"] > 0
        assert door.engine.cache.misses > 2      # storms forced recompiles

    _run(go())


def test_fault_policy_seeded_determinism():
    a = FaultPolicy(seed=42, drop_rate=0.3, delay_rate=0.3)
    b = FaultPolicy(seed=42, drop_rate=0.3, delay_rate=0.3)
    seq_a = [(a.take_drop(), a.take_delay()) for _ in range(50)]
    seq_b = [(b.take_drop(), b.take_delay()) for _ in range(50)]
    assert seq_a == seq_b
    assert a.injected == b.injected
    with pytest.raises(ValueError):
        FaultPolicy(drop_rate=1.5)


# ---------------------------------------------------------------------------
# fairness
# ---------------------------------------------------------------------------

def test_flooding_tenant_does_not_starve_other(rng):
    """Tenant a floods; tenant b's requests still complete promptly via
    round-robin dispatch + a's inflight cap."""
    g_a, g_b = _graph(rng, n_gates=300), _graph(rng, n_in=10, n_out=6)

    async def go():
        door = _door(max_queue=256, dispatch_batch=4)
        door.register("a", g_a, max_inflight=2)
        door.register("b", g_b)
        await _warm(door, [("a", g_a), ("b", g_b)], rng)
        flood = [asyncio.create_task(door.submit(
            "a", rng.integers(0, 2, (32, g_a.n_inputs)).astype(bool)))
            for _ in range(40)]
        await asyncio.sleep(0)
        bits = rng.integers(0, 2, (8, g_b.n_inputs)).astype(bool)
        out = await door.submit("b", bits)
        assert (out == g_b.evaluate(bits)).all()
        # b completed while most of a's flood was still queued/inflight
        assert sum(not t.done() for t in flood) > 0, \
            "flood drained before b was served — can't observe fairness"
        await asyncio.gather(*flood)
        await door.stop(drain=True)

    _run(go())


# ---------------------------------------------------------------------------
# the acceptance integration test: graceful degradation at 2x load
# ---------------------------------------------------------------------------

def test_graceful_degradation_at_2x_load_with_faults(rng):
    """At ~2x sustainable offered load with fault injection on (eviction
    storm + injected dispatch delay): the p99 of ADMITTED requests stays
    bounded (<= 3x the unloaded p99, plus an absolute scheduling-noise
    floor), every rejection carries a machine-readable shed reason,
    zero requests hang, zero requests cross tenants, and the traffic
    report carries the serve.traffic.* counters."""
    g_a = _graph(rng, n_in=14, n_gates=250, n_out=8)
    g_b = _graph(rng, n_in=10, n_gates=180, n_out=6)
    n = 150 if STRESS else 50

    async def go():
        fault = FaultPolicy(seed=5, evict_rate=0.2, delay_rate=0.1,
                            delay_s=0.002)
        door = FrontDoor(spec=CompileSpec(n_unit=16), capacity=128,
                         max_queue=16, default_deadline_s=0.5,
                         fault_policy=fault)
        door.register("a", g_a, max_inflight=8)
        door.register("b", g_b, max_inflight=8)
        tenants = [("a", g_a), ("b", g_b)]
        await _warm(door, tenants, rng, waves=6)

        # unloaded p99: sequential closed-loop requests, no queueing
        for name, g in tenants * 10:
            bits = rng.integers(0, 2, (24, g.n_inputs)).astype(bool)
            out = await door.submit(name, bits, deadline_s=60.0)
            assert (out == g.evaluate(bits)).all()
        unloaded_p99 = door.metrics()["latency_p99_ms"]
        door.reset_metrics()

        # sustainable rate ~ capacity / wave_time; offer ~2x that,
        # split across tenants, one Poisson + one heavy-tail
        wave = door.wave_s
        sustainable_rps = door.engine.capacity / max(wave, 1e-4) / 24
        rate = 2.0 * sustainable_rps / 2
        trace = build_trace([
            TrafficPattern(tenant="a", rate_rps=rate, n_requests=n,
                           size_mean=24, size_max=96, deadline_s=0.4),
            TrafficPattern(tenant="b", rate_rps=rate, n_requests=n,
                           arrival="pareto", pareto_alpha=1.5,
                           size_mean=24, size_max=96, deadline_s=0.4),
        ], seed=17)
        report = await run_trace(door, trace, seed=19)
        await door.stop(drain=True)
        return unloaded_p99, report, door

    unloaded_p99, report, door = _run(go())

    # zero hangs: every offered request resolved one way or the other
    assert report.completed + report.shed == report.offered == 2 * (
        150 if STRESS else 50)
    # every rejection machine-readable
    assert all(code in SHED_CODES for code in report.shed_by_code)
    # the serve.traffic.* counters all materialized
    d = report.to_dict()
    for key in ("p50_ms", "p99_ms", "goodput_samples_per_s", "shed_rate",
                "deadline_miss_rate"):
        assert key in d
    # overloaded: the door actually shed / degraded rather than queueing
    # without bound (2x load MUST not complete everything in-deadline)
    assert report.shed > 0 or report.deadline_missed > 0
    # graceful: admitted p99 bounded by 3x unloaded p99 plus an absolute
    # floor for container scheduling noise (the deadline/shed machinery
    # is what enforces this — queued work beyond it was rejected)
    if report.p99_ms is not None:
        bound = 3.0 * unloaded_p99 + 75.0
        assert report.p99_ms <= bound, \
            f"admitted p99 {report.p99_ms:.1f}ms > bound {bound:.1f}ms " \
            f"(unloaded {unloaded_p99:.1f}ms)"
    # degradation ran under real faults
    assert door.fault_policy.injected["evict"] > 0 or \
        door.fault_policy.injected["delay"] > 0


# ---------------------------------------------------------------------------
# traffic generator
# ---------------------------------------------------------------------------

def test_trace_deterministic_and_sorted():
    pats = [TrafficPattern(tenant="a", rate_rps=200, n_requests=40),
            TrafficPattern(tenant="b", rate_rps=100, n_requests=30,
                           arrival="pareto")]
    t1, t2 = build_trace(pats, seed=1), build_trace(pats, seed=1)
    assert t1 == t2
    assert t1 != build_trace(pats, seed=2)
    assert all(t1[i].t <= t1[i + 1].t for i in range(len(t1) - 1))
    assert {r.tenant for r in t1} == {"a", "b"}
    # ragged sizes: not all multiples of 32
    assert any(r.n_samples % 32 for r in t1)


def test_interarrival_rates_match():
    rng = np.random.default_rng(0)
    for arrival in ("poisson", "pareto"):
        pat = TrafficPattern(tenant="a", rate_rps=50.0, arrival=arrival,
                             n_requests=1)
        gaps = interarrivals(pat, 20_000, rng)
        assert gaps.min() >= 0
        # long-run rate within 10% of the configured mean
        assert abs(gaps.mean() - 0.02) < 0.002, arrival


def test_traffic_pattern_validation():
    with pytest.raises(ValueError):
        TrafficPattern(tenant="a", arrival="bursty")
    with pytest.raises(ValueError):
        TrafficPattern(tenant="a", pareto_alpha=1.0)
    with pytest.raises(ValueError):
        TrafficPattern(tenant="a", rate_rps=0)


# ---------------------------------------------------------------------------
# ProgramCache thread-safety (satellite): concurrent engines, one cache
# ---------------------------------------------------------------------------

def test_program_cache_thread_safe_under_contention(rng):
    """Threads hammer get/evict on a shared bounded cache: no
    exceptions, no corrupted entries, and every returned artifact still
    executes its own graph bit-exactly (LRU eviction racing entry
    construction was the PR-6 motivating bug)."""
    graphs = [_graph(rng, n_gates=60 + 7 * i, n_out=5) for i in range(6)]
    oracle = {g.fingerprint(): g for g in graphs}
    cache = ProgramCache(max_entries=3)
    spec = CompileSpec(n_unit=8, optimize="none")
    errors: list[BaseException] = []
    barrier = threading.Barrier(4)

    def worker(seed: int) -> None:
        r = np.random.default_rng(seed)
        barrier.wait()
        try:
            for i in range(40):
                g = graphs[int(r.integers(len(graphs)))]
                entry = cache.get(g, spec)
                got = oracle[entry.artifact.graph.fingerprint()]
                bits = r.integers(0, 2, (4, got.n_inputs)).astype(bool)
                assert (entry.artifact.execute(bits)
                        == got.evaluate(bits)).all()
                if i % 7 == 0:
                    cache.evict()
        except BaseException as exc:     # surfaced on the main thread
            errors.append(exc)

    threads = [threading.Thread(target=worker, args=(s,)) for s in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120)
    assert not errors, errors
    assert len(cache) <= 3
    assert cache.stats()["entries"] == len(cache)


def test_program_cache_evict_api(rng):
    cache = ProgramCache()
    assert cache.evict() is None                    # empty: nothing to do
    g = _graph(rng, n_gates=50)
    entry = cache.get(g, CompileSpec(n_unit=8))
    assert cache.evict(("nope",)) is None           # unknown key
    assert cache.evict(entry.key) == entry.key
    assert len(cache) == 0
    cache.get(g, CompileSpec(n_unit=8))
    assert cache.evict() is not None                # LRU eviction
    assert len(cache) == 0


# ---------------------------------------------------------------------------
# SlotTable under cancellation (satellite): leak-freedom + isolation
# ---------------------------------------------------------------------------

def _slot_invariants(table: SlotTable, active: dict) -> None:
    held = [r for rows in active.values() for r in rows.tolist()]
    assert len(held) == len(set(held)), "row handed to two requests"
    assert table.n_active == len(held)
    assert table.n_active + table.n_free == table.capacity
    assert all(0 <= r < table.capacity for r in held)


def _slot_script(capacity: int, ops: list) -> None:
    """Replay (acquire n | cancel i | retire i) ops, checking invariants
    after every op: cancelling mid-wave and retiring ragged requests
    must never leak rows and never alias another request's rows."""
    table = SlotTable(capacity)
    active: dict[int, np.ndarray] = {}
    uid = 0
    for kind, arg in ops:
        if kind == "acquire":
            rows = table.acquire(arg)
            if arg > table.capacity - sum(len(v) for v in active.values()):
                assert rows is None
            if rows is not None:
                assert len(rows) == arg
                active[uid] = rows
                uid += 1
        elif active:        # cancel/retire both release; order differs
            keys = sorted(active)
            key = keys[arg % len(keys)]
            table.release(active.pop(key))
        _slot_invariants(table, active)
    for rows in active.values():        # drain: nothing leaked
        table.release(rows)
    assert table.n_free == capacity and table.n_active == 0
    full = table.acquire(capacity)      # every row really came back
    assert full is not None and len(set(full.tolist())) == capacity


def test_slot_table_cancellation_deterministic(rng):
    """Seeded fuzz (always runs): ragged acquire sizes incl. 0 and
    over-capacity, interleaved with cancellations."""
    for seed in range(5):
        r = np.random.default_rng(seed)
        ops = []
        for _ in range(120):
            if r.random() < 0.6:
                ops.append(("acquire", int(r.integers(0, 40))))
            else:
                ops.append(("cancel", int(r.integers(0, 1 << 30))))
        _slot_script(int(r.integers(1, 97)), ops)


def test_slot_table_double_release_and_range_guard():
    t = SlotTable(8)
    rows = t.acquire(4)
    t.release(rows)
    with pytest.raises(RuntimeError):
        t.release(rows)                  # cancel-after-retire must be loud
    with pytest.raises(ValueError):
        t.release(np.array([99]))


if HAVE_HYPOTHESIS:
    @settings(max_examples=60, deadline=None)
    @given(
        capacity=st.integers(min_value=1, max_value=96),
        ops=st.lists(
            st.one_of(
                st.tuples(st.just("acquire"),
                          st.integers(min_value=0, max_value=48)),
                st.tuples(st.just("cancel"),
                          st.integers(min_value=0, max_value=1 << 30))),
            max_size=200))
    def test_hypothesis_slot_table_never_leaks(capacity, ops):
        _slot_script(capacity, list(ops))


# ---------------------------------------------------------------------------
# O(1) claim path (satellite): retained-set + lazy compaction
# ---------------------------------------------------------------------------

def test_claim_bookkeeping_stays_bounded_under_churn(rng):
    """High request churn with claim-newest-first (the worst case for
    head-compaction): the finished-order deque must stay within a
    constant factor of the live retained set — the O(n) deque.remove is
    gone and nothing accumulates."""
    g = _graph(rng, n_in=6, n_gates=40, n_out=4)
    eng = LogicEngine(CompileSpec(n_unit=8), capacity=32)
    live: list[int] = []
    for i in range(120):
        live.append(eng.submit(g, rng.integers(0, 2, (3, 6)).astype(bool)))
        eng.drain()
        if len(live) > 4:               # always claim the NEWEST first
            eng.result(live.pop())
            eng.result(live.pop())
        assert len(eng._finished_order) <= 2 * len(eng._retained) + 8
    for uid in live:
        eng.result(uid)
    assert not eng._retained and not eng._requests
    assert len(eng._finished_order) <= 8


def test_max_retained_counts_only_unclaimed_after_refactor(rng):
    """Claimed uids are stale deque entries: they must not consume
    max_retained slots nor resurrect on later retires."""
    g = _graph(rng, n_in=6, n_gates=40, n_out=4)
    eng = LogicEngine(CompileSpec(n_unit=8), capacity=32, max_retained=3)
    uids = []
    for _ in range(3):
        uids.append(eng.submit(g, rng.integers(0, 2, (2, 6)).astype(bool)))
        eng.drain()
    eng.result(uids[1])                  # claim the middle one
    for _ in range(2):                   # two more: u0,u2 + 2 new = 4 > 3
        uids.append(eng.submit(g, rng.integers(0, 2, (2, 6)).astype(bool)))
        eng.drain()
    with pytest.raises(KeyError):
        eng.result(uids[0])              # oldest unclaimed was dropped
    with pytest.raises(KeyError):
        eng.result(uids[1])              # claimed: gone, not resurrected
    for uid in uids[2:]:
        assert eng.result(uid).shape == (2, 4)
