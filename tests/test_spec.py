"""CompileSpec / LogicCompiler: the one declarative compilation target.

Covers the DESIGN.md §8 contracts: validation, functional updates,
cache-key canonicity (the single cache-keying code path), JSON
round-trip, the pinned canonical defaults and paper-exact preset, the
``n_unit="auto"`` design-space resolution, the typed ``LayerLoad``
search API, and the deprecation shim (old kwargs -> byte-identical
programs + exactly one warning).
"""
import json
import warnings

import numpy as np
import pytest

from repro.core.compiler import CompiledArtifact, LogicCompiler
from repro.core.cost_model import CostModel, FfclStats, LayerLoad
from repro.core.gate_ir import random_graph
from repro.core.opt import PassManager
from repro.core.optimizer import binary_search, sweep
from repro.core.partition import compile_partitions, partition
from repro.core.scheduler import compile_graph
from repro.core.spec import CompileSpec, DEPRECATION_PREFIX
from repro.serve import LogicEngine, ProgramCache


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def _graph(rng, n_in=12, n_gates=300, n_out=10):
    return random_graph(rng, n_in, n_gates, n_out, locality=48)


def _same_streams(a, b) -> bool:
    return (a.n_addr == b.n_addr and
            (a.src_a == b.src_a).all() and (a.src_b == b.src_b).all() and
            (a.dst == b.dst).all() and (a.opcode == b.opcode).all() and
            (a.output_addrs == b.output_addrs).all())


# ---------------------------------------------------------------------------
# validation + canonical defaults
# ---------------------------------------------------------------------------

def test_canonical_defaults_pinned():
    """THE defaults live on CompileSpec (consumers stopped declaring
    their own): liveness allocation, both scheduler layout knobs on,
    the default pass pipeline, monolithic."""
    s = CompileSpec()
    assert s.n_unit == 64
    assert s.alloc == "liveness"
    assert s.opcode_sort is True and s.fuse_levels is True
    assert s.pipeline == PassManager.default()
    assert s.max_gates is None


def test_paper_exact_preset_pinned():
    """The paper-faithful target: eq. 23 layout (no fusion, no opcode
    sort), raw factoring, direct (§6.3 address == wire) allocation."""
    s = CompileSpec.paper_exact(8)
    assert s.n_unit == 8
    assert s.alloc == "direct"
    assert s.opcode_sort is False and s.fuse_levels is False
    assert s.pipeline is None and s.optimize == "none"
    assert s.max_gates is None


@pytest.mark.parametrize("bad", [
    dict(n_unit=0), dict(n_unit=-3), dict(n_unit="many"), dict(n_unit=2.5),
    dict(n_unit=True), dict(alloc="greedy"), dict(opcode_sort=1),
    dict(fuse_levels="yes"), dict(max_gates=0), dict(max_gates=-1),
    dict(max_gates=True), dict(optimize="bogus"), dict(optimize=42),
])
def test_validation_rejects(bad):
    with pytest.raises(ValueError):
        CompileSpec(**bad)


def test_optimize_normalized_at_construction():
    """Equivalent spellings construct EQUAL specs."""
    assert CompileSpec(optimize="default") == \
        CompileSpec(optimize=PassManager.default())
    assert CompileSpec(optimize=True) == CompileSpec(optimize="default")
    assert CompileSpec(optimize=None) == CompileSpec(optimize=False) \
        == CompileSpec(optimize="none")
    assert CompileSpec(optimize="none").pipeline is None
    # hashable (usable directly as a dict key)
    assert {CompileSpec(): 1}[CompileSpec(optimize="default")] == 1


def test_with_is_functional():
    s = CompileSpec(n_unit=16)
    t = s.with_(n_unit=32, alloc="direct")
    assert (t.n_unit, t.alloc) == (32, "direct")
    assert (s.n_unit, s.alloc) == (16, "liveness")   # original untouched
    with pytest.raises(TypeError):
        s.with_(n_units=8)                           # typo'd field
    with pytest.raises(ValueError):
        s.with_(n_unit=0)                            # updates re-validate


# ---------------------------------------------------------------------------
# cache keying: the single code path
# ---------------------------------------------------------------------------

def test_cache_key_stable_across_equivalent_constructions():
    k1 = CompileSpec(n_unit=16, optimize="default").cache_key()
    k2 = CompileSpec(n_unit=16, optimize=PassManager.default()).cache_key()
    assert k1 == k2
    assert CompileSpec(n_unit=16).cache_key() != \
        CompileSpec(n_unit=32).cache_key()
    assert CompileSpec(n_unit=16).cache_key() != \
        CompileSpec(n_unit=16, optimize="none").cache_key()
    # every stream-shaping knob participates (the old hand-built tuple
    # silently missed opcode_sort/fuse_levels)
    assert CompileSpec(n_unit=16).cache_key() != \
        CompileSpec(n_unit=16, fuse_levels=False).cache_key()
    assert CompileSpec(n_unit=16).cache_key() != \
        CompileSpec(n_unit=16, opcode_sort=False).cache_key()


def test_cache_key_requires_resolved_n_unit():
    with pytest.raises(ValueError, match="auto"):
        CompileSpec(n_unit="auto").cache_key()


def test_normalize_unbinding_budget(rng):
    g = _graph(rng, n_gates=80)
    s = CompileSpec(n_unit=8, optimize="none", max_gates=400)
    assert s.normalize(g).max_gates is None          # 80 <= 400
    assert s.with_(max_gates=30).normalize(g).max_gates == 30
    assert s.normalize(g).cache_key() == \
        s.with_(max_gates=None).cache_key()


def test_program_cache_key_of_uses_spec_key(rng):
    """ProgramCache.key_of == (fingerprint, normalized spec.cache_key())
    — no second keying code path."""
    g = _graph(rng, n_gates=80)
    s = CompileSpec(n_unit=8, optimize="none", max_gates=10 ** 6)
    assert ProgramCache.key_of(g, s) == \
        (g.fingerprint(), s.normalize(g).cache_key())
    cache = ProgramCache()
    entry = cache.get(g, s)
    assert entry.key == ProgramCache.key_of(g, s)


# ---------------------------------------------------------------------------
# JSON round-trip
# ---------------------------------------------------------------------------

def test_json_round_trip():
    for s in (CompileSpec(),
              CompileSpec(n_unit="auto", max_gates=500),
              CompileSpec.paper_exact(128),
              CompileSpec(n_unit="auto", objective="wallclock"),
              CompileSpec(n_unit=7, alloc="direct", opcode_sort=False,
                          optimize="none")):
        d = json.loads(json.dumps(s.to_dict()))     # through real JSON
        assert CompileSpec.from_dict(d) == s


def test_objective_validated_and_default_pinned():
    assert CompileSpec().objective == "cycles"
    assert CompileSpec(objective="wallclock").objective == "wallclock"
    for bad in ("seconds", "", 1, None):
        with pytest.raises(ValueError):
            CompileSpec(objective=bad)


def test_explicit_cycles_objective_byte_identical_to_default():
    """The paper-exact default must stay byte-identical: an explicit
    objective="cycles" spec serializes, cache-keys, and compares exactly
    like a spec that never mentioned the field — so every historical
    serialized spec, cache key, and store artifact is unchanged."""
    default, explicit = CompileSpec(n_unit=16), CompileSpec(
        n_unit=16, objective="cycles")
    assert default == explicit
    assert default.to_dict() == explicit.to_dict()
    assert "objective" not in default.to_dict()
    assert json.dumps(default.to_dict(), sort_keys=True) == \
        json.dumps(explicit.to_dict(), sort_keys=True)
    assert default.cache_key() == explicit.cache_key()


def test_objective_excluded_from_cache_key():
    """The objective steers WHICH n_unit the DSE picks; once resolved,
    the compiled streams depend only on the resolved spec — the same
    (graph, resolved spec) must land on one cache entry regardless of
    which objective chose it."""
    a = CompileSpec(n_unit=16, objective="wallclock")
    b = CompileSpec(n_unit=16)
    assert a.cache_key() == b.cache_key()
    assert a.to_dict()["objective"] == "wallclock"   # but it serializes


def test_json_rejects_custom_pipeline_and_unknown_keys():
    custom = PassManager([PassManager.default().passes[0]], name="custom")
    with pytest.raises(ValueError, match="custom"):
        CompileSpec(optimize=custom).to_dict()
    with pytest.raises(ValueError, match="unknown"):
        CompileSpec.from_dict({"n_units": 8})


# ---------------------------------------------------------------------------
# LayerLoad + search robustness
# ---------------------------------------------------------------------------

def test_layer_load_tuple_shim(rng):
    stats = FfclStats.from_graph(_graph(rng))
    model = CostModel()
    typed = [LayerLoad(stats, n_copies=4, n_input_vectors=128)]
    legacy = [(stats, 4, 128)]
    assert model.network_cycles(typed, 32) == model.network_cycles(legacy, 32)
    # LayerLoad unpacks like the tuple it replaced
    st, m, nv = typed[0]
    assert (st, m, nv) == (stats, 4, 128)
    with pytest.raises(ValueError):
        LayerLoad(stats, n_copies=0)
    with pytest.raises(ValueError):
        LayerLoad(stats, n_input_vectors=0)


def test_binary_search_degenerate_ranges(rng):
    stats = FfclStats.from_graph(_graph(rng))
    layers = [LayerLoad(stats, 4, 128)]
    model = CostModel()
    for lo, hi in ((1, 1), (1, 2), (1, 3), (4, 5), (7, 7)):
        res = binary_search(model, layers, n_unit_max=hi, n_unit_min=lo)
        assert lo <= res.best_n_unit <= hi
        probed = [u for u, _ in res.evaluations]
        assert min(probed) >= lo and max(probed) <= hi
        assert len(probed) == len(set(probed))       # each probe recorded once
        # degenerate range == exhaustive enumeration
        exhaustive = sweep(model, layers, list(range(lo, hi + 1)))
        assert res.best_n_unit == exhaustive.best_n_unit
    with pytest.raises(ValueError):
        binary_search(model, layers, n_unit_max=0)
    with pytest.raises(ValueError):
        binary_search(model, layers, n_unit_max=4, n_unit_min=5)
    with pytest.raises(ValueError):
        binary_search(model, layers, n_unit_max=4, n_unit_min=0)
    with pytest.raises(ValueError):
        sweep(model, layers, [])


# ---------------------------------------------------------------------------
# n_unit="auto": the §7.2 search as a spec value
# ---------------------------------------------------------------------------

def test_auto_n_unit_matches_manual_binary_search(rng):
    g = _graph(rng, n_gates=500)
    compiler = LogicCompiler(n_unit_max=512, n_input_vectors=256)
    spec = CompileSpec(n_unit="auto", optimize="none")
    art = compiler.compile(g, spec)
    # the manual workflow the spec value replaces
    manual = binary_search(CostModel(),
                           [LayerLoad(FfclStats.from_graph(g), 1, 256)],
                           n_unit_max=512)
    assert art.spec.n_unit == manual.best_n_unit
    assert art.search is not None
    assert art.search.best_n_unit == manual.best_n_unit
    assert art.programs[0].n_unit == manual.best_n_unit
    # compiled artifact still computes the function
    X = rng.integers(0, 2, (64, g.n_inputs)).astype(bool)
    assert (art.execute(X) == g.evaluate(X)).all()


def test_auto_n_unit_through_engine(rng):
    """End to end: an auto-spec engine resolves per graph, serves
    bit-exactly, and cache-keys on the resolved unit count."""
    g = _graph(rng, n_gates=400)
    eng = LogicEngine(CompileSpec(n_unit="auto"), capacity=64)
    X = rng.integers(0, 2, (40, g.n_inputs)).astype(bool)
    assert (eng.serve(g, X) == g.evaluate(X)).all()
    (entry,) = eng.cache._entries.values()
    assert isinstance(entry.spec.n_unit, int)
    opt_g = eng.cache._optimized(g, eng.spec)
    manual = binary_search(
        eng.cache.compiler.model,
        [LayerLoad(FfclStats.from_graph(opt_g), 1,
                   eng.cache.compiler.n_input_vectors)],
        n_unit_max=eng.cache.compiler.n_unit_max)
    assert entry.spec.n_unit == manual.best_n_unit
    assert (eng.serve(g, X) == g.evaluate(X)).all()  # cache hit path
    assert eng.cache.misses == 1 and eng.cache.hits >= 1


def test_compile_graph_rejects_auto(rng):
    with pytest.raises(ValueError, match="LogicCompiler"):
        compile_graph(_graph(rng), CompileSpec(n_unit="auto"))


# ---------------------------------------------------------------------------
# LogicCompiler: the unified compile path
# ---------------------------------------------------------------------------

def test_compiler_monolithic_vs_partitioned(rng):
    g = _graph(rng, n_gates=400)
    compiler = LogicCompiler()
    mono = compiler.compile(g, CompileSpec(n_unit=16, optimize="none"))
    assert isinstance(mono, CompiledArtifact)
    assert not mono.partitioned and mono.program.n_unit == 16
    part = compiler.compile(
        g, CompileSpec(n_unit=16, optimize="none", max_gates=150))
    assert part.partitioned
    X = rng.integers(0, 2, (50, g.n_inputs)).astype(bool)
    want = g.evaluate(X)
    assert (mono.execute(X) == want).all()
    assert (part.execute(X) == want).all()
    with pytest.raises(ValueError):
        part.program                                  # pipeline, not mono
    st = part.stats()
    assert st["n_programs"] == len(part.programs) >= 2
    assert st["spec"] == part.spec.to_dict()


def test_compiler_matches_direct_compile_graph(rng):
    """The facade's monolithic path emits byte-identical streams to the
    scheduler primitive (one compile path, not a fourth)."""
    g = _graph(rng)
    spec = CompileSpec(n_unit=16)
    assert _same_streams(LogicCompiler().compile(g, spec).programs[0],
                         compile_graph(g, spec))


def test_partition_accepts_spec(rng):
    g = _graph(rng, n_gates=400)
    spec = CompileSpec(max_gates=150, optimize="default")
    parts = partition(g, spec)
    assert len(parts) >= 2
    raw_parts = partition(g, 150)
    assert [p.output_indices for p in parts] == \
        [p.output_indices for p in raw_parts]
    progs = compile_partitions(parts, CompileSpec(n_unit=8))
    assert all(p.n_unit == 8 for p in progs)
    with pytest.raises(ValueError, match="max_gates"):
        partition(g, CompileSpec())                   # budget-less spec


# ---------------------------------------------------------------------------
# deprecation shim: old kwargs still work, warn once, byte-identical
# ---------------------------------------------------------------------------

def _one_legacy_warning(w):
    legacy = [i for i in w if issubclass(i.category, DeprecationWarning)
              and str(i.message).startswith(DEPRECATION_PREFIX)]
    return len(legacy) == 1


def test_shim_compile_graph_byte_identical(rng):
    g = _graph(rng)
    new = compile_graph(g, CompileSpec(n_unit=16, alloc="direct",
                                       fuse_levels=False))
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        old = compile_graph(g, n_unit=16, alloc="direct", fuse_levels=False)
    assert _one_legacy_warning(w)
    assert _same_streams(old, new)


def test_shim_legacy_positional_n_unit(rng):
    g = _graph(rng)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        old = compile_graph(g, 16)
    assert _one_legacy_warning(w)
    assert _same_streams(old, compile_graph(g, CompileSpec(n_unit=16)))


def test_shim_unspecified_kwargs_take_canonical_defaults(rng):
    """The documented default unification: a legacy call now fills the
    gaps with CompileSpec defaults (liveness + default pipeline), NOT
    the old per-entry-point ones."""
    g = _graph(rng)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        old = compile_graph(g, n_unit=16)
    assert _one_legacy_warning(w)
    assert _same_streams(old, compile_graph(g, CompileSpec(n_unit=16)))


def test_shim_engine_and_cache_parity(rng):
    g = _graph(rng)
    X = rng.integers(0, 2, (30, g.n_inputs)).astype(bool)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        old_eng = LogicEngine(n_unit=16, alloc="liveness")
    assert _one_legacy_warning(w)
    new_eng = LogicEngine(CompileSpec(n_unit=16))
    assert old_eng.spec == new_eng.spec
    assert (old_eng.serve(g, X) == new_eng.serve(g, X)).all()

    cache = ProgramCache()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        old_entry = cache.get(g, 16, "liveness", None, pipeline=None)
    assert _one_legacy_warning(w)
    new_entry = ProgramCache().get(g, CompileSpec(n_unit=16,
                                                  optimize="none"))
    assert old_entry.key == new_entry.key
    assert _same_streams(old_entry.programs[0], new_entry.programs[0])


def test_shim_rejects_mixing_spec_and_kwargs(rng):
    g = _graph(rng)
    with pytest.raises(TypeError, match="not both"):
        compile_graph(g, CompileSpec(n_unit=8), n_unit=16)
    with pytest.raises(TypeError, match="not both"):
        LogicEngine(CompileSpec(n_unit=8), alloc="direct")


def test_legacy_positional_alloc_rejected_loudly(rng):
    """The pre-spec 3rd positional was alloc; it must not silently bind
    to the lv parameter and compile with the wrong allocator."""
    g = _graph(rng)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        with pytest.raises(TypeError, match="alloc"):
            compile_graph(g, 16, "direct")


def test_classifier_default_engine_honors_budget(rng):
    """build_classifier's contract: spec.max_gates rides along to the
    (default) engine backend and partitions the composed stack."""
    from repro.flow import build_classifier
    params = {
        "w0": rng.normal(size=(6, 5)).astype(np.float32),
        "b0": rng.normal(size=5).astype(np.float32),
        "w1": rng.normal(size=(5, 2)).astype(np.float32),
        "b1": np.zeros(2, np.float32),
    }
    x = rng.integers(0, 2, (40, 6)).astype(np.uint8)
    clf = build_classifier(params, 2, x, CompileSpec(n_unit=8, max_gates=2))
    from repro.flow.classifier import input_bits
    bits = input_bits(x)
    ref = clf.hidden_bits(bits, backend="reference")
    got = clf.hidden_bits(bits, backend="engine")     # default engine
    assert (got == ref).all()
    eng = clf._serve_engine()
    assert eng.max_gates == 2
    (entry,) = eng.cache._entries.values()
    assert entry.partitioned                          # budget really bound


def test_auto_resolution_memoized_on_hit_path(rng):
    """Repeat traffic must not re-run the design-space search: after the
    first request the registry's hot path never touches the compiler."""
    g = _graph(rng, n_gates=400)
    eng = LogicEngine(CompileSpec(n_unit="auto"), capacity=64)
    X = rng.integers(0, 2, (20, g.n_inputs)).astype(bool)
    eng.serve(g, X)

    class _Poison:
        def resolve(self, *a, **k):
            raise AssertionError("hit path re-ran the DSE search")

        def compile(self, *a, **k):
            raise AssertionError("hit path recompiled")

    eng.cache.compiler = _Poison()
    assert (eng.serve(g, X) == g.evaluate(X)).all()   # memoized resolution
    assert eng.cache.hits >= 1


def test_cross_pipeline_engines_share_entry(rng):
    """optimize's effect lives in the post-optimization fingerprint, so a
    default-pipeline engine (raw graph in) and a none-pipeline engine
    (optimized netlist in) must land on ONE registry entry."""
    from repro.core.opt import PassManager
    from repro.serve import ProgramCache
    g = _graph(rng)
    g_opt = PassManager.default().run(g).graph
    cache = ProgramCache()
    a = LogicEngine(CompileSpec(n_unit=16), capacity=32, cache=cache)
    b = LogicEngine(CompileSpec(n_unit=16, optimize="none"), capacity=32,
                    cache=cache)
    X = rng.integers(0, 2, (20, g.n_inputs)).astype(bool)
    assert (a.serve(g, X) == g.evaluate(X)).all()
    assert (b.serve(g_opt, X) == g.evaluate(X)).all()
    assert len(cache) == 1 and cache.misses == 1 and cache.hits >= 1


def test_shim_explicit_optimize_none_still_means_none(rng):
    """optimize=None was a legal old spelling of 'no optimization' and
    must not fall through to the default pipeline."""
    g = _graph(rng)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        old = compile_graph(g, n_unit=16, optimize=None)
    assert _one_legacy_warning(w)
    assert _same_streams(
        old, compile_graph(g, CompileSpec(n_unit=16, optimize="none")))
