"""Wall-clock calibration (core/calibrate.py) + objective threading.

What must hold (DESIGN.md §12): phase-timing hooks cost nothing when
disabled and record a bit-identical execution when enabled; degenerate
fit inputs raise typed ``CalibrationError`` (never a NaN factor steering
the DSE); calibrations round-trip through JSON and the artifact store
with corruption failing loudly; ``objective="wallclock"`` threads
through spec -> compiler -> serving with an explicit cycles fallback;
and the plateau-edge ``binary_search`` agrees EXACTLY with an
exhaustive sweep under both objectives (deterministic seeds always;
hypothesis widens the coverage when installed).
"""
import json
import math

import numpy as np
import pytest

from repro.core import calibrate
from repro.core.calibrate import (Calibration, CalibrationError, PHASES,
                                  PHASE_REGRESSORS, PhaseFit, PhaseProbe,
                                  PhaseTimer, WallClockModel, fit_calibration,
                                  phase_terms)
from repro.core.compiler import LogicCompiler
from repro.core.cost_model import (CostModel, FfclStats, LayerLoad,
                                   n_subkernels)
from repro.core.gate_ir import random_graph
from repro.core.optimizer import binary_search, sweep
from repro.core.scheduler import compile_graph, execute_program_np
from repro.core.spec import CompileSpec

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:           # tier-1 containers may lack hypothesis
    HAVE_HYPOTHESIS = False


MODEL = CostModel()


def _graph(seed=0, n_in=12, n_gates=150, n_out=8):
    return random_graph(np.random.default_rng(seed), n_in, n_gates, n_out,
                        locality=32)


def _probe(label, stats, n_unit, measured, n_gates=100):
    return PhaseProbe(label=label, n_unit=n_unit, n_input_vectors=256,
                      n_gates=n_gates,
                      terms=phase_terms(MODEL, stats, n_unit, 256),
                      measured=measured)


def _linear_probes(coefs=None, offsets=None, units=(4, 8, 16, 32, 64),
                   graph_seeds=(0, 1, 2)):
    """Probes whose measurements are EXACTLY linear in the phase
    regressors — the fit must recover them to machine precision.

    Spans BOTH grid axes (workload x n_unit): pack/unpack regressors are
    n_unit-independent, so a single-graph grid would be zero-variance.
    """
    coefs = coefs or {p: tuple(1e-7 * (i + 1)
                               for i in range(len(PHASE_REGRESSORS[p])))
                      for p in PHASES}
    offsets = offsets or {p: 1e-4 for p in PHASES}
    probes = []
    for seed in graph_seeds:
        # pack/unpack regressors scale with the input/output widths, so
        # the workload axis must vary those (mirrors default_probe_graphs)
        stats = FfclStats.from_graph(
            _graph(seed=seed, n_in=12 + 8 * seed, n_gates=100 + 80 * seed,
                   n_out=6 + 4 * seed))
        for u in units:
            terms = phase_terms(MODEL, stats, u, 256)
            measured = {p: sum(c * t for c, t in zip(coefs[p], terms[p]))
                        + offsets[p] for p in PHASES}
            probes.append(_probe("lin", stats, u, measured))
    return probes, coefs, offsets


def _synthetic_calibration():
    """A hand-built calibration (no measurement) for objective tests."""
    fits = {p: PhaseFit(coefs=tuple(1e-7 for _ in PHASE_REGRESSORS[p]),
                        offset=1e-4, n_probes=5, median_abs_rel_err=0.01)
            for p in PHASES}
    return Calibration(fits=fits, meta={"synthetic": True})


# ---------------------------------------------------------------------------
# phase-timing hooks
# ---------------------------------------------------------------------------

def test_phase_timer_disabled_by_default():
    assert calibrate.active_timer() is None


def test_phase_timer_records_pallas_path_bit_identical():
    from repro.kernels.logic_dsp.ops import logic_infer_bits
    g = _graph()
    prog = compile_graph(g, CompileSpec(n_unit=16, optimize="none"))
    rng = np.random.default_rng(1)
    bits = rng.integers(0, 2, (64, g.n_inputs)).astype(bool)
    plain = logic_infer_bits(prog, bits)
    with PhaseTimer() as t:
        timed = logic_infer_bits(prog, bits)
    assert calibrate.active_timer() is None          # restored on exit
    assert (timed == plain).all(), "phased path must be bit-identical"
    assert len(t.samples) == 1
    sample = t.samples[0]
    assert set(sample["phases"]) == set(PHASES)
    assert all(v >= 0.0 for v in sample["phases"].values())
    assert sample["meta"]["backend"] == "pallas"
    assert sample["meta"]["n_unit"] == 16
    assert sample["meta"]["batch"] == 64


def test_phase_timer_records_numpy_oracle():
    g = _graph(seed=2)
    prog = compile_graph(g, CompileSpec(n_unit=8, optimize="none"))
    rng = np.random.default_rng(2)
    bits = rng.integers(0, 2, (32, g.n_inputs)).astype(bool)
    with PhaseTimer() as t:
        out = execute_program_np(prog, bits)
    assert (out == g.evaluate(bits)).all()
    assert len(t.samples) == 1
    assert set(t.samples[0]["phases"]) == set(PHASES)
    assert t.samples[0]["meta"]["backend"] == "numpy"


def test_phase_timer_nests_and_restores():
    outer = PhaseTimer()
    with outer:
        with PhaseTimer() as inner:
            assert calibrate.active_timer() is inner
        assert calibrate.active_timer() is outer
    assert calibrate.active_timer() is None


def test_phased_infer_matches_plain_and_reference():
    from repro.kernels.logic_dsp.ops import (logic_infer_bits,
                                             phased_infer_bits)
    g = _graph(seed=3, n_gates=200)
    prog = compile_graph(g, CompileSpec(n_unit=16, optimize="none"))
    rng = np.random.default_rng(3)
    bits = rng.integers(0, 2, (96, g.n_inputs)).astype(bool)
    out, phases = phased_infer_bits(prog, bits)
    assert (out == logic_infer_bits(prog, bits)).all()
    assert (out == g.evaluate(bits)).all()
    assert set(phases) == set(PHASES)
    assert all(math.isfinite(v) and v >= 0.0 for v in phases.values())


# ---------------------------------------------------------------------------
# phase <-> regressor mapping
# ---------------------------------------------------------------------------

def test_phase_terms_arity_matches_declared_regressors():
    stats = FfclStats.from_graph(_graph())
    terms = phase_terms(MODEL, stats, 16, 256)
    assert set(terms) == set(PHASES)
    for p in PHASES:
        assert len(terms[p]) == len(PHASE_REGRESSORS[p])


def test_phase_terms_kernel_width_uses_lane_padding():
    """The executed slab width is n_unit padded to the kernel's sublane
    multiple (NOP rows still execute) — the width regressor must see the
    padded width, or unaligned unit counts are under-predicted."""
    stats = FfclStats.from_graph(_graph())
    for u in (9, 22, 39):
        nsk = float(n_subkernels(stats, u))
        padded = -(-u // calibrate.PAD_UNIT) * calibrate.PAD_UNIT
        assert phase_terms(MODEL, stats, u, 256)["kernel"] == (nsk,
                                                               nsk * padded)
    # aligned counts are unchanged
    nsk = float(n_subkernels(stats, 16))
    assert phase_terms(MODEL, stats, 16, 256)["kernel"] == (nsk, nsk * 16)


def test_phase_terms_pack_is_unit_independent():
    stats = FfclStats.from_graph(_graph())
    assert (phase_terms(MODEL, stats, 4, 256)["pack"]
            == phase_terms(MODEL, stats, 128, 256)["pack"])


# ---------------------------------------------------------------------------
# fitting: recovery + degenerate inputs
# ---------------------------------------------------------------------------

def test_fit_recovers_exact_linear_measurements():
    probes, coefs, offsets = _linear_probes()
    cal = fit_calibration(probes)
    for p in PHASES:
        f = cal.fits[p]
        np.testing.assert_allclose(f.coefs, coefs[p], rtol=1e-6, atol=1e-12)
        np.testing.assert_allclose(f.offset, offsets[p], rtol=1e-6)
        assert f.median_abs_rel_err < 1e-6
    assert cal.median_abs_rel_err() < 1e-6


def test_fit_increments_fit_count():
    before = calibrate.fit_count()
    fit_calibration(_linear_probes()[0])
    assert calibrate.fit_count() == before + 1


def test_fit_clamps_coefficients_nonnegative():
    """Adversarial measurements decreasing in the regressor must clamp
    to coef=0 (offset-only), never a negative seconds-per-cycle."""
    probes, _, _ = _linear_probes()
    flipped = [PhaseProbe(label=p.label, n_unit=p.n_unit,
                          n_input_vectors=p.n_input_vectors,
                          n_gates=p.n_gates, terms=p.terms,
                          measured={ph: 1e-3 - v for ph, v in
                                    p.measured.items()})
               for p in probes]
    cal = fit_calibration(flipped)
    for p in PHASES:
        assert all(c >= 0.0 for c in cal.fits[p].coefs)
        assert cal.fits[p].offset >= 0.0


def test_fit_rejects_single_probe():
    probes, _, _ = _linear_probes(units=(16,), graph_seeds=(0,))
    with pytest.raises(CalibrationError, match=">= 2 probes"):
        fit_calibration(probes)


def test_fit_rejects_gateless_probes():
    probes, _, _ = _linear_probes()
    gateless = [PhaseProbe(label="empty", n_unit=p.n_unit,
                           n_input_vectors=p.n_input_vectors, n_gates=0,
                           terms=p.terms, measured=p.measured)
                for p in probes]
    with pytest.raises(CalibrationError, match="gateless"):
        fit_calibration(gateless)


def test_fit_rejects_zero_variance_regressor():
    probes, _, _ = _linear_probes(units=(16, 16, 16), graph_seeds=(0,))
    with pytest.raises(CalibrationError, match="zero-variance"):
        fit_calibration(probes)


def test_fit_rejects_nonfinite_measurements():
    probes, _, _ = _linear_probes()
    bad = probes[:-1] + [PhaseProbe(
        label=probes[-1].label, n_unit=probes[-1].n_unit,
        n_input_vectors=256, n_gates=100, terms=probes[-1].terms,
        measured={**probes[-1].measured, "kernel": float("nan")})]
    with pytest.raises(CalibrationError, match="non-finite"):
        fit_calibration(bad)


def test_fit_rejects_negative_measurements():
    probes, _, _ = _linear_probes()
    bad = probes[:-1] + [PhaseProbe(
        label=probes[-1].label, n_unit=probes[-1].n_unit,
        n_input_vectors=256, n_gates=100, terms=probes[-1].terms,
        measured={**probes[-1].measured, "pack": -1e-6})]
    with pytest.raises(CalibrationError, match="negative measured"):
        fit_calibration(bad)


# ---------------------------------------------------------------------------
# Calibration object: validation + serialization
# ---------------------------------------------------------------------------

def test_calibration_roundtrip():
    cal = fit_calibration(_linear_probes()[0], meta={"host": "x"})
    back = Calibration.from_dict(cal.to_dict())
    assert back.fits == cal.fits
    assert back.meta == cal.meta
    # and the dict itself is json-stable
    assert json.loads(json.dumps(cal.to_dict())) == cal.to_dict()


def test_calibration_rejects_missing_phase():
    cal = _synthetic_calibration()
    fits = {p: f for p, f in cal.fits.items() if p != "kernel"}
    with pytest.raises(CalibrationError, match="missing phase"):
        Calibration(fits=fits)


def test_calibration_rejects_nonfinite_factors():
    fits = dict(_synthetic_calibration().fits)
    fits["pack"] = PhaseFit(coefs=(float("nan"),), offset=0.0,
                            n_probes=2, median_abs_rel_err=0.0)
    with pytest.raises(CalibrationError, match="non-finite/negative"):
        Calibration(fits=fits)
    fits["pack"] = PhaseFit(coefs=(1e-7,), offset=-1e-9,
                            n_probes=2, median_abs_rel_err=0.0)
    with pytest.raises(CalibrationError, match="non-finite/negative"):
        Calibration(fits=fits)


def test_calibration_from_dict_rejects_bad_records():
    good = _synthetic_calibration().to_dict()
    with pytest.raises(CalibrationError, match="format_version"):
        Calibration.from_dict({**good, "format_version": 99})
    with pytest.raises(CalibrationError, match="must be a dict"):
        Calibration.from_dict("nope")
    with pytest.raises(CalibrationError, match="'phases'"):
        Calibration.from_dict({"format_version": 1})
    broken = json.loads(json.dumps(good))
    del broken["phases"]["kernel"]["coefs"]
    with pytest.raises(CalibrationError, match="malformed"):
        Calibration.from_dict(broken)


def test_predict_rejects_arity_mismatch():
    cal = _synthetic_calibration()
    with pytest.raises(CalibrationError, match="regressor"):
        cal.fits["kernel"].predict((1.0,))      # kernel expects 2


# ---------------------------------------------------------------------------
# WallClockModel
# ---------------------------------------------------------------------------

def test_wallclock_model_seconds_and_cycles():
    cal = _synthetic_calibration()
    wc = WallClockModel(cal)
    stats = FfclStats.from_graph(_graph())
    layers = [LayerLoad(stats, 2, 256)]
    s1 = wc.network_seconds(layers, 16)
    assert s1 > 0 and math.isfinite(s1)
    # n_copies scales linearly; parallel_factor divides
    assert wc.network_seconds([LayerLoad(stats, 4, 256)], 16) \
        == pytest.approx(2 * s1)
    assert wc.network_seconds(layers, 16, parallel_factor=2) \
        == pytest.approx(s1 / 2)
    # the cycles view delegates to the wrapped cycles model exactly
    assert wc.network_cycles(layers, 16) \
        == MODEL.network_cycles(layers, 16)


def test_wallclock_model_requires_calibration():
    with pytest.raises(CalibrationError, match="needs a Calibration"):
        WallClockModel("not a calibration")


# ---------------------------------------------------------------------------
# store persistence
# ---------------------------------------------------------------------------

def test_store_calibration_roundtrip(tmp_path):
    from repro.core.artifact_store import ArtifactStore
    store = ArtifactStore(tmp_path / "store")
    cal = fit_calibration(_linear_probes()[0], meta={"grid": "test"})
    path = store.save_calibration(cal)
    assert path.is_file()
    loaded = store.load_calibration()
    assert loaded is not None
    assert loaded.fits == cal.fits and loaded.meta == cal.meta


def test_store_calibration_miss_returns_none(tmp_path):
    from repro.core.artifact_store import ArtifactStore
    store = ArtifactStore(tmp_path / "store")
    assert store.load_calibration() is None
    assert store.misses == 1


def test_store_calibration_corruption_quarantines(tmp_path):
    from repro.core.artifact_store import ArtifactStore
    from repro.core.errors import ArtifactIntegrityError
    store = ArtifactStore(tmp_path / "store")
    path = store.save_calibration(fit_calibration(_linear_probes()[0]))
    raw = path.read_text().replace('"offset": ', '"offset": 9')
    path.write_text(raw)
    with pytest.raises(ArtifactIntegrityError, match="checksum"):
        store.load_calibration()
    assert store.integrity_failures == 1
    assert store.quarantined == 1
    assert not path.is_file(), "corrupt record must leave the namespace"
    assert store.load_calibration() is None     # now a clean miss


def test_store_calibration_rejects_bad_names(tmp_path):
    from repro.core.artifact_store import ArtifactStore
    store = ArtifactStore(tmp_path / "store")
    for name in ("", "a/b", "..", " pad "):
        with pytest.raises(ValueError, match="invalid calibration name"):
            store.calibration_path_of(name)


# ---------------------------------------------------------------------------
# objective threading: spec -> compiler -> serving
# ---------------------------------------------------------------------------

def test_resolve_wallclock_requires_calibration():
    g = _graph()
    with pytest.raises(CalibrationError, match="no calibration"):
        LogicCompiler().resolve(
            g, CompileSpec(n_unit="auto", objective="wallclock"))


def test_resolve_wallclock_records_both_objectives():
    g = _graph(n_gates=300)
    compiler = LogicCompiler(calibration=_synthetic_calibration(),
                             n_unit_max=256)
    spec, search = compiler.resolve(
        g, CompileSpec(n_unit="auto", objective="wallclock"))
    assert spec.resolved and spec.n_unit == search.best_n_unit
    assert search.objective == "wallclock"
    assert search.alt is not None and search.alt.objective == "cycles"
    # and the mirror image: a cycles resolve on a calibrated compiler
    # records the wallclock pick as provenance
    spec_c, search_c = compiler.resolve(g, CompileSpec(n_unit="auto"))
    assert search_c.objective == "cycles"
    assert search_c.alt.objective == "wallclock"
    assert search_c.alt.best_n_unit == search.best_n_unit


def test_cycles_objective_resolution_unchanged_by_calibration():
    """The paper-exact default: the cycles pick must be identical with
    and without a calibration attached (the calibration only ADDS
    provenance, never steers the default objective)."""
    g = _graph(n_gates=300)
    plain, s_plain = LogicCompiler(n_unit_max=256).resolve(
        g, CompileSpec(n_unit="auto"))
    calib, s_calib = LogicCompiler(
        calibration=_synthetic_calibration(), n_unit_max=256).resolve(
        g, CompileSpec(n_unit="auto"))
    assert plain == calib
    assert s_plain.best_n_unit == s_calib.best_n_unit
    assert [e for e in s_plain.evaluations] == \
        [e for e in s_calib.evaluations]


def test_artifact_stats_record_search_provenance():
    g = _graph(n_gates=300)
    compiler = LogicCompiler(calibration=_synthetic_calibration(),
                             n_unit_max=256)
    art = compiler.compile(g, CompileSpec(n_unit="auto",
                                          objective="wallclock",
                                          optimize="none"))
    st = art.stats()
    assert st["search_objective"] == "wallclock"
    assert st["alt_objective"] == "cycles"
    assert st["search_probes"] > 0
    assert isinstance(st["alt_n_unit"], int)


def test_program_cache_wallclock_falls_back_with_warning():
    from repro.serve import ProgramCache
    cache = ProgramCache()                       # no calibration anywhere
    g = _graph(n_gates=200)
    spec = CompileSpec(n_unit="auto", objective="wallclock")
    with pytest.warns(RuntimeWarning, match="falling back"):
        entry = cache.get(g, spec)
    assert entry.spec.resolved
    # the fallback memoizes under the REQUESTED objective: repeat
    # requests stay O(1) and warn only once
    assert (cache._optimized(g, spec).fingerprint(), "wallclock") \
        in cache._auto_memo
    import warnings as w
    with w.catch_warnings():
        w.simplefilter("error")
        assert cache.get(g, spec) is entry


def test_program_cache_memoizes_objectives_separately():
    from repro.serve import ProgramCache
    cache = ProgramCache(
        compiler=LogicCompiler(calibration=_synthetic_calibration(),
                               n_unit_max=256))
    g = _graph(n_gates=300)
    cache.get(g, CompileSpec(n_unit="auto"))
    cache.get(g, CompileSpec(n_unit="auto", objective="wallclock"))
    fp = cache._optimized(g, CompileSpec(n_unit="auto")).fingerprint()
    assert (fp, "cycles") in cache._auto_memo
    assert (fp, "wallclock") in cache._auto_memo


def test_program_cache_warm_starts_calibration_from_store(tmp_path):
    from repro.core.artifact_store import ArtifactStore
    from repro.serve import ProgramCache
    store = ArtifactStore(tmp_path / "store")
    cal = fit_calibration(_linear_probes()[0])
    store.save_calibration(cal)
    before = calibrate.fit_count()
    cache = ProgramCache(store=ArtifactStore(tmp_path / "store"))
    assert cache.compiler.calibration is not None
    assert cache.compiler.calibration.fits == cal.fits
    assert calibrate.fit_count() == before, "warm start must never re-fit"
    # an explicit compiler calibration is never overridden by the store
    own = LogicCompiler(calibration=_synthetic_calibration())
    cache2 = ProgramCache(compiler=own,
                          store=ArtifactStore(tmp_path / "store"))
    assert cache2.compiler.calibration.meta == {"synthetic": True}


# ---------------------------------------------------------------------------
# property: binary_search == exhaustive sweep, both objectives
# ---------------------------------------------------------------------------

def _random_layers(rng, n_layers):
    layers = []
    for _ in range(n_layers):
        g = random_graph(rng, int(rng.integers(6, 16)),
                         int(rng.integers(40, 400)),
                         int(rng.integers(4, 12)),
                         locality=int(rng.integers(16, 64)))
        layers.append(LayerLoad(FfclStats.from_graph(g),
                                int(rng.integers(1, 4)),
                                int(rng.integers(64, 1024))))
    return layers


def _objective_models():
    return [("cycles", MODEL),
            ("wallclock", WallClockModel(_synthetic_calibration(), MODEL))]


def _assert_search_matches_sweep(layers, lo, hi, objective, model):
    res = binary_search(model, layers, n_unit_max=hi, n_unit_min=lo,
                        objective=objective)
    swp = sweep(model, layers, range(lo, hi + 1), objective=objective)
    assert res.best_n_unit == swp.best_n_unit, \
        (f"{objective}: binary_search picked {res.best_n_unit}, "
         f"exhaustive sweep {swp.best_n_unit} on [{lo}, {hi}]")
    assert res.best_cycles == pytest.approx(swp.best_cycles)
    # every probe lands in range, each exactly once
    probed = [u for u, _ in res.evaluations]
    assert all(lo <= u <= hi for u in probed)
    assert len(probed) == len(set(probed))


@pytest.mark.parametrize("objective,model", _objective_models())
@pytest.mark.parametrize("seed", range(8))
def test_binary_search_matches_exhaustive_sweep(seed, objective, model):
    rng = np.random.default_rng(seed)
    layers = _random_layers(rng, int(rng.integers(1, 4)))
    lo = int(rng.integers(1, 8))
    hi = int(rng.integers(lo + 4, 260))
    _assert_search_matches_sweep(layers, lo, hi, objective, model)


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 2 ** 31 - 1),
           lo=st.integers(1, 12), span=st.integers(4, 300),
           objective_idx=st.integers(0, 1))
    def test_hypothesis_search_matches_sweep(seed, lo, span, objective_idx):
        rng = np.random.default_rng(seed)
        layers = _random_layers(rng, int(rng.integers(1, 3)))
        objective, model = _objective_models()[objective_idx]
        _assert_search_matches_sweep(layers, lo, lo + span, objective,
                                     model)
