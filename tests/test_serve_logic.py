"""LogicEngine serving: cache, slot recycling, parity, partitions, shards."""
import subprocess
import sys

import numpy as np
import pytest

from repro.core.gate_ir import random_graph
from repro.core.scheduler import compile_graph
from repro.core.spec import CompileSpec
from repro.kernels.logic_dsp import logic_infer_bits
from repro.serve import LogicEngine, ProgramCache, SlotTable


def _graph(rng, n_in=12, n_gates=300, n_out=10):
    return random_graph(rng, n_in, n_gates, n_out, locality=48)


# ---------------------------------------------------------------------------
# program registry
# ---------------------------------------------------------------------------

def test_program_cache_hit_on_structural_copy(rng):
    """Keyed by structure: a renamed copy reuses the compiled program."""
    g = _graph(rng)
    eng = LogicEngine(CompileSpec(n_unit=16), capacity=64)
    X = rng.integers(0, 2, (20, g.n_inputs)).astype(bool)
    eng.serve(g, X)
    assert (eng.cache.hits, eng.cache.misses) == (0, 1)
    g2 = g.copy()
    g2.name = "same-structure-different-name"
    assert g2.fingerprint() == g.fingerprint()
    out = eng.serve(g2, X)
    assert eng.cache.misses == 1 and eng.cache.hits >= 1
    assert (out == g.evaluate(X)).all()


def test_program_cache_miss_on_structure_change(rng):
    g = _graph(rng)
    g2 = g.copy()
    g2.set_outputs(list(reversed(g2.outputs)))
    assert g.fingerprint() != g2.fingerprint()
    cache = ProgramCache()
    cache.get(g, CompileSpec(n_unit=16))
    cache.get(g2, CompileSpec(n_unit=16))
    cache.get(g, CompileSpec(n_unit=32))            # same graph, different fabric width
    assert cache.misses == 3 and cache.hits == 0
    cache.get(g, CompileSpec(n_unit=16))
    assert cache.hits == 1


def test_program_cache_lru_eviction(rng):
    cache = ProgramCache(max_entries=2)
    graphs = [_graph(rng, n_gates=60 + i) for i in range(3)]
    for g in graphs:
        cache.get(g, CompileSpec(n_unit=8))
    assert len(cache) == 2
    # oldest entry (graphs[0]) was evicted; re-fetch recompiles
    cache.get(graphs[0], CompileSpec(n_unit=8))
    assert cache.misses == 4


def test_program_cache_stats_schema(rng):
    """The stats dict is a pinned schema (dashboards + warm-start tests
    key on it): store counters are present — and zero — with no store
    attached, and compiles tracks actual facade invocations."""
    cache = ProgramCache()
    g = _graph(rng)
    cache.get(g, CompileSpec(n_unit=16))
    cache.get(g, CompileSpec(n_unit=16))
    assert cache.stats() == {
        "entries": 1, "hits": 1, "misses": 1, "compiles": 1,
        "compile_failures": 0, "store_hits": 0, "store_misses": 0,
        "store_failures": 0, "store_saves": 0, "store_save_failures": 0,
        "verifies": 0, "verify_failures": 0,
        "programs": 1}
    assert cache.store is None


def test_unbinding_budget_shares_monolithic_entry(rng):
    """Budgets the graph fits under normalize to the no-budget key."""
    g = _graph(rng, n_gates=80)
    cache = ProgramCache()
    # optimize="none": normalization must see the 80 raw gates (the
    # default pipeline would shrink the graph under the binding budget)
    spec = CompileSpec(n_unit=8, optimize="none")
    cache.get(g, spec)
    cache.get(g, spec.with_(max_gates=400))   # 80 <= 400: same mono program
    cache.get(g, spec.with_(max_gates=10 ** 6))
    assert cache.misses == 1 and cache.hits == 2
    cache.get(g, spec.with_(max_gates=30))    # binding budget: new pipeline
    assert cache.misses == 2


def test_max_retained_bounds_unclaimed_results(rng):
    """Fire-and-forget traffic cannot grow _requests without bound."""
    g = _graph(rng, n_in=6, n_gates=40, n_out=4)
    eng = LogicEngine(CompileSpec(n_unit=8), capacity=32, max_retained=2)
    uids = []
    for _ in range(5):
        uids.append(eng.submit(g, rng.integers(0, 2, (4, 6)).astype(bool)))
        eng.drain()                       # fire and forget: never claimed
    assert len(eng._requests) == 2        # only the 2 newest retained
    with pytest.raises(KeyError):
        eng.result(uids[0])               # oldest was dropped
    assert eng.result(uids[-1]).shape == (4, 4)


def test_claimed_results_leave_retention_window(rng):
    """Claiming a result frees its retention slot and its bookkeeping:
    max_retained bounds UNCLAIMED results only, and a steady
    submit/drain/claim loop leaves no residue behind."""
    g = _graph(rng, n_in=6, n_gates=40, n_out=4)
    eng = LogicEngine(CompileSpec(n_unit=8), capacity=32, max_retained=2)
    u0 = eng.submit(g, rng.integers(0, 2, (4, 6)).astype(bool))
    eng.drain()
    u1 = eng.submit(g, rng.integers(0, 2, (4, 6)).astype(bool))
    eng.drain()
    eng.result(u1)                        # claim the NEWEST
    u2 = eng.submit(g, rng.integers(0, 2, (4, 6)).astype(bool))
    eng.drain()
    assert eng.result(u0).shape == (4, 4)  # u0 survived: only 2 unclaimed
    eng.result(u2)
    assert not eng._requests and not eng._finished_order  # no residue


def test_shared_cache_rejects_max_programs(rng):
    with pytest.raises(ValueError):
        LogicEngine(cache=ProgramCache(), max_programs=4)


def test_eviction_with_queued_requests_recovers(rng):
    """An LRU-evicted program recompiles from the retained graph; queued
    requests complete instead of wedging the engine."""
    g1 = _graph(rng, n_gates=80)
    g2 = _graph(rng, n_gates=90)
    eng = LogicEngine(CompileSpec(n_unit=8), capacity=32, max_programs=1)
    X1 = rng.integers(0, 2, (10, g1.n_inputs)).astype(bool)
    X2 = rng.integers(0, 2, (10, g2.n_inputs)).astype(bool)
    u1 = eng.submit(g1, X1)
    u2 = eng.submit(g2, X2)          # compiles g2, evicting g1's entry
    assert len(eng.cache) == 1
    eng.drain()
    assert (eng.result(u1) == g1.evaluate(X1)).all()
    assert (eng.result(u2) == g2.evaluate(X2)).all()
    assert eng.cache.misses >= 3     # g1, g2, then g1's recompile


def test_shared_cache_engines_keep_their_own_runners(rng):
    """Engines sharing a ProgramCache must not run each other's traces:
    runner config (backend/capacity/shard) is part of the runner key."""
    g = _graph(rng)
    cache = ProgramCache()
    a = LogicEngine(CompileSpec(n_unit=16), capacity=32, use_ref=True,
                    cache=cache)
    b = LogicEngine(CompileSpec(n_unit=16), capacity=64, shard=True,
                    cache=cache)
    X = rng.integers(0, 2, (20, g.n_inputs)).astype(bool)
    assert (a.serve(g, X) == g.evaluate(X)).all()
    assert (b.serve(g, X) == g.evaluate(X)).all()    # cache hit, own runner
    assert cache.misses == 1 and cache.hits >= 1
    # fetch the entry the engines shared: keyed on the POST-optimization
    # fingerprint, so the lookup goes through the same pass pipeline
    entry = cache.get(g, a.spec)
    assert len(entry.runners) == 2                   # one trace per config


# ---------------------------------------------------------------------------
# parity vs direct execution
# ---------------------------------------------------------------------------

def test_engine_parity_vs_logic_infer_bits(rng):
    """Batched serving == direct fused kernel call, bit for bit."""
    g = _graph(rng)
    prog = compile_graph(g, CompileSpec(n_unit=16))
    eng = LogicEngine(CompileSpec(n_unit=16), capacity=96)
    for n in (1, 31, 32, 37, 96):        # ragged and word-aligned sizes
        X = rng.integers(0, 2, (n, g.n_inputs)).astype(bool)
        got = eng.serve(g, X)
        assert got.shape == (n, g.n_outputs)
        assert (got == logic_infer_bits(prog, X)).all()
        assert (got == g.evaluate(X)).all()
    # every serve after the first hit the program cache
    assert eng.cache.misses == 1


def test_engine_parity_on_cached_path(rng):
    """Second serve (cache hit, warm jit) stays exact."""
    g = _graph(rng)
    eng = LogicEngine(CompileSpec(n_unit=16), capacity=64)
    X1 = rng.integers(0, 2, (40, g.n_inputs)).astype(bool)
    X2 = rng.integers(0, 2, (64, g.n_inputs)).astype(bool)
    eng.serve(g, X1)
    assert (eng.serve(g, X2) == g.evaluate(X2)).all()
    assert eng.cache.hits >= 1


def test_gateless_graph_served(rng):
    """0-step programs route through the jnp reference inside the engine."""
    from repro.core.gate_ir import LogicGraph
    g = LogicGraph(4, name="wires-only")
    g.set_outputs([2, 5, 3])
    eng = LogicEngine(CompileSpec(n_unit=8), capacity=32)
    X = rng.integers(0, 2, (11, 4)).astype(bool)
    assert (eng.serve(g, X) == g.evaluate(X)).all()


# ---------------------------------------------------------------------------
# slot batching / recycling
# ---------------------------------------------------------------------------

def test_slot_table_acquire_release_recycles():
    t = SlotTable(8)
    r1 = t.acquire(5)
    assert t.n_free == 3 and t.high_water == 5
    assert t.acquire(4) is None          # insufficient free rows
    r2 = t.acquire(3)
    assert t.n_free == 0 and t.high_water == 8
    t.release(r1)
    r3 = t.acquire(5)                    # recycled rows come back
    assert sorted(np.concatenate([r2, r3]).tolist()) == list(range(8))
    t.release(r2)
    t.release(r3)
    assert t.n_free == 8
    with pytest.raises(RuntimeError):    # partial double-release is caught
        t.release(r3)


def test_slot_recycling_ragged_requests(rng):
    """Ragged sizes (not multiples of 32) pack together and recycle slots."""
    g = _graph(rng, n_in=8, n_gates=120, n_out=6)
    eng = LogicEngine(CompileSpec(n_unit=8), capacity=64)
    sizes = [40, 33, 10, 64, 1, 17]      # crosses word boundaries freely
    uids = [eng.submit(g, rng.integers(0, 2, (n, 8)).astype(bool))
            for n in sizes]
    waves = 0
    while not eng.idle:
        eng.step()
        waves += 1
        assert waves < 20
    assert eng.invocations >= 2          # couldn't fit in one wave
    assert eng.samples_served == sum(sizes)
    assert eng.slots.n_free == eng.capacity          # everything recycled
    for uid, n in zip(uids, sizes):
        req = eng._requests[uid]
        assert req.done
        assert (eng.result(uid) ==
                g.evaluate(req.inputs)).all()
    # first wave packed multiple ragged requests into one invocation
    assert eng.stats()["slot_high_water"] > max(sizes[:3])


def test_oversized_request_chunks(rng):
    """Requests above capacity split into waves but return one result."""
    g = _graph(rng, n_in=8, n_gates=100, n_out=5)
    eng = LogicEngine(CompileSpec(n_unit=8), capacity=32)
    X = rng.integers(0, 2, (150, 8)).astype(bool)
    out = eng.serve(g, X)
    assert out.shape == (150, 5)
    assert (out == g.evaluate(X)).all()
    assert eng.invocations >= 5


def test_empty_request_completes_immediately(rng):
    g = _graph(rng, n_in=6, n_gates=40, n_out=4)
    eng = LogicEngine(CompileSpec(n_unit=8), capacity=32)
    uid = eng.submit(g, np.zeros((0, 6), dtype=bool))
    assert eng.idle
    assert eng.result(uid).shape == (0, 4)


def test_mixed_graph_queues_serve_fifo(rng):
    """Two different graphs queued at once both complete correctly."""
    ga = _graph(rng, n_in=8, n_gates=90, n_out=5)
    gb = _graph(rng, n_in=11, n_gates=140, n_out=7)
    eng = LogicEngine(CompileSpec(n_unit=8), capacity=64)
    Xa = rng.integers(0, 2, (21, 8)).astype(bool)
    Xb = rng.integers(0, 2, (50, 11)).astype(bool)
    ua, ub = eng.submit(ga, Xa), eng.submit(gb, Xb)
    eng.drain()
    assert (eng.result(ua) == ga.evaluate(Xa)).all()
    assert (eng.result(ub) == gb.evaluate(Xb)).all()
    assert len(eng.cache) == 2


# ---------------------------------------------------------------------------
# partitioned serving
# ---------------------------------------------------------------------------

def test_partitioned_serving_equivalence(rng):
    """Pipelined multi-program serving == monolithic, bit for bit."""
    g = random_graph(rng, 12, 400, 20, locality=48)
    eng = LogicEngine(CompileSpec(n_unit=16, max_gates=150), capacity=96)
    # fetch the entry the engine serves (post-optimization key)
    entry = eng.cache.get(g, eng.spec)
    assert len(entry.programs) >= 2      # actually partitioned
    X = rng.integers(0, 2, (70, 12)).astype(bool)
    got = eng.serve(g, X)
    assert (got == g.evaluate(X)).all()
    mono = compile_graph(g, CompileSpec(n_unit=16))
    assert (got == logic_infer_bits(mono, X)).all()
    # partitioning shrank the per-program buffer budget (the point of it)
    assert max(p.n_addr for p in entry.programs) < mono.n_addr


def test_partitioned_and_monolithic_cache_separately(rng):
    g = random_graph(rng, 10, 300, 12, locality=40)
    cache = ProgramCache()
    mono = cache.get(g, CompileSpec(n_unit=16))
    part = cache.get(g, CompileSpec(n_unit=16, max_gates=100))
    assert len(mono.programs) == 1 and len(part.programs) >= 2
    assert cache.misses == 2
    assert cache.get(g, CompileSpec(n_unit=16, max_gates=100)) is part


# ---------------------------------------------------------------------------
# sharded serving
# ---------------------------------------------------------------------------

def test_sharded_path_parity_single_device(rng):
    """shard_map path on the host mesh stays exact (1 device here)."""
    g = _graph(rng)
    eng = LogicEngine(CompileSpec(n_unit=16), capacity=64, shard=True)
    assert eng.shard and eng.mesh is not None
    X = rng.integers(0, 2, (45, g.n_inputs)).astype(bool)
    assert (eng.serve(g, X) == g.evaluate(X)).all()


@pytest.mark.slow
def test_sharded_parity_multi_device_subprocess():
    """Data-parallel word-axis serving across 4 forced host devices."""
    code = (
        "import os;"
        "os.environ['XLA_FLAGS']='--xla_force_host_platform_device_count=4';"
        "os.environ['JAX_PLATFORMS']='cpu';"
        "import numpy as np, jax;"
        "from repro.core.gate_ir import random_graph;"
        "from repro.serve import LogicEngine;"
        "assert len(jax.devices()) == 4;"
        "rng = np.random.default_rng(1);"
        "g = random_graph(rng, 10, 200, 8, locality=32);"
        "from repro.core.spec import CompileSpec;"
        "eng = LogicEngine(CompileSpec(n_unit=16), words_per_device=1);"
        "assert eng.shard and eng.capacity == 128;"
        "X = rng.integers(0, 2, (100, 10)).astype(bool);"
        "assert (eng.serve(g, X) == g.evaluate(X)).all();"
        "eng2 = LogicEngine(CompileSpec(n_unit=16, max_gates=80));"
        "assert (eng2.serve(g, X) == g.evaluate(X)).all();"
        "print('sharded-ok')"
    )
    try:
        out = subprocess.run(
            [sys.executable, "-c", code], capture_output=True, text=True,
            timeout=300, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    except subprocess.TimeoutExpired:
        pytest.skip("multi-device serving smoke exceeded 300s on this host")
    assert "sharded-ok" in out.stdout, out.stderr[-2000:]
