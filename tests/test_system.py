"""End-to-end behaviour tests for the paper's system."""
import subprocess
import sys

import pytest

from repro.core.cost_model import CostModel, FfclStats
from repro.core.nullanet import (BinaryMLPConfig, mlp_to_logic_network,
                                 train_binary_mlp)
from repro.core.optimizer import binary_search
from repro.core.spec import CompileSpec
from repro.core.scheduler import compile_graph
from repro.data import make_binary_classification
from repro.kernels.logic_dsp import logic_infer_bits


def test_paper_pipeline_micro():
    """NN -> FFCL -> compile -> logic-fabric inference, the full §4-§7 flow."""
    x, y = make_binary_classification(1200, 16, n_classes=3, noise=0.05,
                                      seed=3)
    xt, yt, xv, yv = x[:1000], y[:1000], x[1000:], y[1000:]
    cfg = BinaryMLPConfig(n_features=16, hidden=(12,), n_classes=3)
    params = train_binary_mlp(cfg, xt, yt, steps=150)
    net = mlp_to_logic_network(params, cfg, xt, mode="isf")

    progs = [compile_graph(g, CompileSpec(n_unit=8))
             for g in net.graphs]

    def kernel_exec(graph, bits):
        prog = progs[[g is graph for g in net.graphs].index(True)]
        return logic_infer_bits(prog, bits)

    pred_direct = net.predict(xv)
    pred_kernel = net.predict(xv, executor=kernel_exec)
    # the kernel path must agree with direct evaluation EXACTLY
    assert (pred_direct == pred_kernel).all()
    # and the whole pipeline must actually classify
    assert (pred_kernel == yv).mean() > 0.8

    # design-space optimization runs on the real graphs (paper §7.2)
    model = CostModel()
    layers = [(FfclStats.from_graph(g), 1, len(xv)) for g in net.graphs]
    res = binary_search(model, layers, n_unit_max=2048)
    assert 1 <= res.best_n_unit <= 2048


@pytest.mark.slow
def test_dryrun_entry_small_mesh():
    """The dry-run entrypoint machinery works end-to-end (subprocess owns
    its own device count; one cheap decode cell)."""
    code = (
        "import os;"
        "os.environ['XLA_FLAGS']='--xla_force_host_platform_device_count=512';"
        "from repro.launch.dryrun import run_cell;"
        "r = run_cell('mamba2-370m', 'decode_32k', False, force=True);"
        "assert r['ok'], r; print('dryrun-ok', r['roofline']['bound'])"
    )
    try:
        out = subprocess.run(
            [sys.executable, "-c", code], capture_output=True,
            text=True, timeout=420,
            env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    except subprocess.TimeoutExpired:
        # 512 fake devices + decode-cell jit can exceed the budget on slow
        # shared hosts; that is a capacity limit, not a dry-run bug.
        pytest.skip("dry-run smoke exceeded 420s on this host")
    assert "dryrun-ok" in out.stdout, out.stderr[-2000:]
