"""Backend-equivalence + opcode-homogeneous scheduling invariants.

Runs without hypothesis (plain parametrization) so this coverage survives
environments where the property-testing dependency is absent: the Pallas
kernel, the jnp reference, and the vectorized numpy oracle must all match
``LogicGraph.evaluate`` bit-exactly across alloc x fuse_levels x graphs,
and the homogeneity/fusion metadata must be self-consistent.
"""
import numpy as np
import pytest

from repro.core.cost_model import FfclStats, n_subkernels
from repro.core.gate_ir import MIXED_DISPATCH, random_graph
from repro.core.levelize import levelize
from repro.core.spec import CompileSpec
from repro.core.scheduler import compile_graph, execute_program_np
from repro.kernels.logic_dsp import logic_infer_bits


def _random_case(seed):
    rng = np.random.default_rng(seed)
    ni = int(rng.integers(4, 16))
    g = random_graph(rng, ni, int(rng.integers(50, 400)),
                     int(rng.integers(2, 10)),
                     locality=int(rng.choice([8, 64, 1000])))
    X = rng.integers(0, 2, (int(rng.integers(33, 130)), ni)).astype(bool)
    n_unit = int(rng.choice([3, 8, 16, 64]))
    return g, X, n_unit


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
@pytest.mark.parametrize("fuse", [False, True], ids=["nofuse", "fuse"])
@pytest.mark.parametrize("alloc", ["direct", "liveness"])
def test_all_backends_match_graph_eval(alloc, fuse, seed):
    g, X, n_unit = _random_case(seed)
    prog = compile_graph(g, CompileSpec(n_unit=n_unit, alloc=alloc,
                                        fuse_levels=fuse, optimize="none"))
    ref = g.evaluate(X)
    assert (execute_program_np(prog, X) == ref).all()          # numpy oracle
    assert (logic_infer_bits(prog, X) == ref).all()            # pallas
    assert (logic_infer_bits(prog, X, use_ref=True) == ref).all()  # jnp ref


@pytest.mark.parametrize("seed", [0, 5, 9])
def test_schedule_dependency_order(seed):
    """Opcode sorting + fusion never break dataflow: every operand of a
    step was produced at a strictly earlier step (or is an input/const),
    for both the unfused (level_of_step-monotone) and fused layouts."""
    g, _, n_unit = _random_case(seed)
    for fuse in (False, True):
        prog = compile_graph(g, CompileSpec(n_unit=n_unit, fuse_levels=fuse,
                                            optimize="none"))
        produced_at = {0: -1, 1: -1}
        produced_at.update((int(a), -1) for a in prog.input_addrs)
        for s in range(prog.n_steps):
            for u in range(prog.n_unit):
                if prog.opcode[s, u] == 0:
                    continue
                for src in (prog.src_a[s, u], prog.src_b[s, u]):
                    assert produced_at[int(src)] < s
                produced_at[int(prog.dst[s, u])] = s
        if not fuse:
            # unfused steps serve levels in order (eq. 23 layout)
            assert (np.diff(prog.level_of_step) >= 0).all()


@pytest.mark.parametrize("seed", [1, 4, 7])
def test_homogeneity_metadata_consistent(seed):
    g, _, n_unit = _random_case(seed)
    prog = compile_graph(g, CompileSpec(n_unit=n_unit, optimize="none"))
    assert prog.step_opcode.shape == (prog.n_steps,)
    assert prog.homogeneous.shape == (prog.n_steps,)
    for s in range(prog.n_steps):
        active = prog.opcode[s][prog.opcode[s] != 0]
        if prog.homogeneous[s]:
            assert prog.step_branch[s] == prog.step_opcode[s]
            if len(active):
                assert (active == prog.step_opcode[s]).all()
            else:
                assert prog.step_opcode[s] == 0
        else:
            assert len(np.unique(active)) > 1
            assert prog.step_branch[s] == MIXED_DISPATCH


def test_real_nop_gates_not_clobbered():
    """A *real* NOP gate (legal IR; evaluates to 0) must not be conflated
    with NOP padding: a step of [NOP, AND] gates is NOT homogeneous-AND,
    since the slab op would overwrite the NOP gate's wire with a&b."""
    from repro.core.gate_ir import LogicGraph, OpCode
    g = LogicGraph(2)
    w_nop = g.add_gate(OpCode.NOP, 2, 3)
    w_and = g.add_gate(OpCode.AND, 2, 3)
    g.set_outputs([w_nop, w_and])
    X = np.array([[1, 1], [1, 0], [0, 1], [0, 0]], dtype=bool)
    ref = g.evaluate(X)
    assert (ref[:, 0] == 0).all()        # NOP gate always produces 0
    for n_unit in (2, 8):
        prog = compile_graph(g, CompileSpec(n_unit=n_unit, optimize="none"))
        assert (execute_program_np(prog, X) == ref).all()
        assert (logic_infer_bits(prog, X) == ref).all()
        assert (logic_infer_bits(prog, X, use_ref=True) == ref).all()


def test_gateless_program_executes():
    """A graph whose outputs are inputs/consts compiles to 0 steps and
    still runs through every backend (pallas falls back to the jnp ref:
    (0, n_unit) stream blocks are unrepresentable in pallas)."""
    from repro.core.gate_ir import LogicGraph
    g = LogicGraph(3)
    g.set_outputs([0, 1, g.input_wire(2)])
    X = np.random.default_rng(1).integers(0, 2, (37, 3)).astype(bool)
    prog = compile_graph(g, CompileSpec(n_unit=8, optimize="none"))
    assert prog.n_steps == 0
    ref = g.evaluate(X)
    assert (execute_program_np(prog, X) == ref).all()
    assert (logic_infer_bits(prog, X) == ref).all()
    assert (logic_infer_bits(prog, X, use_ref=True) == ref).all()


def test_opcode_sort_increases_homogeneity():
    """A wide level sliced at n_unit granularity yields mostly homogeneous
    steps once sorted; the unsorted layout stays mixed."""
    rng = np.random.default_rng(2)
    g = random_graph(rng, 24, 4000, 8, locality=4000)   # few, wide levels
    ps = compile_graph(g, CompileSpec(n_unit=8, opcode_sort=True,
                                      fuse_levels=False, optimize="none"))
    pu = compile_graph(g, CompileSpec(n_unit=8, opcode_sort=False,
                                      fuse_levels=False, optimize="none"))
    assert ps.n_steps == pu.n_steps
    assert ps.homogeneous.mean() > pu.homogeneous.mean()
    assert ps.homogeneous.mean() > 0.5


def test_fusion_shrinks_ragged_schedules():
    """Levels whose sizes are ragged modulo n_unit leave spare unit slots;
    fusion back-fills them and strictly reduces the step count."""
    rng = np.random.default_rng(3)
    g = random_graph(rng, 32, 1500, 16, locality=128)
    shrunk = 0
    for n_unit in (8, 16, 24):
        pf = compile_graph(g, CompileSpec(n_unit=n_unit, fuse_levels=True,
                                          optimize="none"))
        pu = compile_graph(g, CompileSpec(n_unit=n_unit, fuse_levels=False,
                                          optimize="none"))
        expected = int(np.ceil(levelize(g).histogram() / n_unit).sum())
        assert pu.n_steps == expected
        assert pf.n_steps <= pu.n_steps
        shrunk += pf.n_steps < pu.n_steps
        # program-derived stats expose the fused count to the cost model
        assert n_subkernels(FfclStats.from_program(pf), n_unit) == pf.n_steps
    assert shrunk >= 1, "fusion never fired on a ragged workload"
