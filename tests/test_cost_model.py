"""Cost model (eqs. 2-23), simulator, and binary-search optimizer."""
import numpy as np
import pytest

from repro.core.cost_model import CostModel, FfclStats, n_subkernels
from repro.core.gate_ir import random_graph
from repro.core.optimizer import binary_search, sweep
from repro.core.scheduler import compile_graph
from repro.core.simulator import simulate_no_pipeline, simulate_pipeline
from repro.core.spec import CompileSpec


@pytest.fixture(scope="module")
def workload():
    rng = np.random.default_rng(3)
    g = random_graph(rng, 64, 3000, 32, locality=256)
    return g, FfclStats.from_graph(g)


def test_u_shape(workload):
    _, stats = workload
    model = CostModel()
    layers = [(stats, 16, 4096)]
    costs = [model.network_cycles(layers, 2 ** k) for k in range(0, 13)]
    best = int(np.argmin(costs))
    assert 0 < best < 12, "latency vs n_unit must be interior-minimized"
    # rising tail and falling head (paper Fig. 6 Pareto shape)
    assert costs[0] > costs[best]
    assert costs[-1] > costs[best]


def test_binary_search_matches_sweep(workload):
    _, stats = workload
    model = CostModel()
    layers = [(stats, 16, 4096)]
    res = binary_search(model, layers, n_unit_max=4096)
    swp = sweep(model, layers, list(range(1, 513, 7)))
    # the plateau-edge search is EXACT, so it can only do better than
    # (or equal) any subsampled sweep
    assert res.best_cycles <= swp.best_cycles
    # plateau-edge enumeration probes O(sum sqrt(level_height)) points,
    # not the whole [1, 4096] range
    assert len(res.evaluations) < 0.05 * 4096


def test_pipeline_beats_sequential(workload):
    g, _ = workload
    progs = [compile_graph(g, CompileSpec(n_unit=64, optimize="none"))
             for _ in range(8)]
    pipe = simulate_pipeline(progs, n_input_vectors=4096)
    seq = simulate_no_pipeline(progs, n_input_vectors=4096)
    assert pipe.total_cycles <= seq.total_cycles
    # eq. 2 upper-bounds the pipelined sim (same max-term structure)
    model = CostModel()
    stats = FfclStats.from_graph(g)
    bound = model.total_cycles(stats, 64, 4096, m_modules=8)
    assert pipe.total_cycles <= bound * 1.01


def test_model_error_shrinks_with_m(workload):
    """Paper Fig. 6: <10% model-vs-actual error. Our 'actual' is the
    discrete-event simulator; the worst-case-occupancy model converges as
    the number of pipelined modules grows."""
    g, stats = workload
    model = CostModel()
    prog = compile_graph(g, CompileSpec(n_unit=64, optimize="none"))
    errs = {}
    for m in (2, 64):
        sim = simulate_pipeline([prog] * m, n_input_vectors=4096)
        mdl = model.total_cycles(stats, 64, 4096, m_modules=m)
        errs[m] = abs(mdl - sim.total_cycles) / sim.total_cycles
    assert errs[64] < errs[2]
    assert errs[64] < 0.35


def test_eq23(workload):
    """Paper eq. 23 holds for the unfused layout; fusion only shrinks it,
    and program-derived stats report the scheduled count."""
    g, stats = workload
    for u in (1, 7, 64, 4096):
        unfused = compile_graph(g, CompileSpec(n_unit=u, fuse_levels=False,
                                               optimize="none"))
        assert n_subkernels(stats, u) == unfused.n_steps
        fused = compile_graph(g, CompileSpec(n_unit=u, optimize="none"))
        assert fused.n_steps <= unfused.n_steps
        assert n_subkernels(FfclStats.from_program(fused), u) == fused.n_steps


def test_breakdown_bound_shares(workload):
    """Paper Fig. 7: the data-movement share of the pipeline grows with the
    number of units (address streams scale with n_unit x n_subkernels),
    while few units are compute-dominated."""
    _, stats = workload
    model = CostModel()
    b_small = model.breakdown(stats, 4, 4096)
    b_large = model.breakdown(stats, 4096, 4096)
    assert b_small.bound == "compute"       # few units -> compute-dominated
    share_small = b_small.n_data_moves / b_small.n_compute
    share_large = b_large.n_data_moves / b_large.n_compute
    assert share_large > share_small
