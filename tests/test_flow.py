"""End-to-end flow: packed-word layer handoff, composition, accuracy parity.

The packed-handoff contract (DESIGN.md §6): chaining per-layer programs at
the word level — layer k's packed (n_out, W) output slab fed directly as
layer k+1's packed input slab — must equal per-layer execution with an
unpack -> repack round-trip between layers, bit for bit, including sample
counts that do not fill the last 32-bit word.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.gate_ir import LogicGraph, OpCode, compose_graphs
from repro.core.opt import PassManager
from repro.core.spec import CompileSpec
from repro.core.scheduler import compile_graph, execute_program_np
from repro.flow import (FlowConfig, build_classifier, convert_layer,
                        hard_forward, input_bits, layer_to_program, run_flow)
from repro.kernels.logic_dsp.ops import (forward_words, logic_infer_bits,
                                         pack_bits_jnp, program_arrays,
                                         unpack_bits_jnp)


# ---------------------------------------------------------------------------
# hand-computable 2-layer fixture
# ---------------------------------------------------------------------------

def _layer_a() -> LogicGraph:
    """3 inputs -> 2 outputs: o0 = i0 & i1, o1 = i1 ^ i2."""
    g = LogicGraph(3, name="A")
    i0, i1, i2 = g.input_wire(0), g.input_wire(1), g.input_wire(2)
    g.set_outputs([g.add_gate(OpCode.AND, i0, i1),
                   g.add_gate(OpCode.XOR, i1, i2)])
    return g


def _layer_b() -> LogicGraph:
    """2 inputs -> 2 outputs: p0 = a0 | a1, p1 = ~a0."""
    g = LogicGraph(2, name="B")
    a0, a1 = g.input_wire(0), g.input_wire(1)
    g.set_outputs([g.add_gate(OpCode.OR, a0, a1),
                   g.add_gate(OpCode.NOT, a0)])
    return g


def _expected(bits: np.ndarray) -> np.ndarray:
    i0, i1, i2 = bits[:, 0], bits[:, 1], bits[:, 2]
    a0, a1 = i0 & i1, i1 ^ i2
    return np.stack([a0 | a1, ~a0], axis=1)


@pytest.mark.parametrize("alloc", ["direct", "liveness"])
@pytest.mark.parametrize("batch", [1, 31, 32, 33, 70])
def test_packed_handoff_matches_unpack_repack(rng, alloc, batch):
    """Chained words == per-layer unpack->repack == hand truth, bit for bit."""
    ga, gb = _layer_a(), _layer_b()
    spec = CompileSpec(n_unit=8, alloc=alloc, optimize="none")
    pa = compile_graph(ga, spec)
    pb = compile_graph(gb, spec)
    bits = rng.integers(0, 2, (batch, 3)).astype(bool)

    # packed handoff: pack once, words flow layer to layer
    words = pack_bits_jnp(jnp.asarray(bits))
    for prog in (pa, pb):
        a = program_arrays(prog)
        words = forward_words(a["src_a"], a["src_b"], a["dst"], a["opcode"],
                              a["step_branch"], a["output_addrs"], words,
                              n_addr=a["n_addr"], use_ref=True)
    chained = np.asarray(unpack_bits_jnp(words, batch))

    # per-layer round-trips (kernel + numpy oracle)
    h = logic_infer_bits(pa, bits)
    per_layer = logic_infer_bits(pb, h)
    np_h = execute_program_np(pa, bits)
    np_out = execute_program_np(pb, np_h)

    expected = _expected(bits)
    assert (chained == per_layer).all()
    assert (chained == np_out).all()
    assert (chained == expected).all()


@pytest.mark.parametrize("batch", [33, 64])
def test_padding_lanes_stay_clean(rng, batch):
    """Zero padding in the last word must not leak into real samples: the
    same samples must produce identical outputs at any batch position."""
    ga, gb = _layer_a(), _layer_b()
    spec = CompileSpec(n_unit=8, optimize="none")
    pa = compile_graph(ga, spec)
    pb = compile_graph(gb, spec)
    bits = rng.integers(0, 2, (batch, 3)).astype(bool)
    out_full = logic_infer_bits(pb, logic_infer_bits(pa, bits))
    head = bits[:17]
    out_head = logic_infer_bits(pb, logic_infer_bits(pa, head))
    assert (out_full[:17] == out_head).all()


def test_compose_graphs_equals_chain(rng):
    ga, gb = _layer_a(), _layer_b()
    stacked = compose_graphs([ga, gb])
    bits = rng.integers(0, 2, (40, 3)).astype(bool)
    assert (stacked.evaluate(bits) == _expected(bits)).all()
    prog = compile_graph(stacked, CompileSpec(n_unit=8, optimize="none"))
    assert (execute_program_np(prog, bits) == _expected(bits)).all()


def test_compose_graphs_degenerate_stages(rng):
    """Constant and pass-through stage outputs compose exactly."""
    g1 = LogicGraph(2, name="const-ish")
    # outputs: const1, input0 (pass-through), one real gate
    g1.set_outputs([1, g1.input_wire(0),
                    g1.add_gate(OpCode.NOR, g1.input_wire(0),
                                g1.input_wire(1))])
    g2 = LogicGraph(3, name="top")
    g2.set_outputs([g2.add_gate(OpCode.AND, g2.input_wire(0),
                                g2.input_wire(2)),
                    g2.input_wire(1)])
    stacked = compose_graphs([g1, g2])
    bits = rng.integers(0, 2, (16, 2)).astype(bool)
    i0, i1 = bits[:, 0], bits[:, 1]
    expected = np.stack([np.ones_like(i0) & ~(i0 | i1), i0], axis=1)
    assert (stacked.evaluate(bits) == expected).all()


def test_compose_graphs_width_mismatch():
    g1 = _layer_a()          # 2 outputs
    g3 = LogicGraph(3)       # expects 3 inputs
    g3.set_outputs([g3.input_wire(0)])
    with pytest.raises(ValueError, match="expects 3 inputs"):
        compose_graphs([g1, g3])
    with pytest.raises(ValueError, match="at least one"):
        compose_graphs([])


# ---------------------------------------------------------------------------
# conversion path + classifier parity
# ---------------------------------------------------------------------------

def test_convert_layer_enum_is_exact(rng):
    """Enumerated conversion reproduces the float64 sign comparison on
    every input pattern (the basis of the parity claim)."""
    W = rng.normal(size=(6, 4)).astype(np.float32)
    b = rng.normal(size=4).astype(np.float32)
    layer = convert_layer(W, b, np.zeros((0, 6), np.uint8),
                          CompileSpec(n_unit=8), mode="enum", name="t")
    pats = ((np.arange(64)[:, None] >> np.arange(6)[None, :]) & 1
            ).astype(np.uint8)
    want = ((2.0 * pats - 1.0) @ W.astype(np.float64)
            + b.astype(np.float64)) >= 0
    assert (layer.graph.evaluate(pats.astype(bool)) == want).all()
    assert (execute_program_np(layer.program, pats.astype(bool))
            == want).all()


def test_classifier_three_backends_bit_identical(rng):
    """Small trained-free classifier: random weights, all three execution
    paths must agree with hard_forward bit for bit."""
    params = {
        "w0": rng.normal(size=(7, 5)).astype(np.float32),
        "b0": rng.normal(size=5).astype(np.float32),
        "w1": rng.normal(size=(5, 4)).astype(np.float32),
        "b1": rng.normal(size=4).astype(np.float32),
        "w2": rng.normal(size=(4, 3)).astype(np.float32),
        "b2": np.zeros(3, np.float32),
    }
    x = rng.integers(0, 2, (77, 7)).astype(np.uint8)
    clf = build_classifier(params, 3, x, CompileSpec(n_unit=8))
    bits = input_bits(x)
    acts, logits = hard_forward(params, bits, 3)
    outs = {b: clf.hidden_bits(bits, backend=b)
            for b in ("reference", "pallas", "megakernel", "engine")}
    for name, h in outs.items():
        assert (h == acts[-1].astype(bool)).all(), name
    assert (clf.predict(x) == np.argmax(logits, -1)).all()


def test_classifier_optimize_on_off_parity(rng):
    """Accuracy parity is preserved by the gate-level pass pipeline: the
    optimized classifier predicts identically to the raw-synthesis one
    (and to hard_forward) while strictly shrinking gates and steps."""
    params = {
        "w0": rng.normal(size=(7, 5)).astype(np.float32),
        "b0": rng.normal(size=5).astype(np.float32),
        "w1": rng.normal(size=(5, 4)).astype(np.float32),
        "b1": rng.normal(size=4).astype(np.float32),
        "w2": rng.normal(size=(4, 3)).astype(np.float32),
        "b2": np.zeros(3, np.float32),
    }
    x = rng.integers(0, 2, (64, 7)).astype(np.uint8)
    raw = build_classifier(params, 3, x,
                           CompileSpec(n_unit=8, optimize="none"))
    opt = build_classifier(params, 3, x,
                           CompileSpec(n_unit=8))      # default pipeline
    bits = input_bits(x)
    acts, _ = hard_forward(params, bits, 3)
    for backend in ("reference", "pallas", "megakernel", "engine"):
        h_raw = raw.hidden_bits(bits, backend=backend)
        h_opt = opt.hidden_bits(bits, backend=backend)
        assert (h_raw == acts[-1].astype(bool)).all(), backend
        assert (h_opt == acts[-1].astype(bool)).all(), backend
    # the default pipeline strictly reduces scheduled work vs raw synthesis
    assert sum(c.program.n_gates for c in opt.layers) < \
        sum(c.program.n_gates for c in raw.layers)
    assert sum(c.program.n_steps for c in opt.layers) < \
        sum(c.program.n_steps for c in raw.layers)


@pytest.mark.slow
def test_run_flow_optimize_none_matches_default():
    """flow.e2e accuracy parity holds with optimization on AND off, and
    both configurations report identical accuracies (semantics equal)."""
    cfg = FlowConfig(n_features=6, hidden=(5,), n_classes=3,
                     n_samples=400, train_steps=40, spec=CompileSpec(n_unit=8))
    assert cfg.optimize == PassManager.default()   # normalized spec value
    report, _ = run_flow(cfg)
    report_raw, _ = run_flow(dataclasses.replace(
        cfg, spec=cfg.spec.with_(optimize="none")))
    assert report.parity and report.bit_identical
    assert report_raw.parity and report_raw.bit_identical
    assert report.logic_acc == report_raw.logic_acc
    assert report.n_gates <= report_raw.n_gates


def test_classifier_engine_partitioned_matches(rng):
    """Engine serving with a partition budget (pipelined multi-program
    sequence over the composed stack) stays bit-identical."""
    from repro.serve import LogicEngine
    params = {
        "w0": rng.normal(size=(6, 5)).astype(np.float32),
        "b0": rng.normal(size=5).astype(np.float32),
        "w1": rng.normal(size=(5, 2)).astype(np.float32),
        "b1": np.zeros(2, np.float32),
    }
    x = rng.integers(0, 2, (40, 6)).astype(np.uint8)
    clf = build_classifier(params, 2, x, CompileSpec(n_unit=8))
    bits = input_bits(x)
    ref = clf.hidden_bits(bits, backend="reference")
    budget = max(2, clf.stacked_graph.n_gates // 3)
    eng = LogicEngine(CompileSpec(n_unit=8, max_gates=budget), capacity=64)
    got = clf.hidden_bits(bits, backend="engine", engine=eng)
    assert (got == ref).all()
    # the entry the engine served, keyed on the post-optimization form
    entry = eng.cache.get(clf.stacked_graph, eng.spec)
    assert len(entry.programs) > 1     # the budget actually partitioned
    assert eng.cache.misses == 1       # no phantom raw compile


def test_ffn_to_program_wrapper_matches_flow(rng):
    """models/logic_mlp.ffn_to_program is a thin wrapper over the flow
    conversion path: identical program streams for identical inputs."""
    from repro.models.logic_mlp import ffn_to_program
    p = {"w_in": rng.normal(size=(6, 4)).astype(np.float32),
         "b_in": rng.normal(size=4).astype(np.float32)}
    calib = rng.integers(0, 2, (50, 6)).astype(np.uint8)
    via_model = ffn_to_program(p, calib, CompileSpec(n_unit=8), mode="isf")
    via_flow = layer_to_program(p["w_in"], p["b_in"], calib,
                                CompileSpec(n_unit=8), mode="isf")
    assert (via_model.src_a == via_flow.src_a).all()
    assert (via_model.opcode == via_flow.opcode).all()
    assert via_model.n_addr == via_flow.n_addr


@pytest.mark.slow
def test_run_flow_exact_parity():
    """The acceptance criterion, small: logic acc == binarized acc exactly,
    all backends bit-identical, flow stats populated."""
    cfg = FlowConfig(n_features=8, hidden=(6, 5), n_classes=3,
                     n_samples=700, train_steps=60,
                     spec=CompileSpec(n_unit=16))
    assert cfg.exact
    report, clf = run_flow(cfg)
    assert report.parity
    assert report.bit_identical
    assert report.exact_mode
    assert set(report.logic_acc) == {"reference", "pallas",
                                     "megakernel", "engine"}
    assert all(acc == report.binarized_acc
               for acc in report.logic_acc.values())
    assert len(report.layers) == 2
    assert report.n_gates == sum(c.program.n_gates for c in clf.layers)
    assert report.sim_cycles > 0
    d = report.to_dict()
    assert d["parity"] and d["logic_acc"]["pallas"] == report.binarized_acc
