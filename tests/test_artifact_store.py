"""ArtifactStore: differential persistence, corruption injection, races.

The integrity contract under test (DESIGN.md §10): a store-loaded
program is byte-identical to the fresh compile it replaces, any
corrupted or mismatched entry fails **loudly** (``ArtifactIntegrityError``,
a ``PermanentCompileError``) and is quarantined — never silently served —
and ``ProgramCache`` degrades a bad disk to a clean recompile, pinned by
counters rather than timing.  Concurrency sections prove the atomic
publish protocol: racing writers of one key leave exactly one valid
entry, and racing readers never observe a torn write.
"""
import json
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from repro.core.artifact_store import (ArtifactStore, FORMAT_VERSION,
                                       _canonical_json, _digest, store_key)
from repro.core.compiler import LogicCompiler
from repro.core.errors import ArtifactIntegrityError, PermanentCompileError
from repro.core.gate_ir import random_graph
from repro.core.scheduler import LogicProgram
from repro.core.spec import CompileSpec
from repro.serve import FrontDoor, LogicEngine, ProgramCache

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:           # tier-1 containers may lack hypothesis
    HAVE_HYPOTHESIS = False


def _graph(rng, n_in=10, n_gates=200, n_out=8):
    return random_graph(rng, n_in, n_gates, n_out, locality=32)


def _compiled(rng, spec=None, **kw):
    """A (graph, resolved target spec, artifact) triple on the exact
    identity ``ProgramCache`` keys on: post-opt graph, normalized spec."""
    spec = spec or CompileSpec(n_unit=8)
    g = _graph(rng, **kw)
    pipeline = spec.pipeline
    go = pipeline.run(g).graph if pipeline is not None else g
    target, _ = LogicCompiler().resolve(go, spec, assume_optimized=True)
    target = target.normalize(go).with_(optimize="none")
    art = LogicCompiler().compile(go, target, assume_optimized=True)
    return go, target, art


def _assert_same_artifact(a, b):
    """Bit-for-bit equality of two CompiledArtifacts' schedule tables."""
    assert a.spec == b.spec
    assert a.graph.fingerprint() == b.graph.fingerprint()
    assert a.output_perm.dtype == b.output_perm.dtype
    assert a.output_perm.tobytes() == b.output_perm.tobytes()
    assert len(a.programs) == len(b.programs)
    for pa, pb in zip(a.programs, b.programs):
        for f in LogicProgram.ARRAY_FIELDS:
            xa, xb = getattr(pa, f), getattr(pb, f)
            assert xa.dtype == xb.dtype, f
            assert xa.tobytes() == xb.tobytes(), f
        for f in LogicProgram.SCALAR_FIELDS:
            assert getattr(pa, f) == getattr(pb, f), f


def _retamper(store, key, mutate):
    """Corrupt an entry *consistently*: apply ``mutate(payload, path)``
    then recompute the manifest checksum — modelling a wrong-but-
    internally-consistent entry (only deeper checks can catch it)."""
    path = store.path_of(key)
    manifest = json.loads((path / "manifest.json").read_text())
    mutate(manifest["payload"], path)
    manifest["checksum"] = _digest(_canonical_json(manifest["payload"]))
    (path / "manifest.json").write_text(json.dumps(manifest))


# ---------------------------------------------------------------------------
# round trip + content addressing
# ---------------------------------------------------------------------------

def test_round_trip_bit_identical(tmp_path, rng):
    store = ArtifactStore(tmp_path)
    g, spec, art = _compiled(rng)
    key = store.save(art)
    assert key in store and store.contains(g.fingerprint(), spec)
    loaded = store.load(g.fingerprint(), spec)
    _assert_same_artifact(loaded, art)
    assert loaded.compile_s == pytest.approx(art.compile_s)
    bits = rng.integers(0, 2, (40, g.n_inputs)).astype(bool)
    assert (loaded.execute(bits) == g.evaluate(bits)).all()


def test_partitioned_round_trip(tmp_path, rng):
    store = ArtifactStore(tmp_path)
    g, spec, art = _compiled(rng, spec=CompileSpec(n_unit=8, max_gates=60),
                             n_gates=300)
    assert len(art.programs) > 1
    store.save(art)
    loaded = store.load(g.fingerprint(), spec)
    _assert_same_artifact(loaded, art)
    bits = rng.integers(0, 2, (33, g.n_inputs)).astype(bool)
    assert (loaded.execute(bits) == g.evaluate(bits)).all()


def test_save_is_idempotent(tmp_path, rng):
    store = ArtifactStore(tmp_path)
    _, _, art = _compiled(rng)
    k1 = store.save(art)
    k2 = store.save(art)
    assert k1 == k2 and store.saves == 1 and len(store.keys()) == 1


def test_content_addressing_separates_specs(tmp_path, rng):
    """Same graph under different fabric widths = different entries;
    a structural copy (different name) = the same entry."""
    store = ArtifactStore(tmp_path)
    g = _graph(rng)
    keys = set()
    for n_unit in (8, 16):
        spec = CompileSpec(n_unit=n_unit, optimize="none").normalize(g)
        art = LogicCompiler().compile(g, spec, assume_optimized=True)
        keys.add(store.save(art))
    assert len(keys) == 2
    g2 = g.copy()
    g2.name = "renamed-structural-copy"
    spec = CompileSpec(n_unit=8, optimize="none").normalize(g)
    assert store.contains(g2.fingerprint(), spec)


def test_clean_miss_returns_none(tmp_path, rng):
    store = ArtifactStore(tmp_path)
    g, spec, _ = _compiled(rng)
    assert store.load(g.fingerprint(), spec) is None
    assert store.misses == 1 and store.integrity_failures == 0


def test_store_key_requires_resolved_spec(rng):
    g = _graph(rng)
    with pytest.raises(ValueError, match="auto"):
        store_key(g.fingerprint(), CompileSpec(n_unit="auto"))


def test_custom_pipeline_is_not_storable(tmp_path, rng):
    """A custom PassManager has no declarative serial form: save must
    raise (from to_dict) rather than store a lossy key."""
    from repro.core.opt import PassManager
    store = ArtifactStore(tmp_path)
    g = _graph(rng)
    spec = CompileSpec(n_unit=8, optimize=PassManager([])).normalize(g)
    art = LogicCompiler().compile(g, spec, assume_optimized=True)
    with pytest.raises(ValueError, match="pipeline"):
        store.save(art)


def test_load_key_by_bare_key(tmp_path, rng):
    store = ArtifactStore(tmp_path)
    _, _, art = _compiled(rng)
    key = store.save(art)
    _assert_same_artifact(store.load_key(key), art)
    with pytest.raises(KeyError):
        store.load_key("0" * 32)


# ---------------------------------------------------------------------------
# corruption injection — every bad entry fails LOUDLY and is quarantined
# ---------------------------------------------------------------------------

def _saved(tmp_path, rng):
    store = ArtifactStore(tmp_path)
    g, spec, art = _compiled(rng)
    key = store.save(art)
    return store, g, spec, key


def _assert_integrity_failure(store, g, spec, match):
    with pytest.raises(ArtifactIntegrityError, match=match) as ei:
        store.load(g.fingerprint(), spec)
    # the loud-failure contract: permanent (not retryable), quarantined,
    # and the entry can never be served again — next load is a clean miss
    assert isinstance(ei.value, PermanentCompileError)
    assert ei.value.quarantine_path is not None
    assert ei.value.quarantine_path.exists()
    assert store.integrity_failures == 1 and store.quarantined == 1
    assert store.load(g.fingerprint(), spec) is None


def test_truncated_arrays_fail_loudly(tmp_path, rng):
    store, g, spec, key = _saved(tmp_path, rng)
    npz = store.path_of(key) / "arrays.npz"
    npz.write_bytes(npz.read_bytes()[:100])
    _assert_integrity_failure(store, g, spec, "checksum")


def test_bit_flipped_arrays_fail_loudly(tmp_path, rng):
    store, g, spec, key = _saved(tmp_path, rng)
    npz = store.path_of(key) / "arrays.npz"
    blob = bytearray(npz.read_bytes())
    blob[len(blob) // 2] ^= 0x01
    npz.write_bytes(bytes(blob))
    _assert_integrity_failure(store, g, spec, "checksum")


@pytest.mark.parametrize("pos", ["start", "middle", "end"])
def test_bit_flipped_manifest_fails_loudly(tmp_path, rng, pos):
    """ANY manifest bit flip fails: either json no longer parses or the
    payload no longer matches its own checksum."""
    store, g, spec, key = _saved(tmp_path, rng)
    mf = store.path_of(key) / "manifest.json"
    blob = bytearray(mf.read_bytes())
    i = {"start": 1, "middle": len(blob) // 2, "end": len(blob) - 2}[pos]
    blob[i] ^= 0x08
    mf.write_bytes(bytes(blob))
    _assert_integrity_failure(store, g, spec, "manifest")


def test_fingerprint_mismatch_fails_loudly(tmp_path, rng):
    """A wrong-but-internally-consistent entry (tampered + rechecksummed
    fingerprint) must still be refused — it names a different program."""
    store, g, spec, key = _saved(tmp_path, rng)

    def swap_fp(payload, path):
        payload["fingerprint"] = "f" * len(payload["fingerprint"])
    _retamper(store, key, swap_fp)
    _assert_integrity_failure(store, g, spec, "fingerprint")


def test_tampered_graph_fails_end_to_end_check(tmp_path, rng):
    """Tamper the graph tables AND recompute every checksum: only the
    rebuilt-fingerprint end-to-end check can catch it — and does."""
    import io
    store, g, spec, key = _saved(tmp_path, rng)

    def swap_gates(payload, path):
        blob = (path / "arrays.npz").read_bytes()
        with np.load(io.BytesIO(blob), allow_pickle=False) as z:
            arrays = {k: z[k] for k in z.files}
        gates = arrays["graph_gates"]
        gates[0, 1], gates[0, 2] = gates[0, 2], gates[0, 1] + 1
        buf = io.BytesIO()
        np.savez(buf, **arrays)
        (path / "arrays.npz").write_bytes(buf.getvalue())
        payload["arrays_checksum"] = _digest(buf.getvalue())
    _retamper(store, key, swap_gates)
    _assert_integrity_failure(store, g, spec, "fingerprint")


def test_future_format_version_is_refused(tmp_path, rng):
    store, g, spec, key = _saved(tmp_path, rng)

    def bump(payload, path):
        payload["format_version"] = FORMAT_VERSION + 1
    _retamper(store, key, bump)
    _assert_integrity_failure(store, g, spec, "format-version")


def test_spec_mismatch_fails_loudly(tmp_path, rng):
    store, g, spec, key = _saved(tmp_path, rng)

    def swap_spec(payload, path):
        payload["spec"]["alloc"] = (
            "direct" if payload["spec"]["alloc"] == "liveness"
            else "liveness")
    _retamper(store, key, swap_spec)
    _assert_integrity_failure(store, g, spec, "spec")


def test_load_key_detects_moved_entry(tmp_path, rng):
    """An entry renamed to another key's address is corruption, not a
    hit — the manifest-derived key must re-derive to the address."""
    import shutil
    store, g, spec, key = _saved(tmp_path, rng)
    fake = "0" * len(key)
    dst = store.path_of(fake)
    dst.parent.mkdir(parents=True, exist_ok=True)
    shutil.move(store.path_of(key), dst)
    with pytest.raises(ArtifactIntegrityError, match="key"):
        store.load_key(fake)
    assert store.quarantined == 1


# ---------------------------------------------------------------------------
# ProgramCache integration — write-through, warm start, loud fallback
# ---------------------------------------------------------------------------

def test_cache_write_through_then_warm_start(tmp_path, rng):
    g = _graph(rng)
    spec = CompileSpec(n_unit=8)
    store = ArtifactStore(tmp_path)
    cold = ProgramCache(store=store)
    entry = cold.get(g, spec)
    assert cold.stats() == {
        "entries": 1, "hits": 0, "misses": 1, "compiles": 1,
        "compile_failures": 0, "store_hits": 0, "store_misses": 1,
        "store_failures": 0, "store_saves": 1, "store_save_failures": 0,
        "verifies": 0, "verify_failures": 0, "programs": 1}
    # a brand-new cache over the same store: zero compiles, by counter
    warm = ProgramCache(store=ArtifactStore(tmp_path))
    w_entry = warm.get(g, spec)
    assert warm.stats()["compiles"] == 0
    assert warm.stats()["store_hits"] == 1
    _assert_same_artifact(w_entry.artifact, entry.artifact)
    # in-memory hit on repeat: the store is not consulted again
    warm.get(g, spec)
    assert warm.stats()["hits"] == 1 and warm.store.loads == 1


def test_cache_falls_back_to_compile_on_corruption(tmp_path, rng):
    """A corrupt store degrades to cold-start latency, never to wrong
    bits or a crashed server: counter-pinned fallback + quarantine."""
    g = _graph(rng)
    spec = CompileSpec(n_unit=8)
    store = ArtifactStore(tmp_path)
    ProgramCache(store=store).get(g, spec)
    key = store.keys()[0]
    npz = store.path_of(key) / "arrays.npz"
    npz.write_bytes(b"not an npz at all")

    fresh_store = ArtifactStore(tmp_path)
    cache = ProgramCache(store=fresh_store)
    entry = cache.get(g, spec)
    st = cache.stats()
    assert st["compiles"] == 1 and st["store_failures"] == 1
    assert st["store_hits"] == 0 and st["store_saves"] == 1
    assert fresh_store.integrity_failures == 1
    assert fresh_store.quarantined == 1
    bits = rng.integers(0, 2, (25, g.n_inputs)).astype(bool)
    assert (entry.artifact.execute(bits) == g.evaluate(bits)).all()
    # the write-through after fallback republished a valid entry
    warm = ProgramCache(store=ArtifactStore(tmp_path))
    warm.get(g, spec)
    assert warm.stats()["compiles"] == 0


def test_cache_survives_store_write_failure(tmp_path, rng):
    """Write-through is best-effort: a failing disk warns and counts,
    serving continues."""
    g = _graph(rng)
    store = ArtifactStore(tmp_path)
    store.save = lambda artifact: (_ for _ in ()).throw(OSError("disk full"))
    cache = ProgramCache(store=store)
    with pytest.warns(RuntimeWarning, match="write-through"):
        entry = cache.get(g, CompileSpec(n_unit=8))
    st = cache.stats()
    assert st["store_save_failures"] == 1 and st["store_saves"] == 0
    assert entry.artifact is not None


def test_cache_without_store_pins_zero_store_counters(rng):
    cache = ProgramCache()
    cache.get(_graph(rng), CompileSpec(n_unit=8))
    st = cache.stats()
    assert st["compiles"] == 1
    assert (st["store_hits"], st["store_misses"], st["store_failures"],
            st["store_saves"], st["store_save_failures"]) == (0,) * 5


def test_engine_and_frontdoor_store_wiring(tmp_path, rng):
    g = _graph(rng)
    store = ArtifactStore(tmp_path)
    eng = LogicEngine(CompileSpec(n_unit=8), capacity=64, store=store)
    assert eng.cache.store is store
    bits = rng.integers(0, 2, (20, g.n_inputs)).astype(bool)
    assert (eng.serve(g, bits) == g.evaluate(bits)).all()
    # the front door warm-starts its engine from the populated store
    door = FrontDoor(spec=CompileSpec(n_unit=8), capacity=64,
                     store=ArtifactStore(tmp_path))
    assert (door.engine.serve(g, bits) == g.evaluate(bits)).all()
    assert door.engine.cache.stats()["compiles"] == 0
    assert door.engine.cache.stats()["store_hits"] == 1
    # a caller-owned engine and a store are mutually exclusive
    with pytest.raises(ValueError, match="store"):
        LogicEngine(CompileSpec(n_unit=8), cache=ProgramCache(), store=store)
    with pytest.raises(ValueError, match="store"):
        FrontDoor(engine=eng, store=store)


# ---------------------------------------------------------------------------
# raw-identity aliases — warm start without re-running the optimizer
# ---------------------------------------------------------------------------

def test_alias_warm_start_skips_pipeline(tmp_path, rng, monkeypatch):
    """The whole point of alias records: a fresh process resolves a raw
    graph + ``optimize="default"`` spec from the store WITHOUT running
    the pass pipeline (the dominant cold-start cost).  Pinned by making
    the pipeline explode, not by timing."""
    from repro.core.opt import PassManager
    g = _graph(rng)
    spec = CompileSpec(n_unit=8)          # optimize="default"
    ProgramCache(store=ArtifactStore(tmp_path)).get(g, spec)

    def boom(self, graph):
        raise AssertionError("pass pipeline ran on the warm path")
    monkeypatch.setattr(PassManager, "run", boom)
    warm = ProgramCache(store=ArtifactStore(tmp_path))
    entry = warm.get(g.copy(), spec)      # fresh object: no memos
    st = warm.stats()
    assert st["compiles"] == 0 and st["store_hits"] == 1
    bits = rng.integers(0, 2, (20, g.n_inputs)).astype(bool)
    assert (entry.artifact.execute(bits) == g.evaluate(bits)).all()
    # and the repeat request stays in memory (memos were seeded)
    warm.get(g.copy(), spec)
    assert warm.stats()["hits"] == 1 and warm.store.loads == 1


def test_corrupt_alias_fails_loudly_and_falls_back(tmp_path, rng):
    """A flipped alias record is refused + quarantined; the cache falls
    back to the normal path, which still finds the (valid) canonical
    entry — zero compiles, one counted store failure."""
    from repro.core.artifact_store import alias_key
    g = _graph(rng)
    spec = CompileSpec(n_unit=8)
    store = ArtifactStore(tmp_path)
    ProgramCache(store=store).get(g, spec)
    apath = store.alias_path_of(alias_key(g.fingerprint(), spec))
    blob = bytearray(apath.read_bytes())
    blob[len(blob) // 2] ^= 0x04
    apath.write_bytes(bytes(blob))

    cache = ProgramCache(store=ArtifactStore(tmp_path))
    cache.get(g.copy(), spec)
    st = cache.stats()
    assert st["compiles"] == 0            # canonical entry still served
    assert st["store_failures"] == 1 and st["store_hits"] == 1
    assert cache.store.quarantined == 1
    assert not apath.exists()             # record can never be read again

    # a direct load of a (re-)corrupted record raises, quarantines
    apath.write_bytes(b"{ not json")
    fresh_store = ArtifactStore(tmp_path)
    with pytest.raises(ArtifactIntegrityError, match="alias"):
        fresh_store.load_alias(g.fingerprint(), spec)
    assert fresh_store.quarantined == 1


def test_dangling_alias_is_a_clean_miss(tmp_path, rng):
    """An alias whose canonical entry was quarantined by another process
    reads as a miss: recompile, republish, no error."""
    import shutil
    g = _graph(rng)
    spec = CompileSpec(n_unit=8)
    store = ArtifactStore(tmp_path)
    ProgramCache(store=store).get(g, spec)
    shutil.rmtree(tmp_path / "objects")
    (tmp_path / "objects").mkdir()

    cache = ProgramCache(store=ArtifactStore(tmp_path))
    cache.get(g.copy(), spec)
    st = cache.stats()
    assert st["compiles"] == 1 and st["store_hits"] == 0
    # write-through republished BOTH records: next process warm-starts
    warm = ProgramCache(store=ArtifactStore(tmp_path))
    warm.get(g.copy(), spec)
    assert warm.stats()["compiles"] == 0


def test_alias_respects_spec_identity(tmp_path, rng):
    """Aliases are keyed by the requested spec too: a different fabric
    width must not hit another spec's alias."""
    g = _graph(rng)
    store = ArtifactStore(tmp_path)
    ProgramCache(store=store).get(g, CompileSpec(n_unit=8))
    assert store.load_alias(g.fingerprint(), CompileSpec(n_unit=16)) is None
    cache = ProgramCache(store=ArtifactStore(tmp_path))
    cache.get(g.copy(), CompileSpec(n_unit=16))
    assert cache.stats()["compiles"] == 1


# ---------------------------------------------------------------------------
# concurrency — the atomic-rename publish contract
# ---------------------------------------------------------------------------

def test_racing_writers_one_valid_artifact(tmp_path, rng):
    """N threads publish the same key at once: exactly one entry exists,
    every racer either published or benignly lost the rename, and the
    survivor verifies."""
    g, spec, art = _compiled(rng)
    stores = [ArtifactStore(tmp_path) for _ in range(8)]
    barrier = threading.Barrier(len(stores))
    errors = []

    def publish(store):
        try:
            barrier.wait()
            store.save(art)
        except Exception as exc:              # noqa: BLE001 — fail the test
            errors.append(exc)

    threads = [threading.Thread(target=publish, args=(s,)) for s in stores]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    assert len(stores[0].keys()) == 1
    assert sum(s.saves + s.save_races for s in stores) == len(stores)
    _assert_same_artifact(stores[0].load(g.fingerprint(), spec), art)
    assert (tmp_path / "tmp").exists()
    assert list((tmp_path / "tmp").iterdir()) == []   # staging all cleaned


def test_reader_never_sees_torn_write(tmp_path, rng):
    """Readers racing a writer observe either a clean miss or a fully
    verified artifact — never a torn entry (that would raise)."""
    g, spec, art = _compiled(rng, n_gates=120)
    writer_store = ArtifactStore(tmp_path)
    outcomes, errors = [], []
    start = threading.Event()

    def read():
        store = ArtifactStore(tmp_path)
        start.wait()
        deadline = time.monotonic() + 30.0
        while time.monotonic() < deadline:
            try:
                loaded = store.load(g.fingerprint(), spec)
            except Exception as exc:          # noqa: BLE001 — fail the test
                errors.append(exc)
                return
            if loaded is not None:
                _assert_same_artifact(loaded, art)
                outcomes.append(True)         # observed the published entry
                return
        outcomes.append(False)                # never saw the write land

    readers = [threading.Thread(target=read) for _ in range(4)]
    for t in readers:
        t.start()
    start.set()
    writer_store.save(art)
    for t in readers:
        t.join()
    assert not errors
    assert outcomes == [True] * len(readers)


# ---------------------------------------------------------------------------
# hypothesis property coverage
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    @st.composite
    def artifact_cases(draw):
        seed = draw(st.integers(0, 10 ** 6))
        n_inputs = draw(st.integers(2, 10))
        n_gates = draw(st.integers(1, 120))
        n_unit = draw(st.sampled_from([8, 16]))
        alloc = draw(st.sampled_from(["direct", "liveness"]))
        max_gates = draw(st.sampled_from([None, 40]))
        rng = np.random.default_rng(seed)
        g = random_graph(rng, n_inputs, n_gates,
                         min(4, n_gates), locality=16)
        spec = CompileSpec(n_unit=n_unit, alloc=alloc, max_gates=max_gates,
                           optimize="none").normalize(g)
        return g, spec

    @settings(max_examples=25, deadline=None)
    @given(artifact_cases())
    def test_property_round_trip_byte_identical(tmp_path_factory, case):
        """For arbitrary (graph, spec): save -> load reproduces every
        schedule stream byte for byte and every spec field exactly."""
        g, spec = case
        art = LogicCompiler().compile(g, spec, assume_optimized=True)
        store = ArtifactStore(tmp_path_factory.mktemp("prop-store"))
        store.save(art)
        loaded = store.load(g.fingerprint(), spec)
        _assert_same_artifact(loaded, art)
        assert loaded.spec == spec

    @settings(max_examples=50, deadline=None)
    @given(st.sampled_from([8, 16, 64]),
           st.sampled_from(["direct", "liveness"]),
           st.booleans(), st.booleans(),
           st.sampled_from([None, 40, 4096]),
           st.sampled_from(["none", "default"]))
    def test_property_spec_dict_round_trip(n_unit, alloc, opcode_sort,
                                           fuse_levels, max_gates, optimize):
        spec = CompileSpec(n_unit=n_unit, alloc=alloc,
                           opcode_sort=opcode_sort, fuse_levels=fuse_levels,
                           max_gates=max_gates, optimize=optimize)
        back = CompileSpec.from_dict(spec.to_dict())
        assert back == spec and back.cache_key() == spec.cache_key()
        assert (_canonical_json(back.to_dict())
                == _canonical_json(spec.to_dict()))


# ---------------------------------------------------------------------------
# two-process warm start (the fleet contract, end to end)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_two_process_warm_start(tmp_path):
    """tools/precompile.py in one process, a fresh engine in another:
    the engine's first request compiles nothing (counter-pinned)."""
    args = ["--seed", "3", "--gates", "250", "--inputs", "10",
            "--outputs", "6", "--n-unit", "8"]
    env = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"}
    try:
        pre = subprocess.run(
            [sys.executable, "tools/precompile.py", "--store",
             str(tmp_path), "--jobs", "0", "--verify", *args],
            capture_output=True, text=True, timeout=300, env=env)
        assert pre.returncode == 0, pre.stderr[-2000:]
        warm = subprocess.run(
            [sys.executable, "examples/warm_start.py", "--store",
             str(tmp_path), *args],
            capture_output=True, text=True, timeout=300, env=env)
    except subprocess.TimeoutExpired:
        pytest.skip("two-process warm-start smoke exceeded 300s")
    assert warm.returncode == 0, warm.stderr[-2000:]
    assert "0 compiles" in warm.stdout and "warm-start OK" in warm.stdout
