"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev)")
from hypothesis import given, settings, strategies as st

from repro.core import packing
from repro.core.gate_ir import random_graph
from repro.core.scheduler import compile_graph, execute_program_np
from repro.core.spec import CompileSpec
from repro.kernels.logic_dsp import (logic_infer_bits,
                                     pack_bits_jnp, unpack_bits_jnp)
from repro.kernels.xnor_gemm import pack_pm1, xnor_gemm, xnor_gemm_ref


# ---------------------------------------------------------------------------
# packing
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.integers(1, 200), st.integers(1, 40), st.integers(0, 2 ** 31 - 1))
def test_packing_roundtrip(batch, n, seed):
    rng = np.random.default_rng(seed)
    bits = rng.integers(0, 2, (batch, n)).astype(bool)
    words = packing.pack_bits(bits)
    assert words.shape == (n, -(-batch // 32))
    assert (packing.unpack_bits(words, batch) == bits).all()
    # jnp implementation bit-identical
    jw = np.asarray(pack_bits_jnp(jnp.asarray(bits)))
    assert (jw == words).all()
    assert (np.asarray(unpack_bits_jnp(jnp.asarray(words), batch)) == bits
            ).all()


# ---------------------------------------------------------------------------
# logic_dsp kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("ni,ng,no,n_unit,alloc,batch", [
    (4, 10, 2, 8, "direct", 33),
    (8, 200, 5, 16, "direct", 300),
    (8, 200, 5, 16, "liveness", 300),
    (32, 800, 24, 64, "liveness", 257),
    (16, 500, 8, 3, "liveness", 64),
    (6, 50, 6, 128, "direct", 1000),   # n_unit >> gates per level
])
def test_logic_kernel_vs_oracle(ni, ng, no, n_unit, alloc, batch, rng):
    g = random_graph(rng, ni, ng, no)
    prog = compile_graph(g, CompileSpec(n_unit=n_unit, alloc=alloc,
                                        optimize="none"))
    X = rng.integers(0, 2, (batch, ni)).astype(bool)
    ref = g.evaluate(X)
    assert (execute_program_np(prog, X) == ref).all()
    assert (logic_infer_bits(prog, X) == ref).all()                # pallas
    assert (logic_infer_bits(prog, X, use_ref=True) == ref).all()  # jnp ref


def test_logic_kernel_multiblock(rng):
    """W > block_w exercises the grid (paper's multi-round batching)."""
    g = random_graph(rng, 8, 100, 4)
    prog = compile_graph(g, CompileSpec(n_unit=16, optimize="none"))
    X = rng.integers(0, 2, (32 * 300, 8)).astype(bool)  # W = 300 words
    assert (logic_infer_bits(prog, X, block_w=128) == g.evaluate(X)).all()


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10 ** 6))
def test_logic_kernel_property(seed):
    rng = np.random.default_rng(seed)
    ni = int(rng.integers(2, 10))
    g = random_graph(rng, ni, int(rng.integers(5, 120)), 3)
    prog = compile_graph(g, CompileSpec(
        n_unit=int(rng.integers(1, 33)),
        alloc=str(rng.choice(["direct", "liveness"])), optimize="none"))
    X = rng.integers(0, 2, (int(rng.integers(1, 100)), ni)).astype(bool)
    assert (logic_infer_bits(prog, X) == g.evaluate(X)).all()


# ---------------------------------------------------------------------------
# xnor_gemm kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,n,k,bm,bn,bk", [
    (64, 48, 100, 32, 32, 2),
    (128, 128, 512, 128, 128, 16),
    (17, 5, 33, 8, 8, 1),
    (256, 64, 2304, 64, 64, 8),   # VGG16 conv fanin (paper §1)
])
def test_xnor_gemm_vs_oracle(m, n, k, bm, bn, bk, rng):
    a = jnp.asarray(rng.integers(0, 2, (m, k)), jnp.uint8)
    b = jnp.asarray(rng.integers(0, 2, (n, k)), jnp.uint8)
    got = xnor_gemm(a, b, bm=bm, bn=bn, bk=bk)
    assert (np.asarray(got) == np.asarray(xnor_gemm_ref(a, b))).all()


def test_pack_pm1_shapes(rng):
    bits = jnp.asarray(rng.integers(0, 2, (5, 70)), jnp.uint8)
    packed = pack_pm1(bits)
    assert packed.shape == (5, 3)
