"""Static schedule verifier (core/verify.py): clean-pass matrix and
mutation-tested detection power.

Two obligations, pinned together because they are meaningless apart:

  * **zero false positives** — every artifact the real toolchain emits
    (both allocators x n_unit {8, 64} x monolithic / partitioned /
    chain, compiled fresh or round-tripped through the store) verifies
    with zero diagnostics;
  * **100% mutation kill** — every seeded mutation operator (operand
    swaps, liveness clobbers, NOP hijacks, metadata lies, megaprogram
    corruption ...) applied to a verified-clean program is detected.
    Dataflow mutations pick their site by *backward liveness* over the
    streams — mutating a dead lane is semantics-preserving and MUST NOT
    be part of the kill gate.

Also here: the §10.4 alias-trust closure (a store entry that passes
every checksum but encodes a wrong schedule is quarantined on load and
the request falls back to a clean compile — counter-pinned), the
``build_megaprogram`` trash-aliasing guard, the ``verify=`` knob
contract on :class:`CompileSpec`, and hypothesis property sections.
"""
import dataclasses

import numpy as np
import pytest

from repro.core.artifact_store import ArtifactStore
from repro.core.compiler import CompiledArtifact, LogicCompiler
from repro.core.errors import ArtifactIntegrityError
from repro.core.gate_ir import (LogicGraph, OpCode, compose_graphs,
                                random_graph)
from repro.core.opt import Pass, PassManager, PassResult, identity_remap
from repro.core.partition import (compile_partitions, mega_pipeline,
                                  output_permutation, partition)
from repro.core.scheduler import build_megaprogram, compile_graph
from repro.core.spec import CompileSpec
from repro.core.verify import (RULE_CODES, ScheduleVerificationError,
                               certify_remap, effective_mode,
                               verify_artifact, verify_megaprogram,
                               verify_program)
from repro.serve.logic_engine import ProgramCache

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:           # tier-1 containers may lack hypothesis
    HAVE_HYPOTHESIS = False

N_UNITS = (8, 64)
ALLOCS = ("direct", "liveness")

NOP = int(OpCode.NOP)
UNARY_OPS = (int(OpCode.NOT), int(OpCode.COPY))


def _graph(rng, n_in=12, n_gates=300, n_out=10):
    return random_graph(rng, n_inputs=n_in, n_gates=n_gates,
                        n_outputs=n_out, locality=48)


def _mono(rng, n_unit=16, alloc="liveness"):
    """A verified-clean (program, graph) pair from the real toolchain.

    Compiled with ``optimize="default"`` so structural hashing has run:
    distinct rows then hold distinct terms, which the operand-directed
    mutation operators rely on for a guaranteed kill."""
    g = _graph(rng)
    art = LogicCompiler().compile(
        g, CompileSpec(n_unit=n_unit, alloc=alloc))
    return art.programs[0], art.graph


# ---------------------------------------------------------------------------
# backward liveness: mutation sites that provably reach an output
# ---------------------------------------------------------------------------

def _live_sites(p):
    """(step, lane) sites whose write flows to an output read, newest
    first — the only sites where a dataflow mutation is guaranteed to
    change an output term."""
    needed = {int(a) for a in np.asarray(p.output_addrs)}
    sites = []
    for s in range(p.n_steps - 1, -1, -1):
        writers = []            # effective (last-lane-wins) live writers
        written = set()
        for u in range(p.n_unit - 1, -1, -1):
            d, op = int(p.dst[s, u]), int(p.opcode[s, u])
            if op == NOP and d == p.trash_addr:
                continue        # padding lane
            if d in needed and d not in written:
                written.add(d)
                writers.append((u, op))
        reads = set()
        for u, op in writers:
            sites.append((s, u))
            if op != NOP:
                reads.add(int(p.src_a[s, u]))
                if op not in UNARY_OPS:
                    reads.add(int(p.src_b[s, u]))
        needed -= written
        needed |= reads
    return sites


def _live_binary_site(p):
    for s, u in _live_sites(p):
        op = int(p.opcode[s, u])
        if op != NOP and op not in UNARY_OPS and \
                int(p.src_a[s, u]) != int(p.src_b[s, u]):
            return s, u
    return None


def _mut_array(p, field, fn):
    arr = np.array(getattr(p, field))
    fn(arr)
    return dataclasses.replace(p, **{field: arr})


# Each operator: name -> fn(program) returning a mutated program.
# Every operator must find a site on the standard fixture (asserted).

def _op_swap_operands(p):
    s, u = _live_binary_site(p)
    a, b = np.array(p.src_a), np.array(p.src_b)
    a[s, u], b[s, u] = b[s, u], a[s, u]
    return dataclasses.replace(p, src_a=a, src_b=b)


def _op_duplicate_operand(p):
    s, u = _live_binary_site(p)
    b = np.array(p.src_b)
    b[s, u] = p.src_a[s, u]
    return dataclasses.replace(p, src_b=b)


def _op_opcode_flip(p):
    s, u = _live_binary_site(p)
    op = int(p.opcode[s, u])
    return _mut_array(p, "opcode", lambda a: a.__setitem__(
        (s, u), int(OpCode.OR) if op != int(OpCode.OR) else int(OpCode.AND)))


def _op_nop_hijack(p):
    pads = np.argwhere((p.opcode == NOP) & (p.dst == p.trash_addr))
    if not len(pads):
        return None
    s, u = map(int, pads[0])
    return _mut_array(p, "dst", lambda a: a.__setitem__(
        (s, u), int(np.asarray(p.output_addrs)[0])))


def _op_dst_to_trash(p):
    s, u = _live_sites(p)[0]
    return _mut_array(p, "dst", lambda a: a.__setitem__(
        (s, u), p.trash_addr))


def _op_step_swap(p):
    # find a live lane reading a row the PREVIOUS step's live lane wrote
    sites = set(_live_sites(p))
    for s, u in sorted(sites):
        if s == 0 or int(p.opcode[s, u]) == NOP:
            continue
        prev_writes = {int(p.dst[s - 1, v])
                       for v in range(p.n_unit) if (s - 1, v) in sites}
        reads = {int(p.src_a[s, u])}
        if int(p.opcode[s, u]) not in UNARY_OPS:
            reads.add(int(p.src_b[s, u]))
        if reads & prev_writes:
            arrays = {}
            for f in ("src_a", "src_b", "dst", "opcode", "step_opcode",
                      "homogeneous", "level_of_step"):
                arr = np.array(getattr(p, f))
                arr[[s - 1, s]] = arr[[s, s - 1]]
                arrays[f] = arr
            return dataclasses.replace(p, **arrays)
    return None


def _op_oob_read(p):
    s, u = _live_sites(p)[0]
    return _mut_array(p, "src_a", lambda a: a.__setitem__((s, u), p.n_addr))


def _op_lane_chop(p):
    if p.n_unit < 2:
        return None
    return dataclasses.replace(
        p, src_a=p.src_a[:, :-1], src_b=p.src_b[:, :-1],
        dst=p.dst[:, :-1], opcode=p.opcode[:, :-1])


def _op_homog_lie(p):
    h = np.array(p.homogeneous)
    h[0] = ~h[0].astype(bool)
    return dataclasses.replace(p, homogeneous=h)


def _op_input_shift(p):
    return dataclasses.replace(
        p, input_addrs=np.asarray(p.input_addrs) + 1)


def _op_output_swap(p):
    outs = np.array(p.output_addrs)
    pairs = [(j, k) for j in range(len(outs)) for k in range(j + 1,
             len(outs)) if outs[j] != outs[k]]
    if not pairs:
        return None
    j, k = pairs[0]
    outs[j], outs[k] = outs[k], outs[j]
    return dataclasses.replace(p, output_addrs=outs)


def _op_output_to_trash(p):
    outs = np.array(p.output_addrs)
    outs[0] = p.trash_addr
    return dataclasses.replace(p, output_addrs=outs)


def _op_trash_alias(p):
    return dataclasses.replace(p, trash_addr=2)   # first input row


def _op_step_dup(p):
    s = _live_sites(p)[0][0]
    arrays = {}
    for f in ("src_a", "src_b", "dst", "opcode", "step_opcode",
              "homogeneous", "level_of_step"):
        arr = np.asarray(getattr(p, f))
        arrays[f] = np.concatenate([arr, arr[s:s + 1]], axis=0)
    return dataclasses.replace(p, **arrays)


def _op_gates_lie(p):
    return dataclasses.replace(p, n_gates=p.n_gates + 1)


MUTATIONS = {
    "swap-operands": _op_swap_operands,
    "duplicate-operand": _op_duplicate_operand,
    "opcode-flip": _op_opcode_flip,
    "nop-hijack": _op_nop_hijack,
    "dst-to-trash": _op_dst_to_trash,
    "step-swap": _op_step_swap,
    "oob-read": _op_oob_read,
    "lane-chop": _op_lane_chop,
    "homog-lie": _op_homog_lie,
    "input-shift": _op_input_shift,
    "output-swap": _op_output_swap,
    "output-to-trash": _op_output_to_trash,
    "trash-alias": _op_trash_alias,
    "step-dup": _op_step_dup,
    "gates-lie": _op_gates_lie,
}


# ---------------------------------------------------------------------------
# vocabulary + knob contract
# ---------------------------------------------------------------------------

def test_rule_code_vocabulary_pinned():
    assert RULE_CODES == tuple(f"V{c}" for c in range(101, 116))


def test_verify_knob_contract():
    with pytest.raises(ValueError, match="verify"):
        CompileSpec(verify="paranoid")
    on = CompileSpec(n_unit=16, verify="full")
    off = CompileSpec(n_unit=16)
    # operational knob: same identity, same serialization, same key —
    # verify-on and verify-off fleets must share store entries
    assert on == off
    assert on.cache_key() == off.cache_key()
    assert on.to_dict() == off.to_dict()
    assert "verify" not in on.to_dict()
    # ... but from_dict still accepts the key (forward tooling)
    assert CompileSpec.from_dict({**on.to_dict(), "verify": "full"}
                                 ).verify == "full"
    assert effective_mode("off", None) == "off"
    assert effective_mode("off", "load") == "load"
    assert effective_mode("compile", "full") == "compile"
    with pytest.raises(ValueError, match="verify"):
        LogicCompiler(verify="sometimes")


# ---------------------------------------------------------------------------
# zero false positives: the clean conformance matrix
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("alloc", ALLOCS)
@pytest.mark.parametrize("n_unit", N_UNITS)
def test_clean_monolithic(rng, alloc, n_unit):
    g = _graph(rng)
    art = LogicCompiler().compile(
        g, CompileSpec(n_unit=n_unit, alloc=alloc, verify="full"))
    report = verify_artifact(art)
    assert report.ok, report.summary()
    report = verify_program(art.programs[0], art.graph)
    assert report.ok, report.summary()
    # program-only (no graph) verification is a strict subset
    assert verify_program(art.programs[0]).ok


@pytest.mark.parametrize("alloc", ALLOCS)
@pytest.mark.parametrize("n_unit", N_UNITS)
def test_clean_partitioned(rng, alloc, n_unit):
    g = _graph(rng)
    art = LogicCompiler().compile(
        g, CompileSpec(n_unit=n_unit, alloc=alloc, max_gates=120,
                       verify="full"))
    assert len(art.programs) > 1
    report = verify_artifact(art)      # includes the parallel megaprogram
    assert report.ok, report.summary()


@pytest.mark.parametrize("alloc", ALLOCS)
@pytest.mark.parametrize("n_unit", N_UNITS)
def test_clean_chain(rng, alloc, n_unit):
    g1 = _graph(rng)
    g2 = random_graph(rng, n_inputs=g1.n_outputs, n_gates=200,
                      n_outputs=8, locality=32)
    spec = CompileSpec(n_unit=n_unit, alloc=alloc, optimize="none")
    progs = [compile_graph(g, spec) for g in (g1, g2)]
    mega = build_megaprogram(progs, mode="chain", name="chain")
    composed = compose_graphs([g1, g2], name="composed")
    report = verify_megaprogram(mega, composed)
    assert report.ok, report.summary()


def test_clean_store_roundtrip(rng, tmp_path):
    g = _graph(rng)
    store = ArtifactStore(tmp_path / "store", verify_on_load=True)
    spec = CompileSpec(n_unit=16)
    cache = ProgramCache(store=store)
    cache.get(g, spec)
    # a fresh process loads the published artifact; verify_on_load means
    # the store itself re-proves the schedule before returning it
    warm = ProgramCache(store=store)
    entry = warm.get(g, spec)
    assert warm.stats()["compiles"] == 0
    assert verify_artifact(entry.artifact).ok


def test_clean_get_chain(rng):
    g1 = _graph(rng)
    g2 = random_graph(rng, n_inputs=g1.n_outputs, n_gates=150,
                      n_outputs=6, locality=32)
    cache = ProgramCache()
    entry = cache.get_chain([g1, g2], CompileSpec(n_unit=16,
                                                  verify="compile"))
    assert cache.stats()["verifies"] == 1
    assert verify_artifact(entry.artifact).ok


# ---------------------------------------------------------------------------
# 100% mutation kill
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(MUTATIONS))
@pytest.mark.parametrize("alloc", ALLOCS)
def test_mutation_killed(rng, name, alloc):
    prog, graph = _mono(rng, alloc=alloc)
    assert verify_program(prog, graph).ok       # clean before mutation
    mutated = MUTATIONS[name](prog)
    assert mutated is not None, f"operator {name} found no site"
    report = verify_program(mutated, graph)
    assert not report.ok, f"mutation {name} survived verification"
    assert all(d.code in RULE_CODES for d in report.diagnostics)


def test_mutation_sites_are_live(rng):
    """The site picker only returns output-reaching lanes: zeroing any
    NON-site lane's write must keep the outputs' terms intact (i.e. the
    harness never wastes a kill obligation on dead code)."""
    prog, graph = _mono(rng)
    live = set(_live_sites(prog))
    assert live, "no live sites on a 300-gate program?"
    # every output row's final writer is a live site
    for a in np.asarray(prog.output_addrs):
        hits = np.argwhere(prog.dst == int(a))
        s, u = map(int, hits[hits[:, 0].argmax()])
        assert (s, u) in live


MEGA_MUTATIONS = {}


def _mega_op(fn):
    MEGA_MUTATIONS[fn.__name__.replace("_mop_", "").replace("_", "-")] = fn
    return fn


@_mega_op
def _mop_meta_shift(m):
    meta = list(m.stage_meta)
    lo, hi, ni, no, olo = meta[-1]
    meta[-1] = (lo + 1, hi, ni, no, olo)
    return dataclasses.replace(m, stage_meta=tuple(meta))


@_mega_op
def _mop_naddr_shrink(m):
    return dataclasses.replace(m, n_addr=m.n_addr - 1)


@_mega_op
def _mop_perm_break(m):
    perm = np.array(m.output_perm)
    if len(perm) < 2:
        return None
    perm[0] = perm[1]
    return dataclasses.replace(m, output_perm=perm)


@_mega_op
def _mop_step_trash_corrupt(m):
    st_ = np.array(m.step_trash)
    st_[0] = 0
    return dataclasses.replace(m, step_trash=st_)


@_mega_op
def _mop_stream_corrupt(m):
    dst = np.array(m.dst)
    dst[0, 0] = m.n_addr - 1 if dst[0, 0] != m.n_addr - 1 else 0
    return dataclasses.replace(m, dst=dst)


@_mega_op
def _mop_out_addrs_corrupt(m):
    oa = np.array(m.out_addrs)
    oa[0] = (oa[0] + 1) % m.n_addr
    return dataclasses.replace(m, out_addrs=oa)


@pytest.mark.parametrize("name", sorted(MEGA_MUTATIONS))
@pytest.mark.parametrize("mode", ("chain", "parallel"))
def test_mega_mutation_killed(rng, name, mode):
    g = _graph(rng)
    if mode == "chain":
        g2 = random_graph(rng, n_inputs=g.n_outputs, n_gates=150,
                          n_outputs=6, locality=32)
        spec = CompileSpec(n_unit=16, optimize="none")
        progs = [compile_graph(x, spec) for x in (g, g2)]
        mega = build_megaprogram(progs, mode="chain")
        graph = compose_graphs([g, g2])
    else:
        spec = CompileSpec(n_unit=16, optimize="none", max_gates=120)
        parts = partition(g, spec)
        progs = compile_partitions(parts, spec)
        perm = output_permutation(parts, g.n_outputs)
        mega = mega_pipeline(progs, perm, mode="parallel")
        graph = g
    assert verify_megaprogram(mega, graph).ok
    mutated = MEGA_MUTATIONS[name](mega)
    if mutated is None:
        pytest.skip(f"no site for {name} in mode {mode}")
    report = verify_megaprogram(mutated, graph)
    assert not report.ok, f"mega mutation {name} survived"
    assert all(d.code in RULE_CODES for d in report.diagnostics)


# ---------------------------------------------------------------------------
# the build_megaprogram trash-aliasing guard (satellite regression)
# ---------------------------------------------------------------------------

def test_megaprogram_rejects_trash_aliasing_stage():
    """A stage whose trash row aliases an input row (only reachable via
    an untrusted ``from_payload``) must be refused at build time — its
    padding lanes would clobber the stage's own input preload."""
    g = LogicGraph(2, name="tiny")
    w = g.add_gate(OpCode.AND, 2, 3)
    g.set_outputs([g.add_gate(OpCode.XOR, w, 2)])
    prog = compile_graph(g, CompileSpec(n_unit=4, optimize="none"))
    assert verify_program(prog, g).ok
    bad = dataclasses.replace(prog, trash_addr=2)      # input row 0
    report = verify_program(bad, g)
    assert not report.ok
    assert any(d.code == "V104" for d in report.diagnostics)
    with pytest.raises(ValueError, match="aliases"):
        build_megaprogram([bad, bad], mode="parallel")


# ---------------------------------------------------------------------------
# §10.4 closure: verifier-rejected store entries quarantine + fall back
# ---------------------------------------------------------------------------

def _poisoned_store(tmp_path, g, spec):
    """A store holding a checksum-valid but schedule-WRONG artifact for
    (g, spec), alias record included — §10.4's trust hole made flesh."""
    store = ArtifactStore(tmp_path / "store")
    opt = spec.pipeline.run(g).graph
    mono = spec.normalize(opt).with_(optimize="none")
    prog = compile_graph(opt, mono)
    bad = _op_swap_operands(prog)
    art = CompiledArtifact(
        spec=mono, graph=opt, programs=(bad,),
        output_perm=np.arange(opt.n_outputs, dtype=np.int64))
    key = store.save(art)
    store.save_alias(g.fingerprint(), spec, key)
    return store, key


def test_verifier_rejects_poisoned_store_entry(rng, tmp_path):
    g = _graph(rng)
    spec = CompileSpec(n_unit=16, verify="load")
    store, key = _poisoned_store(tmp_path, g, spec)
    cache = ProgramCache(store=store)
    entry = cache.get(g, spec)
    # the poisoned artifact was loaded (via the alias fast path),
    # rejected BEFORE any memo was seeded, quarantined, and the request
    # fell back to a clean compile + write-through at the same key
    stats = cache.stats()
    assert stats["verifies"] == 1
    assert stats["verify_failures"] == 1
    assert stats["compiles"] == 1
    assert stats["store_hits"] == 0
    assert verify_artifact(entry.artifact).ok
    assert store.quarantined == 1
    assert key in store                      # re-published clean
    assert verify_artifact(store.load_key(key)).ok
    # a second fresh process warm-starts from the re-published entry
    warm = ProgramCache(store=store)
    warm.get(g, spec)
    assert warm.stats()["compiles"] == 0
    assert warm.stats()["verify_failures"] == 0


def test_verifier_off_trusts_poisoned_entry(rng, tmp_path):
    """Without the knob the §10.4 trust model is unchanged (checksums
    only) — pinning that the default stays cheap and the closure is an
    opt-in."""
    g = _graph(rng)
    spec = CompileSpec(n_unit=16)            # verify="off"
    store, _ = _poisoned_store(tmp_path, g, CompileSpec(
        n_unit=16, verify="load"))
    cache = ProgramCache(store=store)
    cache.get(g, spec)
    stats = cache.stats()
    assert stats["verifies"] == 0 and stats["compiles"] == 0
    assert stats["store_hits"] == 1


def test_store_verify_on_load_knob(rng, tmp_path):
    g = _graph(rng)
    spec = CompileSpec(n_unit=16, verify="load")
    store, key = _poisoned_store(tmp_path, g, spec)
    checking = ArtifactStore(store.root, verify_on_load=True)
    with pytest.raises(ArtifactIntegrityError, match="verification"):
        checking.load_key(key)
    assert checking.quarantined == 1


# ---------------------------------------------------------------------------
# compile-path gating: ScheduleVerificationError + remap certificates
# ---------------------------------------------------------------------------

class _BrokenPass(Pass):
    """Rewrites nothing but lies about the wire map (drops outputs)."""

    name = "broken"

    def run(self, graph):
        remap = identity_remap(graph)
        remap[graph.outputs[0]] = -1
        return PassResult(graph, remap)


def test_certify_remap_catches_broken_pass(rng):
    g = _graph(rng)
    res = _BrokenPass().run(g)
    diags = certify_remap(g, res.graph, res.remap, label="broken")
    assert diags and all(d.code == "V115" for d in diags)
    pm = PassManager([_BrokenPass()], name="bad-pipeline")
    with pytest.raises(ScheduleVerificationError) as e:
        pm.run(g, certify=True)
    assert any(d.code == "V115" for d in e.value.report.diagnostics)
    # certify=False (the default) keeps the historical trust model
    pm.run(g)


def test_identity_remap_certifies_clean(rng):
    g = _graph(rng)
    assert certify_remap(g, g, identity_remap(g)) == []


def test_compile_verify_raises_on_broken_pipeline(rng):
    g = _graph(rng)
    pm = PassManager([_BrokenPass()], name="bad-pipeline")
    spec = CompileSpec(n_unit=16, optimize=pm, verify="compile")
    with pytest.raises(ScheduleVerificationError):
        LogicCompiler().compile(g, spec)
    # compiler-level default has the same effect on a plain spec
    with pytest.raises(ScheduleVerificationError):
        LogicCompiler(verify="compile").compile(
            g, CompileSpec(n_unit=16, optimize=pm))
    # and verify="off" compiles the same spec without the gate
    LogicCompiler().compile(g, CompileSpec(n_unit=16, optimize=pm))


# ---------------------------------------------------------------------------
# hypothesis property sections
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**32 - 1),
           n_unit=st.sampled_from((4, 16, 64)),
           alloc=st.sampled_from(ALLOCS),
           n_gates=st.integers(8, 220))
    def test_property_compiled_programs_verify_clean(seed, n_unit, alloc,
                                                     n_gates):
        rng = np.random.default_rng(seed)
        g = random_graph(rng, n_inputs=8, n_gates=n_gates, n_outputs=6,
                         locality=24)
        art = LogicCompiler().compile(
            g, CompileSpec(n_unit=n_unit, alloc=alloc, verify="full"))
        report = verify_artifact(art)
        assert report.ok, report.summary()

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**32 - 1),
           alloc=st.sampled_from(ALLOCS),
           max_gates=st.sampled_from((60, 120)))
    def test_property_partitioned_verifies_clean(seed, alloc, max_gates):
        rng = np.random.default_rng(seed)
        g = random_graph(rng, n_inputs=10, n_gates=260, n_outputs=8,
                         locality=32)
        art = LogicCompiler().compile(
            g, CompileSpec(n_unit=8, alloc=alloc, max_gates=max_gates,
                           verify="full"))
        report = verify_artifact(art)
        assert report.ok, report.summary()

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**32 - 1),
           alloc=st.sampled_from(ALLOCS))
    def test_property_chain_verifies_clean(seed, alloc):
        rng = np.random.default_rng(seed)
        g1 = random_graph(rng, n_inputs=8, n_gates=120, n_outputs=7,
                          locality=24)
        g2 = random_graph(rng, n_inputs=7, n_gates=90, n_outputs=5,
                          locality=24)
        spec = CompileSpec(n_unit=16, alloc=alloc, optimize="none")
        mega = build_megaprogram(
            [compile_graph(g, spec) for g in (g1, g2)], mode="chain")
        report = verify_megaprogram(mega, compose_graphs([g1, g2]))
        assert report.ok, report.summary()
