"""Trainer, checkpointing, fault tolerance, optimizer, compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.synthetic import TokenPipeline
from repro.optim import (adamw_init, adamw_update, clip_by_global_norm,
                         compress_int8, decompress_int8, cosine_schedule,
                         wsd_schedule, ef_compress)
from repro.train import (CheckpointManager, Heartbeat, StragglerMonitor,
                         TrainConfig, Trainer)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_decreases_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = adamw_init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state = adamw_update(grads, state, params, lr=0.05,
                                     weight_decay=0.0)
    assert float(jnp.abs(params["w"]).max()) < 0.3


def test_wsd_schedule_shape():
    fn = wsd_schedule(1.0, warmup_steps=10, stable_steps=80, decay_steps=10)
    assert float(fn(0)) == 0.0
    assert float(fn(10)) == pytest.approx(1.0)
    assert float(fn(50)) == pytest.approx(1.0)      # stable plateau
    assert float(fn(100)) == pytest.approx(0.1, rel=0.05)


def test_cosine_schedule_monotone_decay():
    fn = cosine_schedule(1.0, 5, 100)
    vals = [float(fn(s)) for s in range(5, 100, 5)]
    assert all(a >= b for a, b in zip(vals, vals[1:]))


def test_clip_by_global_norm():
    g = {"a": jnp.asarray([3.0, 4.0])}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(5.0)
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# int8 error-feedback gradient compression
# ---------------------------------------------------------------------------

def test_int8_roundtrip_error_bound(rng):
    g = jnp.asarray(rng.normal(size=(1000,)), jnp.float32)
    q, s = compress_int8(g)
    rec = decompress_int8(q, s, g.shape)
    # blockwise symmetric quantization: |err| <= scale/2 per block
    err = np.abs(np.asarray(rec - g))
    scales = np.repeat(np.asarray(s).reshape(-1), 256)[:1000]
    assert (err <= scales / 2 + 1e-7).all()


def test_error_feedback_accumulates():
    g = jnp.full((256,), 1e-4, jnp.float32)   # below quantization step alone
    residual = jnp.zeros((256,), jnp.float32)
    total = jnp.zeros((256,), jnp.float32)
    for _ in range(50):
        q, s, residual = ef_compress(g, residual)
        total = total + decompress_int8(q, s, g.shape)
    # EF: the long-run average transmitted equals the true gradient
    np.testing.assert_allclose(np.asarray(total / 50),
                               np.asarray(g), rtol=0.2)


# ---------------------------------------------------------------------------
# checkpoint manager
# ---------------------------------------------------------------------------

@pytest.fixture
def ckpt_dir(tmp_path):
    return str(tmp_path / "ckpt")


def test_checkpoint_roundtrip(ckpt_dir):
    mgr = CheckpointManager(ckpt_dir)
    tree = {"a": jnp.arange(10, dtype=jnp.float32),
            "b": {"c": jnp.ones((3, 4), jnp.bfloat16)}}
    mgr.save(7, tree, meta={"data_step": 7})
    like = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
    restored, meta = mgr.restore(like)
    assert meta["data_step"] == 7
    assert (np.asarray(restored["a"]) == np.arange(10)).all()
    assert restored["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_async_and_gc(ckpt_dir):
    mgr = CheckpointManager(ckpt_dir, keep=2)
    tree = {"w": jnp.zeros((4,))}
    for s in (1, 2, 3, 4):
        mgr.save_async(s, tree)
    mgr.wait()
    assert mgr.latest_step == 4
    steps = sorted(int(d[5:]) for d in os.listdir(ckpt_dir)
                   if d.startswith("step_"))
    assert steps == [3, 4]      # gc kept newest 2


def test_checkpoint_ignores_partial(ckpt_dir):
    mgr = CheckpointManager(ckpt_dir)
    mgr.save(1, {"w": jnp.ones((2,))})
    # simulate a crash mid-save: step dir without manifest
    os.makedirs(os.path.join(ckpt_dir, "step_000000000099"))
    assert mgr.latest_step == 1


def test_checkpoint_shape_mismatch_raises(ckpt_dir):
    mgr = CheckpointManager(ckpt_dir)
    mgr.save(1, {"w": jnp.ones((2,))})
    with pytest.raises(ValueError):
        mgr.restore({"w": jnp.ones((3,))})


# ---------------------------------------------------------------------------
# resilience
# ---------------------------------------------------------------------------

def test_straggler_monitor_flags_sustained_outliers():
    m = StragglerMonitor(min_samples=5, consecutive=3)
    flagged = False
    for _ in range(20):
        flagged |= m.record(1.0)
    assert not flagged
    m.record(5.0)
    m.record(5.0)
    assert not m.record(1.0)    # hysteresis resets on a good step
    for _ in range(2):
        m.record(5.0)
    assert m.record(5.0)        # 3 consecutive -> alarm


def test_heartbeat_detects_dead_host():
    hb = Heartbeat(timeout=10.0)
    hb.beat("host0", now=0.0)
    hb.beat("host1", now=5.0)
    assert hb.dead_hosts(now=12.0) == ["host0"]


# ---------------------------------------------------------------------------
# trainer end-to-end (1-device mesh)
# ---------------------------------------------------------------------------

def _mk_trainer(tmp, **tc_kw):
    cfg = get_config("qwen3-8b", smoke=True)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    tc = TrainConfig(lr=1e-3, warmup_steps=2, total_steps=50,
                     checkpoint_every=5, checkpoint_dir=str(tmp), **tc_kw)
    return Trainer(cfg, tc, mesh, global_batch=8, seq_len=32)


def test_trainer_loss_decreases_and_resumes(tmp_path):
    tr = _mk_trainer(tmp_path / "c1")
    hist = tr.run(steps=10, log_every=0)
    assert len(hist) == 10
    assert all(np.isfinite(h["loss"]) for h in hist)
    # resume continues the step counter from the checkpoint
    tr2 = _mk_trainer(tmp_path / "c1")
    tr2.run(steps=2, log_every=0)
    assert tr2.step == 12


def test_grad_accum_matches_full_batch(tmp_path):
    """accum=2 over the same global batch gives (near-)identical updates."""
    t1 = _mk_trainer(tmp_path / "a", grad_accum=1)
    t2 = _mk_trainer(tmp_path / "b", grad_accum=2)
    h1 = t1.run(steps=3, log_every=0)
    h2 = t2.run(steps=3, log_every=0)
    for a, b in zip(h1, h2):
        assert a["loss"] == pytest.approx(b["loss"], rel=2e-3)


def test_compressed_grads_still_converge(tmp_path):
    tr = _mk_trainer(tmp_path / "c", compress_grads=True)
    hist = tr.run(steps=8, log_every=0)
    assert np.isfinite(hist[-1]["loss"])


def test_data_pipeline_deterministic_and_host_sharded():
    p = TokenPipeline(vocab_size=100, global_batch=8, seq_len=16, seed=1)
    a = p.batch(3)["tokens"]
    b = p.batch(3)["tokens"]
    assert (a == b).all()
    assert not (a == p.batch(4)["tokens"]).all()
    # host sharding partitions the global batch
    h0 = p.batch(3, host_id=0, n_hosts=2)["tokens"]
    h1 = p.batch(3, host_id=1, n_hosts=2)["tokens"]
    assert h0.shape[0] == 4 and h1.shape[0] == 4
    assert not (h0 == h1).all()
