"""Serving: prefill + decode == full forward; continuous batcher."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.transformer import forward, init_params
from repro.serve import Request, RequestBatcher, decode_step, prefill
from repro.serve.engine import init_decode_cache

ARCHS = ["qwen3-8b", "mixtral-8x7b", "mamba2-370m", "recurrentgemma-2b",
         "internvl2-76b"]


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch, rng):
    cfg = get_config(arch, smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 16
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    extra, off = {}, 0
    if cfg.family == "vlm":
        extra["vision"] = jnp.asarray(
            rng.normal(size=(B, cfg.vision_tokens, cfg.d_model)), jnp.float32)
        off = cfg.vision_tokens
    full = forward(params, cfg, {"tokens": toks, **extra})
    P = S - 4
    lp, cache = prefill(params, cfg, {"tokens": toks[:, :P], **extra},
                        context=S + off)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(full[:, :off + P]),
                               rtol=2e-3, atol=2e-3)
    for t in range(P, S):
        lg, cache = decode_step(params, cfg, toks[:, t:t + 1], cache)
        np.testing.assert_allclose(np.asarray(lg[:, 0]),
                                   np.asarray(full[:, off + t]),
                                   rtol=2e-3, atol=2e-3)


def test_sliding_window_ring_cache(rng):
    """Decode far beyond the window: ring buffer must stay exact."""
    cfg = get_config("mixtral-8x7b", smoke=True)   # window 16
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 1, 40
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    full = forward(params, cfg, {"tokens": toks})
    _, cache = prefill(params, cfg, {"tokens": toks[:, :8]}, context=S)
    for t in range(8, S):
        lg, cache = decode_step(params, cfg, toks[:, t:t + 1], cache)
        np.testing.assert_allclose(np.asarray(lg[:, 0]),
                                   np.asarray(full[:, t]),
                                   rtol=3e-3, atol=3e-3)
    # cache stayed O(window)
    assert cache.kv_k.shape[2] == cfg.sliding_window


def test_decode_cache_encoder_rejected():
    cfg = get_config("hubert-xlarge", smoke=True)
    with pytest.raises(AssertionError):
        init_decode_cache(cfg, 2, 64)


def test_cache_is_constant_memory_for_ssm():
    cfg = get_config("mamba2-370m", smoke=True)
    c1 = init_decode_cache(cfg, 2, 128)
    c2 = init_decode_cache(cfg, 2, 1 << 19)
    assert c1.ssm_state.shape == c2.ssm_state.shape   # O(1) in context


def test_batcher_continuous():
    b = RequestBatcher(batch_size=2)
    for uid in range(5):
        b.submit(Request(uid=uid, prompt=np.array([1, 2]), max_new_tokens=2))
    served = 0
    rounds = 0
    while not b.idle and rounds < 50:
        b.admit()
        toks = np.full((2,), 7, np.int64)
        before = len(b.finished)
        b.record_tokens(toks)
        served += len(b.finished) - before
        rounds += 1
    assert served == 5
    assert all(len(r.generated) == 2 for r in b.finished)


def test_batcher_slot_recycling():
    b = RequestBatcher(batch_size=1)
    b.submit(Request(uid=0, prompt=np.array([1]), max_new_tokens=1))
    b.submit(Request(uid=1, prompt=np.array([1]), max_new_tokens=1))
    b.admit()
    assert b.slots[0].uid == 0
    b.record_tokens(np.array([5]))
    assert b.slots[0] is None
    b.admit()
    assert b.slots[0].uid == 1
