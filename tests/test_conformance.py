"""Cross-backend differential conformance: every executor, one semantics.

For any well-formed :class:`LogicGraph`, these five evaluations must agree
bit for bit:

  1. ``LogicGraph.evaluate``           (pure-python/numpy oracle)
  2. ``scheduler.execute_program_np``  (compiled-program numpy oracle)
  3. ``logic_forward_ref``             (jnp reference, via use_ref=True)
  4. the Pallas kernel                 (interpret mode, via use_ref=False)
  5. Verilog-text round trip           (emit -> parse -> evaluate)

across ``n_unit in {8, 64}`` and both address-allocation modes. The
deterministic sections always run; the hypothesis property sections add
randomized coverage when hypothesis is installed (requirements-dev.txt).

The degenerate-cover section is the regression suite for espresso/NullaNet
corners: constant-true / constant-false neurons, empty ISF care-sets,
pass-through and constant outputs, gateless programs — ``layer_to_graph``
must never emit a graph any backend cannot simulate.

``REPRO_VERIFY=full`` (or ``compile``) additionally runs the static
schedule verifier (core/verify.py, DESIGN.md §13) over every program and
megaprogram this suite compiles — the CI ``verify`` job's way of proving
the whole conformance matrix carries zero diagnostics, not just agreeing
at runtime on the sampled input batches.
"""
import os

import numpy as np
import pytest

from repro.core import espresso
from repro.core.artifact_store import ArtifactStore
from repro.core.compiler import LogicCompiler
from repro.core.gate_ir import (CONST0, CONST1, LogicGraph, OpCode,
                                random_graph)
from repro.core.nullanet import layer_to_graph
from repro.core.spec import CompileSpec
from repro.core.scheduler import (LogicProgram, compile_graph,
                                  execute_program_np)
from repro.core.synth import optimize
from repro.core.verilog import emit_verilog, parse_verilog
from repro.kernels.logic_dsp.ops import logic_infer_bits

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:           # tier-1 containers may lack hypothesis
    HAVE_HYPOTHESIS = False

N_UNITS = (8, 64)
ALLOCS = ("direct", "liveness")

# "off" => runtime agreement only; "compile"/"full" => every program the
# matrix compiles must ALSO prove clean statically (CI verify job)
VERIFY_MODE = os.environ.get("REPRO_VERIFY", "off")


def _maybe_verify(prog, graph=None):
    if VERIFY_MODE in ("compile", "load", "full"):
        from repro.core.verify import verify_program
        verify_program(prog, graph).raise_if_failed()


def assert_conformance(graph: LogicGraph, bits: np.ndarray,
                       n_units=N_UNITS, allocs=ALLOCS) -> None:
    """All five backends agree with ``graph.evaluate`` on ``bits``."""
    bits = np.asarray(bits, dtype=bool)
    want = graph.evaluate(bits)
    got_v = parse_verilog(emit_verilog(graph)).evaluate(bits)
    assert (got_v == want).all(), "verilog round-trip diverged"
    for n_unit in n_units:
        for alloc in allocs:
            prog = compile_graph(graph, CompileSpec(n_unit=n_unit, alloc=alloc,
                                                    optimize="none"))
            _maybe_verify(prog, graph)
            ctx = f"n_unit={n_unit} alloc={alloc}"
            got_np = execute_program_np(prog, bits)
            assert (got_np == want).all(), f"execute_program_np ({ctx})"
            got_ref = logic_infer_bits(prog, bits, use_ref=True)
            assert (got_ref == want).all(), f"jnp reference ({ctx})"
            got_k = logic_infer_bits(prog, bits, use_ref=False)
            assert (got_k == want).all(), f"pallas interpret ({ctx})"


def _bits(rng, batch, n_inputs):
    return rng.integers(0, 2, (batch, n_inputs)).astype(bool)


# ---------------------------------------------------------------------------
# deterministic differential sweep
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed,n_inputs,n_gates,n_outputs,unary_frac,locality",
                         [(0, 8, 200, 8, 0.1, 64),
                          (1, 4, 30, 4, 0.3, 8),    # narrow fan-in, unary-rich
                          (2, 16, 500, 16, 0.05, 256),  # wide fan-in, deep
                          (3, 2, 5, 2, 0.5, 4),
                          (4, 10, 64, 10, 0.0, 16)])
def test_random_graph_conformance(seed, n_inputs, n_gates, n_outputs,
                                  unary_frac, locality):
    rng = np.random.default_rng(seed)
    g = random_graph(rng, n_inputs, n_gates, n_outputs,
                     unary_frac=unary_frac, locality=locality)
    assert_conformance(g, _bits(rng, 45, n_inputs))


def test_single_gate_graphs(rng):
    """Every opcode as the lone gate, including both unary ops and NOP."""
    for op in OpCode:
        g = LogicGraph(2, name=f"single-{op.name}")
        g.set_outputs([g.add_gate(op, g.input_wire(0), g.input_wire(1))])
        assert_conformance(g, _bits(rng, 33, 2))


def test_constant_and_passthrough_outputs(rng):
    """Outputs at CONST0/CONST1/input wires need no gates at all."""
    g = LogicGraph(3, name="degenerate-outs")
    w = g.add_gate(OpCode.XNOR, g.input_wire(0), g.input_wire(2))
    g.set_outputs([CONST0, CONST1, g.input_wire(1), w, CONST1])
    assert_conformance(g, _bits(rng, 40, 3))


def test_gateless_graph(rng):
    """0 steps: pallas cannot take (0, n_unit) streams; must route to ref."""
    g = LogicGraph(2, name="gateless")
    g.set_outputs([g.input_wire(1), CONST1, g.input_wire(0)])
    assert_conformance(g, _bits(rng, 39, 2))


def test_duplicated_outputs(rng):
    """The same wire exported at several output positions."""
    g = LogicGraph(2, name="dup")
    w = g.add_gate(OpCode.NAND, g.input_wire(0), g.input_wire(1))
    g.set_outputs([w, w, g.input_wire(0), w])
    assert_conformance(g, _bits(rng, 21, 2))


def test_deep_chain(rng):
    """Depth >> n_unit: one gate per level, exercises level raggedness."""
    g = LogicGraph(2, name="chain")
    w = g.input_wire(0)
    for k in range(120):
        w = g.add_gate(OpCode.XOR if k % 3 else OpCode.NAND, w,
                       g.input_wire(k % 2))
        if k % 7 == 0:
            w = g.add_gate(OpCode.NOT, w)
    g.set_outputs([w])
    assert_conformance(g, _bits(rng, 64, 2))


def test_real_nop_gates(rng):
    """A *real* NOP gate (not padding) drives constant 0 on its wire and
    must survive scheduling homogeneity and the Verilog round trip."""
    g = LogicGraph(2, name="nop")
    nop = g.add_gate(OpCode.NOP, g.input_wire(0), g.input_wire(1))
    both = g.add_gate(OpCode.OR, nop, g.input_wire(1))
    g.set_outputs([nop, both])
    assert_conformance(g, _bits(rng, 37, 2))


# ---------------------------------------------------------------------------
# optimized vs unoptimized: the pass pipeline (core/opt.py) must keep all
# five backends bit-identical, and the optimized graph must compute the
# raw graph's function exactly
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed,n_inputs,n_gates,n_outputs,unary_frac",
                         [(0, 8, 200, 8, 0.1),
                          (1, 4, 30, 4, 0.3),
                          (5, 6, 150, 6, 0.5),     # unary-rich: NOT fusion
                          (6, 10, 400, 12, 0.05)])
def test_optimized_graph_conformance(seed, n_inputs, n_gates, n_outputs,
                                     unary_frac):
    from repro.core.opt import PassManager
    rng = np.random.default_rng(seed)
    g = random_graph(rng, n_inputs, n_gates, n_outputs,
                     unary_frac=unary_frac, locality=32)
    bits = _bits(rng, 45, n_inputs)
    go = PassManager.default().run(g).graph
    assert (go.evaluate(bits) == g.evaluate(bits)).all()
    assert go.n_gates <= g.n_gates
    assert_conformance(go, bits)


def test_compile_optimize_knob_conformance(rng):
    """``compile_graph(optimize='default')`` programs agree with the RAW
    graph's evaluate through the numpy / jnp / Pallas executors."""
    g = random_graph(rng, 8, 250, 8, locality=32)
    bits = _bits(rng, 45, 8)
    want = g.evaluate(bits)
    for n_unit in N_UNITS:
        for alloc in ALLOCS:
            prog = compile_graph(g, CompileSpec(n_unit=n_unit, alloc=alloc))
            assert (execute_program_np(prog, bits) == want).all()
            assert (logic_infer_bits(prog, bits, use_ref=True) == want).all()
            assert (logic_infer_bits(prog, bits, use_ref=False) == want).all()


# ---------------------------------------------------------------------------
# store-load vs fresh-compile differential (persistence conformance)
# ---------------------------------------------------------------------------

def _round_trip(tmp_path, graph, spec):
    """Fresh compile -> store -> load through a *separate* store
    instance (nothing shared in memory); asserts the schedule streams
    are byte-identical before handing back both artifacts."""
    fresh = LogicCompiler().compile(graph, spec, assume_optimized=True)
    ArtifactStore(tmp_path).save(fresh)
    loaded = ArtifactStore(tmp_path).load(graph.fingerprint(), spec)
    assert loaded is not None
    assert len(loaded.programs) == len(fresh.programs)
    for pf, pl in zip(fresh.programs, loaded.programs):
        for f in LogicProgram.ARRAY_FIELDS:
            a, b = getattr(pf, f), getattr(pl, f)
            assert a.dtype == b.dtype and a.tobytes() == b.tobytes(), f
    return fresh, loaded


@pytest.mark.parametrize("alloc", ALLOCS)
def test_store_loaded_program_conformance(tmp_path, alloc):
    """A store-loaded program is indistinguishable from the fresh
    compile it replaces on EVERY backend: numpy oracle, jnp reference,
    and the Pallas kernel all serve the same bits from the loaded
    streams."""
    rng = np.random.default_rng(11)
    g = random_graph(rng, 10, 220, 8, locality=32)
    bits = _bits(rng, 37, 10)
    want = g.evaluate(bits)
    for n_unit in N_UNITS:
        spec = CompileSpec(n_unit=n_unit, alloc=alloc,
                           optimize="none").normalize(g)
        _, loaded = _round_trip(tmp_path, g, spec)
        (prog,) = loaded.programs
        ctx = f"n_unit={n_unit} alloc={alloc} (store-loaded)"
        assert (execute_program_np(prog, bits) == want).all(), ctx
        assert (logic_infer_bits(prog, bits, use_ref=True) == want).all(), ctx
        assert (logic_infer_bits(prog, bits, use_ref=False) == want).all(), ctx


def test_store_loaded_partitioned_conformance(tmp_path):
    """Partitioned artifacts round-trip too: each loaded sub-program
    conforms on every backend, and the re-assembled pipeline (concat +
    output permutation) matches the raw graph."""
    rng = np.random.default_rng(12)
    g = random_graph(rng, 12, 320, 10, locality=48)
    bits = _bits(rng, 41, 12)
    want = g.evaluate(bits)
    spec = CompileSpec(n_unit=8, max_gates=80, optimize="none").normalize(g)
    fresh, loaded = _round_trip(tmp_path, g, spec)
    assert len(loaded.programs) > 1
    assert (loaded.output_perm == fresh.output_perm).all()
    for backend in (execute_program_np,
                    lambda p, x: logic_infer_bits(p, x, use_ref=True),
                    lambda p, x: logic_infer_bits(p, x, use_ref=False)):
        outs = np.concatenate([np.asarray(backend(p, bits))
                               for p in loaded.programs], axis=1)
        assert (outs[:, loaded.output_perm] == want).all()


def test_store_loaded_optimized_graph_conformance(tmp_path):
    """The cache-path identity (post-optimization graph + stripped
    spec) round-trips and still serves the RAW graph's semantics."""
    from repro.core.opt import PassManager
    rng = np.random.default_rng(13)
    g = random_graph(rng, 9, 180, 6, locality=24)
    bits = _bits(rng, 29, 9)
    go = PassManager.default().run(g).graph
    spec = CompileSpec(n_unit=8, optimize="none").normalize(go)
    _, loaded = _round_trip(tmp_path, go, spec)
    assert (loaded.execute(bits) == g.evaluate(bits)).all()


def test_optimized_degenerate_graphs_conform(rng):
    """Degenerate shapes stay servable after optimization: real NOP gates
    fold to CONST0 outputs, duplicated/constant/pass-through outputs keep
    their positions."""
    from repro.core.opt import PassManager
    pm = PassManager.default()
    g = LogicGraph(2, name="nop")
    nop = g.add_gate(OpCode.NOP, g.input_wire(0), g.input_wire(1))
    g.set_outputs([nop, g.add_gate(OpCode.OR, nop, g.input_wire(1)),
                   CONST1, g.input_wire(0), nop])
    go = pm.run(g).graph
    assert go.n_gates == 0                   # NOP folds, OR(0, b) passes b
    assert go.outputs == [CONST0, g.input_wire(1), CONST1,
                          g.input_wire(0), CONST0]
    assert_conformance(go, _bits(rng, 37, 2))


# ---------------------------------------------------------------------------
# espresso / NullaNet degenerate covers (regression suite)
# ---------------------------------------------------------------------------

def all_patterns(n: int) -> np.ndarray:
    return ((np.arange(2 ** n)[:, None] >> np.arange(n)[None, :]) & 1
            ).astype(np.uint8)


def test_constant_false_neuron_minimizes_to_empty_cover():
    cubes = espresso.minimize(np.zeros((0, 4), np.uint8), all_patterns(4))
    assert cubes == []
    g = optimize(espresso.sop_to_graph([cubes], n_inputs=4))
    assert g.n_gates == 0 and g.outputs == [CONST0]
    assert_conformance(g, all_patterns(4).astype(bool))


def test_constant_true_neuron_minimizes_to_tautology():
    pats = all_patterns(4)
    cubes = espresso.minimize(pats, np.zeros((0, 4), np.uint8))
    assert len(cubes) == 1 and not cubes[0][0].any()   # literal-free cube
    g = optimize(espresso.sop_to_graph([cubes], n_inputs=4))
    assert g.n_gates == 0 and g.outputs == [CONST1]
    assert_conformance(g, pats.astype(bool))


def test_empty_isf_care_set():
    """Zero calibration rows: every pattern is don't-care; layer_to_graph
    must still emit a simulatable (constant) graph."""
    g = layer_to_graph(np.zeros((0, 5), np.uint8), np.ones((5, 3)),
                       np.zeros(3), mode="isf")
    assert g.n_outputs == 3
    assert_conformance(g, all_patterns(5).astype(bool))


def test_layer_with_constant_and_live_neurons():
    """A layer mixing always-on, always-off, and input-dependent neurons
    (saturated biases) compiles and matches the float64 sign spec."""
    W = np.array([[1.0, 1.0, 1.0], [1.0, -1.0, 1.0]])
    b = np.array([50.0, 0.0, -50.0])     # always-on / live / always-off
    pats = all_patterns(2)
    for mode in ("enum", "isf"):
        g = layer_to_graph(pats, W, b, mode=mode)
        want = ((2.0 * pats - 1.0) @ W + b) >= 0
        assert (g.evaluate(pats.astype(bool)) == want).all()
        assert_conformance(g, pats.astype(bool))


def test_zero_neuron_layer():
    g = layer_to_graph(all_patterns(3), np.zeros((3, 0)), np.zeros(0))
    assert g.n_outputs == 0
    prog = compile_graph(g, CompileSpec(n_unit=8, optimize="none"))
    out = execute_program_np(prog, all_patterns(3).astype(bool))
    assert out.shape == (8, 0)


def test_engine_serves_gateless_and_constant_graphs(rng):
    """The serving engine must handle degenerate programs end to end."""
    from repro.serve import LogicEngine
    eng = LogicEngine(CompileSpec(n_unit=8), capacity=64)
    g = LogicGraph(3, name="deg")
    g.set_outputs([CONST1, g.input_wire(2), CONST0])
    bits = _bits(rng, 50, 3)
    assert (eng.serve(g, bits) == g.evaluate(bits)).all()


# ---------------------------------------------------------------------------
# megakernel: the fused single-launch executor must agree bit for bit with
# the chained per-program kernel AND the numpy oracle, for layer stacks
# (chain mode) and partitioned pipelines (parallel mode, in-kernel
# output permutation), across both allocators and n_unit in {8, 64}
# ---------------------------------------------------------------------------

def _layer_stack(rng, widths):
    """Chainable random layer graphs: widths[k] inputs -> widths[k+1] outs."""
    return [random_graph(rng, widths[k], 40 + 30 * k, widths[k + 1],
                         unary_frac=0.2, locality=16)
            for k in range(len(widths) - 1)]


def _stack_eval(graphs, bits):
    h = np.asarray(bits, dtype=bool)
    for g in graphs:
        h = g.evaluate(h)
    return h


def assert_mega_chain_conformance(graphs, bits, n_units=N_UNITS,
                                  allocs=ALLOCS) -> None:
    """Fused chain megakernel == chained per-program launches == numpy."""
    from repro.core.scheduler import build_megaprogram
    from repro.kernels.logic_dsp.ops import mega_infer_bits
    bits = np.asarray(bits, dtype=bool)
    want = _stack_eval(graphs, bits)
    for n_unit in n_units:
        for alloc in allocs:
            spec = CompileSpec(n_unit=n_unit, alloc=alloc, optimize="none")
            progs = [compile_graph(g, spec) for g in graphs]
            for p, g in zip(progs, graphs):
                _maybe_verify(p, g)
            ctx = f"n_unit={n_unit} alloc={alloc}"
            h = bits
            for p in progs:
                h = logic_infer_bits(p, h, use_ref=False)
            assert (h == want).all(), f"chained pallas launches ({ctx})"
            mega = build_megaprogram(progs, mode="chain")
            if VERIFY_MODE in ("compile", "load", "full"):
                from repro.core.gate_ir import compose_graphs
                from repro.core.verify import verify_megaprogram
                verify_megaprogram(
                    mega, compose_graphs(graphs)).raise_if_failed()
            got_np = bits
            for p in progs:
                got_np = execute_program_np(p, got_np)
            assert (got_np == want).all(), f"chained numpy oracle ({ctx})"
            got_mega = mega_infer_bits(mega, bits, use_ref=False)
            assert (got_mega == want).all(), f"megakernel ({ctx})"
            got_mref = mega_infer_bits(mega, bits, use_ref=True)
            assert (got_mref == want).all(), f"mega jnp reference ({ctx})"


@pytest.mark.parametrize("seed,widths",
                         [(0, (6, 5, 4)),           # 2-layer stack
                          (1, (8, 7, 5, 3)),        # 3-layer stack
                          (2, (4, 9, 2))])          # widening then narrowing
def test_megakernel_chain_conformance(seed, widths):
    rng = np.random.default_rng(seed)
    graphs = _layer_stack(rng, widths)
    assert_mega_chain_conformance(graphs, _bits(rng, 45, widths[0]))


@pytest.mark.parametrize("n_unit", N_UNITS)
@pytest.mark.parametrize("alloc", ALLOCS)
def test_megakernel_partitioned_conformance(n_unit, alloc):
    """A genuinely multi-program partitioned artifact fused into one
    parallel-mode launch (output permutation applied in-kernel)."""
    from repro.kernels.logic_dsp.ops import mega_infer_bits
    rng = np.random.default_rng(3)
    g = random_graph(rng, 10, 200, 6)
    spec = CompileSpec(n_unit=n_unit, alloc=alloc, optimize="none",
                       max_gates=16)
    if VERIFY_MODE != "off":
        spec = spec.with_(verify=VERIFY_MODE)
    art = LogicCompiler().compile(g, spec)
    assert len(art.programs) > 1, "fixture must actually partition"
    bits = _bits(rng, 45, 10)
    want = g.evaluate(bits)
    assert (art.execute(bits) == want).all()
    mega = art.megaprogram()
    assert mega.mode == "parallel" and mega.n_stages == len(art.programs)
    assert (mega_infer_bits(mega, bits, use_ref=False) == want).all()
    assert (mega_infer_bits(mega, bits, use_ref=True) == want).all()


# ---------------------------------------------------------------------------
# hypothesis property coverage
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    @st.composite
    def graph_cases(draw):
        """Random graphs with varied fan-in, opcode mix, depth, and
        degenerate output sets (constants / inputs / duplicates)."""
        seed = draw(st.integers(0, 10 ** 6))
        n_inputs = draw(st.integers(1, 12))
        n_gates = draw(st.integers(0, 150))
        unary_frac = draw(st.sampled_from([0.0, 0.1, 0.4]))
        locality = draw(st.sampled_from([2, 8, 64]))
        rng = np.random.default_rng(seed)
        if n_gates:
            g = random_graph(rng, n_inputs, n_gates,
                             min(4, n_gates), unary_frac=unary_frac,
                             locality=locality)
        else:
            g = LogicGraph(n_inputs, name="gateless")
            g.set_outputs([g.input_wire(0)])
        extras = draw(st.lists(
            st.sampled_from([CONST0, CONST1, 2]), max_size=3))
        if extras:
            g.set_outputs(list(g.outputs) + extras)
        batch = draw(st.sampled_from([1, 31, 32, 45]))
        return g, _bits(rng, batch, n_inputs)

    @settings(max_examples=40, deadline=None)
    @given(graph_cases(), st.sampled_from(N_UNITS), st.sampled_from(ALLOCS))
    def test_property_conformance(case, n_unit, alloc):
        g, bits = case
        assert_conformance(g, bits, n_units=(n_unit,), allocs=(alloc,))

    @settings(max_examples=25, deadline=None)
    @given(graph_cases(), st.sampled_from(N_UNITS))
    def test_property_optimized_conformance(case, n_unit):
        """The pass pipeline preserves every backend's semantics on the
        same randomized structure/degenerate-output space."""
        from repro.core.opt import PassManager
        g, bits = case
        go = PassManager.default().run(g).graph
        assert (go.evaluate(bits) == g.evaluate(bits)).all()
        assert_conformance(go, bits, n_units=(n_unit,),
                           allocs=("liveness",))

    @settings(max_examples=15, deadline=None)
    @given(st.integers(0, 10 ** 6), st.integers(1, 6), st.integers(1, 5))
    def test_property_layer_to_graph_conformance(seed, fanin, n_neurons):
        """NullaNet layers (enum + isf) are simulatable by every backend
        and match the float64 sign spec where defined."""
        rng = np.random.default_rng(seed)
        W = rng.normal(size=(fanin, n_neurons))
        b = rng.normal(size=n_neurons) * 2.0
        pats = all_patterns(fanin)
        calib = pats[rng.random(len(pats)) < 0.6]
        want = ((2.0 * pats - 1.0) @ W + b) >= 0
        g_enum = layer_to_graph(calib, W, b, mode="enum")
        assert (g_enum.evaluate(pats.astype(bool)) == want).all()
        assert_conformance(g_enum, pats.astype(bool),
                           n_units=(8,), allocs=("liveness",))
        g_isf = layer_to_graph(calib, W, b, mode="isf")
        if len(calib):
            assert (g_isf.evaluate(calib.astype(bool))
                    == (((2.0 * calib - 1.0) @ W + b) >= 0)).all()
        assert_conformance(g_isf, pats.astype(bool),
                           n_units=(8,), allocs=("direct",))
