"""Sharding rules: divisibility degradation + spec shapes (1-device mesh
suffices: rules are pure functions of mesh axis sizes)."""
import jax
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.models.transformer import param_shapes
from repro.serve.engine import init_decode_cache
from repro.train import sharding as shd


class FakeMesh:
    """Just axis names + sizes — what the rule functions consume."""

    def __init__(self, shape: dict):
        self._shape = dict(shape)

    @property
    def axis_names(self):
        return tuple(self._shape)

    @property
    def shape(self):
        return self._shape


MESH = FakeMesh({"data": 16, "model": 16})
MESH3 = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_param_pspec_rank_matches():
    for arch in ("qwen3-8b", "mixtral-8x7b", "mamba2-370m",
                 "recurrentgemma-2b", "hubert-xlarge"):
        cfg = get_config(arch)
        shapes = param_shapes(cfg)
        specs = shd.param_pspecs(cfg, MESH, shapes)
        flat_s = jax.tree.leaves(shapes)
        flat_p = jax.tree.leaves(specs,
                                 is_leaf=lambda x: isinstance(x, P))
        assert len(flat_s) == len(flat_p)
        for s, p in zip(flat_s, flat_p):
            assert len(p) <= len(s.shape), (arch, s.shape, p)


def test_indivisible_dims_degrade_to_replication():
    cfg = get_config("qwen3-8b")
    # vocab 151936 % 16 == 0 -> sharded; a fake mesh of 7 can't divide it
    mesh7 = FakeMesh({"data": 7, "model": 7})
    shapes = param_shapes(cfg)
    spec = shd.param_pspecs(cfg, mesh7, shapes)["embed"]
    assert spec == P(None, None)


def test_moe_expert_specs():
    cfg = get_config("mixtral-8x7b")
    shapes = param_shapes(cfg)
    specs = shd.param_pspecs(cfg, MESH, shapes)
    # stacked (L, E, D, F): L/E replicated, D->data, F->model
    assert specs["blocks"]["w_gate"] == P(None, None, "data", "model")
    assert specs["blocks"]["w_down"] == P(None, None, "model", "data")


def test_moment_specs_add_pod_axis():
    cfg = get_config("grok-1-314b")
    shapes = param_shapes(cfg)
    m = shd.moment_pspecs(cfg, MESH3, shapes)
    # stacked leading L=64 divisible by pod=2 -> ZeRO over pod
    assert m["blocks"]["wq"][0] == "pod"
    # without a pod axis, moments == params
    m2 = shd.moment_pspecs(cfg, MESH, shapes)
    p2 = shd.param_pspecs(cfg, MESH, shapes)
    assert m2["blocks"]["wq"] == p2["blocks"]["wq"]


def test_batch_pspec_divisibility():
    assert shd.batch_pspec(MESH3, 256, 2) == P(("pod", "data"), None)
    assert shd.batch_pspec(MESH3, 1, 2) == P(None, None)   # long_500k
    assert shd.batch_pspec(MESH, 8, 1) == P(None)          # 8 % 16 != 0


def test_cache_pspecs_seq_sharded_when_kv_small():
    cfg = get_config("qwen3-8b")    # kv=8 < model=16
    cache = jax.eval_shape(lambda: init_decode_cache(cfg, 128, 32768))
    specs = shd.cache_pspecs(cfg, MESH, cache)
    # (L, B, C, Hk, hd): C (seq) sharded over model, heads replicated
    assert specs.kv_k == P(None, "data", "model", None, None)


def test_cache_pspecs_head_sharded_when_divisible():
    cfg = get_config("hubert-xlarge").with_(is_encoder=False)  # kv=16
    cache = jax.eval_shape(lambda: init_decode_cache(cfg, 128, 1024))
    specs = shd.cache_pspecs(cfg, MESH, cache)
    assert specs.kv_k == P(None, "data", None, "model", None)


def test_cache_pspecs_ssm():
    cfg = get_config("mamba2-370m")
    cache = jax.eval_shape(lambda: init_decode_cache(cfg, 128, 32768))
    specs = shd.cache_pspecs(cfg, MESH, cache)
    assert specs.ssm_state == P(None, "data", "model", None, None)
