"""Per-arch smoke tests: one forward + train step on CPU, shapes + no NaNs.

Reduced configs of the same family (assignment requirement (f))."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import moe
from repro.models.mamba2 import ssd_chunked, ssd_reference
from repro.models.rglru import rglru_reference, rglru_scan, rglru_step
from repro.models.transformer import forward, init_params, train_loss


def _batch(cfg, rng, b=2, s=16):
    if cfg.family == "audio":
        return {"frames": jnp.asarray(
            rng.normal(size=(b, s, cfg.frontend_dim)), jnp.float32),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)),
                                  jnp.int32)}
    if cfg.family == "vlm":
        return {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (b, s - cfg.vision_tokens)),
            jnp.int32),
            "vision": jnp.asarray(
                rng.normal(size=(b, cfg.vision_tokens, cfg.d_model)),
                jnp.float32)}
    return {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)),
                                  jnp.int32)}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_and_train_step(arch, rng):
    cfg = get_config(arch, smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, rng)
    logits = forward(params, cfg, batch)
    b = 2
    assert logits.shape[0] == b and logits.shape[-1] == cfg.padded_vocab
    real = np.asarray(logits[..., :cfg.vocab_size])
    assert np.isfinite(real).all(), f"{arch}: NaN/inf logits"
    if cfg.padded_vocab != cfg.vocab_size:   # pad columns masked to -inf
        assert (np.asarray(logits[..., cfg.vocab_size:]) <= -1e29).all()
    loss, grads = jax.value_and_grad(
        lambda p: train_loss(p, cfg, batch))(params)
    assert np.isfinite(float(loss))
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, f"{arch}: degenerate grads"


def test_full_configs_match_assignment():
    """The exact published dimensions (assignment block)."""
    expect = {
        "qwen3-8b": (36, 4096, 32, 8, 12288, 151936),
        "internlm2-20b": (48, 6144, 48, 8, 16384, 92544),
        "minicpm-2b": (40, 2304, 36, 36, 5760, 122753),
        "qwen3-32b": (64, 5120, 64, 8, 25600, 151936),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
        "mamba2-370m": (48, 1024, 1, 1, 0, 50280),
        "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
        "internvl2-76b": (80, 8192, 64, 8, 28672, 128256),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
    }
    for arch, (L, d, h, kv, ff, v) in expect.items():
        cfg = get_config(arch)
        got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
               cfg.d_ff, cfg.vocab_size)
        assert got == (L, d, h, kv, ff, v), f"{arch}: {got}"
    assert get_config("qwen3-8b").qk_norm
    assert get_config("mixtral-8x7b").sliding_window == 4096
    assert get_config("mixtral-8x7b").n_experts == 8
    assert get_config("mamba2-370m").ssm_state == 128
    assert get_config("hubert-xlarge").is_encoder
    assert get_config("recurrentgemma-2b").block_pattern == \
        ("rec", "rec", "attn")


def test_moe_sorted_matches_dense(rng):
    cfg = get_config("mixtral-8x7b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    lp = jax.tree.map(lambda x: x[0], params["blocks"])
    x = jnp.asarray(rng.normal(size=(3, 16, cfg.d_model)), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(moe.moe_sorted(lp, x, cfg)),
        np.asarray(moe.moe_dense(lp, x, cfg)), rtol=2e-4, atol=2e-4)


def test_moe_capacity_drops_bounded(rng):
    cfg = get_config("mixtral-8x7b", smoke=True).with_(capacity_factor=1.0)
    params = init_params(cfg, jax.random.PRNGKey(0))
    lp = jax.tree.map(lambda x: x[0], params["blocks"])
    x = jnp.asarray(rng.normal(size=(3, 16, cfg.d_model)), jnp.float32)
    y = moe.moe_sorted(lp, x, cfg)
    assert np.isfinite(np.asarray(y)).all()


def test_moe_aux_loss_positive(rng):
    cfg = get_config("mixtral-8x7b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    lp = jax.tree.map(lambda x: x[0], params["blocks"])
    x = jnp.asarray(rng.normal(size=(2, 8, cfg.d_model)), jnp.float32)
    aux = float(moe.aux_load_balance_loss(lp, x, cfg))
    assert aux >= 1.0 - 1e-3   # >= 1 by Cauchy-Schwarz, == 1 when balanced


def test_ssd_chunked_vs_reference(rng):
    B, S, H, P, N, Q = 2, 24, 3, 4, 8, 8
    x = jnp.asarray(rng.normal(size=(B, S, H, P)), jnp.float32)
    dt = jnp.asarray(np.abs(rng.normal(size=(B, S, H))) * 0.5 + 0.05)
    a_log = jnp.asarray(rng.normal(size=(H,)) * 0.3, jnp.float32)
    bm = jnp.asarray(rng.normal(size=(B, S, N)), jnp.float32)
    cm = jnp.asarray(rng.normal(size=(B, S, N)), jnp.float32)
    y_ref, st_ref = ssd_reference(x, dt, a_log, bm, cm)
    y, st = ssd_chunked(x, dt, a_log, bm, cm, Q)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_ref),
                               rtol=1e-4, atol=1e-4)


def test_ssd_chunked_ragged_tail(rng):
    """seq not a multiple of chunk exercises the internal padding."""
    B, S, H, P, N, Q = 1, 19, 2, 4, 8, 8
    x = jnp.asarray(rng.normal(size=(B, S, H, P)), jnp.float32)
    dt = jnp.asarray(np.abs(rng.normal(size=(B, S, H))) * 0.5 + 0.05)
    a_log = jnp.asarray(rng.normal(size=(H,)) * 0.3, jnp.float32)
    bm = jnp.asarray(rng.normal(size=(B, S, N)), jnp.float32)
    cm = jnp.asarray(rng.normal(size=(B, S, N)), jnp.float32)
    y_ref, st_ref = ssd_reference(x, dt, a_log, bm, cm)
    y, st = ssd_chunked(x, dt, a_log, bm, cm, Q)
    assert y.shape == (B, S, H, P)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_ref),
                               rtol=1e-4, atol=1e-4)


def test_rglru_scan_vs_reference(rng):
    B, S, D = 2, 17, 8
    params = {"w_a": jnp.asarray(rng.normal(size=(D, D)) * 0.3, jnp.float32),
              "b_a": jnp.asarray(rng.normal(size=(D,)), jnp.float32),
              "w_x": jnp.asarray(rng.normal(size=(D, D)) * 0.3, jnp.float32),
              "b_x": jnp.asarray(rng.normal(size=(D,)), jnp.float32),
              "lam": jnp.asarray(rng.normal(size=(D,)) + 2.0, jnp.float32)}
    x = jnp.asarray(rng.normal(size=(B, S, D)), jnp.float32)
    h, h_last = rglru_scan(params, x)
    h_ref = rglru_reference(params, x)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h_last), np.asarray(h_ref[:, -1]),
                               rtol=1e-5, atol=1e-5)
    # decode continuation
    hstep = rglru_step(params, x[:, 10], jnp.asarray(np.asarray(h_ref[:, 9])))
    np.testing.assert_allclose(np.asarray(hstep), np.asarray(h_ref[:, 10]),
                               rtol=1e-5, atol=1e-5)
