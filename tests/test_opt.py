"""Pass-based optimization pipeline (core/opt.py): semantics, remaps,
and the staged wiring through compile / partition / serving / cost model.

Equivalence methodology: for small graphs (n_inputs <= 10) every pass is
checked under FULL input enumeration — the strongest possible statement —
and under random vectors for larger fanins; a hypothesis section widens
the random-structure coverage when hypothesis is installed.
"""
import numpy as np
import pytest

from repro.core.gate_ir import (CONST0, CONST1, LogicGraph, OpCode,
                                random_graph, remap_wires)
from repro.core.levelize import levelize
from repro.core.opt import (ConstantFold, DeadGateElim, OptResult,
                            PassManager, Rebalance, SimplifyIdentities,
                            StructuralHash, compose_remaps, resolve_pipeline)
from repro.core.scheduler import compile_graph, execute_program_np
from repro.core.spec import CompileSpec

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

ALL_PASSES = [ConstantFold(), SimplifyIdentities(), StructuralHash(),
              DeadGateElim(), Rebalance()]


def _vectors(g: LogicGraph, seed: int = 0) -> np.ndarray:
    """Full enumeration for small fanin, random vectors otherwise."""
    if g.n_inputs <= 10:
        n = 2 ** g.n_inputs
        return ((np.arange(n)[:, None] >> np.arange(g.n_inputs)[None, :])
                & 1).astype(bool)
    rng = np.random.default_rng(seed)
    return rng.integers(0, 2, (256, g.n_inputs)).astype(bool)


def _all_wire_values(g: LogicGraph, X: np.ndarray) -> np.ndarray:
    """(n_wires, batch) value table — the oracle for the remap contract."""
    probe = g.copy()
    probe.set_outputs(range(probe.n_wires))
    return probe.evaluate(X).T


def assert_remap_contract(g: LogicGraph, res, X: np.ndarray) -> None:
    """The full PassResult/OptResult contract of the opt module docstring:
    outputs remap in order, and EVERY live old wire's function is computed
    bit-for-bit by its image in the new graph."""
    new, remap = res.graph, res.remap
    assert len(remap) == g.n_wires
    assert new.n_inputs == g.n_inputs
    # constants + primary inputs are fixed points
    assert (remap[:g.first_gate_wire] ==
            np.arange(g.first_gate_wire)).all()
    # output lists remap in order
    assert remap_wires(remap, g.outputs, new.n_wires) == list(new.outputs)
    old_vals = _all_wire_values(g, X)
    new_vals = _all_wire_values(new, X)
    live = np.flatnonzero(remap >= 0)
    assert (old_vals[live] == new_vals[remap[live]]).all(), \
        "a live wire's image computes a different function"


# ---------------------------------------------------------------------------
# per-pass equivalence on random + constructed graphs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("opt_pass", ALL_PASSES, ids=lambda p: p.name)
@pytest.mark.parametrize("seed,n_inputs,n_gates", [
    (0, 6, 120), (1, 8, 300), (2, 4, 40), (3, 12, 200)])
def test_pass_preserves_semantics_and_remap(opt_pass, seed, n_inputs,
                                            n_gates):
    rng = np.random.default_rng(seed)
    g = random_graph(rng, n_inputs, n_gates, 8, unary_frac=0.25, locality=24)
    X = _vectors(g, seed)
    want = g.evaluate(X)
    res = opt_pass.run(g)
    assert (res.graph.evaluate(X) == want).all()
    assert res.graph.n_gates <= g.n_gates
    assert_remap_contract(g, res, X)


def test_constant_fold_absorbs_every_opcode():
    """Each (op, const) rule fires: the folded graph has no const-fed
    binary gates left, NOPs fold to CONST0, and semantics hold under
    full enumeration."""
    g = LogicGraph(2)
    a, b = g.input_wire(0), g.input_wire(1)
    outs = []
    for op in (OpCode.AND, OpCode.OR, OpCode.XOR, OpCode.NAND, OpCode.NOR,
               OpCode.XNOR):
        outs.append(g.add_gate(op, a, CONST0))
        outs.append(g.add_gate(op, CONST1, b))
    outs.append(g.add_gate(OpCode.NOP, a, b))       # wire is identically 0
    outs.append(g.add_gate(OpCode.NOT, CONST0))
    outs.append(g.add_gate(OpCode.COPY, a))
    g.set_outputs(outs)
    res = ConstantFold().run(g)
    X = _vectors(g)
    assert (res.graph.evaluate(X) == g.evaluate(X)).all()
    for op, x, y in res.graph.gates:
        if OpCode(op) not in (OpCode.NOT, OpCode.COPY):
            assert CONST0 not in (x, y) and CONST1 not in (x, y)
    # 12 const-fed binaries + NOP + NOT(0) + COPY -> at most the 2 NOTs
    # the 'not' rules need (deduped per operand)
    assert res.graph.n_gates <= 2


def test_constant_fold_cascades():
    """A constant produced by folding propagates to downstream gates."""
    g = LogicGraph(2)
    a, b = g.input_wire(0), g.input_wire(1)
    zero = g.add_gate(OpCode.AND, a, CONST0)       # == 0
    dead = g.add_gate(OpCode.OR, zero, b)          # == b
    out = g.add_gate(OpCode.XOR, dead, zero)       # == b
    g.set_outputs([out])
    res = ConstantFold().run(g)
    assert res.graph.n_gates == 0
    assert res.graph.outputs == [g.input_wire(1)]
    assert res.remap[out] == g.input_wire(1)


def test_structural_hash_dedupes_commutative():
    g = LogicGraph(2)
    a, b = g.input_wire(0), g.input_wire(1)
    w1 = g.add_gate(OpCode.AND, a, b)
    w2 = g.add_gate(OpCode.AND, b, a)              # commuted duplicate
    w3 = g.add_gate(OpCode.AND, a, b)              # literal duplicate
    out = g.add_gate(OpCode.OR, w1, w2)
    g.set_outputs([out, w3])
    res = StructuralHash().run(g)
    assert res.remap[w1] == res.remap[w2] == res.remap[w3]
    # OR(x, x) is left for SimplifyIdentities; dedup itself: 3 ANDs -> 1
    assert res.graph.n_gates == 2
    X = _vectors(g)
    assert (res.graph.evaluate(X) == g.evaluate(X)).all()


def test_simplify_double_negation_and_fusion():
    g = LogicGraph(2)
    a, b = g.input_wire(0), g.input_wire(1)
    n1 = g.add_gate(OpCode.NOT, a)
    n2 = g.add_gate(OpCode.NOT, n1)                # == a
    land = g.add_gate(OpCode.AND, n2, b)
    nand = g.add_gate(OpCode.NOT, land)            # fuses -> NAND(a, b)
    same = g.add_gate(OpCode.XOR, b, b)            # == 0
    g.set_outputs([nand, same])
    res = SimplifyIdentities().run(g)
    pipe = PassManager([SimplifyIdentities(), DeadGateElim()]).run(g)
    X = _vectors(g)
    assert (res.graph.evaluate(X) == g.evaluate(X)).all()
    assert res.remap[n2] == g.input_wire(0)
    assert res.remap[same] == CONST0
    # after sweeping the unreferenced AND: a single NAND remains
    assert pipe.graph.n_gates == 1
    assert OpCode(pipe.graph.gates[0][0]) == OpCode.NAND


def test_dead_gate_elim_drops_and_remaps_to_minus_one():
    g = LogicGraph(4)
    live = g.add_gate(OpCode.AND, g.input_wire(0), g.input_wire(1))
    dead = [g.add_gate(OpCode.OR, g.input_wire(2), g.input_wire(3))
            for _ in range(15)]
    g.set_outputs([live])
    res = DeadGateElim().run(g)
    assert res.graph.n_gates == 1
    assert (res.remap[np.asarray(dead)] == -1).all()
    with pytest.raises(ValueError, match="dropped"):
        remap_wires(res.remap, [dead[0]], res.graph.n_wires)


def test_dead_gate_elim_unary_with_dead_ignored_operand():
    """A NOT/COPY gate whose ignored b operand references a DEAD gate must
    rebuild with b pinned to CONST0, not gather the dropped wire's -1."""
    g = LogicGraph(1)
    i0 = g.input_wire(0)
    dead = g.add_gate(OpCode.AND, i0, i0)
    live = g.add_gate(OpCode.NOT, i0, dead)        # b ignored semantically
    g.set_outputs([live])
    res = DeadGateElim().run(g)
    assert res.graph.n_gates == 1
    assert res.remap[dead] == -1
    X = _vectors(g)
    assert (res.graph.evaluate(X) == g.evaluate(X)).all()


def test_dead_gate_elim_ignores_nop_operand_cones():
    """NOP's result ignores its operands, so a cone whose only reader is
    a NOP gate is dead — the rebuilt NOP pins operands to CONST0."""
    g = LogicGraph(2)
    cone = g.input_wire(0)
    for _ in range(10):
        cone = g.add_gate(OpCode.OR, cone, g.input_wire(1))
    nop = g.add_gate(OpCode.NOP, cone, cone)
    g.set_outputs([nop])
    res = DeadGateElim().run(g)
    assert res.graph.n_gates == 1                  # just the NOP survives
    assert res.graph.gates[0] == (int(OpCode.NOP), CONST0, CONST0)
    X = _vectors(g)
    assert (res.graph.evaluate(X) == g.evaluate(X)).all()


def test_pipeline_cache_key_distinguishes_pass_classes():
    """Custom Pass subclasses that forget to override ``name`` must not
    collide in the serving memo: the key carries the class identity."""
    class A(DeadGateElim):
        pass

    class B(DeadGateElim):
        pass

    ka = PassManager([A()]).cache_key
    kb = PassManager([B()]).cache_key
    assert ka != kb
    assert PassManager([A()]).cache_key == ka      # deterministic


def test_rebalance_cuts_depth_with_remap():
    g = LogicGraph(8)
    w = g.input_wire(0)
    for i in range(1, 8):
        w = g.add_gate(OpCode.AND, w, g.input_wire(i))
    g.set_outputs([w])
    res = Rebalance().run(g)
    assert levelize(res.graph).depth == 3
    assert res.graph.n_gates == 7
    X = _vectors(g)
    assert (res.graph.evaluate(X) == g.evaluate(X)).all()
    assert res.remap[w] == res.graph.outputs[0]


# ---------------------------------------------------------------------------
# the composed default pipeline
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(5))
def test_default_pipeline_equivalence_and_composed_remap(seed):
    rng = np.random.default_rng(seed)
    g = random_graph(rng, 8, 250, 10, unary_frac=0.2, locality=32)
    X = _vectors(g)                                # full enumeration (2^8)
    res = PassManager.default().run(g)
    assert isinstance(res, OptResult)
    assert (res.graph.evaluate(X) == g.evaluate(X)).all()
    assert res.graph.n_gates <= g.n_gates
    assert levelize(res.graph).depth <= levelize(g).depth
    assert_remap_contract(g, res, X)


def test_pipeline_idempotent_on_fixed_point():
    rng = np.random.default_rng(9)
    g = random_graph(rng, 6, 150, 6, locality=16)
    pm = PassManager.default()
    once = pm.run(g).graph
    twice = pm.run(once)
    assert twice.graph.n_gates == once.n_gates
    # structurally frozen graphs exit after 1 iteration (fingerprint
    # check); count-stable renumbering churn is bounded at 2 by the
    # (n_gates, depth) guard
    assert twice.iterations <= 2
    X = _vectors(g)
    assert (twice.graph.evaluate(X) == once.evaluate(X)).all()
    # a tiny frozen graph: true structural fixed point after 1 iteration
    h = LogicGraph(2)
    h.set_outputs([h.add_gate(OpCode.AND, h.input_wire(0),
                              h.input_wire(1))])
    hh = pm.run(pm.run(h).graph)
    assert hh.iterations == 1


def test_deep_serial_chain_no_recursion_error():
    """Multi-thousand-gate single-fanout chains must optimize (and serve)
    without blowing the recursion limit (Rebalance.collect is iterative)."""
    g = LogicGraph(4)
    w = g.input_wire(0)
    for i in range(3000):
        w = g.add_gate(OpCode.AND, w, g.input_wire(1 + i % 3))
    g.set_outputs([w])
    res = PassManager.default().run(g)
    X = _vectors(g)
    assert (res.graph.evaluate(X) == g.evaluate(X)).all()
    assert levelize(res.graph).depth < levelize(g).depth


def test_compose_remaps_propagates_drops():
    r1 = np.asarray([0, 1, 2, -1, 3])
    r2 = np.asarray([0, -1, 2, 1])
    out = compose_remaps(r1, r2)
    assert out.tolist() == [0, -1, 2, -1, 1]


def test_remap_wires_validation():
    remap = np.asarray([0, 1, -1, 5])
    assert remap_wires(remap, [0, 1], 10) == [0, 1]
    with pytest.raises(ValueError, match="outside the remap domain"):
        remap_wires(remap, [4], 10)
    with pytest.raises(ValueError, match="dropped"):
        remap_wires(remap, [2], 10)
    with pytest.raises(ValueError, match="forward reference"):
        remap_wires(remap, [3], 5)


def test_resolve_pipeline_knob():
    assert resolve_pipeline("none") is None
    assert resolve_pipeline(None) is None
    assert resolve_pipeline(False) is None
    assert isinstance(resolve_pipeline("default"), PassManager)
    assert isinstance(resolve_pipeline(True), PassManager)
    pm = PassManager([DeadGateElim()])
    assert resolve_pipeline(pm) is pm
    with pytest.raises(ValueError, match="optimize"):
        resolve_pipeline("aggressive")


# ---------------------------------------------------------------------------
# staged wiring: compiler / partition / serving cache / cost model
# ---------------------------------------------------------------------------

def test_compile_graph_optimize_knob(rng):
    g = random_graph(rng, 9, 300, 8, locality=24)
    X = _vectors(g)
    raw = compile_graph(g, CompileSpec(n_unit=16, optimize="none"))
    opt = compile_graph(g, CompileSpec(n_unit=16, optimize="default"))
    custom = compile_graph(g, CompileSpec(n_unit=16,
                                          optimize=PassManager.default()))
    assert opt.n_gates < raw.n_gates
    assert opt.n_steps < raw.n_steps
    assert custom.n_gates == opt.n_gates
    for prog in (raw, opt, custom):
        assert (execute_program_np(prog, X) == g.evaluate(X)).all()
    with pytest.raises(ValueError, match="optimize"):
        compile_graph(g, CompileSpec(n_unit=16, optimize="bogus"))


def test_compile_graph_optimize_ignores_stale_levelization(rng):
    """A caller-supplied levelization of the RAW graph must not leak into
    the optimized schedule."""
    g = random_graph(rng, 6, 120, 6, locality=16)
    lv_raw = levelize(g)
    prog = compile_graph(g, CompileSpec(n_unit=8), lv=lv_raw)
    X = _vectors(g)
    assert (execute_program_np(prog, X) == g.evaluate(X)).all()


def test_partition_optimize_per_cluster(rng):
    from repro.core.partition import execute_partitions, partition
    g = random_graph(rng, 10, 400, 16, locality=40)
    raw = partition(g, 120)
    opt = partition(g, CompileSpec(max_gates=120, optimize="default"))
    X = _vectors(g)
    want = g.evaluate(X)
    assert (execute_partitions(raw, X) == want).all()
    assert (execute_partitions(opt, X) == want).all()
    assert [p.output_indices for p in opt] == \
        [p.output_indices for p in raw]
    assert sum(p.graph.n_gates for p in opt) < \
        sum(p.graph.n_gates for p in raw)


def test_program_cache_keys_on_post_opt_fingerprint(rng):
    """Structurally different raw graphs with one optimized form share a
    single compiled entry (the serving cache-keying change)."""
    from repro.serve import LogicEngine, ProgramCache

    def base_graph():
        g = LogicGraph(3)
        a, b, c = (g.input_wire(i) for i in range(3))
        w = g.add_gate(OpCode.AND, a, b)
        g.set_outputs([g.add_gate(OpCode.OR, w, c)])
        return g

    g1 = base_graph()
    g2 = LogicGraph(3)                      # same function, noisy structure
    a, b, c = (g2.input_wire(i) for i in range(3))
    g2.add_gate(OpCode.XOR, a, c)           # dead
    w = g2.add_gate(OpCode.AND, b, a)       # commuted
    nn = g2.add_gate(OpCode.NOT, g2.add_gate(OpCode.NOT, w))  # double-NOT
    g2.set_outputs([g2.add_gate(OpCode.OR, nn, c)])
    assert g1.fingerprint() != g2.fingerprint()

    cache = ProgramCache()
    eng = LogicEngine(CompileSpec(n_unit=8), capacity=32, cache=cache)
    X = _vectors(g1)
    assert (eng.serve(g1, X) == g1.evaluate(X)).all()
    assert (eng.serve(g2, X) == g1.evaluate(X)).all()
    assert cache.misses == 1 and cache.hits == 1 and len(cache) == 1

    # optimize="none" keys on the raw fingerprints -> two entries
    raw_cache = ProgramCache()
    raw_eng = LogicEngine(CompileSpec(n_unit=8, optimize="none"),
                          capacity=32, cache=raw_cache)
    raw_eng.serve(g1, X)
    raw_eng.serve(g2, X)
    assert raw_cache.misses == 2


def test_program_cache_budget_normalizes_on_optimized_gates(rng):
    """A budget the OPTIMIZED graph fits under serves monolithically and
    shares the no-budget entry."""
    from repro.serve import ProgramCache
    g = random_graph(rng, 8, 300, 8, locality=24)
    pm = PassManager.default()
    assert pm.run(g).graph.n_gates < g.n_gates
    cache = ProgramCache()
    spec = CompileSpec(n_unit=8, optimize=pm)
    mono = cache.get(g, spec)
    budget = cache.get(g, spec.with_(max_gates=g.n_gates))
    assert budget is mono                   # raw-size budget is unbinding
    assert cache.misses == 1 and cache.hits == 1


def test_ffcl_stats_optimized_path(rng):
    from repro.core.cost_model import CostModel, FfclStats
    from repro.core.optimizer import sweep
    g = random_graph(rng, 10, 400, 12, locality=32)
    raw = FfclStats.from_graph(g)
    opt = FfclStats.from_graph(g, optimized=True)
    assert opt.n_gates < raw.n_gates
    assert opt.depth <= raw.depth
    model = CostModel()
    units = [8, 32, 128]
    res_raw = sweep(model, [(raw, 4, 128)], units)
    res_opt = sweep(model, [(opt, 4, 128)], units)
    assert res_opt.best_cycles < res_raw.best_cycles


def test_copy_preserves_fingerprint_cache(rng):
    g = random_graph(rng, 6, 80, 4, locality=16)
    fp = g.fingerprint()
    c = g.copy()
    assert getattr(c, "_fingerprint_cache", None) is not None
    assert c.fingerprint() == fp
    # the carried cache must still invalidate on mutation
    c.add_gate(OpCode.NOT, c.input_wire(0))
    c.set_outputs([c.n_wires - 1])
    assert c.fingerprint() != fp


# ---------------------------------------------------------------------------
# hypothesis: randomized structure coverage
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    @st.composite
    def graphs(draw):
        seed = draw(st.integers(0, 2 ** 31 - 1))
        rng = np.random.default_rng(seed)
        return random_graph(rng, draw(st.integers(1, 10)),
                            draw(st.integers(1, 150)),
                            draw(st.integers(1, 8)),
                            unary_frac=draw(st.sampled_from([0.0, 0.2, 0.5])),
                            locality=draw(st.sampled_from([4, 32, 1000])))

    @settings(max_examples=40, deadline=None)
    @given(graphs(), st.sampled_from(range(len(ALL_PASSES))))
    def test_hypothesis_single_pass_equivalence(g, pass_idx):
        X = _vectors(g)
        res = ALL_PASSES[pass_idx].run(g)
        assert (res.graph.evaluate(X) == g.evaluate(X)).all()
        assert_remap_contract(g, res, X)

    @settings(max_examples=30, deadline=None)
    @given(graphs())
    def test_hypothesis_pipeline_equivalence(g):
        X = _vectors(g)
        res = PassManager.default().run(g)
        assert (res.graph.evaluate(X) == g.evaluate(X)).all()
        assert res.graph.n_gates <= g.n_gates
        assert levelize(res.graph).depth <= levelize(g).depth
        assert_remap_contract(g, res, X)

    @settings(max_examples=20, deadline=None)
    @given(graphs(), st.sampled_from([1, 8, 64]))
    def test_hypothesis_compiled_optimized_equivalence(g, n_unit):
        X = _vectors(g)
        prog = compile_graph(g, CompileSpec(n_unit=n_unit))
        assert (execute_program_np(prog, X) == g.evaluate(X)).all()
