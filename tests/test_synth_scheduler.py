"""Property-based tests of the FFCL compiler invariants (hypothesis)."""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev)")
from hypothesis import given, settings, strategies as st

from repro.core.gate_ir import LogicGraph, OpCode, random_graph
from repro.core.levelize import levelize
from repro.core.scheduler import compile_graph, execute_program_np
from repro.core.spec import CompileSpec
from repro.core.synth import dead_gate_elim, optimize, rebalance


@st.composite
def graphs(draw):
    seed = draw(st.integers(0, 2 ** 31 - 1))
    n_inputs = draw(st.integers(1, 12))
    n_gates = draw(st.integers(1, 150))
    n_outputs = draw(st.integers(1, 8))
    rng = np.random.default_rng(seed)
    return random_graph(rng, n_inputs, n_gates, n_outputs,
                        locality=draw(st.sampled_from([4, 32, 1000])))


def _vectors(g, seed=0):
    rng = np.random.default_rng(seed)
    n = min(64, 2 ** g.n_inputs)
    return rng.integers(0, 2, (n, g.n_inputs)).astype(bool)


@settings(max_examples=40, deadline=None)
@given(graphs())
def test_optimize_preserves_semantics(g):
    X = _vectors(g)
    ref = g.evaluate(X)
    go = optimize(g)
    assert (go.evaluate(X) == ref).all()
    # objectives never regress
    assert go.n_gates <= g.n_gates
    assert levelize(go).depth <= levelize(g).depth


@settings(max_examples=40, deadline=None)
@given(graphs(), st.sampled_from([1, 2, 7, 64]),
       st.sampled_from(["direct", "liveness"]))
def test_program_matches_direct_eval(g, n_unit, alloc):
    X = _vectors(g)
    prog = compile_graph(g, CompileSpec(n_unit=n_unit, alloc=alloc,
                                        optimize="none"))
    assert (execute_program_np(prog, X) == g.evaluate(X)).all()


@settings(max_examples=30, deadline=None)
@given(graphs(), st.sampled_from([1, 3, 16]))
def test_schedule_respects_dependencies(g, n_unit):
    """Every operand of a step was produced at a strictly earlier step (or
    is an input/const), and dst addresses within a step never collide."""
    prog = compile_graph(g, CompileSpec(n_unit=n_unit, optimize="none"))
    produced_at = {}
    for a in [0, 1, *prog.input_addrs.tolist()]:
        produced_at[a] = -1
    for s in range(prog.n_steps):
        live_dsts = []
        for u in range(prog.n_unit):
            op = prog.opcode[s, u]
            if op == 0:      # NOP
                continue
            for src in (prog.src_a[s, u], prog.src_b[s, u]):
                assert src in produced_at and produced_at[src] < s, \
                    f"step {s} reads address {src} not yet produced"
            live_dsts.append(prog.dst[s, u])
        assert len(live_dsts) == len(set(live_dsts)), f"dst collision @ {s}"
        for dcur in live_dsts:
            produced_at[int(dcur)] = s


@settings(max_examples=30, deadline=None)
@given(graphs(), st.sampled_from([2, 8, 128]))
def test_eq23_subkernel_count(g, n_unit):
    """Paper eq. 23: n_subkernels = sum_l ceil(gates_l / n_unit) for the
    unfused layout; step fusion may only shrink the count."""
    lv = levelize(g)
    prog = compile_graph(g, CompileSpec(n_unit=n_unit, fuse_levels=False,
                                        optimize="none"))
    expected = int(np.ceil(lv.histogram() / n_unit).sum())
    assert prog.n_steps == expected
    fused = CompileSpec(n_unit=n_unit, optimize="none")
    assert compile_graph(g, fused).n_steps <= expected


@settings(max_examples=25, deadline=None)
@given(graphs())
def test_liveness_never_larger(g):
    d = compile_graph(g, CompileSpec(n_unit=8, alloc="direct",
                                     optimize="none"))
    lv = compile_graph(g, CompileSpec(n_unit=8, alloc="liveness",
                                      optimize="none"))
    assert lv.n_addr <= d.n_addr


def test_dead_gate_elim_removes_unreachable(rng):
    g = LogicGraph(4)
    live = g.add_gate(OpCode.AND, g.input_wire(0), g.input_wire(1))
    for _ in range(20):   # dead chain
        g.add_gate(OpCode.OR, g.input_wire(2), g.input_wire(3))
    g.set_outputs([live])
    ge = dead_gate_elim(g)
    assert ge.n_gates == 1


def test_rebalance_reduces_chain_depth():
    g = LogicGraph(8)
    w = g.input_wire(0)
    for i in range(1, 8):
        w = g.add_gate(OpCode.AND, w, g.input_wire(i))
    g.set_outputs([w])
    assert levelize(g).depth == 7
    gb = rebalance(g)
    assert levelize(gb).depth == 3      # ceil(log2(8))
    X = np.random.default_rng(0).integers(0, 2, (64, 8)).astype(bool)
    assert (gb.evaluate(X) == g.evaluate(X)).all()
