"""Elastic scaling: a checkpoint written under one mesh restores onto a
different mesh (the fleet-downsize path). The subprocess owns its own
device count (8 fake devices) so the main test process stays 1-device."""
import subprocess
import sys

import pytest

CODE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np, tempfile
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.models.transformer import init_params, param_shapes
from repro.train import sharding as shd
from repro.train.checkpoint import CheckpointManager

cfg = get_config("qwen3-8b", smoke=True)
mesh_a = jax.make_mesh((4, 2), ("data", "model"))
mesh_b = jax.make_mesh((2, 4), ("data", "model"))
shapes = param_shapes(cfg)
shard_a = shd.param_shardings(cfg, mesh_a, shapes)
shard_b = shd.param_shardings(cfg, mesh_b, shapes)

with mesh_a:
    params = jax.jit(lambda k: init_params(cfg, k),
                     out_shardings=shard_a)(jax.random.PRNGKey(0))
with tempfile.TemporaryDirectory() as d:
    mgr = CheckpointManager(d)
    mgr.save(5, params, meta={"data_step": 5})
    like = jax.tree.map(lambda x: jnp.zeros(x.shape, x.dtype), params)
    with mesh_b:
        restored, meta = mgr.restore(like, shardings=shard_b)
    assert meta["data_step"] == 5
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # restored arrays actually live on mesh_b's sharding
    leaf = restored["blocks"]["wq"]
    assert leaf.sharding.mesh.shape["data"] == 2
print("elastic-ok")
"""


@pytest.mark.slow
def test_cross_mesh_restore():
    try:
        out = subprocess.run(
            [sys.executable, "-c", CODE], capture_output=True,
            text=True, timeout=300,
            env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    except subprocess.TimeoutExpired:
        # 8 fake devices + smoke-model jit can exceed the budget on slow
        # shared hosts; that is a capacity limit, not a restore bug.
        pytest.skip("cross-mesh smoke compile exceeded 300s on this host")
    assert "elastic-ok" in out.stdout, out.stderr[-2000:]
