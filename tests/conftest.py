import os

# Tests run on the single host device; the dry-run (and only the dry-run)
# forces 512 devices in its own process.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


def pytest_configure(config):
    # Default per-test timeout when pytest-timeout is installed (it is
    # in requirements-dev.txt / CI): a hung front-door dispatcher or
    # deadlocked wave fails in 120s instead of wedging the whole run.
    # Individual tests can still override with @pytest.mark.timeout.
    if (config.pluginmanager.hasplugin("timeout")
            and not getattr(config.option, "timeout", None)):
        config.option.timeout = 120.0


@pytest.fixture
def rng():
    return np.random.default_rng(0)
