import os

# Tests run on the single host device; the dry-run (and only the dry-run)
# forces 512 devices in its own process.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
