"""Two-level minimization + NullaNet conversion (paper §7)."""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev)")
from hypothesis import given, settings, strategies as st

from repro.core import espresso
from repro.core.nullanet import (BinaryMLPConfig, layer_to_graph,
                                 mlp_accuracy, mlp_to_logic_network,
                                 neuron_enumerated, neuron_isf,
                                 train_binary_mlp)
from repro.data import make_binary_classification


def all_patterns(n):
    return ((np.arange(2 ** n)[:, None] >> np.arange(n)[None, :]) & 1
            ).astype(np.uint8)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10 ** 6), st.integers(2, 9))
def test_minimize_exact_function(seed, v):
    """Complete truth table: SOP must equal the function everywhere."""
    rng = np.random.default_rng(seed)
    pats = all_patterns(v)
    f = rng.integers(0, 2, 2 ** v).astype(bool)
    cubes = espresso.minimize(pats[f], pats[~f])
    assert espresso.check_cover(cubes, pats[f], pats[~f])
    assert (espresso.eval_sop(cubes, pats) == f).all()


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10 ** 6), st.integers(4, 24))
def test_minimize_isf_with_dont_cares(seed, v):
    """Sampled ISF: cover on-set, avoid off-set; DC may go either way."""
    rng = np.random.default_rng(seed)
    n = min(200, 2 ** v)
    samples = rng.integers(0, 2, (n, v)).astype(np.uint8)
    samples = np.unique(samples, axis=0)
    f = rng.integers(0, 2, samples.shape[0]).astype(bool)
    cubes = espresso.minimize(samples[f], samples[~f])
    assert espresso.check_cover(cubes, samples[f], samples[~f])


def test_minimize_fewer_cubes_than_minterms():
    # AND function: 1 minterm in on-set per assignment; espresso finds 1 cube
    pats = all_patterns(6)
    f = pats.all(axis=1)
    cubes = espresso.minimize(pats[f], pats[~f])
    assert len(cubes) == 1


def test_neuron_enumerated_matches_threshold():
    rng = np.random.default_rng(0)
    w = rng.normal(size=8)
    b = 0.3
    x_on, x_off = neuron_enumerated(w, b)
    assert x_on.shape[0] + x_off.shape[0] == 2 ** 8
    got = ((2.0 * x_on - 1) @ w + b >= 0)
    assert got.all()


def test_neuron_isf_consistent():
    rng = np.random.default_rng(0)
    x = rng.integers(0, 2, (500, 16)).astype(np.uint8)
    w = rng.normal(size=16)
    x_on, x_off = neuron_isf(x, w, -0.1)
    # no pattern in both sets
    on = {tuple(r) for r in x_on}
    off = {tuple(r) for r in x_off}
    assert not (on & off)


def test_layer_to_graph_exact_on_observed():
    rng = np.random.default_rng(1)
    x = rng.integers(0, 2, (300, 12)).astype(np.uint8)
    W = rng.normal(size=(12, 5)).astype(np.float32)
    b = rng.normal(size=5).astype(np.float32) * 0.1
    g = layer_to_graph(x, W, b, mode="isf")
    got = g.evaluate(x.astype(bool))
    want = ((2.0 * x - 1.0) @ W + b) >= 0
    assert (got == want).all()   # ISF construction is exact on observed


@pytest.mark.slow
def test_nullanet_end_to_end_accuracy():
    x, y = make_binary_classification(2000, 24, n_classes=3, noise=0.05)
    xt, yt, xv, yv = x[:1500], y[:1500], x[1500:], y[1500:]
    cfg = BinaryMLPConfig(n_features=24, hidden=(16, 12), n_classes=3)
    params = train_binary_mlp(cfg, xt, yt, steps=200)
    acc_mlp = mlp_accuracy(params, cfg, xv, yv)
    net = mlp_to_logic_network(params, cfg, xt, mode="isf")
    acc_logic = (net.predict(xv) == yv).mean()
    # paper §2: binary-implementation accuracy drop < 4%
    assert acc_mlp > 0.9
    assert acc_mlp - acc_logic < 0.04
