"""Megakernel regression suite: the single-launch whole-network executor.

Pins the three launch-chain bugfixes this feature shipped with, plus the
serving/flow integration contracts:

  * **per-program jit caching** — runner traces are cached on the program
    object (ops.py), so repeated same-shape calls take exactly ONE trace
    and distinct programs never collide in a module-global cache;
  * **gateless stages** — a 0-step stage inside a megaprogram must be a
    pure pass-through (no zero-trip ``fori_loop``, no stage-offset
    desync for the stages after it);
  * **padding hygiene** — the 32-samples/word packing and the block_w
    grid padding produce garbage lanes; chained stages must never let
    that garbage contaminate real lanes (batch 1, batch 31/33, and a
    batch that spills across grid blocks all agree with the oracle);
  * **single launch** — the fused path really is one ``pallas_call``
    (counter hook, not timing);
  * **engine chain serving** — ``serve_chain`` caches, LRU-evicts, and
    recompiles chain entries bit-exactly.
"""
import numpy as np
import pytest

from repro.core.gate_ir import CONST1, LogicGraph, random_graph
from repro.core.scheduler import (build_megaprogram, compile_graph,
                                  execute_megaprogram_np)
from repro.core.spec import CompileSpec
from repro.kernels.logic_dsp import kernel as _k
from repro.kernels.logic_dsp.ops import (mega_forward_words, mega_infer_bits,
                                         logic_infer_bits, pack_bits_jnp,
                                         trace_count, unpack_bits_jnp)

import jax.numpy as jnp


def _bits(rng, batch, n):
    return rng.integers(0, 2, (batch, n)).astype(bool)


def _layer(rng, n_in, n_gates, n_out):
    return random_graph(rng, n_in, n_gates, n_out, unary_frac=0.2,
                        locality=16)


def _chain_progs(graphs, n_unit=8, alloc="liveness"):
    spec = CompileSpec(n_unit=n_unit, alloc=alloc, optimize="none")
    return [compile_graph(g, spec) for g in graphs]


def _stack_eval(graphs, bits):
    h = np.asarray(bits, dtype=bool)
    for g in graphs:
        h = g.evaluate(h)
    return h


# ---------------------------------------------------------------------------
# satellite 1: per-program jit caching — trace-count pin
# ---------------------------------------------------------------------------

def test_runner_traces_once_per_shape():
    """Same program, same batch shape, three calls -> exactly one trace."""
    rng = np.random.default_rng(0)
    g = _layer(rng, 6, 50, 4)
    prog = compile_graph(g, CompileSpec(n_unit=8, optimize="none"))
    bits = _bits(rng, 40, 6)
    before = trace_count()
    for _ in range(3):
        out = logic_infer_bits(prog, bits)
    assert trace_count() - before == 1
    assert (out == g.evaluate(bits)).all()
    # a NEW batch shape is a legitimate retrace — exactly one more
    logic_infer_bits(prog, _bits(rng, 7, 6))
    assert trace_count() - before == 2


def test_runner_cache_is_per_program_object():
    """Two same-shape programs keep separate runners: no module-global
    cache collision, and traces die with the program object."""
    rng = np.random.default_rng(1)
    g1, g2 = _layer(rng, 5, 30, 3), _layer(rng, 5, 30, 3)
    spec = CompileSpec(n_unit=8, optimize="none")
    p1, p2 = compile_graph(g1, spec), compile_graph(g2, spec)
    bits = _bits(rng, 33, 5)
    assert (logic_infer_bits(p1, bits) == g1.evaluate(bits)).all()
    assert (logic_infer_bits(p2, bits) == g2.evaluate(bits)).all()
    assert getattr(p1, "_jit_runners") is not getattr(p2, "_jit_runners")


def test_mega_runner_traces_once_per_shape():
    rng = np.random.default_rng(2)
    graphs = [_layer(rng, 6, 40, 5), _layer(rng, 5, 30, 3)]
    mega = build_megaprogram(_chain_progs(graphs), mode="chain")
    bits = _bits(rng, 45, 6)
    before = trace_count()
    for _ in range(3):
        out = mega_infer_bits(mega, bits)
    assert trace_count() - before == 1
    assert (out == _stack_eval(graphs, bits)).all()


# ---------------------------------------------------------------------------
# satellite 2: gateless stages inside a megaprogram
# ---------------------------------------------------------------------------

def _passthrough(n):
    g = LogicGraph(n, name="pass")
    g.set_outputs([g.input_wire(i) for i in range(n)])
    return g


def test_gateless_middle_stage():
    """A 0-step pass-through between two real stages: no zero-trip loop,
    and the stage AFTER it still reads the right step/out offsets."""
    rng = np.random.default_rng(3)
    graphs = [_layer(rng, 6, 40, 4), _passthrough(4), _layer(rng, 4, 25, 3)]
    progs = _chain_progs(graphs)
    assert progs[1].n_steps == 0
    mega = build_megaprogram(progs, mode="chain")
    bits = _bits(rng, 37, 6)
    want = _stack_eval(graphs, bits)
    assert (mega_infer_bits(mega, bits, use_ref=False) == want).all()
    assert (mega_infer_bits(mega, bits, use_ref=True) == want).all()
    assert (execute_megaprogram_np(mega, bits) == want).all()


def test_gateless_edge_stages():
    """Gateless first and last stages (shuffle + const outputs survive)."""
    rng = np.random.default_rng(4)
    shuffle = LogicGraph(5, name="shuffle")
    shuffle.set_outputs([shuffle.input_wire(i) for i in (3, 1, 4, 0, 2)])
    tail = LogicGraph(3, name="tail")
    tail.set_outputs([tail.input_wire(2), CONST1, tail.input_wire(0)])
    graphs = [shuffle, _layer(rng, 5, 30, 3), tail]
    mega = build_megaprogram(_chain_progs(graphs), mode="chain")
    bits = _bits(rng, 50, 5)
    want = _stack_eval(graphs, bits)
    assert (mega_infer_bits(mega, bits, use_ref=False) == want).all()


def test_all_gateless_pipeline_routes_to_ref():
    """total_steps == 0: pallas cannot take (0, n_unit) streams; the mega
    path must fall back to the jnp reference and still be exact."""
    rng = np.random.default_rng(5)
    graphs = [_passthrough(4), _passthrough(4)]
    mega = build_megaprogram(_chain_progs(graphs), mode="chain")
    assert mega.total_steps == 0
    bits = _bits(rng, 21, 4)
    assert (mega_infer_bits(mega, bits) == bits).all()


# ---------------------------------------------------------------------------
# satellite 3: padding hygiene on the chained path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("batch", [1, 31, 32, 33, 70])
def test_chain_padding_parity(batch):
    """Word-padding garbage (inverting gates flip the zero-padded lanes)
    must stay confined to padding lanes across stage handoffs."""
    rng = np.random.default_rng(6)
    graphs = [_layer(rng, 6, 40, 5), _layer(rng, 5, 35, 4)]
    mega = build_megaprogram(_chain_progs(graphs), mode="chain")
    bits = _bits(rng, batch, 6)
    want = _stack_eval(graphs, bits)
    assert (mega_infer_bits(mega, bits) == want).all()


def test_block_spill_padding_parity():
    """A batch spanning several grid blocks (block_w=2 words): the
    _pad_words fill for the ragged last block must not leak either."""
    rng = np.random.default_rng(7)
    graphs = [_layer(rng, 6, 40, 5), _layer(rng, 5, 35, 4)]
    mega = build_megaprogram(_chain_progs(graphs), mode="chain")
    bits = _bits(rng, 5 * 32 + 3, 6)      # 6 words -> 3 blocks of 2
    want = _stack_eval(graphs, bits)
    words = pack_bits_jnp(jnp.asarray(bits))
    out = mega_forward_words(mega, words, block_w=2)
    got = np.asarray(unpack_bits_jnp(out, bits.shape[0]))
    assert (got == want).all()


# ---------------------------------------------------------------------------
# single-launch pin (counter hook, not timing)
# ---------------------------------------------------------------------------

def test_megakernel_is_single_launch():
    """One fresh megaprogram, one trace -> exactly one pallas_call, even
    for a 3-stage pipeline that used to take 3 launches."""
    rng = np.random.default_rng(8)
    graphs = [_layer(rng, 6, 40, 5), _layer(rng, 5, 30, 4),
              _layer(rng, 4, 25, 3)]
    mega = build_megaprogram(_chain_progs(graphs), mode="chain")
    bits = _bits(rng, 45, 6)
    before = _k.launch_count()
    out = mega_infer_bits(mega, bits)
    assert _k.launch_count() - before == 1
    assert (out == _stack_eval(graphs, bits)).all()
    # cached runner: further same-shape calls add ZERO launches
    mega_infer_bits(mega, bits)
    assert _k.launch_count() - before == 1


# ---------------------------------------------------------------------------
# builder validation
# ---------------------------------------------------------------------------

def test_build_megaprogram_validation():
    rng = np.random.default_rng(9)
    a = compile_graph(_layer(rng, 6, 30, 4),
                      CompileSpec(n_unit=8, optimize="none"))
    b = compile_graph(_layer(rng, 5, 30, 3),
                      CompileSpec(n_unit=8, optimize="none"))
    with pytest.raises(ValueError, match="at least one stage"):
        build_megaprogram([])
    with pytest.raises(ValueError, match="width mismatch"):
        build_megaprogram([a, b], mode="chain")     # 4 outs != 5 ins
    with pytest.raises(ValueError, match="no output permutation"):
        build_megaprogram([a], mode="chain",
                          output_perm=np.arange(4))
    with pytest.raises(ValueError, match="mode"):
        build_megaprogram([a], mode="fanout")


def test_parallel_mode_permutation():
    """Parallel mode applies the partition permutation in-kernel."""
    rng = np.random.default_rng(10)
    g1 = _layer(rng, 6, 30, 2)
    g2 = _layer(rng, 6, 25, 2)
    p1, p2 = _chain_progs([g1, g2])
    perm = np.array([2, 0, 3, 1], dtype=np.int64)   # interleave the slabs
    mega = build_megaprogram([p1, p2], mode="parallel", output_perm=perm)
    bits = _bits(rng, 41, 6)
    cat = np.concatenate([g1.evaluate(bits), g2.evaluate(bits)], axis=1)
    want = cat[:, perm]
    assert (mega_infer_bits(mega, bits) == want).all()
    assert (execute_megaprogram_np(mega, bits) == want).all()


# ---------------------------------------------------------------------------
# mega lane padding: mixed n_unit stages
# ---------------------------------------------------------------------------

def test_mixed_n_unit_stages_lane_padded():
    """Stages scheduled at different n_unit concatenate by padding the
    narrow stage's lanes with NOPs into its OWN trash row."""
    rng = np.random.default_rng(11)
    g1, g2 = _layer(rng, 6, 40, 5), _layer(rng, 5, 35, 4)
    p1 = compile_graph(g1, CompileSpec(n_unit=8, optimize="none"))
    p2 = compile_graph(g2, CompileSpec(n_unit=64, optimize="none"))
    mega = build_megaprogram([p1, p2], mode="chain")
    assert mega.n_unit == 64
    bits = _bits(rng, 39, 6)
    want = _stack_eval([g1, g2], bits)
    assert (mega_infer_bits(mega, bits, use_ref=False) == want).all()
    assert (execute_megaprogram_np(mega, bits) == want).all()


# ---------------------------------------------------------------------------
# engine chain serving
# ---------------------------------------------------------------------------

def test_engine_serve_chain_bit_exact_and_cached():
    from repro.serve import LogicEngine
    rng = np.random.default_rng(12)
    graphs = [_layer(rng, 6, 40, 5), _layer(rng, 5, 30, 3)]
    eng = LogicEngine(CompileSpec(n_unit=8), capacity=64)
    bits = _bits(rng, 150, 6)           # > capacity: 3 chunks, 1 launch/wave
    want = _stack_eval(graphs, bits)
    assert (eng.serve_chain(graphs, bits) == want).all()
    misses = eng.cache.misses
    assert (eng.serve_chain(graphs, bits) == want).all()
    assert eng.cache.misses == misses   # second serve is a cache hit
    assert eng.cache.hits >= 1


def test_engine_serve_chain_evict_recompile():
    """An LRU-evicted chain entry recompiles transparently mid-queue."""
    from repro.serve import LogicEngine
    from repro.serve.logic_engine import ProgramCache
    rng = np.random.default_rng(13)
    chain_a = [_layer(rng, 6, 40, 5), _layer(rng, 5, 30, 3)]
    chain_b = [_layer(rng, 6, 35, 4), _layer(rng, 4, 25, 2)]
    eng = LogicEngine(CompileSpec(n_unit=8), capacity=64,
                      cache=ProgramCache(max_entries=1))
    bits = _bits(rng, 40, 6)
    assert (eng.serve_chain(chain_a, bits)
            == _stack_eval(chain_a, bits)).all()
    assert (eng.serve_chain(chain_b, bits)
            == _stack_eval(chain_b, bits)).all()     # evicts chain_a
    assert (eng.serve_chain(chain_a, bits)
            == _stack_eval(chain_a, bits)).all()     # recompiles
    assert eng.cache.compiles >= 3


def test_engine_serve_chain_validates_width():
    from repro.serve import LogicEngine
    rng = np.random.default_rng(14)
    eng = LogicEngine(CompileSpec(n_unit=8), capacity=64)
    g = _layer(rng, 6, 30, 4)
    with pytest.raises(ValueError):
        eng.serve_chain([g], _bits(rng, 10, 5))      # 5 bits vs 6 inputs
    with pytest.raises(ValueError):
        eng.submit_chain([], _bits(rng, 10, 6))      # empty stage list
    with pytest.raises(ValueError):
        eng.cache.get_chain([g], CompileSpec(n_unit="auto"))


# ---------------------------------------------------------------------------
# flow classifier megakernel backend
# ---------------------------------------------------------------------------

def test_classifier_megakernel_backend_matches_reference():
    from repro.flow.classifier import build_classifier
    from repro.flow.report import FlowConfig
    from repro.core.nullanet import BinaryMLPConfig, train_binary_mlp
    from repro.flow.classifier import input_bits
    cfg = FlowConfig(n_samples=400, train_steps=30, hidden=(6, 5))
    xt, yt, xv, _ = cfg.load_data()
    mcfg = BinaryMLPConfig(n_features=cfg.n_features, hidden=cfg.hidden,
                           n_classes=cfg.n_classes, seed=cfg.seed)
    params = train_binary_mlp(mcfg, xt, yt, steps=cfg.train_steps)
    params = {k: np.asarray(v) for k, v in params.items()}
    clf = build_classifier(params, len(cfg.hidden) + 1, xt, cfg.spec)
    bits = input_bits(xv)
    ref = clf.hidden_bits(bits, backend="reference")
    got = clf.hidden_bits(bits, backend="megakernel")
    assert (got == ref).all()
    assert clf.megaprogram.n_stages == len(clf.layers)
