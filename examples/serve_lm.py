"""Batched serving demo: continuous batching over the decode engine.

    PYTHONPATH=src python examples/serve_lm.py

Thin wrapper over launch/serve.py defaults so `examples/` has a runnable
serving scenario next to the training one.
"""
import sys

from repro.launch import serve

if __name__ == "__main__":
    sys.argv = [sys.argv[0], "--arch", "qwen3-8b", "--smoke",
                "--requests", "8", "--batch-size", "4",
                "--prompt-len", "12", "--max-new", "6"] + sys.argv[1:]
    serve.main()
