"""Quickstart: compile a Boolean netlist onto the time-shared logic fabric.

    PYTHONPATH=src python examples/quickstart.py

Walks the paper's full §4/§6 flow on a small Verilog module: parse ->
logic synthesis -> levelize -> sub-kernel scheduling -> execution on the
Pallas "DSP fabric" kernel, validated against direct DAG evaluation, plus
the analytical cost model's view of the schedule.
"""
import numpy as np

from repro.core.cost_model import CostModel, FfclStats
from repro.core.levelize import levelize
from repro.core.opt import PassManager
from repro.core.scheduler import compile_graph
from repro.core.spec import CompileSpec
from repro.core.verilog import parse_verilog
from repro.kernels.logic_dsp import logic_infer_bits

VERILOG = """
module majority5_and_parity(a, b, c, d, e, maj, par);
  input a, b, c, d, e;
  output maj, par;
  wire ab, ac, ad, ae, bc, bd, be, cd, ce, de;
  and g0 (ab, a, b);  and g1 (ac, a, c);  and g2 (ad, a, d);
  and g3 (ae, a, e);  and g4 (bc, b, c);  and g5 (bd, b, d);
  and g6 (be, b, e);  and g7 (cd, c, d);  and g8 (ce, c, e);
  and g9 (de, d, e);
  // majority-of-5 = OR of all 3-subsets; factored via pair terms
  assign maj = (ab & (c | d | e)) | (ac & (d | e)) | (ad & e)
             | (bc & (d | e)) | (bd & e) | (cd & e);
  assign par = a ^ b ^ c ^ d ^ e;
endmodule
"""


def main() -> None:
    graph = parse_verilog(VERILOG)
    print(f"parsed: {graph.stats()}")
    res = PassManager.default().run(graph)   # pass-based optimization
    graph = res.graph
    lv = levelize(graph)
    print(f"synthesized ({res.iterations} pipeline iters): {graph.stats()}  "
          f"level histogram={list(lv.histogram())}")

    # the declarative compilation target (core/spec.py): optimize="none"
    # because the pass pipeline already ran above
    spec = CompileSpec(n_unit=4, alloc="liveness", optimize="none")
    prog = compile_graph(graph, spec)
    print(f"scheduled on {spec.n_unit} units: {prog.n_steps} sub-kernel "
          f"steps, {prog.n_addr} buffer rows (paper eq. 23)")

    rng = np.random.default_rng(0)
    x = rng.integers(0, 2, (1000, 5)).astype(bool)
    got = logic_infer_bits(prog, x)          # Pallas kernel (interpret)
    want = graph.evaluate(x)
    assert (got == want).all()
    maj = x.sum(axis=1) >= 3
    par = x.sum(axis=1) % 2 == 1
    assert (got[:, 0] == maj).all() and (got[:, 1] == par).all()
    print("kernel output == direct evaluation == ground truth  [1000 vectors]")

    model = CostModel()
    b = model.breakdown(FfclStats.from_graph(graph), spec.n_unit, 1000)
    print(f"cost model: {b.n_total_pipelined:.0f} cycles "
          f"(dm={b.n_data_moves:.0f}, compute={b.n_compute:.0f}, "
          f"bound={b.bound})")


if __name__ == "__main__":
    main()
