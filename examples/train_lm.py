"""End-to-end LM training driver: ~100M-param model, few hundred steps.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

Exercises the full distributed trainer stack on the host mesh: sharded
train step (FSDP x TP rules degrade gracefully to 1 device), WSD schedule,
gradient accumulation, async checkpointing + auto-resume, straggler
monitor, and the stateless-seekable data pipeline.
"""
import argparse
import shutil

import jax

from repro.configs import get_config
from repro.launch.mesh import make_host_mesh
from repro.train import TrainConfig, Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--resume", action="store_true",
                    help="keep checkpoint dir (demonstrates auto-resume)")
    args = ap.parse_args()

    # ~100M params: qwen3-style block at width 512
    cfg = get_config("qwen3-8b").with_(
        name="qwen3-100m", n_layers=8, d_model=512, n_heads=8, n_kv_heads=4,
        head_dim=64, d_ff=1536, vocab_size=32000, remat="none",
        seq_parallel=False, param_dtype="float32", compute_dtype="float32")
    n_params = cfg.param_count()
    print(f"model: {cfg.name}, {n_params / 1e6:.0f}M params")

    ckpt_dir = "/tmp/repro_train_lm"
    if not args.resume:
        shutil.rmtree(ckpt_dir, ignore_errors=True)
    tc = TrainConfig(lr=6e-4, warmup_steps=30, total_steps=args.steps,
                     schedule="wsd", grad_accum=2,
                     checkpoint_dir=ckpt_dir, checkpoint_every=100)
    mesh = make_host_mesh()
    trainer = Trainer(cfg, tc, mesh, global_batch=8, seq_len=256)
    history = trainer.run(args.steps, log_every=25)
    first, last = history[0]["loss"], history[-1]["loss"]
    print(f"loss: {first:.3f} -> {last:.3f} over {len(history)} steps "
          f"({'improved' if last < first else 'NO IMPROVEMENT'})")


if __name__ == "__main__":
    main()
