"""Fleet warm start: a fresh engine process serves its first request
with zero compiles by loading compiled artifacts from a shared store.

Two-process flow (what CI's smoke test runs)::

    PYTHONPATH=src python tools/precompile.py   --store /tmp/logic-store
    PYTHONPATH=src python examples/warm_start.py --store /tmp/logic-store

The second command builds the *same* seeded workload (identical
generator arguments name identical graphs — see tools/precompile.py),
boots a brand-new :class:`~repro.serve.LogicEngine` pointed at the
store, serves every graph bit-exactly, and asserts **compiles == 0**
via the cache counters — proof the fleet warm-started from disk rather
than re-deriving the schedules.

Run without ``--store`` for a self-contained demo: phase one plays the
cold node (compile + write-through), phase two plays the warm node
(store hit), with the cold/warm timings printed side by side.
"""
import argparse
import sys
import tempfile
import time

import numpy as np

from repro.core.gate_ir import LogicGraph, random_graph
from repro.core.spec import CompileSpec
from repro.serve import ArtifactStore, LogicEngine


def build_graphs(seed: int, count: int, n_inputs: int, n_gates: int,
                 n_outputs: int, locality: int) -> list[LogicGraph]:
    # Must match tools/precompile.py byte for byte: same arguments,
    # same graphs, same store keys.
    rng = np.random.default_rng(seed)
    return [random_graph(rng, n_inputs, n_gates, n_outputs,
                         locality=locality) for _ in range(count)]


def serve_all(engine: LogicEngine, graphs: list[LogicGraph],
              rng: np.random.Generator) -> float:
    t0 = time.perf_counter()
    for g in graphs:
        bits = rng.integers(0, 2, (64, g.n_inputs)).astype(bool)
        out = engine.serve(g, bits)
        assert (out == g.evaluate(bits)).all(), "served wrong bits"
    return time.perf_counter() - t0


def parse_n_unit(v: str):
    return "auto" if v == "auto" else int(v)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n\n")[0])
    ap.add_argument("--store", metavar="DIR", default=None,
                    help="store populated by tools/precompile.py; "
                         "omitted = self-contained two-phase demo")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--count", type=int, default=1)
    ap.add_argument("--inputs", type=int, default=16)
    ap.add_argument("--gates", type=int, default=800)
    ap.add_argument("--outputs", type=int, default=8)
    ap.add_argument("--locality", type=int, default=64)
    ap.add_argument("--n-unit", type=parse_n_unit, default=32,
                    metavar="N|auto")
    ap.add_argument("--alloc", choices=("direct", "liveness"),
                    default="liveness")
    ap.add_argument("--optimize", choices=("default", "none"),
                    default="default")
    ap.add_argument("--max-gates", type=int, default=None)
    args = ap.parse_args(argv)

    spec = CompileSpec(n_unit=args.n_unit, alloc=args.alloc,
                       optimize=args.optimize, max_gates=args.max_gates)
    graphs = build_graphs(args.seed, args.count, args.inputs, args.gates,
                          args.outputs, args.locality)
    rng = np.random.default_rng(args.seed + 2)

    tmp = None
    if args.store is None:
        tmp = tempfile.TemporaryDirectory(prefix="warm-start-")
        store = ArtifactStore(tmp.name)
        # Phase 1 — the cold node: compiles, then writes through to the
        # shared store so the rest of the fleet never has to.
        cold = LogicEngine(spec, capacity=128, store=store)
        cold_s = serve_all(cold, graphs, np.random.default_rng(args.seed + 2))
        cs = cold.cache.stats()
        assert cs["compiles"] == len(graphs) and cs["store_saves"] == len(graphs)
        print(f"cold node: {cs['compiles']} compiles, "
              f"{cs['store_saves']} artifacts published, "
              f"{cold_s * 1e3:.1f} ms  [bit-exact]")
    else:
        store = ArtifactStore(args.store)
        cold_s = None
        if store.stats()["entries"] == 0:
            print(f"store {args.store} is empty — run tools/precompile.py "
                  f"with the same workload arguments first", file=sys.stderr)
            return 1

    # Phase 2 — the warm node: a brand-new engine (fresh process when
    # --store is used) whose first request must not compile anything.
    warm = LogicEngine(spec, capacity=128, store=store)
    warm_s = serve_all(warm, graphs, rng)
    ws = warm.cache.stats()
    assert ws["compiles"] == 0, f"warm node compiled: {ws}"
    assert ws["store_hits"] == len(graphs), f"expected all store hits: {ws}"
    speed = f" ({cold_s / warm_s:.1f}x vs cold)" if cold_s else ""
    print(f"warm node: 0 compiles, {ws['store_hits']} store hits, "
          f"{warm_s * 1e3:.1f} ms{speed}  [bit-exact]")
    print("warm-start OK:", {k: ws[k] for k in
                             ("compiles", "store_hits", "store_misses",
                              "store_failures")})
    if tmp is not None:
        tmp.cleanup()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
