"""End-to-end NullaNet classifier (paper §7-§8): train -> FFCL -> serve -> acc.

    PYTHONPATH=src python examples/e2e_nullanet.py [--quick] [--json PATH]

The whole paper loop as one artifact (flow/):

1. Trains a binarized MLP AND a float (ReLU) upper-bound MLP on a
   synthetic classification task (MNIST stand-in; datasets are
   offline-unavailable).
2. Converts EVERY hidden layer to fixed-function combinational logic
   through the single flow conversion path (ISF/enumeration -> espresso ->
   gate factoring -> synth -> sub-kernel scheduling).
3. Executes the chained logic stack — input binarization, packed-word
   layer handoff, numeric argmax head — through all three backends:
   jnp reference, Pallas fabric kernel (interpret), and batched
   LogicEngine serving of the composed hidden-stack graph.
4. Reports accuracy parity (float / binarized / logic), per-layer gate &
   step counts, and the pipelined-simulator cycle estimate.

With the default configuration every layer fanin admits full input
enumeration, so the logic computes exactly the binarized model's function:
the script *asserts* logic acc == binarized acc and bit-identical hidden
activations across backends.
"""
import argparse
import json

from repro.core.spec import CompileSpec
from repro.flow import FlowConfig, run_flow


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--quick", action="store_true",
                    help="smaller task + fewer train steps (~8s)")
    ap.add_argument("--features", type=int, default=12)
    ap.add_argument("--hidden", default="10,8",
                    help="comma-separated hidden widths")
    ap.add_argument("--classes", type=int, default=4)
    ap.add_argument("--samples", type=int, default=None,
                    help="default 4000 (1500 with --quick)")
    ap.add_argument("--train-steps", type=int, default=None,
                    help="default 300 (120 with --quick)")
    ap.add_argument("--n-unit", default="32",
                    help="compute units, or 'auto' for the paper §7.2 "
                         "design-space search per layer (CompileSpec)")
    ap.add_argument("--alloc", choices=("direct", "liveness"),
                    default="liveness")
    ap.add_argument("--mode", choices=("auto", "enum", "isf"), default="auto")
    ap.add_argument("--optimize", choices=("default", "none"),
                    default="default",
                    help="gate-level pass pipeline (core/opt.py); 'none' "
                         "keeps raw espresso factoring for A/B comparison")
    ap.add_argument("--max-gates", type=int, default=None,
                    help="engine partition budget (pipelined sub-programs)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the report as JSON")
    args = ap.parse_args()

    hidden = tuple(int(h) for h in args.hidden.split(",") if h)
    quick_default = lambda given, quick, full: \
        given if given is not None else (quick if args.quick else full)
    spec = CompileSpec(
        n_unit="auto" if args.n_unit == "auto" else int(args.n_unit),
        alloc=args.alloc, optimize=args.optimize, max_gates=args.max_gates)
    cfg = FlowConfig(
        n_features=args.features, hidden=hidden, n_classes=args.classes,
        n_samples=quick_default(args.samples, 1500, 4000),
        train_steps=quick_default(args.train_steps, 120, 300),
        spec=spec, mode=args.mode)
    print(f"compilation target: {spec.to_dict()}")

    report, _ = run_flow(cfg, log_every=0 if args.quick else 100)
    print(report.summary())

    assert report.bit_identical, \
        "backends disagree bit-for-bit — conformance bug"
    if cfg.exact:
        assert report.parity, (
            "exact-mode conversion must preserve accuracy exactly: "
            f"logic {report.logic_acc} vs binarized {report.binarized_acc}")
        print("[ok] exact accuracy parity + bit-identical backends")
    else:
        drop = report.binarized_acc - max(report.logic_acc.values())
        print(f"[ok] ISF mode: acc drop {drop:+.4f} "
              "(paper reports <4% drops); backends bit-identical")

    if args.json:
        with open(args.json, "w") as f:
            json.dump(report.to_dict(), f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
