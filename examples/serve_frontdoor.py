"""Serving front door demo: two tenants, deadlines, faults, traffic.

Stands up a :class:`~repro.serve.FrontDoor` over one shared
``LogicEngine``/``ProgramCache``, registers two tenant models, warms
the compile/jit caches, then drives a Poisson + heavy-tail (Pareto)
closed-loop trace with fault injection on (eviction storm + injected
dispatch delay) and prints the degradation report: p50/p99 latency,
goodput, shed rate by machine-readable reason, deadline-miss rate.

Run:  PYTHONPATH=src python examples/serve_frontdoor.py [--quick]
"""
import argparse
import asyncio
import json

import numpy as np

from repro.core.gate_ir import random_graph
from repro.core.spec import CompileSpec
from repro.serve import (FaultPolicy, FrontDoor, Priority, TrafficPattern,
                         build_trace, run_trace)


async def main(quick: bool) -> None:
    rng = np.random.default_rng(0)
    graph_a = random_graph(rng, 16, 300 if quick else 800, 10, locality=64)
    graph_b = random_graph(rng, 12, 200 if quick else 500, 8, locality=64)

    door = FrontDoor(spec=CompileSpec(n_unit=32), capacity=128,
                     max_queue=24, default_deadline_s=0.5,
                     fault_policy=FaultPolicy(seed=7, evict_rate=0.05,
                                              delay_rate=0.05,
                                              delay_s=0.003))
    door.register("vision", graph_a, max_inflight=8)
    door.register("ranking", graph_b, max_inflight=8)

    async with door:
        # warm the compile + jit caches AND the wave-time window (the
        # admission controller's service estimate) so the trace
        # measures serving, not cold starts
        for _ in range(5):
            for name, g in (("vision", graph_a), ("ranking", graph_b)):
                bits = rng.integers(0, 2, (48, g.n_inputs)).astype(bool)
                out = await door.submit(name, bits, deadline_s=30.0)
                assert (out == g.evaluate(bits)).all()
        door.reset_metrics()

        n = 60 if quick else 200
        trace = build_trace([
            TrafficPattern(tenant="vision", rate_rps=150.0, n_requests=n,
                           size_mean=40, deadline_s=0.25,
                           priority_mix=((Priority.HIGH, 0.2),
                                         (Priority.NORMAL, 0.8))),
            TrafficPattern(tenant="ranking", rate_rps=100.0, n_requests=n,
                           arrival="pareto", pareto_alpha=1.4,
                           size_mean=24, deadline_s=0.25,
                           priority_mix=((Priority.NORMAL, 0.5),
                                         (Priority.BATCH, 0.5))),
        ], seed=11)
        report = await run_trace(door, trace, seed=13)

    print(json.dumps(report.to_dict(), indent=2))
    m = door.metrics()
    print(f"door: retries={m['retries']} faults={m['faults_injected']} "
          f"wave_est_ms={m['wave_est_ms']:.2f}")
    assert report.completed + report.shed == report.offered, \
        "every offered request must resolve (complete or shed) — no hangs"
    print("ok: every request resolved (no hangs)")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    asyncio.run(main(args.quick))
