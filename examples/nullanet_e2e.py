"""End-to-end NullaNet driver (paper §7 + §8): train -> FFCL -> logic infer.

    PYTHONPATH=src python examples/nullanet_e2e.py

1. Trains a binarized MLP (~300 steps) on a synthetic classification task
   (MNIST stand-in; datasets are offline-unavailable).
2. Converts every hidden layer to fixed-function combinational logic via
   ISF extraction + two-level minimization + gate factoring.
3. Compiles each FFCL onto n_unit time-shared units and runs inference
   through the Pallas logic fabric — no weights, only bitwise programs.
4. Reports accuracy parity and the cost-model/simulator view, including
   the binary search over n_unit (paper Fig. 6).
"""
import time

import numpy as np

from repro.core.cost_model import CostModel, FfclStats
from repro.core.nullanet import (BinaryMLPConfig, mlp_accuracy,
                                 mlp_to_logic_network, train_binary_mlp)
from repro.core.optimizer import binary_search
from repro.core.scheduler import compile_graph
from repro.core.simulator import simulate_pipeline
from repro.data import make_binary_classification
from repro.kernels.logic_dsp import logic_infer_bits


def main() -> None:
    x, y = make_binary_classification(6000, 48, n_classes=6, noise=0.06)
    xt, yt, xv, yv = x[:5000], y[:5000], x[5000:], y[5000:]
    cfg = BinaryMLPConfig(n_features=48, hidden=(32, 24), n_classes=6)

    t0 = time.time()
    params = train_binary_mlp(cfg, xt, yt, steps=300, log_every=100)
    acc_mlp = mlp_accuracy(params, cfg, xv, yv)
    print(f"[1] binarized MLP: val acc {acc_mlp:.3f} ({time.time() - t0:.0f}s)")

    t0 = time.time()
    net = mlp_to_logic_network(params, cfg, xt, mode="isf")
    for i, g in enumerate(net.graphs):
        print(f"    layer {i}: {g.n_gates} gates, depth "
              f"{g.stats()['depth']}")
    print(f"[2] FFCL conversion done ({time.time() - t0:.0f}s)")

    n_unit = 32
    progs = [compile_graph(g, n_unit=n_unit, alloc="liveness")
             for g in net.graphs]
    print(f"[3] compiled on {n_unit} units: "
          f"{[p.n_steps for p in progs]} sub-kernel steps/layer")

    def kernel_exec(graph, bits):
        prog = next(p for p, g in zip(progs, net.graphs) if g is graph)
        return logic_infer_bits(prog, bits)

    acc_logic = (net.predict(xv, executor=kernel_exec) == yv).mean()
    print(f"[4] logic-fabric inference: val acc {acc_logic:.3f} "
          f"(drop {acc_mlp - acc_logic:+.3f}; paper reports <4% drops)")

    model = CostModel()
    layers = [(FfclStats.from_graph(g), 1, len(xv)) for g in net.graphs]
    res = binary_search(model, layers, n_unit_max=4096)
    sim = simulate_pipeline(progs, n_input_vectors=len(xv))
    print(f"[5] cost model: best n_unit={res.best_n_unit} "
          f"({res.best_cycles:.0f} cycles); simulator @ {n_unit} units: "
          f"{sim.total_cycles:.0f} cycles, bound={sim.bound}")


if __name__ == "__main__":
    main()
