"""Serving demo: continuous-batched inference over compiled logic programs.

    PYTHONPATH=src python examples/serve_logic.py

Spins up a :class:`~repro.serve.LogicEngine` and serves mixed traffic the
way a production front-end would (ROADMAP north star; paper §5.2.4):

  1. ragged bit-vector requests for one FFCL, slot-packed into single
     fabric invocations (32 samples/word x W words, core/packing.py);
  2. repeat traffic for a structurally identical graph — program-cache hit,
     no recompile;
  3. a graph over the partition budget, served as a pipelined sequence of
     sub-programs (core/partition.py) with word-level re-assembly.

Every response is checked bit-exact against direct DAG evaluation.
"""
import time

import numpy as np

from repro.core.gate_ir import random_graph
from repro.core.spec import CompileSpec
from repro.serve import LogicEngine


def main() -> None:
    rng = np.random.default_rng(0)
    engine = LogicEngine(CompileSpec(n_unit=64), capacity=256)
    print(f"engine: capacity={engine.capacity} samples/invocation, "
          f"n_unit={engine.n_unit}, devices={engine.stats()['n_devices']}")

    # -- 1. ragged traffic for one graph ------------------------------------
    g = random_graph(rng, 32, 1500, 16, locality=128)
    sizes = [97, 33, 64, 5, 180, 41, 12, 70]
    reqs = [(n, rng.integers(0, 2, (n, 32)).astype(bool)) for n in sizes]
    uids = [engine.submit(g, bits) for _, bits in reqs]
    t0 = time.perf_counter()
    engine.drain()
    dt = time.perf_counter() - t0
    for uid, (_, bits) in zip(uids, reqs):
        assert (engine.result(uid) == g.evaluate(bits)).all()
    n = sum(sizes)
    print(f"served {len(sizes)} ragged requests ({n} samples) in "
          f"{engine.invocations} invocations, {dt * 1e3:.1f} ms "
          f"({n / dt:.0f} samples/s)  [bit-exact]")

    # -- 2. repeat traffic: program-cache hit -------------------------------
    g_again = g.copy()
    g_again.name = "resubmitted-by-another-worker"
    x = rng.integers(0, 2, (50, 32)).astype(bool)
    t0 = time.perf_counter()
    out = engine.serve(g_again, x)
    assert (out == g.evaluate(x)).all()
    print(f"structural-copy request: cache hit, no recompile "
          f"({(time.perf_counter() - t0) * 1e3:.1f} ms; "
          f"hits={engine.cache.hits} misses={engine.cache.misses})")

    # -- 3. partitioned pipeline for an over-budget graph -------------------
    part_engine = LogicEngine(CompileSpec(n_unit=64, max_gates=600),
                              capacity=256, cache=engine.cache)
    big = random_graph(rng, 24, 2000, 24, locality=96)
    x = rng.integers(0, 2, (130, 24)).astype(bool)
    out = part_engine.serve(big, x)
    assert (out == big.evaluate(x)).all()
    # keyed on the POST-optimization fingerprint: fetch with the engine's
    # spec to get the entry it actually served
    entry = part_engine.cache.get(big, part_engine.spec)
    print(f"over-budget graph ({big.n_gates} gates) served as "
          f"{len(entry.programs)} pipelined sub-programs  [bit-exact]")

    print("stats:", engine.stats())


if __name__ == "__main__":
    main()
