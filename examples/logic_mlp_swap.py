"""The paper's technique inside an LM: FFCL-substituted FFN blocks.

    PYTHONPATH=src python examples/logic_mlp_swap.py

Trains a tiny transformer whose FFNs are *binarized* (NullaNet-compatible,
STE gradients), then converts each FFN's binary hidden map into a
fixed-function combinational logic program (ISF -> espresso -> gates ->
sub-kernel schedule) and serves the model through the logic fabric:
the FFN matmul w_in disappears — inference executes bitwise programs and
never touches those weights (paper §7.1).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.spec import CompileSpec
from repro.data.synthetic import TokenPipeline
from repro.models import logic_mlp
from repro.models.layers import rms_norm, softmax_xent
from repro.models.transformer import init_params
from repro.models import attention as attn
from repro.optim import adamw_init, adamw_update


def forward(params, cfg, tokens, ffn_fn):
    x = params["embed"].astype(jnp.float32)[tokens]
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    for i in range(cfg.n_layers):
        p = jax.tree.map(lambda a, i=i: a[i], params["blocks"])
        h = rms_norm(x, p["attn_norm"])
        x = x + attn.attention_forward(p, h, cfg, positions=positions)
        h = rms_norm(x, p["mlp_norm"])
        x = x + ffn_fn(i, p, h)
    x = rms_norm(x, params["final_norm"])
    return x @ params["lm_head"].astype(x.dtype)


def main() -> None:
    cfg = get_config("qwen3-8b", smoke=True).with_(
        n_layers=2, d_model=48, d_ff=24, n_heads=4, n_kv_heads=2,
        head_dim=12, vocab_size=256)
    params = init_params(cfg, jax.random.PRNGKey(0))
    # replace FFN params with binarized-FFN params
    L, d, f = cfg.n_layers, cfg.d_model, cfg.d_ff
    key = jax.random.PRNGKey(1)
    params["blocks"]["w_in"] = 0.5 * jax.random.normal(key, (L, d, f))
    params["blocks"]["b_in"] = jnp.zeros((L, f))
    params["blocks"]["w_out"] = 0.1 * jax.random.normal(key, (L, f, d))
    for k in ("w_gate", "w_up", "w_down"):
        params["blocks"].pop(k)

    def ste_ffn(i, p, h):
        return logic_mlp.binary_ffn(p, h)

    pipe = TokenPipeline(cfg.vocab_size, global_batch=8, seq_len=32, seed=0)

    def loss_fn(prm, tokens):
        logits = forward(prm, cfg, tokens, ste_ffn)
        return softmax_xent(logits[:, :-1].astype(jnp.float32),
                            tokens[:, 1:])

    opt = adamw_init(params)
    step_fn = jax.jit(lambda p, o, t: (
        lambda l, g: adamw_update(g, o, p, lr=2e-3) + (l,))(
        *jax.value_and_grad(loss_fn)(p, t)))
    for step in range(150):
        tokens = jnp.asarray(pipe.batch(step)["tokens"])
        params, opt, loss = step_fn(params, opt, tokens)
        if step % 50 == 0:
            print(f"step {step}: loss {float(loss):.4f}")

    # --- NullaNet conversion of each FFN ---
    # ISF density drives held-out fidelity (paper §7.1: the samples are a
    # tiny fraction of the 2^48 input space; more calibration -> better
    # don't-care assignments). Capture several batches.
    captured: dict[int, list] = {i: [] for i in range(cfg.n_layers)}

    def capture_ffn(i, p, h):
        captured[i].append(np.asarray((h >= 0).reshape(-1, h.shape[-1])))
        return logic_mlp.binary_ffn(p, h)

    for cb in range(8):
        forward(params, cfg, jnp.asarray(pipe.batch(900 + cb)["tokens"]),
                capture_ffn)
    calib_bits = [(i, np.concatenate(v)) for i, v in captured.items()]
    programs = {}
    for i, bits in calib_bits:
        p = jax.tree.map(lambda a, i=i: a[i], params["blocks"])
        programs[i] = logic_mlp.ffn_to_program(
            {"w_in": p["w_in"], "b_in": p["b_in"]}, bits,
            CompileSpec(n_unit=16), name=f"ffn{i}")
        print(f"layer {i}: FFCL program {programs[i].n_gates} gates, "
              f"{programs[i].n_steps} sub-kernel steps")

    # --- parity: STE forward vs logic-fabric forward ---
    def logic_ffn(i, p, h):
        return logic_mlp.logic_ffn_apply(programs[i], p, h)

    test = jnp.asarray(pipe.batch(1234)["tokens"])
    logits_ste = forward(params, cfg, test, ste_ffn)
    logits_logic = forward(params, cfg, test, logic_ffn)
    loss_ste = float(softmax_xent(logits_ste[:, :-1], test[:, 1:]))
    loss_logic = float(softmax_xent(logits_logic[:, :-1], test[:, 1:]))
    agree = float(jnp.mean(jnp.argmax(logits_ste, -1)
                           == jnp.argmax(logits_logic, -1)))
    print(f"loss: STE {loss_ste:.4f} vs logic-fabric {loss_logic:.4f}")
    print(f"next-token argmax agreement: {agree:.3f} "
          f"(ISF is exact on observed patterns; held-out patterns may "
          f"diverge, paper §7.1)")


if __name__ == "__main__":
    main()
