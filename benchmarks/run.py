"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Usage:
  PYTHONPATH=src python -m benchmarks.run [--quick] [--json PATH]

``--json`` additionally writes the rows as ``{name: {us, derived}}`` —
the machine-readable perf trajectory (``BENCH_logic.json``) that future
PRs diff against.  Every row that compiles a logic program also records
the serialized :class:`~repro.core.spec.CompileSpec` it compiled
against (``"spec"`` key), so the perf trajectory is attributable to an
exact compilation target.  The JSON also carries a ``bench_env`` header
block (host hash, cpu count, jax/jaxlib versions, interpret flag,
timestamp) so wall-clock rows are attributable to the machine that
produced them — schema in benchmarks/README.md.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from benchmarks import baselines, workloads
from repro.core.cost_model import CostModel, FfclStats, FpgaFabric, TpuFabric
from repro.core.gate_ir import random_graph
from repro.core.optimizer import binary_search, sweep
from repro.core.scheduler import compile_graph
from repro.core.simulator import simulate_no_pipeline, simulate_pipeline
from repro.core.spec import CompileSpec

ROWS: list[tuple[str, float, str, dict | None]] = []
CLOCK = TpuFabric().clock_hz


def row(name: str, us: float, derived: str = "",
        spec: CompileSpec | None = None) -> None:
    ROWS.append((name, us, derived,
                 None if spec is None else spec.to_dict()))
    print(f"{name},{us:.3f},{derived}")


def cycles_us(cycles: float) -> float:
    return cycles / CLOCK * 1e6


def timed(fn, reps: int, *, warmup: int = 1) -> float:
    """Mean seconds per call of ``fn`` over ``reps`` calls.

    The shared wall-clock discipline for every measured loop in this
    harness: ``warmup`` unwarmed calls run first (jit trace/compile and
    first-touch allocation excluded from the measurement), and every
    call — warmup included — is synchronized through
    ``jax.block_until_ready`` on its result, so jax's asynchronous
    dispatch can never under-report a row (numpy results pass through
    unchanged)."""
    import jax
    for _ in range(max(0, warmup)):
        jax.block_until_ready(fn())
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / reps


def bench_env() -> dict:
    """The ``bench_env`` header block: enough provenance to attribute a
    wall-clock row to the machine/backends that produced it, without
    leaking the hostname itself (hashed)."""
    import hashlib
    import os
    import socket

    import jax
    import jaxlib
    return {
        "host": hashlib.blake2b(socket.gethostname().encode(),
                                digest_size=4).hexdigest(),
        "cpu_count": os.cpu_count(),
        "jax": jax.__version__,
        "jaxlib": jaxlib.__version__,
        "interpret": True,      # the harness runs pallas interpret mode
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
    }


# ---------------------------------------------------------------------------
# Fig. 6: cost model vs "actual" (discrete-event simulator), layer conv7/8
# ---------------------------------------------------------------------------

def bench_cost_model_validation(quick: bool) -> None:
    wl = workloads.build_workload(
        [workloads.VGG16_LAYERS[6]], n_samples=96 if quick else 160)
    lw = wl[0]
    model = CostModel()
    m = 16 if quick else 64     # filters pipelined per launch
    errs = []
    for n_unit in (64, 256, 1024):
        # the workload graphs are pre-optimized (workloads.py), so the
        # compile target itself runs no pass pipeline
        spec = CompileSpec(n_unit=n_unit, optimize="none")
        prog = compile_graph(lw.graph, spec)
        sim = simulate_pipeline([prog] * m, n_input_vectors=lw.n_patches)
        # stats from the compiled program: with step fusion enabled the
        # model must charge the scheduled step count, not eq. 23's
        mdl = model.total_cycles(FfclStats.from_program(prog), n_unit,
                                 lw.n_patches, m_modules=m)
        err = (mdl - sim.total_cycles) / sim.total_cycles
        errs.append(abs(err))
        row(f"fig6.model_vs_sim.n{n_unit}", cycles_us(sim.total_cycles),
            f"model_err={err:+.1%}", spec=spec)
    row("fig6.max_abs_err", 0.0, f"{max(errs):.1%} (paper: <10%)")


# ---------------------------------------------------------------------------
# Fig. 7: latency split (data movement vs compute) across n_unit
# ---------------------------------------------------------------------------

def bench_latency_split(quick: bool) -> None:
    wl = workloads.build_workload(
        [workloads.VGG16_LAYERS[6]], n_samples=96 if quick else 160)
    lw = wl[0]
    model = CostModel()
    for n_unit in (16, 64, 256, 1024, 4096):
        b = model.breakdown(lw.stats, n_unit, lw.n_patches)
        share = b.n_data_moves / (b.n_data_moves + b.n_compute)
        row(f"fig7.split.n{n_unit}", cycles_us(b.n_total_pipelined),
            f"dm_share={share:.0%} bound={b.bound}")


# ---------------------------------------------------------------------------
# Fig. 6 / §8.1: U-shaped design space + binary search
# ---------------------------------------------------------------------------

def bench_pareto_search(quick: bool) -> None:
    wl = workloads.build_workload(
        workloads.VGG16_LAYERS[:4] if quick else workloads.VGG16_LAYERS,
        n_samples=96 if quick else 160)
    layers = workloads.cost_model_layers(wl)
    model = CostModel()
    grid = [2 ** k for k in range(2, 13)]
    swp = sweep(model, layers, grid)
    res = binary_search(model, layers, n_unit_max=4096)
    row("pareto.sweep_best", cycles_us(swp.best_cycles),
        f"n_unit={swp.best_n_unit}")
    row("pareto.binary_search", cycles_us(res.best_cycles),
        f"n_unit={res.best_n_unit} probes={len(res.evaluations)}")


# ---------------------------------------------------------------------------
# Figs. 9/10: MAC vs XNOR vs NullaDSP, VGG16 + LeNet-5
# ---------------------------------------------------------------------------

def bench_nn_e2e(quick: bool) -> None:
    """Figs. 9/10 on BOTH fabrics.

    fpga (paper-faithful constants): reproduces the paper's headline —
    NullaDSP at its Pareto-optimal unit count beats the (DDR-bound) MAC
    array at 1024 units (paper VGG16: 2.99 ms vs 5.72 ms ~ 1.9x); XNOR is
    fastest but least accurate.

    tpu (hardware adaptation): on an HBM-class memory system the MAC
    baseline is compute-bound and far stronger — the FFCL win shrinks.
    Recorded as a finding in DESIGN.md §2 / EXPERIMENTS.md §Perf.
    """
    for fab_name, fabric in (("fpga", FpgaFabric()), ("tpu", TpuFabric())):
        model = CostModel(fabric)
        for net, layer_spec in (("vgg16", workloads.VGG16_LAYERS),
                                ("lenet5", workloads.LENET5_LAYERS)):
            spec = layer_spec[:4] if (quick and net == "vgg16") else \
                layer_spec
            wl = workloads.build_workload(spec,
                                          n_samples=128 if quick else 400)
            cls = workloads.cost_model_layers(wl)
            us = 1e6 / fabric.clock_hz
            units = (140, 512) if net == "lenet5" else (1024, 4096)
            for n_unit in units:
                mac = baselines.mac_cycles(spec, n_unit, fabric)
                xnor = baselines.xnor_cycles(spec, n_unit, fabric)
                nd = baselines.nulladsp_cycles(cls, n_unit, model)
                row(f"fig9_10.{fab_name}.{net}.n{n_unit}.mac", mac * us, "")
                row(f"fig9_10.{fab_name}.{net}.n{n_unit}.xnor", xnor * us, "")
                row(f"fig9_10.{fab_name}.{net}.n{n_unit}.nulladsp", nd * us,
                    f"vs_mac={mac / nd:.2f}x")
            best = binary_search(model, cls, n_unit_max=4096)
            mac1024 = baselines.mac_cycles(spec, 1024, fabric)
            row(f"fig9_10.{fab_name}.{net}.pareto.nulladsp",
                best.best_cycles * us,
                f"n_unit={best.best_n_unit} "
                f"vs_mac1024={mac1024 / best.best_cycles:.2f}x")
            # eq. 25: k parallel compute kernels share the SAME unit budget
            # as the MAC baseline — the paper's headline configuration
            par_c, n_per, k = baselines.nulladsp_parallel_best(
                cls, 1024, model)
            row(f"fig9_10.{fab_name}.{net}.eq25.nulladsp", par_c * us,
                f"{k}x{n_per}u vs_mac1024={mac1024 / par_c:.2f}x"
                + (" (paper: ~1.9x vgg16)" if fab_name == "fpga" else ""))


# ---------------------------------------------------------------------------
# Table 4: resource utilization -> VMEM/HBM working sets per design size
# ---------------------------------------------------------------------------

def bench_resources(quick: bool) -> None:
    wl = workloads.build_workload(
        [workloads.VGG16_LAYERS[6]], n_samples=96 if quick else 160)
    lw = wl[0]
    w_words = -(-lw.n_patches // 32)
    for label, n_unit in (("large", 1000), ("medium", 250), ("small", 180),
                          ("tiny", 100)):
        spec = CompileSpec(n_unit=n_unit, alloc="liveness", optimize="none")
        prog = compile_graph(lw.graph, spec)
        data_buf = prog.n_addr * w_words * 4
        streams = prog.n_steps * prog.n_unit * (3 * 4 + 1)
        row(f"table4.{label}.n{n_unit}", 0.0,
            f"vmem_data={data_buf / 2 ** 10:.0f}KiB "
            f"streams={streams / 2 ** 10:.0f}KiB steps={prog.n_steps}",
            spec=spec)


# ---------------------------------------------------------------------------
# kernel micro-benchmarks (wall-clock; interpret mode on CPU)
# ---------------------------------------------------------------------------

def bench_kernels(quick: bool) -> None:
    import jax.numpy as jnp

    from repro.kernels.logic_dsp import logic_infer_bits
    from repro.kernels.xnor_gemm import xnor_gemm

    rng = np.random.default_rng(0)
    g = random_graph(rng, 32, 1500, 16, locality=128)
    # optimize="none" keeps the kernel row comparable across snapshots
    # (the measured program is exactly the 1500-gate random netlist)
    spec = CompileSpec(n_unit=64, alloc="liveness", optimize="none")
    prog = compile_graph(g, spec)
    X = rng.integers(0, 2, (4096, 32)).astype(bool)
    reps = 2 if quick else 5
    dt = timed(lambda: logic_infer_bits(prog, X), reps)
    row("kernel.logic_dsp.interp", dt * 1e6,
        f"gates={prog.n_gates} steps={prog.n_steps} batch=4096 "
        f"homog={prog.homogeneous.mean():.0%}", spec=spec)

    a = jnp.asarray(rng.integers(0, 2, (256, 2304)), jnp.uint8)
    b = jnp.asarray(rng.integers(0, 2, (256, 2304)), jnp.uint8)
    dt = timed(lambda: xnor_gemm(a, b), reps)
    row("kernel.xnor_gemm.interp", dt * 1e6, "m=n=256 k=2304")


# ---------------------------------------------------------------------------
# serving throughput: LogicEngine batched vs single-shot (serve/logic_engine)
# ---------------------------------------------------------------------------

def bench_serve_logic(quick: bool) -> None:
    from repro.serve import LogicEngine

    rng = np.random.default_rng(3)
    g = random_graph(rng, 32, 1200 if quick else 2000, 16, locality=128)
    sizes = ([48, 17, 96, 33, 62] if quick else
             [48, 17, 96, 33, 62, 130, 5, 81, 256, 44])
    reqs = [rng.integers(0, 2, (n, 32)).astype(bool) for n in sizes]
    total = sum(sizes)
    # host-side wave overhead is ~ms-scale: more reps than the kernel
    # benches to keep the serving rows stable on small containers
    reps = 5 if quick else 10

    # batched: slot-packed requests share fabric invocations
    spec = CompileSpec(n_unit=64)
    eng = LogicEngine(spec, capacity=256)

    def wave(engine):
        uids = [engine.submit(g, bits) for bits in reqs]
        engine.drain()
        return [engine.result(uid) for uid in uids]

    wave(eng)                                  # compile + jit warmup
    eng.reset_telemetry()       # occupancy of the timed waves only
    dt = timed(lambda: wave(eng), reps, warmup=0)
    st = eng.stats()
    row("serve.logic_dsp.batched", dt * 1e6,
        f"samples_per_s={total / dt:.0f} reqs={len(sizes)} "
        f"occ={st['mean_occupancy']:.0%}", spec=spec)

    # single-shot baseline: one fabric invocation per request (per-shape
    # jits warmed; same optimized netlist as the engine serves, so the
    # gap left is the engine's batching amortization)
    from repro.kernels.logic_dsp import logic_infer_bits
    prog = compile_graph(g, spec)
    dt_single = timed(
        lambda: [logic_infer_bits(prog, bits) for bits in reqs], reps)
    row("serve.logic_dsp.single_shot", dt_single * 1e6,
        f"samples_per_s={total / dt_single:.0f} "
        f"vs_batched={dt_single / dt:.2f}x", spec=spec)

    # program-cache effect: structurally equal resubmission vs cold compile
    fresh = LogicEngine(spec, capacity=256)
    probe = reqs[0]
    t0 = time.perf_counter()
    fresh.serve(g, probe)                              # compile + trace
    cold = time.perf_counter() - t0
    g2 = g.copy()
    g2.name = "resubmitted"
    t0 = time.perf_counter()
    fresh.serve(g2, probe)                             # registry hit
    warm = time.perf_counter() - t0
    row("serve.logic_dsp.program_cache", warm * 1e6,
        f"cold_us={cold * 1e6:.0f} speedup={cold / max(warm, 1e-9):.0f}x "
        f"hits={fresh.cache.hits} misses={fresh.cache.misses}", spec=spec)

    # partitioned pipeline serving (multi-FFCL task pipelining)
    pspec = spec.with_(max_gates=400 if quick else 700)
    peng = LogicEngine(pspec, capacity=256)
    wave(peng)
    peng.reset_telemetry()
    dt_part = timed(lambda: wave(peng), reps, warmup=0)
    n_parts = len(peng.cache.get(g, peng.spec).programs)
    row("serve.logic_dsp.partitioned", dt_part * 1e6,
        f"programs={n_parts} samples_per_s={total / dt_part:.0f} "
        f"vs_mono={dt_part / dt:.2f}x", spec=pspec)


# ---------------------------------------------------------------------------
# fleet warm start: cold compile vs artifact-store load vs in-memory hit
# ---------------------------------------------------------------------------

def bench_warm_start(quick: bool) -> None:
    """``serve.warm_start.*`` rows: what the artifact store buys a fresh
    serving process.  Three ``ProgramCache.get`` latencies for the SAME
    (graph, spec): a cold cache with no store (full compile), a cold
    cache over a populated store (verified load), and a warm in-memory
    repeat (registry hit).  Counter-pinned — the store row asserts zero
    compiles — so a silent fallback-to-compile can never masquerade as
    a fast load.  Schema in benchmarks/README.md."""
    import tempfile

    from repro.core.artifact_store import ArtifactStore
    from repro.serve import ProgramCache

    rng = np.random.default_rng(9)
    g = random_graph(rng, 32, 1200 if quick else 3000, 16, locality=128)
    spec = CompileSpec(n_unit=64)
    reps = 3 if quick else 5

    def timed_get(cache):
        t0 = time.perf_counter()
        cache.get(g, spec)
        return time.perf_counter() - t0

    cold = min(timed_get(ProgramCache()) for _ in range(reps))

    with tempfile.TemporaryDirectory(prefix="bench-warm-") as root:
        ProgramCache(store=ArtifactStore(root)).get(g, spec)   # publish
        loads, warm_cache = [], None
        for _ in range(reps):
            warm_cache = ProgramCache(store=ArtifactStore(root))
            loads.append(timed_get(warm_cache))
        load = min(loads)
        st = warm_cache.stats()
        assert st["compiles"] == 0 and st["store_hits"] == 1, st
        hit = min(timed_get(warm_cache) for _ in range(reps))

    row("serve.warm_start.cold_compile", cold * 1e6,
        f"gates={g.n_gates}", spec=spec)
    row("serve.warm_start.store_load", load * 1e6,
        f"vs_cold={cold / max(load, 1e-9):.1f}x compiles=0 store_hits=1",
        spec=spec)
    row("serve.warm_start.memory_hit", hit * 1e6,
        f"vs_cold={cold / max(hit, 1e-9):.0f}x", spec=spec)


# ---------------------------------------------------------------------------
# static schedule verifier: proof overhead vs the compile it certifies
# ---------------------------------------------------------------------------

def bench_verify(quick: bool) -> None:
    """``verify.overhead.*`` / ``verify.load.*`` rows (DESIGN.md §13):
    what the static schedule verifier costs, gated in-bench.

      * ``verify.overhead.<case>``: added wall-clock of compiling with
        ``verify="compile"`` over the same compile with the verifier
        off — the price of turning the knob on.  Asserted ``<= 25%`` of
        the unverified compile for both the monolithic and the
        partitioned case (the partitioned proof reuses the clusters the
        compiler just derived, so it does not re-pay partitioning);
      * ``verify.load.<case>``: standalone ``verify_artifact`` on the
        finished artifact — the store-load / CLI audit path.  For
        partitioned artifacts this INCLUDES the deterministic partition
        re-derivation (the load path's trust anchor), so it is
        reported, not gated against the compile.

    Every timed proof is also asserted clean (zero diagnostics).
    Schema in benchmarks/README.md."""
    from repro.core.compiler import LogicCompiler
    from repro.core.verify import verify_artifact

    rng = np.random.default_rng(13)
    g = random_graph(rng, 24, 1500 if quick else 4000, 12, locality=96)
    reps = 3 if quick else 5
    comp = LogicCompiler()
    cases = [("mono", CompileSpec(n_unit=64)),
             ("partitioned", CompileSpec(
                 n_unit=64, max_gates=400 if quick else 1000))]
    def once(fn):
        t0 = time.perf_counter()
        fn()
        return time.perf_counter() - t0

    for label, spec in cases:
        # interleaved off/on pairs with one unmeasured warmup pair, min
        # per side: common-mode host noise (the surrounding harness is
        # busy) cancels instead of landing entirely on one variant
        comp.compile(g, spec)
        comp.compile(g, spec.with_(verify="compile"))
        off, on = [], []
        for _ in range(reps):
            off.append(once(lambda: comp.compile(g, spec)))
            on.append(once(lambda: comp.compile(
                g, spec.with_(verify="compile"))))
        off, on = min(off), min(on)
        overhead = max(on - off, 0.0)
        ratio = overhead / max(off, 1e-9)
        assert ratio <= 0.25, \
            f"{label}: verify overhead {ratio:.1%} exceeds the 25% gate"
        art = comp.compile(g, spec)
        t_load, report = None, None
        for _ in range(reps):
            t0 = time.perf_counter()
            report = verify_artifact(art)
            dt = time.perf_counter() - t0
            t_load = dt if t_load is None else min(t_load, dt)
        assert report.ok, report.summary()
        row(f"verify.overhead.{label}", overhead * 1e6,
            f"ratio={ratio:.3f} compile_us={off * 1e6:.0f} "
            f"programs={len(art.programs)} diagnostics=0 gate<=0.25",
            spec=spec)
        row(f"verify.load.{label}", t_load * 1e6,
            f"steps={report.checked['steps']} "
            f"terms={report.checked['terms']} diagnostics=0", spec=spec)


# ---------------------------------------------------------------------------
# wall-clock calibration: phase fit quality + objective="wallclock" DSE
# ---------------------------------------------------------------------------

def bench_calibration(quick: bool) -> None:
    """``calib.*`` / ``dse.wallclock.*`` rows (DESIGN.md §12): fit the
    per-phase wall-clock model on the seeded probe grid and gate it
    in-bench —

      * ``calib.fit.<phase>``: fitted coefficients/offset per phase;
      * ``calib.err.<phase>``: median |pred-measured|/measured of the
        fit, ASSERTED <= 25% per phase;
      * ``dse.wallclock.<workload>``: the n_unit the calibrated
        ``objective="wallclock"`` auto-search picks, with its MEASURED
        fused-path latency vs the measured best over the exhaustive
        probe-unit sweep — ASSERTED within 10%.

    Gates live here (not only in tests) so a perf snapshot that shipped
    with a drifted calibration is impossible: the harness itself fails.
    """
    from repro.core import calibrate
    from repro.core.compiler import LogicCompiler
    from repro.core.cost_model import n_subkernels

    reps = 5 if quick else 7
    graphs = calibrate.default_probe_graphs(quick=quick)
    units = calibrate.default_probe_units(quick=quick)
    probes = calibrate.collect_probes(graphs, units, reps=reps)
    cal = calibrate.fit_calibration(probes, meta={
        "grid": "quick" if quick else "full", "reps": reps})

    for phase in calibrate.PHASES:
        f = cal.fits[phase]
        coefs = " ".join(f"{c:.3e}" for c in f.coefs)
        row(f"calib.fit.{phase}", f.offset * 1e6,
            f"coefs=[{coefs}] probes={f.n_probes}")
        err = f.median_abs_rel_err
        assert err <= 0.25, \
            f"calibration phase {phase!r} median error {err:.1%} > 25%"
        row(f"calib.err.{phase}", 0.0, f"median_abs_rel_err={err:.1%}")

    # the DSE gate: per calibration workload, the wallclock-objective
    # auto pick's MEASURED latency must be within 10% of the measured
    # best over the exhaustive probe-unit sweep (the same grid the fit
    # saw; the compiler is clamped to its range so the search and the
    # sweep explore the same design space).  Two measurement passes,
    # both round-robin interleaved (sequential per-candidate loops let
    # host drift swamp the ~10% differences this gate resolves):
    # first the sweep locates the apparently-best candidate, then the
    # pick and that candidate are RE-measured head to head — a min over
    # many noisy candidates is biased low (extreme-value selection), so
    # gating against the sweep's raw min would fail even a perfect pick
    # on a flat design space.
    from repro.kernels.logic_dsp.ops import phased_infer_bits
    compiler = LogicCompiler(calibration=cal, n_unit_min=min(units),
                             n_unit_max=max(units))
    rng = np.random.default_rng(0)

    def roundrobin(progs, bits, n_rounds):
        best = {u: float("inf") for u in progs}
        for p in progs.values():                          # warm traces
            phased_infer_bits(p, bits)
        for _ in range(n_rounds):
            for u, p in progs.items():
                _, phases = phased_infer_bits(p, bits)
                best[u] = min(best[u], sum(phases.values()))
        return best

    def duel(p_pick, p_best, bits, n_rounds):
        """Median of per-round PAIRED pick/best latency ratios (plus
        the pick's median seconds).  Pairing inside each round cancels
        the sustained host-load shifts that an unpaired min-over-rounds
        comparison is still exposed to."""
        ratios, t_picks = [], []
        for _ in range(n_rounds):
            _, ph_a = phased_infer_bits(p_pick, bits)
            _, ph_b = phased_infer_bits(p_best, bits)
            t_picks.append(sum(ph_a.values()))
            ratios.append(t_picks[-1] / sum(ph_b.values()))
        return float(np.median(ratios)), float(np.median(t_picks))

    for label, g in graphs.items():
        spec, search = compiler.resolve(
            g, CompileSpec(n_unit="auto", objective="wallclock",
                           optimize="none"))
        pick = spec.n_unit
        progs = {u: compile_graph(g, CompileSpec(n_unit=u,
                                                 optimize="none"))
                 for u in sorted(set(units) | {pick})}
        bits = rng.integers(0, 2, (1024, g.n_inputs)).astype(bool)
        sweep = roundrobin(progs, bits, reps)
        sweep_best = min(sweep, key=sweep.get)
        if pick == sweep_best:
            ratio, t_pick = 1.0, sweep[pick]
        else:
            ratio, t_pick = duel(progs[pick], progs[sweep_best], bits,
                                 3 * reps)
        stats = FfclStats.from_graph(g)
        row(f"dse.wallclock.{label}", t_pick * 1e6,
            f"n_unit={pick} vs_sweep_best={ratio:.3f}x "
            f"sweep_best_n={sweep_best} "
            f"cycles_pick={search.alt.best_n_unit} "
            f"nsk={n_subkernels(stats, pick)}", spec=spec)
        assert ratio <= 1.10, \
            (f"wallclock pick n_unit={pick} measured {ratio:.2f}x the "
             f"sweep best (n_unit={sweep_best}) on {label} (> 1.10x)")


# ---------------------------------------------------------------------------
# serving front door under load: admission, deadlines, shedding (serve/)
# ---------------------------------------------------------------------------

def bench_serve_traffic(quick: bool) -> None:
    """``serve.traffic.*`` rows: the front door driven closed-loop by a
    two-tenant Poisson + heavy-tail (Pareto) trace.  ``us`` on the
    latency rows is the percentile itself; shed/deadline-miss rows are
    ``derived``-only rates.  Schema in benchmarks/README.md."""
    import asyncio

    from repro.serve import (FrontDoor, Priority, TrafficPattern,
                             build_trace, run_trace)

    rng = np.random.default_rng(5)
    g_a = random_graph(rng, 16, 300 if quick else 800, 10, locality=64)
    g_b = random_graph(rng, 12, 200 if quick else 500, 8, locality=64)
    spec = CompileSpec(n_unit=32)
    n = 60 if quick else 200
    trace = build_trace([
        TrafficPattern(tenant="vision", rate_rps=150.0, n_requests=n,
                       size_mean=40, deadline_s=0.5,
                       priority_mix=((Priority.HIGH, 0.2),
                                     (Priority.NORMAL, 0.8))),
        TrafficPattern(tenant="ranking", rate_rps=100.0, n_requests=n,
                       arrival="pareto", pareto_alpha=1.4,
                       size_mean=24, deadline_s=0.5,
                       priority_mix=((Priority.NORMAL, 0.5),
                                     (Priority.BATCH, 0.5))),
    ], seed=11)

    async def drive():
        door = FrontDoor(spec=spec, capacity=128, max_queue=24,
                         default_deadline_s=0.5)
        door.register("vision", g_a, max_inflight=8)
        door.register("ranking", g_b, max_inflight=8)
        async with door:
            # warm compile/jit caches and the admission controller's
            # wave-time window so the trace measures serving, not cold
            # starts
            for _ in range(5):
                for name, g in (("vision", g_a), ("ranking", g_b)):
                    bits = rng.integers(0, 2, (48, g.n_inputs)).astype(bool)
                    await door.submit(name, bits, deadline_s=30.0)
            door.reset_metrics()
            report = await run_trace(door, trace, seed=13)
        return report, door.metrics()

    report, m = asyncio.run(drive())
    sheds = " ".join(f"{k}={v}" for k, v in
                     sorted(report.shed_by_code.items()))
    row("serve.traffic.p50", report.p50_ms * 1e3 if report.p50_ms else 0.0,
        f"completed={report.completed} offered={report.offered}", spec=spec)
    row("serve.traffic.p99", report.p99_ms * 1e3 if report.p99_ms else 0.0,
        f"wave_est_ms={m['wave_est_ms']:.2f}", spec=spec)
    row("serve.traffic.goodput", 0.0,
        f"samples_per_s={report.goodput_sps:.0f} "
        f"elapsed_s={report.elapsed_s:.2f}", spec=spec)
    row("serve.traffic.shed_rate", 0.0,
        f"rate={report.shed_rate:.4f} shed={report.shed}"
        + (f" {sheds}" if sheds else ""), spec=spec)
    row("serve.traffic.deadline_miss", 0.0,
        f"rate={report.deadline_miss_rate:.4f} "
        f"missed={report.deadline_missed} retries={m['retries']}", spec=spec)


# ---------------------------------------------------------------------------
# end-to-end NullaNet classifier flow (flow/): train -> FFCL -> serve -> acc
# ---------------------------------------------------------------------------

def bench_flow_e2e(quick: bool) -> None:
    from repro.flow import FlowConfig, input_bits, run_flow
    from repro.serve import LogicEngine

    cfg = FlowConfig(n_features=10 if quick else 12,
                     hidden=(8, 6) if quick else (10, 8),
                     n_classes=4, n_samples=1200 if quick else 4000,
                     train_steps=120 if quick else 300,
                     spec=CompileSpec(n_unit=32))
    report, clf = run_flow(cfg)
    row("flow.e2e.convert", report.convert_s * 1e6,
        f"layers={len(report.layers)} gates={report.n_gates} "
        f"steps={report.n_steps}", spec=cfg.spec)
    row("flow.e2e.parity", 0.0,
        f"parity={'EXACT' if report.parity else 'approx'} "
        f"bit_identical={report.bit_identical} "
        f"logic_acc={report.logic_acc['pallas']:.4f} "
        f"binarized_acc={report.binarized_acc:.4f} "
        f"float_acc={report.float_acc:.4f}")
    row("flow.e2e.sim_cycles", cycles_us(report.sim_cycles),
        f"bound={report.sim_bound} n_vectors={report.n_val}")

    # warm per-backend inference wall-clock over the same val set the
    # reported accuracies used
    _, _, xv, _ = cfg.load_data()
    bits = input_bits(xv)
    engine = LogicEngine(cfg.spec, capacity=256)
    reps = 3 if quick else 5

    # single-launch pin (counter hook, not timing): a FRESH chain
    # megaprogram — its runner cache is empty, so this traces once — must
    # execute the whole hidden stack in exactly ONE pallas_call, and the
    # result must be bit-exact against the reference backend
    from repro.core.scheduler import build_megaprogram
    from repro.kernels.logic_dsp import kernel as _kern
    from repro.kernels.logic_dsp.ops import mega_infer_bits
    fresh_mega = build_megaprogram(clf.programs, mode="chain")
    before = _kern.launch_count()
    h_mega = mega_infer_bits(fresh_mega, bits)
    launches = _kern.launch_count() - before
    assert launches == 1, \
        f"megakernel took {launches} pallas_call launches, expected 1"
    h_ref = clf.hidden_bits(bits, backend="reference")
    assert (h_mega == h_ref).all(), "megakernel diverged from reference"

    for backend in ("reference", "pallas", "megakernel", "engine"):
        dt = timed(lambda b=backend: clf.hidden_bits(bits, backend=b,
                                                     engine=engine), reps)
        extra = " launches=1 parity=exact" if backend == "megakernel" else ""
        row(f"flow.e2e.{backend}", dt * 1e6,
            f"samples_per_s={len(bits) / dt:.0f} batch={len(bits)}{extra}",
            spec=cfg.spec)


# ---------------------------------------------------------------------------
# compiler wall-clock: vectorized stream emission (scheduler.compile_graph)
# ---------------------------------------------------------------------------

def bench_compile(quick: bool) -> None:
    # default ISF density (400): the same conv7 FFCL the full nn_e2e
    # benchmarks compile, a few hundred gates
    wl = workloads.build_workload([workloads.VGG16_LAYERS[6]])
    g = wl[0].graph
    reps = 20 if quick else 50
    # optimize="none": these rows time the SCHEDULER (levelize -> sort ->
    # fuse -> alloc -> emit), not the pass pipeline (opt.* rows time that)
    for alloc in ("direct", "liveness"):
        spec = CompileSpec(n_unit=256, alloc=alloc, optimize="none")
        compile_graph(g, spec)                             # warm caches
        t0 = time.perf_counter()
        for _ in range(reps):
            prog = compile_graph(g, spec)
        row(f"compile.vgg16_conv7.{alloc}",
            (time.perf_counter() - t0) / reps * 1e6,
            f"gates={g.n_gates} steps={prog.n_steps}", spec=spec)
    # VGG16-scale stress: tens of thousands of gates through the same path
    rng = np.random.default_rng(7)
    n_gates = 10_000 if quick else 30_000
    big = random_graph(rng, 64, n_gates, 32, locality=256)
    for alloc in ("direct", "liveness"):
        spec = CompileSpec(n_unit=256, alloc=alloc, optimize="none")
        t0 = time.perf_counter()
        prog = compile_graph(big, spec)
        row(f"compile.random{n_gates // 1000}k.{alloc}",
            (time.perf_counter() - t0) * 1e6,
            f"gates={big.n_gates} steps={prog.n_steps}", spec=spec)


# ---------------------------------------------------------------------------
# gate-level optimization pipeline (core/opt.py): gate/step/compile deltas
# ---------------------------------------------------------------------------

def bench_opt(quick: bool) -> None:
    """``opt.*`` rows: what the default pass pipeline buys versus raw
    synthesis on (a) the e2e NullaNet workload and (b) a random-graph
    stress case — gate count, scheduled steps, and compile wall-clock.
    ``us`` is the pass-pipeline wall-clock itself (the price paid once
    per distinct structure; the serving registry memoizes it)."""
    from repro.core.nullanet import (BinaryMLPConfig, train_binary_mlp)
    from repro.core.opt import PassManager
    from repro.flow import FlowConfig, hard_forward, input_bits
    from repro.flow.convert import layer_graph

    def ab_rows(tag: str, raw_graphs: list, n_unit: int) -> None:
        pm = PassManager.default()
        spec = CompileSpec(n_unit=n_unit, alloc="liveness", optimize="none")
        t0 = time.perf_counter()
        opt_graphs = [pm.run(g).graph for g in raw_graphs]
        opt_us = (time.perf_counter() - t0) * 1e6
        g_raw = sum(g.n_gates for g in raw_graphs)
        g_opt = sum(g.n_gates for g in opt_graphs)
        row(f"opt.{tag}.gates", opt_us,
            f"raw={g_raw} opt={g_opt} ({(g_opt - g_raw) / g_raw:+.0%})")
        t0 = time.perf_counter()
        s_raw = sum(compile_graph(g, spec).n_steps for g in raw_graphs)
        raw_c = (time.perf_counter() - t0) * 1e6
        t0 = time.perf_counter()
        s_opt = sum(compile_graph(g, spec).n_steps for g in opt_graphs)
        opt_c = (time.perf_counter() - t0) * 1e6
        row(f"opt.{tag}.steps", opt_c,
            f"raw={s_raw} opt={s_opt} ({(s_opt - s_raw) / s_raw:+.0%}) "
            f"raw_compile_us={raw_c:.0f}", spec=spec)

    # (a) the e2e NullaNet classifier workload (same config family as
    # flow.e2e.*): every hidden layer, raw espresso factoring vs pipeline
    cfg = FlowConfig(n_features=10 if quick else 12,
                     hidden=(8, 6) if quick else (10, 8),
                     n_classes=4, n_samples=1200 if quick else 4000,
                     train_steps=120 if quick else 300,
                     spec=CompileSpec(n_unit=32))
    xt, yt, _, _ = cfg.load_data()
    mcfg = BinaryMLPConfig(n_features=cfg.n_features, hidden=cfg.hidden,
                           n_classes=cfg.n_classes, seed=cfg.seed)
    n_layers = len(cfg.hidden) + 1
    params = train_binary_mlp(mcfg, xt, yt, steps=cfg.train_steps)
    params_np = {k: np.asarray(v) for k, v in params.items()}
    acts, _ = hard_forward(params_np, input_bits(xt).astype(np.uint8),
                           n_layers)
    raw_layers = [layer_graph(params_np[f"w{i}"], params_np[f"b{i}"],
                              acts[i], name=f"layer{i}", optimize="none")
                  for i in range(n_layers - 1)]
    ab_rows("nullanet", raw_layers, cfg.n_unit)

    # (b) random-graph stress: duplicate cones + dead fanout by design
    rng = np.random.default_rng(11)
    big = random_graph(rng, 64, 3000 if quick else 10_000, 48, locality=128)
    ab_rows("random", [big], 256)


# ---------------------------------------------------------------------------
# pipelining ablation (paper Fig. 8 a/b)
# ---------------------------------------------------------------------------

def bench_pipelining(quick: bool) -> None:
    rng = np.random.default_rng(1)
    g = random_graph(rng, 64, 3000, 32, locality=256)
    progs = [compile_graph(g, CompileSpec(n_unit=128, optimize="none"))
             ] * (8 if quick else 32)
    pipe = simulate_pipeline(progs, n_input_vectors=4096)
    seq = simulate_no_pipeline(progs, n_input_vectors=4096)
    row("fig8.pipelined", cycles_us(pipe.total_cycles),
        f"speedup={seq.total_cycles / pipe.total_cycles:.2f}x")
    row("fig8.sequential", cycles_us(seq.total_cycles), "")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write rows as JSON {name: {us, derived}}")
    args, _ = ap.parse_known_args()
    print("name,us_per_call,derived")
    t0 = time.time()
    bench_cost_model_validation(args.quick)
    bench_latency_split(args.quick)
    bench_pareto_search(args.quick)
    bench_nn_e2e(args.quick)
    bench_resources(args.quick)
    bench_pipelining(args.quick)
    bench_compile(args.quick)
    bench_opt(args.quick)
    bench_kernels(args.quick)
    bench_serve_logic(args.quick)
    bench_warm_start(args.quick)
    bench_verify(args.quick)
    bench_calibration(args.quick)
    bench_serve_traffic(args.quick)
    bench_flow_e2e(args.quick)
    print(f"# total {time.time() - t0:.1f}s, {len(ROWS)} rows")
    if args.json:
        doc = {name: {"us": round(us, 3), "derived": derived,
                      **({} if spec is None else {"spec": spec})}
               for name, us, derived, spec in ROWS}
        doc["bench_env"] = bench_env()
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
