"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from dryrun results.

  PYTHONPATH=src python -m benchmarks.roofline_table [--mesh pod1]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "dryrun_results")

ARCH_ORDER = ["qwen3-8b", "internlm2-20b", "minicpm-2b", "qwen3-32b",
              "mixtral-8x7b", "grok-1-314b", "mamba2-370m", "hubert-xlarge",
              "internvl2-76b", "recurrentgemma-2b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(mesh: str) -> dict:
    out = {}
    for path in glob.glob(os.path.join(RESULTS, f"*__{mesh}.json")):
        with open(path) as f:
            d = json.load(f)
        out[(d["arch"], d["shape"])] = d
    return out


def fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}us"


def roofline_fraction(r: dict) -> float:
    """MODEL-flop time / dominant roofline term — the perf score basis."""
    ideal = r["model_flops_total"] / (r["chips"] * 197e12)
    dom = max(r["compute_s"], r["memory_s"], r["collective_s"])
    return ideal / dom if dom else 0.0


def table(mesh: str, results: dict) -> str:
    lines = [
        f"### Roofline — {mesh} "
        f"({'512 chips (2x16x16)' if mesh == 'pod2' else '256 chips (16x16)'})",
        "",
        "| arch | shape | compute | memory | collective | bound | "
        "useful FLOPs ratio | roofline fraction | peak HBM/dev (TPU est) | "
        "fits 16G |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            d = results.get((arch, shape))
            if d is None:
                lines.append(f"| {arch} | {shape} | — | — | — | — | — | — "
                             f"| MISSING | — |")
                continue
            if not d.get("supported", True):
                lines.append(f"| {arch} | {shape} | n/a | n/a | n/a | n/a "
                             f"| n/a | n/a | n/a — {d['reason']} | n/a |")
                continue
            if not d.get("ok", False):
                lines.append(f"| {arch} | {shape} | FAIL | | | | | | "
                             f"{d.get('error', '')[:60]} | |")
                continue
            r = d["roofline"]
            peak = d["memory"]["peak_hbm_tpu_est"]
            frac = roofline_fraction(r)
            lines.append(
                f"| {arch} | {shape} | {fmt_s(r['compute_s'])} | "
                f"{fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} | "
                f"{r['bound']} | {r['useful_flops_ratio']:.2f} | "
                f"{frac:.1%} | {peak / 2 ** 30:.1f} GiB | "
                f"{'yes' if peak <= 16 * 2 ** 30 else 'NO'} |")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="both", choices=["pod1", "pod2", "both"])
    args = ap.parse_args()
    meshes = ["pod1", "pod2"] if args.mesh == "both" else [args.mesh]
    for mesh in meshes:
        print(table(mesh, load(mesh)))
        print()


if __name__ == "__main__":
    main()
