"""Cycle models for the paper's two baselines (§8.3), on the same fabric.

All three implementations are charged against the SAME hardware budget
(paper: "all implementations used in this paper utilize the same set of
hardware resources"): n_unit compute units, one HBM interface.

  MAC  — generic MAC-array accelerator [Sohrabizadeh et al. 2020 +
          the paper's improvements: weights cached on-chip, partial sums
          in-register]. 1 MAC/unit/cycle; weights streamed once per layer.
  XNOR — FINN-style MVTU with popcount units. One unit consumes a 32-bit
          word of +-1 products per cycle (XNOR+popcount), weights resident.
"""
from __future__ import annotations

from repro.core.cost_model import CostModel, TpuFabric


def mac_cycles(layers, n_unit: int, fabric: TpuFabric | None = None,
               act_bits: int = 8, w_bits: int = 8) -> float:
    """layers: [(name, n_filters, fanin, n_patches, in_ch)].

    Usable parallelism is capped at in_ch x out_ch (the spatially-unrolled
    channel loops — paper §8.3's dataflow discussion): surplus units idle.
    """
    f = fabric or TpuFabric()
    total = 0.0
    for _, n_filters, fanin, n_patches, in_ch in layers:
        eff = min(n_unit, n_filters * in_ch)
        macs = n_filters * fanin * n_patches
        compute = macs / eff
        w_bytes = n_filters * fanin * w_bits / 8
        a_bytes = n_patches * fanin * act_bits / 8
        dm = (w_bytes + a_bytes) / f.hbm_bytes_per_cycle
        total += max(compute, dm)  # weights stream overlaps compute
    return total


def xnor_cycles(layers, n_unit: int, fabric: TpuFabric | None = None
                ) -> float:
    """FINN MVTU: PE x SIMD unrolls (out_ch, in_ch) — same cap (§8.3)."""
    f = fabric or TpuFabric()
    total = 0.0
    for _, n_filters, fanin, n_patches, in_ch in layers:
        eff = min(n_unit, n_filters * in_ch)
        words = n_filters * n_patches * -(-fanin // f.simd_lanes)
        compute = words / eff
        # binarized weights resident on-chip (paper: XNOR keeps everything
        # on-chip -> no recurring DDR cost); activations 1-bit
        a_bytes = n_patches * fanin / 8
        dm = a_bytes / f.hbm_bytes_per_cycle
        total += max(compute, dm)
    return total


def nulladsp_cycles(cost_layers, n_unit: int,
                    model: CostModel | None = None,
                    parallel_factor: int = 1) -> float:
    model = model or CostModel()
    return model.network_cycles(cost_layers, n_unit, parallel_factor)


def nulladsp_parallel_best(cost_layers, n_unit_total: int,
                           model: CostModel | None = None
                           ) -> tuple[float, int, int]:
    """Paper eq. 25: split the unit budget across k parallel compute
    kernels of n_per units each (filters distribute across kernels).
    Returns (cycles, n_per, k) at the joint optimum — this is how the
    paper reaches its headline numbers with thousands of DSPs while each
    kernel sits at the U-curve's sweet spot."""
    model = model or CostModel()
    best = (float("inf"), n_unit_total, 1)
    n_per = 1
    while n_per <= n_unit_total:
        k = n_unit_total // n_per
        c = model.network_cycles_parallel(cost_layers, n_per, k)
        if c < best[0]:
            best = (c, n_per, k)
        n_per = max(n_per + 1, int(n_per * 1.3))
    return best
