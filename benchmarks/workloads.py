"""Representative FFCL workloads matching the paper's networks (§8).

CIFAR-10/MNIST aren't available offline; what the cost experiments need is
FFCL *statistics* (gates/levels per filter, fanin, filter/patch counts),
which we generate from the same layer geometry the paper quotes — e.g.
VGG16 conv8: 512 filters x fanin 3*3*256 = 2304, 4x4 = 16 patches (paper
§1) — by synthesizing a representative NullaNet neuron per layer (ISF
sampled threshold function -> espresso -> 2-input gates -> optimize).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.cost_model import FfclStats, LayerLoad
from repro.core.espresso import minimize, sop_to_graph
from repro.core.gate_ir import LogicGraph
from repro.core.synth import optimize

# (name, n_filters, fanin, n_patches, in_ch) per conv layer; input 32x32
# CIFAR-10, VGG16 feature map halves at each pool. Layers 2-13 (paper).
# in_ch feeds the baselines' channel-unrolling cap (paper §8.3: MAC/XNOR
# arrays spatially unroll the in/out channel loops, so usable parallelism
# is bounded by in_ch x out_ch — the stated reason LeNet favors NullaDSP).
VGG16_LAYERS = [
    ("conv2", 64, 3 * 3 * 64, 32 * 32, 64),
    ("conv3", 128, 3 * 3 * 64, 16 * 16, 64),
    ("conv4", 128, 3 * 3 * 128, 16 * 16, 128),
    ("conv5", 256, 3 * 3 * 128, 8 * 8, 128),
    ("conv6", 256, 3 * 3 * 256, 8 * 8, 256),
    ("conv7", 256, 3 * 3 * 256, 8 * 8, 256),
    ("conv8", 512, 3 * 3 * 256, 4 * 4, 256),   # paper §1's example layer
    ("conv9", 512, 3 * 3 * 512, 4 * 4, 512),
    ("conv10", 512, 3 * 3 * 512, 4 * 4, 512),
    ("conv11", 512, 3 * 3 * 512, 2 * 2, 512),
    ("conv12", 512, 3 * 3 * 512, 2 * 2, 512),
    ("conv13", 512, 3 * 3 * 512, 2 * 2, 512),
]

# LeNet-5 on MNIST (28x28): conv1 6@5x5, conv2 16@5x5x6, fc1 120, fc2 84
LENET5_LAYERS = [
    ("conv1", 6, 5 * 5, 28 * 28, 1),
    ("conv2", 16, 5 * 5 * 6, 10 * 10, 6),
    ("fc1", 120, 400, 1, 400),
    ("fc2", 84, 120, 1, 120),
]


@dataclass(frozen=True)
class LayerWorkload:
    name: str
    n_filters: int
    fanin: int
    n_patches: int
    graph: LogicGraph
    stats: FfclStats


def representative_neuron(fanin: int, n_samples: int = 400,
                          seed: int = 0) -> LogicGraph:
    """ISF-sampled threshold neuron -> minimized 2-input gate graph.

    n_samples sets the ISF density: NullaNet neurons synthesized from real
    training traffic see hundreds-to-thousands of distinct patterns per
    neuron; the cube count (and thus gate count) grows with it. 400 gives
    graph sizes in the small-thousands of gates for fanin ~2k, matching
    the regime where the paper's DSP mapping pays off."""
    rng = np.random.default_rng(seed)
    x = rng.integers(0, 2, (min(n_samples, 2 ** min(fanin, 30)), fanin)
                     ).astype(np.uint8)
    x = np.unique(x, axis=0)
    w = rng.normal(size=fanin)
    b = float(rng.normal() * 0.1)
    act = ((2.0 * x - 1.0) @ w + b) >= 0
    cubes = minimize(x[act], x[~act], rng=rng)
    g = sop_to_graph([cubes], n_inputs=fanin, name=f"neuron_f{fanin}")
    return optimize(g)


_CACHE: dict = {}


def build_workload(layers, seed: int = 0,
                   n_samples: int = 400) -> list[LayerWorkload]:
    out = []
    for i, (name, n_filters, fanin, n_patches, _in_ch) in enumerate(layers):
        key = (fanin, seed + i, n_samples)
        if key not in _CACHE:
            _CACHE[key] = representative_neuron(fanin, n_samples, seed + i)
        g = _CACHE[key]
        out.append(LayerWorkload(name=name, n_filters=n_filters,
                                 fanin=fanin, n_patches=n_patches, graph=g,
                                 stats=FfclStats.from_graph(g)))
    return out


def cost_model_layers(workload: list[LayerWorkload]) -> list[LayerLoad]:
    """-> typed :class:`LayerLoad` list for ``CostModel.network_cycles``
    and the optimizer searches (legacy tuple consumers still unpack it:
    ``LayerLoad`` iterates as ``(stats, n_copies, n_input_vectors)``)."""
    return [LayerLoad(stats=lw.stats, n_copies=lw.n_filters,
                      n_input_vectors=lw.n_patches) for lw in workload]
