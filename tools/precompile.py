#!/usr/bin/env python
"""AOT-compile logic workloads into a shared artifact store (fleet warm
start).

Populates an :class:`~repro.core.artifact_store.ArtifactStore` directory
with the exact entries a serving process's ``ProgramCache`` would have
compiled on first contact, so a fleet of fresh ``LogicEngine`` processes
(``LogicEngine(spec, store=...)`` / ``FrontDoor(spec=..., store=...)``)
serves its first request with **zero compiles** — cold starts become as
rare as cache misses (ROADMAP: compiled-artifact persistence).

Partition clusters compile in a **process pool**: a ``max_gates`` budget
splits a graph into independent output-cone clusters (core/partition.py)
whose schedules don't depend on each other, so the per-cluster
``compile_graph`` calls — the dominant cost for 100k+-gate graphs —
fan out across cores while the parent reassembles the one
:class:`CompiledArtifact` (same bits as the serial facade: clustering,
spec normalization, and scheduling are all deterministic).

Usage::

    PYTHONPATH=src python tools/precompile.py --store /var/logic-store \\
        --gates 5000 --max-gates 800 --n-unit 64 --jobs 8 --verify

The workload generator is seeded and shared with
``examples/warm_start.py``: the same ``--seed/--count/--inputs/--gates/
--outputs/--locality`` arguments name the same graphs in both, which is
how the CI two-process smoke proves a *different process* warm-starts
from this one's output.
"""
from __future__ import annotations

import argparse
import os
import sys
import time
from concurrent.futures import ProcessPoolExecutor

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.artifact_store import ArtifactStore, store_key  # noqa: E402
from repro.core.compiler import CompiledArtifact, LogicCompiler  # noqa: E402
from repro.core.gate_ir import LogicGraph, random_graph  # noqa: E402
from repro.core.partition import output_permutation, partition  # noqa: E402
from repro.core.scheduler import LogicProgram, compile_graph  # noqa: E402
from repro.core.spec import CompileSpec  # noqa: E402


def build_graphs(seed: int, count: int, n_inputs: int, n_gates: int,
                 n_outputs: int, locality: int) -> list[LogicGraph]:
    """The seeded workload generator (shared, by convention, with
    examples/warm_start.py — identical arguments MUST name identical
    graphs across processes)."""
    rng = np.random.default_rng(seed)
    return [random_graph(rng, n_inputs, n_gates, n_outputs,
                         locality=locality) for _ in range(count)]


def _compile_cluster(payload: tuple) -> tuple[dict, dict]:
    """Pool worker: schedule one (sub-)graph; returns the program payload
    (picklable arrays + scalars, not the frozen dataclass)."""
    graph, spec_dict = payload
    prog = compile_graph(graph, CompileSpec.from_dict(spec_dict))
    return prog.to_payload()


def registry_target(graph: LogicGraph, spec: CompileSpec
                    ) -> tuple[LogicGraph, CompileSpec]:
    """Mirror ``ProgramCache.get``'s keying exactly: optimize the graph
    per ``spec``, resolve ``n_unit="auto"``, fold an unbinding partition
    budget, and strip ``optimize`` (its whole effect is the post-opt
    fingerprint).  The returned pair is what the store entry is addressed
    by — any divergence here and the fleet would recompile anyway."""
    pipeline = spec.pipeline
    g = pipeline.run(graph).graph if pipeline is not None else graph
    spec, _ = LogicCompiler().resolve(g, spec, assume_optimized=True)
    return g, spec.normalize(g).with_(optimize="none")


def precompile_graph(store: ArtifactStore, graph: LogicGraph,
                     spec: CompileSpec, pool: ProcessPoolExecutor | None
                     ) -> tuple[str, CompiledArtifact | None, float]:
    """Compile ``(graph, spec)`` — partition clusters through ``pool``
    when it binds — and publish to ``store``.  Returns ``(key, artifact,
    seconds)``; artifact is ``None`` when the store already had it."""
    g, target = registry_target(graph, spec)
    fp = g.fingerprint()
    key = store_key(fp, target)
    if store.contains(fp, target):
        if spec.pipeline is not None:   # heal a missing/stale alias
            store.save_alias(graph.fingerprint(), spec, key)
        return key, None, 0.0
    t0 = time.perf_counter()
    mono = target.with_(max_gates=None)
    if target.max_gates is not None and g.n_gates > target.max_gates:
        parts = partition(g, target)
        tasks = [(p.graph, mono.to_dict()) for p in parts]
        if pool is not None:
            payloads = list(pool.map(_compile_cluster, tasks))
        else:
            payloads = [_compile_cluster(t) for t in tasks]
        programs = tuple(LogicProgram.from_payload(a, s)
                         for a, s in payloads)
        perm = output_permutation(parts, g.n_outputs)
    else:
        task = (g, mono.to_dict())
        a, s = (pool.submit(_compile_cluster, task).result()
                if pool is not None else _compile_cluster(task))
        programs = (LogicProgram.from_payload(a, s),)
        perm = np.arange(g.n_outputs, dtype=np.int64)
    dt = time.perf_counter() - t0
    artifact = CompiledArtifact(spec=target, graph=g, programs=programs,
                                output_perm=perm, compile_s=dt)
    saved_key = store.save(artifact)
    assert saved_key == key, "store key drifted from registry target"
    if spec.pipeline is not None:
        # raw-identity alias: serving processes resolve the original
        # (unoptimized) graph straight here, skipping the pass pipeline
        store.save_alias(graph.fingerprint(), spec, key)
    return key, artifact, dt


def verify_entry(store: ArtifactStore, graph: LogicGraph,
                 spec: CompileSpec, rng: np.random.Generator) -> None:
    """Reload the published entry and prove it is the *right* program:
    byte-identical schedule tables and numpy-oracle parity with the raw
    graph on random bits."""
    g, target = registry_target(graph, spec)
    loaded = store.load(g.fingerprint(), target)
    assert loaded is not None, "published entry vanished"
    fresh = LogicCompiler().compile(g, target, assume_optimized=True)
    assert len(loaded.programs) == len(fresh.programs)
    for lp, fp_ in zip(loaded.programs, fresh.programs):
        for f in LogicProgram.ARRAY_FIELDS:
            a, b = getattr(lp, f), getattr(fp_, f)
            assert a.dtype == b.dtype and a.tobytes() == b.tobytes(), \
                f"stream {f} diverged after store round-trip"
    bits = rng.integers(0, 2, (96, graph.n_inputs)).astype(bool)
    assert (loaded.execute(bits) == graph.evaluate(bits)).all(), \
        "store-loaded artifact diverged from graph semantics"


def parse_n_unit(v: str):
    return "auto" if v == "auto" else int(v)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n\n")[0])
    ap.add_argument("--store", required=True, metavar="DIR",
                    help="artifact-store root directory (created if missing)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--count", type=int, default=1,
                    help="number of workload graphs")
    ap.add_argument("--inputs", type=int, default=16)
    ap.add_argument("--gates", type=int, default=800)
    ap.add_argument("--outputs", type=int, default=8)
    ap.add_argument("--locality", type=int, default=64)
    ap.add_argument("--n-unit", type=parse_n_unit, default=32,
                    metavar="N|auto")
    ap.add_argument("--alloc", choices=("direct", "liveness"),
                    default="liveness")
    ap.add_argument("--optimize", choices=("default", "none"),
                    default="default")
    ap.add_argument("--max-gates", type=int, default=None,
                    help="partition budget; clusters compile in the pool")
    ap.add_argument("--jobs", type=int, default=None,
                    help="process-pool workers (default: cpu count; "
                         "0 = in-process, no pool)")
    ap.add_argument("--verify", action="store_true",
                    help="reload every entry and assert byte + semantic "
                         "parity with a fresh compile")
    args = ap.parse_args(argv)

    store = ArtifactStore(args.store)
    spec = CompileSpec(n_unit=args.n_unit, alloc=args.alloc,
                       optimize=args.optimize, max_gates=args.max_gates)
    graphs = build_graphs(args.seed, args.count, args.inputs, args.gates,
                          args.outputs, args.locality)
    jobs = os.cpu_count() if args.jobs is None else args.jobs
    pool = ProcessPoolExecutor(max_workers=jobs) if jobs else None
    rng = np.random.default_rng(args.seed + 1)
    t0 = time.perf_counter()
    try:
        for i, g in enumerate(graphs):
            key, artifact, dt = precompile_graph(store, g, spec, pool)
            if artifact is None:
                print(f"graph[{i}] {g.n_gates}g: already published "
                      f"key={key}")
            else:
                print(f"graph[{i}] {g.n_gates}g -> "
                      f"{len(artifact.programs)} program(s), "
                      f"{sum(p.n_steps for p in artifact.programs)} steps, "
                      f"{dt * 1e3:.1f} ms, key={key}")
            if args.verify:
                verify_entry(store, g, spec, rng)
    finally:
        if pool is not None:
            pool.shutdown()
    st = store.stats()
    print(f"store {st['root']}: {st['entries']} entries "
          f"(+{st['saves']} saved) in {time.perf_counter() - t0:.2f}s"
          + (" [verified]" if args.verify else ""))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
