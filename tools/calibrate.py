#!/usr/bin/env python
"""Fit and persist the wall-clock phase calibration (DESIGN.md §12).

Measures the seeded workload x ``n_unit`` probe grid on THIS host/backend
(``core.calibrate.collect_probes``: each probe compiles one graph and
times the fused pack/setup/kernel/unpack path behind ``block_until_ready``),
least-squares fits the per-phase overhead factors, and publishes the
result to an :class:`~repro.core.artifact_store.ArtifactStore` as the
named calibration record — the fit ``LogicCompiler``/``ProgramCache``
pick up for ``CompileSpec(n_unit="auto", objective="wallclock")``.

Usage::

    PYTHONPATH=src python tools/calibrate.py --store /var/logic-store \\
        --quick --verify

``--verify`` spawns a FRESH python process that loads the record back
through the store and asserts ``calibrate.fit_count() == 0`` — a warm
process must resolve wallclock specs with *zero re-fits*, the same
counter-pinned contract as the artifact store's zero-compile warm start.
A calibration is host- and backend-specific: re-run this tool after
moving stores across machines or changing jax/interpret configuration.
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import calibrate  # noqa: E402
from repro.core.artifact_store import ArtifactStore  # noqa: E402

#: The --verify child: load from the store in a fresh interpreter, prove
#: the load path never re-fits, and resolve a wallclock auto spec with it.
_VERIFY_SNIPPET = """
import sys
import numpy as np
from repro.core import calibrate
from repro.core.artifact_store import ArtifactStore
from repro.core.compiler import LogicCompiler
from repro.core.gate_ir import random_graph
from repro.core.spec import CompileSpec

store_root, name = sys.argv[1], sys.argv[2]
cal = ArtifactStore(store_root).load_calibration(name)
assert cal is not None, "persisted calibration record not found"
assert calibrate.fit_count() == 0, (
    "loading a persisted calibration must not re-fit "
    f"(fit_count={calibrate.fit_count()})")
compiler = LogicCompiler(calibration=cal)
g = random_graph(np.random.default_rng(7), 16, 400, 8, locality=64)
spec, search = compiler.resolve(
    g, CompileSpec(n_unit="auto", objective="wallclock"))
assert spec.resolved and search.objective == "wallclock"
assert search.alt is not None and search.alt.objective == "cycles"
assert calibrate.fit_count() == 0, "resolve must not re-fit either"
print(f"verify: wallclock pick n_unit={spec.n_unit} "
      f"(cycles pick {search.alt.best_n_unit}), zero re-fits")
"""


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n\n")[0])
    ap.add_argument("--store", required=True, metavar="DIR",
                    help="artifact-store root directory (created if "
                         "missing)")
    ap.add_argument("--name", default="default",
                    help="calibration record name (default: %(default)s)")
    grid = ap.add_mutually_exclusive_group()
    grid.add_argument("--quick", action="store_true", default=True,
                      help="3-workload x 5-unit probe grid (default)")
    grid.add_argument("--full", dest="quick", action="store_false",
                      help="5-workload x 6-unit probe grid")
    ap.add_argument("--reps", type=int, default=5,
                    help="timed repetitions per probe, min taken "
                         "(default: %(default)s)")
    ap.add_argument("--batch", type=int, default=1024,
                    help="input vectors per probe (default: %(default)s)")
    ap.add_argument("--verify", action="store_true",
                    help="fresh-process load smoke: the persisted record "
                         "must serve wallclock resolution with ZERO "
                         "re-fits (fit_count() == 0)")
    args = ap.parse_args(argv)

    store = ArtifactStore(args.store)
    graphs = calibrate.default_probe_graphs(quick=args.quick)
    units = calibrate.default_probe_units(quick=args.quick)
    print(f"probing {len(graphs)} workloads x {len(units)} unit counts "
          f"(reps={args.reps}, batch={args.batch})...")
    t0 = time.perf_counter()
    probes = calibrate.collect_probes(graphs, units,
                                      n_input_vectors=args.batch,
                                      reps=args.reps)
    cal = calibrate.fit_calibration(probes, meta={
        "grid": "quick" if args.quick else "full",
        "reps": args.reps, "batch": args.batch,
        "n_probes": len(probes)})
    for phase in calibrate.PHASES:
        f = cal.fits[phase]
        coefs = ", ".join(f"{c:.3e}" for c in f.coefs)
        print(f"  {phase:7s} coefs=[{coefs}] offset={f.offset * 1e6:8.1f}us"
              f"  median |err| {f.median_abs_rel_err * 100:5.1f}%")
    path = store.save_calibration(cal, name=args.name)
    print(f"fitted {len(probes)} probes in {time.perf_counter() - t0:.1f}s; "
          f"worst-phase median error "
          f"{cal.median_abs_rel_err() * 100:.1f}%; saved -> {path}")

    if args.verify:
        env = dict(os.environ)
        src = os.path.join(os.path.dirname(__file__), "..", "src")
        env["PYTHONPATH"] = os.pathsep.join(
            p for p in (src, env.get("PYTHONPATH")) if p)
        proc = subprocess.run(
            [sys.executable, "-c", _VERIFY_SNIPPET, args.store, args.name],
            env=env, capture_output=True, text=True)
        sys.stdout.write(proc.stdout)
        sys.stderr.write(proc.stderr)
        if proc.returncode != 0:
            print("verify FAILED", file=sys.stderr)
            return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
