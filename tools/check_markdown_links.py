"""Markdown link checker (stdlib-only, used by the CI docs job).

    python tools/check_markdown_links.py README.md DESIGN.md ...

Validates that every relative link/image target in the given markdown
files exists on disk (anchors and external http(s)/mailto links are
skipped). Also validates that back-tick-free inline references of the form
`[text](path)` inside tables resolve. Exits non-zero listing every broken
link.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

_LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_CODE_FENCE = re.compile(r"```.*?```", flags=re.S)


def broken_links(md_path: Path) -> list[str]:
    text = _CODE_FENCE.sub("", md_path.read_text(encoding="utf-8"))
    bad = []
    for target in _LINK.findall(text):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        if not (md_path.parent / rel).exists():
            bad.append(f"{md_path}: broken link -> {target}")
    return bad


def main(argv: list[str]) -> int:
    if not argv:
        print("usage: check_markdown_links.py FILE.md [FILE.md ...]")
        return 2
    failures: list[str] = []
    for name in argv:
        p = Path(name)
        if not p.exists():
            failures.append(f"{name}: file not found")
            continue
        failures.extend(broken_links(p))
    for f in failures:
        print(f, file=sys.stderr)
    print(f"checked {len(argv)} files, {len(failures)} broken links")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
