#!/usr/bin/env python
"""Statically verify compiled artifacts in an artifact store (CLI front
end of core/verify.py; DESIGN.md §13).

Store checksums prove the *bytes* of an entry round-tripped; this tool
proves the *schedules* still compute their recorded graphs — def-before-
use on every scratch row, trash-row isolation, megakernel stage-handoff
soundness, permutation bijectivity, and the full dataflow-term
comparison against each entry's post-optimization graph.  Run it against
a fleet's shared store after a toolchain upgrade, before promoting a
warm-start directory, or in CI against freshly precompiled entries::

    PYTHONPATH=src python tools/verify_program.py --store /var/logic-store
    PYTHONPATH=src python tools/verify_program.py --store S KEY1 KEY2
    PYTHONPATH=src python tools/verify_program.py --store S --json

Exit status: 0 when every selected entry verifies clean, 1 when any
entry fails (the failure summaries name exact rule codes and
``(stage, step, lane, addr)`` locations), 2 on usage errors (unknown
key, empty store with explicit keys).  Verification failures do NOT
quarantine here — this is an inspection tool; pass ``--quarantine`` to
opt into moving failed entries out of the serving namespace the way a
``verify="load"`` server would.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.artifact_store import ArtifactStore  # noqa: E402
from repro.core.errors import ArtifactIntegrityError  # noqa: E402
from repro.core.verify import verify_artifact  # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="statically verify compiled artifacts in a store")
    ap.add_argument("--store", required=True,
                    help="artifact store root directory")
    ap.add_argument("keys", nargs="*",
                    help="store keys to verify (default: every entry)")
    ap.add_argument("--json", action="store_true",
                    help="emit one JSON object per entry instead of text")
    ap.add_argument("--quarantine", action="store_true",
                    help="quarantine entries that fail verification")
    ap.add_argument("--max-diagnostics", type=int, default=16,
                    help="diagnostic cap per entry (default 16)")
    args = ap.parse_args(argv)

    store = ArtifactStore(args.store)
    keys = args.keys or store.keys()
    if args.keys:
        unknown = [k for k in args.keys if k not in store]
        if unknown:
            print(f"error: no store entry for {unknown}", file=sys.stderr)
            return 2
    if not keys:
        print(f"{args.store}: no entries", file=sys.stderr)
        return 0

    failed = 0
    for key in keys:
        try:
            artifact = store.load_key(key)
        except ArtifactIntegrityError as exc:
            # integrity failures quarantine at the store layer already
            failed += 1
            rec = {"key": key, "ok": False, "error": str(exc)}
            print(json.dumps(rec) if args.json
                  else f"FAIL {key}: {exc}")
            continue
        report = verify_artifact(artifact,
                                 max_diagnostics=args.max_diagnostics)
        if args.json:
            print(json.dumps({
                "key": key, "ok": report.ok, "name": artifact.graph.name,
                "n_programs": len(artifact.programs),
                "elapsed_s": report.elapsed_s,
                "checked": report.checked,
                "diagnostics": [str(d) for d in report.diagnostics]}))
        else:
            print(("OK   " if report.ok else "FAIL ") + key + ": "
                  + report.summary())
        if not report.ok:
            failed += 1
            if args.quarantine:
                qpath = store.quarantine(key)
                if not args.json:
                    print(f"     quarantined -> {qpath}")
    if not args.json:
        print(f"{len(keys)} entr{'y' if len(keys) == 1 else 'ies'}, "
              f"{failed} failed")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
