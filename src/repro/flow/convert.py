"""The single NN-layer -> FFCL conversion code path (paper §7 NullaNet flow).

Every consumer that turns one binarized layer into executable logic —
the end-to-end classifier (flow/classifier.py), the transformer FFN swap
(models/logic_mlp.py), examples, benchmarks — goes through
:func:`convert_layer`: Boolean-spec extraction (``nullanet.layer_to_graph``:
ISF or full enumeration per neuron) -> two-level minimization
(core/espresso.py) -> multi-level restructuring (core/synth.py) ->
sub-kernel scheduling (``scheduler.compile_graph``). Keeping one code path
means the degenerate-cover guarantees (constant-true/false neurons, empty
ISF care-sets — tests/test_conformance.py) hold everywhere.

Weights are cast to float64 *here*, before spec extraction, so the layer's
Boolean function is defined by exactly one numeric comparison —
``(2x-1) @ W + b >= 0`` in float64 — and the hard reference forward
(flow/classifier.py ``hard_forward``) reproduces it bit-for-bit. That is
what makes the accuracy-parity claim *exact* rather than approximate.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.compiler import LogicCompiler
from repro.core.gate_ir import LogicGraph
from repro.core.nullanet import layer_to_graph
from repro.core.scheduler import LogicProgram, compile_graph
from repro.core.spec import CompileSpec, resolve_spec, _UNSET


@dataclass(frozen=True)
class CompiledLayer:
    """One hidden layer as both its gate DAG and its compiled program.

    The graph is retained next to the program because the two serve
    different executors: direct reference / Pallas paths run the program's
    streams, while the serving engine keys its registry on the graph and
    compiles (or cache-hits) from it.
    """

    graph: LogicGraph
    program: LogicProgram

    @property
    def n_inputs(self) -> int:
        return self.graph.n_inputs

    @property
    def n_outputs(self) -> int:
        return self.graph.n_outputs


def layer_graph(W: np.ndarray, b: np.ndarray, calib_bits: np.ndarray,
                *, mode: str = "auto", name: str = "layer",
                optimize="default") -> LogicGraph:
    """Graph-only conversion of one binarized layer (no scheduling).

    Args:
      W / b: (fanin, n_neurons) weights and (n_neurons,) bias of the layer
        (any float dtype; cast to float64 for spec extraction — the parity
        rule of the module docstring lives here).
      calib_bits: (N, fanin) {0,1} calibration activations — the observed
        care-set for ISF mode; unused by full enumeration.
      mode: 'isf' | 'enum' | 'auto' (enumeration when fanin <= ENUM_LIMIT;
        enumeration makes the conversion *exact*, see module docstring).
      optimize: gate-level pass pipeline for the synthesized graph
        (core/opt.py): ``"default"`` | ``"none"`` | a ``PassManager``.
        Semantics-preserving, so the parity guarantees are unaffected.
    """
    W = np.asarray(W, dtype=np.float64)
    b = np.asarray(b, dtype=np.float64)
    return layer_to_graph(np.asarray(calib_bits, dtype=np.uint8), W, b,
                          mode=mode, name=name, optimize=optimize)


def convert_layer(W: np.ndarray, b: np.ndarray, calib_bits: np.ndarray,
                  spec: CompileSpec | None = None, *, mode: str = "auto",
                  name: str = "layer", n_unit=_UNSET, alloc=_UNSET,
                  opcode_sort=_UNSET, fuse_levels=_UNSET,
                  optimize=_UNSET) -> CompiledLayer:
    """NullaNet-convert one binarized layer (:func:`layer_graph`) and
    compile it against ``spec`` (the one declarative target,
    core/spec.py; canonical defaults when omitted).

    ``spec.optimize`` is applied once, at the graph stage, so the
    retained ``graph`` and the compiled ``program`` describe the same
    optimized netlist; ``spec.n_unit="auto"`` resolves per layer via the
    design-space search (core/compiler.py); ``spec.max_gates`` is moot
    here (one layer compiles monolithically — budget-aware serving
    partitions the composed stack instead).  Loose ``n_unit``/``alloc``/
    ``opcode_sort``/``fuse_levels``/``optimize`` kwargs are the
    deprecated pre-spec convention.
    """
    spec = resolve_spec(spec, caller="convert_layer", n_unit=n_unit,
                        alloc=alloc, opcode_sort=opcode_sort,
                        fuse_levels=fuse_levels, optimize=optimize)
    graph = layer_graph(W, b, calib_bits, mode=mode, name=name,
                        optimize=spec.optimize)
    spec, _ = LogicCompiler().resolve(graph, spec, assume_optimized=True)
    program = compile_graph(graph, spec.with_(optimize="none",
                                              max_gates=None))
    return CompiledLayer(graph=graph, program=program)


def layer_to_program(W: np.ndarray, b: np.ndarray, calib_bits: np.ndarray,
                     spec: CompileSpec | None = None, *, mode: str = "auto",
                     name: str = "layer", n_unit=_UNSET, alloc=_UNSET,
                     optimize=_UNSET) -> LogicProgram:
    """Program-only convenience over :func:`convert_layer`."""
    spec = resolve_spec(spec, caller="layer_to_program", n_unit=n_unit,
                        alloc=alloc, optimize=optimize)
    return convert_layer(W, b, calib_bits, spec, mode=mode,
                         name=name).program
