"""End-to-end NullaNet classifier flow: train -> per-layer FFCL -> serve.

The paper loop as one artifact: ``run_flow`` trains a binarized MLP,
converts every hidden layer through the single conversion code path
(``convert_layer``: ISF/enumeration -> espresso -> synth -> schedule),
chains the compiled programs with packed-word handoff, and measures
accuracy parity across the reference, Pallas, and serving-engine
backends. See DESIGN.md §6.
"""
from repro.flow.classifier import (BACKENDS, LogicClassifier,
                                   build_classifier, hard_forward,
                                   input_bits)
from repro.flow.convert import (CompiledLayer, convert_layer, layer_graph,
                                layer_to_program)
from repro.flow.report import EndToEndReport, FlowConfig, run_flow

__all__ = [
    "BACKENDS", "CompiledLayer", "EndToEndReport", "FlowConfig",
    "LogicClassifier", "build_classifier", "convert_layer", "hard_forward",
    "input_bits", "layer_graph", "layer_to_program", "run_flow",
]
