"""End-to-end accuracy-parity study: train -> compile -> map -> serve -> acc.

:func:`run_flow` is the whole paper loop as one artifact (ROADMAP north
star): train a float upper-bound MLP and a binarized MLP on the synthetic
classification task, NullaNet-convert every hidden layer of the binarized
model (flow/convert.py), and run the resulting logic classifier through
all execution backends (flow/classifier.py), measuring

  * **float acc**      — same architecture, ReLU hidden activations
                         (never logic-convertible; the accuracy ceiling);
  * **binarized acc**  — the hard {0,1}-activation model
                         (``classifier.hard_forward``), the function the
                         logic is compiled from;
  * **logic acc**      — per backend (reference / pallas / engine).

**Parity methodology** (DESIGN.md §6): with full input enumeration
(``mode='enum'``, every layer fanin <= ``nullanet.ENUM_LIMIT``) the
compiled logic computes *the same Boolean function* as the binarized
model, so ``logic acc == binarized acc`` must hold exactly and all
backends must return bit-identical hidden activations — both are asserted
by the CLI (examples/e2e_nullanet.py) and the flow tests. With ISF
sampling (wide layers) the don't-care assignments may diverge on
patterns unseen during calibration; the report then records the drop
instead of asserting parity.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field

import numpy as np

from repro.core.nullanet import (BinaryMLPConfig, ENUM_LIMIT, mlp_accuracy,
                                 train_binary_mlp)
from repro.core.spec import CompileSpec, resolve_spec, _UNSET
from repro.data.synthetic import make_binary_classification, train_val_split
from repro.flow.classifier import (BACKENDS, LogicClassifier, hard_forward,
                                   input_bits, build_classifier)


@dataclass(frozen=True, init=False)
class FlowConfig:
    """One end-to-end run. Defaults keep every layer under ``ENUM_LIMIT``
    fanin so the conversion is exact and parity is provable.

    ``spec`` is the one declarative compilation target
    (:class:`~repro.core.spec.CompileSpec`) the whole run compiles and
    serves against — per-layer conversion AND the engine backend
    (``spec.max_gates`` is the engine's partition budget; per-layer
    programs stay monolithic).  The loose ``n_unit``/``alloc``/
    ``optimize``/``max_gates`` constructor arguments are the deprecated
    pre-spec convention (still accepted, with a ``DeprecationWarning``);
    ``cfg.n_unit`` etc. remain readable as views on the spec.  The
    ``__init__`` is hand-written (not dataclass-generated) so
    ``dataclasses.replace(cfg, spec=...)`` keeps working — the legacy
    arguments are not fields.
    """

    n_features: int = 12
    hidden: tuple[int, ...] = (10, 8)
    n_classes: int = 4
    n_samples: int = 4000
    val_frac: float = 0.25
    noise: float = 0.05
    train_steps: int = 300
    spec: CompileSpec | None = None
    mode: str = "auto"
    seed: int = 0
    backends: tuple[str, ...] = BACKENDS

    def __init__(self, n_features: int = 12, hidden: tuple = (10, 8),
                 n_classes: int = 4, n_samples: int = 4000,
                 val_frac: float = 0.25, noise: float = 0.05,
                 train_steps: int = 300, spec: CompileSpec | None = None,
                 mode: str = "auto", seed: int = 0,
                 backends: tuple = BACKENDS, *, n_unit=_UNSET, alloc=_UNSET,
                 optimize=_UNSET, max_gates=_UNSET):
        spec = resolve_spec(spec, caller="FlowConfig", n_unit=n_unit,
                            alloc=alloc, optimize=optimize,
                            max_gates=max_gates)
        for name, val in (("n_features", n_features), ("hidden", hidden),
                          ("n_classes", n_classes), ("n_samples", n_samples),
                          ("val_frac", val_frac), ("noise", noise),
                          ("train_steps", train_steps), ("spec", spec),
                          ("mode", mode), ("seed", seed),
                          ("backends", backends)):
            object.__setattr__(self, name, val)

    @property
    def exact(self) -> bool:
        """True iff every hidden layer's fanin admits full enumeration."""
        if self.mode == "isf":
            return False
        fanins = (self.n_features, *self.hidden[:-1])
        return all(f <= ENUM_LIMIT for f in fanins)

    def load_data(self) -> tuple[np.ndarray, np.ndarray,
                                 np.ndarray, np.ndarray]:
        """The run's deterministic (x_train, y_train, x_val, y_val) —
        shared by :func:`run_flow` and the benchmarks so timed inference
        runs on exactly the sample set the reported accuracies used."""
        x, y = make_binary_classification(
            self.n_samples, self.n_features, n_classes=self.n_classes,
            noise=self.noise, seed=self.seed)
        return train_val_split(x, y, val_frac=self.val_frac, seed=self.seed)


# Read-only views on the spec under the pre-spec attribute names
# (``cfg.n_unit`` etc.).  Attached after decoration because the names
# double as the deprecated InitVar constructor arguments above — a
# property in the class body would shadow the InitVar defaults.
for _knob in ("n_unit", "alloc", "optimize", "max_gates"):
    setattr(FlowConfig, _knob,
            property(lambda self, _k=_knob: getattr(self.spec, _k)))
del _knob


@dataclass
class EndToEndReport:
    """Everything the accuracy-parity acceptance criterion needs."""

    float_acc: float
    binarized_acc: float
    logic_acc: dict[str, float]
    parity: bool                    # logic acc == binarized acc, all backends
    bit_identical: bool             # hidden bits equal across backends
    exact_mode: bool                # every layer fully enumerated
    layers: list[dict]              # per-layer gate/step/depth stats
    n_gates: int
    n_steps: int
    sim_cycles: float               # pipelined multi-FFCL simulator estimate
    sim_bound: str
    n_train: int
    n_val: int
    train_s: float
    convert_s: float
    eval_s: dict[str, float] = field(default_factory=dict)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def summary(self) -> str:
        lines = [
            f"float MLP (relu) val acc     {self.float_acc:.4f}",
            f"binarized MLP val acc        {self.binarized_acc:.4f}",
        ]
        for b, acc in self.logic_acc.items():
            lines.append(f"logic [{b:<9}] val acc      {acc:.4f}  "
                         f"({self.eval_s.get(b, 0.0) * 1e3:.0f} ms)")
        lines.append(
            f"parity: {'EXACT' if self.parity else 'approx'}"
            f" | backends bit-identical: {self.bit_identical}"
            f" | mode: {'enum (exact)' if self.exact_mode else 'isf'}")
        for st in self.layers:
            lines.append(
                f"  {st['name']}: {st['n_inputs']}->{st['n_outputs']} "
                f"{st['n_gates']} gates depth {st['depth']} "
                f"-> {st['n_steps']} steps @ {st['n_unit']} units "
                f"(occ {st['occupancy']:.0%})")
        lines.append(
            f"simulated: {self.sim_cycles:.0f} cycles ({self.sim_bound}-"
            f"bound) for {self.n_val} input vectors; "
            f"train {self.train_s:.1f}s convert {self.convert_s:.1f}s")
        return "\n".join(lines)


def run_flow(cfg: FlowConfig = FlowConfig(), log_every: int = 0
             ) -> tuple[EndToEndReport, LogicClassifier]:
    """Run the full train -> FFCL -> serve -> accuracy loop."""
    xt, yt, xv, yv = cfg.load_data()
    mcfg = BinaryMLPConfig(n_features=cfg.n_features, hidden=cfg.hidden,
                           n_classes=cfg.n_classes, seed=cfg.seed)
    n_layers = len(cfg.hidden) + 1

    t0 = time.perf_counter()
    params = train_binary_mlp(mcfg, xt, yt, steps=cfg.train_steps,
                              log_every=log_every)
    float_params = train_binary_mlp(mcfg, xt, yt, steps=cfg.train_steps,
                                    log_every=log_every, activation="relu")
    train_s = time.perf_counter() - t0

    float_acc = mlp_accuracy(float_params, mcfg, xv, yv, activation="relu")
    params_np = {k: np.asarray(v) for k, v in params.items()}
    _, logits = hard_forward(params_np, input_bits(xv), n_layers)
    binarized_acc = float((np.argmax(logits, -1) == yv).mean())

    t0 = time.perf_counter()
    clf = build_classifier(params_np, n_layers, xt, cfg.spec, mode=cfg.mode)
    convert_s = time.perf_counter() - t0

    engine = None
    if "engine" in cfg.backends:
        from repro.serve import LogicEngine
        engine = LogicEngine(cfg.spec, capacity=256)

    logic_acc: dict[str, float] = {}
    eval_s: dict[str, float] = {}
    hidden: dict[str, np.ndarray] = {}
    bits_v = input_bits(xv)
    for backend in cfg.backends:
        t0 = time.perf_counter()
        h = clf.hidden_bits(bits_v, backend=backend, engine=engine)
        eval_s[backend] = time.perf_counter() - t0
        hidden[backend] = h
        lg = clf.logits_from_hidden(h)
        logic_acc[backend] = float((np.argmax(lg, -1) == yv).mean())

    ref = next(iter(hidden.values()))
    bit_identical = all((h == ref).all() for h in hidden.values())
    parity = all(acc == binarized_acc for acc in logic_acc.values())

    sim = clf.simulate(n_input_vectors=len(xv))
    stats = clf.layer_stats()
    report = EndToEndReport(
        float_acc=float(float_acc), binarized_acc=binarized_acc,
        logic_acc=logic_acc, parity=parity, bit_identical=bit_identical,
        exact_mode=cfg.exact,
        layers=stats,
        n_gates=sum(s["n_gates"] for s in stats),
        n_steps=sum(s["n_steps"] for s in stats),
        sim_cycles=float(sim.total_cycles), sim_bound=sim.bound,
        n_train=len(xt), n_val=len(xv),
        train_s=train_s, convert_s=convert_s, eval_s=eval_s)
    return report, clf
