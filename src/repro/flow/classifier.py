"""Multi-layer NullaNet classifier over chained compiled logic programs.

The paper's actual workload (§7-§8): a whole NN inferred through
fixed-function combinational logic. :class:`LogicClassifier` holds one
:class:`~repro.flow.convert.CompiledLayer` per hidden layer plus the
full-precision output head, and executes the hidden stack through three
interchangeable paths that must agree bit-for-bit:

  * ``reference``  — the jnp program oracle (kernels/logic_dsp/ref.py);
  * ``pallas``     — the Pallas fabric kernel, one launch per layer;
  * ``megakernel`` — the whole hidden stack fused into ONE
    :class:`~repro.core.scheduler.MegaProgram` and executed in a single
    ``pallas_call`` (the layer loop runs *inside* the kernel, stage k's
    output slab gathered straight into stage k+1's input rows);
  * ``engine``     — batched :class:`~repro.serve.LogicEngine` serving.
    With no partition budget the engine serves the per-layer programs as
    a chain-mode megakernel entry (``submit_chain``); with
    ``spec.max_gates`` set it serves the *composed* hidden-stack graph
    (``gate_ir.compose_graphs``) so the budget splits the stack by
    output cones into a parallel-mode pipeline (core/partition.py) —
    either way the runner is one fused launch.

**Packed-word handoff contract** (tested in tests/test_flow.py): for the
reference/pallas paths the input batch is bit-packed ONCE into the
``(n_bits, W)`` word layout (core/packing.py); each layer's packed output
slab is fed directly as the next layer's packed input slab — row i of
layer k's output words IS row i of layer k+1's input words, with no
unpack/repack round-trip between layers. This works because every program
loads its inputs at contiguous buffer rows 2..2+n_inputs and the layer
widths chain (``layers[k].n_outputs == layers[k+1].n_inputs``). Samples
that don't fill the last 32-bit word enter as zero padding; inverting
gates and the constant-1 row flip those lanes, so inter-layer padding
bits are garbage, not zeros — correctness rests on every gate op being
lane-wise (padding lanes can never contaminate real lanes) plus the
single final unpack slicing the padding off.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.gate_ir import LogicGraph, compose_graphs
from repro.core.scheduler import build_megaprogram
from repro.core.simulator import SimResult, simulate_pipeline
from repro.core.spec import CompileSpec, resolve_spec, _UNSET
from repro.flow.convert import CompiledLayer, convert_layer
from repro.kernels.logic_dsp.ops import (forward_words, mega_infer_runner,
                                         pack_bits_jnp, program_arrays,
                                         unpack_bits_jnp)

BACKENDS = ("reference", "pallas", "megakernel", "engine")


def input_bits(x: np.ndarray) -> np.ndarray:
    """Binarize features at the sign/half boundary -> (N, n_features) bool."""
    return (np.asarray(x, dtype=np.float64) >= 0.5)


def hard_forward(params: dict, bits: np.ndarray, n_layers: int
                 ) -> tuple[list[np.ndarray], np.ndarray]:
    """Bit-exact binarized inference: hard {0,1} activations in float64.

    This — not the STE float32 training forward — is the semantic spec the
    logic conversion implements: each hidden activation is
    ``(2a-1) @ W + b >= 0`` evaluated in float64, matching
    ``nullanet.neuron_enumerated``/``neuron_isf`` exactly (the float32
    weights are representable exactly in float64, so the comparison is the
    same one the spec extraction performed). Returns (per-layer {0,1}
    activations including the input, float64 logits).
    """
    acts = [np.asarray(bits, dtype=np.uint8)]
    h = 2.0 * acts[0].astype(np.float64) - 1.0
    for i in range(n_layers - 1):
        y = h @ np.asarray(params[f"w{i}"], np.float64) \
            + np.asarray(params[f"b{i}"], np.float64)
        acts.append((y >= 0).astype(np.uint8))
        h = 2.0 * acts[-1] - 1.0
    logits = h @ np.asarray(params[f"w{n_layers - 1}"], np.float64) \
        + np.asarray(params[f"b{n_layers - 1}"], np.float64)
    return acts, logits


@dataclass
class LogicClassifier:
    """Hidden layers as compiled FFCL programs + numeric argmax head.

    ``spec`` is the :class:`~repro.core.spec.CompileSpec` the layers
    were converted against — the single compilation-target record the
    engine backend, reports, and benchmarks read (``n_unit``/``alloc``/
    ``optimize`` remain as read-only views).
    """

    layers: tuple[CompiledLayer, ...]
    w_out: np.ndarray
    b_out: np.ndarray
    spec: CompileSpec = field(default_factory=CompileSpec)
    _stacked: LogicGraph | None = field(default=None, repr=False)
    _mega: object = field(default=None, repr=False)
    _runners: dict = field(default_factory=dict, repr=False)
    _engine: object = field(default=None, repr=False)

    @property
    def n_unit(self):
        return self.spec.n_unit

    @property
    def alloc(self) -> str:
        return self.spec.alloc

    @property
    def optimize(self):
        return self.spec.optimize

    @property
    def n_features(self) -> int:
        return self.layers[0].n_inputs

    @property
    def n_classes(self) -> int:
        return int(self.w_out.shape[1])

    @property
    def programs(self) -> list:
        return [layer.program for layer in self.layers]

    @property
    def stacked_graph(self) -> LogicGraph:
        """The hidden stack composed into one graph (engine serving path)."""
        if self._stacked is None:
            self._stacked = compose_graphs(
                [layer.graph for layer in self.layers], name="hidden-stack")
        return self._stacked

    @property
    def megaprogram(self):
        """The per-layer programs fused into one chain-mode
        :class:`~repro.core.scheduler.MegaProgram` (the single-launch
        form of the packed-word chain below)."""
        if self._mega is None:
            self._mega = build_megaprogram(
                self.programs, mode="chain", name="hidden-stack")
        return self._mega

    # -- execution ----------------------------------------------------------

    def _chain_runner(self, backend: str):
        """Fused jit for the packed-word chain: pack -> layer programs
        back-to-back on the word slabs -> one final unpack. Mirrors the
        serving engine's runner (serve/logic_engine.py) but chains stages
        input->output instead of concatenating partition outputs."""
        if backend not in self._runners:
            arrs = [program_arrays(layer.program) for layer in self.layers]
            kw = dict(interpret=True, use_ref=(backend == "reference"))

            def run(bits):
                words = pack_bits_jnp(bits)
                for a in arrs:
                    words = forward_words(
                        a["src_a"], a["src_b"], a["dst"], a["opcode"],
                        a["step_branch"], a["output_addrs"], words,
                        n_addr=a["n_addr"], **kw)
                return unpack_bits_jnp(words, bits.shape[0])

            self._runners[backend] = jax.jit(run)
        return self._runners[backend]

    def _serve_engine(self):
        """Default engine over the classifier's FULL spec — including
        ``max_gates``, which partitions the composed hidden stack into a
        pipelined program sequence (the budget is moot for the per-layer
        programs but binds here, exactly as ``build_classifier``
        documents) — so an ``optimize="none"`` build really serves the
        raw netlist on the engine backend too (the A/B contract).
        Callers wanting a shared cache or different serving config pass
        their own engine to :meth:`hidden_bits`."""
        if self._engine is None:
            from repro.serve import LogicEngine
            self._engine = LogicEngine(self.spec, capacity=256)
        return self._engine

    def hidden_bits(self, bits: np.ndarray, backend: str = "reference",
                    engine=None) -> np.ndarray:
        """(N, n_features) bool -> (N, n_hidden_out) bool through ``backend``."""
        bits = np.asarray(bits, dtype=bool)
        if backend in ("reference", "pallas"):
            return np.asarray(self._chain_runner(backend)(jnp.asarray(bits)))
        if backend == "megakernel":
            run = mega_infer_runner(self.megaprogram)
            return np.asarray(run(jnp.asarray(bits)))
        if backend == "engine":
            eng = engine if engine is not None else self._serve_engine()
            # route on the ENGINE's compilation target (a caller-supplied
            # engine may carry its own budget/spec, not the classifier's)
            if eng.spec.max_gates is None and eng.spec.resolved:
                # No partition budget: serve the per-layer programs as a
                # chain-mode megakernel entry — no composed-graph
                # recompile, stage handoff fused in-kernel.
                return eng.serve_chain(
                    [layer.graph for layer in self.layers], bits)
            return eng.serve(self.stacked_graph, bits)
        raise ValueError(f"unknown backend {backend!r}; use one of {BACKENDS}")

    def logits_from_hidden(self, h: np.ndarray) -> np.ndarray:
        """The numeric head on hidden bits: ``(2h-1) @ w_out + b_out``,
        float64 (the one place the head math lives)."""
        return (2.0 * np.asarray(h, np.float64) - 1.0) \
            @ np.asarray(self.w_out, np.float64) \
            + np.asarray(self.b_out, np.float64)

    def logits(self, x: np.ndarray, backend: str = "reference",
               engine=None) -> np.ndarray:
        """Binarize -> hidden stack -> numeric head, float64 logits."""
        h = self.hidden_bits(input_bits(x), backend=backend, engine=engine)
        return self.logits_from_hidden(h)

    def predict(self, x: np.ndarray, backend: str = "reference",
                engine=None) -> np.ndarray:
        return np.argmax(self.logits(x, backend=backend, engine=engine),
                         axis=-1)

    # -- analysis -----------------------------------------------------------

    def simulate(self, n_input_vectors: int) -> SimResult:
        """Cycle estimate: the per-layer programs pipelined on one fabric
        (core/simulator.py double-buffered multi-FFCL model)."""
        return simulate_pipeline(self.programs, n_input_vectors)

    def layer_stats(self) -> list[dict]:
        return [{**layer.program.stats(),
                 "n_inputs": layer.n_inputs, "n_outputs": layer.n_outputs}
                for layer in self.layers]


def build_classifier(params: dict, n_layers: int, calib_x: np.ndarray,
                     spec: CompileSpec | None = None, *, mode: str = "auto",
                     n_unit=_UNSET, alloc=_UNSET,
                     optimize=_UNSET) -> LogicClassifier:
    """Convert a trained binarized MLP's hidden stack (all layers).

    Calibration activations come from :func:`hard_forward` on the
    calibration set, so ISF care-sets are sampled from exactly the
    function the logic must reproduce.  ``spec`` is the one declarative
    compilation target every layer is converted against
    (``spec.optimize`` is semantics-preserving, so parity holds either
    way — ``"none"`` keeps raw synthesis output for A/B benchmarking;
    ``spec.max_gates`` rides along to the engine backend, which serves
    the composed stack as a pipelined program sequence).  Loose
    ``n_unit``/``alloc``/``optimize`` kwargs are the deprecated
    pre-spec convention.
    """
    spec = resolve_spec(spec, caller="build_classifier", n_unit=n_unit,
                        alloc=alloc, optimize=optimize)
    bits = input_bits(calib_x).astype(np.uint8)
    acts, _ = hard_forward(params, bits, n_layers)
    layers = tuple(
        convert_layer(params[f"w{i}"], params[f"b{i}"], acts[i],
                      spec, mode=mode, name=f"layer{i}")
        for i in range(n_layers - 1))
    return LogicClassifier(
        layers=layers,
        w_out=np.asarray(params[f"w{n_layers - 1}"]),
        b_out=np.asarray(params[f"b{n_layers - 1}"]),
        spec=spec)
