"""Global-norm gradient clipping."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(leaf.astype(jnp.float32)))
                        for leaf in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm
