"""Int8 error-feedback gradient compression for cross-pod all-reduce.

At 1000+ nodes the cross-pod (DCN-class) gradient all-reduce is the scaling
bottleneck; int8 block-quantization cuts its bytes 4x vs fp32 (2x vs bf16).
Error feedback (residual carried into the next step) keeps SGD convergence
[Seide et al. 2014; Karimireddy et al. 2019, arXiv:1901.09847].

Usage in the trainer (per DP-reduced leaf):
    q, scale = ef_compress(g + ef.residual)        # quantize locally
    q_sum    = lax.psum(q.astype(int32), 'pod')    # integer-exact reduce
    g_hat    = decompress(q_sum, psum(scale))      # see ef_decompress_apply
    residual = (g + residual) - dequant(q, scale)  # local error kept
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

_BLOCK = 256


def _pad_to(x: jnp.ndarray, mult: int) -> jnp.ndarray:
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % mult
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    return flat


def compress_int8(g: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Blockwise symmetric int8 quantization. Returns (q int8, scale f32)."""
    flat = _pad_to(g.astype(jnp.float32), _BLOCK).reshape(-1, _BLOCK)
    amax = jnp.max(jnp.abs(flat), axis=1, keepdims=True)
    scale = amax / 127.0 + 1e-12
    q = jnp.clip(jnp.round(flat / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def decompress_int8(q: jnp.ndarray, scale: jnp.ndarray, shape,
                    dtype=jnp.float32) -> jnp.ndarray:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape).astype(dtype)


class ErrorFeedbackState(NamedTuple):
    residual: Any      # pytree like grads


def ef_init(params: Any) -> ErrorFeedbackState:
    return ErrorFeedbackState(
        residual=jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params))


def ef_compress(g: jnp.ndarray, residual: jnp.ndarray):
    """Quantize (g + residual); return (q, scale, new_residual)."""
    target = g.astype(jnp.float32) + residual
    q, scale = compress_int8(target)
    recon = decompress_int8(q, scale, target.shape)
    return q, scale, target - recon


def ef_decompress_apply(q_sum: jnp.ndarray, scale: jnp.ndarray, shape,
                        n_participants: int) -> jnp.ndarray:
    """Average of a psum'd (q*scale) representation.

    Exactness note: we psum the *dequantized* fp32 blocks (q_i * scale_i) so
    heterogeneous per-shard scales are handled; bytes on the wire are int8 q
    + one f32 scale per 256 elements (~4.02 bits/elem overhead-adjusted).
    """
    return (decompress_int8(q_sum, scale, shape) / n_participants)
