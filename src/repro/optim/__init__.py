from repro.optim.adamw import (AdamWState, adamw_init, adamw_update,
                               resolve_moment_dtype)
from repro.optim.schedule import wsd_schedule, cosine_schedule, linear_warmup
from repro.optim.clip import clip_by_global_norm
from repro.optim.compression import (compress_int8, decompress_int8,
                                     ErrorFeedbackState, ef_init, ef_compress,
                                     ef_decompress_apply)

__all__ = [
    "AdamWState", "adamw_init", "adamw_update", "resolve_moment_dtype",
    "wsd_schedule", "cosine_schedule", "linear_warmup",
    "clip_by_global_norm",
    "compress_int8", "decompress_int8", "ErrorFeedbackState", "ef_init",
    "ef_compress", "ef_decompress_apply",
]
