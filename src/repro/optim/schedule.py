"""LR schedules. WSD (warmup-stable-decay) is MiniCPM's schedule
[arXiv:2404.06395] — required by the minicpm-2b assigned architecture."""
from __future__ import annotations

import jax.numpy as jnp


def linear_warmup(step, warmup_steps: int, peak: float):
    s = jnp.asarray(step, jnp.float32)
    return peak * jnp.minimum(1.0, s / jnp.maximum(1, warmup_steps))


def wsd_schedule(peak: float, warmup_steps: int, stable_steps: int,
                 decay_steps: int, final_frac: float = 0.1):
    """Warmup -> Stable (constant) -> Decay (exponential-ish to final_frac)."""

    def fn(step):
        s = jnp.asarray(step, jnp.float32)
        warm = peak * jnp.minimum(1.0, s / jnp.maximum(1, warmup_steps))
        in_decay = jnp.maximum(0.0, s - (warmup_steps + stable_steps))
        frac = jnp.minimum(1.0, in_decay / jnp.maximum(1, decay_steps))
        decay_mult = final_frac ** frac          # 1 -> final_frac
        return jnp.where(s < warmup_steps, warm, peak * decay_mult)

    return fn


def cosine_schedule(peak: float, warmup_steps: int, total_steps: int,
                    final_frac: float = 0.1):
    def fn(step):
        s = jnp.asarray(step, jnp.float32)
        warm = peak * jnp.minimum(1.0, s / jnp.maximum(1, warmup_steps))
        prog = jnp.clip((s - warmup_steps) /
                        jnp.maximum(1, total_steps - warmup_steps), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(s < warmup_steps, warm, peak * cos)

    return fn
