"""AdamW with decoupled weight decay, pytree-native, shard-friendly.

Moments are stored in ``moment_dtype`` (fp32 default; bf16 optional to cut
the optimizer-state memory roofline term in half — see EXPERIMENTS.md §Perf).
State shapes mirror the param pytree, so FSDP shardings apply verbatim.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray     # () int32
    mu: Any               # pytree like params
    nu: Any               # pytree like params


_MOMENT_DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}


def resolve_moment_dtype(name: str):
    """Config-string -> jnp dtype for the moment buffers (the one place
    the supported set lives; Trainer and the launch dry-run both resolve
    ``cfg.moment_dtype`` through here so their optimizer-state footprints
    agree)."""
    try:
        return _MOMENT_DTYPES[name]
    except KeyError:
        raise ValueError(
            f"unknown moment_dtype {name!r}; "
            f"use one of {sorted(_MOMENT_DTYPES)}") from None


def adamw_init(params: Any, moment_dtype=jnp.float32) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, dtype=moment_dtype)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def adamw_update(grads: Any, state: AdamWState, params: Any, *,
                 lr, b1: float = 0.9, b2: float = 0.95,
                 eps: float = 1e-8, weight_decay: float = 0.1
                 ) -> tuple[Any, AdamWState]:
    """Returns (new_params, new_state). ``lr`` may be a scalar or callable(step)."""
    step = state.step + 1
    lr_t = lr(step) if callable(lr) else lr
    b1c = 1.0 - b1 ** step.astype(jnp.float32)
    b2c = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g32 = g.astype(jnp.float32)
        mu_n = b1 * mu.astype(jnp.float32) + (1 - b1) * g32
        nu_n = b2 * nu.astype(jnp.float32) + (1 - b2) * g32 * g32
        mu_hat = mu_n / b1c
        nu_hat = nu_n / b2c
        delta = mu_hat / (jnp.sqrt(nu_hat) + eps) + weight_decay * (
            p.astype(jnp.float32))
        p_n = p.astype(jnp.float32) - lr_t * delta
        return (p_n.astype(p.dtype), mu_n.astype(mu.dtype),
                nu_n.astype(nu.dtype))

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state.mu)
    flat_nu = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, n)
           for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, mu=new_mu, nu=new_nu)
