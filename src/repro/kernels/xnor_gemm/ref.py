"""Oracle for the XNOR GEMM: dense +-1 matmul on unpacked bits."""
from __future__ import annotations

import jax.numpy as jnp


def xnor_gemm_ref(a_bits: jnp.ndarray, b_bits: jnp.ndarray) -> jnp.ndarray:
    """a_bits: (M, K) {0,1}; b_bits: (N, K) {0,1} -> (M, N) int32 +-1 dot."""
    a = 2 * a_bits.astype(jnp.int32) - 1
    b = 2 * b_bits.astype(jnp.int32) - 1
    return a @ b.T
