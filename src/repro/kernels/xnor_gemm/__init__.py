from repro.kernels.xnor_gemm.ops import xnor_gemm, pack_pm1
from repro.kernels.xnor_gemm.ref import xnor_gemm_ref

__all__ = ["xnor_gemm", "pack_pm1", "xnor_gemm_ref"]
