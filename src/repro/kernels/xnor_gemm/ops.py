"""Jit'd public API over the xnor_gemm kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.xnor_gemm import kernel as _k

WORD_BITS = 32


def pack_pm1(bits: jnp.ndarray) -> jnp.ndarray:
    """(R, K) {0,1} bits -> (R, ceil(K/32)) int32, K packed LSB-first."""
    r, k = bits.shape
    kw = -(-k // WORD_BITS)
    b = jnp.pad(bits.astype(jnp.uint32), ((0, 0), (0, kw * WORD_BITS - k)))
    chunks = b.reshape(r, kw, WORD_BITS)
    weights = (jnp.uint32(1) << jnp.arange(WORD_BITS, dtype=jnp.uint32))
    return (chunks * weights).sum(axis=-1, dtype=jnp.uint32).astype(jnp.int32)


def _pad_rows(x: jnp.ndarray, mult: int) -> jnp.ndarray:
    pad = (-x.shape[0]) % mult
    return jnp.pad(x, ((0, pad), (0, 0))) if pad else x


def _pad_cols(x: jnp.ndarray, mult: int) -> jnp.ndarray:
    pad = (-x.shape[1]) % mult
    return jnp.pad(x, ((0, 0), (0, pad))) if pad else x


def xnor_gemm(a_bits: jnp.ndarray, b_bits: jnp.ndarray, *, bm: int = 128,
              bn: int = 128, bk: int = 16, interpret: bool = True
              ) -> jnp.ndarray:
    """Binarized +-1 GEMM: a (M, K) {0,1} x b (N, K) {0,1} -> (M, N) int32."""
    m, k = a_bits.shape
    n, k2 = b_bits.shape
    if k != k2:
        raise ValueError(f"K mismatch: {k} vs {k2}")
    ap = _pad_cols(_pad_rows(pack_pm1(a_bits), bm), bk)
    bp = _pad_cols(_pad_rows(pack_pm1(b_bits), bn), bk)
    out = _k.xnor_gemm_pallas(ap, bp, k_bits=k, bm=bm, bn=bn, bk=bk,
                              interpret=interpret)
    return out[:m, :n]
