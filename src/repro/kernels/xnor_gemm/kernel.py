"""Pallas TPU kernel: XNOR + popcount GEMM (binarized matmul).

The paper's XNOR baseline (§8.3) replaces FINN's LUT-based XNOR unit with a
DSP-based one inside the MVTU. On TPU the same op is a K-bitpacked GEMM:

    dot_{+-1}(a, b) = K - 2 * popcount(a_packed XOR b_packed)

Tiling: grid (M/bm, N/bn, Kw/bk); per step the kernel XORs a (bm, bk) slab
of packed activations against a (bn, bk) slab of packed weights, reduces
popcounts along bk into an int32 (bm, bn) VMEM accumulator. The K grid axis
is innermost so Mosaic pipelines the HBM->VMEM slab DMAs (double buffering)
against the VPU popcount reduction — the same overlap discipline as the
paper's burst/double-buffer design.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _xnor_kernel(a_ref, b_ref, out_ref, acc_ref, *, k_bits: int, n_kw: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]                                   # (bm, bk) int32
    b = b_ref[...]                                   # (bn, bk) int32
    x = jax.lax.population_count(
        (a[:, None, :] ^ b[None, :, :]).astype(jnp.uint32)).astype(jnp.int32)
    acc_ref[...] += x.sum(axis=-1)

    @pl.when(pl.program_id(2) == n_kw - 1)
    def _done():
        # dot = K - 2 * hamming
        out_ref[...] = jnp.int32(k_bits) - 2 * acc_ref[...]


@functools.partial(jax.jit,
                   static_argnames=("k_bits", "bm", "bn", "bk", "interpret"))
def xnor_gemm_pallas(a_packed: jnp.ndarray, b_packed: jnp.ndarray, *,
                     k_bits: int, bm: int = 128, bn: int = 128, bk: int = 16,
                     interpret: bool = True) -> jnp.ndarray:
    """a_packed: (M, Kw) int32; b_packed: (N, Kw) int32 -> (M, N) int32.

    M % bm == N % bn == Kw % bk == 0 (caller pads). Zero-padding BOTH
    operands' K-words is safe: pad XOR pad = 0 contributes nothing to the
    hamming count, and ``k_bits`` counts only real bits.
    """
    m, kw = a_packed.shape
    n, _ = b_packed.shape
    grid = (m // bm, n // bn, kw // bk)
    return pl.pallas_call(
        functools.partial(_xnor_kernel, k_bits=k_bits, n_kw=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(a_packed, b_packed)
