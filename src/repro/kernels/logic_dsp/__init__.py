from repro.kernels.logic_dsp.ops import (logic_forward, logic_infer_bits,
                                         pack_bits_jnp, unpack_bits_jnp)
from repro.kernels.logic_dsp.ref import logic_forward_ref

__all__ = ["logic_forward", "logic_infer_bits", "logic_forward_ref",
           "pack_bits_jnp", "unpack_bits_jnp"]
