from repro.kernels.logic_dsp.ops import (forward_words, logic_forward,
                                         logic_infer_bits, pack_bits_jnp,
                                         program_arrays, unpack_bits_jnp)
from repro.kernels.logic_dsp.ref import logic_forward_ref

__all__ = ["forward_words", "logic_forward", "logic_infer_bits",
           "logic_forward_ref", "pack_bits_jnp", "program_arrays",
           "unpack_bits_jnp"]
