"""Pallas TPU kernel: the "DSP fabric" — levelized gate-program executor.

Maps the paper's hardware architecture (Fig. 3) onto a TPU core:

  BRAM data buffer     -> VMEM scratch ``buf`` (n_addr rows x Wb lanes int32)
  Addr./Opcode buffers -> program streams (n_steps, n_unit), VMEM-resident
                          (replicated across grid steps via a 0-index map)
  DSP registers        -> VREG slabs: per step, gather 2x(n_unit, Wb) operand
                          slabs, apply the step's bitwise op, scatter
                          (n_unit, Wb) results
  48-lane DSP SIMD     -> 32 samples/int32 x Wb lanes per row
  URAM double buffer   -> the Pallas grid pipeline: while block g computes,
                          Mosaic DMAs block g+1's input slab HBM->VMEM
                          (paper §5.2.2/§5.2.3 made structural)

Opcode dispatch is *banked* (DESIGN.md §1.2): the scheduler emits a per-step
branch index (``LogicProgram.step_branch``); homogeneous steps — the common
case after opcode sorting — run ONE specialized bitwise slab op selected by
``jax.lax.switch``, instead of the 8-way chained ``jnp.where`` select the
mixed fallback branch pays. Step fusion further shrinks the ``fori_loop``
trip count (DESIGN.md §1.3).

Grid: one dimension over batch-word blocks (Wb = 128 lanes each). The whole
program executes per block; blocks are independent (batch parallelism), so
the paper's "multiple parallel accelerators" (§5.2.4) appear as grid steps
here and as shard_map shards across chips.

TARGET is TPU; correctness is validated in interpret mode (CPU container).
The dynamic row gather/scatter (jnp.take / .at[].set on the VMEM-resident
value) is the Mosaic-side requirement; tiling keeps every slab (8,128)-
aligned: n_unit is padded to a multiple of 8, Wb = 128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.logic_dsp.ref import apply_step_jnp

LANE = 128      # lane tile (int32)
SUBLANE = 8     # sublane tile

# ---------------------------------------------------------------------------
# launch accounting (counter hook, not timing)
# ---------------------------------------------------------------------------

_launches = 0


def _count_launch() -> None:
    global _launches
    _launches += 1


def launch_count() -> int:
    """Number of ``pl.pallas_call`` invocations *issued* so far.

    The counter increments in the Python body of the launch wrappers, so
    under ``jax.jit`` it counts launches **per trace** (the compiled
    computation replays exactly those launches on every execution) and in
    eager mode once per call.  The benchmark harness pins the megakernel
    row with it: one fresh trace of the fused runner must move the counter
    by exactly 1, whereas the chained per-layer path moves it once per
    stage.
    """
    return _launches


def _logic_kernel(src_a_ref, src_b_ref, dst_ref, opcode_ref,
                  step_branch_ref, inputs_ref, out_addrs_ref, out_ref,
                  *, n_addr: int):
    """One grid step: run the full program over one batch-word block."""
    wb = inputs_ref.shape[1]
    n_steps = src_a_ref.shape[0]

    buf = jnp.zeros((n_addr, wb), jnp.int32)
    buf = buf.at[1, :].set(jnp.int32(-1))                    # const-1 row
    buf = jax.lax.dynamic_update_slice(buf, inputs_ref[...], (2, 0))

    def step(s, buf):
        idx_a = src_a_ref[s]                                  # (n_unit,)
        idx_b = src_b_ref[s]
        a = jnp.take(buf, idx_a, axis=0)                      # (n_unit, Wb)
        b = jnp.take(buf, idx_b, axis=0)
        r = apply_step_jnp(step_branch_ref[s], opcode_ref[s], a, b)
        return buf.at[dst_ref[s]].set(r)

    if n_steps:  # static; a gateless program has (0, n_unit) streams whose
        buf = jax.lax.fori_loop(0, n_steps, step, buf)  # body can't trace
    out_ref[...] = jnp.take(buf, out_addrs_ref[...], axis=0)


def logic_pallas_call(src_a, src_b, dst, opcode, step_branch, input_words,
                      output_addrs, *, n_addr: int, block_w: int = LANE,
                      interpret: bool = True):
    """Launch the kernel over ceil(W / block_w) batch-word blocks.

    Deliberately NOT jit-wrapped at module scope: a global jit cache keys
    traces on the stream *shapes*, so every distinct (n_steps, n_unit, W)
    program retraces into one process-wide cache that outlives program
    eviction and that ``ops.program_arrays``'s per-program memo cannot
    dedupe.  Callers jit per program instead (``ops.logic_infer_bits``'s
    per-program runner cache, the engine's per-entry runners), so traces
    live and die with the program object.

    Args:
      src_a/src_b/dst/opcode: (n_steps, n_unit) int32 (n_unit % 8 == 0
        recommended for sublane alignment; scheduler pads with NOPs).
      step_branch: (n_steps,) int32 per-step dispatch branch
        (opcode for homogeneous steps, MIXED_DISPATCH for mixed ones).
      input_words: (n_inputs, W) int32; W padded to block_w by the caller.
      output_addrs: (n_outputs,) int32.
    Returns:
      (n_outputs, W) int32.
    """
    _count_launch()
    n_inputs, w = input_words.shape
    n_outputs = output_addrs.shape[0]
    if w % block_w:
        raise ValueError(f"W={w} must be a multiple of block_w={block_w}")
    grid = (w // block_w,)

    prog_spec = lambda arr: pl.BlockSpec(arr.shape,
                                         lambda g, nd=arr.ndim: (0,) * nd)
    return pl.pallas_call(
        functools.partial(_logic_kernel, n_addr=n_addr),
        grid=grid,
        in_specs=[
            prog_spec(src_a), prog_spec(src_b), prog_spec(dst),
            prog_spec(opcode), prog_spec(step_branch),
            pl.BlockSpec((n_inputs, block_w), lambda g: (0, g)),
            pl.BlockSpec((n_outputs,), lambda g: (0,)),
        ],
        out_specs=pl.BlockSpec((n_outputs, block_w), lambda g: (0, g)),
        out_shape=jax.ShapeDtypeStruct((n_outputs, w), jnp.int32),
        interpret=interpret,
    )(src_a, src_b, dst, opcode, step_branch, input_words, output_addrs)


# ---------------------------------------------------------------------------
# megakernel: the whole program pipeline in ONE launch
# ---------------------------------------------------------------------------

def _mega_kernel(src_a_ref, src_b_ref, dst_ref, opcode_ref, step_branch_ref,
                 inputs_ref, out_addrs_ref, perm_ref, out_ref, *,
                 n_addr: int, stage_meta: tuple, chain: bool):
    """One grid step: run EVERY stage of the pipeline over one batch-word
    block, the word slab staying resident across stages.

    The stage loop is a *static* Python loop over ``stage_meta``
    (``(step_lo, step_hi, n_inputs, n_outputs, out_lo)`` per stage — the
    MegaProgram offset table); each stage runs its step range of the
    concatenated streams as its own ``fori_loop``.  A gateless stage has
    ``step_hi == step_lo`` and traces NO loop at all — the zero-trip
    guard that ``if n_steps:`` provides for monolithic programs must
    survive per-stage here (a zero-trip ``fori_loop`` body over the
    concatenated streams cannot trace when total_steps == 0, and tracing
    one pointlessly costs compile time when it could).

    Chain mode gathers stage *k*'s output rows into a slab that becomes
    stage *k+1*'s input slice; parallel mode re-reads the primary-input
    block per stage and re-assembles the per-stage output slabs through
    ``perm_ref`` in-kernel.  Every stage starts from a freshly
    re-initialized buffer — the liveness allocator is free to reuse
    const/input rows as gate destinations, so stage *k*'s final buffer is
    NOT a valid initial state for stage *k+1*'s address space; rows the
    re-init does not touch are only ever read after an in-stage write
    (operands are produced at strictly earlier steps), so stale garbage
    in them is unobservable.
    """
    wb = inputs_ref.shape[1]

    def step(s, buf):
        a = jnp.take(buf, src_a_ref[s], axis=0)               # (n_unit, Wb)
        b = jnp.take(buf, src_b_ref[s], axis=0)
        r = apply_step_jnp(step_branch_ref[s], opcode_ref[s], a, b)
        return buf.at[dst_ref[s]].set(r)

    feed = inputs_ref[...]
    slabs = []
    for (step_lo, step_hi, n_in, n_out, out_lo) in stage_meta:
        stage_in = feed if chain else inputs_ref[...]
        buf = jnp.zeros((n_addr, wb), jnp.int32)
        buf = buf.at[1, :].set(jnp.int32(-1))                 # const-1 row
        buf = jax.lax.dynamic_update_slice(buf, stage_in, (2, 0))
        if step_hi > step_lo:          # static; gateless stage: no loop
            buf = jax.lax.fori_loop(step_lo, step_hi, step, buf)
        slab = jnp.take(buf, out_addrs_ref[out_lo:out_lo + n_out], axis=0)
        if chain:
            feed = slab
        else:
            slabs.append(slab)
    if chain:
        out_ref[...] = feed
    else:
        cat = slabs[0] if len(slabs) == 1 else \
            jnp.concatenate(slabs, axis=0)
        out_ref[...] = jnp.take(cat, perm_ref[...], axis=0)


def mega_pallas_call(src_a, src_b, dst, opcode, step_branch, input_words,
                     out_addrs, perm, *, n_addr: int, stage_meta: tuple,
                     chain: bool, block_w: int = LANE,
                     interpret: bool = True):
    """Launch the megakernel: the whole stage pipeline per grid step.

    Args mirror :func:`logic_pallas_call` with the streams concatenated
    along the step axis (``MegaProgram``), plus the static per-stage
    offset table, the flattened per-stage output addresses, and the
    output permutation (identity in chain mode).  Like the monolithic
    wrapper it is not jit-wrapped here — callers key the trace per
    MegaProgram object.
    """
    _count_launch()
    n_inputs, w = input_words.shape
    n_outputs = perm.shape[0]
    if w % block_w:
        raise ValueError(f"W={w} must be a multiple of block_w={block_w}")
    grid = (w // block_w,)

    prog_spec = lambda arr: pl.BlockSpec(arr.shape,
                                         lambda g, nd=arr.ndim: (0,) * nd)
    return pl.pallas_call(
        functools.partial(_mega_kernel, n_addr=n_addr,
                          stage_meta=stage_meta, chain=chain),
        grid=grid,
        in_specs=[
            prog_spec(src_a), prog_spec(src_b), prog_spec(dst),
            prog_spec(opcode), prog_spec(step_branch),
            pl.BlockSpec((n_inputs, block_w), lambda g: (0, g)),
            prog_spec(out_addrs), prog_spec(perm),
        ],
        out_specs=pl.BlockSpec((n_outputs, block_w), lambda g: (0, g)),
        out_shape=jax.ShapeDtypeStruct((n_outputs, w), jnp.int32),
        interpret=interpret,
    )(src_a, src_b, dst, opcode, step_branch, input_words, out_addrs, perm)
