"""Pallas TPU kernel: the "DSP fabric" — levelized gate-program executor.

Maps the paper's hardware architecture (Fig. 3) onto a TPU core:

  BRAM data buffer     -> VMEM scratch ``buf`` (n_addr rows x Wb lanes int32)
  Addr./Opcode buffers -> program streams (n_steps, n_unit), VMEM-resident
                          (replicated across grid steps via a 0-index map)
  DSP registers        -> VREG slabs: per step, gather 2x(n_unit, Wb) operand
                          slabs, apply the step's bitwise op, scatter
                          (n_unit, Wb) results
  48-lane DSP SIMD     -> 32 samples/int32 x Wb lanes per row
  URAM double buffer   -> the Pallas grid pipeline: while block g computes,
                          Mosaic DMAs block g+1's input slab HBM->VMEM
                          (paper §5.2.2/§5.2.3 made structural)

Opcode dispatch is *banked* (DESIGN.md §1.2): the scheduler emits a per-step
branch index (``LogicProgram.step_branch``); homogeneous steps — the common
case after opcode sorting — run ONE specialized bitwise slab op selected by
``jax.lax.switch``, instead of the 8-way chained ``jnp.where`` select the
mixed fallback branch pays. Step fusion further shrinks the ``fori_loop``
trip count (DESIGN.md §1.3).

Grid: one dimension over batch-word blocks (Wb = 128 lanes each). The whole
program executes per block; blocks are independent (batch parallelism), so
the paper's "multiple parallel accelerators" (§5.2.4) appear as grid steps
here and as shard_map shards across chips.

TARGET is TPU; correctness is validated in interpret mode (CPU container).
The dynamic row gather/scatter (jnp.take / .at[].set on the VMEM-resident
value) is the Mosaic-side requirement; tiling keeps every slab (8,128)-
aligned: n_unit is padded to a multiple of 8, Wb = 128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.logic_dsp.ref import apply_step_jnp

LANE = 128      # lane tile (int32)
SUBLANE = 8     # sublane tile


def _logic_kernel(src_a_ref, src_b_ref, dst_ref, opcode_ref,
                  step_branch_ref, inputs_ref, out_addrs_ref, out_ref,
                  *, n_addr: int):
    """One grid step: run the full program over one batch-word block."""
    wb = inputs_ref.shape[1]
    n_steps = src_a_ref.shape[0]

    buf = jnp.zeros((n_addr, wb), jnp.int32)
    buf = buf.at[1, :].set(jnp.int32(-1))                    # const-1 row
    buf = jax.lax.dynamic_update_slice(buf, inputs_ref[...], (2, 0))

    def step(s, buf):
        idx_a = src_a_ref[s]                                  # (n_unit,)
        idx_b = src_b_ref[s]
        a = jnp.take(buf, idx_a, axis=0)                      # (n_unit, Wb)
        b = jnp.take(buf, idx_b, axis=0)
        r = apply_step_jnp(step_branch_ref[s], opcode_ref[s], a, b)
        return buf.at[dst_ref[s]].set(r)

    if n_steps:  # static; a gateless program has (0, n_unit) streams whose
        buf = jax.lax.fori_loop(0, n_steps, step, buf)  # body can't trace
    out_ref[...] = jnp.take(buf, out_addrs_ref[...], axis=0)


@functools.partial(jax.jit, static_argnames=("n_addr", "block_w", "interpret"))
def logic_pallas_call(src_a, src_b, dst, opcode, step_branch, input_words,
                      output_addrs, *, n_addr: int, block_w: int = LANE,
                      interpret: bool = True):
    """Launch the kernel over ceil(W / block_w) batch-word blocks.

    Args:
      src_a/src_b/dst/opcode: (n_steps, n_unit) int32 (n_unit % 8 == 0
        recommended for sublane alignment; scheduler pads with NOPs).
      step_branch: (n_steps,) int32 per-step dispatch branch
        (opcode for homogeneous steps, MIXED_DISPATCH for mixed ones).
      input_words: (n_inputs, W) int32; W padded to block_w by the caller.
      output_addrs: (n_outputs,) int32.
    Returns:
      (n_outputs, W) int32.
    """
    n_inputs, w = input_words.shape
    n_outputs = output_addrs.shape[0]
    if w % block_w:
        raise ValueError(f"W={w} must be a multiple of block_w={block_w}")
    grid = (w // block_w,)

    prog_spec = lambda arr: pl.BlockSpec(arr.shape,
                                         lambda g, nd=arr.ndim: (0,) * nd)
    return pl.pallas_call(
        functools.partial(_logic_kernel, n_addr=n_addr),
        grid=grid,
        in_specs=[
            prog_spec(src_a), prog_spec(src_b), prog_spec(dst),
            prog_spec(opcode), prog_spec(step_branch),
            pl.BlockSpec((n_inputs, block_w), lambda g: (0, g)),
            pl.BlockSpec((n_outputs,), lambda g: (0,)),
        ],
        out_specs=pl.BlockSpec((n_outputs, block_w), lambda g: (0, g)),
        out_shape=jax.ShapeDtypeStruct((n_outputs, w), jnp.int32),
        interpret=interpret,
    )(src_a, src_b, dst, opcode, step_branch, input_words, output_addrs)
