"""Jit'd public API over the logic_dsp kernel + jnp bit packing."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.scheduler import LogicProgram
from repro.kernels.logic_dsp import kernel as _k
from repro.kernels.logic_dsp.ref import logic_forward_ref

WORD_BITS = 32


def pack_bits_jnp(bits: jnp.ndarray) -> jnp.ndarray:
    """(batch, n) bool -> (n, ceil(batch/32)) int32 (LSB-first), jit-safe."""
    batch, n = bits.shape
    w = -(-batch // WORD_BITS)
    pad = w * WORD_BITS - batch
    b = jnp.pad(bits.astype(jnp.uint32), ((0, pad), (0, 0)))
    chunks = b.reshape(w, WORD_BITS, n)
    weights = (jnp.uint32(1) << jnp.arange(WORD_BITS, dtype=jnp.uint32))
    words = (chunks * weights[None, :, None]).sum(axis=1, dtype=jnp.uint32)
    return words.astype(jnp.int32).T


def unpack_bits_jnp(words: jnp.ndarray, batch: int) -> jnp.ndarray:
    """(n, W) int32 -> (batch, n) bool."""
    n, w = words.shape
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    bits = (words.astype(jnp.uint32)[:, :, None] >> shifts) & jnp.uint32(1)
    return bits.reshape(n, w * WORD_BITS).T[:batch].astype(bool)


def _pad_words(words: jnp.ndarray, block_w: int) -> jnp.ndarray:
    w = words.shape[1]
    pad = (-w) % block_w
    if pad:
        words = jnp.pad(words, ((0, 0), (0, pad)))
    return words


def program_arrays(prog: LogicProgram, pad_unit: int = 8) -> dict:
    """Program streams as device arrays, n_unit padded to sublane multiple."""
    pad = (-prog.n_unit) % pad_unit

    def p(a, fill):
        a = np.asarray(a, dtype=np.int32)
        if pad:
            a = np.pad(a, ((0, 0), (0, pad)), constant_values=fill)
        return jnp.asarray(a)

    return {
        "src_a": p(prog.src_a, 0), "src_b": p(prog.src_b, 0),
        "dst": p(prog.dst, prog.trash_addr), "opcode": p(prog.opcode, 0),
        "output_addrs": jnp.asarray(prog.output_addrs, dtype=jnp.int32),
        "n_addr": prog.n_addr,
    }


def logic_forward(prog: LogicProgram, input_words: jnp.ndarray,
                  block_w: int = _k.LANE, interpret: bool = True,
                  use_ref: bool = False) -> jnp.ndarray:
    """Packed-word forward: (n_inputs, W) int32 -> (n_outputs, W) int32."""
    arrs = program_arrays(prog)
    w = input_words.shape[1]
    if use_ref:
        return logic_forward_ref(
            arrs["src_a"], arrs["src_b"], arrs["dst"], arrs["opcode"],
            input_words, arrs["output_addrs"], arrs["n_addr"])
    padded = _pad_words(input_words, block_w)
    out = _k.logic_pallas_call(
        arrs["src_a"], arrs["src_b"], arrs["dst"], arrs["opcode"],
        padded, arrs["output_addrs"],
        n_addr=arrs["n_addr"], block_w=block_w, interpret=interpret)
    return out[:, :w]


def logic_infer_bits(prog: LogicProgram, bits: np.ndarray | jnp.ndarray,
                     **kw) -> np.ndarray:
    """Boolean convenience wrapper: (batch, n_inputs) -> (batch, n_outputs)."""
    bits = jnp.asarray(bits, dtype=bool)
    batch = bits.shape[0]
    words = pack_bits_jnp(bits)
    out = logic_forward(prog, words, **kw)
    return np.asarray(unpack_bits_jnp(out, batch))
