"""Jit'd public API over the logic_dsp kernel + jnp bit packing.

Jit caching is **per program object**, not module-global: each
(frozen, immutable) :class:`LogicProgram` / :class:`MegaProgram` carries
its own runner cache (attached the same way :func:`program_arrays`
memoizes device arrays), so a program's traces are deduped against ITS
prior calls and released with the object — a module-scope ``jax.jit``
would key on stream shapes, retrace once per distinct
``(n_steps, n_unit, W)`` into a process-wide cache, and keep evicted
programs' traces alive forever.  ``trace_count()`` observes actual
retraces (the counter bumps inside the traced Python body) so tests can
pin the contract.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import calibrate as _calibrate
from repro.core.scheduler import LogicProgram, MegaProgram
from repro.kernels.logic_dsp import kernel as _k
from repro.kernels.logic_dsp.ref import logic_forward_ref

WORD_BITS = 32

_traces = 0


def _count_trace() -> None:
    global _traces
    _traces += 1


def trace_count() -> int:
    """Number of runner *traces* taken so far (bumped inside the traced
    body, so a jit cache hit does not move it)."""
    return _traces


def _runner_cache(prog) -> dict:
    """The per-program jit-runner cache, created on first use and attached
    to the (frozen) program object — same lifetime trick as the
    ``program_arrays`` memo, so traces die with the program."""
    cache = getattr(prog, "_jit_runners", None)
    if cache is None:
        cache = {}
        object.__setattr__(prog, "_jit_runners", cache)
    return cache


def pack_bits_jnp(bits: jnp.ndarray) -> jnp.ndarray:
    """(batch, n) bool -> (n, ceil(batch/32)) int32 (LSB-first), jit-safe."""
    batch, n = bits.shape
    w = -(-batch // WORD_BITS)
    pad = w * WORD_BITS - batch
    b = jnp.pad(bits.astype(jnp.uint32), ((0, pad), (0, 0)))
    chunks = b.reshape(w, WORD_BITS, n)
    weights = (jnp.uint32(1) << jnp.arange(WORD_BITS, dtype=jnp.uint32))
    words = (chunks * weights[None, :, None]).sum(axis=1, dtype=jnp.uint32)
    return words.astype(jnp.int32).T


def unpack_bits_jnp(words: jnp.ndarray, batch: int) -> jnp.ndarray:
    """(n, W) int32 -> (batch, n) bool."""
    n, w = words.shape
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    bits = (words.astype(jnp.uint32)[:, :, None] >> shifts) & jnp.uint32(1)
    return bits.reshape(n, w * WORD_BITS).T[:batch].astype(bool)


def _pad_words(words: jnp.ndarray, block_w: int) -> jnp.ndarray:
    w = words.shape[1]
    pad = (-w) % block_w
    if pad:
        words = jnp.pad(words, ((0, 0), (0, pad)))
    return words


def program_arrays(prog: LogicProgram, pad_unit: int = 8) -> dict:
    """Program streams as device arrays, n_unit padded to sublane multiple.

    NOP padding (opcode 0, sources at row 0, dst at trash) preserves step
    homogeneity: the specialized slab op also runs on padded rows, whose
    results land on the trash address and are never read.

    The result is memoized on the (frozen, immutable) program object:
    the streams are per-program constants, and re-padding/re-uploading
    them on every inference call would sit in the hot loop.
    """
    cached = getattr(prog, "_device_arrays", None)
    if cached is not None and cached[0] == pad_unit:
        return cached[1]
    pad = (-prog.n_unit) % pad_unit

    def p(a, fill):
        a = np.asarray(a, dtype=np.int32)
        if pad:
            a = np.pad(a, ((0, 0), (0, pad)), constant_values=fill)
        return jnp.asarray(a)

    arrs = {
        "src_a": p(prog.src_a, 0), "src_b": p(prog.src_b, 0),
        "dst": p(prog.dst, prog.trash_addr), "opcode": p(prog.opcode, 0),
        "step_branch": jnp.asarray(prog.step_branch, dtype=jnp.int32),
        "output_addrs": jnp.asarray(prog.output_addrs, dtype=jnp.int32),
        "n_addr": prog.n_addr,
    }
    object.__setattr__(prog, "_device_arrays", (pad_unit, arrs))
    return arrs


def forward_words(src_a, src_b, dst, opcode, step_branch, output_addrs,
                  words: jnp.ndarray, *, n_addr: int,
                  block_w: int = _k.LANE, interpret: bool = True,
                  use_ref: bool = False) -> jnp.ndarray:
    """Word-level program execution: (n_inputs, W) -> (n_outputs, W) int32.

    Jit-safe core shared by :func:`logic_forward`, the fused
    :func:`logic_infer_bits` path, and the serving engine
    (serve/logic_engine.py), which amortizes one call across all queued
    samples of a batch slot table. Gateless programs (0 steps) fall back to
    the jnp reference: pallas rejects the (0, n_unit) stream block shape.
    """
    if use_ref or src_a.shape[0] == 0:
        return logic_forward_ref(src_a, src_b, dst, opcode, words,
                                 output_addrs, n_addr,
                                 step_branch=step_branch)
    # never pad a small batch out to a full lane tile: clamping the block
    # to the (sublane-rounded) word count keeps the grid at one step while
    # shrinking the padded compute (a 10-word batch runs 16 wide, not 128)
    block_w = min(block_w,
                  -(-words.shape[1] // _k.SUBLANE) * _k.SUBLANE)
    padded = _pad_words(words, block_w)
    out = _k.logic_pallas_call(
        src_a, src_b, dst, opcode, step_branch, padded, output_addrs,
        n_addr=n_addr, block_w=block_w, interpret=interpret)
    return out[:, :words.shape[1]]


def logic_forward(prog: LogicProgram, input_words: jnp.ndarray,
                  block_w: int = _k.LANE, interpret: bool = True,
                  use_ref: bool = False) -> jnp.ndarray:
    """Packed-word forward: (n_inputs, W) int32 -> (n_outputs, W) int32."""
    arrs = program_arrays(prog)
    return forward_words(
        arrs["src_a"], arrs["src_b"], arrs["dst"], arrs["opcode"],
        arrs["step_branch"], arrs["output_addrs"], input_words,
        n_addr=arrs["n_addr"], block_w=block_w, interpret=interpret,
        use_ref=use_ref)


def infer_runner(prog: LogicProgram, block_w: int = _k.LANE,
                 interpret: bool = True, use_ref: bool = False):
    """The program's fused pack -> execute -> unpack jit runner, cached ON
    the program object per kernel config.

    Keeping the bit (un)packing inside the same XLA computation as the
    kernel matters: eagerly dispatched pack/unpack around the (sub-ms)
    program execution used to dominate end-to-end latency by >10x.  The
    per-program cache (not a module-scope jit) is what lets repeat calls
    on one program — the engine-runner pattern — hit exactly one trace
    per batch shape, and lets eviction drop the traces with the program.
    """
    cache = _runner_cache(prog)
    key = ("bits", block_w, interpret, use_ref)
    fn = cache.get(key)
    if fn is None:
        arrs = program_arrays(prog)

        def run(bits):
            _count_trace()
            words = pack_bits_jnp(bits)
            out = forward_words(
                arrs["src_a"], arrs["src_b"], arrs["dst"], arrs["opcode"],
                arrs["step_branch"], arrs["output_addrs"], words,
                n_addr=arrs["n_addr"], block_w=block_w,
                interpret=interpret, use_ref=use_ref)
            return unpack_bits_jnp(out, bits.shape[0])

        fn = jax.jit(run)
        cache[key] = fn
    return fn


def logic_infer_bits(prog: LogicProgram, bits: np.ndarray | jnp.ndarray,
                     block_w: int = _k.LANE, interpret: bool = True,
                     use_ref: bool = False) -> np.ndarray:
    """Boolean convenience wrapper: (batch, n_inputs) -> (batch, n_outputs).

    While a :class:`~repro.core.calibrate.PhaseTimer` is active the call
    routes through :func:`phased_infer_bits` and records its per-phase
    wall-clock split on the timer; disabled (the default), the check is
    one module-attribute read — zero overhead on the fused hot path.
    """
    timer = _calibrate._ACTIVE
    if timer is not None:
        out, phases = phased_infer_bits(prog, bits, block_w=block_w,
                                        interpret=interpret, use_ref=use_ref)
        timer.record(phases, backend="ref" if use_ref else "pallas",
                     n_unit=prog.n_unit,
                     batch=int(np.asarray(bits).shape[0]))
        return out
    bits = jnp.asarray(bits, dtype=bool)
    run = infer_runner(prog, block_w=block_w, interpret=interpret,
                       use_ref=use_ref)
    return np.asarray(run(bits))


# ---------------------------------------------------------------------------
# phase-split execution (calibration measurement path, DESIGN.md §12)
# ---------------------------------------------------------------------------

def _host_streams(prog: LogicProgram, pad_unit: int = 8) -> dict:
    """The :func:`program_arrays` padding, but as HOST numpy arrays and
    memoized separately — the phased path re-uploads them every call so
    the ``setup`` phase times an actual program-stream transfer instead
    of a device-cache hit."""
    cached = getattr(prog, "_phase_host_arrays", None)
    if cached is not None and cached[0] == pad_unit:
        return cached[1]
    pad = (-prog.n_unit) % pad_unit

    def p(a, fill):
        a = np.asarray(a, dtype=np.int32)
        if pad:
            a = np.pad(a, ((0, 0), (0, pad)), constant_values=fill)
        return a

    arrs = {
        "src_a": p(prog.src_a, 0), "src_b": p(prog.src_b, 0),
        "dst": p(prog.dst, prog.trash_addr), "opcode": p(prog.opcode, 0),
        "step_branch": np.asarray(prog.step_branch, dtype=np.int32),
        "output_addrs": np.asarray(prog.output_addrs, dtype=np.int32),
    }
    object.__setattr__(prog, "_phase_host_arrays", (pad_unit, arrs))
    return arrs


def phased_infer_bits(prog: LogicProgram, bits: np.ndarray | jnp.ndarray,
                      block_w: int = _k.LANE, interpret: bool = True,
                      use_ref: bool = False
                      ) -> tuple[np.ndarray, dict[str, float]]:
    """One inference split into the four calibration phases.

    Returns ``(out, phases)`` where ``phases`` maps each of
    ``core.calibrate.PHASES`` to seconds, each boundary forced with
    ``block_until_ready`` so async dispatch cannot smear a phase into
    its neighbour:

        pack    H2D of the boolean batch + jitted bit packing
        setup   fresh device_put of every program stream (what the
                memoized fast path amortizes away)
        kernel  the jitted program execution over packed words
        unpack  jitted unpacking + D2H of the result

    The output is bit-identical to :func:`logic_infer_bits` (same kernel
    body, pinned by tests); only the fusion boundaries differ, which is
    why the fused runner — not this path — stays the serving hot path.
    Runners are cached per program object like :func:`infer_runner`.
    """
    cache = _runner_cache(prog)
    key = ("phases", block_w, interpret, use_ref)
    fns = cache.get(key)
    if fns is None:
        def compute(streams, words):
            _count_trace()
            return forward_words(
                streams["src_a"], streams["src_b"], streams["dst"],
                streams["opcode"], streams["step_branch"],
                streams["output_addrs"], words, n_addr=prog.n_addr,
                block_w=block_w, interpret=interpret, use_ref=use_ref)

        fns = (jax.jit(pack_bits_jnp), jax.jit(compute),
               jax.jit(unpack_bits_jnp, static_argnums=(1,)))
        cache[key] = fns
    pack_fn, compute_fn, unpack_fn = fns
    host = _host_streams(prog)
    batch = int(np.asarray(bits).shape[0])
    t = time.perf_counter

    t0 = t()
    dev_bits = jax.block_until_ready(jnp.asarray(bits, dtype=bool))
    words = jax.block_until_ready(pack_fn(dev_bits))
    t1 = t()
    streams = jax.block_until_ready(
        {k: jax.device_put(v) for k, v in host.items()})
    t2 = t()
    out_words = jax.block_until_ready(compute_fn(streams, words))
    t3 = t()
    out = np.asarray(jax.block_until_ready(unpack_fn(out_words, batch)))
    t4 = t()
    phases = {"pack": t1 - t0, "setup": t2 - t1, "kernel": t3 - t2,
              "unpack": t4 - t3}
    return out, phases


# ---------------------------------------------------------------------------
# megaprogram execution (single-launch pipelines)
# ---------------------------------------------------------------------------

def mega_arrays(mega: MegaProgram, pad_unit: int = 8) -> dict:
    """MegaProgram streams as device arrays, lanes padded to a sublane
    multiple — the NOP fill writes each step's OWN stage trash row
    (``mega.step_trash``), since stages may size their buffers
    differently and a foreign trash row could alias a live address.
    Memoized on the (frozen) mega object like :func:`program_arrays` —
    but as HOST (numpy) arrays: mega runners call this from inside their
    own trace, where a ``jnp.asarray`` result would be a tracer that must
    not leak into the memo.  Numpy streams embed as constants at trace
    time, so the jitted runner pays the upload once per trace either
    way."""
    cached = getattr(mega, "_host_arrays", None)
    if cached is not None and cached[0] == pad_unit:
        return cached[1]
    pad = (-mega.n_unit) % pad_unit

    def p(a, fill):
        a = np.asarray(a, dtype=np.int32)
        if pad:
            fill_cols = np.broadcast_to(
                np.asarray(fill, dtype=np.int32).reshape(-1, 1),
                (a.shape[0], pad))
            a = np.concatenate([a, fill_cols], axis=1)
        return a

    zeros = np.zeros(mega.total_steps, dtype=np.int32)
    arrs = {
        "src_a": p(mega.src_a, zeros), "src_b": p(mega.src_b, zeros),
        "dst": p(mega.dst, mega.step_trash),
        "opcode": p(mega.opcode, zeros),
        "step_branch": np.asarray(mega.step_branch, dtype=np.int32),
        "out_addrs": np.asarray(mega.out_addrs, dtype=np.int32),
        "perm": np.asarray(mega.output_perm, dtype=np.int32),
    }
    object.__setattr__(mega, "_host_arrays", (pad_unit, arrs))
    return arrs


def _mega_forward_ref(mega: MegaProgram, arrs: dict,
                      words: jnp.ndarray) -> jnp.ndarray:
    """jnp reference for mega execution: the per-stage
    :func:`logic_forward_ref` chain / fan-out the fused kernel replaces.
    Also the fallback when the pipeline has zero total steps (pallas
    rejects (0, n_unit) stream blocks)."""
    def stage(meta):
        step_lo, step_hi, n_in, n_out, out_lo = meta
        # slices go through jnp: logic_forward_ref's fori_loop indexes the
        # streams with a traced step counter, which numpy can't do
        def run(stage_words):
            return logic_forward_ref(
                jnp.asarray(arrs["src_a"][step_lo:step_hi]),
                jnp.asarray(arrs["src_b"][step_lo:step_hi]),
                jnp.asarray(arrs["dst"][step_lo:step_hi]),
                jnp.asarray(arrs["opcode"][step_lo:step_hi]), stage_words,
                jnp.asarray(arrs["out_addrs"][out_lo:out_lo + n_out]),
                mega.n_addr,
                step_branch=jnp.asarray(
                    arrs["step_branch"][step_lo:step_hi]))
        return run

    if mega.mode == "chain":
        h = words
        for meta in mega.stage_meta:
            h = stage(meta)(h)
        return h
    slabs = [stage(meta)(words) for meta in mega.stage_meta]
    cat = slabs[0] if len(slabs) == 1 else jnp.concatenate(slabs, axis=0)
    return jnp.take(cat, arrs["perm"], axis=0)


def mega_forward_words(mega: MegaProgram, words: jnp.ndarray, *,
                       block_w: int = _k.LANE, interpret: bool = True,
                       use_ref: bool = False) -> jnp.ndarray:
    """Word-level mega execution: (n_inputs, W) -> (n_outputs, W) int32 in
    ONE kernel launch (or the stage-chained jnp reference)."""
    arrs = mega_arrays(mega)
    if use_ref or mega.total_steps == 0:
        return _mega_forward_ref(mega, arrs, words)
    # same small-batch clamp as forward_words: one grid step, minimal pad
    block_w = min(block_w,
                  -(-words.shape[1] // _k.SUBLANE) * _k.SUBLANE)
    padded = _pad_words(words, block_w)
    out = _k.mega_pallas_call(
        arrs["src_a"], arrs["src_b"], arrs["dst"], arrs["opcode"],
        arrs["step_branch"], padded, arrs["out_addrs"], arrs["perm"],
        n_addr=mega.n_addr, stage_meta=mega.stage_meta,
        chain=(mega.mode == "chain"), block_w=block_w, interpret=interpret)
    return out[:, :words.shape[1]]


def mega_infer_runner(mega: MegaProgram, block_w: int = _k.LANE,
                      interpret: bool = True, use_ref: bool = False):
    """Fused pack -> megakernel -> unpack jit, cached on the mega object
    (one trace per batch shape per config — the single-launch analogue of
    :func:`infer_runner`)."""
    cache = _runner_cache(mega)
    key = ("bits", block_w, interpret, use_ref)
    fn = cache.get(key)
    if fn is None:
        def run(bits):
            _count_trace()
            words = pack_bits_jnp(bits)
            out = mega_forward_words(mega, words, block_w=block_w,
                                     interpret=interpret, use_ref=use_ref)
            return unpack_bits_jnp(out, bits.shape[0])

        fn = jax.jit(run)
        cache[key] = fn
    return fn


def mega_infer_bits(mega: MegaProgram, bits: np.ndarray | jnp.ndarray,
                    block_w: int = _k.LANE, interpret: bool = True,
                    use_ref: bool = False) -> np.ndarray:
    """Boolean convenience wrapper over the megakernel:
    (batch, n_inputs) -> (batch, n_outputs) in one launch."""
    bits = jnp.asarray(bits, dtype=bool)
    run = mega_infer_runner(mega, block_w=block_w, interpret=interpret,
                            use_ref=use_ref)
    return np.asarray(run(bits))
