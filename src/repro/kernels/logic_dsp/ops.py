"""Jit'd public API over the logic_dsp kernel + jnp bit packing."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.scheduler import LogicProgram
from repro.kernels.logic_dsp import kernel as _k
from repro.kernels.logic_dsp.ref import logic_forward_ref

WORD_BITS = 32


def pack_bits_jnp(bits: jnp.ndarray) -> jnp.ndarray:
    """(batch, n) bool -> (n, ceil(batch/32)) int32 (LSB-first), jit-safe."""
    batch, n = bits.shape
    w = -(-batch // WORD_BITS)
    pad = w * WORD_BITS - batch
    b = jnp.pad(bits.astype(jnp.uint32), ((0, pad), (0, 0)))
    chunks = b.reshape(w, WORD_BITS, n)
    weights = (jnp.uint32(1) << jnp.arange(WORD_BITS, dtype=jnp.uint32))
    words = (chunks * weights[None, :, None]).sum(axis=1, dtype=jnp.uint32)
    return words.astype(jnp.int32).T


def unpack_bits_jnp(words: jnp.ndarray, batch: int) -> jnp.ndarray:
    """(n, W) int32 -> (batch, n) bool."""
    n, w = words.shape
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    bits = (words.astype(jnp.uint32)[:, :, None] >> shifts) & jnp.uint32(1)
    return bits.reshape(n, w * WORD_BITS).T[:batch].astype(bool)


def _pad_words(words: jnp.ndarray, block_w: int) -> jnp.ndarray:
    w = words.shape[1]
    pad = (-w) % block_w
    if pad:
        words = jnp.pad(words, ((0, 0), (0, pad)))
    return words


def program_arrays(prog: LogicProgram, pad_unit: int = 8) -> dict:
    """Program streams as device arrays, n_unit padded to sublane multiple.

    NOP padding (opcode 0, sources at row 0, dst at trash) preserves step
    homogeneity: the specialized slab op also runs on padded rows, whose
    results land on the trash address and are never read.

    The result is memoized on the (frozen, immutable) program object:
    the streams are per-program constants, and re-padding/re-uploading
    them on every inference call would sit in the hot loop.
    """
    cached = getattr(prog, "_device_arrays", None)
    if cached is not None and cached[0] == pad_unit:
        return cached[1]
    pad = (-prog.n_unit) % pad_unit

    def p(a, fill):
        a = np.asarray(a, dtype=np.int32)
        if pad:
            a = np.pad(a, ((0, 0), (0, pad)), constant_values=fill)
        return jnp.asarray(a)

    arrs = {
        "src_a": p(prog.src_a, 0), "src_b": p(prog.src_b, 0),
        "dst": p(prog.dst, prog.trash_addr), "opcode": p(prog.opcode, 0),
        "step_branch": jnp.asarray(prog.step_branch, dtype=jnp.int32),
        "output_addrs": jnp.asarray(prog.output_addrs, dtype=jnp.int32),
        "n_addr": prog.n_addr,
    }
    object.__setattr__(prog, "_device_arrays", (pad_unit, arrs))
    return arrs


def forward_words(src_a, src_b, dst, opcode, step_branch, output_addrs,
                  words: jnp.ndarray, *, n_addr: int,
                  block_w: int = _k.LANE, interpret: bool = True,
                  use_ref: bool = False) -> jnp.ndarray:
    """Word-level program execution: (n_inputs, W) -> (n_outputs, W) int32.

    Jit-safe core shared by :func:`logic_forward`, the fused
    :func:`logic_infer_bits` path, and the serving engine
    (serve/logic_engine.py), which amortizes one call across all queued
    samples of a batch slot table. Gateless programs (0 steps) fall back to
    the jnp reference: pallas rejects the (0, n_unit) stream block shape.
    """
    if use_ref or src_a.shape[0] == 0:
        return logic_forward_ref(src_a, src_b, dst, opcode, words,
                                 output_addrs, n_addr,
                                 step_branch=step_branch)
    padded = _pad_words(words, block_w)
    out = _k.logic_pallas_call(
        src_a, src_b, dst, opcode, step_branch, padded, output_addrs,
        n_addr=n_addr, block_w=block_w, interpret=interpret)
    return out[:, :words.shape[1]]


def logic_forward(prog: LogicProgram, input_words: jnp.ndarray,
                  block_w: int = _k.LANE, interpret: bool = True,
                  use_ref: bool = False) -> jnp.ndarray:
    """Packed-word forward: (n_inputs, W) int32 -> (n_outputs, W) int32."""
    arrs = program_arrays(prog)
    return forward_words(
        arrs["src_a"], arrs["src_b"], arrs["dst"], arrs["opcode"],
        arrs["step_branch"], arrs["output_addrs"], input_words,
        n_addr=arrs["n_addr"], block_w=block_w, interpret=interpret,
        use_ref=use_ref)


@functools.partial(jax.jit, static_argnames=("n_addr", "block_w",
                                             "interpret", "use_ref"))
def _infer_bits_packed(src_a, src_b, dst, opcode, step_branch, output_addrs,
                       bits, *, n_addr: int, block_w: int, interpret: bool,
                       use_ref: bool):
    """One fused jit: pack -> program execution -> unpack.

    Keeping the bit (un)packing inside the same XLA computation as the
    kernel matters: eagerly dispatched pack/unpack around the (sub-ms)
    program execution used to dominate end-to-end latency by >10x.
    """
    words = pack_bits_jnp(bits)
    out = forward_words(src_a, src_b, dst, opcode, step_branch, output_addrs,
                        words, n_addr=n_addr, block_w=block_w,
                        interpret=interpret, use_ref=use_ref)
    return unpack_bits_jnp(out, bits.shape[0])


def logic_infer_bits(prog: LogicProgram, bits: np.ndarray | jnp.ndarray,
                     block_w: int = _k.LANE, interpret: bool = True,
                     use_ref: bool = False) -> np.ndarray:
    """Boolean convenience wrapper: (batch, n_inputs) -> (batch, n_outputs)."""
    bits = jnp.asarray(bits, dtype=bool)
    arrs = program_arrays(prog)
    out = _infer_bits_packed(
        arrs["src_a"], arrs["src_b"], arrs["dst"], arrs["opcode"],
        arrs["step_branch"], arrs["output_addrs"], bits,
        n_addr=arrs["n_addr"], block_w=block_w, interpret=interpret,
        use_ref=use_ref)
    return np.asarray(out)
