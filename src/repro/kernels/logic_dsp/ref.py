"""Pure-jnp oracle for the logic_dsp kernel.

Semantics contract (identical to ``scheduler.execute_program_np``): a data
buffer of ``n_addr`` int32 rows; row 0 = const0, row 1 = const1 (all ones),
rows 2..2+n_inputs hold the packed primary inputs; per sub-kernel step,
unit u computes ``opcode[s,u]`` over rows ``src_a[s,u]``/``src_b[s,u]`` and
writes row ``dst[s,u]`` (NOPs write a trash row). Outputs are gathered from
``output_addrs`` at the end.

Dispatch is *banked* (DESIGN.md §1.2): the scheduler sorts each level's
gates by opcode, so nearly every step is opcode-homogeneous and executes
one specialized slab op selected by ``jax.lax.switch`` on the per-step
branch index; only mixed tail steps pay the generic 8-way chained select.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp



def apply_opcode_jnp(op: jnp.ndarray, a: jnp.ndarray,
                     b: jnp.ndarray) -> jnp.ndarray:
    """Generic vectorized opcode dispatch; ``op`` broadcasts against a/b
    (int32). Used for mixed-opcode steps only."""
    ones = jnp.int32(-1)
    r = jnp.zeros_like(a)                                   # NOP = 0
    r = jnp.where(op == 1, a & b, r)                        # AND
    r = jnp.where(op == 2, a | b, r)                        # OR
    r = jnp.where(op == 3, a ^ b, r)                        # XOR
    r = jnp.where(op == 4, (a & b) ^ ones, r)               # NAND
    r = jnp.where(op == 5, (a | b) ^ ones, r)               # NOR
    r = jnp.where(op == 6, (a ^ b) ^ ones, r)               # XNOR
    r = jnp.where(op == 7, a ^ ones, r)                     # NOT
    r = jnp.where(op == 8, a, r)                            # COPY
    return r


# Branch k (k < MIXED_DISPATCH) is the specialized slab op for opcode k —
# applied to ALL unit rows of the step, including NOP-padding rows, whose
# results land on the trash address and are never read. Branch
# MIXED_DISPATCH is the generic fallback for ragged mixed-opcode steps.
STEP_BRANCHES = (
    lambda a, b, ops: jnp.zeros_like(a),                    # NOP
    lambda a, b, ops: a & b,                                # AND
    lambda a, b, ops: a | b,                                # OR
    lambda a, b, ops: a ^ b,                                # XOR
    lambda a, b, ops: (a & b) ^ jnp.int32(-1),              # NAND
    lambda a, b, ops: (a | b) ^ jnp.int32(-1),              # NOR
    lambda a, b, ops: (a ^ b) ^ jnp.int32(-1),              # XNOR
    lambda a, b, ops: a ^ jnp.int32(-1),                    # NOT
    lambda a, b, ops: a,                                    # COPY
    lambda a, b, ops: apply_opcode_jnp(ops[:, None], a, b),  # mixed
)


def apply_step_jnp(branch: jnp.ndarray, opcodes: jnp.ndarray,
                   a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """One sub-kernel step on (n_unit, W) operand slabs: a single bitwise
    slab op for homogeneous steps, the chained select otherwise."""
    return jax.lax.switch(branch, STEP_BRANCHES, a, b, opcodes)


def logic_forward_ref(src_a: jnp.ndarray, src_b: jnp.ndarray,
                      dst: jnp.ndarray, opcode: jnp.ndarray,
                      input_words: jnp.ndarray, output_addrs: jnp.ndarray,
                      n_addr: int,
                      step_branch: jnp.ndarray | None = None) -> jnp.ndarray:
    """Execute the program on packed inputs.

    Args:
      src_a/src_b/dst/opcode: (n_steps, n_unit) int32 program streams.
      input_words: (n_inputs, W) int32 packed inputs (row i = input i).
      output_addrs: (n_outputs,) int32.
      n_addr: buffer rows (incl. consts + trash).
      step_branch: (n_steps,) int32 per-step dispatch branch
        (``LogicProgram.step_branch``); None forces the generic dispatch on
        every step (legacy path, used as a baseline in benchmarks).
    Returns:
      (n_outputs, W) int32 packed outputs.
    """
    n_inputs, w = input_words.shape
    buf = jnp.zeros((n_addr, w), jnp.int32)
    buf = buf.at[1].set(jnp.int32(-1))
    buf = jax.lax.dynamic_update_slice(buf, input_words.astype(jnp.int32),
                                       (2, 0))

    def step(s, buf):
        a = jnp.take(buf, src_a[s], axis=0)       # (n_unit, W)
        b = jnp.take(buf, src_b[s], axis=0)
        if step_branch is None:
            r = apply_opcode_jnp(opcode[s][:, None], a, b)
        else:
            r = apply_step_jnp(step_branch[s], opcode[s], a, b)
        return buf.at[dst[s]].set(r)

    if src_a.shape[0]:  # static guard: gateless programs have no steps
        buf = jax.lax.fori_loop(0, src_a.shape[0], step, buf)
    return jnp.take(buf, output_addrs, axis=0)
