"""Request batching for the serving path (paper §5.2.4 host-side queueing).

The paper enqueues multiple OpenCL kernels out-of-order to keep the fabric
busy; here a ``RequestBatcher`` packs incoming prompts into fixed-shape
decode batches (continuous batching, slot-based): finished slots are
recycled without recompiling, because the decode step is shape-stable.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

import numpy as np


@dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (prompt_len,) int32
    max_new_tokens: int
    generated: list = field(default_factory=list)
    done: bool = False


class RequestBatcher:
    """Slot-based continuous batcher over a fixed decode batch size."""

    def __init__(self, batch_size: int, eos_id: int = -1):
        self.batch_size = batch_size
        self.eos_id = eos_id
        self.slots: list[Request | None] = [None] * batch_size
        self.queue: list[Request] = []
        self.finished: list[Request] = []

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def admit(self) -> list[tuple[int, Request]]:
        """Fill empty slots from the queue; returns newly admitted."""
        admitted = []
        for i in range(self.batch_size):
            if self.slots[i] is None and self.queue:
                req = self.queue.pop(0)
                self.slots[i] = req
                admitted.append((i, req))
        return admitted

    def active_mask(self) -> np.ndarray:
        return np.array([s is not None for s in self.slots], dtype=bool)

    def record_tokens(self, tokens: np.ndarray) -> None:
        """tokens: (batch,) next token per slot; retire finished slots."""
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            tok = int(tokens[i])
            req.generated.append(tok)
            if (tok == self.eos_id or
                    len(req.generated) >= req.max_new_tokens):
                req.done = True
                self.finished.append(req)
                self.slots[i] = None

    @property
    def idle(self) -> bool:
        return not self.queue and all(s is None for s in self.slots)
