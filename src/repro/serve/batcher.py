"""Request batching for the serving path (paper §5.2.4 host-side queueing).

The paper enqueues multiple OpenCL kernels out-of-order to keep the fabric
busy; here a ``RequestBatcher`` packs incoming prompts into fixed-shape
decode batches (continuous batching, slot-based): finished slots are
recycled without recompiling, because the decode step is shape-stable.

``SlotTable`` generalizes the same slot discipline beyond token decode: it
allocates *sample rows* of a fixed-capacity batch (for the logic engine,
``32 * W`` rows — the sample capacity of a packed ``(n_wires, W)`` word
slab, see core/packing.py). A bit-vector request occupies ``len(samples)``
rows for one fabric invocation and the rows are recycled for the next
admission wave, so ragged request sizes (not multiples of 32) share words
with their neighbours instead of padding to private word boundaries.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (prompt_len,) int32
    max_new_tokens: int
    generated: list = field(default_factory=list)
    done: bool = False


class RequestBatcher:
    """Slot-based continuous batcher over a fixed decode batch size."""

    def __init__(self, batch_size: int, eos_id: int = -1):
        self.batch_size = batch_size
        self.eos_id = eos_id
        self.slots: list[Request | None] = [None] * batch_size
        self.queue: list[Request] = []
        self.finished: list[Request] = []

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def admit(self) -> list[tuple[int, Request]]:
        """Fill empty slots from the queue; returns newly admitted."""
        admitted = []
        for i in range(self.batch_size):
            if self.slots[i] is None and self.queue:
                req = self.queue.pop(0)
                self.slots[i] = req
                admitted.append((i, req))
        return admitted

    def active_mask(self) -> np.ndarray:
        return np.array([s is not None for s in self.slots], dtype=bool)

    def record_tokens(self, tokens: np.ndarray) -> None:
        """tokens: (batch,) next token per slot; retire finished slots."""
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            tok = int(tokens[i])
            req.generated.append(tok)
            if (tok == self.eos_id or
                    len(req.generated) >= req.max_new_tokens):
                req.done = True
                self.finished.append(req)
                self.slots[i] = None

    @property
    def idle(self) -> bool:
        return not self.queue and all(s is None for s in self.slots)


class SlotTable:
    """Row-granular slot allocator over a fixed sample capacity.

    ``acquire(n)`` hands out ``n`` free row indices (lowest-first, so the
    active region stays dense and word-aligned requests pack adjacently);
    ``release(rows)`` recycles them. The high-water mark records the densest
    simultaneous occupancy ever reached — the serving analogue of decode
    batch utilization.
    """

    def __init__(self, capacity: int):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self._free: list[int] = list(range(capacity - 1, -1, -1))  # stack
        self._allocated: set[int] = set()
        self.high_water = 0

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_active(self) -> int:
        return self.capacity - len(self._free)

    def acquire(self, n: int) -> np.ndarray | None:
        """Reserve ``n`` rows; None when fewer than ``n`` are free."""
        if n < 0:
            raise ValueError("n must be >= 0")
        if n > len(self._free):
            return None
        # bulk slice off the top of the stack (reversed = pop order, so
        # the handed-out rows stay lowest-first) — a per-row pop loop is
        # measurable serving overhead at capacity-sized waves
        taken = self._free[len(self._free) - n:]
        del self._free[len(self._free) - n:]
        taken.reverse()
        self._allocated.update(taken)
        self.high_water = max(self.high_water, self.n_active)
        return np.array(taken, dtype=np.int64)

    def release(self, rows: np.ndarray) -> None:
        lst = np.asarray(rows, dtype=np.int64).tolist()
        held = set(lst)
        if lst and not (0 <= min(lst) and max(lst) < self.capacity):
            bad = next(r for r in lst if not 0 <= r < self.capacity)
            raise ValueError(f"row {bad} out of range")
        if len(held) != len(lst):
            bad = next(r for r in lst if lst.count(r) > 1)
            raise RuntimeError(f"row {bad} released without being held")
        if not held <= self._allocated:
            bad = next(r for r in lst if r not in self._allocated)
            raise RuntimeError(f"row {bad} released without being held")
        self._allocated -= held
        self._free.extend(reversed(lst))
