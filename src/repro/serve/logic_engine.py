"""Batched serving engine for compiled logic programs (``LogicEngine``).

PR 1 made compile and single-shot execution fast; this module makes
compiled :class:`~repro.core.scheduler.LogicProgram` objects a *served*
artifact (ROADMAP north star; paper §5.2.4 host-side queueing and the §2
"inference engine for ANY network" claim). Three layers:

1. **Program registry** (:class:`ProgramCache`) — compiled programs plus
   their device arrays, keyed by ``(graph fingerprint,
   CompileSpec.cache_key())`` — the one declarative compilation target
   (core/spec.py). Repeat traffic for a structurally identical FFCL never
   recompiles and never re-uploads streams; LRU-evicted entries drop their
   jit runners with them. Misses compile through the one
   :class:`~repro.core.compiler.LogicCompiler` facade.

2. **Slot/word batching** (:class:`LogicEngine` + ``batcher.SlotTable``) —
   incoming bit-vector requests are packed into the sample rows of one
   fixed-capacity ``(capacity, n_inputs)`` batch, i.e. the ``32 * W``
   samples of the packed ``(n_wires, W)`` word layout (core/packing.py).
   One fabric invocation amortizes pack -> program(s) -> unpack across
   every queued request, and the fixed capacity keeps the fused jit
   shape-stable (one trace per program). Ragged request sizes share words;
   freed rows are recycled between invocation waves.

3. **Execution** — partitioned graphs (core/partition.py) run as a
   *pipelined sequence* of sub-programs over one shared packed input slab
   (the simulator's multi-FFCL task-pipelining model), re-assembled at the
   word level via ``output_permutation``. With a multi-device mesh the
   whole fused function runs under ``shard_map``: the batch axis — and
   with it the packed word axis, ``W / n_devices`` words per shard — is
   data-parallel across devices (specs built with train/sharding.py
   helpers).

Requests are one-shot (combinational logic has no decode loop): a request
completes in the first invocation wave it is admitted to, so continuous
batching here means draining an arbitrarily deep queue through a
fixed-shape invocation at maximum word occupancy.
"""
from __future__ import annotations

import threading
import time
import warnings
from collections import OrderedDict, deque
from dataclasses import dataclass, field
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh

from repro.core.artifact_store import ArtifactStore, store_key
from repro.core.calibrate import CalibrationError
from repro.core.compiler import CompiledArtifact, LogicCompiler
from repro.core.errors import PermanentCompileError
from repro.core.gate_ir import LogicGraph, compose_graphs
from repro.core.packing import WORD_BITS
from repro.core.scheduler import LogicProgram, compile_graph
from repro.core.spec import CompileSpec, resolve_spec, _UNSET
from repro.core.verify import effective_mode, verify_artifact
from repro.kernels.logic_dsp import kernel as _k
from repro.kernels.logic_dsp.ops import (mega_arrays, mega_forward_words,
                                         pack_bits_jnp, unpack_bits_jnp)
from repro.serve.batcher import SlotTable
from repro.train.sharding import batch_pspec


# ---------------------------------------------------------------------------
# program registry
# ---------------------------------------------------------------------------

def _resolve_cache_spec(spec, alloc, max_gates, n_unit, pipeline, *,
                        caller: str) -> CompileSpec:
    """The registry's deprecation shim: the pre-spec convention was
    ``(graph, n_unit, alloc, max_gates, pipeline=...)`` with ``alloc``/
    ``max_gates`` positional and the pass pipeline under the ``pipeline``
    name (``None`` = raw) — normalize all of that onto the spec's
    ``optimize`` field before handing to :func:`resolve_spec`."""
    optimize = _UNSET
    if pipeline is not _UNSET:
        optimize = "none" if pipeline is None else pipeline
    return resolve_spec(spec, caller=caller, stacklevel=4, n_unit=n_unit,
                        alloc=alloc, max_gates=max_gates, optimize=optimize)


@dataclass
class CompiledEntry:
    """One registry entry: a :class:`CompiledArtifact` plus its runners.

    The artifact is the facade's one result type (resolved spec,
    post-optimization graph, program pipeline, output permutation); the
    entry adds the registry key and the lazily-attached fused jit
    runners, keyed by engine execution config (mesh/shard/backend/
    capacity) so engines sharing a cache never run another engine's
    trace — evicted with the entry.
    """

    key: tuple
    artifact: CompiledArtifact
    runners: dict = field(default_factory=dict)

    @property
    def spec(self) -> CompileSpec:
        return self.artifact.spec

    @property
    def programs(self) -> tuple[LogicProgram, ...]:
        return self.artifact.programs

    @property
    def output_perm(self) -> np.ndarray:
        return self.artifact.output_perm

    @property
    def n_inputs(self) -> int:
        return self.artifact.n_inputs

    @property
    def n_outputs(self) -> int:
        return self.artifact.n_outputs

    @property
    def compile_s(self) -> float:
        return self.artifact.compile_s

    @property
    def partitioned(self) -> bool:
        return self.artifact.partitioned


class ProgramCache:
    """LRU registry of compiled logic programs.

    Keying contract (documented in DESIGN.md §5/§8): the key is
    ``(fingerprint, spec.cache_key())`` — the graph's structural
    identity plus the one canonical :meth:`CompileSpec.cache_key`
    (which replaced the registry's hand-built tuple), taken with
    ``optimize`` stripped to ``"none"`` since the pipeline's whole
    effect is absorbed into the fingerprint — where the fingerprint is
    taken **after** gate-level optimization when the spec carries a
    pass pipeline:

      * ``fingerprint()`` hashes inputs/gates/outputs but NOT the name, so
        structurally identical graphs from different producers share one
        compiled program;
      * with ``spec.optimize`` active, the key uses the
        *post-optimization* fingerprint: two raw graphs that rewrite to
        the same optimized netlist — e.g. the same NullaNet layer
        synthesized by two workers with different dead fanin — hit ONE
        cache entry instead of compiling twice;
      * the spec key is normalized per graph (:meth:`CompileSpec
        .normalize`): an unbinding partition budget keys as ``None``,
        and ``n_unit="auto"`` is resolved to its ``binary_search`` pick
        before keying, so a key always names one concrete program
        pipeline.

    Optimization itself is memoized per ``(raw fingerprint,
    spec.optimize_key)``, so the serving hot path stays O(1) per repeat
    request: the raw fingerprint is memoized on the graph object, the
    optimized graph on the cache — the pass pipeline runs once per
    distinct raw structure, not once per request.  Compilation on a
    miss goes through the one :class:`~repro.core.compiler
    .LogicCompiler` facade (no private compile path anymore).

    Device arrays ride along for free: ``program_arrays`` memoizes on the
    (immutable) program object, and each engine attaches its fused jit
    runner to the entry keyed by its execution config (mesh, shard,
    backend, capacity — engines sharing a cache never run another
    engine's trace), so eviction releases program, arrays, and traces
    together.
    """

    def __init__(self, max_entries: int | None = None,
                 compiler: LogicCompiler | None = None,
                 store: ArtifactStore | None = None):
        self.max_entries = max_entries
        self.compiler = compiler or LogicCompiler()
        # Optional durable backing (core/artifact_store.py): an
        # in-memory miss consults the store BEFORE compiling (fleet warm
        # start — a fresh process serves its first request with zero
        # compiles from a populated store), and a compile writes through
        # so sibling processes never repeat it.
        self.store = store
        # One reentrant lock serializes get/peek/evict and both memos:
        # engines sharing a cache from threads (the front door steps the
        # engine in an executor; the artifact-store warmers will too)
        # must not race LRU eviction against entry construction.
        # Compilation runs UNDER the lock — a duplicate concurrent miss
        # would compile the same program twice and momentarily double
        # device memory, which is worse than briefly serializing misses
        # (hits only touch an OrderedDict move_to_end).
        self._lock = threading.RLock()
        self._entries: OrderedDict[tuple, CompiledEntry] = OrderedDict()
        # (raw fingerprint, spec.optimize_key) -> optimized LogicGraph;
        # LRU-bounded looser than the entries (graphs are cheap next to
        # compiled programs + device arrays, and a memo hit is what keeps
        # re-admitted evictees from re-running the pass pipeline).
        self._opt_memo: OrderedDict[tuple, LogicGraph] = OrderedDict()
        # (post-opt fingerprint, spec.objective) -> resolved n_unit for
        # n_unit="auto" specs: the design-space search (levelize +
        # binary_search probes) must run once per distinct structure,
        # not once per request — the hot path stays O(1) per repeat.
        # The objective is part of the key because "cycles" and
        # "wallclock" searches legitimately pick different unit counts
        # for the same structure; the cache's single compiler fixes the
        # remaining search inputs.
        self._auto_memo: OrderedDict[tuple, int] = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.compiles = 0           # actual compiler invocations (a miss
        #                             served from the store never compiles
        #                             — warm-start tests pin this to 0)
        self.compile_failures = 0
        self.store_hits = 0         # misses satisfied by a verified load
        self.store_misses = 0       # store consulted, no entry published
        self.store_failures = 0     # corrupt entry: quarantined, recompiled
        self.store_saves = 0        # write-through persists after compile
        self.store_save_failures = 0
        self.verifies = 0           # schedule-verifier runs (verify="load"/
        #                             "full" load paths + chain compiles)
        self.verify_failures = 0    # verifier-rejected loads: quarantined,
        #                             recompiled (DESIGN.md §13)
        # Warm-start the wall-clock calibration too: a compiler with no
        # fitted calibration picks up the store's persisted "default"
        # fit, so a fresh process can serve objective="wallclock" specs
        # with zero re-fits (fit_count() == 0 — same contract as the
        # zero-compile warm start).  Best-effort: a corrupt record is
        # quarantined at the store layer and serving degrades to the
        # cycles objective (see :meth:`_resolved`).
        if store is not None and self.compiler.calibration is None:
            try:
                self.compiler.calibration = store.load_calibration()
            except PermanentCompileError as exc:
                self.store_failures += 1
                warnings.warn(
                    f"calibration warm start failed: {exc!r}; "
                    "objective='wallclock' will fall back to 'cycles'",
                    RuntimeWarning, stacklevel=2)

    @property
    def _opt_memo_bound(self) -> int | None:
        return None if self.max_entries is None else 8 * self.max_entries

    def _optimized(self, graph: LogicGraph, spec: CompileSpec) -> LogicGraph:
        """The graph the registry compiles and keys on (memoized)."""
        pipeline = spec.pipeline
        if pipeline is None:
            return graph
        memo_key = (graph.fingerprint(), spec.optimize_key)
        with self._lock:
            cached = self._opt_memo.get(memo_key)
            if cached is not None:
                self._opt_memo.move_to_end(memo_key)
                return cached
            opt = pipeline.run(graph).graph
            self._opt_memo[memo_key] = opt
            bound = self._opt_memo_bound
            if bound is not None:
                while len(self._opt_memo) > bound:
                    self._opt_memo.popitem(last=False)
            return opt

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def __contains__(self, key: tuple) -> bool:
        with self._lock:
            return key in self._entries

    @staticmethod
    def key_of(graph: LogicGraph, spec: CompileSpec | int | None = None,
               alloc=_UNSET, max_gates=_UNSET, *, n_unit=_UNSET,
               pipeline=_UNSET) -> tuple:
        """Registry key for ``(graph, spec)`` — pass the graph the
        registry will actually compile (i.e. the *post-optimization*
        graph when the spec carries a pipeline; :meth:`get` handles that
        internally) and a spec with a concrete ``n_unit``.

        The spec side is ``cache_key()`` with ``optimize`` stripped: the
        pipeline's entire effect is absorbed into the post-optimization
        fingerprint, so a ``optimize="default"`` engine submitting a raw
        graph and an ``optimize="none"`` engine submitting the already-
        optimized netlist land on ONE entry (sharing programs, device
        arrays, and runners) instead of compiling the byte-identical
        program twice."""
        spec = _resolve_cache_spec(spec, alloc, max_gates, n_unit, pipeline,
                                   caller="ProgramCache.key_of")
        return (graph.fingerprint(),
                spec.normalize(graph).with_(optimize="none").cache_key())

    def peek(self, key: tuple) -> CompiledEntry | None:
        """Entry for ``key`` without compiling, counting, or LRU-touching."""
        with self._lock:
            return self._entries.get(key)

    def evict(self, key: tuple | None = None) -> tuple | None:
        """Drop one entry (programs + device arrays + runners together).

        ``key=None`` evicts the least-recently-used entry — the knob
        fault injection (``serve.frontdoor.FaultPolicy.evict_rate``)
        turns to simulate an eviction storm; a concrete ``key`` drops
        that entry (e.g. to force a recompile after an external
        invalidation). Returns the evicted key, or ``None`` when there
        was nothing to evict.  Engines with queued requests for an
        evicted entry recompile from the retained graph
        (:meth:`LogicEngine.step`) — eviction never wedges a queue.
        """
        with self._lock:
            if key is None:
                if not self._entries:
                    return None
                key, _ = self._entries.popitem(last=False)
                return key
            return key if self._entries.pop(key, None) is not None else None

    def get(self, graph: LogicGraph, spec: CompileSpec | int | None = None,
            alloc=_UNSET, max_gates=_UNSET, *, n_unit=_UNSET,
            pipeline=_UNSET) -> CompiledEntry:
        """Return (compiling on miss) the program pipeline for
        ``(graph, spec)``.

        The graph is optimized per ``spec.optimize`` first (memoized)
        and the entry is keyed on the optimized structure; budget
        normalization, ``n_unit="auto"`` resolution, and partitioning
        then see post-optimization gate counts — a graph whose
        optimized form fits ``spec.max_gates`` serves monolithically
        even when its raw form would have split.  Loose ``n_unit``/
        ``alloc``/``max_gates``/``pipeline`` arguments are the
        deprecated pre-spec convention.
        """
        spec = _resolve_cache_spec(spec, alloc, max_gates, n_unit, pipeline,
                                   caller="ProgramCache.get")
        with self._lock:
            raw_fp, req_spec = graph.fingerprint(), spec
            entry = self._alias_fast_path(graph, raw_fp, spec)
            if entry is not None:
                return entry
            graph = self._optimized(graph, spec)
            spec = self._resolved(graph, spec)
            # normalize BEFORE compiling so the artifact's recorded spec
            # is exactly what the key names (an unbinding budget keys —
            # and records — as None; optimize strips to "none" because
            # its whole effect lives in the post-optimization
            # fingerprint — see :meth:`key_of` — and
            # ``assume_optimized`` below means the facade never re-runs
            # it anyway)
            spec = spec.normalize(graph).with_(optimize="none")
            key = (graph.fingerprint(), spec.cache_key())
            entry = self._entries.get(key)
            if entry is not None:
                self.hits += 1
                self._entries.move_to_end(key)
                return entry
            self.misses += 1
            artifact = self._store_load(graph.fingerprint(), spec)
            if artifact is None:
                try:
                    self.compiles += 1
                    artifact = self.compiler.compile(graph, spec,
                                                     assume_optimized=True)
                except Exception:
                    # a failed compile leaves no entry behind: the next
                    # attempt (the front door's retry-with-backoff on
                    # transient failures) recompiles from scratch
                    self.compile_failures += 1
                    raise
                self._store_save(artifact, raw_fp, req_spec)
            entry = CompiledEntry(key=key, artifact=artifact)
            self._entries[key] = entry
            if self.max_entries is not None:
                while len(self._entries) > self.max_entries:
                    self._entries.popitem(last=False)
            return entry

    def get_chain(self, graphs, spec: CompileSpec | None = None
                  ) -> CompiledEntry:
        """Return (compiling on miss) a *chain* pipeline entry: the stage
        graphs compiled separately and served as ONE chain-mode megakernel
        launch (stage k's outputs feed stage k+1 in-kernel).

        Keyed on ``("chain", stage post-opt fingerprints...)`` plus the
        normalized spec key, so the same layer stack submitted by any
        producer shares one entry — distinct from the composed graph's
        monolithic entry, which flattens the stage structure.  Each stage
        is optimized per ``spec.optimize`` (memoized like :meth:`get`;
        passes preserve the per-stage I/O interface, so the chain widths
        still match).  Constraints: ``n_unit`` must be concrete and
        ``max_gates`` is ignored (a budget that binds needs output-cone
        partitioning of the composed graph — serve that via :meth:`get`).
        Chain entries are in-memory only (no artifact-store read/write:
        the store persists single-graph artifacts).
        """
        graphs = tuple(graphs)
        if not graphs:
            raise ValueError("get_chain needs at least one stage graph")
        spec = resolve_spec(spec, caller="ProgramCache.get_chain")
        if not spec.resolved:
            raise ValueError(
                "get_chain needs a concrete n_unit: per-stage "
                "n_unit='auto' resolution has no single spec to key on — "
                "serve the composed graph via get() instead")
        with self._lock:
            opt = [self._optimized(g, spec) for g in graphs]
            mono = spec.with_(optimize="none", max_gates=None)
            key = (("chain",) + tuple(g.fingerprint() for g in opt),
                   mono.cache_key())
            entry = self._entries.get(key)
            if entry is not None:
                self.hits += 1
                self._entries.move_to_end(key)
                return entry
            self.misses += 1
            self.compiles += 1
            t0 = time.perf_counter()
            try:
                programs = tuple(compile_graph(g, mono) for g in opt)
                composed = compose_graphs(
                    list(opt), name="+".join(g.name for g in graphs))
                artifact = CompiledArtifact(
                    spec=mono, graph=composed, programs=programs,
                    output_perm=np.arange(composed.n_outputs,
                                          dtype=np.int64),
                    compile_s=time.perf_counter() - t0, mode="chain")
                if effective_mode(spec.verify,
                                  getattr(self.compiler, "verify", None)
                                  ) in ("compile", "full"):
                    # chain entries bypass the LogicCompiler facade, so
                    # the verify="compile" gate lives here
                    self.verifies += 1
                    verify_artifact(artifact).raise_if_failed()
            except Exception:
                self.compile_failures += 1
                raise
            entry = CompiledEntry(key=key, artifact=artifact)
            self._entries[key] = entry
            if self.max_entries is not None:
                while len(self._entries) > self.max_entries:
                    self._entries.popitem(last=False)
            return entry

    def _alias_fast_path(self, graph: LogicGraph, raw_fp: str,
                         spec: CompileSpec) -> CompiledEntry | None:
        """Warm start WITHOUT the pass pipeline: on first contact with a
        raw structure, resolve ``(raw fingerprint, requested spec)``
        through the store's alias records straight to the verified
        canonical artifact — skipping the optimizer run the canonical
        (post-opt) address would otherwise force, which is the dominant
        cold-start cost for ``optimize="default"`` specs.

        ``None`` falls through to the normal path: no store, nothing to
        skip (``optimize="none"`` — the canonical lookup covers it),
        structure already seen in this process (the opt memo makes the
        normal path O(1)), a custom pipeline (no declarative identity),
        an alias miss, or a corrupt alias (counted, quarantined at the
        store layer, recompiled here)."""
        if self.store is None or spec.pipeline is None:
            return None
        if (raw_fp, spec.optimize_key) in self._opt_memo:
            return None
        try:
            spec.to_dict()
        except ValueError:
            return None
        try:
            artifact = self.store.load_alias(raw_fp, spec)
        except PermanentCompileError:
            self.store_failures += 1
            return None
        if artifact is None:
            return None
        # verify BEFORE seeding the memos: a schedule-invalid artifact's
        # graph must never be trusted as "the optimized form" either
        if not self._verify_loaded(artifact, spec,
                                   label=f"alias fp={raw_fp[:12]}"):
            return None             # falls through to the normal path
        # seed the memos the normal path would have filled, so repeat
        # requests for this structure never leave memory
        opt_fp = artifact.graph.fingerprint()
        self._opt_memo[(raw_fp, spec.optimize_key)] = artifact.graph
        bound = self._opt_memo_bound
        if bound is not None:
            while len(self._opt_memo) > bound:
                self._opt_memo.popitem(last=False)
        if not spec.resolved:
            self._auto_memo[(opt_fp, spec.objective)] = artifact.spec.n_unit
        key = (opt_fp, artifact.spec.cache_key())
        entry = self._entries.get(key)
        if entry is not None:       # admitted meanwhile via another raw form
            self.hits += 1
            self._entries.move_to_end(key)
            return entry
        self.misses += 1
        self.store_hits += 1
        entry = CompiledEntry(key=key, artifact=artifact)
        self._entries[key] = entry
        if self.max_entries is not None:
            while len(self._entries) > self.max_entries:
                self._entries.popitem(last=False)
        return entry

    def _verify_loaded(self, artifact: CompiledArtifact,
                       req_spec: CompileSpec, *, label: str) -> bool:
        """Gate a store-loaded artifact behind the static schedule
        verifier (``verify="load"``/``"full"`` — DESIGN.md §13).

        Store checksums prove the bytes round-tripped; the verifier
        proves the *schedule* still computes the recorded graph — the
        one trust hole §10.4 left open (an entry that was wrong when
        written verifies its checksums forever).  A rejected artifact is
        quarantined at the store (so no other process serves it either)
        and ``False`` sends this request to a clean compile: detection
        must degrade the fleet to cold-start latency, never to wrong
        bits.  ``True`` = passed or exempt (mode off/compile-only).
        """
        mode = effective_mode(req_spec.verify,
                              getattr(self.compiler, "verify", None))
        if mode not in ("load", "full"):
            return True
        self.verifies += 1
        report = verify_artifact(artifact)
        if report.ok:
            return True
        self.verify_failures += 1
        qpath = None
        if self.store is not None:
            try:
                qpath = self.store.quarantine(store_key(
                    artifact.graph.fingerprint(), artifact.spec))
            except Exception:           # noqa: BLE001 — quarantine is
                qpath = None            # best-effort; rejection is not
        warnings.warn(
            f"store-loaded artifact rejected by schedule verifier "
            f"({label}): {report.summary()}; quarantined -> {qpath}; "
            "falling back to a clean compile",
            RuntimeWarning, stacklevel=3)
        return False

    def _store_load(self, fingerprint: str, spec: CompileSpec
                    ) -> CompiledArtifact | None:
        """Store-hit-before-compile: a verified artifact, or ``None`` on
        a clean miss / no store.  A corrupt entry is LOUD at the store
        layer (quarantined there) but *recoverable* here: the registry
        counts it and falls back to a clean compile — a bad disk must
        degrade a fleet to cold-start latency, never to wrong bits or a
        crashed server."""
        if self.store is None:
            return None
        try:
            artifact = self.store.load(fingerprint, spec)
        except PermanentCompileError:
            self.store_failures += 1
            return None
        if artifact is None:
            self.store_misses += 1
            return None
        if not self._verify_loaded(artifact, spec,
                                   label=f"entry fp={fingerprint[:12]}"):
            return None             # rejected: caller compiles cleanly
        self.store_hits += 1
        return artifact

    def _store_save(self, artifact: CompiledArtifact,
                    raw_fp: str | None = None,
                    req_spec: CompileSpec | None = None) -> None:
        """Write-through after a compile (best-effort: a full/read-only
        disk costs persistence, not serving).  When the request carried
        a pipeline, an alias record for the RAW identity rides along so
        other processes warm-start without re-running the optimizer."""
        if self.store is None:
            return
        try:
            key = self.store.save(artifact)
            self.store_saves += 1
        except Exception as exc:              # noqa: BLE001 — see docstring
            self.store_save_failures += 1
            warnings.warn(f"artifact-store write-through failed: {exc!r}",
                          RuntimeWarning, stacklevel=3)
            return
        if req_spec is None or req_spec.pipeline is None:
            return
        try:
            req_spec.to_dict()
        except ValueError:                    # custom pipeline: no alias
            return
        try:
            self.store.save_alias(raw_fp, req_spec, key)
        except Exception as exc:              # noqa: BLE001 — best-effort
            self.store_save_failures += 1
            warnings.warn(f"artifact-store alias write failed: {exc!r}",
                          RuntimeWarning, stacklevel=3)

    def _resolved(self, graph: LogicGraph, spec: CompileSpec) -> CompileSpec:
        """Resolve ``n_unit="auto"`` for ``graph`` (memoized): repeat
        requests must not re-run the design-space search.

        A ``wallclock`` objective on a compiler with no fitted
        calibration degrades to the ``cycles`` objective with a
        :class:`RuntimeWarning` — serving must not 500 on a missing
        calibration file; the typed
        :class:`~repro.core.calibrate.CalibrationError` makes the
        fallback explicit and the warning makes it visible."""
        if spec.resolved:
            return spec
        # the search depends only on the (post-opt) graph stats, the
        # objective, and the cache's one compiler
        memo_key = (graph.fingerprint(), spec.objective)
        with self._lock:
            n_unit = self._auto_memo.get(memo_key)
            if n_unit is None:
                try:
                    resolved, _ = self.compiler.resolve(
                        graph, spec, assume_optimized=True)
                except CalibrationError as exc:
                    warnings.warn(
                        f"objective={spec.objective!r} resolution failed "
                        f"({exc}); falling back to objective='cycles'",
                        RuntimeWarning, stacklevel=2)
                    resolved, _ = self.compiler.resolve(
                        graph, spec.with_(objective="cycles"),
                        assume_optimized=True)
                n_unit = resolved.n_unit
                self._auto_memo[memo_key] = n_unit
                bound = self._opt_memo_bound
                if bound is not None:
                    while len(self._auto_memo) > bound:
                        self._auto_memo.popitem(last=False)
            else:
                self._auto_memo.move_to_end(memo_key)
        return spec.with_(n_unit=n_unit)

    def stats(self) -> dict:
        with self._lock:
            return {"entries": len(self._entries), "hits": self.hits,
                    "misses": self.misses, "compiles": self.compiles,
                    "compile_failures": self.compile_failures,
                    "store_hits": self.store_hits,
                    "store_misses": self.store_misses,
                    "store_failures": self.store_failures,
                    "store_saves": self.store_saves,
                    "store_save_failures": self.store_save_failures,
                    "verifies": self.verifies,
                    "verify_failures": self.verify_failures,
                    "programs": sum(len(e.programs)
                                    for e in self._entries.values())}


# ---------------------------------------------------------------------------
# requests
# ---------------------------------------------------------------------------

@dataclass
class LogicRequest:
    """One bit-vector inference request against a served graph."""

    uid: int
    key: tuple                     # program-cache key it is bound to
    graph: LogicGraph              # retained so eviction can recompile
    inputs: np.ndarray             # (n_samples, n_inputs) bool
    result: np.ndarray             # (n_samples, n_outputs) bool, filled in
    pending_chunks: int = 0
    done: bool = False
    #: stage graphs of a chain request (``serve_chain``), retained so an
    #: LRU-evicted chain entry can recompile; ``None`` = single-graph.
    chain: tuple | None = None

    @property
    def n_samples(self) -> int:
        return int(self.inputs.shape[0])


@dataclass
class _Chunk:
    """A capacity-bounded slice [lo, hi) of a request's samples."""

    req: LogicRequest
    lo: int
    hi: int

    @property
    def n(self) -> int:
        return self.hi - self.lo


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------

class LogicEngine:
    """Continuous-batching inference engine over compiled logic programs.

    Args:
      spec: the :class:`~repro.core.spec.CompileSpec` every submitted
        graph is compiled against (canonical defaults when omitted):
        fabric width (``n_unit``; ``"auto"`` resolves per graph via the
        registry's design-space search), address allocation, scheduler
        layout knobs, the gate-level pass pipeline (submitted graphs
        are optimized — memoized per raw fingerprint — and the program
        cache keys on the POST-optimization fingerprint, so
        structurally equal requests share one compiled entry;
        ``optimize="none"`` serves raw), and the partition budget
        (``max_gates`` — graphs above it are split by output-cone
        clustering and served as a pipelined program sequence).  The
        loose ``n_unit``/``alloc``/``max_gates``/``optimize`` kwargs
        are the deprecated pre-spec convention.
      capacity: samples per fabric invocation; rounded up to a multiple of
        ``32 * n_devices`` so every device shard packs whole words. Default
        ``32 * words_per_device * n_devices``.
      words_per_device: sizes the default capacity (W words per device).
      mesh: optional 1-axis ``jax.sharding.Mesh`` for data-parallel
        serving; default builds one over all local devices when there is
        more than one (or when ``shard=True``).
      shard: force (True) / forbid (False) the shard_map path; default
        ``None`` = auto (shard iff the mesh spans > 1 device).
      cache: optionally share a :class:`ProgramCache` across engines.
        Mutually exclusive with ``max_programs`` / ``store`` — bound and
        back a shared cache at its own construction.
      max_programs: LRU bound on the engine-owned program cache
        (compiled programs + device arrays + jit traces per entry).
      store: optional :class:`~repro.core.artifact_store.ArtifactStore`
        backing the engine-owned cache — a fresh engine process warms
        from the shared store directory (first request served with zero
        compiles when precompiled, e.g. via ``tools/precompile.py``)
        and writes its own compiles through for the rest of the fleet.
      max_retained: bound on *completed* requests kept for
        :meth:`result` pickup; beyond it the oldest unclaimed results are
        dropped (FIFO). ``None`` (default) retains until claimed — set a
        bound for fire-and-forget traffic so unclaimed results cannot
        grow without limit.
      use_ref / interpret / block_w: forwarded to the kernel layer.
    """

    def __init__(self, spec: CompileSpec | int | None = None, *,
                 capacity: int | None = None, words_per_device: int = 4,
                 mesh: Mesh | None = None,
                 shard: bool | None = None, cache: ProgramCache | None = None,
                 max_programs: int | None = None,
                 store: ArtifactStore | None = None,
                 max_retained: int | None = None, use_ref: bool = False,
                 interpret: bool = True, block_w: int = _k.LANE,
                 n_unit=_UNSET, alloc=_UNSET, max_gates=_UNSET,
                 optimize=_UNSET):
        self.spec = resolve_spec(spec, caller="LogicEngine", n_unit=n_unit,
                                 alloc=alloc, max_gates=max_gates,
                                 optimize=optimize)
        self.use_ref = use_ref
        self.interpret = interpret
        self.block_w = block_w
        if cache is not None and max_programs is not None:
            raise ValueError(
                "max_programs bounds the engine-owned cache; bound a shared "
                "ProgramCache at its own construction instead")
        if cache is not None and store is not None:
            raise ValueError(
                "store backs the engine-owned cache; attach an "
                "ArtifactStore to the shared ProgramCache at its own "
                "construction instead")
        self.cache = cache if cache is not None else \
            ProgramCache(max_programs, store=store)

        if mesh is None and (shard or (shard is None and
                                       len(jax.devices()) > 1)):
            mesh = Mesh(np.asarray(jax.devices()), ("data",))
        self.mesh = mesh
        n_dev = int(np.prod(list(mesh.shape.values()))) if mesh else 1
        quantum = WORD_BITS * n_dev
        if capacity is None:
            capacity = WORD_BITS * words_per_device * n_dev
        self.capacity = -(-capacity // quantum) * quantum
        # auto (None) shards only when the mesh actually spans devices; an
        # explicit shard=True forces the shard_map path even on one device
        # (exercised by tests without multi-device hosts).
        self.shard = bool(mesh is not None and
                          (shard is True or (shard is None and n_dev > 1)))

        self.slots = SlotTable(self.capacity)
        self.max_retained = max_retained
        self._queues: OrderedDict[tuple, deque[_Chunk]] = OrderedDict()
        self._requests: dict[int, LogicRequest] = {}
        # Unclaimed completed requests: `_retained` is the O(1)
        # membership truth (claiming = one set.discard), `_finished_order`
        # only remembers FIFO age for the max_retained trim. Claimed uids
        # become stale deque entries compacted lazily — never an O(n)
        # deque.remove on the claim path (high-churn front-door traffic
        # claims every result).
        self._finished_order: deque[int] = deque()
        self._retained: set[int] = set()
        self._next_uid = 0
        # execution-config key for per-engine runners on shared cache
        # entries: two engines only share a trace when every knob that
        # shapes it matches (devices included — a mesh is its device ids).
        mesh_key = (None if self.mesh is None else
                    (tuple(self.mesh.shape.items()),
                     tuple(d.id for d in self.mesh.devices.flat)))
        self._exec_key = (self.capacity, self.shard, mesh_key, self.use_ref,
                          self.interpret, self.block_w)
        # telemetry
        self.invocations = 0
        self.samples_served = 0
        self._occupancy_sum = 0.0

    # -- compilation-target views (read-only; the spec is the source) -------

    @property
    def n_unit(self):
        return self.spec.n_unit

    @property
    def alloc(self) -> str:
        return self.spec.alloc

    @property
    def max_gates(self) -> int | None:
        return self.spec.max_gates

    @property
    def pipeline(self):
        return self.spec.pipeline

    # -- program / runner plumbing ------------------------------------------

    def _entry(self, graph: LogicGraph) -> CompiledEntry:
        entry = self.cache.get(graph, self.spec)
        if self._exec_key not in entry.runners:
            entry.runners[self._exec_key] = self._build_runner(entry)
        return entry

    def _build_runner(self, entry: CompiledEntry) -> Callable:
        """Fused jit: pack -> megakernel -> unpack, ONE launch per wave.

        The whole artifact — monolithic, partitioned pipeline, or served
        chain — executes as a single ``mega_pallas_call``: partition
        sub-programs run stage-by-stage inside the kernel over the
        resident word slab with the output permutation applied in-kernel
        (no per-program launches, no separate re-assembly gather), and
        chain stages hand off without leaving the kernel.  The streams
        close over as trace constants (memoized by ``mega_arrays``), so
        the only runtime operand is the fixed-shape
        ``(capacity, n_inputs)`` bool batch — one trace per registry
        entry per engine config.
        """
        mega = entry.artifact.megaprogram()
        mega_arrays(mega)       # memoize host streams outside the trace
        kw = dict(block_w=self.block_w, interpret=self.interpret,
                  use_ref=self.use_ref)

        def run(bits: jnp.ndarray) -> jnp.ndarray:
            words = pack_bits_jnp(bits)
            ow = mega_forward_words(mega, words, **kw)
            return unpack_bits_jnp(ow, bits.shape[0])

        if self.shard:
            # batch rows -> devices; each shard packs/serves its own
            # capacity/n_dev samples = W/n_dev words of the word axis.
            spec = batch_pspec(self.mesh, self.capacity, 2)
            run = shard_map(run, mesh=self.mesh, in_specs=(spec,),
                            out_specs=spec, check_rep=False)
        return jax.jit(run)

    # -- request lifecycle ---------------------------------------------------

    def _chain_entry(self, graphs: tuple) -> CompiledEntry:
        entry = self.cache.get_chain(graphs, self.spec)
        if self._exec_key not in entry.runners:
            entry.runners[self._exec_key] = self._build_runner(entry)
        return entry

    def submit(self, graph: LogicGraph, bits: np.ndarray) -> int:
        """Queue a request; returns its uid (serve with :meth:`step`)."""
        bits = np.asarray(bits, dtype=bool)
        if bits.ndim != 2 or bits.shape[1] != graph.n_inputs:
            raise ValueError(
                f"inputs must be (n, {graph.n_inputs}), got {bits.shape}")
        return self._admit(self._entry(graph), graph, bits, chain=None)

    def submit_chain(self, graphs, bits: np.ndarray) -> int:
        """Queue a request against a *stage chain* (e.g. a classifier's
        per-layer graphs): the stack is compiled per stage and served as
        one chain-mode megakernel launch per wave — no composed-monolith
        compile, no per-stage launches.  Stage widths must chain
        (``graphs[k].n_outputs == graphs[k+1].n_inputs``)."""
        graphs = tuple(graphs)
        if not graphs:
            raise ValueError("submit_chain needs at least one stage graph")
        bits = np.asarray(bits, dtype=bool)
        if bits.ndim != 2 or bits.shape[1] != graphs[0].n_inputs:
            raise ValueError(
                f"inputs must be (n, {graphs[0].n_inputs}), got "
                f"{bits.shape}")
        return self._admit(self._chain_entry(graphs), graphs[0], bits,
                           chain=graphs)

    def _admit(self, entry: CompiledEntry, graph: LogicGraph,
               bits: np.ndarray, chain: tuple | None) -> int:
        uid = self._next_uid
        self._next_uid += 1
        req = LogicRequest(
            uid=uid, key=entry.key, graph=graph, inputs=bits, chain=chain,
            result=np.zeros((bits.shape[0], entry.n_outputs), dtype=bool))
        self._requests[uid] = req
        queue = self._queues.setdefault(entry.key, deque())
        # oversized requests split into capacity-bounded chunks; each chunk
        # is admitted independently so no request can wedge the queue.
        for lo in range(0, max(req.n_samples, 1), self.capacity):
            hi = min(lo + self.capacity, req.n_samples)
            if hi > lo:
                queue.append(_Chunk(req, lo, hi))
                req.pending_chunks += 1
        if req.pending_chunks == 0:      # empty request: trivially done
            req.done = True
            self._retire(uid)
        return uid

    def _retire(self, uid: int) -> None:
        """Track a completed request; drop the oldest unclaimed results
        beyond ``max_retained`` (already-claimed uids are stale deque
        entries and don't count against the bound)."""
        self._finished_order.append(uid)
        self._retained.add(uid)
        if self.max_retained is None:
            return
        while len(self._retained) > self.max_retained:
            old = self._finished_order.popleft()
            if old in self._retained:       # stale (claimed) uids skip
                self._retained.discard(old)
                self._requests.pop(old, None)

    def _compact_finished(self) -> None:
        """Lazy compaction of claimed uids out of ``_finished_order``:
        amortized O(1) per claim — pop the stale head run, and rebuild
        outright once stale entries outnumber live ones (bounds deque
        memory under claim-newest-first patterns where the stale run
        never reaches the head)."""
        order, retained = self._finished_order, self._retained
        while order and order[0] not in retained:
            order.popleft()
        if len(order) > 2 * len(retained) + 8:
            self._finished_order = deque(u for u in order if u in retained)

    def step(self) -> list[int]:
        """One invocation wave: admit, execute, scatter back, recycle.

        Serves the longest-waiting non-empty program queue (FIFO across
        keys), admitting chunks into slot rows until the table is full,
        then runs ONE fused fabric invocation for all of them. Returns the
        uids completed this wave.
        """
        key = next((k for k, q in self._queues.items() if q), None)
        if key is None:
            return []
        queue = self._queues[key]
        entry = self.cache.peek(key)
        if entry is None:
            # LRU-evicted with requests still queued (max_programs below the
            # concurrent working set): recompile from the retained graph(s)
            # — the request must not wedge the queue.
            req = queue[0].req
            entry = self._chain_entry(req.chain) if req.chain is not None \
                else self._entry(req.graph)
        elif self._exec_key not in entry.runners:
            entry.runners[self._exec_key] = self._build_runner(entry)
        admitted: list[tuple[_Chunk, np.ndarray]] = []
        while queue:
            rows = self.slots.acquire(queue[0].n)
            if rows is None:
                break
            admitted.append((queue.popleft(), rows))
        if not admitted:
            return []

        bits = np.zeros((self.capacity, entry.n_inputs), dtype=bool)
        for chunk, rows in admitted:
            bits[rows] = chunk.req.inputs[chunk.lo:chunk.hi]
        # hand the numpy slab straight to the jit runner: its C argument
        # path transfers it far cheaper than an eager jnp.asarray round
        # trip (which cost more than the kernel itself at small waves)
        out = np.asarray(entry.runners[self._exec_key](bits))

        finished: list[int] = []
        n_active = sum(c.n for c, _ in admitted)
        for chunk, rows in admitted:
            chunk.req.result[chunk.lo:chunk.hi] = out[rows]
            chunk.req.pending_chunks -= 1
            self.slots.release(rows)
            if chunk.req.pending_chunks == 0:
                chunk.req.done = True
                finished.append(chunk.req.uid)
                self._retire(chunk.req.uid)
        self.invocations += 1
        self.samples_served += n_active
        self._occupancy_sum += n_active / self.capacity
        if not queue:
            del self._queues[key]
        return finished

    @property
    def idle(self) -> bool:
        return not any(self._queues.values())

    def result(self, uid: int, *, pop: bool = True) -> np.ndarray:
        """Completed request's (n_samples, n_outputs) bool outputs."""
        req = self._requests.get(uid)
        if req is None:
            raise KeyError(f"request {uid} unknown: never submitted, "
                           "already claimed, or dropped by max_retained")
        if not req.done:
            raise RuntimeError(f"request {uid} still in flight")
        if pop:
            del self._requests[uid]
            # claimed results leave the retention window (max_retained
            # counts only UNCLAIMED ones): O(1) set discard, the deque
            # entry goes stale and is compacted lazily
            self._retained.discard(uid)
            self._compact_finished()
        return req.result

    def drain(self) -> None:
        """Run invocation waves until every queued request completes."""
        while not self.idle:
            self.step()

    def serve(self, graph: LogicGraph, bits: np.ndarray) -> np.ndarray:
        """Synchronous convenience: submit + drain + result."""
        uid = self.submit(graph, bits)
        self.drain()
        return self.result(uid)

    def serve_chain(self, graphs, bits: np.ndarray) -> np.ndarray:
        """Synchronous convenience: submit_chain + drain + result."""
        uid = self.submit_chain(graphs, bits)
        self.drain()
        return self.result(uid)

    def reset_telemetry(self) -> None:
        """Zero the invocation/occupancy counters (e.g. after warmup), so
        steady-state measurements aren't polluted by warmup waves. Program
        cache counters and slot high-water are left untouched."""
        self.invocations = 0
        self.samples_served = 0
        self._occupancy_sum = 0.0

    def stats(self) -> dict:
        inv = max(1, self.invocations)
        return {
            "capacity": self.capacity,
            "n_devices": (int(np.prod(list(self.mesh.shape.values())))
                          if self.mesh else 1),
            "sharded": self.shard,
            "invocations": self.invocations,
            "samples_served": self.samples_served,
            "mean_occupancy": self._occupancy_sum / inv,
            "slot_high_water": self.slots.high_water,
            **{f"cache_{k}": v for k, v in self.cache.stats().items()},
        }
