"""Serving: per-family decode caches, prefill, and single-token decode.

Cache layouts (batch is always sharded over ('pod','data')):

  dense/moe/vlm : KV (L, B, C, Hk, hd) — C = min(context, window or ctx).
                  When kv_heads < |model| the cache C axis is sharded over
                  'model' (sequence-sharded decode; see attention.py).
  ssm           : state (L, B, H, P, N) + conv carry (L, B, W-1, CH) — O(1).
  hybrid        : KV stack over attention layers only + RG-LRU h-state and
                  conv carries over recurrent layers.
  audio         : encoder-only — no decode (asserted).

``prefill`` runs the full forward once and materializes every layer's cache;
``decode_step`` advances one token. Both are pure jit-able functions of
(params, cache, tokens) so the dry-run lowers them directly.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import mamba2, moe, rglru
from repro.models.attention import KVCache
from repro.models.config import ModelConfig
from repro.models.layers import rms_norm, swiglu, gelu_mlp
from repro.models.pspec_utils import constrain
from repro.models.transformer import (_cdtype, _rec_mix, _ssm_mix,
                                      iter_layer_params, layer_kinds,
                                      embed_inputs, lm_logits)


class DecodeCache(NamedTuple):
    kv_k: Any = None          # (La, B, C, Hk, hd)
    kv_v: Any = None
    ssm_state: Any = None     # (Ls, B, H, P, N)
    conv_carry: Any = None    # (Ls, B, W-1, CH)
    rec_h: Any = None         # (Lr, B, D_rnn)
    rec_conv: Any = None      # (Lr, B, W-1, D_rnn)
    length: jnp.ndarray = None  # () int32 tokens so far


def cache_capacity(cfg: ModelConfig, context: int) -> int:
    if cfg.sliding_window:
        return min(context, cfg.sliding_window)
    if cfg.family == "hybrid" and cfg.local_window:
        return min(context, cfg.local_window)
    return context


def init_decode_cache(cfg: ModelConfig, batch: int, context: int
                      ) -> DecodeCache:
    assert not cfg.is_encoder, f"{cfg.name} is encoder-only: no decode"
    dt = _cdtype(cfg)
    hd = cfg.resolved_head_dim
    cap = cache_capacity(cfg, context)
    kinds = layer_kinds(cfg)
    n_attn = sum(1 for k in kinds if k in ("dense", "moe"))
    n_ssm = sum(1 for k in kinds if k == "ssm")
    n_rec = sum(1 for k in kinds if k == "rec")
    kw: dict[str, Any] = {"length": jnp.zeros((), jnp.int32)}
    if n_attn:
        shape = (n_attn, batch, cap, cfg.n_kv_heads, hd)
        kw["kv_k"] = jnp.zeros(shape, dt)
        kw["kv_v"] = jnp.zeros(shape, dt)
    if n_ssm:
        d_in, nh, p, n = mamba2.ssm_dims(cfg)
        kw["ssm_state"] = jnp.zeros((n_ssm, batch, nh, p, n), jnp.float32)
        kw["conv_carry"] = jnp.zeros(
            (n_ssm, batch, cfg.ssm_conv_width - 1, d_in + 2 * n), dt)
    if n_rec:
        d_rnn = cfg.n_heads * hd
        kw["rec_h"] = jnp.zeros((n_rec, batch, d_rnn), jnp.float32)
        kw["rec_conv"] = jnp.zeros(
            (n_rec, batch, cfg.ssm_conv_width - 1, d_rnn), dt)
    return DecodeCache(**kw)


# ---------------------------------------------------------------------------
# per-kind single-token block steps
# ---------------------------------------------------------------------------

def _attn_block_step(p, x, cfg, kv: KVCache, window: int):
    h = rms_norm(x, p["attn_norm"])
    h, kv = attn.attention_decode(p, h, cfg, kv, window=window)
    x = x + h
    h = rms_norm(x, p["mlp_norm"])
    if cfg.family == "audio":
        h = gelu_mlp(h, p["w_in"], p["w_out"])
    elif "w_router" in p:
        h = moe.moe_forward(p, h, cfg)
    else:
        h = swiglu(h, p["w_gate"], p["w_up"], p["w_down"])
    return x + h, kv


def _ssm_block_step(p, x, cfg, state, carry):
    """x (B, 1, D). Single-token SSD step."""
    b = x.shape[0]
    d_in, nh, hp, n = mamba2.ssm_dims(cfg)
    h = rms_norm(x, p["norm"])
    zxbcdt = h @ p["in_proj"].astype(h.dtype)
    z, xin, bmat, cmat, dt = jnp.split(
        zxbcdt, [d_in, 2 * d_in, 2 * d_in + n, 2 * d_in + 2 * n], axis=-1)
    conv_in = jnp.concatenate([xin, bmat, cmat], axis=-1)
    conv_out, carry = rglru.temporal_conv(
        {"conv_w": p["conv_w"]}, conv_in, cfg.ssm_conv_width, carry)
    conv_out = jax.nn.silu(conv_out.astype(jnp.float32)).astype(h.dtype)
    xin, bmat, cmat = jnp.split(conv_out, [d_in, d_in + n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         p["dt_bias"].astype(jnp.float32))
    y, state = mamba2.ssd_decode_step(
        xin[:, 0].reshape(b, nh, hp), dt[:, 0], p["a_log"],
        bmat[:, 0], cmat[:, 0], state)
    y = y + xin[:, 0].reshape(b, nh, hp).astype(jnp.float32) * \
        p["skip_d"].astype(jnp.float32)[None, :, None]
    y = y.reshape(b, 1, d_in).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    y = rms_norm(y, p["out_norm"])
    return x + y @ p["out_proj"].astype(x.dtype), state, carry


def _rec_block_step(p, x, cfg, h_state, carry):
    h = rms_norm(x, p["attn_norm"])
    gate = jax.nn.gelu((h @ p["gate_proj"].astype(h.dtype)
                        ).astype(jnp.float32)).astype(h.dtype)
    u = h @ p["rnn_proj"].astype(h.dtype)
    u, carry = rglru.temporal_conv({"conv_w": p["conv_w"]}, u,
                                   cfg.ssm_conv_width, carry)
    lru_p = {k: p[k] for k in ("w_a", "b_a", "w_x", "b_x", "lam")}
    h_state = rglru.rglru_step(lru_p, u[:, 0], h_state, cfg.rglru_c)
    y = (gate * h_state[:, None].astype(gate.dtype)) @ \
        p["out_proj"].astype(x.dtype)
    x = x + y
    h = rms_norm(x, p["mlp_norm"])
    return x + swiglu(h, p["w_gate"], p["w_up"], p["w_down"]), h_state, carry


# ---------------------------------------------------------------------------
# decode_step
# ---------------------------------------------------------------------------

def decode_step(params, cfg: ModelConfig, tokens: jnp.ndarray,
                cache: DecodeCache) -> tuple[jnp.ndarray, DecodeCache]:
    """tokens (B, 1) -> (logits (B, 1, V), new cache)."""
    cdt = _cdtype(cfg)
    x = constrain(params["embed"].astype(cdt)[tokens], "dp", None, None)
    kinds = layer_kinds(cfg)
    window = cfg.sliding_window or (
        cfg.local_window if cfg.family == "hybrid" else 0)

    if "blocks" in params and kinds[0] in ("dense", "moe"):
        # homogeneous attention stack: scan over (params, kv) slices
        def body(carry, inp):
            x, length = carry
            lp, k_l, v_l = inp
            kv = KVCache(k=k_l, v=v_l, length=length)
            x, kv = _attn_block_step(lp, x, cfg, kv, window)
            return (x, length), (kv.k, kv.v)

        (x, _), (new_k, new_v) = jax.lax.scan(
            body, (x, cache.length),
            (params["blocks"], cache.kv_k, cache.kv_v))
        cache = cache._replace(kv_k=new_k, kv_v=new_v,
                               length=cache.length + 1)
    elif "blocks" in params and kinds[0] == "ssm":
        def body(x, inp):
            lp, st, cv = inp
            x, st, cv = _ssm_block_step(lp, x, cfg, st, cv)
            return x, (st, cv)

        x, (new_st, new_cv) = jax.lax.scan(
            body, x, (params["blocks"], cache.ssm_state, cache.conv_carry))
        cache = cache._replace(ssm_state=new_st, conv_carry=new_cv,
                               length=cache.length + 1)
    else:
        # heterogeneous (hybrid): python loop with per-kind counters
        ia = irec = 0
        new_k, new_v = [], []
        new_h, new_rc = [], []
        for lp, kind in zip(iter_layer_params(params, cfg), kinds):
            if kind in ("dense", "moe"):
                kv = KVCache(k=cache.kv_k[ia], v=cache.kv_v[ia],
                             length=cache.length)
                x, kv = _attn_block_step(lp, x, cfg, kv, window)
                new_k.append(kv.k)
                new_v.append(kv.v)
                ia += 1
            elif kind == "rec":
                x, h_state, carry = _rec_block_step(
                    lp, x, cfg, cache.rec_h[irec], cache.rec_conv[irec])
                new_h.append(h_state)
                new_rc.append(carry)
                irec += 1
        cache = cache._replace(
            kv_k=jnp.stack(new_k) if new_k else cache.kv_k,
            kv_v=jnp.stack(new_v) if new_v else cache.kv_v,
            rec_h=jnp.stack(new_h) if new_h else cache.rec_h,
            rec_conv=jnp.stack(new_rc) if new_rc else cache.rec_conv,
            length=cache.length + 1)

    x = rms_norm(x, params["final_norm"])
    return lm_logits(params, cfg, x), cache


# ---------------------------------------------------------------------------
# prefill
# ---------------------------------------------------------------------------

def prefill(params, cfg: ModelConfig, batch, context: int
            ) -> tuple[jnp.ndarray, DecodeCache]:
    """Full forward over the prompt; returns (logits, populated cache)."""
    x, positions = embed_inputs(params, cfg, batch)
    x = constrain(x, "dp", None, None)
    b, s = x.shape[:2]
    cap = cache_capacity(cfg, context)
    kinds = layer_kinds(cfg)
    window = cfg.sliding_window or (
        cfg.local_window if cfg.family == "hybrid" else 0)

    if "blocks" in params and kinds[0] in ("dense", "moe"):
        def body(x, lp):
            h = rms_norm(x, lp["attn_norm"])
            h, kv = attn.prefill_cache(lp, h, cfg, cap, positions=positions,
                                       window=window)
            x = x + h
            h = rms_norm(x, lp["mlp_norm"])
            if "w_router" in lp:
                h = moe.moe_forward(lp, h, cfg)
            else:
                h = swiglu(h, lp["w_gate"], lp["w_up"], lp["w_down"])
            return x + h, (kv.k, kv.v)

        x, (ks, vs) = jax.lax.scan(body, x, params["blocks"])
        cache = DecodeCache(kv_k=ks, kv_v=vs,
                            length=jnp.asarray(s, jnp.int32))
    elif "blocks" in params and kinds[0] == "ssm":
        def body(x, lp):
            h = rms_norm(x, lp["norm"])
            y, carry, state = _ssm_mix(lp, h, cfg)
            return x + y, (state, carry)

        x, (sts, cvs) = jax.lax.scan(body, x, params["blocks"])
        cache = DecodeCache(ssm_state=sts, conv_carry=cvs,
                            length=jnp.asarray(s, jnp.int32))
    else:
        ks, vs, hs, rcs = [], [], [], []
        for lp, kind in zip(iter_layer_params(params, cfg), kinds):
            if kind in ("dense", "moe"):
                h = rms_norm(x, lp["attn_norm"])
                h, kv = attn.prefill_cache(lp, h, cfg, cap,
                                           positions=positions, window=window)
                x = x + h
                h = rms_norm(x, lp["mlp_norm"])
                if "w_router" in lp:
                    h = moe.moe_forward(lp, h, cfg)
                else:
                    h = swiglu(h, lp["w_gate"], lp["w_up"], lp["w_down"])
                x = x + h
                ks.append(kv.k)
                vs.append(kv.v)
            elif kind == "rec":
                h = rms_norm(x, lp["attn_norm"])
                y, carry, h_last = _rec_mix(lp, h, cfg)
                x = x + y
                h = rms_norm(x, lp["mlp_norm"])
                x = x + swiglu(h, lp["w_gate"], lp["w_up"], lp["w_down"])
                hs.append(h_last)
                rcs.append(carry)
        cache = DecodeCache(
            kv_k=jnp.stack(ks) if ks else None,
            kv_v=jnp.stack(vs) if vs else None,
            rec_h=jnp.stack(hs) if hs else None,
            rec_conv=jnp.stack(rcs) if rcs else None,
            length=jnp.asarray(s, jnp.int32))
    x = rms_norm(x, params["final_norm"])
    return lm_logits(params, cfg, x), cache
