from repro.serve.engine import (DecodeCache, init_decode_cache, prefill,
                                decode_step)
from repro.serve.batcher import Request, RequestBatcher, SlotTable
from repro.serve.logic_engine import (CompiledEntry, LogicEngine,
                                      LogicRequest, ProgramCache)
from repro.serve.frontdoor import (FaultPolicy, FrontDoor, Priority,
                                   RequestRejected, ShedReason, SHED_CODES,
                                   Tenant)
from repro.serve.traffic import (TrafficPattern, TrafficReport,
                                 TrafficRequest, build_trace, run_trace,
                                 run_trace_sync)
from repro.core.artifact_store import ArtifactStore

__all__ = ["DecodeCache", "init_decode_cache", "prefill", "decode_step",
           "RequestBatcher", "Request", "SlotTable", "ArtifactStore",
           "LogicEngine", "LogicRequest", "ProgramCache", "CompiledEntry",
           "FrontDoor", "FaultPolicy", "Priority", "RequestRejected",
           "ShedReason", "SHED_CODES", "Tenant",
           "TrafficPattern", "TrafficReport", "TrafficRequest",
           "build_trace", "run_trace", "run_trace_sync"]
