from repro.serve.engine import (DecodeCache, init_decode_cache, prefill,
                                decode_step)
from repro.serve.batcher import Request, RequestBatcher, SlotTable
from repro.serve.logic_engine import (CompiledEntry, LogicEngine,
                                      LogicRequest, ProgramCache)

__all__ = ["DecodeCache", "init_decode_cache", "prefill", "decode_step",
           "RequestBatcher", "Request", "SlotTable",
           "LogicEngine", "LogicRequest", "ProgramCache", "CompiledEntry"]
