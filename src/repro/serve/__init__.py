from repro.serve.engine import (DecodeCache, init_decode_cache, prefill,
                                decode_step)
from repro.serve.batcher import RequestBatcher, Request

__all__ = ["DecodeCache", "init_decode_cache", "prefill", "decode_step",
           "RequestBatcher", "Request"]
