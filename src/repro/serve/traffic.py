"""Closed-loop traffic generation for the serving front door.

The ROADMAP's "millions of users" claim is only testable against
realistic load: bursty arrivals, ragged request sizes, a tenant mix.
This module builds seeded-deterministic traffic traces — Poisson
(exponential interarrivals) or heavy-tail (Pareto interarrivals, the
open-loop burst model) — and drives a :class:`~repro.serve.frontdoor
.FrontDoor` closed-loop: every request is actually awaited, every
outcome (completion latency, shed reason, deadline miss) recorded, and
the result folded into a :class:`TrafficReport` whose numbers are what
``benchmarks/run.py`` persists as ``serve.traffic.*`` rows in
``BENCH_logic.json`` (schema in benchmarks/README.md).
"""
from __future__ import annotations

import asyncio
import time
from dataclasses import dataclass, field

import numpy as np

from repro.serve.frontdoor import FrontDoor, Priority, RequestRejected

_ARRIVALS = ("poisson", "pareto")


@dataclass(frozen=True)
class TrafficPattern:
    """One tenant's offered-load model.

    * ``arrival="poisson"`` draws exponential interarrivals (rate
      ``rate_rps``); ``"pareto"`` draws Lomax/Pareto-II interarrivals
      with shape ``pareto_alpha`` scaled to the same mean rate — the
      heavy tail produces the bursts that exercise shedding.
    * Request sizes are geometric with mean ``size_mean`` clipped to
      ``size_max`` — ragged (rarely multiples of 32), so slot/word
      sharing is always in play.
    * ``deadline_s`` ± ``deadline_jitter`` (uniform fraction) per
      request; ``priority_mix`` is ``((Priority, weight), ...)``.
    """

    tenant: str
    rate_rps: float = 100.0
    arrival: str = "poisson"
    pareto_alpha: float = 1.5
    n_requests: int = 100
    size_mean: float = 24.0
    size_max: int = 256
    deadline_s: float = 0.25
    deadline_jitter: float = 0.0
    priority_mix: tuple = ((Priority.NORMAL, 1.0),)

    def __post_init__(self):
        if self.arrival not in _ARRIVALS:
            raise ValueError(f"arrival must be one of {_ARRIVALS}, "
                             f"got {self.arrival!r}")
        if self.pareto_alpha <= 1.0:
            raise ValueError("pareto_alpha must be > 1 (finite mean)")
        if self.rate_rps <= 0 or self.n_requests < 1:
            raise ValueError("rate_rps must be > 0 and n_requests >= 1")


@dataclass(frozen=True)
class TrafficRequest:
    """One scheduled arrival of a trace."""

    t: float                        # arrival offset from trace start (s)
    tenant: str
    n_samples: int
    deadline_s: float
    priority: Priority


def interarrivals(pattern: TrafficPattern, n: int,
                  rng: np.random.Generator) -> np.ndarray:
    """``n`` interarrival gaps (seconds) for ``pattern``'s process."""
    mean = 1.0 / pattern.rate_rps
    if pattern.arrival == "poisson":
        return rng.exponential(mean, n)
    # Lomax (Pareto II): mean = scale / (alpha - 1); scale chosen so the
    # heavy-tail process offers the same long-run rate as the Poisson one
    a = pattern.pareto_alpha
    return rng.pareto(a, n) * (mean * (a - 1.0))


def build_trace(patterns: list[TrafficPattern],
                seed: int = 0) -> list[TrafficRequest]:
    """Merge per-tenant arrival streams into one time-sorted trace.

    Deterministic in ``(patterns, seed)``: each pattern gets its own
    child seed, so adding a tenant never perturbs another's stream.
    """
    rng = np.random.default_rng(seed)
    trace: list[TrafficRequest] = []
    for pat, child in zip(patterns, rng.spawn(len(patterns))):
        t = np.cumsum(interarrivals(pat, pat.n_requests, child))
        sizes = np.minimum(child.geometric(1.0 / max(1.0, pat.size_mean),
                                           pat.n_requests), pat.size_max)
        prios = [p for p, _ in pat.priority_mix]
        weights = np.asarray([w for _, w in pat.priority_mix], float)
        picks = child.choice(len(prios), pat.n_requests,
                             p=weights / weights.sum())
        jit = child.uniform(-pat.deadline_jitter, pat.deadline_jitter,
                            pat.n_requests) if pat.deadline_jitter else \
            np.zeros(pat.n_requests)
        trace.extend(
            TrafficRequest(t=float(t[i]), tenant=pat.tenant,
                           n_samples=int(sizes[i]),
                           deadline_s=float(pat.deadline_s * (1.0 + jit[i])),
                           priority=prios[int(picks[i])])
            for i in range(pat.n_requests))
    return sorted(trace, key=lambda r: (r.t, r.tenant))


@dataclass
class TrafficReport:
    """Outcome of one closed-loop trace run (the ``serve.traffic.*``
    row source).  ``deadline-miss`` counts admitted requests that
    failed their deadline either way — completed late or expired before
    dispatch; ``shed`` counts every :class:`RequestRejected`; goodput
    counts only samples completed in-deadline."""

    offered: int = 0
    completed: int = 0
    shed: int = 0
    deadline_missed: int = 0            # late completions + queue expiries
    goodput_samples: int = 0
    elapsed_s: float = 0.0
    latencies_s: list = field(default_factory=list)
    shed_by_code: dict = field(default_factory=dict)
    per_tenant: dict = field(default_factory=dict)

    def _pct(self, q: float) -> float | None:
        if not self.latencies_s:
            return None
        return float(np.percentile(np.asarray(self.latencies_s), q))

    @property
    def p50_ms(self) -> float | None:
        p = self._pct(50)
        return None if p is None else p * 1e3

    @property
    def p99_ms(self) -> float | None:
        p = self._pct(99)
        return None if p is None else p * 1e3

    @property
    def shed_rate(self) -> float:
        return self.shed / max(1, self.offered)

    @property
    def deadline_miss_rate(self) -> float:
        return self.deadline_missed / max(1, self.offered)

    @property
    def goodput_sps(self) -> float:
        return self.goodput_samples / max(1e-9, self.elapsed_s)

    def to_dict(self) -> dict:
        return {
            "offered": self.offered, "completed": self.completed,
            "shed": self.shed, "shed_by_code": dict(self.shed_by_code),
            "shed_rate": round(self.shed_rate, 4),
            "deadline_missed": self.deadline_missed,
            "deadline_miss_rate": round(self.deadline_miss_rate, 4),
            "goodput_samples_per_s": round(self.goodput_sps, 1),
            "p50_ms": None if self.p50_ms is None else round(self.p50_ms, 3),
            "p99_ms": None if self.p99_ms is None else round(self.p99_ms, 3),
            "elapsed_s": round(self.elapsed_s, 4),
            "per_tenant": dict(self.per_tenant),
        }


async def run_trace(door: FrontDoor, trace: list[TrafficRequest], *,
                    seed: int = 0, time_scale: float = 1.0
                    ) -> TrafficReport:
    """Drive ``door`` with ``trace`` closed-loop and report.

    Arrivals are scheduled at ``trace[i].t * time_scale`` on the wall
    clock; request payloads are seeded random bits per tenant.  The
    front door must already have every tenant in the trace registered.
    """
    rng = np.random.default_rng(seed)
    report = TrafficReport()
    lock = asyncio.Lock()               # report mutation is awaited-only
    n_inputs = {name: t.graph.n_inputs for name, t in door.tenants.items()}

    async def issue(req: TrafficRequest, bits: np.ndarray) -> None:
        t0 = time.monotonic()
        try:
            out = await door.submit(req.tenant, bits,
                                    deadline_s=req.deadline_s,
                                    priority=req.priority)
            latency = time.monotonic() - t0
            async with lock:
                report.completed += 1
                report.latencies_s.append(latency)
                tenant = report.per_tenant.setdefault(
                    req.tenant, {"completed": 0, "shed": 0})
                tenant["completed"] += 1
                if latency > req.deadline_s:
                    report.deadline_missed += 1
                else:
                    report.goodput_samples += int(out.shape[0])
        except RequestRejected as exc:
            async with lock:
                report.shed += 1
                code = exc.reason.code
                report.shed_by_code[code] = \
                    report.shed_by_code.get(code, 0) + 1
                if code == "deadline_expired":
                    report.deadline_missed += 1
                tenant = report.per_tenant.setdefault(
                    req.tenant, {"completed": 0, "shed": 0})
                tenant["shed"] += 1

    await door.start()
    start = time.monotonic()
    tasks = []
    for req in trace:
        delay = start + req.t * time_scale - time.monotonic()
        if delay > 0:
            await asyncio.sleep(delay)
        bits = rng.integers(0, 2, (req.n_samples,
                                   n_inputs[req.tenant])).astype(bool)
        report.offered += 1
        tasks.append(asyncio.create_task(issue(req, bits)))
    await asyncio.gather(*tasks)
    report.elapsed_s = time.monotonic() - start
    return report


def run_trace_sync(door: FrontDoor, trace: list[TrafficRequest], *,
                   seed: int = 0, time_scale: float = 1.0) -> TrafficReport:
    """Synchronous convenience wrapper (one fresh event loop)."""
    async def go():
        async with door:
            return await run_trace(door, trace, seed=seed,
                                   time_scale=time_scale)
    return asyncio.run(go())
