"""Async serving front door over :class:`~repro.serve.LogicEngine`.

``LogicEngine``/``SlotTable`` are a library API: callers submit, step,
and claim, and nothing enforces deadlines, sheds load, isolates tenants,
or survives a mid-request eviction/recompile storm.  This module is the
production front door (DESIGN.md §9) that turns the compiled-logic
artifact into a *service whose failure behavior is specified*:

admission (``submit``)
    Every request carries a **deadline** and a **priority class**.
    Admission is synchronous and can reject immediately with a
    machine-readable :class:`ShedReason`: the bounded queue is full
    (``queue_full`` — unless a strictly lower-priority victim can be
    displaced, ``displaced``), or the projected wait — queued + inflight
    samples over the engine's measured wave throughput — already
    exceeds the deadline (``deadline_infeasible``).  Shedding at the
    door, before any work is queued, is what keeps the p99 of *admitted*
    requests bounded under overload.

dispatch (the one async loop)
    Queued tickets are popped highest-priority-first, round-robin
    across tenants within a class (no tenant starves another), capped
    per tenant by ``max_inflight``.  **Expired work is dropped before
    dispatch, not after**: a ticket whose deadline passed while queued
    is rejected (``deadline_expired``) without touching the engine.
    Dispatched tickets enter the engine's slot/word batching; the
    engine steps in a thread-pool executor so the event loop keeps
    admitting while the fabric runs.

faults and retries
    Recoverable faults — a program LRU-evicted mid-flight, a transient
    compile failure (:class:`~repro.core.errors.TransientCompileError`)
    — are retried with bounded exponential backoff; permanent compile
    failures shed with ``compile_failed``; exhausted retries with
    ``retries_exhausted``.  :class:`FaultPolicy` injects all three
    fault kinds (drop / delay / fail-compile / evict) with seeded
    determinism so every degradation path is testable, not accidental.

tenancy
    Many ``CompileSpec``-keyed models share one engine + one
    :class:`~repro.serve.ProgramCache` (thread-safe since this PR).
    Results route by engine uid, so a tenant can never observe another
    tenant's bits; fairness is round-robin at dispatch, isolation is
    the per-tenant inflight cap.

The closed-loop traffic generator that drives this under Poisson /
heavy-tail arrivals lives in :mod:`repro.serve.traffic`.
"""
from __future__ import annotations

import asyncio
import time
from collections import OrderedDict, deque
from dataclasses import dataclass, field
from enum import IntEnum

import numpy as np

from repro.core.artifact_store import ArtifactStore
from repro.core.errors import TransientCompileError, is_transient
from repro.core.gate_ir import LogicGraph
from repro.core.spec import CompileSpec
from repro.serve.logic_engine import LogicEngine


class Priority(IntEnum):
    """Admission priority classes (lower value = served first)."""

    HIGH = 0
    NORMAL = 1
    BATCH = 2


#: every rejection's ``ShedReason.code`` is one of these (the
#: machine-readable contract: clients and tests switch on the code,
#: never on message text)
SHED_CODES = (
    "queue_full",           # bounded admission queue at capacity
    "deadline_infeasible",  # projected wait already exceeds the deadline
    "deadline_expired",     # expired while queued/retrying: dropped pre-dispatch
    "displaced",            # evicted from the queue by a higher-priority arrival
    "injected_drop",        # FaultPolicy dropped it at dispatch
    "compile_failed",       # permanent compile failure (errors.py taxonomy)
    "retries_exhausted",    # transient faults outlived the retry budget
    "shutdown",             # front door stopped without draining
)


@dataclass(frozen=True)
class ShedReason:
    """Why a request was rejected — machine-readable, code-first."""

    code: str                           # one of SHED_CODES
    tenant: str = ""
    detail: str = ""
    projected_wait_s: float | None = None

    def __post_init__(self):
        if self.code not in SHED_CODES:
            raise ValueError(f"unknown shed code {self.code!r}")

    def to_dict(self) -> dict:
        d = {"code": self.code, "tenant": self.tenant}
        if self.detail:
            d["detail"] = self.detail
        if self.projected_wait_s is not None:
            d["projected_wait_s"] = round(self.projected_wait_s, 6)
        return d


class RequestRejected(RuntimeError):
    """Raised to the submitter when the front door sheds a request."""

    def __init__(self, reason: ShedReason):
        super().__init__(f"request shed: {reason.to_dict()}")
        self.reason = reason


@dataclass
class FaultPolicy:
    """Seeded-deterministic fault injection for the front door.

    Rates are per-decision probabilities drawn from one
    ``numpy.random.default_rng(seed)`` stream, so a given (policy,
    traffic) pair replays the exact same fault schedule.  Fault kinds:

    * ``drop_rate`` — drop the request at dispatch (client sees an
      ``injected_drop`` rejection; models a lossy ingress hop).
    * ``delay_rate`` / ``delay_s`` — stall dispatch by ``delay_s``
      (models a slow ingress hop; inflates latency and can push a
      request over its deadline — the graceful-degradation path).
    * ``compile_fail_rate`` / ``compile_fail_first`` — raise
      :class:`TransientCompileError` from the compiler's fault hook on
      an admission-time cache-miss compile (``compile_fail_first`` N
      fails the first N compiles deterministically; the rate draws
      after that).  Exercises retry-with-backoff.
    * ``evict_rate`` — before an engine wave, LRU-evict one program
      cache entry (an eviction storm); the engine's mid-flight
      recompile path must absorb it.
    """

    seed: int = 0
    drop_rate: float = 0.0
    delay_rate: float = 0.0
    delay_s: float = 0.002
    compile_fail_rate: float = 0.0
    compile_fail_first: int = 0
    evict_rate: float = 0.0

    injected: dict = field(default_factory=lambda: {
        "drop": 0, "delay": 0, "compile_fail": 0, "evict": 0})

    def __post_init__(self):
        for name in ("drop_rate", "delay_rate", "compile_fail_rate",
                     "evict_rate"):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{name} must be in [0, 1], got {v}")
        self._rng = np.random.default_rng(self.seed)
        self._compile_calls = 0

    def _draw(self, rate: float, kind: str) -> bool:
        hit = rate > 0.0 and float(self._rng.random()) < rate
        if hit:
            self.injected[kind] += 1
        return hit

    def take_drop(self) -> bool:
        return self._draw(self.drop_rate, "drop")

    def take_delay(self) -> float:
        """Injected dispatch delay in seconds (0.0 = none)."""
        return self.delay_s if self._draw(self.delay_rate, "delay") else 0.0

    def take_compile_fail(self) -> bool:
        self._compile_calls += 1
        if self._compile_calls <= self.compile_fail_first:
            self.injected["compile_fail"] += 1
            return True
        return self._draw(self.compile_fail_rate, "compile_fail")

    def take_evict(self) -> bool:
        return self._draw(self.evict_rate, "evict")


@dataclass
class Tenant:
    """One registered model sharing the front door's engine + cache."""

    name: str
    graph: LogicGraph
    max_inflight: int | None = None
    inflight: int = 0                  # dispatched, not yet finished
    submitted: int = 0
    completed: int = 0
    shed: int = 0


@dataclass
class _Ticket:
    """One admitted request waiting for dispatch / completion."""

    tenant: Tenant
    bits: np.ndarray
    priority: Priority
    arrival_t: float
    deadline: float                    # absolute, on the front door clock
    future: asyncio.Future
    attempts: int = 0                  # dispatch attempts so far

    @property
    def n_samples(self) -> int:
        return int(self.bits.shape[0])


class FrontDoor:
    """Async admission layer over one shared :class:`LogicEngine`.

    Args:
      engine: the engine to front (one is built from ``spec`` /
        ``capacity`` when omitted).  The engine's ``ProgramCache`` is
        shared by every tenant; per-engine runner keying plus uid-routed
        results keep tenants isolated.
      spec / capacity / store: engine construction knobs when ``engine``
        is omitted (``store`` warm-starts the door's ProgramCache from a
        shared artifact-store directory — a fresh front-door process
        serves its first request with zero compiles when the store was
        precompiled, e.g. by ``tools/precompile.py``).
      max_queue: bound on queued (admitted, undispatched) requests
        across all tenants — beyond it arrivals shed ``queue_full``
        unless they can displace a strictly lower-priority victim.
      default_deadline_s: deadline for submits that don't carry one.
      max_retries: dispatch attempts per request beyond the first for
        transient faults; exhausted -> ``retries_exhausted``.
      backoff_s / backoff_cap_s: exponential retry backoff
        ``min(cap, backoff * 2**(attempt-1))``.
      fault_policy: optional :class:`FaultPolicy`; installs the
        compiler fault hook when compile faults are configured.
      dispatch_batch: max tickets dispatched per loop round (bounds the
        per-round admission latency under a flood).
    """

    def __init__(self, engine: LogicEngine | None = None, *,
                 spec: CompileSpec | None = None, capacity: int = 256,
                 store: ArtifactStore | None = None,
                 max_queue: int = 64, default_deadline_s: float = 1.0,
                 max_retries: int = 3, backoff_s: float = 0.002,
                 backoff_cap_s: float = 0.05,
                 fault_policy: FaultPolicy | None = None,
                 dispatch_batch: int = 16):
        if max_queue < 1:
            raise ValueError("max_queue must be >= 1")
        if engine is not None and store is not None:
            raise ValueError(
                "store backs the door-owned engine; attach an "
                "ArtifactStore to the shared engine's ProgramCache at its "
                "own construction instead")
        self.engine = engine if engine is not None else \
            LogicEngine(spec, capacity=capacity, store=store)
        self.max_queue = max_queue
        self.default_deadline_s = default_deadline_s
        self.max_retries = max_retries
        self.backoff_s = backoff_s
        self.backoff_cap_s = backoff_cap_s
        self.dispatch_batch = dispatch_batch
        self.fault_policy = fault_policy
        self._clock = time.monotonic
        if fault_policy is not None and (fault_policy.compile_fail_rate > 0
                                         or fault_policy.compile_fail_first):
            self.engine.cache.compiler.fault_hook = self._compile_fault_hook
        # injected compile failures arm only around admission-time
        # dispatch: the engine's mid-wave recompile (eviction recovery)
        # stays fault-free so every admitted request keeps making
        # progress — DESIGN.md §9 fault taxonomy.
        self._compile_faults_armed = False

        self._tenants: dict[str, Tenant] = {}
        # priority tier -> tenant name -> FIFO of tickets; dispatch
        # walks tiers in order and round-robins tenants within a tier
        self._queues: dict[Priority, OrderedDict[str, deque[_Ticket]]] = {
            p: OrderedDict() for p in Priority}
        self._rr: dict[Priority, int] = {p: 0 for p in Priority}
        self._n_queued = 0
        self._queued_samples = 0
        self._inflight: dict[int, _Ticket] = {}     # engine uid -> ticket
        self._inflight_samples = 0
        self._retry_tasks: set[asyncio.Task] = set()

        self._task: asyncio.Task | None = None
        self._wake: asyncio.Event | None = None
        self._stopping = False

        # service-rate estimate: median of the last 16 engine-wave
        # wall-clocks.  Median, not EWMA: cold-compile and
        # eviction-recompile waves are huge outliers, and an estimate
        # they inflate would shed EVERYTHING as deadline_infeasible —
        # the opposite of graceful degradation.
        self._wave_times: deque[float] = deque(maxlen=16)

        # metrics
        self.offered = 0
        self.admitted = 0
        self.completed = 0
        self.retries = 0
        self.deadline_misses = 0        # admitted but finished late
        self.goodput_samples = 0        # samples completed in-deadline
        self.shed_by_code: dict[str, int] = {}
        self._latencies: list[float] = []

    # -- tenancy -------------------------------------------------------------

    def register(self, name: str, graph: LogicGraph, *,
                 max_inflight: int | None = None) -> Tenant:
        """Register a tenant model.  Compilation is lazy (first
        dispatch compiles through the shared cache), so registration is
        cheap and a registration-time fault cannot exist."""
        if name in self._tenants:
            raise ValueError(f"tenant {name!r} already registered")
        tenant = Tenant(name=name, graph=graph, max_inflight=max_inflight)
        self._tenants[name] = tenant
        for tier in self._queues.values():
            tier[name] = deque()
        return tenant

    @property
    def tenants(self) -> dict[str, Tenant]:
        return dict(self._tenants)

    # -- lifecycle -----------------------------------------------------------

    async def start(self) -> None:
        if self._task is not None:
            return
        self._wake = asyncio.Event()
        self._stopping = False
        self._task = asyncio.create_task(self._run())

    async def stop(self, drain: bool = True) -> None:
        """Stop the dispatch loop.  ``drain=True`` serves everything
        already admitted first; ``drain=False`` sheds queued tickets
        with ``shutdown`` (inflight engine work still completes)."""
        if self._task is None:
            return
        if not drain:
            for tier in self._queues.values():
                for name, q in tier.items():
                    while q:
                        self._reject(q.popleft(), "shutdown", queued=True)
        self._stopping = True
        self._wake.set()
        await self._task
        self._task = None
        for t in list(self._retry_tasks):
            t.cancel()

    async def __aenter__(self) -> "FrontDoor":
        await self.start()
        return self

    async def __aexit__(self, *exc) -> None:
        await self.stop(drain=not any(exc))

    # -- admission -----------------------------------------------------------

    async def submit(self, tenant: str, bits: np.ndarray, *,
                     deadline_s: float | None = None,
                     priority: Priority = Priority.NORMAL) -> np.ndarray:
        """Admit one request and await its ``(n, n_outputs)`` result.

        Raises :class:`RequestRejected` (with a machine-readable
        ``.reason``) when shed — at admission, pre-dispatch expiry, or
        fault handling; raises ``KeyError`` for an unknown tenant and
        ``ValueError`` for a shape mismatch (caller bugs, not load)."""
        ten = self._tenants[tenant]
        bits = np.asarray(bits, dtype=bool)
        if bits.ndim != 2 or bits.shape[1] != ten.graph.n_inputs:
            raise ValueError(f"tenant {tenant!r} inputs must be "
                             f"(n, {ten.graph.n_inputs}), got {bits.shape}")
        if bits.shape[0] == 0:          # trivially complete: no admission
            return np.zeros((0, ten.graph.n_outputs), dtype=bool)
        if self._task is None:
            await self.start()          # lazy start on first submit
        now = self._clock()
        rel_deadline = (self.default_deadline_s if deadline_s is None
                        else deadline_s)
        self.offered += 1
        ten.submitted += 1
        reason = self._admission_check(ten, bits.shape[0], rel_deadline,
                                       priority)
        if reason is not None:
            ten.shed += 1
            self.shed_by_code[reason.code] = \
                self.shed_by_code.get(reason.code, 0) + 1
            raise RequestRejected(reason)
        ticket = _Ticket(tenant=ten, bits=bits, priority=priority,
                         arrival_t=now, deadline=now + rel_deadline,
                         future=asyncio.get_running_loop().create_future())
        self.admitted += 1
        self._enqueue(ticket)
        return await ticket.future

    def _admission_check(self, tenant: Tenant, n_samples: int,
                         rel_deadline: float, priority: Priority
                         ) -> ShedReason | None:
        """None = admit; a ShedReason = reject at the door."""
        wait = self.projected_wait_s(n_samples)
        if wait is not None and wait > rel_deadline:
            return ShedReason("deadline_infeasible", tenant=tenant.name,
                              projected_wait_s=wait,
                              detail=f"deadline_s={rel_deadline:.4f}")
        if self._n_queued >= self.max_queue:
            if self._displace(priority):
                return None
            return ShedReason("queue_full", tenant=tenant.name,
                              detail=f"max_queue={self.max_queue}")
        return None

    @property
    def wave_s(self) -> float | None:
        """Robust engine-wave service-time estimate (median of the last
        16 waves); ``None`` until a wave has been measured."""
        if not self._wave_times:
            return None
        return float(np.median(np.asarray(self._wave_times)))

    def projected_wait_s(self, n_samples: int = 0) -> float | None:
        """Estimated queueing delay for a new ``n_samples``-sample
        request: backlog (queued + inflight + this request) in engine
        waves times the measured wave time.  ``None`` until the first
        wave has been measured (admission then skips the feasibility
        check rather than guessing)."""
        wave = self.wave_s
        if wave is None:
            return None
        backlog = self._queued_samples + self._inflight_samples + n_samples
        waves = -(-backlog // self.engine.capacity)
        return waves * wave

    def _displace(self, priority: Priority) -> bool:
        """Evict the most recent, lowest-priority queued ticket that is
        STRICTLY lower-priority than the arrival; False when none is."""
        for tier_prio in sorted(Priority, reverse=True):
            if tier_prio <= priority:
                return False
            tier = self._queues[tier_prio]
            for name in reversed(list(tier.keys())):
                if tier[name]:
                    victim = tier[name].pop()
                    self._n_queued -= 1
                    self._queued_samples -= victim.n_samples
                    self._reject(victim, "displaced", queued=False,
                                 detail=f"by_priority={priority.name}")
                    return True
        return False

    def _enqueue(self, ticket: _Ticket, *, front: bool = False) -> None:
        q = self._queues[ticket.priority][ticket.tenant.name]
        (q.appendleft if front else q.append)(ticket)
        self._n_queued += 1
        self._queued_samples += ticket.n_samples
        if self._wake is not None:
            self._wake.set()

    # -- rejection / completion bookkeeping ----------------------------------

    def _reject(self, ticket: _Ticket, code: str, *, queued: bool = False,
                detail: str = "") -> None:
        """Reject an already-admitted ticket (post-admission shed)."""
        if queued:      # caller did not already fix the queue counters
            self._n_queued -= 1
            self._queued_samples -= ticket.n_samples
        reason = ShedReason(code, tenant=ticket.tenant.name, detail=detail)
        ticket.tenant.shed += 1
        self.shed_by_code[code] = self.shed_by_code.get(code, 0) + 1
        if code == "deadline_expired":
            self.deadline_misses += 1
        if not ticket.future.done():
            ticket.future.set_exception(RequestRejected(reason))

    def _complete(self, ticket: _Ticket, result: np.ndarray) -> None:
        now = self._clock()
        latency = now - ticket.arrival_t
        self._latencies.append(latency)
        self.completed += 1
        ticket.tenant.completed += 1
        if now > ticket.deadline:
            self.deadline_misses += 1
        else:
            self.goodput_samples += ticket.n_samples
        if not ticket.future.done():
            ticket.future.set_result(result)

    # -- the dispatch loop ---------------------------------------------------

    async def _run(self) -> None:
        loop = asyncio.get_running_loop()
        while True:
            batch = self._pop_batch(self._clock())
            for ticket in batch:
                await self._dispatch(ticket)
            if self._inflight:
                finished = await loop.run_in_executor(None, self._step)
                self._route(finished)
                continue
            if batch:
                continue
            if self._stopping and not self._n_queued and not self._inflight \
                    and not self._retry_tasks:
                return
            try:        # idle: sleep until new work or a 5 ms deadline tick
                await asyncio.wait_for(self._wake.wait(), timeout=0.005)
            except asyncio.TimeoutError:
                pass
            self._wake.clear()

    def _pop_batch(self, now: float) -> list[_Ticket]:
        """Highest-priority-first, round-robin across tenants within a
        tier, per-tenant inflight caps respected, expired tickets
        dropped before dispatch."""
        out: list[_Ticket] = []
        budget = self.dispatch_batch
        for prio in Priority:
            tier = self._queues[prio]
            names = list(tier.keys())
            if not names or budget <= 0:
                continue
            start = self._rr[prio] % len(names)
            stalled = 0                 # tenants in a row with nothing to give
            i = start
            while budget > 0 and stalled < len(names):
                name = names[i % len(names)]
                i += 1
                q = tier[name]
                # deadline check BEFORE dispatch: expired work never
                # reaches the engine
                while q and q[0].deadline < now:
                    t = q.popleft()
                    self._n_queued -= 1
                    self._queued_samples -= t.n_samples
                    self._reject(t, "deadline_expired")
                ten = self._tenants[name]
                if not q or (ten.max_inflight is not None
                             and ten.inflight >= ten.max_inflight):
                    stalled += 1
                    continue
                stalled = 0
                t = q.popleft()
                self._n_queued -= 1
                self._queued_samples -= t.n_samples
                ten.inflight += 1       # reserved; released on finish/shed
                out.append(t)
                budget -= 1
            self._rr[prio] = i
        return out

    def _compile_fault_hook(self, graph, spec) -> None:
        pol = self.fault_policy
        if (pol is not None and self._compile_faults_armed
                and pol.take_compile_fail()):
            raise TransientCompileError(
                "injected transient compile failure "
                f"(FaultPolicy seed={pol.seed})")

    async def _dispatch(self, ticket: _Ticket) -> None:
        pol = self.fault_policy
        if pol is not None:
            if pol.take_drop():
                ticket.tenant.inflight -= 1
                self._reject(ticket, "injected_drop")
                return
            delay = pol.take_delay()
            if delay:
                await asyncio.sleep(delay)
                if ticket.deadline < self._clock():
                    ticket.tenant.inflight -= 1
                    self._reject(ticket, "deadline_expired",
                                 detail="expired during injected delay")
                    return
        try:
            self._compile_faults_armed = True
            uid = self.engine.submit(ticket.tenant.graph, ticket.bits)
        except Exception as exc:
            ticket.tenant.inflight -= 1
            if is_transient(exc):
                self._schedule_retry(ticket, exc)
            else:
                self._reject(ticket, "compile_failed", detail=repr(exc))
            return
        finally:
            self._compile_faults_armed = False
        self._inflight[uid] = ticket
        self._inflight_samples += ticket.n_samples

    def _schedule_retry(self, ticket: _Ticket, exc: Exception) -> None:
        ticket.attempts += 1
        if ticket.attempts > self.max_retries:
            self._reject(ticket, "retries_exhausted",
                         detail=f"attempts={ticket.attempts} last={exc!r}")
            return
        self.retries += 1
        backoff = min(self.backoff_cap_s,
                      self.backoff_s * 2 ** (ticket.attempts - 1))

        async def requeue():
            await asyncio.sleep(backoff)
            if ticket.deadline < self._clock():
                self._reject(ticket, "deadline_expired",
                             detail="expired during retry backoff")
            else:       # retries re-enter at the FRONT: age beats arrival
                self._enqueue(ticket, front=True)

        task = asyncio.create_task(requeue())
        self._retry_tasks.add(task)
        task.add_done_callback(self._retry_tasks.discard)

    def _step(self) -> list[int]:
        """One engine wave in the executor thread; measures wave time
        for the admission-control throughput estimate and applies the
        eviction-storm fault."""
        pol = self.fault_policy
        if pol is not None and pol.take_evict():
            self.engine.cache.evict()   # LRU storm; step() recompiles
        t0 = self._clock()
        finished = self.engine.step()
        self._wave_times.append(self._clock() - t0)
        return finished

    def _route(self, finished: list[int]) -> None:
        for uid in finished:
            ticket = self._inflight.pop(uid, None)
            if ticket is None:          # engine-level submitter wasn't us
                continue
            result = self.engine.result(uid)
            ticket.tenant.inflight -= 1
            self._inflight_samples -= ticket.n_samples
            self._complete(ticket, result)

    # -- metrics -------------------------------------------------------------

    @property
    def queue_depth(self) -> int:
        return self._n_queued

    def reset_metrics(self) -> None:
        """Zero the request counters and latency window (e.g. after the
        compile/jit warmup waves), so steady-state measurements aren't
        polluted by cold starts.  The wave-time window, tenant registry,
        and engine/cache state stay — they ARE the warm state."""
        self.offered = self.admitted = self.completed = 0
        self.retries = self.deadline_misses = self.goodput_samples = 0
        self.shed_by_code = {}
        self._latencies = []
        for t in self._tenants.values():
            t.submitted = t.completed = t.shed = 0

    def metrics(self) -> dict:
        lat = np.asarray(self._latencies, dtype=float)
        shed = int(sum(self.shed_by_code.values()))
        return {
            "offered": self.offered,
            "admitted": self.admitted,
            "completed": self.completed,
            "shed": shed,
            "shed_by_code": dict(self.shed_by_code),
            "shed_rate": shed / max(1, self.offered),
            "deadline_misses": self.deadline_misses,
            "deadline_miss_rate": self.deadline_misses / max(1, self.offered),
            "retries": self.retries,
            "goodput_samples": self.goodput_samples,
            "latency_p50_ms": (float(np.percentile(lat, 50)) * 1e3
                               if lat.size else None),
            "latency_p99_ms": (float(np.percentile(lat, 99)) * 1e3
                               if lat.size else None),
            "wave_est_ms": (None if self.wave_s is None
                            else self.wave_s * 1e3),
            "faults_injected": (dict(self.fault_policy.injected)
                                if self.fault_policy else {}),
            "tenants": {n: {"submitted": t.submitted,
                            "completed": t.completed, "shed": t.shed,
                            "inflight": t.inflight}
                        for n, t in self._tenants.items()},
            "engine": self.engine.stats(),
        }
