"""Attention: GQA + qk-norm + sliding-window + bidirectional + cached decode.

Shapes: x (B, S, D); q heads H, kv heads Hk (H % Hk == 0); head_dim hd.
GQA is computed grouped — q reshaped (B, S, Hk, G, hd) against k/v
(B, S, Hk, hd) — so no materialized kv repetition (memory term win).

Decode: the KV cache is (B, C, Hk, hd) per layer. For sliding-window archs
the cache is a ring buffer of C = window entries (O(window) memory at 500k
context — the long_500k cells rely on this). The decode softmax is written
with explicit max/sum so XLA SPMD can convert a *sequence-sharded* cache
(C over 'model') into local partial attention + a tiny AllReduce — the
flash-decoding-style layout used when kv_heads < model-axis size.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, rms_norm
from repro.models.pspec_utils import active_mesh, constrain

NEG_INF = -1e30


def _constrain_qkv(q, k, v, cfg):
    """In-attention layout choice (measured in EXPERIMENTS.md §Perf).

    If the (repeated) head count divides the 'model' axis, shard heads —
    scores (B, H, S, T) partition on H. Otherwise (minicpm: 36 heads vs
    model=16) XLA would REPLICATE the S x T score tensor on every device
    (+35 GiB/dev at train_4k); instead shard the QUERY sequence over
    'model' (sequence-parallel attention: keys/values gathered, queries
    local) — causal masking is position-based so a sharded query block
    masks correctly."""
    from repro.models.pspec_utils import dp_axes
    mesh = active_mesh()
    if mesh is None or "model" not in mesh.axis_names \
            or "model" in dp_axes():   # pure-DP: batch owns 'model'
        return q, k, v
    model = mesh.shape["model"]
    if cfg.n_heads % model == 0:
        # shardable heads: XLA's propagation already partitions the score
        # tensor on H; forcing placements here measured WORSE (qwen3-8b
        # train_4k collective 7.1 -> 12.2 s/step) — refuted, leave to XLA.
        return q, k, v
    q = constrain(q, "dp", "model", None, None)
    k = constrain(k, "dp", None, None, None)
    v = constrain(v, "dp", None, None, None)
    return q, k, v


class KVCache(NamedTuple):
    k: jnp.ndarray        # (B, C, Hk, hd)
    v: jnp.ndarray        # (B, C, Hk, hd)
    # () int32: tokens written so far (ring position = length % C)
    length: jnp.ndarray


def init_cache(batch: int, capacity: int, n_kv_heads: int, head_dim: int,
               dtype) -> KVCache:
    shape = (batch, capacity, n_kv_heads, head_dim)
    return KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
                   length=jnp.zeros((), jnp.int32))


def _qkv(params, x, cfg):
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = (x @ params["wq"].astype(x.dtype)).reshape(b, s, cfg.n_heads, hd)
    k = (x @ params["wk"].astype(x.dtype)).reshape(b, s, cfg.n_kv_heads, hd)
    v = (x @ params["wv"].astype(x.dtype)).reshape(b, s, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"])
        k = rms_norm(k, params["k_norm"])
    return q, k, v


def _grouped_scores(q, k):
    """q (B,S,Hk,G,hd) x k (B,T,Hk,hd) -> (B,Hk,G,S,T)."""
    return jnp.einsum("bshgd,bthd->bhgst", q, k)


def _grouped_out(w, v):
    """w (B,Hk,G,S,T) x v (B,T,Hk,hd) -> (B,S,Hk,G,hd)."""
    return jnp.einsum("bhgst,bthd->bshgd", w, v)


def attention_forward(params: dict, x: jnp.ndarray, cfg, *,
                      positions: jnp.ndarray,
                      causal: bool = True,
                      window: int = 0) -> jnp.ndarray:
    """Full (train/prefill) attention. window > 0 => sliding-window causal.

    GQA is computed with KV *repeated to the full H query heads* before the
    score einsum. Rationale (sharding): kv_heads (8) is smaller than the
    'model' axis (16), so any layout keyed on kv-heads replicates the
    (B, heads, S, S) score tensor — 100+ GiB/device at train_4k. Repeating
    KV keeps the head axis at H (32), which shards cleanly; the repeated
    K/V themselves are ~MBs. (The grouped, non-repeated form is kept for
    the decode path, where scores are (B,*,1,C) and C is what we shard.)
    """
    b, s, d = x.shape
    hd = cfg.resolved_head_dim
    g = cfg.q_per_kv
    q, k, v = _qkv(params, x, cfg)
    if not cfg.is_encoder:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    if g > 1:
        k = jnp.repeat(k, g, axis=2)              # (B, T, H, hd)
        v = jnp.repeat(v, g, axis=2)
    q, k, v = _constrain_qkv(q, k, v, cfg)
    scores = jnp.einsum("bshd,bthd->bhst",
                        q.astype(jnp.float32) * (hd ** -0.5),
                        k.astype(jnp.float32))    # (B, H, S, T)
    ii = positions[:, :, None]                    # (B, S, 1) query pos
    jj = positions[:, None, :]                    # (B, 1, S) key pos
    if causal:
        mask = jj <= ii
        if window:
            mask &= jj > ii - window
    else:
        mask = jnp.ones((b, s, s), bool)
    scores = jnp.where(mask[:, None, :, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhst,bthd->bshd", w, v).reshape(
        b, s, cfg.n_heads * hd)
    return out @ params["wo"].astype(x.dtype)


def attention_decode(params: dict, x: jnp.ndarray, cfg, cache: KVCache, *,
                     window: int = 0) -> tuple[jnp.ndarray, KVCache]:
    """One-token decode step. x: (B, 1, D). Ring-buffer cache when window>0."""
    b, s, d = x.shape
    assert s == 1, "decode step takes one token"
    hd = cfg.resolved_head_dim
    hk, g = cfg.n_kv_heads, cfg.q_per_kv
    cap = cache.k.shape[1]
    pos = cache.length                                      # () int32
    positions = jnp.broadcast_to(pos[None], (b, 1))
    q, k, v = _qkv(params, x, cfg)
    if not cfg.is_encoder:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    slot = jax.lax.rem(pos, jnp.int32(cap))
    new_k = jax.lax.dynamic_update_slice(cache.k, k.astype(cache.k.dtype),
                                         (0, slot, 0, 0))
    new_v = jax.lax.dynamic_update_slice(cache.v, v.astype(cache.v.dtype),
                                         (0, slot, 0, 0))
    # validity: entry t is live iff written; after the ring wraps, all are
    t = jnp.arange(cap, dtype=jnp.int32)
    live = jnp.where(pos + 1 >= cap, jnp.ones((cap,), bool), t <= slot)
    qg = q.reshape(b, 1, hk, g, hd).astype(jnp.float32) * (hd ** -0.5)
    scores = _grouped_scores(qg, new_k.astype(jnp.float32))  # (B,Hk,G,1,C)
    scores = jnp.where(live[None, None, None, None, :], scores, NEG_INF)
    # explicit max/sum softmax => SPMD-friendly over a C-sharded cache
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - jax.lax.stop_gradient(m))
    z = jnp.sum(e, axis=-1, keepdims=True)
    w = (e / z).astype(x.dtype)
    out = _grouped_out(w, new_v).reshape(b, 1, cfg.n_heads * hd)
    out = out @ params["wo"].astype(x.dtype)
    return out, KVCache(k=new_k, v=new_v, length=pos + 1)


def prefill_cache(params: dict, x: jnp.ndarray, cfg, capacity: int, *,
                  positions: jnp.ndarray, window: int = 0
                  ) -> tuple[jnp.ndarray, KVCache]:
    """Prefill: full attention + populate the cache (last `capacity` keys)."""
    b, s, _ = x.shape
    out = attention_forward(params, x, cfg, positions=positions,
                            causal=not cfg.is_encoder, window=window)
    q, k, v = _qkv(params, x, cfg)
    if not cfg.is_encoder:
        k = apply_rope(k, positions, cfg.rope_theta)
    if capacity >= s:
        pad = capacity - s
        kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    else:  # keep the most recent `capacity` (ring layout, slot=s%cap aligned)
        kc = k[:, s - capacity:]
        vc = v[:, s - capacity:]
        # rotate so that entry (t mod cap) sits at index t mod cap
        shift = jax.lax.rem(jnp.int32(s - capacity), jnp.int32(capacity))
        kc = jnp.roll(kc, shift, axis=1)
        vc = jnp.roll(vc, shift, axis=1)
    cache = KVCache(k=kc.astype(cfg_dtype(cfg)), v=vc.astype(cfg_dtype(cfg)),
                    length=jnp.asarray(s, jnp.int32))
    return out, cache


def cfg_dtype(cfg):
    import jax.numpy as jnp
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[
        cfg.compute_dtype]
