"""RG-LRU recurrence (Griffin / RecurrentGemma) [arXiv:2402.19427].

    r_t = sigmoid(W_a x_t + b_a)                 (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)                 (input gate)
    a_t = exp(c * r_t * log(sigmoid(Lambda)))    (= a^{c r_t}, a in (0,1))
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Train/prefill uses ``jax.lax.associative_scan`` over (log_a, b) pairs —
O(log S) depth, the sub-quadratic path for the long_500k cell. Decode is a
single fused step on a carried state (O(1) memory).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _gates(params, x):
    r = jax.nn.sigmoid(x.astype(jnp.float32) @
                       params["w_a"].astype(jnp.float32) + params["b_a"])
    i = jax.nn.sigmoid(x.astype(jnp.float32) @
                       params["w_x"].astype(jnp.float32) + params["b_x"])
    return r, i


def _log_a(params, r, c: float):
    # log a_t = c * r_t * log sigmoid(Lambda)   (<= 0)
    log_lam = jax.nn.log_sigmoid(params["lam"].astype(jnp.float32))
    return c * r * log_lam[None, None, :]


def rglru_scan(params, x: jnp.ndarray, c: float = 8.0,
               init_h: jnp.ndarray | None = None
               ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D_rnn) -> (h (B, S, D_rnn), final h (B, D_rnn))."""
    b, s, d = x.shape
    r, i = _gates(params, x)
    log_a = _log_a(params, r, c)                           # (B,S,D)
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (
        i * x.astype(jnp.float32))

    # h_t = a_t h_{t-1} + g_t: associative over pairs (a, g):
    #   (a2, g2) o (a1, g1) = (a1*a2, a2*g1 + g2)
    def combine(lft, rgt):
        a_l, g_l = lft
        a_r, g_r = rgt
        return a_l * a_r, a_r * g_l + g_r

    if init_h is not None:
        gated = gated.at[:, 0].add(a[:, 0] * init_h.astype(jnp.float32))
    _, h = jax.lax.associative_scan(combine, (a, gated), axis=1)
    return h.astype(x.dtype), h[:, -1].astype(jnp.float32)


def rglru_step(params, x_t: jnp.ndarray, h_prev: jnp.ndarray, c: float = 8.0
               ) -> jnp.ndarray:
    """One decode step: x_t (B, D_rnn), h_prev (B, D_rnn) -> h_t."""
    r, i = _gates(params, x_t[:, None, :])
    log_a = _log_a(params, r, c)[:, 0]
    a = jnp.exp(log_a)
    g = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (
        i[:, 0] * x_t.astype(jnp.float32))
    return a * h_prev.astype(jnp.float32) + g


def rglru_reference(params, x: jnp.ndarray, c: float = 8.0) -> jnp.ndarray:
    """Sequential oracle."""
    b, s, d = x.shape
    h = jnp.zeros((b, d), jnp.float32)
    out = []
    for t in range(s):
        h = rglru_step(params, x[:, t], h, c)
        out.append(h)
    return jnp.stack(out, axis=1).astype(x.dtype)


def temporal_conv(params, x: jnp.ndarray, width: int,
                  carry: jnp.ndarray | None = None
                  ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Depthwise causal conv1d; carry (B, width-1, D) for decode chaining."""
    b, s, d = x.shape
    w = params["conv_w"].astype(jnp.float32)               # (width, D)
    if carry is None:
        carry = jnp.zeros((b, width - 1, d), x.dtype)
    xx = jnp.concatenate([carry.astype(x.dtype), x], axis=1)
    out = jnp.zeros((b, s, d), jnp.float32)
    for k in range(width):
        out = out + xx[:, k:k + s].astype(jnp.float32) * w[k]
    new_carry = xx[:, -(width - 1):] if width > 1 else \
        jnp.zeros((b, 0, d), x.dtype)
    return out.astype(x.dtype), new_carry
