"""Model assembly for every assigned architecture family.

One parameter pytree + three entry points:

  * ``train_loss(params, cfg, batch)``   — next-token (or masked-frame) loss
  * ``prefill(params, cfg, batch, cap)`` — full forward + cache population
  * ``decode_step(params, cfg, tokens, cache)`` — one token, O(1)/O(window)

Families: dense (llama/qwen-style GQA+SwiGLU), moe (Mixtral/Grok top-2),
ssm (Mamba-2/SSD), audio (encoder-only, stub frontend), vlm (LM backbone +
stub patch embeddings), hybrid (RecurrentGemma RG-LRU + local attention).

Homogeneous stacks scan over layers (keeps HLO small: one block compiled
once — essential for 512-way SPMD compiles); the hybrid family python-loops
over its 26 heterogeneous layers.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import mamba2, moe, rglru
from repro.models.config import ModelConfig
from repro.models.layers import (gelu_mlp, normal_init, ones_init, rms_norm,
                                 softmax_xent, swiglu, zeros_init)
from repro.models.pspec_utils import constrain


def _dtype(cfg: ModelConfig):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.param_dtype]


def _cdtype(cfg: ModelConfig):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[
        cfg.compute_dtype]


# ===========================================================================
# Parameter construction
# ===========================================================================

def _attn_params(cfg, d):
    hd = cfg.resolved_head_dim
    p = {
        "attn_norm": ("ones", (d,)),
        "wq": ("normal", (d, cfg.n_heads * hd)),
        "wk": ("normal", (d, cfg.n_kv_heads * hd)),
        "wv": ("normal", (d, cfg.n_kv_heads * hd)),
        "wo": ("normal", (cfg.n_heads * hd, d)),
    }
    if cfg.qk_norm:
        p["q_norm"] = ("ones", (hd,))
        p["k_norm"] = ("ones", (hd,))
    return p


def _mlp_params(cfg, d):
    if cfg.family == "audio":
        return {"mlp_norm": ("ones", (d,)),
                "w_in": ("normal", (d, cfg.d_ff)),
                "w_out": ("normal", (cfg.d_ff, d))}
    return {"mlp_norm": ("ones", (d,)),
            "w_gate": ("normal", (d, cfg.d_ff)),
            "w_up": ("normal", (d, cfg.d_ff)),
            "w_down": ("normal", (cfg.d_ff, d))}


def _moe_params(cfg, d):
    e, f = cfg.n_experts, cfg.d_ff
    return {"mlp_norm": ("ones", (d,)),
            "w_router": ("normal", (d, e)),
            "w_gate": ("normal", (e, d, f)),
            "w_up": ("normal", (e, d, f)),
            "w_down": ("normal", (e, f, d))}


def _ssm_params(cfg, d):
    d_in, nh, p, n = mamba2.ssm_dims(cfg)
    conv_ch = d_in + 2 * n
    return {
        "norm": ("ones", (d,)),
        "in_proj": ("normal", (d, 2 * d_in + 2 * n + nh)),
        "conv_w": ("normal", (cfg.ssm_conv_width, conv_ch)),
        "dt_bias": ("zeros", (nh,)),
        "a_log": ("zeros", (nh,)),
        "skip_d": ("ones", (nh,)),
        "out_norm": ("ones", (d_in,)),
        "out_proj": ("normal", (d_in, d)),
    }


def _rec_params(cfg, d):
    d_rnn = cfg.n_heads * cfg.resolved_head_dim
    return {
        "attn_norm": ("ones", (d,)),          # pre-norm of the mixing block
        "gate_proj": ("normal", (d, d_rnn)),
        "rnn_proj": ("normal", (d, d_rnn)),
        "conv_w": ("normal", (cfg.ssm_conv_width, d_rnn)),
        "w_a": ("normal", (d_rnn, d_rnn)),
        "b_a": ("zeros", (d_rnn,)),
        "w_x": ("normal", (d_rnn, d_rnn)),
        "b_x": ("zeros", (d_rnn,)),
        "lam": ("ones", (d_rnn,)),
        "out_proj": ("normal", (d_rnn, d)),
    }


def block_param_spec(cfg: ModelConfig, kind: str) -> dict:
    d = cfg.d_model
    if kind == "ssm":
        return _ssm_params(cfg, d)
    if kind == "rec":
        return {**_rec_params(cfg, d), **_mlp_params(cfg, d)}
    if kind == "moe":
        return {**_attn_params(cfg, d), **_moe_params(cfg, d)}
    # dense / audio / vlm / hybrid-attn
    return {**_attn_params(cfg, d), **_mlp_params(cfg, d)}


def iter_layer_params(params: dict, cfg: ModelConfig):
    """Yield one param dict per layer regardless of storage layout
    (unrolled list / hybrid group-stack). Used by the decode/prefill paths,
    which python-loop heterogeneous stacks."""
    if "layers" in params:
        yield from params["layers"]
        return
    if "groups" in params:
        plen = len(cfg.block_pattern)
        n_groups, _ = hybrid_grouping(cfg)
        for g in range(n_groups):
            for j in range(plen):
                yield jax.tree.map(lambda a, g=g: a[g], params["groups"][j])
        yield from params["tail"]
        return
    for i in range(cfg.n_layers):
        yield jax.tree.map(lambda a, i=i: a[i], params["blocks"])


def layer_kinds(cfg: ModelConfig) -> list[str]:
    if cfg.family == "ssm":
        return ["ssm"] * cfg.n_layers
    if cfg.family == "hybrid":
        pat = cfg.block_pattern or ("rec", "rec", "attn")
        # normalize: pattern "attn" entries are plain dense blocks
        return [("dense" if pat[i % len(pat)] == "attn" else
                 pat[i % len(pat)]) for i in range(cfg.n_layers)]
    if cfg.family == "moe":
        return ["moe"] * cfg.n_layers
    return ["dense"] * cfg.n_layers


def hybrid_grouping(cfg: ModelConfig) -> tuple[int, int]:
    """(n_groups, n_tail) for scanning a heterogeneous pattern stack."""
    plen = len(cfg.block_pattern) or 1
    n_groups = cfg.n_layers // plen
    return n_groups, cfg.n_layers - n_groups * plen


def param_spec(cfg: ModelConfig) -> dict:
    """Nested dict of (init_kind, shape) — consumed by init and eval_shape."""
    d = cfg.d_model
    spec: dict[str, Any] = {"final_norm": ("ones", (d,))}
    vp = cfg.padded_vocab
    if cfg.family == "audio":
        spec["frontend_proj"] = ("normal", (cfg.frontend_dim, d))
        spec["head"] = ("normal", (d, vp))
    else:
        spec["embed"] = ("normal", (vp, d))
        if not cfg.tie_embeddings:
            spec["lm_head"] = ("normal", (d, vp))
    kinds = layer_kinds(cfg)
    if cfg.scan_layers and len(set(kinds)) == 1:
        # homogeneous: stack layer dim onto every leaf
        blk = block_param_spec(cfg, kinds[0])
        spec["blocks"] = {k: (ik, (cfg.n_layers, *shape))
                          for k, (ik, shape) in blk.items()}
    elif cfg.scan_layers and cfg.family == "hybrid" and cfg.block_pattern:
        # heterogeneous pattern: scan over whole (rec, rec, attn) GROUPS —
        # one group compiled once instead of 26 unrolled layers (a 512-way
        # SPMD hybrid train cell compiles in ~1 min vs 30+ unrolled).
        n_groups, n_tail = hybrid_grouping(cfg)
        plen = len(cfg.block_pattern)
        spec["groups"] = [
            {k: (ik, (n_groups, *shape))
             for k, (ik, shape) in block_param_spec(cfg, kinds[j]).items()}
            for j in range(plen)]
        spec["tail"] = [block_param_spec(cfg, kinds[n_groups * plen + j])
                        for j in range(n_tail)]
    else:
        spec["layers"] = [block_param_spec(cfg, k) for k in kinds]
    return spec


_INITS = {"normal": normal_init, "zeros": zeros_init, "ones": ones_init}


def init_params(cfg: ModelConfig, key) -> dict:
    dt = _dtype(cfg)

    def build(spec, path=()):
        if isinstance(spec, dict):
            return {k: build(v, path + (k,)) for k, v in spec.items()}
        if isinstance(spec, list):
            return [build(v, path + (str(i),)) for i, v in enumerate(spec)]
        ik, shape = spec
        sub = jax.random.fold_in(key, hash(path) % (2 ** 31))
        scale = 0.02
        if path[-1] in ("lam",):
            # Griffin init: a ~ uniform in [0.9, 0.999] -> lam = logit(a)
            return jnp.full(shape, 4.0, dt)
        return _INITS[ik](sub, shape, dt, scale)

    return build(param_spec(cfg))


def param_shapes(cfg: ModelConfig) -> Any:
    """ShapeDtypeStruct pytree (no allocation) — dry-run path."""
    dt = _dtype(cfg)

    def build(spec):
        if isinstance(spec, dict):
            return {k: build(v) for k, v in spec.items()}
        if isinstance(spec, list):
            return [build(v) for v in spec]
        _, shape = spec
        return jax.ShapeDtypeStruct(shape, dt)

    return build(param_spec(cfg))


# ===========================================================================
# Block forwards (train/prefill path)
# ===========================================================================

def _attn_block(p, x, cfg, positions, window):
    h = rms_norm(x, p["attn_norm"])
    h = attn.attention_forward(p, h, cfg, positions=positions,
                               causal=not cfg.is_encoder, window=window)
    x = x + h
    h = rms_norm(x, p["mlp_norm"])
    if cfg.family == "audio":
        h = gelu_mlp(h, p["w_in"], p["w_out"])
    else:
        h = swiglu(h, p["w_gate"], p["w_up"], p["w_down"])
    return x + h


def _moe_block(p, x, cfg, positions, window):
    h = rms_norm(x, p["attn_norm"])
    h = attn.attention_forward(p, h, cfg, positions=positions,
                               causal=True, window=window)
    x = x + h
    h = rms_norm(x, p["mlp_norm"])
    h = moe.moe_forward(p, h, cfg)
    return x + h


def _ssm_mix(p, xz, cfg, conv_carry=None, init_state=None):
    """Core mamba2 mixing on pre-normed input. Returns (y, carry, state)."""
    b, s, d = xz.shape
    d_in, nh, hp, n = mamba2.ssm_dims(cfg)
    zxbcdt = xz @ p["in_proj"].astype(xz.dtype)
    z, xin, bmat, cmat, dt = jnp.split(
        zxbcdt, [d_in, 2 * d_in, 2 * d_in + n, 2 * d_in + 2 * n], axis=-1)
    conv_in = jnp.concatenate([xin, bmat, cmat], axis=-1)
    conv_out, new_carry = rglru.temporal_conv(
        {"conv_w": p["conv_w"]}, conv_in, cfg.ssm_conv_width, conv_carry)
    conv_out = jax.nn.silu(conv_out.astype(jnp.float32)).astype(xz.dtype)
    xin, bmat, cmat = jnp.split(conv_out, [d_in, d_in + n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         p["dt_bias"].astype(jnp.float32))
    xh = xin.reshape(b, s, nh, hp)
    y, state = mamba2.ssd_chunked(xh, dt, p["a_log"], bmat, cmat,
                                  cfg.ssm_chunk, init_state)
    y = y + xh.astype(jnp.float32) * p["skip_d"].astype(jnp.float32)[
        None, None, :, None]
    y = y.reshape(b, s, d_in).astype(xz.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(xz.dtype)
    y = rms_norm(y, p["out_norm"])
    return y @ p["out_proj"].astype(xz.dtype), new_carry, state


def _ssm_block(p, x, cfg):
    h = rms_norm(x, p["norm"])
    y, _, _ = _ssm_mix(p, h, cfg)
    return x + y


def _rec_mix(p, h, cfg, conv_carry=None, init_h=None):
    """Griffin recurrent mixing on pre-normed input."""
    gate = jax.nn.gelu((h @ p["gate_proj"].astype(h.dtype)
                        ).astype(jnp.float32)).astype(h.dtype)
    u = h @ p["rnn_proj"].astype(h.dtype)
    u, new_carry = rglru.temporal_conv({"conv_w": p["conv_w"]}, u,
                                       cfg.ssm_conv_width, conv_carry)
    lru_p = {k: p[k] for k in ("w_a", "b_a", "w_x", "b_x", "lam")}
    u, h_last = rglru.rglru_scan(lru_p, u, cfg.rglru_c, init_h)
    y = (gate * u) @ p["out_proj"].astype(h.dtype)
    return y, new_carry, h_last


def _rec_block(p, x, cfg):
    h = rms_norm(x, p["attn_norm"])
    y, _, _ = _rec_mix(p, h, cfg)
    x = x + y
    h = rms_norm(x, p["mlp_norm"])
    return x + swiglu(h, p["w_gate"], p["w_up"], p["w_down"])


def _block_fn(cfg, kind):
    if kind == "ssm":
        return lambda p, x, pos: _ssm_block(p, x, cfg)
    if kind == "rec":
        return lambda p, x, pos: _rec_block(p, x, cfg)
    if kind == "moe":
        return lambda p, x, pos: _moe_block(p, x, cfg, pos,
                                            cfg.sliding_window)
    window = cfg.local_window if (cfg.family == "hybrid" and kind == "dense"
                                  ) else cfg.sliding_window
    return lambda p, x, pos: _attn_block(p, x, cfg, pos, window)


def _maybe_remat(fn, cfg):
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return fn


# ===========================================================================
# Full forward
# ===========================================================================

def embed_inputs(params, cfg, batch) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (x (B, S, D), positions (B, S))."""
    cdt = _cdtype(cfg)
    if cfg.family == "audio":
        x = batch["frames"].astype(cdt) @ params["frontend_proj"].astype(cdt)
    elif cfg.family == "vlm":
        tok = params["embed"].astype(cdt)[batch["tokens"]]
        vis = batch["vision"].astype(cdt)          # stub patch embeddings
        x = jnp.concatenate([vis, tok], axis=1)
    else:
        x = params["embed"].astype(cdt)[batch["tokens"]]
    b, s = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    return x, positions


def forward(params, cfg: ModelConfig, batch) -> jnp.ndarray:
    """Logits (B, S, V)."""
    x, positions = embed_inputs(params, cfg, batch)
    # seq_parallel (Megatron-SP): the residual stream between blocks is
    # sequence-sharded over 'model', so the per-layer saved carries of the
    # backward scan shrink by the TP degree; XLA inserts the all-gather /
    # reduce-scatter pair at the block boundary.
    seq_ax = "model" if cfg.seq_parallel else None
    x = constrain(x, "dp", seq_ax, None)
    kinds = layer_kinds(cfg)
    if "blocks" in params:                            # homogeneous scan
        fn = _maybe_remat(_block_fn(cfg, kinds[0]), cfg)

        def body(x, lp):
            return constrain(fn(lp, x, positions), "dp", seq_ax, None), None

        x, _ = jax.lax.scan(body, x, params["blocks"])
    elif "groups" in params:                          # hybrid group scan
        plen = len(cfg.block_pattern)
        fns = [_block_fn(cfg, kinds[j]) for j in range(plen)]

        def group_fn(gps, x, positions):
            for fn, gp in zip(fns, gps):
                x = constrain(fn(gp, x, positions), "dp", seq_ax, None)
            return x

        gfn = _maybe_remat(group_fn, cfg)

        def gbody(x, gps):
            return gfn(gps, x, positions), None

        x, _ = jax.lax.scan(gbody, x, tuple(params["groups"]))
        n_groups, n_tail = hybrid_grouping(cfg)
        for j, lp in enumerate(params["tail"]):
            kind = kinds[n_groups * plen + j]
            x = _maybe_remat(_block_fn(cfg, kind), cfg)(lp, x, positions)
            x = constrain(x, "dp", seq_ax, None)
    else:
        for lp, kind in zip(params["layers"], kinds):
            x = _maybe_remat(_block_fn(cfg, kind), cfg)(lp, x, positions)
            x = constrain(x, "dp", seq_ax, None)
    x = rms_norm(x, params["final_norm"])
    return lm_logits(params, cfg, x)


def lm_logits(params, cfg: ModelConfig, x) -> jnp.ndarray:
    """(…, D) -> (…, padded_vocab) fp32 logits, pad columns at -inf."""
    if cfg.family == "audio":
        head = params["head"]
    elif cfg.tie_embeddings:
        head = params["embed"].T
    else:
        head = params["lm_head"]
    logits = (x @ head.astype(x.dtype)).astype(jnp.float32)
    if cfg.padded_vocab != cfg.vocab_size:
        pad = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                       logits.ndim - 1) >= cfg.vocab_size
        logits = jnp.where(pad, -1e30, logits)
    spec = ["dp"] + [None] * (logits.ndim - 2) + ["model"]
    return constrain(logits, *spec)


def train_loss(params, cfg: ModelConfig, batch) -> jnp.ndarray:
    logits = forward(params, cfg, batch)
    if cfg.family == "audio":
        return softmax_xent(logits, batch["labels"])
    if cfg.family == "vlm":
        n_vis = batch["vision"].shape[1]
        text_logits = logits[:, n_vis:]
        return softmax_xent(text_logits[:, :-1], batch["tokens"][:, 1:])
    return softmax_xent(logits[:, :-1], batch["tokens"][:, 1:])
