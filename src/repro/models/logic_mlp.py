"""FFCL-substituted FFN: the paper's technique as a first-class LM feature.

With ``cfg.logic_mlp = True`` a transformer block's FFN becomes a
*binarized* MLP (NullaNet-compatible): the block input is binarized at a
sign boundary, the hidden activation is binary, and only the output
projection is numeric:

    xb = sign01(x);  h = sign01((2xb-1) @ w_in + b_in);  y = (2h-1) @ w_out

Training uses straight-through estimators; after training,
``ffn_to_program`` runs the NullaNet flow (ISF from calibration data ->
espresso -> gate factoring -> synth -> sub-kernel scheduling) per layer, and
``logic_ffn_apply`` executes the xb -> h map as an FFCL *program* — bitwise
ops only, no w_in matmul, no weight access (paper §7.1's selling point) —
via the jnp reference semantics (jit-able; the Pallas kernel runs the same
program on the packed words when called outside jit).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.nullanet import layer_to_graph
from repro.core.scheduler import LogicProgram, compile_graph
from repro.kernels.logic_dsp.ops import program_arrays
from repro.kernels.logic_dsp.ref import logic_forward_ref


def _ste01(y: jnp.ndarray) -> jnp.ndarray:
    soft = 0.5 * (jnp.tanh(y) + 1.0)
    hard = (y >= 0).astype(jnp.float32)
    return (soft + jax.lax.stop_gradient(hard - soft)).astype(y.dtype)


def binary_ffn(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    """STE-binarized FFN (training / reference inference path)."""
    xb = _ste01(x.astype(jnp.float32))
    h = _ste01((2.0 * xb - 1.0) @ p["w_in"].astype(jnp.float32)
               + p["b_in"].astype(jnp.float32))
    return ((2.0 * h - 1.0) @ p["w_out"].astype(jnp.float32)).astype(x.dtype)


def ffn_to_program(p: dict, calib_bits: np.ndarray, n_unit: int = 64,
                   mode: str = "isf", name: str = "ffn"
                   ) -> LogicProgram:
    """NullaNet conversion of the xb -> h map of one FFN layer."""
    w = np.asarray(p["w_in"], np.float64)
    b = np.asarray(p["b_in"], np.float64)
    graph = layer_to_graph(calib_bits.astype(np.uint8), w, b, mode=mode,
                           name=name)
    return compile_graph(graph, n_unit=n_unit, alloc="liveness")


def logic_ffn_apply(prog: LogicProgram, p: dict, x: jnp.ndarray
                    ) -> jnp.ndarray:
    """Inference through the compiled FFCL program (bitwise ops only).

    x (B, S, D) -> y (B, S, D). Bit packing runs along the flattened
    (B*S) sample axis — the paper's SIMD lanes.
    """
    from repro.kernels.logic_dsp.ops import pack_bits_jnp, unpack_bits_jnp
    bsh = x.shape[:-1]
    d = x.shape[-1]
    xb = (x.astype(jnp.float32) >= 0).reshape(-1, d)          # (N, D) bits
    words = pack_bits_jnp(xb)
    arrs = program_arrays(prog)
    out_words = logic_forward_ref(
        arrs["src_a"], arrs["src_b"], arrs["dst"], arrs["opcode"],
        words, arrs["output_addrs"], arrs["n_addr"],
        step_branch=arrs["step_branch"])
    h = unpack_bits_jnp(out_words, xb.shape[0]).astype(jnp.float32)
    y = (2.0 * h - 1.0) @ p["w_out"].astype(jnp.float32)
    return y.reshape(*bsh, -1).astype(x.dtype)
