"""FFCL-substituted FFN: the paper's technique as a first-class LM feature.

With ``cfg.logic_mlp = True`` a transformer block's FFN becomes a
*binarized* MLP (NullaNet-compatible): the block input is binarized at a
sign boundary, the hidden activation is binary, and only the output
projection is numeric:

    xb = sign01(x);  h = sign01((2xb-1) @ w_in + b_in);  y = (2h-1) @ w_out

Training uses straight-through estimators; after training,
``ffn_to_program`` converts the xb -> h map per layer through THE flow
conversion path (flow/convert.py: ISF from calibration data -> espresso ->
gate factoring -> synth -> sub-kernel scheduling — one code path shared
with the end-to-end classifier), and ``logic_ffn_apply`` executes it as an
FFCL *program* — bitwise ops only, no w_in matmul, no weight access (paper
§7.1's selling point) — via the shared ``forward_words`` core (jit-able;
the Pallas kernel runs the same program on the packed words when called
outside jit).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.scheduler import LogicProgram
from repro.core.spec import CompileSpec, resolve_spec, _UNSET
from repro.flow.convert import layer_to_program
from repro.kernels.logic_dsp.ops import (logic_forward, pack_bits_jnp,
                                         unpack_bits_jnp)


def _ste01(y: jnp.ndarray) -> jnp.ndarray:
    soft = 0.5 * (jnp.tanh(y) + 1.0)
    hard = (y >= 0).astype(jnp.float32)
    return (soft + jax.lax.stop_gradient(hard - soft)).astype(y.dtype)


def binary_ffn(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    """STE-binarized FFN (training / reference inference path)."""
    xb = _ste01(x.astype(jnp.float32))
    h = _ste01((2.0 * xb - 1.0) @ p["w_in"].astype(jnp.float32)
               + p["b_in"].astype(jnp.float32))
    return ((2.0 * h - 1.0) @ p["w_out"].astype(jnp.float32)).astype(x.dtype)


def ffn_to_program(p: dict, calib_bits: np.ndarray,
                   spec: CompileSpec | int | None = None,
                   mode: str = "isf", name: str = "ffn", *,
                   n_unit=_UNSET, optimize=_UNSET) -> LogicProgram:
    """NullaNet conversion of the xb -> h map of one FFN layer.

    Thin wrapper over :func:`repro.flow.convert.layer_to_program` — the
    single conversion code path of the repo.  ``spec`` is the one
    declarative compilation target (core/spec.py); the loose
    ``n_unit``/``optimize`` kwargs (or an int third positional, the old
    ``n_unit``) are the deprecated pre-spec convention.
    """
    spec = resolve_spec(spec, caller="ffn_to_program", n_unit=n_unit,
                        optimize=optimize)
    return layer_to_program(p["w_in"], p["b_in"],
                            np.asarray(calib_bits, dtype=np.uint8),
                            spec, mode=mode, name=name)


def logic_ffn_apply(prog: LogicProgram, p: dict, x: jnp.ndarray
                    ) -> jnp.ndarray:
    """Inference through the compiled FFCL program (bitwise ops only).

    x (B, S, D) -> y (B, S, D). Bit packing runs along the flattened
    (B*S) sample axis — the paper's SIMD lanes. Executes through the same
    ``forward_words`` core as the end-to-end flow and the serving engine
    (jnp reference semantics, so the call stays jit-able inside a
    transformer forward).
    """
    bsh = x.shape[:-1]
    d = x.shape[-1]
    xb = (x.astype(jnp.float32) >= 0).reshape(-1, d)          # (N, D) bits
    words = pack_bits_jnp(xb)
    out_words = logic_forward(prog, words, use_ref=True)
    h = unpack_bits_jnp(out_words, xb.shape[0]).astype(jnp.float32)
    y = (2.0 * h - 1.0) @ p["w_out"].astype(jnp.float32)
    return y.reshape(*bsh, -1).astype(x.dtype)
