"""Unified model configuration covering all assigned architecture families."""
from __future__ import annotations

from dataclasses import dataclass, replace


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | audio | vlm | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    qk_norm: bool = False
    sliding_window: int = 0        # 0 = full attention (SWA if > 0)
    rope_theta: float = 1e6
    tie_embeddings: bool = False
    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    # SSM (mamba2 / SSD)
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 64
    ssm_chunk: int = 64
    ssm_conv_width: int = 4
    ssm_expand: int = 2
    # hybrid (recurrentgemma / Griffin)
    block_pattern: tuple = ()      # e.g. ("rec", "rec", "attn") repeated
    local_window: int = 0          # local-attn window for hybrid blocks
    rglru_c: float = 8.0
    # encoder-only / modality frontends (STUBS per assignment spec)
    is_encoder: bool = False
    frontend_dim: int = 0          # audio: precomputed frame-feature dim
    vision_tokens: int = 0         # vlm: precomputed patch embeddings count
    # numerics / memory
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    moment_dtype: str = "float32"
    remat: str = "full"            # none | full | dots
    scan_layers: bool = True
    # parallelism knobs (read by sharding.py / launch)
    seq_parallel: bool = False     # shard activation seq dim over 'model'
    tensor_parallel: bool = True   # False: pure-DP (batch over 'model' too;
                                   # params replicated on 'model') — right
                                   # call for sub-1B models where TP
                                   # collectives swamp compute (§Perf)
    # paper technique integration
    logic_mlp: bool = False        # FFCL-substituted FFN (inference only)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 128 (Megatron-style): lane-aligned
        AND divisible by the 16-wide 'model' axis — an un-shardable vocab
        (e.g. minicpm's 122753) replicates the fp32 logits on every device
        (+30 GiB/dev at train_4k, §Perf). Pad columns are masked to -inf."""
        return -(-self.vocab_size // 128) * 128

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    def with_(self, **kw) -> "ModelConfig":
        return replace(self, **kw)

    # ---- parameter counting (for roofline MODEL_FLOPS = 6*N*D) ----
    def param_count(self, active_only: bool = False) -> int:
        d, hd = self.d_model, self.resolved_head_dim
        if self.family == "ssm":
            d_in = self.ssm_expand * d
            nh = self.ssm_heads or (d_in // self.ssm_head_dim)
            per = (d * (2 * d_in + 2 * self.ssm_state + nh)   # in_proj
                   + self.ssm_conv_width * (d_in + 2 * self.ssm_state)
                   + nh + nh                                  # A_log, D
                   + d_in                                      # norm
                   + d_in * d)                                 # out_proj
            blocks = self.n_layers * (per + d)
            return blocks + self.vocab_size * d * (1 if self.tie_embeddings
                                                   else 2) + d
        attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) \
            + (self.n_heads * hd) * d
        if self.family == "moe":
            e = (self.experts_per_token if active_only else self.n_experts)
            mlp = e * 3 * d * self.d_ff + d * self.n_experts  # + router
        elif self.family == "hybrid":
            mlp = 3 * d * self.d_ff
        else:
            mlp = 3 * d * self.d_ff
        if self.family == "audio":                            # enc: GeLU MLP
            mlp = 2 * d * self.d_ff
        per_layer = attn + mlp + 2 * d
        if self.family == "hybrid":
            # recurrent blocks replace attention with RG-LRU machinery
            n_attn = sum(1 for i in range(self.n_layers)
                         if self.block_pattern[i % len(self.block_pattern)]
                         == "attn")
            n_rec = self.n_layers - n_attn
            d_rnn = self.n_heads * hd
            rec = (2 * d * d_rnn + d_rnn * d            # in/out proj (gated)
                   + self.ssm_conv_width * d_rnn        # temporal conv
                   + 2 * d_rnn + 2 * d_rnn)             # gates a/x
            per_attn = attn + mlp + 2 * d
            per_rec = rec + mlp + 2 * d
            blocks = n_attn * per_attn + n_rec * per_rec
        else:
            blocks = self.n_layers * per_layer
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        if self.family == "audio":
            emb = self.frontend_dim * d + d * self.vocab_size
        return blocks + emb + d
