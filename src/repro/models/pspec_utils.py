"""Activation sharding constraints, mesh-context aware but test-friendly.

Model code calls ``constrain(x, 'dp', None, None)`` with *logical* axes:
  'dp'    -> shard over ('pod','data') (whichever exist in the mesh)
  'model' -> shard over 'model'
  None    -> replicated dim

The launcher/trainer activates a mesh via ``activation_sharding(mesh)``;
without it (unit tests on one device) constrain() is a no-op. Dims that
don't divide the axis size degrade to replication (e.g. batch=1 long_500k).
"""
from __future__ import annotations

import contextlib
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_ACTIVE: Optional[Mesh] = None
_DP_AXES: tuple = ("pod", "data")


@contextlib.contextmanager
def activation_sharding(mesh: Mesh | None, dp_axes: tuple = ("pod", "data")):
    """dp_axes: which mesh axes carry the batch. Pure-DP configs
    (cfg.tensor_parallel=False) pass ('pod','data','model')."""
    global _ACTIVE, _DP_AXES
    prev, _ACTIVE = _ACTIVE, mesh
    prev_dp, _DP_AXES = _DP_AXES, dp_axes
    try:
        yield
    finally:
        _ACTIVE = prev
        _DP_AXES = prev_dp


def active_mesh() -> Mesh | None:
    return _ACTIVE


def dp_axes() -> tuple:
    return _DP_AXES


def _resolve(axis, dim: int, mesh: Mesh):
    if axis is None:
        return None
    if axis == "dp":
        names = tuple(n for n in _DP_AXES if n in mesh.axis_names)
        # biggest divisible contiguous subset (mirrors sharding.batch_pspec)
        best, best_total = None, 1
        for i in range(len(names)):
            for j in range(i + 1, len(names) + 1):
                total = 1
                for n in names[i:j]:
                    total *= mesh.shape[n]
                if dim % total == 0 and total > best_total:
                    best, best_total = names[i:j], total
        return best
    if axis in mesh.axis_names and dim % mesh.shape[axis] == 0:
        return axis
    return None


def constrain(x, *axes):
    mesh = _ACTIVE
    if mesh is None:
        return x
    if len(axes) != x.ndim:
        raise ValueError(f"spec rank {len(axes)} != tensor rank {x.ndim}")
    resolved, used = [], set()
    for a, d in zip(axes, x.shape):
        r = _resolve(a, d, mesh)
        names = (r,) if isinstance(r, str) else (r or ())
        if any(n in used for n in names):   # pure-DP: 'dp' may own 'model'
            r = None
        used.update(names)
        resolved.append(r)
    spec = P(*resolved)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
