"""Top-k MoE (Mixtral/Grok style) with sort-based capacity dispatch.

Two dispatch paths, numerically identical for tokens within capacity:

  * ``dense``  — every token through every expert, gate-weighted combine.
    O(E/k) FLOP overhead; used as the correctness oracle in tests.
  * ``sorted`` — the production path: flatten tokens, sort the (token,
    expert) assignment pairs by expert id, gather into per-expert buffers
    of ``cap = ceil(k*T/E * capacity_factor)`` rows, run a batched
    (E, cap, d) x (E, d, ff) einsum, and scatter-add back with gate weights.
    FLOPs = capacity_factor x the active-expert cost (vs E/k for dense) —
    this is what keeps the MoE roofline's MODEL_FLOPS/HLO_FLOPs ratio
    honest. Overflow tokens are dropped (standard capacity semantics).

Expert weights are stacked (E, d, ff): EP shards E over 'data' (FSDP axis)
and TP shards ff over 'model' — see sharding.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.pspec_utils import constrain


def router_probs(params, x, n_experts: int):
    """x (T, D) -> (gates (T, k), idx (T, k)) with renormalized top-k."""
    logits = (x.astype(jnp.float32) @ params["w_router"].astype(jnp.float32))
    return logits


def _top_k_gates(logits: jnp.ndarray, k: int):
    gates, idx = jax.lax.top_k(logits, k)                 # (T, k)
    gates = jax.nn.softmax(gates, axis=-1)                # renormalize top-k
    return gates, idx


def moe_dense(params, x, cfg):
    """Oracle: (B, S, D) -> (B, S, D), all experts computed."""
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)
    logits = router_probs(params, xf, cfg.n_experts)
    gates, idx = _top_k_gates(logits, cfg.experts_per_token)
    # (T, E) combined gate weights
    comb = jnp.zeros((t, cfg.n_experts), jnp.float32)
    comb = comb.at[jnp.arange(t)[:, None], idx].add(gates)
    g = jnp.einsum("td,edf->tef", xf, params["w_gate"].astype(x.dtype))
    u = jnp.einsum("td,edf->tef", xf, params["w_up"].astype(x.dtype))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    y = jnp.einsum("tef,efd->ted", h, params["w_down"].astype(x.dtype))
    out = jnp.einsum("ted,te->td", y.astype(jnp.float32), comb)
    return out.astype(x.dtype).reshape(b, s, d)


def moe_sorted(params, x, cfg):
    """Production path: GROUPED sort-based dispatch with capacity dropping.

    Routing, sorting, and the dispatch/combine scatters all happen within a
    *group* (one batch row), vmapped over the batch dim. This keeps every
    data-dependent op batched along an axis that is sharded over
    ('pod','data') — a global sort/scatter would force XLA SPMD to
    replicate the (E, cap, D) expert buffers (measured: +100 GiB/device on
    grok-1 train_4k). Capacity is per group (GShard semantics):
    cap = ceil(k*S/E) * capacity_factor.
    """
    b, s, d = x.shape
    k = cfg.experts_per_token
    e = cfg.n_experts
    cap = max(1, int(-(-k * s // e) * cfg.capacity_factor))
    logits = router_probs(params, x.reshape(b * s, d), e).reshape(b, s, e)
    gates, idx = _top_k_gates(logits, k)                  # (B, S, k)

    w_gate = params["w_gate"].astype(x.dtype)
    w_up = params["w_up"].astype(x.dtype)
    w_down = params["w_down"].astype(x.dtype)

    def plan(idxg):
        """One group's routing plan — int32 index arrays only.

        Heavy data movement is GATHER-based: scatters touch only (S*k,)
        int vectors (an XLA row-scatter of (rows, D) data lowers badly —
        it materialized 2.5 GiB u32 index cubes per layer on CPU and is a
        serialization hazard on TPU too).
        Returns:
          inv     (E*cap,) token id feeding each expert slot (S = none)
          a_slot  (S, k)   buffer slot of each assignment (E*cap = dropped)
        """
        fe = idxg.reshape(-1)                             # (S*k,)
        ft = jnp.repeat(jnp.arange(s), k)
        order = jnp.argsort(fe, stable=True)
        se, st_ = fe[order], ft[order]
        pos = jnp.arange(s * k) - jnp.searchsorted(se, se, side="left")
        keep = pos < cap
        slot = jnp.where(keep, se * cap + pos, e * cap)   # dummy overflow
        inv = jnp.full((e * cap + 1,), s, jnp.int32).at[slot].set(st_)
        a_slot = jnp.zeros((s * k,), jnp.int32).at[order].set(
            slot.astype(jnp.int32)).reshape(s, k)
        return inv[:e * cap], a_slot

    inv, a_slot = jax.vmap(plan)(idx)                     # (B,E*cap),(B,S,k)

    def gather_buf(xg, invg):
        xpad = jnp.concatenate([xg, jnp.zeros((1, d), xg.dtype)], axis=0)
        return xpad[invg].reshape(e, cap, d)

    buf = jax.vmap(gather_buf)(x, inv)                    # (B, E, cap, D)
    # Constraints are load-bearing: without them SPMD loses the batch
    # sharding through the sort/gather chain and replicates the expert
    # buffers (measured +90 GiB/device on mixtral train_4k).
    buf = constrain(buf, "dp", None, None, None)
    g = constrain(jnp.einsum("becd,edf->becf", buf, w_gate),
                  "dp", None, None, "model")
    u = constrain(jnp.einsum("becd,edf->becf", buf, w_up),
                  "dp", None, None, "model")
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    y = jnp.einsum("becf,efd->becd", h, w_down)           # (B, E, cap, D)
    y = constrain(y, "dp", None, None, None)

    def combine(yg, a_slotg, gateg):
        ypad = jnp.concatenate(
            [yg.reshape(e * cap, d), jnp.zeros((1, d), yg.dtype)], axis=0)
        contrib = ypad[a_slotg]                           # (S, k, D) gather
        return jnp.einsum("skd,sk->sd", contrib.astype(jnp.float32),
                          gateg.astype(jnp.float32))

    # a dropped assignment points at the dummy zero row, so its gate weight
    # contributes nothing regardless of value
    out = jax.vmap(combine)(y, a_slot, gates)
    return constrain(out.astype(x.dtype), "dp", None, None)


def moe_forward(params, x, cfg, path: str = "sorted"):
    if cfg.experts_per_token >= cfg.n_experts:
        return moe_dense(params, x, cfg)
    return (moe_sorted if path == "sorted" else moe_dense)(params, x, cfg)


def aux_load_balance_loss(params, x, cfg) -> jnp.ndarray:
    """Switch-style load-balance auxiliary loss (mean over batch)."""
    b, s, d = x.shape
    xf = x.reshape(b * s, d)
    logits = router_probs(params, xf, cfg.n_experts)
    probs = jax.nn.softmax(logits, axis=-1)
    _, idx = _top_k_gates(logits, cfg.experts_per_token)
    counts = jnp.zeros((cfg.n_experts,), jnp.float32).at[idx.reshape(-1)].add(
        1.0) / (b * s * cfg.experts_per_token)
    imp = probs.mean(axis=0)
    return cfg.n_experts * jnp.sum(counts * imp)
