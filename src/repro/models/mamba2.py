"""Mamba-2 / SSD (state-space duality) block [arXiv:2405.21060].

Chunked SSD algorithm: split the sequence into chunks of Q tokens; within a
chunk, outputs are a masked (causal, decay-weighted) quadratic attention-like
product; across chunks, a tiny recurrent state (H, P, N) is carried by a
``lax.scan``. Train/prefill cost is O(S*Q) intra + O(S/Q) scan — the
sub-quadratic property that makes the mamba2 ``long_500k`` cell feasible.

Decode is O(1): state <- decay * state + dt*B (x) x;  y = C . state.

Multi-value attention (MVA) layout as in the paper: B and C are shared
across heads (n_groups = 1), A is scalar per head, x has (H, P) heads.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class SSMState(NamedTuple):
    state: jnp.ndarray     # (B, H, P, N)
    conv: jnp.ndarray      # (B, W-1, d_conv_in) trailing conv window
    length: jnp.ndarray    # () int32


def ssm_dims(cfg):
    d_in = cfg.ssm_expand * cfg.d_model
    nh = cfg.ssm_heads or (d_in // cfg.ssm_head_dim)
    return d_in, nh, cfg.ssm_head_dim, cfg.ssm_state


def _segsum(log_a: jnp.ndarray) -> jnp.ndarray:
    """log_a (..., Q) -> (..., Q, Q) lower-triangular cumulative sums:
    out[i, j] = sum_{k=j+1..i} log_a[k] for i >= j, -inf otherwise."""
    q = log_a.shape[-1]
    cs = jnp.cumsum(log_a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]       # sum_{j+1..i}
    ii = jnp.arange(q)
    mask = ii[:, None] >= ii[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x: jnp.ndarray, dt: jnp.ndarray, a_log: jnp.ndarray,
                bmat: jnp.ndarray, cmat: jnp.ndarray, chunk: int,
                init_state: jnp.ndarray | None = None
                ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """SSD scan.

    Args:
      x: (B, S, H, P) inputs. dt: (B, S, H) positive step sizes.
      a_log: (H,) log of -A (A negative) -> per-step decay exp(-dt*exp(a_log)).
      bmat/cmat: (B, S, N) shared across heads.
      chunk: Q.
    Returns: (y (B, S, H, P), final_state (B, H, P, N)).
    """
    b, s, h, p = x.shape
    n = bmat.shape[-1]
    pad = (-s) % chunk
    if pad:
        # zero-pad the tail: dt=0 -> decay=1 and zero input, so the carried
        # state is untouched; padded outputs are sliced off below.
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
        s_out, s = s, s + pad
    else:
        s_out = s
    nc = s // chunk
    # per-step log decay: -dt * exp(a_log)  (negative)
    log_a = (-dt.astype(jnp.float32) *
             jnp.exp(a_log.astype(jnp.float32))[None, None, :])  # (B,S,H)
    xdt = x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None]

    # chunked views: (B, NC, Q, ...)
    def ch(t):
        return t.reshape(b, nc, chunk, *t.shape[2:])

    xc, lac = ch(xdt), ch(log_a)
    bc, cc = ch(bmat.astype(jnp.float32)), ch(cmat.astype(jnp.float32))

    # --- intra-chunk (diagonal blocks): decay-masked quadratic form ---
    lseg = _segsum(lac.transpose(0, 1, 3, 2))            # (B,NC,H,Q,Q)
    decay = jnp.exp(lseg)
    scores = jnp.einsum("bcin,bcjn->bcij", cc, bc)       # (B,NC,Q,Q)
    w = scores[:, :, None] * decay                       # (B,NC,H,Q,Q)
    y_diag = jnp.einsum("bchij,bcjhp->bcihp", w, xc)

    # --- chunk states: decay-to-end weighted sum of B (x) x ---
    la_sum = lac.sum(axis=2)                             # (B,NC,H)
    decay_to_end = jnp.exp(la_sum[:, :, None, :] -
                           jnp.cumsum(lac, axis=2))      # (B,NC,Q,H)
    states = jnp.einsum("bcqh,bcqn,bcqhp->bchpn",
                        decay_to_end, bc, xc)            # (B,NC,H,P,N)

    # --- inter-chunk recurrence (scan over chunks) ---
    if init_state is None:
        init_state = jnp.zeros((b, h, p, n), jnp.float32)

    def scan_fn(carry, inp):
        st_in = carry                                    # (B,H,P,N)
        chunk_state, chunk_decay = inp                   # (B,H,P,N),(B,H)
        st_out = chunk_state + chunk_decay[..., None, None] * st_in
        return st_out, st_in                             # emit PRE-state

    chunk_decay = jnp.exp(la_sum).transpose(1, 0, 2)     # (NC,B,H)
    states_t = states.transpose(1, 0, 2, 3, 4)           # (NC,B,H,P,N)
    final_state, pre_states = jax.lax.scan(
        scan_fn, init_state, (states_t, chunk_decay))
    pre_states = pre_states.transpose(1, 0, 2, 3, 4)     # (B,NC,H,P,N)

    # --- inter-chunk contribution: C . decayed carried state ---
    decay_from_start = jnp.exp(jnp.cumsum(lac, axis=2))  # (B,NC,Q,H)
    y_off = jnp.einsum("bcqn,bcqh,bchpn->bcqhp",
                       cc, decay_from_start, pre_states)
    y = (y_diag + y_off).reshape(b, s, h, p)
    return y[:, :s_out], final_state


def ssd_decode_step(x: jnp.ndarray, dt: jnp.ndarray, a_log: jnp.ndarray,
                    bvec: jnp.ndarray, cvec: jnp.ndarray,
                    state: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """One token: x (B,H,P), dt (B,H), bvec/cvec (B,N), state (B,H,P,N)."""
    decay = jnp.exp(-dt.astype(jnp.float32) *
                    jnp.exp(a_log.astype(jnp.float32))[None, :])  # (B,H)
    xdt = x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None]
    upd = jnp.einsum("bhp,bn->bhpn", xdt, bvec.astype(jnp.float32))
    new_state = state * decay[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", new_state, cvec.astype(jnp.float32))
    return y, new_state


def ssd_reference(x, dt, a_log, bmat, cmat):
    """O(S) sequential oracle for tests: plain per-token recurrence."""
    b, s, h, p = x.shape
    n = bmat.shape[-1]
    state = jnp.zeros((b, h, p, n), jnp.float32)
    ys = []
    for t in range(s):
        y, state = ssd_decode_step(x[:, t], dt[:, t], a_log, bmat[:, t],
                                   cmat[:, t], state)
        ys.append(y)
    return jnp.stack(ys, axis=1), state
