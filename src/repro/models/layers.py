"""Shared layers: norms, RoPE, embeddings, FFN, init helpers."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _dt(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def normal_init(key, shape, dtype, scale: float = 0.02):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def zeros_init(_key, shape, dtype, scale: float = 0.0):
    return jnp.zeros(shape, dtype)


def ones_init(_key, shape, dtype, scale: float = 0.0):
    return jnp.ones(shape, dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-6
             ) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps) * weight.astype(jnp.float32)
    return out.astype(x.dtype)


def layer_norm(x: jnp.ndarray, weight: jnp.ndarray, bias: jnp.ndarray,
               eps: float = 1e-5) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps)
    out = out * weight.astype(jnp.float32) + bias.astype(jnp.float32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float
               ) -> jnp.ndarray:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]                 # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# FFN variants
# ---------------------------------------------------------------------------

def swiglu(x: jnp.ndarray, w_gate: jnp.ndarray, w_up: jnp.ndarray,
           w_down: jnp.ndarray) -> jnp.ndarray:
    g = x @ w_gate.astype(x.dtype)
    u = x @ w_up.astype(x.dtype)
    return (jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u) \
        @ w_down.astype(x.dtype)


def gelu_mlp(x: jnp.ndarray, w_in: jnp.ndarray, w_out: jnp.ndarray
             ) -> jnp.ndarray:
    h = jax.nn.gelu((x @ w_in.astype(x.dtype)).astype(jnp.float32))
    return h.astype(x.dtype) @ w_out.astype(x.dtype)


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray,
                 mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Mean cross-entropy; logits (..., V) fp32-accumulated, labels int.

    The gold-logit pick is a masked reduction rather than take_along_axis:
    with V sharded over 'model' (Megatron vocab parallelism), a gather along
    the sharded axis makes XLA all-gather the full logits (tens of GiB at
    train_4k); where(iota==label) + sum stays shard-local and reduces with
    one tiny AllReduce.
    """
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    hit = (jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                    logits.ndim - 1) == labels[..., None])
    gold = jnp.sum(jnp.where(hit, logits, 0.0), axis=-1)
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
