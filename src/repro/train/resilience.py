"""Straggler detection + heartbeat / failure handling (1000-node posture).

On a real fleet these monitors run per-host and feed the job controller:
a straggling host triggers (a) collective timeout re-tuning, (b) hot-spare
swap-in, or (c) checkpoint-restart excluding the host (elastic downsize —
the checkpoint layer is mesh-agnostic so the restart reshards). Here the
logic is exercised by tests/simulation; the policies are real.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field


@dataclass
class StragglerMonitor:
    """p-quantile based step-time outlier detector with hysteresis."""

    window: int = 50
    threshold: float = 2.0        # x median
    min_samples: int = 10
    consecutive: int = 3          # flags needed before alarm
    _times: deque = field(default_factory=lambda: deque(maxlen=256))
    _flags: int = 0

    def record(self, step_seconds: float) -> bool:
        """Returns True when the host should be declared a straggler."""
        self._times.append(step_seconds)
        if len(self._times) < self.min_samples:
            return False
        recent = sorted(list(self._times)[-self.window:])
        median = recent[len(recent) // 2]
        if step_seconds > self.threshold * median:
            self._flags += 1
        else:
            self._flags = 0
        return self._flags >= self.consecutive

    @property
    def median(self) -> float:
        if not self._times:
            return 0.0
        r = sorted(self._times)
        return r[len(r) // 2]


@dataclass
class Heartbeat:
    """Dead-man switch: a host missing ``timeout`` seconds is presumed dead."""

    timeout: float = 60.0
    _last: dict = field(default_factory=dict)

    def beat(self, host: str, now: float | None = None) -> None:
        self._last[host] = time.monotonic() if now is None else now

    def dead_hosts(self, now: float | None = None) -> list[str]:
        now = time.monotonic() if now is None else now
        return [h for h, t in self._last.items() if now - t > self.timeout]


class PreemptionGuard:
    """Cooperative preemption: SIGTERM -> finish step, checkpoint, exit.

    Register with ``install()``; the trainer polls ``should_stop``.
    """

    def __init__(self):
        self.should_stop = False

    def install(self) -> "PreemptionGuard":
        import signal

        def handler(signum, frame):
            self.should_stop = True

        try:
            signal.signal(signal.SIGTERM, handler)
            signal.signal(signal.SIGINT, handler)
        except ValueError:
            pass   # non-main thread (tests)
        return self
