from repro.train.trainer import Trainer, TrainConfig, make_train_step
from repro.train.checkpoint import CheckpointManager
from repro.train.resilience import StragglerMonitor, Heartbeat, PreemptionGuard

__all__ = ["Trainer", "TrainConfig", "make_train_step", "CheckpointManager",
           "StragglerMonitor", "Heartbeat", "PreemptionGuard"]
