"""Fault-tolerant checkpointing: sharded-agnostic, atomic, async, versioned.

Design (1000+ node posture, DESIGN.md §5):
  * **Mesh-agnostic**: leaves are stored as full logical arrays keyed by
    pytree path; restore re-shards onto whatever mesh/sharding the new job
    uses (elastic scaling: a 512-chip checkpoint restores onto 256 chips).
    On a real multi-host fleet each host would write its addressable shards
    (same manifest format, one npz per host) — the container is single-host,
    so there is exactly one shard file.
  * **Atomic**: write into ``<dir>/tmp.<step>``, fsync, then rename to
    ``step_<k>`` — a crash mid-save can never corrupt the newest complete
    checkpoint; restore scans for the newest directory with a valid
    manifest.
  * **Async**: ``save_async`` snapshots to host memory synchronously (cheap)
    and writes in a background thread, overlapping I/O with the next step
    (the paper's double-buffering discipline applied to checkpoints).
  * **Versioned**: keeps the newest ``keep`` checkpoints, deletes older.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any

import jax
import numpy as np


def _flatten_with_paths(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype.kind == "V" or arr.dtype.name in ("bfloat16", "float8_e4m3fn",
                                                       "float8_e5m2"):
            # npz can't serialize ml_dtypes; store f32 (lossless upcast),
            # restore() casts back to the model's dtype
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    # ---- save ----
    def _write(self, step: int, flat: dict[str, np.ndarray],
               meta: dict) -> None:
        tmp = os.path.join(self.directory, f"tmp.{step}.{os.getpid()}")
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "shard_0.npz"), **flat)
        manifest = {"step": step, "time": time.time(), "n_shards": 1,
                    "keys": sorted(flat), "meta": meta}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        final = os.path.join(self.directory, f"step_{step:012d}")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def save(self, step: int, tree: Any, meta: dict | None = None,
             blocking: bool = True) -> None:
        self.wait()
        flat = _flatten_with_paths(tree)      # device->host snapshot NOW
        if blocking:
            self._write(step, flat, meta or {})
            return

        def run():
            try:
                self._write(step, flat, meta or {})
            except BaseException as e:   # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def save_async(self, step: int, tree: Any, meta: dict | None = None):
        self.save(step, tree, meta, blocking=False)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    # ---- restore ----
    def _steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_"):
                manifest = os.path.join(self.directory, name, "manifest.json")
                if os.path.exists(manifest):
                    out.append(int(name[5:]))
        return sorted(out)

    @property
    def latest_step(self) -> int | None:
        steps = self._steps()
        return steps[-1] if steps else None

    def restore(self, like: Any, step: int | None = None,
                shardings: Any = None) -> tuple[Any, dict]:
        """Restore into the structure of ``like``; re-shard if given."""
        self.wait()
        step = step if step is not None else self.latest_step
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.directory}")
        d = os.path.join(self.directory, f"step_{step:012d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(d, "shard_0.npz"))
        paths, treedef = jax.tree_util.tree_flatten_with_path(like)
        shard_flat = (jax.tree.leaves(shardings) if shardings is not None
                      else [None] * len(paths))
        leaves = []
        for (path, leaf), shd in zip(paths, shard_flat):
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                           for p in path)
            arr = data[key]
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(f"shape mismatch for {key}: "
                                 f"ckpt {arr.shape} vs model {leaf.shape}")
            arr = arr.astype(leaf.dtype)
            leaves.append(jax.device_put(arr, shd) if shd is not None
                          else jax.numpy.asarray(arr))
        return treedef.unflatten(leaves), manifest["meta"]

    def _gc(self) -> None:
        steps = self._steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:012d}"),
                          ignore_errors=True)
