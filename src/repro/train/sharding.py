"""Sharding rules: FSDP ('data') x TP ('model'), pod-replicated params.

Posture (DESIGN.md §5): the 'pod' axis carries only data parallelism whose
gradient all-reduce is the single cross-pod (DCN-class) collective; 'data'
carries FSDP (params/optimizer sharded, weights all-gathered on use);
'model' carries tensor parallelism (Megatron column/row) plus
sequence-sharded KV during decode.

Per-leaf rules are by parameter *name* (names are globally unique across
families). A dim is sharded only when divisible by the axis size —
``_shard_if`` degrades to replication otherwise (e.g. batch=1 long_500k).
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# name -> per-dim logical axes for the UNSTACKED (single-layer) shape.
# 'fsdp' -> 'data', 'tp' -> 'model', None -> replicated.
_RULES: dict[str, tuple] = {
    # embeddings / heads
    "embed": ("tp", "fsdp"),            # (V, D)
    "lm_head": ("fsdp", "tp"),          # (D, V)
    "head": ("fsdp", "tp"),             # (D, V) audio
    "frontend_proj": (None, "fsdp"),    # (frontend, D)
    # attention
    "wq": ("fsdp", "tp"), "wk": ("fsdp", "tp"), "wv": ("fsdp", "tp"),
    "wo": ("tp", "fsdp"),
    "q_norm": (None,), "k_norm": (None,),
    # dense mlp
    "w_in": ("fsdp", "tp"), "w_out": ("tp", "fsdp"),
    "w_gate": ("fsdp", "tp"), "w_up": ("fsdp", "tp"),
    "w_down": ("tp", "fsdp"),
    # moe (E, D, F) / (E, F, D): experts replicated, TP on F, FSDP on D
    "w_router": ("fsdp", None),
    # ssm
    "in_proj": ("fsdp", "tp"), "conv_w": (None, "tp"),
    "dt_bias": ("tp",), "a_log": ("tp",), "skip_d": ("tp",),
    "out_norm": ("tp",), "out_proj": ("tp", "fsdp"),
    # rglru (hybrid)
    "gate_proj": ("fsdp", "tp"), "rnn_proj": ("fsdp", "tp"),
    "w_a": (None, "tp"), "b_a": ("tp",), "w_x": (None, "tp"),
    "b_x": ("tp",), "lam": ("tp",),
    # norms
    "attn_norm": (None,), "mlp_norm": (None,), "norm": (None,),
    "final_norm": (None,),
}

_MOE_3D = {"w_gate": (None, "fsdp", "tp"), "w_up": (None, "fsdp", "tp"),
           "w_down": (None, "tp", "fsdp")}


def _axis(mesh: Mesh, logical: str | None) -> str | None:
    if logical is None:
        return None
    name = {"fsdp": "data", "tp": "model"}[logical]
    return name if name in mesh.axis_names else None


def _shard_if(mesh: Mesh, dim: int, axis: str | None):
    """Shard only when divisible; otherwise replicate this dim."""
    if axis is None or axis not in mesh.axis_names:
        return None
    if dim % mesh.shape[axis] != 0:
        return None
    return axis


def leaf_pspec(mesh: Mesh, name: str, shape: tuple, stacked: bool) -> P:
    body_shape = shape[1:] if stacked else shape
    rule = _RULES.get(name)
    if rule is not None and len(rule) != len(body_shape) and name in _MOE_3D:
        rule = None
    if name in _MOE_3D and len(body_shape) == 3:
        rule = _MOE_3D[name]
    if rule is None or len(rule) != len(body_shape):
        rule = (None,) * len(body_shape)
    axes = [_shard_if(mesh, d, _axis(mesh, r))
            for d, r in zip(body_shape, rule)]
    if stacked:
        axes = [None] + axes
    return P(*axes)


def param_pspecs(cfg, mesh: Mesh, shapes: Any, decode: bool = False) -> Any:
    """PartitionSpec pytree matching ``param_spec``-built params.

    cfg.tensor_parallel=False drops every 'model'-axis placement (params
    replicated across 'model'; the batch occupies it instead).

    decode=True lays the embedding out (D -> 'model') instead of
    (V -> 'model', D -> 'data'): a token gather over a vocab-sharded table
    triggers SPMD's involuntary full rematerialization every step; the
    D-sharded layout makes the lookup collective-free (§Perf)."""

    def strip_model(spec: P) -> P:
        return P(*[None if a == "model" else a for a in spec])

    def walk(node, name=None, stacked=False):
        if isinstance(node, dict):
            return {k: walk(v, k, stacked or k in ("blocks", "groups"))
                    for k, v in node.items()}
        if isinstance(node, list):
            return [walk(v, name, stacked and name != "tail")
                    for v in node]
        if decode and name == "embed":
            spec = P(None, _shard_if(mesh, node.shape[-1], "model"))
        else:
            spec = leaf_pspec(mesh, name, tuple(node.shape), stacked)
        return spec if cfg.tensor_parallel else strip_model(spec)

    return walk(shapes)


def param_shardings(cfg, mesh: Mesh, shapes: Any,
                    decode: bool = False) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_pspecs(cfg, mesh, shapes, decode=decode),
                        is_leaf=lambda x: isinstance(x, P))


def moment_pspecs(cfg, mesh: Mesh, shapes: Any) -> Any:
    """Optimizer-moment specs: param spec + ZeRO-style 'pod' sharding.

    Moments are touched only at the update, never in fwd/bwd, so sharding
    them over the pod axis (on the leading stacked dim, which params keep
    replicated for the scan) costs no hot-path collectives and halves the
    per-device optimizer footprint on the 2-pod mesh."""
    base = param_pspecs(cfg, mesh, shapes)

    def walk(node, spec):
        if isinstance(node, dict):
            return {k: walk(v, spec[k]) for k, v in node.items()}
        if isinstance(node, list):
            return [walk(v, s) for v, s in zip(node, spec)]
        parts = list(spec) + [None] * (len(node.shape) - len(spec))
        if "pod" in mesh.axis_names:
            for i, (dim, p) in enumerate(zip(node.shape, parts)):
                if p is None and dim % mesh.shape["pod"] == 0:
                    parts[i] = "pod"
                    break
        return P(*parts)

    return walk(shapes, base)


def moment_shardings(cfg, mesh: Mesh, shapes: Any) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        moment_pspecs(cfg, mesh, shapes),
                        is_leaf=lambda x: isinstance(x, P))


def dp_axes(mesh: Mesh, include_model: bool = False) -> tuple:
    names = ("pod", "data", "model") if include_model else ("pod", "data")
    return tuple(a for a in names if a in mesh.axis_names)


def batch_pspec(mesh: Mesh, batch_size: int, ndim: int,
                include_model: bool = False) -> P:
    """Shard the leading batch dim over the longest divisible DP-axis
    prefix (e.g. batch 32 on ('pod','data','model') falls back to
    ('pod','data'), then ('pod',), then replication)."""
    axes = dp_axes(mesh, include_model)
    best, best_total = None, 1
    for i in range(len(axes)):
        for j in range(i + 1, len(axes) + 1):
            sub = axes[i:j]
            total = int(np.prod([mesh.shape[a] for a in sub]))
            if batch_size % total == 0 and total > best_total:
                best, best_total = sub, total
    if best:
        # unwrap singleton axis tuples: P('data') and P(('data',)) shard
        # identically but compare unequal, and every consumer (and test)
        # spells the scalar form
        return P(best if len(best) > 1 else best[0],
                 *([None] * (ndim - 1)))
    return P(*([None] * ndim))


def batch_shardings(mesh: Mesh, batch_specs: dict,
                    include_model: bool = False) -> dict:
    return {k: NamedSharding(mesh, batch_pspec(mesh, v.shape[0],
                                               len(v.shape), include_model))
            for k, v in batch_specs.items()}


def cache_pspecs(cfg, mesh: Mesh, cache_shapes) -> Any:
    """DecodeCache shardings: batch -> (pod,data); heads/C -> 'model'.

    KV (L, B, C, Hk, hd): when Hk divides |model| shard heads, else shard
    the *cache sequence* C over 'model' (sequence-sharded decode; the
    explicit-softmax decode path turns this into local partials + a small
    AllReduce). SSM state (L, B, H, P, N): H over 'model'. RG-LRU h
    (L, B, D_rnn): D_rnn over 'model'.
    """
    model = mesh.shape.get("model", 1)

    def spec(path_name, shape):
        nd = len(shape)
        if path_name in ("kv_k", "kv_v"):
            b_axes = batch_pspec(mesh, shape[1], 1)[0]
            if cfg.n_kv_heads % model == 0:
                return P(None, b_axes, None,
                         _shard_if(mesh, shape[3], "model"), None)
            return P(None, b_axes, _shard_if(mesh, shape[2], "model"),
                     None, None)
        if path_name == "ssm_state":
            return P(None, batch_pspec(mesh, shape[1], 1)[0],
                     _shard_if(mesh, shape[2], "model"), None, None)
        if path_name == "conv_carry":
            return P(None, batch_pspec(mesh, shape[1], 1)[0], None,
                     _shard_if(mesh, shape[3], "model"))
        if path_name == "rec_h":
            return P(None, batch_pspec(mesh, shape[1], 1)[0],
                     _shard_if(mesh, shape[2], "model"))
        if path_name == "rec_conv":
            return P(None, batch_pspec(mesh, shape[1], 1)[0], None,
                     _shard_if(mesh, shape[3], "model"))
        if path_name == "length":
            return P()
        return P(*([None] * nd))

    fields = cache_shapes._asdict()
    return type(cache_shapes)(**{
        k: (None if v is None else spec(k, tuple(v.shape)))
        for k, v in fields.items()})


def cache_shardings(cfg, mesh: Mesh, cache_shapes) -> Any:
    specs = cache_pspecs(cfg, mesh, cache_shapes)
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        specs, is_leaf=lambda x: isinstance(x, P))
