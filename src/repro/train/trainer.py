"""Distributed trainer: jit'd sharded train step + fault-tolerant loop.

Step function features:
  * FSDP x TP shardings from train/sharding.py, donated params/opt-state
  * microbatch gradient accumulation (lax.scan over microbatches)
  * global-norm clipping, AdamW, WSD/cosine schedules
  * optional int8 error-feedback compression of the DP gradient (the
    cross-pod all-reduce payload) — optim/compression.py

Loop features (exercised at small scale in tests/examples):
  * stateless-seekable data (restart replays identical batches)
  * async checkpoint every k steps + preemption-triggered save
  * straggler monitor + heartbeat
  * auto-resume from the newest complete checkpoint (elastic: the restore
    reshards onto the current mesh)
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.data.synthetic import TokenPipeline
from repro.models.config import ModelConfig
from repro.models.transformer import init_params, param_shapes, train_loss
from repro.optim import (adamw_init, adamw_update, clip_by_global_norm,
                         cosine_schedule, resolve_moment_dtype,
                         wsd_schedule)
from repro.optim.compression import compress_int8, decompress_int8
from repro.train import sharding as shd
from repro.train.checkpoint import CheckpointManager
from repro.train.resilience import PreemptionGuard, StragglerMonitor


@dataclass
class TrainConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    schedule: str = "cosine"        # cosine | wsd | const
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    grad_accum: int = 1
    compress_grads: bool = False    # int8 EF compression of DP grads
    checkpoint_every: int = 200
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3
    seed: int = 0


def make_lr_fn(tc: TrainConfig):
    if tc.schedule == "wsd":
        stable = int(tc.total_steps * 0.8) - tc.warmup_steps
        decay = tc.total_steps - tc.warmup_steps - stable
        return wsd_schedule(tc.lr, tc.warmup_steps, max(stable, 1),
                            max(decay, 1))
    if tc.schedule == "cosine":
        return cosine_schedule(tc.lr, tc.warmup_steps, tc.total_steps)
    return lambda step: tc.lr


def make_train_step(cfg: ModelConfig, tc: TrainConfig) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    With tc.grad_accum > 1 the batch's leading dim is split into
    microbatches and gradients are accumulated in fp32 by a lax.scan —
    the standard memory-for-throughput trade at large global batch.
    """
    lr_fn = make_lr_fn(tc)
    resolve_moment_dtype(cfg.moment_dtype)   # validate early

    def loss_fn(params, batch):
        return train_loss(params, cfg, batch)

    def compute_grads(params, batch):
        if tc.grad_accum <= 1:
            return jax.value_and_grad(loss_fn)(params, batch)
        k = tc.grad_accum

        def micro(b):
            return {kk: v.reshape(k, v.shape[0] // k, *v.shape[1:])
                    for kk, v in b.items()}

        micro_batches = micro(batch)
        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def body(acc, mb):
            loss_acc, g_acc = acc
            loss, g = jax.value_and_grad(loss_fn)(params, mb)
            g_acc = jax.tree.map(
                lambda a, x: a + x.astype(jnp.float32) / k, g_acc, g)
            return (loss_acc + loss / k, g_acc), None

        (loss, grads), _ = jax.lax.scan(body, (jnp.zeros(()), zero),
                                        micro_batches)
        return loss, grads

    def train_step(params, opt_state, batch):
        loss, grads = compute_grads(params, batch)
        if tc.compress_grads:
            # int8 round-trip models the wire format of the cross-pod
            # all-reduce (the psum itself is inserted by SPMD); the
            # quantization error is what convergence tests must absorb.
            def rt(g):
                q, s = compress_int8(g)
                return decompress_int8(q, s, g.shape, g.dtype)

            grads = jax.tree.map(rt, grads)
        grads, gnorm = clip_by_global_norm(grads, tc.clip_norm)
        new_params, new_opt = adamw_update(
            grads, opt_state, params, lr=lr_fn,
            weight_decay=tc.weight_decay)
        metrics = {"loss": loss.astype(jnp.float32), "grad_norm": gnorm,
                   "lr": lr_fn(opt_state.step + 1)}
        return new_params, new_opt, metrics

    return train_step


class Trainer:
    """End-to-end driver; works on a 1-device mesh (tests/examples) and on
    the production mesh (launch/train.py)."""

    def __init__(self, cfg: ModelConfig, tc: TrainConfig, mesh: Mesh,
                 global_batch: int, seq_len: int):
        self.cfg, self.tc, self.mesh = cfg, tc, mesh
        self.pipeline = TokenPipeline(cfg.vocab_size, global_batch, seq_len,
                                      seed=tc.seed)
        self.ckpt = CheckpointManager(tc.checkpoint_dir,
                                      keep=tc.keep_checkpoints)
        self.monitor = StragglerMonitor()
        self.guard = PreemptionGuard().install()
        self.step = 0

        shapes = param_shapes(cfg)
        self.param_shardings = shd.param_shardings(cfg, mesh, shapes)
        moment_shardings = shd.moment_shardings(cfg, mesh, shapes)
        # honour cfg.moment_dtype (e.g. grok1's bf16 moments: fp32 would
        # not fit HBM) — adamw_init defaults to fp32 otherwise
        self._init_opt = partial(
            adamw_init, moment_dtype=resolve_moment_dtype(cfg.moment_dtype))
        opt_shapes = jax.eval_shape(self._init_opt, shapes)
        self.opt_shardings = type(opt_shapes)(
            step=NamedSharding(mesh, P()),
            mu=moment_shardings, nu=moment_shardings)
        self.batch_sharding = NamedSharding(
            mesh, shd.batch_pspec(mesh, global_batch, 2))

        step_fn = make_train_step(cfg, tc)
        self.train_step = jax.jit(
            step_fn,
            in_shardings=(self.param_shardings, self.opt_shardings,
                          self.batch_sharding),
            out_shardings=(self.param_shardings, self.opt_shardings, None),
            donate_argnums=(0, 1))

    # ---- state ----
    def init_state(self):
        with self.mesh:
            params = jax.jit(
                partial(init_params, self.cfg),
                out_shardings=self.param_shardings)(jax.random.PRNGKey(
                    self.tc.seed))
            opt = jax.jit(
                self._init_opt, out_shardings=self.opt_shardings)(params)
        return params, opt

    def maybe_resume(self, params, opt):
        if self.ckpt.latest_step is None:
            return params, opt
        state = {"params": params, "opt": opt}
        shardings = {"params": self.param_shardings,
                     "opt": self.opt_shardings}
        restored, meta = self.ckpt.restore(state, shardings=shardings)
        self.step = int(meta.get("data_step", self.ckpt.latest_step))
        print(f"[trainer] resumed from step {self.step}")
        return restored["params"], restored["opt"]

    # ---- loop ----
    def run(self, steps: int, log_every: int = 10) -> list[dict]:
        from repro.models.pspec_utils import activation_sharding
        with activation_sharding(self.mesh):
            return self._run(steps, log_every)

    def _run(self, steps: int, log_every: int) -> list[dict]:
        params, opt = self.init_state()
        params, opt = self.maybe_resume(params, opt)
        history = []
        for _ in range(steps):
            if self.guard.should_stop:
                print("[trainer] preemption: checkpoint + stop")
                break
            t0 = time.monotonic()
            batch = {k: jnp.asarray(v)
                     for k, v in self.pipeline.batch(self.step).items()}
            params, opt, metrics = self.train_step(params, opt, batch)
            metrics = {k: float(v) for k, v in metrics.items()}
            dt = time.monotonic() - t0
            if self.monitor.record(dt):
                print(f"[trainer] WARNING straggler: step {self.step} "
                      f"took {dt:.2f}s (median {self.monitor.median:.2f}s)")
            self.step += 1
            metrics["step"] = self.step
            metrics["seconds"] = dt
            history.append(metrics)
            if log_every and self.step % log_every == 0:
                print(f"step {self.step}: loss {metrics['loss']:.4f} "
                      f"({dt:.2f}s)")
            if self.step % self.tc.checkpoint_every == 0:
                self.ckpt.save_async(self.step,
                                     {"params": params, "opt": opt},
                                     meta={"data_step": self.step})
        self.ckpt.save(self.step, {"params": params, "opt": opt},
                       meta={"data_step": self.step})
        return history
