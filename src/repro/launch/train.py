"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --smoke \
      --steps 100 --global-batch 8 --seq-len 128

Uses the host mesh by default (CPU: 1 device). On a real fleet each host
runs this entrypoint under ``jax.distributed.initialize`` and the mesh spans
all processes; the trainer, checkpointing, and data pipeline are already
host-sharded (see data/synthetic.py, train/checkpoint.py).
"""
from __future__ import annotations

import argparse
import importlib

from repro.configs import ARCH_IDS, get_config
from repro.configs.registry import _MODULES
from repro.launch.mesh import make_host_mesh
from repro.train import TrainConfig, Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--model-parallel", type=int, default=1)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    # arch-specific recipe (e.g. minicpm's WSD schedule)
    mod = importlib.import_module(_MODULES[args.arch])
    schedule = getattr(mod, "LR_SCHEDULE", "cosine")

    tc = TrainConfig(
        lr=args.lr, total_steps=args.steps,
        warmup_steps=max(1, args.steps // 10), schedule=schedule,
        grad_accum=args.grad_accum, compress_grads=args.compress_grads,
        checkpoint_dir=args.checkpoint_dir,
        checkpoint_every=args.checkpoint_every)
    mesh = make_host_mesh(model=args.model_parallel)
    trainer = Trainer(cfg, tc, mesh, args.global_batch, args.seq_len)
    history = trainer.run(args.steps)
    if history:
        print(f"final loss: {history[-1]['loss']:.4f} "
              f"(from {history[0]['loss']:.4f})")


if __name__ == "__main__":
    main()
