"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds (TPU v5e constants):

    compute    = HLO_FLOPs / (chips * 197e12)
    memory     = HLO_bytes / (chips * 819e9)
    collective = collective_bytes / (chips * 50e9)

``compiled.cost_analysis()`` reports per-device flops/bytes (post-SPMD), so
chips cancel: term = per_device_quantity / per_chip_rate. collective_bytes
is not in cost_analysis: we parse the post-SPMD HLO text and sum the output
operand sizes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops (per-device bytes on the wire, one hop)."""
from __future__ import annotations

import re
from dataclasses import dataclass, asdict

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # bytes/s / chip
ICI_BW = 50e9             # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g. "bf16[16,4096,256]{2,1,0}" or "f32[]"
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes_from_hlo(hlo_text: str) -> dict[str, int]:
    """Sum per-device output bytes of each collective op kind."""
    out: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.lstrip()
        # optimized HLO: "%name = TYPE[SHAPE] all-gather(...)" or fusion-free
        m = re.match(r"%?[\w.\-]+\s*=\s*(.+?)\s+([\w\-]+)\(", s)
        if not m:
            continue
        result_part, opname = m.groups()
        kind = None
        for c in _COLLECTIVES:
            if opname == c or opname.startswith(c + "-"):
                kind = c
                break
        if kind is None:
            continue
        # result may be a tuple "(bf16[...], bf16[...])"
        total = sum(_shape_bytes(d, dims)
                    for d, dims in _SHAPE_RE.findall(result_part))
        out[kind] += total
    return out


@dataclass
class RooflineTerms:
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    collective_breakdown: dict
    compute_s: float
    memory_s: float
    collective_s: float
    bound: str
    model_flops_total: float       # 6*N*D or 2*N*D
    hlo_flops_total: float
    useful_flops_ratio: float
    chips: int

    def to_dict(self):
        return asdict(self)


def roofline_from_terms(*, flops_per_device: float, bytes_per_device: float,
                        collective_breakdown: dict, chips: int,
                        model_flops_total: float) -> RooflineTerms:
    flops, raw_bytes = flops_per_device, bytes_per_device
    coll = collective_breakdown
    coll_bytes = float(sum(coll.values()))
    compute_s = flops / PEAK_FLOPS
    memory_s = raw_bytes / HBM_BW
    collective_s = coll_bytes / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bound = max(terms, key=terms.get)
    hlo_total = flops * chips
    return RooflineTerms(
        flops_per_device=flops, bytes_per_device=raw_bytes,
        collective_bytes_per_device=coll_bytes, collective_breakdown=coll,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        bound=bound, model_flops_total=model_flops_total,
        hlo_flops_total=hlo_total,
        useful_flops_ratio=(model_flops_total / hlo_total
                            if hlo_total else 0.0),
        chips=chips)


def roofline_from_compiled(compiled, *, chips: int, model_flops_total: float,
                           hlo_text: str | None = None) -> RooflineTerms:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    text = hlo_text if hlo_text is not None else compiled.as_text()
    return roofline_from_terms(
        flops_per_device=float(ca.get("flops", 0.0)),
        bytes_per_device=float(ca.get("bytes accessed", 0.0)),
        collective_breakdown=collective_bytes_from_hlo(text),
        chips=chips, model_flops_total=model_flops_total)


def model_flops(cfg, cell, n_tokens: int | None = None) -> float:
    """MODEL_FLOPS: 6*N*D (train) / 2*N*D (inference), N = active params."""
    n_active = cfg.param_count(active_only=True)
    if n_tokens is None:
        if cell.kind == "train":
            n_tokens = cell.global_batch * cell.seq_len
        elif cell.kind == "prefill":
            n_tokens = cell.global_batch * cell.seq_len
        else:
            n_tokens = cell.global_batch * 1
    mult = 6 if cell.kind == "train" else 2
    return float(mult * n_active * n_tokens)
