import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the jit'd step function (train_step for train_4k,
prefill for prefill_32k, decode_step for decode cells) with the production
shardings, lowers it against ShapeDtypeStruct inputs (no allocation),
compiles it for the 16x16 (single-pod, 256 chip) and 2x16x16 (two-pod, 512
chip) meshes, and records:

  * compiled.memory_analysis()  — proves per-device fit
  * compiled.cost_analysis()    — FLOPs / bytes for the roofline
  * collective bytes parsed from the post-SPMD HLO text

Results cache to benchmarks/dryrun_results/<arch>__<shape>__<mesh>.json so
repeated runs are incremental. Usage:

  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh pod1|pod2|both]
"""

import argparse
import json
import time
import traceback
from functools import partial

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import (ARCH_IDS, SHAPES, cell_supported, get_config,
                           input_specs)
from repro.launch import roofline as rf
from repro.launch.mesh import make_production_mesh
from repro.models.pspec_utils import activation_sharding
from repro.models.transformer import param_shapes
from repro.optim import adamw_init, resolve_moment_dtype
from repro.serve.engine import decode_step, init_decode_cache, prefill
from repro.train import sharding as shd
from repro.train.trainer import TrainConfig, make_train_step

RESULTS_DIR = os.path.join(os.path.dirname(__file__),
                           "../../../benchmarks/dryrun_results")

# XLA's while-loop LICM hoists per-iteration bf16->f32 converts of the
# stacked remat residuals OUT of the backward loop, materializing the whole
# (L, B, S, D) stack in f32 (2x the honest peak). Disabling the pass keeps
# the convert per-iteration; measured effect (qwen3-8b train_4k, 256 dev):
# temp 54.9 -> 30.2 GiB, identical HLO elsewhere. See EXPERIMENTS.md §Perf.
COMPILER_OPTS = {"xla_disable_hlo_passes": "while-loop-invariant-code-motion"}

# Gradient-accumulation microbatching for the memory giants (the standard
# fit lever at fixed global batch). Probes (measure_metrics) always use
# accum=1 so flops/bytes are counted per full step, not per microbatch.
TRAIN_ACCUM = {"grok-1-314b": 8, "internvl2-76b": 4}


def _mesh_name(multi_pod: bool) -> str:
    return "pod2" if multi_pod else "pod1"


def build_lowered(arch: str, shape: str, mesh, *, overrides=None,
                  train_accum: int | None = None):
    """Lower the cell's step function on ``mesh``; returns (lowered, meta)."""
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.with_(**overrides)
    cell = SHAPES[shape]
    specs = input_specs(cfg, shape)
    pshapes = param_shapes(cfg)
    pshard = shd.param_shardings(cfg, mesh, pshapes)
    pure_dp = not cfg.tensor_parallel
    bshard = shd.batch_shardings(mesh, specs, include_model=pure_dp)
    act_dp = ("pod", "data", "model") if pure_dp else ("pod", "data")

    if cell.kind == "train":
        tc = TrainConfig(grad_accum=(train_accum if train_accum is not None
                                     else TRAIN_ACCUM.get(arch, 1)))
        step = make_train_step(cfg, tc)
        # same moment dtype the real Trainer initializes with, so the
        # reported optimizer-state footprint matches (bf16-moment configs)
        init_opt = partial(adamw_init,
                           moment_dtype=resolve_moment_dtype(
                               cfg.moment_dtype))
        opt_shapes = jax.eval_shape(init_opt, pshapes)
        mshard = shd.moment_shardings(cfg, mesh, pshapes)
        opt_shard = type(opt_shapes)(step=NamedSharding(mesh, P()),
                                     mu=mshard, nu=mshard)
        fn = jax.jit(step,
                     in_shardings=(pshard, opt_shard,
                                   {k: bshard[k] for k in specs}),
                     out_shardings=(pshard, opt_shard, None),
                     donate_argnums=(0, 1))
        with mesh, activation_sharding(mesh, act_dp):
            lowered = fn.lower(pshapes, opt_shapes, specs)
    elif cell.kind == "prefill":
        if cfg.is_encoder:
            # encoder-only: prefill_32k is a pure encode (no decode cache)
            from repro.models.transformer import forward
            with mesh, activation_sharding(mesh, act_dp):
                lowered = jax.jit(
                    lambda p, b: forward(p, cfg, b),
                    in_shardings=(pshard, {k: bshard[k] for k in specs}),
                ).lower(pshapes, specs)
        else:
            with mesh, activation_sharding(mesh, act_dp):
                lowered = jax.jit(
                    lambda p, b: prefill(p, cfg, b, cell.seq_len),
                    in_shardings=(pshard, {k: bshard[k] for k in specs}),
                ).lower(pshapes, specs)
    else:  # decode
        pshard = shd.param_shardings(cfg, mesh, pshapes, decode=True)
        cache_shapes = jax.eval_shape(
            lambda: init_decode_cache(cfg, cell.global_batch, cell.seq_len))
        cshard = shd.cache_shardings(cfg, mesh, cache_shapes)
        with mesh, activation_sharding(mesh, act_dp):
            lowered = jax.jit(
                lambda p, t, c: decode_step(p, cfg, t, c),
                in_shardings=(pshard, bshard["tokens"], cshard),
                out_shardings=(None, cshard),
                donate_argnums=(2,),
            ).lower(pshapes, specs["tokens"], cache_shapes)
    return lowered, {"cfg": cfg, "cell": cell}


def _cell_metrics_of(compiled) -> tuple[float, float, dict]:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    coll = rf.collective_bytes_from_hlo(compiled.as_text())
    return float(ca.get("flops", 0.0)), float(ca.get("bytes accessed", 0.0)), \
        coll


def measure_metrics(arch: str, shape: str, mesh, compiled,
                    overrides=None) -> tuple[float, float, dict]:
    """Per-device (flops, bytes, collective-bytes) with scan correction.

    XLA's cost analysis counts a ``while`` body ONCE, so a scanned L-layer
    stack reports ~1 layer of flops/bytes, and collectives inside the loop
    appear once in the HLO text. Fix: lower the model UNROLLED at two
    shallow depths k1 < k2; per-layer cost = (m(k2) - m(k1)) / (k2 - k1),
    outside-the-stack cost = m(k1) - k1 * per_layer; total = outside +
    L * per_layer. Exact for homogeneous stacks (what scan requires).
    Unscanned configs (hybrid) are measured directly on ``compiled``.
    """
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.with_(**overrides)
    if not (cfg.scan_layers and cfg.n_layers > 1):
        return _cell_metrics_of(compiled)
    # hybrid: probe whole pattern groups so the per-layer average covers
    # each block kind in ratio (tail remainder absorbs <4% error at L=26)
    pat = len(cfg.block_pattern) if cfg.family == "hybrid" else 1
    k1, k2 = (pat, 2 * pat) if pat > 1 else (2, 4)
    probes = []
    for k in (k1, k2):
        ov = dict(overrides or {})
        ov.update(n_layers=k, scan_layers=False)
        lowered, _ = build_lowered(arch, shape, mesh, overrides=ov,
                                   train_accum=1)
        probes.append(_cell_metrics_of(
            lowered.compile(compiler_options=COMPILER_OPTS)))
    (f1, b1, c1), (f2, b2, c2) = probes
    L = cfg.n_layers

    def extrap(m1, m2):
        per_layer = (m2 - m1) / (k2 - k1)
        return max(0.0, m1 - k1 * per_layer) + L * per_layer

    coll = {key: extrap(c1.get(key, 0), c2.get(key, 0)) for key in c1}
    return extrap(f1, f2), extrap(b1, b2), coll


def run_cell(arch: str, shape: str, multi_pod: bool, force: bool = False,
             overrides=None, tag: str = "", train_accum: int | None = None
             ) -> dict:
    mesh_name = _mesh_name(multi_pod)
    os.makedirs(RESULTS_DIR, exist_ok=True)
    out_path = os.path.join(
        RESULTS_DIR, f"{arch}__{shape}__{mesh_name}{tag}.json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            return json.load(f)

    cfg = get_config(arch)
    ok, reason = cell_supported(cfg, shape)
    result = {"arch": arch, "shape": shape, "mesh": mesh_name,
              "supported": ok, "reason": reason, "tag": tag}
    if not ok:
        with open(out_path, "w") as f:
            json.dump(result, f, indent=1)
        return result

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    try:
        lowered, meta = build_lowered(arch, shape, mesh, overrides=overrides,
                                      train_accum=train_accum)
        t_lower = time.time() - t0
        compiled = lowered.compile(compiler_options=COMPILER_OPTS)
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        flops, bytes_, coll = measure_metrics(arch, shape, mesh, compiled,
                                              overrides=overrides)
        terms = rf.roofline_from_terms(
            flops_per_device=flops, bytes_per_device=bytes_,
            collective_breakdown=coll, chips=chips,
            model_flops_total=rf.model_flops(meta["cfg"], meta["cell"]))
        result.update({
            "ok": True,
            "chips": chips,
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "memory": {
                "argument_bytes": int(mem.argument_size_in_bytes),
                "output_bytes": int(mem.output_size_in_bytes),
                "temp_bytes": int(mem.temp_size_in_bytes),
                "alias_bytes": int(mem.alias_size_in_bytes),
                # CPU XLA computes bf16 math in f32 (no native bf16 units),
                # inflating every activation temp 2x vs the TPU backend.
                # Verified: an all-f32 model build has the SAME temp as the
                # bf16 build (mixtral train_4k: 27.1 vs 25.7 GiB), so the
                # TPU-bf16 peak estimate is args (real dtypes) + temp/2.
                "peak_hbm_tpu_est": int(mem.argument_size_in_bytes
                                        + mem.temp_size_in_bytes / 2),
                "peak_hbm_cpu": int(mem.argument_size_in_bytes
                                    + mem.output_size_in_bytes
                                    + mem.temp_size_in_bytes
                                    - mem.alias_size_in_bytes),
            },
            "roofline": terms.to_dict(),
        })
        print(f"[dryrun] {arch} {shape} {mesh_name}{tag}: OK "
              f"compile {t_compile:.0f}s bound={terms.bound} "
              f"(c={terms.compute_s*1e3:.1f}ms m={terms.memory_s*1e3:.1f}ms "
              f"coll={terms.collective_s*1e3:.1f}ms) "
              f"peak~{result['memory']['peak_hbm_tpu_est']/2**30:.2f}"
              f"GiB/dev (tpu-est)")
    except Exception as e:  # noqa: BLE001 — record failures as data
        result.update({"ok": False, "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-2000:]})
        print(f"[dryrun] {arch} {shape} {mesh_name}{tag}: FAIL {e}")
    with open(out_path, "w") as f:
        json.dump(result, f, indent=1)
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=ARCH_IDS + [None])
    ap.add_argument("--shape", default=None,
                    choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="both",
                    choices=["pod1", "pod2", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = ARCH_IDS if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) \
        else [args.shape]
    meshes = {"pod1": [False], "pod2": [True],
              "both": [False, True]}[args.mesh]
    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                r = run_cell(arch, shape, mp, force=args.force)
                if r.get("supported") and not r.get("ok", False):
                    n_fail += 1
    print(f"[dryrun] done; {n_fail} failures")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
