"""Serving launcher: batched prefill + continuous-batching decode.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --smoke \
      --requests 8 --prompt-len 16 --max-new 8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.models.transformer import init_params
from repro.serve import Request, RequestBatcher, decode_step, prefill


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--context", type=int, default=64)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    if cfg.is_encoder:
        raise SystemExit(f"{cfg.name} is encoder-only: no decode serving")
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    batcher = RequestBatcher(args.batch_size)
    for uid in range(args.requests):
        batcher.submit(Request(
            uid=uid,
            prompt=rng.integers(0, cfg.vocab_size, args.prompt_len,
                                dtype=np.int32),
            max_new_tokens=args.max_new))

    decode = jax.jit(lambda p, t, c: decode_step(p, cfg, t, c))
    t0 = time.monotonic()
    n_steps = 0
    # slot-parallel serving: prefill each admitted request, merge caches by
    # batch slot, decode all active slots in lockstep (continuous batching)
    caches = [None] * args.batch_size
    while not batcher.idle:
        for slot, req in batcher.admit():
            _, cache = prefill(params, cfg,
                               {"tokens": jnp.asarray(req.prompt)[None]},
                               context=args.context)
            caches[slot] = cache
        active = [i for i, c in enumerate(caches) if c is not None
                  and batcher.slots[i] is not None]
        if not active:
            continue
        toks = np.zeros((args.batch_size,), np.int32)
        for i in active:
            gen = batcher.slots[i].generated
            toks[i] = gen[-1] if gen else batcher.slots[i].prompt[-1]
        nxt = np.full((args.batch_size,), -1, np.int64)
        for i in active:   # per-slot decode (slot caches differ in length)
            logits, caches[i] = decode(params, jnp.asarray([[toks[i]]]),
                                       caches[i])
            nxt[i] = int(jnp.argmax(logits[0, -1]))
            n_steps += 1
        done_before = len(batcher.finished)
        batcher.record_tokens(nxt)
        for i in range(args.batch_size):
            if batcher.slots[i] is None and caches[i] is not None \
                    and len(batcher.finished) > done_before:
                caches[i] = None
    dt = time.monotonic() - t0
    print(f"served {args.requests} requests, {n_steps} decode steps "
          f"in {dt:.2f}s ({n_steps / max(dt, 1e-9):.1f} tok/s)")
    for req in batcher.finished[:4]:
        print(f"  req {req.uid}: {req.generated}")


if __name__ == "__main__":
    main()
