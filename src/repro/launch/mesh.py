"""Production mesh construction.

Axes: ('pod', 'data', 'model'). 'pod' carries only DP whose gradient
all-reduce is the sole cross-pod collective; 'data' is FSDP; 'model' is TP.
A FUNCTION (not a module constant) so importing never touches jax device
state — the dry-run must set XLA_FLAGS before any jax initialization.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)} — the "
            f"dry-run entrypoint must set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            f"any jax import")
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_host_mesh(model: int = 1):
    """Whatever this host has — for examples/tests (usually (1, 1))."""
    n = len(jax.devices())
    data = max(1, n // model)
    return jax.make_mesh((data, model), ("data", "model"),
                         devices=jax.devices()[:data * model])
