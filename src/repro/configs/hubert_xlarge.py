"""hubert-xlarge [audio] — 48L d1280 16H (MHA kv=16) ff5120 V504 (cluster
codes), encoder-only; conv frontend is a STUB: input_specs provides
precomputed frame features (dim 512) [arXiv:2106.07447; unverified]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge", family="audio", n_layers=48, d_model=1280,
    n_heads=16, n_kv_heads=16, d_ff=5120, vocab_size=504, head_dim=80,
    is_encoder=True, frontend_dim=512, remat="full", seq_parallel=True)

SMOKE = CONFIG.with_(
    name="hubert-xlarge-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=128, vocab_size=64, head_dim=16, frontend_dim=16,
    remat="none", param_dtype="float32", compute_dtype="float32")
