"""recurrentgemma-2b [hybrid] — 26L d2560 10H (MQA kv=1, hd=256) ff7680
V256000, RG-LRU + local attn pattern (rec, rec, attn), window 2048
[arXiv:2402.19427; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid", n_layers=26, d_model=2560,
    n_heads=10, n_kv_heads=1, d_ff=7680, vocab_size=256000, head_dim=256,
    block_pattern=("rec", "rec", "attn"), local_window=2048,
    tie_embeddings=True, rope_theta=1e4, scan_layers=True, remat="full",
    seq_parallel=True)   # scan_layers: scans (rec, rec, attn) GROUPS

SMOKE = CONFIG.with_(
    name="recurrentgemma-2b-smoke", n_layers=3, d_model=64, n_heads=4,
    n_kv_heads=1, d_ff=128, vocab_size=512, head_dim=16, local_window=16,
    remat="none", param_dtype="float32", compute_dtype="float32")
