"""qwen3-8b [dense] — 36L d4096 32H (GQA kv=8) ff12288 V151936, qk_norm
[hf:Qwen/Qwen3-8B; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-8b", family="dense", n_layers=36, d_model=4096, n_heads=32,
    n_kv_heads=8, d_ff=12288, vocab_size=151936, head_dim=128, qk_norm=True,
    rope_theta=1e6, remat="full", seq_parallel=True)

SMOKE = CONFIG.with_(
    name="qwen3-8b-smoke", n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
    d_ff=256, vocab_size=512, head_dim=32, remat="none",
    param_dtype="float32", compute_dtype="float32")
