"""grok-1-314b [moe] — 64L d6144 48H (GQA kv=8) ff32768 V131072,
8 experts top-2, full attention [hf:xai-org/grok-1; unverified]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b", family="moe", n_layers=64, d_model=6144, n_heads=48,
    n_kv_heads=8, d_ff=32768, vocab_size=131072, head_dim=128,
    n_experts=8, experts_per_token=2, rope_theta=1e4, remat="full", seq_parallel=True,
    moment_dtype="bfloat16")   # 314B: fp32 moments would not fit v5e HBM

SMOKE = CONFIG.with_(
    name="grok-1-314b-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=160, vocab_size=512, head_dim=16, n_experts=4,
    experts_per_token=2, remat="none",
    capacity_factor=4.0,   # dropless at smoke scale: deterministic tests
    param_dtype="float32", compute_dtype="float32", moment_dtype="float32")
