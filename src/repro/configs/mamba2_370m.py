"""mamba2-370m [ssm] — 48L d1024 attn-free, ssm_state=128, V50280,
SSD (state-space duality) [arXiv:2405.21060; unverified]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m", family="ssm", n_layers=48, d_model=1024, n_heads=1,
    n_kv_heads=1, d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_head_dim=64, ssm_chunk=64, ssm_expand=2,
    tie_embeddings=True, remat="full",
    # 370M params replicate comfortably: pure DP (batch over 'model' too).
    # Measured §Perf: collective term 3.65s -> 94ms (39x) vs TP sharding.
    tensor_parallel=False, seq_parallel=False)

SMOKE = CONFIG.with_(
    name="mamba2-370m-smoke", n_layers=2, d_model=64, vocab_size=512,
    ssm_state=16, ssm_head_dim=16, ssm_chunk=8, remat="none",
    param_dtype="float32", compute_dtype="float32")
