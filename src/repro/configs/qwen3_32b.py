"""qwen3-32b [dense] — 64L d5120 64H (GQA kv=8) ff25600 V151936, qk_norm
[hf:Qwen/Qwen3-32B; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b", family="dense", n_layers=64, d_model=5120, n_heads=64,
    n_kv_heads=8, d_ff=25600, vocab_size=151936, head_dim=128, qk_norm=True,
    rope_theta=1e6, remat="full", seq_parallel=True)

SMOKE = CONFIG.with_(
    name="qwen3-32b-smoke", n_layers=2, d_model=128, n_heads=8, n_kv_heads=2,
    d_ff=320, vocab_size=512, head_dim=16, remat="none",
    param_dtype="float32", compute_dtype="float32")
