"""mixtral-8x7b [moe] — 32L d4096 32H (GQA kv=8) ff14336 V32000,
8 experts top-2, SWA window 4096 [arXiv:2401.04088; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe", n_layers=32, d_model=4096, n_heads=32,
    n_kv_heads=8, d_ff=14336, vocab_size=32000, head_dim=128,
    n_experts=8, experts_per_token=2, sliding_window=4096,
    rope_theta=1e6, remat="full", seq_parallel=True)

SMOKE = CONFIG.with_(
    name="mixtral-8x7b-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=512, head_dim=16, n_experts=4,
    experts_per_token=2, sliding_window=16, remat="none",
    capacity_factor=4.0,   # dropless at smoke scale: deterministic tests
    param_dtype="float32", compute_dtype="float32")
