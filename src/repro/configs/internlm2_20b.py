"""internlm2-20b [dense] — 48L d6144 48H (GQA kv=8) ff16384 V92544
[arXiv:2403.17297; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-20b", family="dense", n_layers=48, d_model=6144,
    n_heads=48, n_kv_heads=8, d_ff=16384, vocab_size=92544, head_dim=128,
    rope_theta=1e6, remat="full", seq_parallel=True)

SMOKE = CONFIG.with_(
    name="internlm2-20b-smoke", n_layers=2, d_model=96, n_heads=6,
    n_kv_heads=2, d_ff=192, vocab_size=512, head_dim=16, remat="none",
    param_dtype="float32", compute_dtype="float32")
