"""internvl2-76b [vlm] — 80L d8192 64H (GQA kv=8) ff28672 V128256 LM
backbone (InternViT frontend is a STUB: input_specs provides precomputed
patch embeddings) [arXiv:2404.16821; unverified]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b", family="vlm", n_layers=80, d_model=8192, n_heads=64,
    n_kv_heads=8, d_ff=28672, vocab_size=128256, head_dim=128,
    vision_tokens=256, rope_theta=5e5, remat="full", seq_parallel=True,
    moment_dtype="bfloat16")

SMOKE = CONFIG.with_(
    name="internvl2-76b-smoke", n_layers=2, d_model=128, n_heads=8,
    n_kv_heads=2, d_ff=256, vocab_size=512, head_dim=16, vision_tokens=8,
    remat="none", param_dtype="float32", compute_dtype="float32",
    moment_dtype="float32")
