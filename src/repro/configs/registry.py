"""Architecture registry + assigned shape cells + input specs.

Shapes (assignment spec):
  train_4k     seq 4,096  x global_batch 256  (training; lowers train_step)
  prefill_32k  seq 32,768 x global_batch 32   (inference prefill)
  decode_32k   seq 32,768 x global_batch 128  (one token, KV ctx = 32k)
  long_500k    seq 524,288 x global_batch 1   (one token, sub-quadratic only)

``cell_supported`` encodes the mandated skips (DESIGN.md §6): decode shapes
are N/A for encoder-only; long_500k is N/A for pure full-attention archs.
"""
from __future__ import annotations

import importlib
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

ARCH_IDS = [
    "qwen3-8b", "internlm2-20b", "minicpm-2b", "qwen3-32b", "mixtral-8x7b",
    "grok-1-314b", "mamba2-370m", "hubert-xlarge", "internvl2-76b",
    "recurrentgemma-2b",
]

_MODULES = {a: "repro.configs." + a.replace("-", "_") for a in ARCH_IDS}
_MODULES["grok-1-314b"] = "repro.configs.grok1_314b"


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(_MODULES[arch])
    return mod.SMOKE if smoke else mod.CONFIG


@dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeCell("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524288, 1),
}


def cell_supported(cfg: ModelConfig, shape: str) -> tuple[bool, str]:
    cell = SHAPES[shape]
    if cfg.is_encoder and cell.kind == "decode":
        return False, "encoder-only: no decode step"
    if shape == "long_500k":
        subq = (cfg.family in ("ssm", "hybrid")) or cfg.sliding_window > 0
        if not subq:
            return False, "pure full attention: 500k decode needs " \
                          "sub-quadratic attention (DESIGN.md §6)"
    return True, ""


def all_cells(smoke: bool = False):
    """Yield (arch, shape, supported, reason) for the full 40-cell table."""
    for arch in ARCH_IDS:
        cfg = get_config(arch, smoke=smoke)
        for shape in SHAPES:
            ok, reason = cell_supported(cfg, shape)
            yield arch, shape, ok, reason


def input_specs(cfg: ModelConfig, shape: str, scaled_batch: int | None = None
                ) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of the step fn.

    For decode cells this is the token batch only — the cache is part of the
    step signature and its specs come from ``serve.init_decode_cache`` via
    ``jax.eval_shape`` (no allocation).
    """
    cell = SHAPES[shape]
    b = scaled_batch or cell.global_batch
    s = cell.seq_len
    i32 = jnp.int32
    cdt = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[
        cfg.compute_dtype]
    if cell.kind in ("train", "prefill"):
        if cfg.family == "audio":
            specs = {"frames": jax.ShapeDtypeStruct(
                (b, s, cfg.frontend_dim), cdt)}
            if cell.kind == "train":
                specs["labels"] = jax.ShapeDtypeStruct((b, s), i32)
            return specs
        if cfg.family == "vlm":
            n_vis = cfg.vision_tokens
            return {
                "tokens": jax.ShapeDtypeStruct((b, s - n_vis), i32),
                "vision": jax.ShapeDtypeStruct((b, n_vis, cfg.d_model), cdt),
            }
        return {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
    # decode: one new token; the KV/state cache carries seq_len context
    return {"tokens": jax.ShapeDtypeStruct((b, 1), i32)}
