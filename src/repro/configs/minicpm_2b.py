"""minicpm-2b [dense] — 40L d2304 36H (MHA kv=36) ff5760 V122753, WSD
schedule, tied embeddings (llama-like arch) [arXiv:2404.06395; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b", family="dense", n_layers=40, d_model=2304, n_heads=36,
    n_kv_heads=36, d_ff=5760, vocab_size=122753, head_dim=64,
    tie_embeddings=True, rope_theta=1e4, remat="full", seq_parallel=True)

# training recipe marker consumed by launch/train.py (MiniCPM's WSD)
LR_SCHEDULE = "wsd"

SMOKE = CONFIG.with_(
    name="minicpm-2b-smoke", n_layers=2, d_model=72, n_heads=6, n_kv_heads=6,
    d_ff=144, vocab_size=512, head_dim=12, remat="none",
    param_dtype="float32", compute_dtype="float32")
