from repro.configs.registry import (ARCH_IDS, get_config, SHAPES,
                                    cell_supported, input_specs, all_cells)

__all__ = ["ARCH_IDS", "get_config", "SHAPES", "cell_supported",
           "input_specs", "all_cells"]
