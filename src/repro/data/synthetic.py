"""Deterministic synthetic data pipelines.

Two consumers:
  * NullaNet experiments (paper §8: MNIST / CIFAR-10 are not available
    offline) -> ``make_binary_classification``: prototype-based binary
    feature vectors with controlled noise; learnable by a small binarized
    MLP, so the NN->FFCL->logic-inference accuracy-parity study is real.
  * LM training (examples + trainer tests) -> ``TokenPipeline``: a
    stateless-seekable token stream (seed, step) -> batch, so restarts and
    elastic re-sharding replay the exact same data (fault-tolerance story).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


def make_binary_classification(n_samples: int, n_features: int,
                               n_classes: int = 10, noise: float = 0.08,
                               seed: int = 0
                               ) -> tuple[np.ndarray, np.ndarray]:
    """Binary {0,1} features from class prototypes with iid bit-flip noise."""
    rng = np.random.default_rng(seed)
    protos = rng.integers(0, 2, size=(n_classes, n_features), dtype=np.int64)
    y = rng.integers(0, n_classes, size=n_samples)
    x = protos[y]
    flips = rng.random((n_samples, n_features)) < noise
    x = np.where(flips, 1 - x, x)
    return x.astype(np.uint8), y.astype(np.int64)


def train_val_split(x: np.ndarray, y: np.ndarray, val_frac: float = 0.25,
                    seed: int = 0
                    ) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Deterministic shuffled split -> (x_train, y_train, x_val, y_val)."""
    if not 0.0 < val_frac < 1.0:
        raise ValueError(f"val_frac must be in (0, 1), got {val_frac}")
    n = len(x)
    perm = np.random.default_rng(seed).permutation(n)
    n_val = max(1, int(round(n * val_frac)))
    tr, va = perm[:-n_val], perm[-n_val:]
    return x[tr], y[tr], x[va], y[va]


@dataclass(frozen=True)
class TokenPipeline:
    """Stateless-seekable synthetic token stream.

    ``batch(step)`` is a pure function of (seed, step, shape) — a restart at
    step k regenerates the identical batch k, and any host can materialize
    just its shard (host-sharded loading at scale: each host slices
    [host_id::n_hosts] of the global batch).
    """

    vocab_size: int
    global_batch: int
    seq_len: int
    seed: int = 0

    def batch(self, step: int, host_id: int = 0, n_hosts: int = 1
              ) -> dict[str, np.ndarray]:
        if self.global_batch % n_hosts:
            raise ValueError("global_batch must divide by n_hosts")
        per_host = self.global_batch // n_hosts
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, host_id]))
        # Markov-ish structure so loss actually decreases during training.
        base = rng.integers(0, self.vocab_size,
                            size=(per_host, self.seq_len), dtype=np.int64)
        shifted = np.roll(base, 1, axis=1)
        mix = rng.random((per_host, self.seq_len)) < 0.5
        tokens = np.where(mix, (shifted * 31 + 7) % self.vocab_size, base)
        return {"tokens": tokens.astype(np.int32)}


def synthetic_tokens(step: int, *, vocab_size: int, global_batch: int,
                     seq_len: int, seed: int = 0) -> np.ndarray:
    return TokenPipeline(vocab_size, global_batch, seq_len,
                         seed).batch(step)["tokens"]
