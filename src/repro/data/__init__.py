from repro.data.synthetic import (make_binary_classification, TokenPipeline,
                                  synthetic_tokens, train_val_split)

__all__ = ["make_binary_classification", "TokenPipeline", "synthetic_tokens",
           "train_val_split"]
