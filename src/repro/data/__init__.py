from repro.data.synthetic import (make_binary_classification, TokenPipeline,
                                  synthetic_tokens)

__all__ = ["make_binary_classification", "TokenPipeline", "synthetic_tokens"]
