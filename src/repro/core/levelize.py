"""Levelization (paper §6.1, eq. 1): l_i = 1 + max_{j in fanin(i)} l_j.

Gates at the same logic level have no connections to each other, so their
operations can execute simultaneously on the compute units. Levelization is
the scheduling skeleton: each level becomes >=1 sub-kernels (scheduler.py).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.gate_ir import LogicGraph


@dataclass(frozen=True)
class Levelization:
    """Per-wire logic levels plus per-level gate lists."""

    levels: np.ndarray          # (n_wires,) int64; consts/inputs are level 0
    depth: int                  # max level over all gates (0 if no gates)
    level_gates: list[np.ndarray]  # level l (1-based) -> gate indices at l

    def gates_at(self, level: int) -> np.ndarray:
        """Gate indices (into graph.gates) at logic level ``level`` (>=1)."""
        return self.level_gates[level - 1]

    def histogram(self) -> np.ndarray:
        """Number of gates per level, shape (depth,)."""
        return np.array([len(g) for g in self.level_gates], dtype=np.int64)


def levelize(graph: LogicGraph) -> Levelization:
    """Single topological pass (graph.gates is already in topo order).

    The level recurrence is inherently sequential, so it runs over plain
    Python ints (no per-gate numpy scalar overhead); the per-level buckets
    are then built with one vectorized sort.
    """
    base = graph.first_gate_wire
    lv: list[int] = [0] * graph.n_wires
    for i, (op, a, b) in enumerate(graph.gates):
        la, lb = lv[a], lv[b]
        lv[base + i] = (la if la >= lb else lb) + 1
    levels = np.asarray(lv, dtype=np.int64)
    depth = int(levels[base:].max()) if graph.n_gates else 0
    gate_levels = levels[base:]
    by_level = np.argsort(gate_levels, kind="stable")
    bounds = np.searchsorted(gate_levels[by_level],
                             np.arange(1, depth + 2))
    level_gates = [by_level[bounds[lev]:bounds[lev + 1]]
                   for lev in range(depth)]
    return Levelization(levels=levels, depth=depth, level_gates=level_gates)
