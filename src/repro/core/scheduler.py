"""Sub-kernel decomposition + memory/opcode assignment (paper §6.1, eq. 23).

Turns a levelized :class:`LogicGraph` into a :class:`LogicProgram` — the
flat address/opcode streams that drive the time-shared compute units.

Scheduling pipeline (DESIGN.md §1): [optimize ->] levelize -> opcode-sort
-> fuse -> address-alloc -> emit (the optional first stage is the
gate-level pass pipeline of core/opt.py, DESIGN.md §7, via the
``optimize=`` knob of :func:`compile_graph`):

  * each logic level with ``n_l`` gates on a fabric with ``n_unit`` units is
    split into ``ceil(n_l / n_unit)`` *sub-kernel steps* (eq. 23);
  * **opcode sorting** (``opcode_sort=True``): gates inside a level are
    stably sorted by opcode before slicing, so most steps are
    *opcode-homogeneous* — every active unit runs the same bitwise op.
    Homogeneous steps carry a per-step ``step_opcode`` scalar and dispatch
    through one specialized slab op in the kernels instead of the 8-way
    chained opcode select (DESIGN.md §1.2);
  * **step fusion** (``fuse_levels=True``): capacity-constrained ASAP list
    scheduling across levels — a gate may join any earlier,
    partially-occupied step as long as every operand was produced at a
    strictly earlier step. This merges (parts of) consecutive levels into
    shared steps and shrinks ``n_steps`` — the ``fori_loop`` trip count and
    the N_subkernel term of eq. 23 — below the eq. 23 value whenever level
    sizes are ragged modulo ``n_unit`` (DESIGN.md §1.3);
  * every wire gets an address in the data buffer; per step, unit ``u`` reads
    ``buf[src_a[s,u]]`` and ``buf[src_b[s,u]]``, applies ``opcode[s,u]``, and
    writes ``buf[dst[s,u]]`` (paper Tables 2/3: Addr. Mem. Buf. holds
    [2 reads + 1 write] per unit, Opcode Buf. one opcode per unit);
  * NOP padding fills partially-occupied steps (paper: "[AND, NOP]"); NOP
    writes target a dedicated trash address so scatters stay unconditional.

Address allocation strategies:
  * ``direct``   — paper-faithful: address == wire id; buffer holds every
    wire (paper §6.3: "total size of the data vector buffer ... is the total
    number of nodes of the DAG").
  * ``liveness`` — beyond-paper: register-allocation-style address reuse.
    A wire's slot is freed after its last reader's step; freed slots become
    reusable the *next* step (within a step, all reads precede all writes,
    but a same-step reuse of a freed slot by another unit's write is still a
    WAR hazard across units only if a reader in the same step uses it — we
    conservatively release at step+1). Cuts the VMEM working set by the
    live-range profile (often 5-20x for deep graphs) which directly shrinks
    the memory roofline term of the logic kernel.
"""
from __future__ import annotations

import itertools
import time
from dataclasses import dataclass

import numpy as np

from repro.core import calibrate as _calibrate
from repro.core.gate_ir import (CONST0, CONST1, LogicGraph, MIXED_DISPATCH,
                                OpCode, apply_op)
from repro.core.levelize import Levelization, levelize
from repro.core.spec import CompileSpec, resolve_spec, _UNSET
from repro.core import packing


@dataclass(frozen=True)
class LogicProgram:
    """Compiled FFCL module: the address/opcode streams + buffer layout."""

    # streams, all (n_steps, n_unit) int32
    src_a: np.ndarray
    src_b: np.ndarray
    dst: np.ndarray
    opcode: np.ndarray
    # per-step dispatch metadata (opcode-homogeneous scheduling)
    step_opcode: np.ndarray     # (n_steps,) int32; the shared opcode where
                                # homogeneous, 0 for mixed steps
    homogeneous: np.ndarray     # (n_steps,) bool; True iff all non-NOP units
                                # in the step run the same opcode
    # buffer layout
    n_addr: int                 # data-buffer rows (incl. consts + trash)
    trash_addr: int
    input_addrs: np.ndarray     # (n_inputs,) address of each primary input
    output_addrs: np.ndarray    # (n_outputs,)
    # provenance / stats
    n_inputs: int
    n_outputs: int
    n_gates: int
    depth: int
    level_of_step: np.ndarray   # (n_steps,) highest logic level in each step
    n_unit: int
    name: str = "ffcl"

    #: array-valued fields, in the canonical serialization order — the
    #: persistence contract of core/artifact_store.py (DESIGN.md §10):
    #: a payload round-trip must reproduce every one byte-identically.
    ARRAY_FIELDS = ("src_a", "src_b", "dst", "opcode", "step_opcode",
                    "homogeneous", "input_addrs", "output_addrs",
                    "level_of_step")
    #: scalar/metadata fields riding in the (JSON) manifest side.
    SCALAR_FIELDS = ("n_addr", "trash_addr", "n_inputs", "n_outputs",
                     "n_gates", "depth", "n_unit", "name")

    @property
    def n_steps(self) -> int:
        return int(self.src_a.shape[0])

    # -- persistence payload (core/artifact_store.py) -----------------------

    def to_payload(self) -> tuple[dict, dict]:
        """``(arrays, scalars)`` split of the program: arrays keep their
        exact dtypes (npz side), scalars are JSON-safe (manifest side).
        Exact inverse of :meth:`from_payload`."""
        arrays = {f: getattr(self, f) for f in self.ARRAY_FIELDS}
        scalars = {f: getattr(self, f) for f in self.SCALAR_FIELDS}
        return arrays, scalars

    @classmethod
    def from_payload(cls, arrays: dict, scalars: dict) -> "LogicProgram":
        """Rebuild a program from :meth:`to_payload` output.  Unknown or
        missing fields raise (``KeyError``/``TypeError``) rather than
        defaulting — a persistence layer must never guess at streams."""
        kw = {f: np.asarray(arrays[f]) for f in cls.ARRAY_FIELDS}
        for f in cls.SCALAR_FIELDS:
            v = scalars[f]
            kw[f] = str(v) if f == "name" else int(v)
        extra = (set(arrays) - set(cls.ARRAY_FIELDS)) | \
            (set(scalars) - set(cls.SCALAR_FIELDS))
        if extra:
            raise TypeError(f"unknown LogicProgram payload fields: "
                            f"{sorted(extra)}")
        return cls(**kw)

    @property
    def n_subkernels(self) -> int:
        """Scheduled step count; == eq. 23 with ``fuse_levels=False``,
        <= eq. 23 with fusion enabled."""
        return self.n_steps

    @property
    def step_branch(self) -> np.ndarray:
        """(n_steps,) dispatch-branch index for the banked kernels:
        the opcode itself for homogeneous steps, :data:`MIXED_DISPATCH`
        (generic 8-way select) for mixed tail steps."""
        return np.where(self.homogeneous, self.step_opcode,
                        MIXED_DISPATCH).astype(np.int32)

    def stats(self) -> dict:
        occupancy = self.n_gates / max(1, self.n_steps * self.n_unit)
        return {
            "name": self.name, "n_gates": self.n_gates, "depth": self.depth,
            "n_steps": self.n_steps, "n_unit": self.n_unit,
            "n_addr": self.n_addr, "occupancy": occupancy,
            "homogeneous_frac": float(self.homogeneous.mean())
            if self.n_steps else 1.0,
        }


def _layout_steps_bulk(graph: LogicGraph, lv: Levelization, n_unit: int,
                       ops_col: np.ndarray, opcode_sort: bool):
    """Eq. 23 layout with zero per-level Python work: one global
    (level, opcode) sort + histogram arithmetic. Used whenever fusion is
    off or provably cannot fire (every level fits in one step)."""
    base = graph.first_gate_wire
    hist = lv.histogram()
    steps_per_level = -(-hist // n_unit)
    cum_steps = np.zeros(lv.depth + 1, dtype=np.int64)
    np.cumsum(steps_per_level, out=cum_steps[1:])
    glevels = lv.levels[base:]
    if opcode_sort:
        order = np.lexsort((ops_col, glevels))
    else:
        order = np.argsort(glevels, kind="stable")
    n_steps = int(cum_steps[-1])
    counts = np.full(n_steps, n_unit, dtype=np.int64)
    if n_steps:
        counts[cum_steps[1:] - 1] = hist - (steps_per_level - 1) * n_unit
    level_tag = np.repeat(np.arange(1, lv.depth + 1, dtype=np.int64),
                          steps_per_level)
    return order, counts, level_tag


def _layout_steps(graph: LogicGraph, lv: Levelization, n_unit: int,
                  ops_col: np.ndarray, a_col: np.ndarray, b_col: np.ndarray,
                  unary_mask: np.ndarray, opcode_sort: bool,
                  fuse_levels: bool):
    """Assign every gate to a (step, unit-slot).

    Returns ``(order, counts, level_tag)`` where ``order`` is the gate
    indices in execution order, ``counts[s]`` the number of gates in step
    ``s`` and ``level_tag[s]`` the highest logic level placed in step ``s``.

    Without fusion this is exactly the eq. 23 layout: each level is sliced
    into ``ceil(n_l / n_unit)`` steps (opcode-sorted when requested). With
    fusion, gates may additionally back-fill spare capacity of any earlier
    step whose index is >= 1 + max(def_step of their operands) — safe
    because within a step all reads precede all writes, and a gate is never
    co-scheduled with a producer of one of its operands.
    """
    base = graph.first_gate_wire
    def_step = np.full(graph.n_wires, -1, dtype=np.int64)
    step_chunks: list[list[np.ndarray]] = []   # step -> gate-index arrays
    occ: list[int] = []                        # step -> occupied unit slots
    level_tag: list[int] = []

    for level in range(1, lv.depth + 1):
        gates = lv.gates_at(level)
        ops_l = ops_col[gates]
        placed = 0
        if fuse_levels:
            ma = def_step[a_col[gates]]
            mb = np.where(unary_mask[gates], np.int64(-1),
                          def_step[b_col[gates]])
            min_step = np.maximum(ma, mb) + 1      # earliest legal step
            keys = (ops_l, min_step) if opcode_sort else (min_step,)
            order_l = np.lexsort(keys)
            gs, ms = gates[order_l], min_step[order_l]
            # back-fill spare capacity of existing steps, earliest first
            s = int(ms[0]) if len(gs) else 0
            while placed < len(gs) and s < len(step_chunks):
                cap = n_unit - occ[s]
                if cap > 0:
                    eligible = int(np.searchsorted(ms, s, side="right"))
                    k = min(cap, eligible - placed)
                    if k > 0:
                        take = gs[placed:placed + k]
                        step_chunks[s].append(take)
                        occ[s] += k
                        def_step[base + take] = s
                        level_tag[s] = level
                        placed += k
                s += 1
            rem = gs[placed:]
            if opcode_sort and len(rem):
                rem = rem[np.argsort(ops_col[rem], kind="stable")]
        elif opcode_sort:
            rem = gates[np.argsort(ops_l, kind="stable")]
        else:
            rem = gates
        # leftover gates open fresh steps at the end (all operands are in
        # earlier steps by construction, so any packing is legal)
        for off in range(0, len(rem), n_unit):
            chunk = rem[off:off + n_unit]
            def_step[base + chunk] = len(step_chunks)
            step_chunks.append([chunk])
            occ.append(len(chunk))
            level_tag.append(level)

    if step_chunks:
        order = np.concatenate(
            [c[0] if len(c) == 1 else np.concatenate(c)
             for c in step_chunks])
    else:
        order = np.zeros(0, dtype=np.int64)
    counts = np.asarray(occ, dtype=np.int64)
    return order, counts, np.asarray(level_tag, dtype=np.int64)


def compile_graph(graph: LogicGraph, spec: CompileSpec | int | None = None,
                  lv: Levelization | None = None, *,
                  n_unit=_UNSET, alloc=_UNSET, opcode_sort=_UNSET,
                  fuse_levels=_UNSET, optimize=_UNSET) -> LogicProgram:
    """Schedule ``graph`` onto the fabric described by ``spec``.

    ``spec`` is the one declarative compilation target
    (:class:`~repro.core.spec.CompileSpec`; canonical defaults when
    omitted).  The scheduling knobs it carries:

      * ``spec.opcode_sort`` groups each level's gates by opcode so
        steps are opcode-homogeneous (one slab op in the kernels);
      * ``spec.fuse_levels`` lets gates back-fill spare unit slots of
        earlier steps, shrinking ``n_steps`` below the eq. 23 count
        (DESIGN.md §1) — ``CompileSpec.paper_exact()`` turns both off;
      * ``spec.optimize`` runs the gate-level pass pipeline
        (core/opt.py) before levelization.  The program's I/O interface
        is unchanged — passes never touch primary inputs or output
        ordering — but ``n_gates``/``n_steps``/``depth`` reflect the
        optimized graph.

    This is the *monolithic* primitive: ``spec.max_gates`` is ignored
    here (budget-aware compilation — partitioning plus the output
    permutation — lives in :class:`~repro.core.compiler.LogicCompiler`),
    and ``spec.n_unit`` must be concrete (``"auto"`` resolution needs
    the facade's cost-model context).

    The loose ``n_unit``/``alloc``/``opcode_sort``/``fuse_levels``/
    ``optimize`` kwargs (and a bare int ``spec``) are the deprecated
    pre-spec convention — they still work, with a ``DeprecationWarning``
    and the canonical defaults for anything unspecified.
    """
    spec = resolve_spec(spec, caller="compile_graph", n_unit=n_unit,
                        alloc=alloc, opcode_sort=opcode_sort,
                        fuse_levels=fuse_levels, optimize=optimize)
    if lv is not None and not isinstance(lv, Levelization):
        # the pre-spec signature took alloc as the 3rd positional; a stale
        # compile_graph(g, 16, "direct") call would otherwise silently
        # bind the string to lv and compile with the wrong allocator
        raise TypeError(
            f"compile_graph's third parameter is a Levelization, got "
            f"{lv!r}; the old positional alloc argument moved onto the "
            f"spec — pass CompileSpec(alloc=...)")
    if not spec.resolved:
        raise ValueError(
            "compile_graph needs a concrete n_unit; resolve "
            "n_unit='auto' through LogicCompiler (core/compiler.py) or "
            "the serving registry first")
    n_unit, alloc = spec.n_unit, spec.alloc
    opcode_sort, fuse_levels = spec.opcode_sort, spec.fuse_levels
    pipeline = spec.pipeline
    if pipeline is not None:
        graph = pipeline.run(graph).graph
        lv = None                      # levelization refers to the old graph
    lv = lv or levelize(graph)
    base = graph.first_gate_wire

    if graph.n_gates:
        # ~5x faster than np.asarray on a large list of tuples
        gates_arr = np.fromiter(
            itertools.chain.from_iterable(graph.gates), dtype=np.int64,
            count=3 * graph.n_gates).reshape(graph.n_gates, 3)
    else:
        gates_arr = np.zeros((0, 3), dtype=np.int64)
    ops_col, a_col, b_col = gates_arr[:, 0], gates_arr[:, 1], gates_arr[:, 2]
    unary_mask = (ops_col == int(OpCode.NOT)) | (ops_col == int(OpCode.COPY))

    # --- step layout (levelize -> opcode-sort -> fuse) ---
    # Back-fill fusion can only fire when some level spans >= 2 steps (a
    # single-step level pins every next-level gate's earliest step past
    # it); otherwise the fully-bulk eq. 23 layout is equivalent and avoids
    # the per-level scheduling loop entirely.
    if not fuse_levels or not graph.n_gates or \
            int(lv.histogram().max()) <= n_unit:
        order, counts, level_tag = _layout_steps_bulk(
            graph, lv, n_unit, ops_col, opcode_sort)
    else:
        order, counts, level_tag = _layout_steps(
            graph, lv, n_unit, ops_col, a_col, b_col, unary_mask,
            opcode_sort, fuse_levels)
    n_steps = len(counts)
    starts = np.zeros(n_steps + 1, dtype=np.int64)
    np.cumsum(counts, out=starts[1:])
    step_idx = np.repeat(np.arange(n_steps, dtype=np.int64), counts)
    pos = np.arange(len(order), dtype=np.int64) - np.repeat(
        starts[:-1], counts)

    # --- step index at which each wire is last read (bulk, no gate loop) ---
    last_read = np.full(graph.n_wires, -1, dtype=np.int64)
    if len(order):
        np.maximum.at(last_read, a_col[order], step_idx)
        binary = ~unary_mask[order]
        np.maximum.at(last_read, b_col[order][binary], step_idx[binary])
    if graph.outputs:
        last_read[np.asarray(graph.outputs, dtype=np.int64)] = n_steps

    # --- address allocation ---
    addr = np.full(graph.n_wires, -1, dtype=np.int64)
    if alloc == "direct":
        addr[:] = np.arange(graph.n_wires)
        trash = graph.n_wires
        n_addr = graph.n_wires + 1
    else:
        addr[CONST0], addr[CONST1] = 0, 1
        addr[2:base] = np.arange(2, base)
        next_fresh = base
        free: list[int] = []
        # release queue: step -> addresses that become free at that step
        release: list[list[int]] = [[] for _ in range(n_steps + 1)]
        pre_lr = last_read[:base]
        for w in np.nonzero((pre_lr >= 0) & (pre_lr < n_steps))[0]:
            release[pre_lr[w] + 1].append(int(addr[w]))
        gate_lr = last_read[base + order].tolist()
        starts_l, assigned = starts.tolist(), []
        for si in range(n_steps):
            if release[si]:
                free.extend(release[si])
            for j in range(starts_l[si], starts_l[si + 1]):
                if free:
                    a = free.pop()
                else:
                    a = next_fresh
                    next_fresh += 1
                assigned.append(a)
                lr = gate_lr[j]
                if 0 <= lr < n_steps:
                    release[lr + 1].append(a)
                elif lr == -1:  # dead gate: reusable immediately next step
                    release[si + 1].append(a)
        if len(order):
            addr[base + order] = np.asarray(assigned, dtype=np.int64)
        trash = next_fresh
        n_addr = next_fresh + 1

    # --- emit streams (bulk scatter, no per-gate/per-unit loop) ---
    src_a = np.zeros((n_steps, n_unit), dtype=np.int32)
    src_b = np.zeros((n_steps, n_unit), dtype=np.int32)
    dst = np.full((n_steps, n_unit), trash, dtype=np.int32)
    opcode = np.zeros((n_steps, n_unit), dtype=np.int32)  # NOP
    if len(order):
        src_a[step_idx, pos] = addr[a_col[order]]
        b_read = np.where(unary_mask[order], np.int64(CONST0), b_col[order])
        src_b[step_idx, pos] = addr[b_read]
        dst[step_idx, pos] = addr[base + order]
        opcode[step_idx, pos] = ops_col[order]

    # --- per-step homogeneity metadata ---
    if n_steps:
        mx = opcode.max(axis=1)
        mn = np.where(opcode == 0, np.int32(127), opcode).min(axis=1)
        # A step is homogeneous only if its opcode-0 lanes are pure padding
        # (dst == trash): a *real* NOP gate must produce 0 on its wire, which
        # the specialized non-NOP slab op would clobber. All-NOP steps are
        # safe either way (the NOP branch writes the correct 0).
        pad_only = ((opcode != 0) | (dst == trash)).all(axis=1)
        homogeneous = (mx == 0) | ((mx == mn) & pad_only)
        step_opcode = np.where(homogeneous, mx, 0).astype(np.int32)
    else:
        homogeneous = np.zeros(0, dtype=bool)
        step_opcode = np.zeros(0, dtype=np.int32)

    return LogicProgram(
        src_a=src_a, src_b=src_b, dst=dst, opcode=opcode,
        step_opcode=step_opcode, homogeneous=homogeneous,
        n_addr=int(n_addr), trash_addr=int(trash),
        input_addrs=addr[2:2 + graph.n_inputs].astype(np.int64),
        output_addrs=addr[np.asarray(graph.outputs, dtype=np.int64)].astype(
            np.int64) if graph.outputs else np.zeros(0, np.int64),
        n_inputs=graph.n_inputs, n_outputs=graph.n_outputs,
        n_gates=graph.n_gates, depth=lv.depth,
        level_of_step=level_tag,
        n_unit=n_unit, name=graph.name,
    )


@dataclass(frozen=True)
class MegaProgram:
    """A whole program *pipeline* flattened for single-launch execution.

    The per-stage :class:`LogicProgram` streams are concatenated along the
    step axis (lanes padded to the widest stage's ``n_unit`` with NOPs
    writing that stage's own trash row), with a static per-stage offset
    table (``stage_meta``) into the shared scratch buffer sized by the
    *maximum* ``n_addr`` across stages.  The megakernel
    (kernels/logic_dsp/kernel.py) walks the table inside ONE
    ``pallas_call``:

      * ``mode="chain"``    — stage *k*'s output-addrs gather feeds stage
        *k+1*'s input slice without leaving the kernel (the classifier's
        per-layer launch chain fused; paper §5.2's cascaded DSP stages);
      * ``mode="parallel"`` — every stage reads the same primary-input
        slab (a partitioned pipeline); the per-stage output slabs are
        concatenated and permuted back to the original output order by
        ``output_perm`` in-kernel.

    Each stage re-initializes the buffer (zeros, const-1 row, inputs at
    rows 2..) because the liveness allocator may have released const or
    input rows for reuse as gate destinations — stale rows from stage
    *k* must never be observable to stage *k+1*'s address space.
    """

    mode: str                        # "chain" | "parallel"
    stages: tuple                    # the source LogicPrograms, in order
    # concatenated streams, (total_steps, n_unit) int32
    src_a: np.ndarray
    src_b: np.ndarray
    dst: np.ndarray
    opcode: np.ndarray
    step_branch: np.ndarray          # (total_steps,) int32 dispatch branch
    step_trash: np.ndarray           # (total_steps,) int32 owning stage's
    #                                  trash row (lane-padding fill value)
    out_addrs: np.ndarray            # (sum stage n_outputs,) int64
    output_perm: np.ndarray          # (n_outputs,) int64; identity for chain
    #: static per-stage offset table — one (step_lo, step_hi, n_inputs,
    #: n_outputs, out_lo) tuple per stage; hashable, closed over by the
    #: kernel as trace-time constants.
    stage_meta: tuple
    n_addr: int                      # max over stages (scratch sizing rule)
    n_unit: int                      # max over stages (lane-padded width)
    n_inputs: int
    n_outputs: int
    name: str = "mega"

    @property
    def total_steps(self) -> int:
        return int(self.src_a.shape[0])

    @property
    def n_stages(self) -> int:
        return len(self.stages)


def build_megaprogram(programs, mode: str = "chain",
                      output_perm: np.ndarray | None = None,
                      name: str | None = None) -> MegaProgram:
    """Flatten a program pipeline into one :class:`MegaProgram`.

    ``mode="chain"`` requires ``programs[k].n_outputs ==
    programs[k+1].n_inputs`` (the packed-handoff width contract);
    ``mode="parallel"`` requires every stage to share the primary-input
    width and takes the partition ``output_perm`` (identity = plain
    concatenation order).
    """
    programs = tuple(programs)
    if not programs:
        raise ValueError("build_megaprogram needs at least one stage")
    if mode not in ("chain", "parallel"):
        raise ValueError(f"unknown mega mode {mode!r}")
    if mode == "chain":
        if output_perm is not None:
            raise ValueError("chain mode has no output permutation: the "
                             "last stage's outputs ARE the pipeline's")
        for k in range(len(programs) - 1):
            if programs[k].n_outputs != programs[k + 1].n_inputs:
                raise ValueError(
                    f"stage width mismatch: stage {k} produces "
                    f"{programs[k].n_outputs} outputs, stage {k + 1} "
                    f"expects {programs[k + 1].n_inputs} inputs")
        n_inputs = programs[0].n_inputs
        n_outputs = programs[-1].n_outputs
        perm = np.arange(n_outputs, dtype=np.int64)
    else:
        n_inputs = programs[0].n_inputs
        for p in programs[1:]:
            if p.n_inputs != n_inputs:
                raise ValueError(
                    "parallel stages must share the primary-input width")
        n_outputs = sum(p.n_outputs for p in programs)
        perm = (np.arange(n_outputs, dtype=np.int64) if output_perm is None
                else np.asarray(output_perm, dtype=np.int64))
        if perm.shape != (n_outputs,) or \
                not np.array_equal(np.sort(perm), np.arange(n_outputs)):
            raise ValueError("output_perm must be a permutation of "
                             f"range({n_outputs})")
    n_unit = max(p.n_unit for p in programs)
    n_addr = max(p.n_addr for p in programs)

    # Trash-row isolation (DESIGN.md §13): every padding lane and
    # step_trash entry below points at the owning stage's trash row,
    # and the megakernel re-initializes rows 0..1+n_inputs (consts +
    # input slice) at every stage boundary.  A trash row aliasing one
    # of those preload rows would let NOP padding clobber a live
    # const/input mid-stage.  Both allocators only ever hand out fresh
    # rows past the preload region, so a violation here means the
    # program came from an untrusted payload (LogicProgram.from_payload
    # does not validate semantics) — refuse loudly rather than fuse a
    # schedule the static verifier would reject.
    for k, p in enumerate(programs):
        if not (2 + p.n_inputs <= p.trash_addr < p.n_addr):
            raise ValueError(
                f"stage {k} ({p.name!r}): trash_addr {p.trash_addr} "
                f"aliases a const/input row (or exceeds n_addr "
                f"{p.n_addr}); refusing to build a megaprogram whose "
                "padding lanes would clobber live preload rows")

    streams = {"src_a": [], "src_b": [], "dst": [], "opcode": []}
    branch, trash, out_addrs, meta = [], [], [], []
    step_lo = out_lo = 0
    for p in programs:
        pad = n_unit - p.n_unit

        def padded(a, fill):
            a = np.asarray(a, dtype=np.int32)
            if pad:
                a = np.pad(a, ((0, 0), (0, pad)), constant_values=fill)
            return a

        streams["src_a"].append(padded(p.src_a, 0))
        streams["src_b"].append(padded(p.src_b, 0))
        streams["dst"].append(padded(p.dst, p.trash_addr))
        streams["opcode"].append(padded(p.opcode, 0))
        branch.append(p.step_branch)
        trash.append(np.full(p.n_steps, p.trash_addr, dtype=np.int32))
        out_addrs.append(np.asarray(p.output_addrs, dtype=np.int64))
        meta.append((step_lo, step_lo + p.n_steps,
                     p.n_inputs, p.n_outputs, out_lo))
        step_lo += p.n_steps
        out_lo += p.n_outputs

    def cat(chunks, width=None):
        if width is None:
            return np.concatenate(chunks) if chunks else \
                np.zeros(0, dtype=np.int32)
        return np.concatenate(chunks, axis=0) if chunks else \
            np.zeros((0, width), dtype=np.int32)

    return MegaProgram(
        mode=mode, stages=programs,
        src_a=cat(streams["src_a"], n_unit),
        src_b=cat(streams["src_b"], n_unit),
        dst=cat(streams["dst"], n_unit),
        opcode=cat(streams["opcode"], n_unit),
        step_branch=cat(branch).astype(np.int32),
        step_trash=cat(trash).astype(np.int32),
        out_addrs=cat(out_addrs).astype(np.int64),
        output_perm=perm, stage_meta=tuple(meta),
        n_addr=int(n_addr), n_unit=int(n_unit),
        n_inputs=int(n_inputs), n_outputs=int(n_outputs),
        name=name or "+".join(p.name for p in programs))


def execute_megaprogram_np(mega: MegaProgram, inputs: np.ndarray
                           ) -> np.ndarray:
    """Numpy oracle for mega execution — the chained / re-assembled
    :func:`execute_program_np` the fused kernel must match bit-for-bit."""
    inputs = np.asarray(inputs)
    if mega.mode == "chain":
        h = inputs
        for p in mega.stages:
            h = execute_program_np(p, h)
        return h
    outs = [execute_program_np(p, inputs) for p in mega.stages]
    cat = outs[0] if len(outs) == 1 else np.concatenate(outs, axis=1)
    return cat[:, mega.output_perm]


def execute_program_np(prog: LogicProgram, inputs: np.ndarray) -> np.ndarray:
    """Numpy oracle for program execution on a boolean batch.

    This is the semantic contract the Pallas kernel (kernels/logic_dsp) and
    the jnp reference (kernels/logic_dsp/ref.py) are tested against, and it
    itself is tested against direct ``LogicGraph.evaluate``. Homogeneous
    steps apply one bulk op to the whole (n_unit, W) slab; only mixed tail
    steps fall back to per-opcode masking (never a per-unit Python loop).

    While a :class:`~repro.core.calibrate.PhaseTimer` is active, the run
    records its pack / setup (buffer init + input scatter) / kernel
    (step loop) / unpack split on the timer (``backend="numpy"``) — the
    same phase shape the jitted path reports, so the calibration
    tooling can compare backends.  Disabled, the check is one module
    attribute read.
    """
    timer = _calibrate._ACTIVE
    t = time.perf_counter
    t0 = t()
    inputs = np.asarray(inputs)
    batch = inputs.shape[0]
    words = packing.pack_bits(inputs.astype(np.uint8))       # (n_inputs, W)
    t1 = t()
    w = words.shape[1]
    buf = np.zeros((prog.n_addr, w), dtype=np.int32)
    buf[1] = -1  # const-1 row = all ones
    buf[prog.input_addrs] = words
    branch = prog.step_branch
    t2 = t()
    for s in range(prog.n_steps):
        a = buf[prog.src_a[s]]
        b = buf[prog.src_b[s]]
        br = int(branch[s])
        if br < MIXED_DISPATCH:                  # homogeneous: one slab op
            res = apply_op(br, a, b)
        else:                                    # mixed tail step
            ops_row = prog.opcode[s]
            res = np.zeros_like(a)
            for oc in np.unique(ops_row):
                lanes = ops_row == oc
                res[lanes] = apply_op(int(oc), a[lanes], b[lanes])
        buf[prog.dst[s]] = res
    t3 = t()
    out_words = buf[prog.output_addrs]
    out = packing.unpack_bits(out_words, batch)
    if timer is not None:
        timer.record({"pack": t1 - t0, "setup": t2 - t1, "kernel": t3 - t2,
                      "unpack": t() - t3},
                     backend="numpy", n_unit=prog.n_unit, batch=batch)
    return out
