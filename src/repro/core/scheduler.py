"""Sub-kernel decomposition + memory/opcode assignment (paper §6.1, eq. 23).

Turns a levelized :class:`LogicGraph` into a :class:`LogicProgram` — the
flat address/opcode streams that drive the time-shared compute units:

  * each logic level with ``n_l`` gates on a fabric with ``n_unit`` units is
    split into ``ceil(n_l / n_unit)`` *sub-kernel steps* (eq. 23);
  * every wire gets an address in the data buffer; per step, unit ``u`` reads
    ``buf[src_a[s,u]]`` and ``buf[src_b[s,u]]``, applies ``opcode[s,u]``, and
    writes ``buf[dst[s,u]]`` (paper Tables 2/3: Addr. Mem. Buf. holds
    [2 reads + 1 write] per unit, Opcode Buf. one opcode per unit);
  * NOP padding fills partially-occupied steps (paper: "[AND, NOP]"); NOP
    writes target a dedicated trash address so scatters stay unconditional.

Address allocation strategies:
  * ``direct``   — paper-faithful: address == wire id; buffer holds every
    wire (paper §6.3: "total size of the data vector buffer ... is the total
    number of nodes of the DAG").
  * ``liveness`` — beyond-paper: register-allocation-style address reuse.
    A wire's slot is freed after its last reader's step; freed slots become
    reusable the *next* step (within a step, all reads precede all writes,
    but a same-step reuse of a freed slot by another unit's write is still a
    WAR hazard across units only if a reader in the same step uses it — we
    conservatively release at step+1). Cuts the VMEM working set by the
    live-range profile (often 5-20x for deep graphs) which directly shrinks
    the memory roofline term of the logic kernel.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.gate_ir import CONST0, CONST1, LogicGraph, OpCode, UNARY, apply_op
from repro.core.levelize import Levelization, levelize
from repro.core import packing


@dataclass(frozen=True)
class LogicProgram:
    """Compiled FFCL module: the address/opcode streams + buffer layout."""

    # streams, all (n_steps, n_unit) int32
    src_a: np.ndarray
    src_b: np.ndarray
    dst: np.ndarray
    opcode: np.ndarray
    # buffer layout
    n_addr: int                 # data-buffer rows (incl. consts + trash)
    trash_addr: int
    input_addrs: np.ndarray     # (n_inputs,) address of each primary input
    output_addrs: np.ndarray    # (n_outputs,)
    # provenance / stats
    n_inputs: int
    n_outputs: int
    n_gates: int
    depth: int
    level_of_step: np.ndarray   # (n_steps,) which logic level each step serves
    n_unit: int
    name: str = "ffcl"

    @property
    def n_steps(self) -> int:
        return int(self.src_a.shape[0])

    @property
    def n_subkernels(self) -> int:
        """Paper eq. 23: sum over levels of ceil(gates_in_level / n_unit)."""
        return self.n_steps

    def stats(self) -> dict:
        occupancy = self.n_gates / max(1, self.n_steps * self.n_unit)
        return {
            "name": self.name, "n_gates": self.n_gates, "depth": self.depth,
            "n_steps": self.n_steps, "n_unit": self.n_unit,
            "n_addr": self.n_addr, "occupancy": occupancy,
        }


def compile_graph(graph: LogicGraph, n_unit: int,
                  alloc: str = "direct",
                  lv: Levelization | None = None) -> LogicProgram:
    """Schedule ``graph`` onto ``n_unit`` time-shared compute units."""
    if n_unit < 1:
        raise ValueError("n_unit must be >= 1")
    if alloc not in ("direct", "liveness"):
        raise ValueError(f"unknown alloc strategy {alloc!r}")
    lv = lv or levelize(graph)
    base = graph.first_gate_wire

    # --- step layout: level -> ceil(n_l/n_unit) steps (eq. 23) ---
    steps: list[np.ndarray] = []          # gate indices per step
    level_of_step: list[int] = []
    for level in range(1, lv.depth + 1):
        gates = lv.gates_at(level)
        for s in range(0, len(gates), n_unit):
            steps.append(gates[s:s + n_unit])
            level_of_step.append(level)
    n_steps = len(steps)

    # --- step index at which each wire is defined / last read ---
    def_step = np.full(graph.n_wires, -1, dtype=np.int64)   # -1: input/const
    for si, gs in enumerate(steps):
        for gi in gs:
            def_step[base + gi] = si
    last_read = np.full(graph.n_wires, -1, dtype=np.int64)
    for si, gs in enumerate(steps):
        for gi in gs:
            op, a, b = graph.gates[gi]
            last_read[a] = max(last_read[a], si)
            if OpCode(op) not in UNARY:
                last_read[b] = max(last_read[b], si)
    for o in graph.outputs:
        last_read[o] = n_steps  # outputs live to the end

    # --- address allocation ---
    addr = np.full(graph.n_wires, -1, dtype=np.int64)
    if alloc == "direct":
        addr[:] = np.arange(graph.n_wires)
        trash = graph.n_wires
        n_addr = graph.n_wires + 1
    else:
        addr[CONST0], addr[CONST1] = 0, 1
        for i in range(graph.n_inputs):
            addr[2 + i] = 2 + i
        next_fresh = 2 + graph.n_inputs
        free: list[int] = []
        # release queue: step -> addresses that become free at that step
        release: list[list[int]] = [[] for _ in range(n_steps + 1)]
        for w in range(graph.n_wires):
            lr = last_read[w]
            if lr >= 0 and lr < n_steps and addr[w] >= 0:
                release[lr + 1].append(int(addr[w]))
        for si, gs in enumerate(steps):
            free.extend(release[si])
            for gi in gs:
                w = base + gi
                if free:
                    addr[w] = free.pop()
                else:
                    addr[w] = next_fresh
                    next_fresh += 1
                lr = last_read[w]
                if 0 <= lr < n_steps:
                    release[lr + 1].append(int(addr[w]))
                elif lr == -1:  # dead gate: reusable immediately next step
                    release[si + 1].append(int(addr[w]))
        trash = next_fresh
        n_addr = next_fresh + 1

    # --- emit streams ---
    src_a = np.zeros((n_steps, n_unit), dtype=np.int32)
    src_b = np.zeros((n_steps, n_unit), dtype=np.int32)
    dst = np.full((n_steps, n_unit), trash, dtype=np.int32)
    opcode = np.zeros((n_steps, n_unit), dtype=np.int32)  # NOP
    for si, gs in enumerate(steps):
        for u, gi in enumerate(gs):
            op, a, b = graph.gates[gi]
            src_a[si, u] = addr[a]
            src_b[si, u] = addr[b] if OpCode(op) not in UNARY else addr[CONST0]
            dst[si, u] = addr[base + gi]
            opcode[si, u] = op

    return LogicProgram(
        src_a=src_a, src_b=src_b, dst=dst, opcode=opcode,
        n_addr=int(n_addr), trash_addr=int(trash),
        input_addrs=addr[2:2 + graph.n_inputs].astype(np.int64),
        output_addrs=addr[np.asarray(graph.outputs, dtype=np.int64)].astype(
            np.int64) if graph.outputs else np.zeros(0, np.int64),
        n_inputs=graph.n_inputs, n_outputs=graph.n_outputs,
        n_gates=graph.n_gates, depth=lv.depth,
        level_of_step=np.asarray(level_of_step, dtype=np.int64),
        n_unit=n_unit, name=graph.name,
    )


def execute_program_np(prog: LogicProgram, inputs: np.ndarray) -> np.ndarray:
    """Numpy oracle for program execution on a boolean batch.

    This is the semantic contract the Pallas kernel (kernels/logic_dsp) and
    the jnp reference (kernels/logic_dsp/ref.py) are tested against, and it
    itself is tested against direct ``LogicGraph.evaluate``.
    """
    inputs = np.asarray(inputs)
    batch = inputs.shape[0]
    words = packing.pack_bits(inputs.astype(np.uint8))       # (n_inputs, W)
    w = words.shape[1]
    buf = np.zeros((prog.n_addr, w), dtype=np.int32)
    buf[1] = -1  # const-1 row = all ones
    buf[prog.input_addrs] = words
    for s in range(prog.n_steps):
        a = buf[prog.src_a[s]].astype(np.int64)
        b = buf[prog.src_b[s]].astype(np.int64)
        res = np.zeros_like(a)
        for u in range(prog.n_unit):
            res[u] = apply_op(int(prog.opcode[s, u]), a[u], b[u])
        buf[prog.dst[s]] = res.astype(np.int32)
    out_words = buf[prog.output_addrs]
    return packing.unpack_bits(out_words, batch)
