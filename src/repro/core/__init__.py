# The paper's primary contribution — implement the SYSTEM here
# (scheduler, optimizer, data path, serving loop, etc.) in the
# host framework. Add sibling subpackages for substrates.
#
# The declarative compile API (DESIGN.md §8) is re-exported at the
# package root: one CompileSpec value describes the full compilation
# target, and LogicCompiler is the one facade that turns (graph, spec)
# into a CompiledArtifact.
from repro.core.artifact_store import (ArtifactStore, FORMAT_VERSION,
                                       alias_key, store_key)
from repro.core.compiler import CompiledArtifact, LogicCompiler
from repro.core.errors import (ArtifactIntegrityError, CompileError,
                               PermanentCompileError, TransientCompileError,
                               is_transient)
from repro.core.spec import CompileSpec

__all__ = ["CompileSpec", "CompiledArtifact", "LogicCompiler",
           "ArtifactStore", "ArtifactIntegrityError", "FORMAT_VERSION",
           "store_key", "alias_key",
           "CompileError", "TransientCompileError",
           "PermanentCompileError", "is_transient"]
