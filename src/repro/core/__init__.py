# The paper's primary contribution — implement the SYSTEM here
# (scheduler, optimizer, data path, serving loop, etc.) in the
# host framework. Add sibling subpackages for substrates.
#
# The declarative compile API (DESIGN.md §8) is re-exported at the
# package root: one CompileSpec value describes the full compilation
# target, and LogicCompiler is the one facade that turns (graph, spec)
# into a CompiledArtifact.
from repro.core.compiler import CompiledArtifact, LogicCompiler
from repro.core.errors import (CompileError, PermanentCompileError,
                               TransientCompileError, is_transient)
from repro.core.spec import CompileSpec

__all__ = ["CompileSpec", "CompiledArtifact", "LogicCompiler",
           "CompileError", "TransientCompileError",
           "PermanentCompileError", "is_transient"]
