"""The unified compile facade: ``LogicCompiler.compile(graph, spec)``.

Before the :class:`~repro.core.spec.CompileSpec` redesign the repo had
three divergent compile paths — direct ``scheduler.compile_graph``,
``partition`` + ``compile_partitions`` for over-budget graphs, and the
serving registry's private ``ProgramCache`` miss path — each re-threading
the same loose kwargs and re-implementing the optimize/partition/permute
bookkeeping.  :class:`LogicCompiler` is the one place that sequence
lives:

    optimize (core/opt.py pipeline)
      -> resolve n_unit="auto" (optimizer.binary_search on the
         post-optimization eq. 23 stats — the paper's §7.2 design-space
         search as a spec value)
        -> partition if the budget binds (core/partition.py, with
           per-cluster re-optimization)
          -> schedule each program (core/scheduler.py)
            -> output permutation for word-level re-assembly

and :class:`CompiledArtifact` is the one result type: the resolved spec,
the post-optimization graph, the program pipeline, the output
permutation, and the compile/DSE provenance.  ``serve.ProgramCache``
compiles through this facade (keying entries on
``spec.cache_key()``); direct callers get the same artifact without a
cache.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from repro.core.calibrate import (Calibration, CalibrationError,
                                  WallClockModel)
from repro.core.cost_model import CostModel, FfclStats, LayerLoad
from repro.core.gate_ir import LogicGraph
from repro.core.optimizer import SearchResult, binary_search
from repro.core.partition import (compile_partitions, output_permutation,
                                  partition)
from repro.core.scheduler import LogicProgram, compile_graph
from repro.core.spec import CompileSpec
from repro.core.verify import (ScheduleVerificationError, certify_remap,
                               effective_mode, verify_artifact)


@dataclass(frozen=True)
class CompiledArtifact:
    """Everything one compilation of (graph, spec) produced.

    ``spec`` is the *resolved* target — ``n_unit`` is always concrete
    (``"auto"`` requests record the ``binary_search`` pick, with the
    probe trail in ``search``) — so ``spec.cache_key()`` plus
    ``graph.fingerprint()`` names this artifact exactly, and
    ``spec.to_dict()`` is what benchmarks/reports persist.
    """

    spec: CompileSpec                      # resolved (concrete n_unit)
    graph: LogicGraph                      # post-optimization graph
    programs: tuple[LogicProgram, ...]     # 1 = monolithic, >1 = pipeline
    output_perm: np.ndarray                # concat(part outs)[perm] == orig
    compile_s: float = 0.0
    search: SearchResult | None = field(default=None, compare=False)
    #: how a multi-program artifact composes: ``"parallel"`` (partition
    #: pipeline — every program reads the primary inputs, outputs
    #: re-assembled through ``output_perm``) or ``"chain"`` (layer stack —
    #: program k's outputs feed program k+1; perm is identity).
    mode: str = "parallel"

    @property
    def partitioned(self) -> bool:
        return len(self.programs) > 1

    @property
    def program(self) -> LogicProgram:
        """The single program of a monolithic artifact."""
        if self.partitioned:
            raise ValueError(
                f"artifact is a {len(self.programs)}-program pipeline; "
                "iterate .programs")
        return self.programs[0]

    @property
    def n_inputs(self) -> int:
        return self.graph.n_inputs

    @property
    def n_outputs(self) -> int:
        return self.graph.n_outputs

    def device_arrays(self) -> list[dict]:
        """Per-program device arrays (memoized per program object by the
        kernel layer; imported lazily so core stays importable without
        jax)."""
        from repro.kernels.logic_dsp.ops import program_arrays
        return [program_arrays(p) for p in self.programs]

    def megaprogram(self):
        """The artifact's whole pipeline flattened into one
        :class:`~repro.core.scheduler.MegaProgram` for single-launch
        execution (memoized on the artifact; the engine's runner path).
        Partitioned artifacts fuse with the output permutation applied
        in-kernel; chain artifacts fuse stage-to-stage handoff."""
        mega = getattr(self, "_megaprogram", None)
        if mega is None:
            from repro.core.partition import mega_pipeline
            mega = mega_pipeline(self.programs, self.output_perm,
                                 mode=self.mode, name=self.graph.name)
            object.__setattr__(self, "_megaprogram", mega)
        return mega

    def execute(self, inputs: np.ndarray) -> np.ndarray:
        """Numpy-oracle execution of the whole artifact (every program
        over the same input slab, re-assembled in original output
        order — or chained stage-to-stage for ``mode="chain"``) — the
        semantic contract the kernel/serving paths are tested against."""
        from repro.core.scheduler import execute_program_np
        if self.mode == "chain":
            h = np.asarray(inputs)
            for p in self.programs:
                h = execute_program_np(p, h)
            return h
        outs = [execute_program_np(p, inputs) for p in self.programs]
        cat = outs[0] if len(outs) == 1 else np.concatenate(outs, axis=1)
        return cat[:, self.output_perm]

    def stats(self) -> dict:
        per_prog = [p.stats() for p in self.programs]
        search = {}
        if self.search is not None:
            search = {"search_probes": len(self.search.evaluations),
                      "search_objective": self.search.objective}
            if self.search.alt is not None:
                # the other objective's pick, for DSE provenance
                search["alt_objective"] = self.search.alt.objective
                search["alt_n_unit"] = self.search.alt.best_n_unit
        return {
            "spec": self.spec.to_dict(),
            "n_programs": len(self.programs),
            "n_gates": sum(s["n_gates"] for s in per_prog),
            "n_steps": sum(s["n_steps"] for s in per_prog),
            "depth": max((s["depth"] for s in per_prog), default=0),
            "compile_s": self.compile_s,
            **search,
        }


class LogicCompiler:
    """Compile :class:`LogicGraph` s against declarative
    :class:`CompileSpec` targets.

    The constructor holds only the *design-space context* for
    ``n_unit="auto"`` resolution — the cost model and search bounds of
    the paper's §7.2 binary search, plus the SIMD batch the latency
    model assumes.  Everything about one compilation lives on the spec.
    """

    def __init__(self, model: CostModel | None = None,
                 n_unit_max: int = 4096, n_unit_min: int = 1,
                 n_input_vectors: int = 1024, fault_hook=None,
                 calibration: Calibration | None = None,
                 verify: str | None = None):
        self.model = model or CostModel()
        self.n_unit_max = n_unit_max
        self.n_unit_min = n_unit_min
        self.n_input_vectors = n_input_vectors
        # Compiler-level static-verification default (core/verify.py):
        # applied when a spec does not opt in itself (spec.verify="off").
        # "compile"/"full" prove every artifact this facade emits and
        # raise ScheduleVerificationError on any Diagnostic.
        if verify not in (None, "off", "compile", "load", "full"):
            raise ValueError(f"unknown verify mode {verify!r}")
        self.verify = verify
        # Fitted per-phase wall-clock calibration (core/calibrate.py).
        # Required for specs with objective="wallclock"; when present,
        # cycles-objective resolutions also record the wallclock pick in
        # the search provenance (SearchResult.alt) and vice versa.
        self.calibration = calibration
        # Optional ``hook(graph, spec)`` called at the top of every
        # :meth:`compile` — the seam fault injection uses to raise a
        # :class:`~repro.core.errors.TransientCompileError` with seeded
        # determinism (serve.frontdoor.FaultPolicy) so retry paths are
        # testable.  ``None`` (default) costs one attribute check.
        self.fault_hook = fault_hook

    # -- n_unit="auto" ------------------------------------------------------

    def resolve(self, graph: LogicGraph, spec: CompileSpec, *,
                assume_optimized: bool = False
                ) -> tuple[CompileSpec, SearchResult | None]:
        """Resolve ``n_unit="auto"`` to the ``binary_search`` Pareto
        pick for ``graph`` (a no-op for concrete specs).

        The search probes the POST-optimization eq. 23 stats
        (``FfclStats.from_graph(optimized=spec)``) — the gate counts the
        scheduler will actually emit.  ``assume_optimized=True`` skips
        re-running the pipeline when ``graph`` already reflects
        ``spec.optimize`` (e.g. the serving registry's memoized
        optimized graph).

        ``spec.objective`` picks the search objective: ``"cycles"``
        descends the modelled eq. 22 cycles (the default, and identical
        to the pre-knob behavior); ``"wallclock"`` descends the
        calibrated per-phase seconds model and requires this compiler to
        carry a fitted ``Calibration`` — without one it raises
        :class:`~repro.core.calibrate.CalibrationError` (callers fall
        back to ``objective="cycles"`` explicitly; the serving registry
        does so with a warning).  When a calibration is present, BOTH
        objectives' picks are resolved and the non-chosen one is
        recorded as ``search.alt`` — the DSE provenance shows what the
        other objective would have picked.
        """
        if spec.resolved:
            return spec, None
        stats = FfclStats.from_graph(
            graph, optimized=False if assume_optimized else spec)
        layers = [LayerLoad(stats, 1, self.n_input_vectors)]
        bounds = dict(n_unit_max=self.n_unit_max, n_unit_min=self.n_unit_min)
        if spec.objective == "wallclock":
            if self.calibration is None:
                raise CalibrationError(
                    "spec requests objective='wallclock' but this "
                    "LogicCompiler has no calibration; fit one "
                    "(core/calibrate.py, tools/calibrate.py) or use "
                    "objective='cycles'")
            wc = WallClockModel(self.calibration, self.model)
            search = binary_search(wc, layers, objective="wallclock",
                                   **bounds)
            search.alt = binary_search(self.model, layers, **bounds)
        else:
            search = binary_search(self.model, layers, **bounds)
            if self.calibration is not None:
                wc = WallClockModel(self.calibration, self.model)
                search.alt = binary_search(wc, layers,
                                           objective="wallclock", **bounds)
        return spec.with_(n_unit=search.best_n_unit), search

    # -- the one compile path -----------------------------------------------

    def compile(self, graph: LogicGraph, spec: CompileSpec | None = None, *,
                assume_optimized: bool = False) -> CompiledArtifact:
        """Compile ``graph`` to a :class:`CompiledArtifact` per ``spec``
        (canonical defaults when omitted).

        Unifies the three historical paths: the optimize stage runs
        once up front (unless ``assume_optimized``), ``"auto"`` unit
        counts resolve via :meth:`resolve`, a binding ``max_gates``
        budget routes through output-cone partitioning with per-cluster
        re-optimization, and partition sub-programs are scheduled with
        the optimize stage stripped (their cones are already optimized
        — re-running the pipeline would be pure waste).
        """
        spec = spec if spec is not None else CompileSpec()
        if self.fault_hook is not None:
            self.fault_hook(graph, spec)
        t0 = time.perf_counter()
        verifying = effective_mode(spec.verify, self.verify) in (
            "compile", "full")
        pipeline = spec.pipeline
        if assume_optimized or pipeline is None:
            g = graph
        elif verifying:
            # keep the composed wire remap so the pass pipeline's own
            # certificate (total, in-range output map — V115) is proven
            # alongside the schedule; certify=True additionally checks
            # each individual pass so a broken rewrite names its pass
            opt = pipeline.run(graph, certify=True)
            remap_diags = certify_remap(graph, opt.graph, opt.remap,
                                        label=f"pipeline({graph.name})")
            if remap_diags:
                from repro.core.verify import VerifyReport
                raise ScheduleVerificationError(VerifyReport(
                    target=graph.name, diagnostics=tuple(remap_diags)))
            g = opt.graph
        else:
            g = pipeline.run(graph).graph
        spec, search = self.resolve(g, spec, assume_optimized=True)
        mono = spec.with_(optimize="none", max_gates=None)
        parts = None
        if spec.max_gates is not None and g.n_gates > spec.max_gates:
            # per-cluster re-optimization: extraction re-exposes slack
            # inside duplicated cones that global passes could not see
            parts = partition(g, spec)
            programs = tuple(compile_partitions(parts, mono))
            perm = output_permutation(parts, g.n_outputs)
        else:
            programs = (compile_graph(g, mono),)
            perm = np.arange(g.n_outputs, dtype=np.int64)
        artifact = CompiledArtifact(
            spec=spec, graph=g, programs=programs, output_perm=perm,
            compile_s=time.perf_counter() - t0, search=search)
        if verifying:
            # a fresh artifact failing its own static proof is a
            # compiler bug — loud, typed, never served; the clusters
            # just scheduled are handed over so the proof does not pay
            # for a redundant partition re-derivation (load-path
            # verification re-derives — there the clusters are not
            # in hand)
            verify_artifact(artifact, parts=parts).raise_if_failed()
        return artifact
