"""One declarative compilation target: :class:`CompileSpec` (DESIGN.md §8).

The paper's framework is a *compiler*: one flow maps FFCL blocks onto a
parameterized DSP fabric (n_unit; §5.2 partition/pipeline; §7.2
design-space search). PRs 1-4 grew that parameterization as six loose
kwargs (``n_unit``, ``alloc``, ``opcode_sort``, ``fuse_levels``,
``optimize``, ``max_gates``) re-threaded ad hoc through every compile
path, with inconsistent defaults (scheduler ``alloc="direct"`` vs cache
``"liveness"``) and a hand-rolled cache-key tuple kept in sync by hand.

This module is the consolidation point:

  * :class:`CompileSpec` — a frozen, validated dataclass capturing the
    full compilation target.  Every compile-path entry point
    (``scheduler.compile_graph``, ``partition``/``compile_partitions``,
    ``serve.ProgramCache.get``, ``serve.LogicEngine``,
    ``flow.build_classifier``/``FlowConfig``, ``models.logic_mlp
    .ffn_to_program``) accepts one; new fabric knobs land HERE and
    nowhere else.
  * the **canonical defaults** — one source of truth (``alloc=
    "liveness"``, ``optimize="default"``); consumers stop declaring
    their own.  :meth:`CompileSpec.paper_exact` is the pinned
    paper-faithful preset (``fuse_levels=False, optimize="none",
    alloc="direct", opcode_sort=False`` — eq. 23 layout, raw factoring,
    address == wire id).
  * :meth:`CompileSpec.cache_key` — THE cache-keying code path
    (replaces ``ProgramCache``'s hand-built tuple and subsumes
    ``PassManager.cache_key`` for the optimized-graph memo).
  * :meth:`CompileSpec.to_dict` / :meth:`CompileSpec.from_dict` — JSON
    round-trip so benchmarks and reports record the exact target they
    measured (``BENCH_logic.json`` rows carry it).
  * :func:`resolve_spec` — the one deprecation shim every entry point
    routes its legacy kwargs through (``DeprecationWarning`` whose
    message starts with :data:`DEPRECATION_PREFIX`, so CI can run the
    suite with ``-W "error:legacy compile kwargs"`` and prove internals
    are fully migrated).

``n_unit="auto"`` makes the paper's §7.2 design-space search a spec
*value*: the :class:`~repro.core.compiler.LogicCompiler` facade (and the
serving registry on top of it) resolves it per graph through
``optimizer.binary_search`` before compiling or cache-keying.
"""
from __future__ import annotations

import dataclasses
import warnings
from dataclasses import dataclass

from repro.core.opt import PassManager, resolve_pipeline

# Every shim warning message starts with this prefix, so a warnings
# filter can turn exactly these into errors (the CI "internals are fully
# migrated" job) without tripping on third-party DeprecationWarnings:
#   python -m pytest -W "error:legacy compile kwargs"
DEPRECATION_PREFIX = "legacy compile kwargs"

_ALLOCS = ("direct", "liveness")

# sentinel distinguishing "kwarg not passed" from an explicit None
# (optimize=None legally meant "no optimization" in the old API)
_UNSET = object()


@dataclass(frozen=True)
class CompileSpec:
    """The full compilation target, as one declarative value.

    Fields (validated in ``__post_init__``):

    n_unit:
        Compute units of the fabric (int >= 1), or ``"auto"`` to let the
        compile path pick the Pareto point via ``optimizer.binary_search``
        on the graph's closed-form eq. 23 stats (paper §7.2).  ``"auto"``
        is resolved to a concrete int before anything is compiled or
        cache-keyed (:meth:`cache_key` refuses an unresolved spec).
    alloc:
        Address allocation: ``"liveness"`` (canonical default;
        register-allocation-style reuse) or ``"direct"`` (paper §6.3,
        address == wire id).
    opcode_sort / fuse_levels:
        Scheduler layout knobs (core/scheduler.py §1.2/§1.3).  Both
        default on; :meth:`paper_exact` turns both off.
    optimize:
        Gate-level pass pipeline (core/opt.py): ``"default"`` /
        ``"none"`` / a :class:`PassManager`.  Normalized at construction
        — the stored value is always a resolved ``PassManager`` or the
        string ``"none"`` — so ``CompileSpec(optimize="default") ==
        CompileSpec(optimize=PassManager.default())``.
    max_gates:
        Partition budget (int >= 1) or ``None`` (monolithic).  Budget-
        aware entry points (``LogicCompiler``, ``ProgramCache``,
        ``partition``) split graphs above it; the monolithic primitive
        ``compile_graph`` documents that it ignores it.
    objective:
        What ``n_unit="auto"`` minimizes: ``"cycles"`` (default — the
        paper's modelled eq. 22 cycles) or ``"wallclock"`` (the
        measurement-calibrated per-phase seconds model of
        core/calibrate.py; needs a ``LogicCompiler`` carrying a fitted
        ``Calibration``, else resolution raises ``CalibrationError``).
        Irrelevant once ``n_unit`` is concrete: the knob steers the
        search, not the emitted program, so it is NOT part of
        :meth:`cache_key` and serializes only when non-default
        (``objective="cycles"`` specs round-trip byte-identically to
        pre-knob records).
    """

    n_unit: object = 64                  # int >= 1 | "auto"
    alloc: str = "liveness"
    opcode_sort: bool = True
    fuse_levels: bool = True
    optimize: object = "default"         # normalized: PassManager | "none"
    max_gates: int | None = None
    objective: str = "cycles"            # "cycles" | "wallclock"
    #: Static schedule verification (core/verify.py, DESIGN.md §13):
    #: ``"off"`` (default), ``"compile"`` (prove every freshly compiled
    #: artifact), ``"load"`` (re-prove store-loaded / alias-resolved
    #: artifacts before serving — the §10.4 alias-trust closure), or
    #: ``"full"`` (both; the CI setting).  Purely *operational*: it
    #: never changes the emitted streams, so it is excluded from
    #: :meth:`cache_key`, from equality/hashing (``compare=False``),
    #: and from :meth:`to_dict` — store keys, alias records, and BENCH
    #: rows stay byte-identical across verify-on and verify-off fleets
    #: (``from_dict`` still accepts the key for CLI convenience).
    verify: str = dataclasses.field(default="off", compare=False)

    def __post_init__(self):
        n = self.n_unit
        if n != "auto" and not (isinstance(n, int)
                                and not isinstance(n, bool) and n >= 1):
            raise ValueError(
                f"n_unit must be an int >= 1 or 'auto', got {n!r}")
        if self.alloc not in _ALLOCS:
            raise ValueError(
                f"unknown alloc strategy {self.alloc!r}; use one of {_ALLOCS}")
        for knob in ("opcode_sort", "fuse_levels"):
            if not isinstance(getattr(self, knob), bool):
                raise ValueError(f"{knob} must be a bool, "
                                 f"got {getattr(self, knob)!r}")
        if self.max_gates is not None and not (
                isinstance(self.max_gates, int)
                and not isinstance(self.max_gates, bool)
                and self.max_gates >= 1):
            raise ValueError(
                f"max_gates must be an int >= 1 or None, "
                f"got {self.max_gates!r}")
        if self.objective not in ("cycles", "wallclock"):
            raise ValueError(
                f"unknown objective {self.objective!r}; "
                "use 'cycles' or 'wallclock'")
        if self.verify not in ("off", "compile", "load", "full"):
            raise ValueError(
                f"unknown verify mode {self.verify!r}; use "
                "'off', 'compile', 'load', or 'full'")
        # normalize the optimize knob once, at the boundary: equal targets
        # compare equal however they were spelled, and `.pipeline` below
        # never re-resolves.
        pipeline = resolve_pipeline(self.optimize)
        object.__setattr__(self, "optimize",
                           "none" if pipeline is None else pipeline)

    # -- presets ------------------------------------------------------------

    @classmethod
    def paper_exact(cls, n_unit: object = 64, *,
                    max_gates: int | None = None) -> "CompileSpec":
        """The paper-faithful target: eq. 23 step layout (no level
        fusion, no opcode sorting), raw synthesis output (no pass
        pipeline), and the §6.3 direct address map (buffer row == wire
        id).  Pinned by tests/test_spec.py — changing any of these
        breaks the paper-exact reproduction contract."""
        return cls(n_unit=n_unit, alloc="direct", opcode_sort=False,
                   fuse_levels=False, optimize="none", max_gates=max_gates)

    # -- derived views ------------------------------------------------------

    @property
    def pipeline(self) -> PassManager | None:
        """The resolved pass pipeline (``None`` when ``optimize="none"``)."""
        return None if self.optimize == "none" else self.optimize

    @property
    def optimize_key(self) -> tuple:
        """Canonical identity of the optimization stage — what the
        serving registry's optimized-graph memo keys on (subsumes the
        bare ``PassManager.cache_key`` it used before)."""
        return ("none",) if self.pipeline is None else self.pipeline.cache_key

    @property
    def resolved(self) -> bool:
        """True iff ``n_unit`` is concrete (not ``"auto"``)."""
        return self.n_unit != "auto"

    # -- functional updates -------------------------------------------------

    def with_(self, **changes) -> "CompileSpec":
        """Functional update: a new validated spec with ``changes``
        applied (the original is immutable and unaffected)."""
        unknown = set(changes) - {f.name for f in dataclasses.fields(self)}
        if unknown:
            raise TypeError(f"unknown CompileSpec field(s): {sorted(unknown)}")
        return dataclasses.replace(self, **changes)

    def normalize(self, graph) -> "CompileSpec":
        """The canonical spec for compiling ``graph``: a partition budget
        the graph already fits under compiles the identical monolithic
        program as no budget at all, so it normalizes to ``None`` —
        engines with different (unbinding) budgets share one cache
        entry (DESIGN.md §5.1)."""
        if self.max_gates is not None and graph.n_gates <= self.max_gates:
            return self.with_(max_gates=None)
        return self

    # -- cache keying -------------------------------------------------------

    def cache_key(self) -> tuple:
        """THE canonical cache key of this compilation target.

        Replaces the hand-built ``(n_unit, alloc, max_gates)`` tuple of
        ``serve.ProgramCache`` (which silently missed ``opcode_sort`` /
        ``fuse_levels``) — every field that changes the emitted streams
        is in here, and equivalent constructions (``optimize="default"``
        vs an explicit default ``PassManager``) key identically.  Pair
        it with a graph fingerprint for a full registry key
        (``ProgramCache.key_of``).  Refuses an unresolved ``"auto"``
        spec: resolve ``n_unit`` first (``LogicCompiler.resolve``) so a
        key always names one concrete program.
        """
        if not self.resolved:
            raise ValueError(
                "cache_key() requires a concrete n_unit; resolve "
                "n_unit='auto' first (LogicCompiler.resolve / "
                "ProgramCache.get do this per graph)")
        return (self.n_unit, self.alloc, self.opcode_sort, self.fuse_levels,
                self.optimize_key, self.max_gates)

    # -- JSON round-trip ----------------------------------------------------

    def to_dict(self) -> dict:
        """JSON-serializable form (exact inverse of :meth:`from_dict`).

        ``optimize`` serializes as ``"none"`` or ``"default"``; a custom
        :class:`PassManager` has no declarative serial form, so it
        raises — benchmarks/reports that record specs stick to the named
        pipelines.  ``objective`` is emitted only when non-default, so
        every ``"cycles"`` spec (all pre-knob records, BENCH rows, and
        store aliases) keeps its exact historical serial form.
        """
        if self.pipeline is None:
            opt = "none"
        elif self.pipeline.cache_key == PassManager.default().cache_key:
            opt = "default"
        else:
            raise ValueError(
                f"custom pass pipeline {self.pipeline!r} is not "
                "JSON-serializable; only 'none'/'default' round-trip")
        d = {"n_unit": self.n_unit, "alloc": self.alloc,
             "opcode_sort": self.opcode_sort,
             "fuse_levels": self.fuse_levels,
             "optimize": opt, "max_gates": self.max_gates}
        if self.objective != "cycles":
            d["objective"] = self.objective
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "CompileSpec":
        """Rebuild a spec from :meth:`to_dict` output (missing keys take
        the canonical defaults; unknown keys are an error)."""
        fields = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - fields
        if unknown:
            raise ValueError(
                f"unknown CompileSpec field(s) in dict: {sorted(unknown)}")
        return cls(**d)


# ---------------------------------------------------------------------------
# the deprecation shim every entry point shares
# ---------------------------------------------------------------------------

def resolve_spec(spec=None, *, caller: str, stacklevel: int = 3,
                 **legacy) -> CompileSpec:
    """Normalize ``(spec | legacy kwargs) -> CompileSpec`` (one release).

    The new calling convention passes a :class:`CompileSpec` (or nothing,
    for the canonical defaults).  The old convention — loose ``n_unit``
    / ``alloc`` / ``opcode_sort`` / ``fuse_levels`` / ``optimize`` /
    ``max_gates`` kwargs, or a bare int where the spec goes (the old
    positional ``n_unit``) — still works but emits a
    ``DeprecationWarning`` (message prefixed :data:`DEPRECATION_PREFIX`)
    attributed to the caller via ``stacklevel``.  Unspecified legacy
    kwargs take the CANONICAL defaults, not the old per-entry-point ones
    (the alloc/optimize default unification; see CHANGES.md for PR 5).

    Mixing a spec with legacy kwargs is ambiguous and raises
    ``TypeError``.  Entry points pass their legacy kwargs with the
    module-level ``_UNSET`` sentinel as "not given" so an explicit
    ``optimize=None`` (legal old spelling of "no optimization") is
    still honoured.
    """
    given = {k: v for k, v in legacy.items() if v is not _UNSET}
    if isinstance(spec, CompileSpec):
        if given:
            raise TypeError(
                f"{caller}: pass either a CompileSpec or legacy kwargs "
                f"({sorted(given)}), not both")
        return spec
    if spec is not None:
        if isinstance(spec, bool) or not isinstance(spec, int):
            raise TypeError(
                f"{caller}: expected a CompileSpec (or a legacy int "
                f"n_unit), got {type(spec).__name__}")
        if "n_unit" in given:
            raise TypeError(f"{caller}: n_unit given both positionally "
                            "and by keyword")
        given["n_unit"] = int(spec)
    if not given:
        return CompileSpec()
    # map old spellings onto the spec fields; optimize=None meant "none"
    if "optimize" in given and given["optimize"] is None:
        given["optimize"] = "none"
    warnings.warn(
        f"{DEPRECATION_PREFIX}: {caller}({', '.join(sorted(given))}=...) is "
        f"deprecated; pass a repro.core.spec.CompileSpec instead "
        f"(unspecified knobs now take the canonical CompileSpec defaults)",
        DeprecationWarning, stacklevel=stacklevel)
    return CompileSpec(**given)
