"""Static schedule verifier: dataflow proofs over compiled programs.

Differential execution is a weak oracle for a scheduled Boolean program:
a read of uninitialized scratch that happens to be zero, or a NOP lane
aimed at a live row, passes every parity test today and only breaks
later (different batch width, different allocator, different stage
order).  This module *proves* the structural invariants of
:class:`~repro.core.scheduler.LogicProgram` /
:class:`~repro.core.scheduler.MegaProgram` statically — no execution,
no input data — and reports violations as typed :class:`Diagnostic`
records with exact ``(stage, step, lane, addr)`` locations (DESIGN.md
§13).

Two analysis layers:

  * **structural** (program-only): stream shapes and dtypes, address
    bounds, the kernel input-scatter contract (inputs at rows
    ``2..1+n_inputs``), trash-row discipline, per-step write conflicts,
    opcode-homogeneity metadata, and the eq. 23 step-count envelope.
  * **symbolic** (graph-aware when a reference
    :class:`~repro.core.gate_ir.LogicGraph` is supplied): the streams
    are executed over hash-consed *terms* instead of bits — every row
    holds the term it was last written, reads of never-written rows are
    use-before-def, and every lane's computed term must exist in the
    reference graph's term set.  Terms are uninterpreted (no algebraic
    identities), so the check is conservative: any operand swap,
    liveness clobber, or retargeted write that changes the dataflow
    changes the term and is flagged, at the first lane that observes it
    and again at the final output comparison.

Rule-code vocabulary (CLOSED — new checks must reuse or extend here,
tests pin the set):

=====  ====================================================================
code   meaning
=====  ====================================================================
V101   stream shape / dtype / metadata-length / opcode-range violation
V102   address out of ``[0, n_addr)``
V103   I/O interface contract (``input_addrs != arange(2, 2+n_inputs)``,
       output arity mismatch, graph/program interface disagreement)
V104   trash-row discipline (trash aliases const/input/output rows,
       non-NOP lane writes trash, live lane reads trash)
V105   use-before-def (effective read of a never-written row)
V106   write conflict (two live lanes write one row in the same step)
V107   opcode-homogeneity metadata disagrees with the streams
V108   capacity contract (live-lane count != n_gates, step count outside
       the ``ceil(n_gates/n_unit) <= n_steps <= eq. 23`` envelope)
V109   dataflow mismatch: a live lane computes a term outside the
       reference graph's term set
V110   output mismatch: an output row's final term differs from the
       graph's output wire term
V111   megaprogram stage_meta / stream-slice / padding-lane corruption
V112   stage-handoff: output-gather row undefined by its stage's stream,
       or chained stage width mismatch
V113   scratch coverage: a stage addresses beyond the shared mega buffer
V114   output permutation is not a bijection
V115   pass-pipeline remap certificate failure (not total on outputs,
       out of range, constants/inputs not fixed, outputs not remapped)
=====  ====================================================================

Entry points: :func:`verify_program`, :func:`verify_megaprogram`,
:func:`verify_artifact`, :func:`certify_remap`; all return a
:class:`VerifyReport` (or a diagnostic list for the remap certificate).
The ``verify=`` knob of :class:`~repro.core.spec.CompileSpec` wires
these through the compile (``"compile"``), store-load (``"load"``), or
both (``"full"``) paths; see DESIGN.md §13 for the knob contract.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.core.errors import PermanentCompileError
from repro.core.gate_ir import CONST0, LogicGraph, OpCode
from repro.core.levelize import levelize

RULE_CODES = (
    "V101", "V102", "V103", "V104", "V105", "V106", "V107", "V108",
    "V109", "V110", "V111", "V112", "V113", "V114", "V115",
)

# symbolic row states that are not interned terms
_UNDEF = -1          # row never written (and not an initial def)
_POISON = -2         # row downstream of an already-reported violation

_N_OPCODES = 9       # NOP..COPY
_UNARY = (int(OpCode.NOT), int(OpCode.COPY))


@dataclass(frozen=True)
class Diagnostic:
    """One verified-false invariant, located as precisely as possible.

    ``stage`` is the megaprogram / pipeline stage index (``None`` for a
    monolithic program), ``step``/``lane`` index into the streams, and
    ``addr`` is the offending buffer row — each ``None`` when the rule
    has no such coordinate (e.g. a shape mismatch).
    """

    code: str
    message: str
    stage: Optional[int] = None
    step: Optional[int] = None
    lane: Optional[int] = None
    addr: Optional[int] = None

    def __str__(self) -> str:
        loc = ",".join(
            f"{k}={v}" for k, v in (("stage", self.stage),
                                    ("step", self.step),
                                    ("lane", self.lane),
                                    ("addr", self.addr)) if v is not None)
        return f"{self.code}[{loc}]: {self.message}" if loc \
            else f"{self.code}: {self.message}"


@dataclass(frozen=True)
class VerifyReport:
    """Outcome of one static verification."""

    target: str                              # what was verified (name)
    diagnostics: tuple[Diagnostic, ...]
    checked: dict = field(default_factory=dict, compare=False)
    elapsed_s: float = field(default=0.0, compare=False)
    truncated: bool = False                  # diagnostic cap hit

    @property
    def ok(self) -> bool:
        return not self.diagnostics

    def raise_if_failed(self) -> "VerifyReport":
        if not self.ok:
            raise ScheduleVerificationError(self)
        return self

    def summary(self) -> str:
        if self.ok:
            c = self.checked
            return (f"{self.target}: OK ({c.get('programs', 0)} program(s), "
                    f"{c.get('steps', 0)} steps, {c.get('lanes', 0)} live "
                    f"lanes, {c.get('terms', 0)} terms)")
        head = "; ".join(str(d) for d in self.diagnostics[:4])
        more = len(self.diagnostics) - 4
        tail = f" (+{more} more)" if more > 0 else ""
        trunc = " [truncated]" if self.truncated else ""
        return (f"{self.target}: {len(self.diagnostics)} violation(s)"
                f"{trunc} — {head}{tail}")


class ScheduleVerificationError(PermanentCompileError):
    """A compiled schedule failed static verification.

    ``PermanentCompileError``: retrying cannot fix a structurally wrong
    program — the front door sheds instead of burning its deadline.
    Carries the full :class:`VerifyReport` as ``.report``."""

    def __init__(self, report: VerifyReport):
        super().__init__(report.summary())
        self.report = report


class _Ctx:
    """Diagnostic accumulator with a hard cap (a corrupted stream must
    not produce one diagnostic per lane of a million-lane program)."""

    def __init__(self, max_diagnostics: int):
        self.max = max_diagnostics
        self.diags: list[Diagnostic] = []
        self.truncated = False
        self.checked = {"programs": 0, "steps": 0, "lanes": 0, "terms": 0}

    @property
    def full(self) -> bool:
        return len(self.diags) >= self.max

    def add(self, code: str, message: str, *, stage: Optional[int] = None,
            step: Optional[int] = None, lane: Optional[int] = None,
            addr: Optional[int] = None) -> None:
        if self.full:
            self.truncated = True
            return
        self.diags.append(Diagnostic(code=code, message=message, stage=stage,
                                     step=step, lane=lane, addr=addr))

    def report(self, target: str, t0: float) -> VerifyReport:
        return VerifyReport(target=target, diagnostics=tuple(self.diags),
                            checked=dict(self.checked),
                            elapsed_s=time.perf_counter() - t0,
                            truncated=self.truncated)


# ---------------------------------------------------------------------------
# hash-consed terms
# ---------------------------------------------------------------------------

class _Interner:
    """Hash-consing for symbolic dataflow terms.

    Leaves are ``("c", 0)`` / ``("c", 1)`` (the constant rows) and
    ``("in", i)`` (primary input *i*); a gate application is
    ``(opcode, a_term, b_term)`` (``(opcode, a_term)`` for unary ops),
    **uncanonicalized** — the scheduler preserves operand order exactly,
    so structural equality is the right equivalence.  NOP collapses to
    the constant-0 term and COPY passes its operand through, mirroring
    ``apply_op`` and the graph-interning rules, so a schedule and its
    source graph intern the same ids for the same dataflow.
    """

    def __init__(self) -> None:
        self._ids: dict = {}
        self.c0 = self.intern(("c", 0))
        self.c1 = self.intern(("c", 1))

    def intern(self, key) -> int:
        tid = self._ids.get(key)
        if tid is None:
            tid = len(self._ids)
            self._ids[key] = tid
        return tid

    def __len__(self) -> int:
        return len(self._ids)

    def leaf_inputs(self, n_inputs: int) -> list[int]:
        return [self.intern(("in", i)) for i in range(n_inputs)]

    def apply(self, op: int, ta: int, tb: int) -> int:
        """Term of ``op(ta, tb)``; poison propagates, NOP/COPY collapse."""
        if op == int(OpCode.NOP):
            return self.c0
        if ta == _POISON or (op not in _UNARY and tb == _POISON):
            return _POISON
        if op == int(OpCode.COPY):
            return ta
        if op == int(OpCode.NOT):
            return self.intern((op, ta))
        return self.intern((op, ta, tb))


def graph_terms(graph: LogicGraph, interner: _Interner,
                input_terms: Optional[list[int]] = None
                ) -> tuple[list[int], set[int]]:
    """Intern every wire of ``graph``; returns ``(wire_terms, term_set)``.

    ``input_terms`` substitutes the primary-input leaves (the chain-mode
    handoff: stage *k+1*'s inputs are stage *k*'s output terms); default
    is the fresh ``("in", i)`` leaves.
    """
    if input_terms is None:
        input_terms = interner.leaf_inputs(graph.n_inputs)
    if len(input_terms) != graph.n_inputs:
        raise ValueError(
            f"graph {graph.name!r} expects {graph.n_inputs} input terms, "
            f"got {len(input_terms)}")
    terms = [interner.c0, interner.c1, *input_terms]
    for op, a, b in graph.gates:
        terms.append(interner.apply(int(op), terms[a], terms[b]))
    return terms, set(terms)


# ---------------------------------------------------------------------------
# structural layer (program-only)
# ---------------------------------------------------------------------------

def _flag_oob(ctx: _Ctx, arr: np.ndarray, n_addr: int, what: str,
              stage: Optional[int]) -> bool:
    bad = (arr < 0) | (arr >= n_addr)
    if not bad.any():
        return True
    for s, u in np.argwhere(bad)[:8]:
        ctx.add("V102", f"{what} address {int(arr[s, u])} outside "
                f"[0, {n_addr})", stage=stage, step=int(s), lane=int(u),
                addr=int(arr[s, u]))
    return False


def _check_structure(ctx: _Ctx, p, stage: Optional[int]) -> bool:
    """Program-only invariants.  Returns False when the streams are too
    malformed for the symbolic walk to be meaningful."""
    streams = {"src_a": p.src_a, "src_b": p.src_b, "dst": p.dst,
               "opcode": p.opcode}
    shape = p.src_a.shape
    ok = True
    for name, arr in streams.items():
        if arr.ndim != 2 or arr.shape != shape:
            ctx.add("V101", f"stream {name} shape {arr.shape} != {shape}",
                    stage=stage)
            ok = False
        elif not np.issubdtype(arr.dtype, np.integer):
            ctx.add("V101", f"stream {name} dtype {arr.dtype} is not "
                    "integral", stage=stage)
            ok = False
    if not ok:
        return False
    n_steps, width = shape
    if width != p.n_unit:
        ctx.add("V101", f"lane count {width} != n_unit {p.n_unit}",
                stage=stage)
        ok = False
    for name, arr in (("step_opcode", p.step_opcode),
                      ("homogeneous", p.homogeneous),
                      ("level_of_step", p.level_of_step)):
        if np.asarray(arr).shape != (n_steps,):
            ctx.add("V101", f"{name} length {np.asarray(arr).shape} != "
                    f"({n_steps},)", stage=stage)
            ok = False
    if not ok:
        return False
    if ((p.opcode < 0) | (p.opcode >= _N_OPCODES)).any():
        s, u = np.argwhere((p.opcode < 0) | (p.opcode >= _N_OPCODES))[0]
        ctx.add("V101", f"opcode {int(p.opcode[s, u])} outside "
                f"[0, {_N_OPCODES})", stage=stage, step=int(s), lane=int(u))
        ok = False

    # address bounds (V102)
    for name, arr in (("src_a", p.src_a), ("src_b", p.src_b),
                      ("dst", p.dst)):
        ok &= _flag_oob(ctx, arr, p.n_addr, name, stage)

    # I/O interface (V103): the kernels scatter the input slab at row 2
    # (jax.lax.dynamic_update_slice(buf, inputs, (2, 0))) — input_addrs
    # MUST be exactly rows 2..1+n_inputs or the jitted paths and the
    # numpy oracle disagree.
    want = np.arange(2, 2 + p.n_inputs)
    if not np.array_equal(np.asarray(p.input_addrs), want):
        ctx.add("V103", f"input_addrs {np.asarray(p.input_addrs).tolist()} "
                f"!= rows 2..{1 + p.n_inputs} (kernel scatter contract)",
                stage=stage)
        ok = False
    out_addrs = np.asarray(p.output_addrs)
    if out_addrs.shape != (p.n_outputs,):
        ctx.add("V103", f"output_addrs arity {out_addrs.shape} != "
                f"n_outputs {p.n_outputs}", stage=stage)
        ok = False
    elif ((out_addrs < 0) | (out_addrs >= p.n_addr)).any():
        j = int(np.argwhere((out_addrs < 0) | (out_addrs >= p.n_addr))[0, 0])
        ctx.add("V102", f"output_addrs[{j}] = {int(out_addrs[j])} outside "
                f"[0, {p.n_addr})", stage=stage, addr=int(out_addrs[j]))
        ok = False

    # trash-row discipline (V104): the trash row must be a dedicated
    # scratch row — aliasing a const/input row would let NOP padding
    # clobber live preloads (the exposure build_megaprogram now guards).
    if not (2 + p.n_inputs <= p.trash_addr < p.n_addr):
        ctx.add("V104", f"trash_addr {p.trash_addr} aliases a "
                f"const/input row or exceeds n_addr {p.n_addr}",
                stage=stage, addr=int(p.trash_addr))
        ok = False
    elif out_addrs.shape == (p.n_outputs,) and \
            (out_addrs == p.trash_addr).any():
        j = int(np.argwhere(out_addrs == p.trash_addr)[0, 0])
        ctx.add("V104", f"output_addrs[{j}] reads the trash row",
                stage=stage, addr=int(p.trash_addr))
        ok = False
    if not ok:
        return False

    nontrash = p.dst != p.trash_addr
    live = (p.opcode != int(OpCode.NOP)) | nontrash    # not pure padding
    bad = ~nontrash & (p.opcode != int(OpCode.NOP))
    if bad.any():
        for s, u in np.argwhere(bad)[:4]:
            ctx.add("V104", f"non-NOP lane (opcode "
                    f"{int(p.opcode[s, u])}) writes the trash row",
                    stage=stage, step=int(s), lane=int(u),
                    addr=int(p.trash_addr))

    # capacity accounting (V108)
    n_live = int(live.sum())
    if n_live != p.n_gates:
        ctx.add("V108", f"live lane count {n_live} != n_gates "
                f"{p.n_gates}", stage=stage)
    min_steps = -(-p.n_gates // max(1, p.n_unit))
    if n_steps < min_steps:
        ctx.add("V108", f"n_steps {n_steps} < ceil(n_gates/n_unit) = "
                f"{min_steps}", stage=stage)

    # homogeneity metadata (V107) — recomputed with the scheduler's
    # exact rule: opcode-0 lanes must be pure padding for a non-NOP
    # specialized slab op to be safe.
    if n_steps:
        mx = p.opcode.max(axis=1)
        mn = np.where(p.opcode == 0, np.int32(127), p.opcode).min(axis=1)
        pad_only = ((p.opcode != 0) | ~nontrash).all(axis=1)
        homog = (mx == 0) | ((mx == mn) & pad_only)
        step_op = np.where(homog, mx, 0)
        bad_h = (np.asarray(p.homogeneous, dtype=bool) != homog) | \
            (np.asarray(p.step_opcode) != step_op)
        for s in np.nonzero(bad_h)[0][:4]:
            ctx.add("V107", f"homogeneous={bool(p.homogeneous[s])}/"
                    f"step_opcode={int(p.step_opcode[s])} but streams say "
                    f"{bool(homog[s])}/{int(step_op[s])}",
                    stage=stage, step=int(s))

    # per-step write conflicts among live lanes (V106)
    for s in range(n_steps):
        drow = p.dst[s][live[s]]
        if len(drow) != len(np.unique(drow)):
            vals, counts = np.unique(drow, return_counts=True)
            for a in vals[counts > 1][:2]:
                ctx.add("V106", f"{int(counts[vals == a][0])} live lanes "
                        f"write row {int(a)} in one step",
                        stage=stage, step=s, addr=int(a))
    ctx.checked["steps"] += n_steps
    ctx.checked["lanes"] += n_live
    return True


# ---------------------------------------------------------------------------
# symbolic layer (graph-aware when term_set is given)
# ---------------------------------------------------------------------------

def _sym_execute(ctx: _Ctx, p, interner: _Interner,
                 input_terms: list[int], term_set: Optional[set[int]],
                 stage: Optional[int]) -> list[int]:
    """Walk the streams over terms; returns the output-row terms.

    Per step, all reads happen before all writes (the kernel contract),
    and duplicate writes resolve last-lane-wins (the numpy oracle's
    scatter semantics).  ``term_set`` enables the foreign-term check
    (V109); without it the walk still proves def-before-use (V105) and
    trash isolation (V104).
    """
    rows = np.full(p.n_addr, _UNDEF, dtype=np.int64)
    rows[0], rows[1] = interner.c0, interner.c1
    rows[np.asarray(p.input_addrs)] = input_terms
    trash = p.trash_addr
    nop = int(OpCode.NOP)
    live = (p.opcode != nop) | (p.dst != trash)
    lanes_of = [np.nonzero(live[s])[0] for s in range(p.src_a.shape[0])]

    def _read(a: int, s: int, u: int) -> int:
        t = int(rows[a])
        if a == trash:
            ctx.add("V104", "live lane reads the trash row",
                    stage=stage, step=s, lane=u, addr=int(a))
            return _POISON
        if t == _UNDEF:
            ctx.add("V105", f"read of row {int(a)} before any write",
                    stage=stage, step=s, lane=u, addr=int(a))
            return _POISON
        return t

    for s, lanes in enumerate(lanes_of):
        writes: list[tuple[int, int]] = []
        for u in lanes:
            op = int(p.opcode[s, u])
            if op == nop:              # real NOP gate: reads nothing
                writes.append((int(p.dst[s, u]), interner.c0))
                continue
            ta = _read(int(p.src_a[s, u]), s, int(u))
            tb = interner.c0 if op in _UNARY \
                else _read(int(p.src_b[s, u]), s, int(u))
            t = interner.apply(op, ta, tb)
            if t != _POISON and term_set is not None and t not in term_set:
                ctx.add("V109", "lane computes a term absent from the "
                        "reference graph (operand swapped or clobbered)",
                        stage=stage, step=s, lane=int(u),
                        addr=int(p.dst[s, u]))
                t = _POISON
            writes.append((int(p.dst[s, u]), t))
        for a, t in writes:            # last-lane-wins, after all reads
            rows[a] = t
        if ctx.full:
            break

    outs = []
    for j, a in enumerate(np.asarray(p.output_addrs)):
        t = int(rows[a])
        if t == _UNDEF:
            ctx.add("V105", f"output {j} reads row {int(a)} that was "
                    "never written", stage=stage, addr=int(a))
            t = _POISON
        outs.append(t)
    ctx.checked["terms"] = len(interner)
    return outs


def _verify_one(ctx: _Ctx, p, graph: Optional[LogicGraph],
                interner: _Interner, input_terms: Optional[list[int]],
                stage: Optional[int]) -> Optional[list[int]]:
    """Full (structural + symbolic) verification of one program.
    Returns its output terms, or ``None`` when the structure was too
    broken to walk."""
    ctx.checked["programs"] += 1
    term_set = None
    expected = None
    if graph is not None:
        if (graph.n_inputs, graph.n_outputs) != (p.n_inputs, p.n_outputs):
            ctx.add("V103", f"graph interface ({graph.n_inputs} in, "
                    f"{graph.n_outputs} out) != program ({p.n_inputs} in, "
                    f"{p.n_outputs} out)", stage=stage)
            graph = None
    if not _check_structure(ctx, p, stage):
        return None
    if input_terms is None:
        input_terms = interner.leaf_inputs(p.n_inputs)
    if graph is not None:
        wire_terms, term_set = graph_terms(graph, interner, input_terms)
        expected = [wire_terms[w] for w in graph.outputs]
        # eq. 23 envelope: levelized layout is the upper bound (fusion
        # only shrinks it)
        lv = levelize(graph)
        bound = int((-(-lv.histogram() // p.n_unit)).sum())
        if p.n_steps > bound:
            ctx.add("V108", f"n_steps {p.n_steps} exceeds the eq. 23 "
                    f"bound {bound} for n_unit={p.n_unit}", stage=stage)
    outs = _sym_execute(ctx, p, interner, input_terms, term_set, stage)
    if expected is not None:
        for j, (got, want) in enumerate(zip(outs, expected)):
            if got != _POISON and got != want:
                ctx.add("V110", f"output {j} computes a different term "
                        "than the graph's output wire", stage=stage,
                        addr=int(np.asarray(p.output_addrs)[j]))
    return outs


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------

def verify_program(prog, graph: Optional[LogicGraph] = None, *,
                   max_diagnostics: int = 64) -> VerifyReport:
    """Statically verify one :class:`LogicProgram`.

    Program-only invariants always run; pass the (post-optimization)
    source ``graph`` to additionally prove the schedule computes exactly
    the graph's dataflow (V109/V110) and respects the eq. 23 step
    envelope.
    """
    t0 = time.perf_counter()
    ctx = _Ctx(max_diagnostics)
    _verify_one(ctx, prog, graph, _Interner(), None, None)
    return ctx.report(getattr(prog, "name", "program"), t0)


def _check_perm(ctx: _Ctx, perm: np.ndarray, n: int) -> bool:
    perm = np.asarray(perm)
    if perm.shape != (n,) or \
            not np.array_equal(np.sort(perm), np.arange(n)):
        ctx.add("V114", f"output_perm is not a permutation of range({n})")
        return False
    return True


def verify_megaprogram(mega, graph: Optional[LogicGraph] = None, *,
                       stage_graphs: Optional[list] = None,
                       max_diagnostics: int = 64) -> VerifyReport:
    """Statically verify a :class:`MegaProgram` against its stages.

    Proves the flattening itself (stage_meta partitions the step axis,
    stream slices match the stage programs, padding lanes only write
    their owning stage's trash row, per-stage scratch fits the shared
    buffer) and then each stage program; with ``graph`` (the composed
    graph for chains, the full post-opt graph for parallel pipelines)
    the stage handoff / reassembly dataflow is proven end to end.

    ``stage_graphs`` (parallel mode) supplies each stage's OWN reference
    graph — required when the partitioner re-optimized its clusters:
    the rewritten cones are semantically equal but structurally
    different from the full graph, so uninterpreted terms must be
    compared per cluster (the cluster graphs themselves are tied back
    to the full graph by the deterministic re-derivation in
    :func:`verify_artifact` plus the pass certificates).
    """
    t0 = time.perf_counter()
    ctx = _Ctx(max_diagnostics)
    stages = tuple(mega.stages)
    if mega.mode not in ("chain", "parallel"):
        ctx.add("V111", f"unknown mega mode {mega.mode!r}")
        return ctx.report(mega.name, t0)
    if len(mega.stage_meta) != len(stages):
        ctx.add("V111", f"stage_meta has {len(mega.stage_meta)} entries "
                f"for {len(stages)} stages")
        return ctx.report(mega.name, t0)

    # stage_meta must partition the step axis and index the gather
    step_lo = out_lo = 0
    meta_ok = True
    for k, (p, meta) in enumerate(zip(stages, mega.stage_meta)):
        lo, hi, n_in, n_out, olo = meta
        if (lo, hi) != (step_lo, step_lo + p.n_steps):
            ctx.add("V111", f"stage_meta step range ({lo}, {hi}) != "
                    f"({step_lo}, {step_lo + p.n_steps})", stage=k)
            meta_ok = False
        if (n_in, n_out) != (p.n_inputs, p.n_outputs):
            ctx.add("V111", f"stage_meta widths ({n_in}, {n_out}) != "
                    f"stage ({p.n_inputs}, {p.n_outputs})", stage=k)
            meta_ok = False
        if olo != out_lo:
            ctx.add("V111", f"stage_meta out_lo {olo} != {out_lo}", stage=k)
            meta_ok = False
        if p.n_addr > mega.n_addr:
            ctx.add("V113", f"stage n_addr {p.n_addr} exceeds the shared "
                    f"scratch buffer ({mega.n_addr} rows)", stage=k)
        if p.n_unit > mega.n_unit:
            ctx.add("V111", f"stage n_unit {p.n_unit} exceeds the padded "
                    f"lane width {mega.n_unit}", stage=k)
            meta_ok = False
        step_lo += p.n_steps
        out_lo += p.n_outputs
    if mega.total_steps != step_lo:
        ctx.add("V111", f"total_steps {mega.total_steps} != sum of stage "
                f"steps {step_lo}")
        meta_ok = False
    if len(np.asarray(mega.out_addrs)) != out_lo:
        ctx.add("V111", f"out_addrs has {len(np.asarray(mega.out_addrs))} "
                f"entries, stages produce {out_lo}")
        meta_ok = False

    if meta_ok:
        for k, (p, meta) in enumerate(zip(stages, mega.stage_meta)):
            lo, hi, _, _, olo = meta
            w = p.n_unit
            for name in ("src_a", "src_b", "dst", "opcode"):
                if not np.array_equal(getattr(mega, name)[lo:hi, :w],
                                      getattr(p, name)):
                    ctx.add("V111", f"mega stream {name} slice differs "
                            "from the stage program", stage=k)
            # padding lanes: NOP writing the owning stage's trash row only
            padc = mega.opcode[lo:hi, w:]
            padd = mega.dst[lo:hi, w:]
            if padc.size and (padc != int(OpCode.NOP)).any():
                s, u = np.argwhere(padc != int(OpCode.NOP))[0]
                ctx.add("V111", "padding lane carries a non-NOP opcode",
                        stage=k, step=int(lo + s), lane=int(w + u))
            if padd.size and (padd != p.trash_addr).any():
                s, u = np.argwhere(padd != p.trash_addr)[0]
                ctx.add("V104", f"padding lane writes row "
                        f"{int(padd[s, u])} instead of the stage trash "
                        f"row {p.trash_addr}", stage=k, step=int(lo + s),
                        lane=int(w + u), addr=int(padd[s, u]))
            if not np.array_equal(mega.step_trash[lo:hi],
                                  np.full(hi - lo, p.trash_addr)):
                ctx.add("V111", "step_trash does not name the owning "
                        f"stage's trash row {p.trash_addr}", stage=k)
            if not np.array_equal(mega.step_branch[lo:hi], p.step_branch):
                ctx.add("V107", "mega step_branch differs from the stage "
                        "program's dispatch metadata", stage=k)
            if not np.array_equal(
                    np.asarray(mega.out_addrs[olo:olo + p.n_outputs]),
                    np.asarray(p.output_addrs)):
                ctx.add("V112", "out_addrs gather slice differs from the "
                        "stage's output_addrs", stage=k)

    # handoff widths + permutation
    if mega.mode == "chain":
        for k in range(len(stages) - 1):
            if stages[k].n_outputs != stages[k + 1].n_inputs:
                ctx.add("V112", f"stage {k} produces "
                        f"{stages[k].n_outputs} outputs, stage {k + 1} "
                        f"expects {stages[k + 1].n_inputs} inputs",
                        stage=k)
        if stages and stages[0].n_inputs != mega.n_inputs:
            ctx.add("V112", f"mega n_inputs {mega.n_inputs} != first "
                    f"stage's {stages[0].n_inputs}", stage=0)
        if not _check_perm(ctx, mega.output_perm, mega.n_outputs):
            pass
        elif not np.array_equal(np.asarray(mega.output_perm),
                                np.arange(mega.n_outputs)):
            ctx.add("V114", "chain-mode output_perm must be the identity")
    else:
        for k, p in enumerate(stages):
            if p.n_inputs != mega.n_inputs:
                ctx.add("V112", f"parallel stage reads {p.n_inputs} "
                        f"inputs, pipeline advertises {mega.n_inputs}",
                        stage=k)
        _check_perm(ctx, mega.output_perm, mega.n_outputs)

    # per-stage programs (+ end-to-end dataflow when a graph is given)
    interner = _Interner()
    chainable = graph is not None and not ctx.diags
    if mega.mode == "chain":
        terms = interner.leaf_inputs(mega.n_inputs)
        gterm_set: Optional[set[int]] = None
        expected = None
        if chainable and graph.n_inputs == mega.n_inputs:
            wire_terms, gterm_set = graph_terms(graph, interner)
            expected = [wire_terms[w] for w in graph.outputs]
        for k, p in enumerate(stages):
            if terms is None or len(terms) != p.n_inputs or \
                    any(t == _POISON for t in terms):
                terms = None           # handoff already diagnosed; walk
                ctx.checked["programs"] += 1  # structurally only
                _check_structure(ctx, p, k)
                continue
            outs = _verify_one(ctx, p, None, interner, terms, k)
            if outs is not None and gterm_set is not None:
                # stage gates must land inside the composed graph's terms
                for j, t in enumerate(outs):
                    if t != _POISON and t not in gterm_set:
                        ctx.add("V109", f"stage output {j} computes a "
                                "term absent from the composed graph",
                                stage=k)
            terms = outs
        if expected is not None and terms is not None:
            for j, (got, want) in enumerate(zip(terms, expected)):
                if got != _POISON and got != want:
                    ctx.add("V110", f"pipeline output {j} computes a "
                            "different term than the composed graph")
    elif stage_graphs is not None and len(stage_graphs) == len(stages):
        # re-optimized clusters: prove each stage against its OWN graph
        leaf = interner.leaf_inputs(mega.n_inputs)
        for k, (p, sg) in enumerate(zip(stages, stage_graphs)):
            ins = leaf if sg.n_inputs == mega.n_inputs \
                else interner.leaf_inputs(sg.n_inputs)
            _verify_one(ctx, p, sg, interner, ins, k)
    else:
        leaf = interner.leaf_inputs(mega.n_inputs)
        gterm_set = None
        expected = None
        if chainable and graph.n_inputs == mega.n_inputs:
            wire_terms, gterm_set = graph_terms(graph, interner)
            expected = [wire_terms[w] for w in graph.outputs]
        cat: list[int] = []
        for k, p in enumerate(stages):
            ins = leaf if p.n_inputs == mega.n_inputs \
                else interner.leaf_inputs(p.n_inputs)
            outs = _verify_one(ctx, p, None, interner, ins, k)
            if outs is not None and gterm_set is not None:
                for t in outs:
                    if t not in (_POISON, _UNDEF) and t not in gterm_set:
                        ctx.add("V109", "partition output computes a term "
                                "absent from the full graph", stage=k)
                        break
            cat.extend(outs if outs is not None
                       else [_POISON] * p.n_outputs)
        if expected is not None and len(cat) == mega.n_outputs and \
                _check_perm(_Ctx(1), mega.output_perm, mega.n_outputs):
            perm = np.asarray(mega.output_perm)
            for j in range(mega.n_outputs):
                got = cat[int(perm[j])]
                if got != _POISON and got != expected[j]:
                    ctx.add("V110", f"re-assembled output {j} computes a "
                            "different term than the graph")
    return ctx.report(mega.name, t0)


def verify_artifact(artifact, *, include_mega: bool = True,
                    parts=None, max_diagnostics: int = 64) -> VerifyReport:
    """Statically verify a whole
    :class:`~repro.core.compiler.CompiledArtifact` against its own
    post-optimization graph — the check the ``verify=`` knob runs at
    compile and store-load time.

    Monolithic artifacts verify the one program against the graph;
    parallel (partitioned) artifacts verify every part over the shared
    primary-input leaves and the permuted re-assembly; chain artifacts
    verify the stage handoff against the composed graph.  With
    ``include_mega`` (default), multi-program artifacts additionally
    verify their flattened :class:`MegaProgram` — the form the engine
    actually serves.

    ``parts`` (compile path only): the partition results the caller just
    scheduled the programs from.  Supplying them skips the deterministic
    partition *re-derivation* — which re-runs per-cluster optimization
    and would otherwise nearly double a partitioned compile — while
    every per-program dataflow proof still runs in full against those
    cluster graphs.  On the load path leave it ``None``: re-deriving the
    clustering from ``(graph, spec)`` is the trust anchor there, since a
    store entry's programs cannot vouch for themselves.
    """
    t0 = time.perf_counter()
    ctx = _Ctx(max_diagnostics)
    graph = artifact.graph
    programs = tuple(artifact.programs)
    mode = getattr(artifact, "mode", "parallel")
    if not programs:
        ctx.add("V101", "artifact has no programs")
        return ctx.report(graph.name, t0)
    spec = getattr(artifact, "spec", None)
    if spec is not None and spec.resolved:
        for k, p in enumerate(programs):
            if p.n_unit != spec.n_unit:
                ctx.add("V101", f"program n_unit {p.n_unit} != spec "
                        f"n_unit {spec.n_unit}",
                        stage=None if len(programs) == 1 else k)

    interner = _Interner()
    parts_graphs: Optional[list[LogicGraph]] = None
    if mode == "chain":
        terms: Optional[list[int]] = interner.leaf_inputs(
            programs[0].n_inputs)
        gterm_set = None
        expected = None
        if graph.n_inputs == programs[0].n_inputs:
            wire_terms, gterm_set = graph_terms(graph, interner)
            expected = [wire_terms[w] for w in graph.outputs]
        else:
            ctx.add("V103", f"graph reads {graph.n_inputs} inputs, first "
                    f"stage {programs[0].n_inputs}")
        for k, p in enumerate(programs):
            if terms is None or len(terms) != p.n_inputs:
                ctx.add("V112", f"stage {k} expects {p.n_inputs} inputs, "
                        f"handoff provides "
                        f"{'?' if terms is None else len(terms)}", stage=k)
                _check_structure(ctx, p, k)
                ctx.checked["programs"] += 1
                terms = None
                continue
            outs = _verify_one(ctx, p, None, interner, terms, k)
            if outs is not None and gterm_set is not None:
                for j, t in enumerate(outs):
                    if t not in (_POISON, _UNDEF) and t not in gterm_set:
                        ctx.add("V109", f"stage output {j} computes a "
                                "term absent from the composed graph",
                                stage=k)
            terms = outs
        if expected is not None and terms is not None:
            for j, (got, want) in enumerate(zip(terms, expected)):
                if got != _POISON and got != want:
                    ctx.add("V110", f"pipeline output {j} computes a "
                            "different term than the composed graph")
        _check_perm(ctx, artifact.output_perm, graph.n_outputs)
    elif len(programs) == 1:
        _verify_one(ctx, programs[0], graph, interner, None, None)
        _check_perm(ctx, artifact.output_perm, graph.n_outputs)
    else:
        # Partitioned pipeline.  The partitioner may have RE-OPTIMIZED
        # each cluster cone (compiler.compile passes the full spec), so
        # the programs' terms are structurally different from the full
        # graph's.  Partitioning is deterministic in (graph, spec):
        # re-derive the cluster graphs and prove each program against
        # its own cluster (V110 per part), the recorded permutation
        # against the re-derived clustering (V114), and leave
        # cluster == cone semantics to the certified pass pipeline.
        if parts is None and spec is not None and \
                getattr(spec, "max_gates", None) is not None:
            from repro.core.partition import partition
            try:
                parts = partition(graph, spec.with_(verify="off"))
            except Exception as exc:        # noqa: BLE001 — any failure
                ctx.add("V111", "partition re-derivation failed: "
                        f"{exc!r}")        # to re-derive is a finding
                parts = None
        if spec is not None and getattr(spec, "max_gates", None) is not None:
            from repro.core.partition import output_permutation
            if parts is not None:
                if len(parts) != len(programs):
                    ctx.add("V111", f"re-derived partitioning has "
                            f"{len(parts)} clusters, artifact has "
                            f"{len(programs)} programs")
                else:
                    parts_graphs = [q.graph for q in parts]
                    want = output_permutation(parts, graph.n_outputs)
                    if not np.array_equal(np.asarray(artifact.output_perm),
                                          want):
                        ctx.add("V114", "output_perm differs from the "
                                "re-derived partition permutation")
        leaf = interner.leaf_inputs(graph.n_inputs)
        if parts_graphs is not None:
            for k, (p, sg) in enumerate(zip(programs, parts_graphs)):
                ins = leaf if sg.n_inputs == graph.n_inputs \
                    else interner.leaf_inputs(sg.n_inputs)
                _verify_one(ctx, p, sg, interner, ins, k)
            _check_perm(ctx, artifact.output_perm, graph.n_outputs)
        else:
            wire_terms, gterm_set = graph_terms(graph, interner)
            expected = [wire_terms[w] for w in graph.outputs]
            cat: list[int] = []
            for k, p in enumerate(programs):
                if p.n_inputs != graph.n_inputs:
                    ctx.add("V103", f"partition reads {p.n_inputs} inputs, "
                            f"graph has {graph.n_inputs}", stage=k)
                    cat.extend([_POISON] * p.n_outputs)
                    continue
                outs = _verify_one(ctx, p, None, interner, leaf, k)
                if outs is None:
                    cat.extend([_POISON] * p.n_outputs)
                    continue
                for t in outs:
                    if t not in (_POISON, _UNDEF) and t not in gterm_set:
                        ctx.add("V109", "partition output computes a term "
                                "absent from the full graph", stage=k)
                        break
                cat.extend(outs)
            if _check_perm(ctx, artifact.output_perm, graph.n_outputs) and \
                    len(cat) == graph.n_outputs:
                perm = np.asarray(artifact.output_perm)
                for j in range(graph.n_outputs):
                    got = cat[int(perm[j])]
                    if got != _POISON and got != expected[j]:
                        ctx.add("V110", f"re-assembled output {j} computes "
                                "a different term than the graph")

    if include_mega and len(programs) > 1 and not ctx.full:
        sub = verify_megaprogram(artifact.megaprogram(),
                                 None if parts_graphs is not None else graph,
                                 stage_graphs=parts_graphs,
                                 max_diagnostics=max_diagnostics
                                 - len(ctx.diags))
        seen = set(ctx.diags)
        for d in sub.diagnostics:
            if d not in seen:
                ctx.diags.append(d)
        ctx.truncated |= sub.truncated
        for k in ("steps", "lanes"):
            ctx.checked[k] += sub.checked.get(k, 0)
    return ctx.report(graph.name, t0)


# ---------------------------------------------------------------------------
# pass-pipeline remap certificates
# ---------------------------------------------------------------------------

def certify_remap(old_graph: LogicGraph, new_graph: LogicGraph,
                  remap: np.ndarray, *,
                  label: str = "remap") -> list[Diagnostic]:
    """Certify one old-wire -> new-wire map (a :class:`PassResult` or a
    composed :class:`OptResult` remap) against its endpoint graphs.

    The certificate (all V115): the map covers every old wire, keeps
    constants and primary inputs fixed (passes must not touch the I/O
    interface), lands every live wire inside the new graph, and maps the
    old outputs exactly onto the new outputs in order — i.e. it composes
    to a *total, in-range output map*.  Dropped gates (``-1``) are legal
    anywhere else.
    """
    diags: list[Diagnostic] = []
    remap = np.asarray(remap)
    if remap.shape != (old_graph.n_wires,):
        diags.append(Diagnostic(
            "V115", f"{label}: shape {remap.shape} != "
            f"({old_graph.n_wires},)"))
        return diags
    fixed = np.arange(old_graph.first_gate_wire)
    if old_graph.n_inputs != new_graph.n_inputs:
        diags.append(Diagnostic(
            "V115", f"{label}: input arity changed "
            f"({old_graph.n_inputs} -> {new_graph.n_inputs})"))
    elif not np.array_equal(remap[:len(fixed)], fixed):
        diags.append(Diagnostic(
            "V115", f"{label}: constants/primary inputs are not mapped "
            "to themselves"))
    live = remap >= CONST0
    if live.any() and int(remap[live].max()) >= new_graph.n_wires:
        w = int(np.argwhere(live & (remap >= new_graph.n_wires))[0, 0])
        diags.append(Diagnostic(
            "V115", f"{label}: wire {w} maps to {int(remap[w])} outside "
            f"the new graph ({new_graph.n_wires} wires)", addr=w))
    outs = np.asarray(old_graph.outputs, dtype=np.int64)
    if len(outs):
        mapped = remap[outs]
        if (mapped < 0).any():
            j = int(np.argwhere(mapped < 0)[0, 0])
            diags.append(Diagnostic(
                "V115", f"{label}: output {j} (wire {int(outs[j])}) was "
                "dropped — the map is not total on outputs", addr=int(
                    outs[j])))
        elif not np.array_equal(mapped,
                                np.asarray(new_graph.outputs,
                                           dtype=np.int64)):
            diags.append(Diagnostic(
                "V115", f"{label}: remapped outputs differ from the new "
                "graph's output list"))
    return diags


def effective_mode(spec_verify: str, default: Optional[str]) -> str:
    """The verify mode one compile/load should run at: the spec's
    opt-in wins; a compiler/store-level default applies otherwise."""
    if spec_verify != "off":
        return spec_verify
    return default or "off"
