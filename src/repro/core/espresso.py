"""Two-level logic minimization over incompletely specified functions.

Place in the synthesis flow: this is the *first* synthesis stage, where a
neuron's truth-table semantics become Boolean structure. NullaNet (paper
§7.1, core/nullanet.py) forms each neuron's spec either by full input
enumeration (small fanin) or as an ISF sampled from training data: an
on-set, an off-set, and everything unobserved as don't-care. ``minimize``
compresses that spec into a small sum-of-products cover; ``sop_to_graph``
factors the cover into the 2-input gate DAG that multi-level restructuring
(core/synth.py), the scheduler (core/scheduler.py), and ultimately the
serving engine consume. The ISF don't-care set is where the paper's
accuracy/area trade lives — the fewer observed minterms, the more freedom
EXPAND has.

This module implements an espresso-style EXPAND / IRREDUNDANT loop over
cube lists:

  cube = (mask, val): covers x  iff  all(x[mask] == val[mask]).

EXPAND greedily drops literals from each on-cube while it stays disjoint
from the off-set (don't-cares absorb automatically: anything not in the
off-set may be covered). IRREDUNDANT removes cubes whose on-set coverage is
contained in the union of the others. The result is a minimal-ish SOP that
``sop_to_graph`` factors into a 2-input gate DAG for the FFCL compiler.

>>> import numpy as np
>>> X_on = np.array([[0, 0], [0, 1]], dtype=np.uint8)   # f = ~a (b free)
>>> X_off = np.array([[1, 0], [1, 1]], dtype=np.uint8)
>>> cubes = minimize(X_on, X_off)
>>> len(cubes)                         # one cube: a == 0, b dropped
1
>>> int(cubes[0][0].sum())             # a single literal survives
1
>>> check_cover(cubes, X_on, X_off)
True
>>> g = sop_to_graph([cubes], n_inputs=2)
>>> bool(g.evaluate(np.array([[0, 1]], dtype=bool))[0, 0])
True
"""
from __future__ import annotations

import numpy as np

from repro.core.gate_ir import CONST0, CONST1, LogicGraph, OpCode


def _covers(mask: np.ndarray, val: np.ndarray, X: np.ndarray) -> np.ndarray:
    """Which rows of X (n, v) the cube covers -> bool (n,)."""
    if X.shape[0] == 0:
        return np.zeros(0, dtype=bool)
    return ((X == val) | ~mask).all(axis=1)


def expand_cube(mask: np.ndarray, val: np.ndarray, X_off: np.ndarray,
                order: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Drop literals (in ``order``) while the cube avoids the off-set.

    Incremental formulation: track, per off-minterm, how many masked
    literals it mismatches. Dropping literal i covers an off-minterm iff
    that minterm's ONLY mismatch is at i, so a drop is safe iff no row has
    (count == 1 and mismatch at i); a safe drop just subtracts its column
    from the counts. O(v * |off|) total vs O(v^2 * |off|) for the naive
    re-check — the difference between minutes and milliseconds at VGG16
    fanins (2304-4608 literals)."""
    mask = mask.copy()
    if X_off.shape[0] == 0:
        mask[:] = False            # no off-set: the cube expands to 1
        return mask, val
    mismatch = (X_off != val) & mask          # (n_off, v)
    counts = mismatch.sum(axis=1)             # per off-minterm
    for i in order:
        if not mask[i]:
            continue
        col = mismatch[:, i]
        if np.any(col & (counts == 1)):
            continue                           # would cover an off-minterm
        mask[i] = False
        counts = counts - col
        mismatch[:, i] = False
    return mask, val


def minimize(X_on: np.ndarray, X_off: np.ndarray,
             rng: np.random.Generator | None = None,
             max_literal_tries: int | None = None
             ) -> list[tuple[np.ndarray, np.ndarray]]:
    """ISF two-level minimization.

    Args:
      X_on / X_off: uint8/bool arrays (n_on, v), (n_off, v) of minterms.
    Returns:
      list of cubes (mask, val) covering every on-minterm, disjoint from
      every off-minterm.
    """
    X_on = np.asarray(X_on, dtype=np.uint8)
    X_off = np.asarray(X_off, dtype=np.uint8)
    if X_on.ndim != 2:
        raise ValueError("X_on must be 2-D")
    n_on, v = X_on.shape
    if n_on == 0:
        return []
    rng = rng or np.random.default_rng(0)

    # literal drop order: try most "balanced" variables first (likely
    # droppable); stable heuristic = ascending |bias| on the on-set.
    bias = np.abs(X_on.mean(axis=0) - 0.5)
    base_order = np.argsort(bias, kind="stable")

    cubes: list[tuple[np.ndarray, np.ndarray]] = []
    covered = np.zeros(n_on, dtype=bool)
    full_mask = np.ones(v, dtype=bool)
    while not covered.all():
        seed_idx = int(np.flatnonzero(~covered)[0])
        val = X_on[seed_idx].copy()
        mask, val = expand_cube(full_mask.copy(), val, X_off, base_order)
        newly = _covers(mask, val, X_on)
        covered |= newly
        cubes.append((mask, val))

    # IRREDUNDANT: greedily drop cubes whose coverage is subsumed.
    cover = np.stack([_covers(m, c, X_on) for m, c in cubes], axis=0)
    keep = np.ones(len(cubes), dtype=bool)
    sizes = cover.sum(axis=1)
    for i in np.argsort(sizes, kind="stable"):       # smallest first
        keep[i] = False
        if not cover[keep].any(axis=0).all():
            keep[i] = True
    return [c for k, c in zip(keep, cubes) if k]


def check_cover(cubes, X_on: np.ndarray, X_off: np.ndarray) -> bool:
    """Verify: every on-minterm covered, no off-minterm covered."""
    X_on = np.asarray(X_on, dtype=np.uint8)
    X_off = np.asarray(X_off, dtype=np.uint8)
    if X_on.shape[0]:
        got = np.zeros(X_on.shape[0], dtype=bool)
        for m, v in cubes:
            got |= _covers(m, v, X_on)
        if not got.all():
            return False
    for m, v in cubes:
        if _covers(m, v, X_off).any():
            return False
    return True


def eval_sop(cubes, X: np.ndarray) -> np.ndarray:
    """Evaluate the SOP on rows of X -> bool (n,)."""
    X = np.asarray(X, dtype=np.uint8)
    out = np.zeros(X.shape[0], dtype=bool)
    for m, v in cubes:
        out |= _covers(m, v, X)
    return out


def _balanced_tree(graph: LogicGraph, op: OpCode, leaves: list[int],
                   cache: dict) -> int:
    """Hash-consed balanced reduction tree."""
    if not leaves:
        return CONST1 if op == OpCode.AND else CONST0
    nodes = leaves
    while len(nodes) > 1:
        nxt = []
        for j in range(0, len(nodes) - 1, 2):
            a, b = sorted((nodes[j], nodes[j + 1]))
            key = (int(op), a, b)
            if key not in cache:
                cache[key] = graph.add_gate(op, a, b)
            nxt.append(cache[key])
        if len(nodes) % 2:
            nxt.append(nodes[-1])
        nodes = nxt
    return nodes[0]


def sop_to_graph(cube_sets: list[list[tuple[np.ndarray, np.ndarray]]],
                 n_inputs: int, name: str = "sop",
                 optimize="none") -> LogicGraph:
    """Factor one-or-more SOPs (sharing inputs) into a 2-input gate DAG.

    ``cube_sets[k]`` is the SOP of output k. Literals and AND/OR subtrees
    are shared across outputs via hash-consing. ``optimize`` routes the
    factored graph through the gate-level pass pipeline (core/opt.py:
    ``"default"`` | ``"none"`` | a ``PassManager``) for further
    sharing/depth reduction — the same default pipeline every synthesis
    consumer uses; ``"none"`` keeps the raw factoring (the doctests and
    the paper-exact scheduling contract).
    """
    g = LogicGraph(n_inputs, name=name)
    cache: dict = {}
    neg: dict[int, int] = {}

    def literal(i: int, value: int) -> int:
        w = g.input_wire(i)
        if value:
            return w
        if w not in neg:
            neg[w] = g.add_gate(OpCode.NOT, w)
        return neg[w]

    outputs = []
    for cubes in cube_sets:
        terms = []
        for mask, val in cubes:
            lits = [literal(int(i), int(val[i]))
                    for i in np.flatnonzero(mask)]
            terms.append(_balanced_tree(g, OpCode.AND, lits, cache))
        outputs.append(_balanced_tree(g, OpCode.OR, terms, cache))
    g.set_outputs(outputs)
    from repro.core.opt import resolve_pipeline   # local import, no cycle
    pipeline = resolve_pipeline(optimize)
    return pipeline.run(g).graph if pipeline is not None else g
