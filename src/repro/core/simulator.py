"""Discrete-event simulation of the double-buffered FFCL pipeline.

Plays the role of the paper's "actual hardware implementation" in the Fig. 6
model-validation study (no FPGA/TPU timing exists in this container). The
simulator is strictly finer-grained than the analytical model:

  * per-step (sub-kernel) compute events with *actual* unit occupancy
    (the model's stated pessimism: it assumes every step uses all units);
  * two on-chip buffers; data movement of module k+1 may only start once
    buffer (k+1) mod 2 was released by compute of module k-1 (double
    buffering, paper §5.2.2);
  * one DMA engine and one compute engine (task pipelining, §5.2.3).

The simulator consumes real compiled :class:`LogicProgram` objects, so its
occupancy profile is exact, not statistical.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.cost_model import CostModel, FfclStats
from repro.core.scheduler import LogicProgram


@dataclass
class SimResult:
    total_cycles: float
    dm_cycles: list[float]        # per-module data-movement duration
    compute_cycles: list[float]   # per-module compute duration
    dm_busy: float                # total DMA-engine busy cycles
    compute_busy: float
    timeline: list[tuple[str, int, float, float]]  # (stage, module, t0, t1)

    @property
    def bound(self) -> str:
        return "data_moves" if self.dm_busy >= self.compute_busy else "compute"


def _module_durations(model: CostModel, prog: LogicProgram,
                      n_input_vectors: int) -> tuple[float, float]:
    """(data-movement cycles, compute cycles) for one module, exact occupancy.

    The stats come from the compiled program, so both the stream-movement
    terms (which scale with the *scheduled*, possibly level-fused, step
    count) and the compute loop (per-step non-NOP occupancy) are exact.
    """
    stats = FfclStats.from_program(prog)
    dm = model.n_data_moves(stats, prog.n_unit, n_input_vectors)
    comp = model.n_compute(stats, prog.n_unit, n_input_vectors,
                           exact_occupancy=True)
    return dm, comp


def simulate_pipeline(programs: list[LogicProgram], n_input_vectors: int,
                      model: CostModel | None = None,
                      n_buffers: int = 2) -> SimResult:
    """Simulate executing ``programs`` back-to-back with task pipelining."""
    model = model or CostModel()
    m = len(programs)
    dms, comps = [], []
    for p in programs:
        dm, comp = _module_durations(model, p, n_input_vectors)
        dms.append(dm)
        comps.append(comp)

    dm_end = [0.0] * m
    comp_end = [0.0] * m
    timeline: list[tuple[str, int, float, float]] = []
    for k in range(m):
        # DMA engine free after previous transfer; buffer (k mod n_buffers)
        # free after compute of module k - n_buffers finished.
        dma_free = dm_end[k - 1] if k else 0.0
        buf_free = comp_end[k - n_buffers] if k >= n_buffers else 0.0
        t0 = max(dma_free, buf_free)
        dm_end[k] = t0 + dms[k]
        timeline.append(("dm", k, t0, dm_end[k]))
        c0 = max(dm_end[k], comp_end[k - 1] if k else 0.0)
        comp_end[k] = c0 + comps[k]
        timeline.append(("compute", k, c0, comp_end[k]))
    return SimResult(
        total_cycles=comp_end[-1] if m else 0.0,
        dm_cycles=dms, compute_cycles=comps,
        dm_busy=float(sum(dms)), compute_busy=float(sum(comps)),
        timeline=timeline)


def simulate_no_pipeline(programs: list[LogicProgram], n_input_vectors: int,
                         model: CostModel | None = None) -> SimResult:
    """Paper Fig. 8(a): sequential data-move -> compute per module."""
    model = model or CostModel()
    t = 0.0
    dms, comps, timeline = [], [], []
    for k, p in enumerate(programs):
        dm, comp = _module_durations(model, p, n_input_vectors)
        timeline.append(("dm", k, t, t + dm))
        t += dm
        timeline.append(("compute", k, t, t + comp))
        t += comp
        dms.append(dm)
        comps.append(comp)
    return SimResult(total_cycles=t, dm_cycles=dms, compute_cycles=comps,
                     dm_busy=float(sum(dms)), compute_busy=float(sum(comps)),
                     timeline=timeline)
