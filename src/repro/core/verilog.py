"""Structural-Verilog front-end/back-end for FFCL modules (paper §4).

Place in the synthesis flow: this is the *interchange boundary* of the
compiler. The paper's flow starts from "a description of a FFCL module in
Verilog format" — NullaNet (core/nullanet.py) emits one netlist per neuron
after two-level minimization (core/espresso.py) and multi-level
restructuring (core/synth.py); ABC/Yosys-normalized third-party netlists
enter the same way. ``parse_verilog`` turns that text into the
:class:`~repro.core.gate_ir.LogicGraph` every downstream stage (levelize ->
schedule -> kernel/serving) consumes, and ``emit_verilog`` closes the loop
for hand-off back to HLS/FPGA tooling (round-trip tested in
tests/test_gate_ir.py).

We support the gate-level subset those tools emit:

  module m(a, b, y);
    input a, b;  output y;  wire w1;
    and g0 (w1, a, b);          // gate primitives: and/or/xor/nand/nor/xnor/
    assign y = ~(w1 ^ b);       // not/buf; or assign with ~ & | ^ and parens
  endmodule

Continuous assigns are parsed with a tiny recursive-descent expression parser
and decomposed into 2-input gates on the fly; statements may appear in any
order (netlists need not be topologically sorted).

>>> import numpy as np
>>> g = parse_verilog('''
...   module m(a, b, y);
...     input a, b;  output y;  wire w1;
...     and g0 (w1, a, b);
...     assign y = ~(w1 ^ b);
...   endmodule''')
>>> g.n_inputs, g.n_outputs, g.n_gates   # and, xor, not
(2, 1, 3)
>>> bool(g.evaluate(np.array([[1, 1]], dtype=bool))[0, 0])  # ~((a&b)^b) = 1
True
>>> parse_verilog(emit_verilog(g)).n_gates                  # round-trips
3
"""
from __future__ import annotations

import re

from repro.core.gate_ir import CONST0, CONST1, LogicGraph, OpCode

_PRIMS = {"and": OpCode.AND, "or": OpCode.OR, "xor": OpCode.XOR,
          "nand": OpCode.NAND, "nor": OpCode.NOR, "xnor": OpCode.XNOR,
          "not": OpCode.NOT, "buf": OpCode.COPY}

_TOKEN = re.compile(r"\s*(\(|\)|~|\^|&|\||1'b[01]|[A-Za-z_][A-Za-z0-9_$\[\]]*)")


class _ExprParser:
    """Precedence: ~  >  &  >  ^  >  |   (Verilog)."""

    def __init__(self, text: str, lookup, emit):
        self.toks = _TOKEN.findall(text)
        self.pos = 0
        self.lookup = lookup   # name -> wire id
        self.emit = emit       # (op, a, b) -> wire id

    def peek(self):
        return self.toks[self.pos] if self.pos < len(self.toks) else None

    def take(self):
        t = self.peek()
        self.pos += 1
        return t

    def parse(self) -> int:
        w = self._or()
        if self.peek() is not None:
            raise ValueError(f"trailing tokens: {self.toks[self.pos:]}")
        return w

    def _or(self) -> int:
        w = self._xor()
        while self.peek() == "|":
            self.take()
            w = self.emit(OpCode.OR, w, self._xor())
        return w

    def _xor(self) -> int:
        w = self._and()
        while self.peek() == "^":
            self.take()
            w = self.emit(OpCode.XOR, w, self._and())
        return w

    def _and(self) -> int:
        w = self._unary()
        while self.peek() == "&":
            self.take()
            w = self.emit(OpCode.AND, w, self._unary())
        return w

    def _unary(self) -> int:
        t = self.take()
        if t == "~":
            return self.emit(OpCode.NOT, self._unary(), CONST0)
        if t == "(":
            w = self._or()
            if self.take() != ")":
                raise ValueError("expected ')'")
            return w
        if t == "1'b0":
            return CONST0
        if t == "1'b1":
            return CONST1
        return self.lookup(t)


def parse_verilog(text: str) -> LogicGraph:
    """Parse a single gate-level module into a LogicGraph."""
    text = re.sub(r"//.*?$", "", text, flags=re.M)
    text = re.sub(r"/\*.*?\*/", "", text, flags=re.S)
    m = re.search(r"module\s+([A-Za-z_][\w$]*)\s*\((.*?)\)\s*;(.*?)endmodule",
                  text, flags=re.S)
    if not m:
        raise ValueError("no module found")
    name, _, body = m.groups()

    def split_decl(kind: str) -> list[str]:
        names: list[str] = []
        for dm in re.finditer(rf"\b{kind}\b\s*(.*?);", body, flags=re.S):
            names.extend(n.strip() for n in dm.group(1).split(",") if n.strip())
        return names

    inputs, outputs = split_decl("input"), split_decl("output")
    graph = LogicGraph(len(inputs), name=name)
    wires: dict[str, int] = {nm: graph.input_wire(i)
                             for i, nm in enumerate(inputs)}

    pending: list[tuple] = []  # statements awaiting operand definitions
    for stmt in re.split(r";", body):
        stmt = stmt.strip()
        if not stmt or re.match(r"\b(input|output|wire)\b", stmt):
            continue
        gm = re.match(r"(\w+)\s+[A-Za-z_][\w$]*\s*\(\s*([^)]*)\)", stmt)
        am = re.match(r"assign\s+([A-Za-z_][\w$\[\]]*)\s*=\s*(.*)", stmt,
                      flags=re.S)
        if gm and gm.group(1) in _PRIMS:
            args = [a.strip() for a in gm.group(2).split(",")]
            pending.append(("gate", _PRIMS[gm.group(1)], args[0], args[1:]))
        elif am:
            pending.append(("assign", am.group(1), am.group(2)))
        elif stmt:
            raise ValueError(f"unsupported statement: {stmt!r}")

    def lookup(nm: str) -> int:
        if nm == "1'b0":
            return CONST0
        if nm == "1'b1":
            return CONST1
        if nm not in wires:
            raise KeyError(nm)
        return wires[nm]

    def emit(op: OpCode, a: int, b: int) -> int:
        return graph.add_gate(op, a, b)

    # iterate until all statements resolve (netlists need not be in topo order)
    remaining = pending
    while remaining:
        progressed, nxt = False, []
        for item in remaining:
            try:
                if item[0] == "gate":
                    _, op, out, ins = item
                    srcs = [lookup(x) for x in ins]
                    a = srcs[0]
                    b = srcs[1] if len(srcs) > 1 else CONST0
                    w = a if (op == OpCode.COPY) else graph.add_gate(op, a, b)
                    for extra in srcs[2:]:  # n-ary primitive: chain
                        w = graph.add_gate(op, w, extra)
                    wires[out] = w
                else:
                    _, out, expr = item
                    wires[out] = _ExprParser(expr, lookup, emit).parse()
                progressed = True
            except KeyError:
                nxt.append(item)
        if not progressed:
            raise ValueError(f"unresolvable statements (cycle?): {nxt[:3]}")
        remaining = nxt

    graph.set_outputs(wires[o] for o in outputs)
    return graph


_OP_NAMES = {int(v): k for k, v in _PRIMS.items()}


def emit_verilog(graph: LogicGraph) -> str:
    """Emit the graph back as gate-level Verilog (round-trip tested).

    Graph names are free-form (partitioning emits ``<name>.part``, flows
    emit ``hidden-stack``); they are sanitized into legal Verilog
    identifiers here.
    """
    name = re.sub(r"[^A-Za-z0-9_$]", "_", graph.name) or "ffcl"
    if not re.match(r"[A-Za-z_]", name):
        name = f"m_{name}"
    ins = [f"i{k}" for k in range(graph.n_inputs)]
    outs = [f"o{k}" for k in range(graph.n_outputs)]
    lines = [f"module {name}({', '.join(ins + outs)});"]
    if ins:
        lines.append(f"  input {', '.join(ins)};")
    if outs:
        lines.append(f"  output {', '.join(outs)};")
    names = {CONST0: "1'b0", CONST1: "1'b1"}
    for i in range(graph.n_inputs):
        names[graph.input_wire(i)] = ins[i]
    gate_wires = [f"w{j}" for j in range(graph.n_gates)]
    if gate_wires:
        lines.append(f"  wire {', '.join(gate_wires)};")
    base = graph.first_gate_wire
    for j, (op, a, b) in enumerate(graph.gates):
        names[base + j] = gate_wires[j]
        if OpCode(op) == OpCode.NOP:
            # NOP gates produce constant 0 on their wire (gate_ir semantics);
            # structural Verilog has no nop primitive, so emit the constant.
            lines.append(f"  buf g{j} ({gate_wires[j]}, 1'b0);")
            continue
        prim = _OP_NAMES[int(op)]
        if OpCode(op) in (OpCode.NOT, OpCode.COPY):
            lines.append(f"  {prim} g{j} ({gate_wires[j]}, {names[a]});")
        else:
            lines.append(
                f"  {prim} g{j} ({gate_wires[j]}, {names[a]}, {names[b]});")
    for k, o in enumerate(graph.outputs):
        lines.append(f"  assign {outs[k]} = {names[o]};")
    lines.append("endmodule")
    return "\n".join(lines)
