"""Measurement-calibrated wall-clock cost model (DESIGN.md §12).

The eq. 22/23 model (core/cost_model.py) counts *cycles* and predicts
scheduled steps well (fig6 max error <6%), but the ``n_unit="auto"``
design-space search ultimately cares about what the fused
pack -> kernel -> unpack path costs *in seconds on the running backend*.
This module imports the SUMMA/WSE-2 performance-model discipline:

  1. decompose one execution into four phases —

         pack    H2D transfer + bit packing of the input batch
         setup   program-stream upload (addresses / opcodes / branches)
         kernel  the sub-kernel step loop itself
         unpack  result unpacking + D2H transfer

     each timed behind ``block_until_ready`` (kernels/logic_dsp/ops.py
     ``phased_infer_bits``; the numpy oracle records the same shape);

  2. map each phase to the cost-model regressors that drive it
     (:func:`phase_terms`) and fit ``seconds = coefs . regressors +
     offset`` per phase by least squares over a seeded grid of
     workloads x ``n_unit`` probes (:func:`fit_calibration`).  The
     kernel phase carries TWO regressors — the eq. 23 step count and
     the eq. 20 loop-cycles term — because measured step time has a
     fixed per-step overhead axis (loop trip count) and a slab-width
     axis (units x words) whose real ratio differs from the modelled
     fabric constants; one scale cannot fit both;

  3. expose the fitted model as :class:`WallClockModel`, a
     seconds-objective twin of :class:`~repro.core.cost_model.CostModel`
     that ``optimizer.binary_search(..., objective="wallclock")`` and
     ``CompileSpec(n_unit="auto", objective="wallclock")`` descend.

Degenerate calibration inputs (fewer than two probes, a zero-variance
phase regressor, gateless probe programs, non-finite measurements)
raise a typed :class:`CalibrationError` — never a silent NaN factor
propagated into the DSE; callers fall back to the cycles objective
explicitly.

Fitted :class:`Calibration` values round-trip through ``to_dict`` /
``from_dict`` and persist via ``ArtifactStore.save_calibration`` so warm
processes never re-fit (:func:`fit_count` is the counter the CLI smoke
pins, like the warm-start zero-compile pin).

This module imports numpy only; everything touching jax or the
scheduler is imported lazily inside the measurement helpers, so the
hot-path hook (``_ACTIVE`` below) costs one attribute read when
disabled.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

import numpy as np

from repro.core.cost_model import (CostModel, FfclStats, normalize_layers,
                                   n_subkernels)

__all__ = [
    "PHASES", "CalibrationError", "PhaseTimer", "active_timer",
    "phase_terms", "PhaseProbe", "PhaseFit", "Calibration",
    "fit_calibration", "fit_count", "WallClockModel",
    "measure_program_phases", "default_probe_graphs", "default_probe_units",
    "collect_probes",
]

#: The phase decomposition, in execution order.
PHASES = ("pack", "setup", "kernel", "unpack")

#: Regressor names per phase (documentation of :func:`phase_terms`'s
#: tuple layout; the fit stores one coefficient per entry).
PHASE_REGRESSORS = {
    "pack": ("n_copy_mem_in",),
    "setup": ("n_read_addr_mem",),
    "kernel": ("n_subkernels", "n_step_width"),
    "unpack": ("n_outputs_drain",),
}

#: Schema version of the persisted calibration record.
FORMAT_VERSION = 1

#: The kernel layer pads each step's unit axis to this multiple with
#: NOP rows that still execute (``kernels.logic_dsp.ops.program_arrays``
#: sublane padding) — so the *executed* slab width at ``n_unit=u`` is
#: ``ceil(u / PAD_UNIT) * PAD_UNIT``, and the kernel phase's width
#: regressor must use the padded width or the fit systematically
#: under-predicts unaligned unit counts.
PAD_UNIT = 8


class CalibrationError(RuntimeError):
    """A calibration could not be fitted, loaded, or applied.

    Raised on degenerate fit inputs (single probe, zero-variance phase
    regressor, gateless probe programs, non-finite measurements), on
    invalid serialized records, and on a ``wallclock`` objective with no
    calibration available.  Callers fall back to the ``cycles``
    objective — the typed error makes that fallback explicit, never a
    NaN factor silently steering the DSE."""


# ---------------------------------------------------------------------------
# phase-timing hook (the hot-path seam)
# ---------------------------------------------------------------------------

# The active timer, or None.  The instrumented runners
# (kernels/logic_dsp/ops.py, scheduler.execute_program_np) check this
# one module attribute per call — zero overhead when disabled.
_ACTIVE: "PhaseTimer | None" = None


class PhaseTimer:
    """Collects per-phase wall-clock samples from instrumented runners.

    Use as a context manager; while active, ``logic_infer_bits`` routes
    through the phased path and ``execute_program_np`` records its
    pack/setup/kernel/unpack split::

        with PhaseTimer() as t:
            logic_infer_bits(prog, bits)
        t.samples[0]["phases"]   # {"pack": s, "setup": s, ...}

    Timers nest (the previous active timer is restored on exit); each
    sample carries the phases dict plus free-form ``meta`` keys from the
    recording site (backend, n_unit, batch).
    """

    def __init__(self):
        self.samples: list[dict] = []
        self._prev: PhaseTimer | None = None

    def record(self, phases: dict, **meta) -> None:
        self.samples.append({"phases": dict(phases), "meta": dict(meta)})

    def __enter__(self) -> "PhaseTimer":
        global _ACTIVE
        self._prev = _ACTIVE
        _ACTIVE = self
        return self

    def __exit__(self, *exc) -> bool:
        global _ACTIVE
        _ACTIVE = self._prev
        return False


def active_timer() -> PhaseTimer | None:
    """The currently-installed :class:`PhaseTimer` (None when disabled)."""
    return _ACTIVE


# ---------------------------------------------------------------------------
# phase <-> cost-model regressor mapping
# ---------------------------------------------------------------------------

def phase_terms(model: CostModel, stats: FfclStats, n_unit: int,
                n_input_vectors: int) -> dict[str, tuple]:
    """The cost-model regressors (in cycles/steps) driving each phase.

    pack    <- eq. 18 input replication (``n_copy_mem_in``): linear in
               ``n_fanin * W``, independent of ``n_unit`` — like the
               measured H2D + packing time.
    setup   <- eq. 6/9 address-stream movement (``n_read_addr_mem``):
               linear in the program-stream footprint ``3 * n_unit *
               n_subkernels`` the setup phase uploads.
    kernel  <- (eq. 23 step count ``n_subkernels``, width work
               ``n_subkernels * n_unit``): the step count carries the
               real per-step fixed overhead (dispatch, loop control),
               the width term the units-x-words slab work.  The raw
               ``nsk * u`` product is used rather than eq. 20's
               ``n_loop_subkernels`` because the latter bakes in the
               fabric's 40-cycle per-step constant — far larger than
               the measured per-step overhead relative to the width
               slope, which would force a negative step-count
               coefficient in that basis.  Both raw-basis coefficients
               are physically non-negative, and ``nsk * u`` is strictly
               increasing within each ceil-staircase plateau, so the
               plateau-edge exact search stays valid.
    unpack  <- output drain (``n_outputs_drain``): linear in
               ``n_outputs * W``, like unpacking + D2H.

    The mapping deliberately avoids ``n_read_inputs_opcode_mem`` for the
    pack phase: its opcode-bytes component varies with ``n_unit`` while
    measured pack time does not, which would pollute the fit.
    """
    b = model.breakdown(stats, n_unit, n_input_vectors)
    nsk = float(n_subkernels(stats, n_unit))
    padded_u = -(-int(n_unit) // PAD_UNIT) * PAD_UNIT
    return {"pack": (b.n_copy_mem_in,),
            "setup": (b.n_read_addr_mem,),
            "kernel": (nsk, nsk * padded_u),
            "unpack": (b.n_outputs_drain,)}


# ---------------------------------------------------------------------------
# probes and fitting
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class PhaseProbe:
    """One (workload, n_unit) measurement: modelled regressors vs
    seconds."""

    label: str
    n_unit: int
    n_input_vectors: int
    n_gates: int
    terms: dict            # phase -> regressor tuple (phase_terms)
    measured: dict         # phase -> seconds (min over reps)


@dataclass(frozen=True)
class PhaseFit:
    """``seconds = coefs . regressors + offset`` for one phase."""

    coefs: tuple           # one >= 0 coefficient per phase regressor
    offset: float          # fixed seconds per call (>= 0)
    n_probes: int
    median_abs_rel_err: float  # |pred - measured| / measured over probes

    def predict(self, terms) -> float:
        terms = tuple(terms)
        if len(terms) != len(self.coefs):
            raise CalibrationError(
                f"phase expects {len(self.coefs)} regressor(s), got "
                f"{len(terms)}: {terms!r}")
        return float(sum(c * float(t) for c, t in zip(self.coefs, terms))
                     + self.offset)


@dataclass(frozen=True)
class Calibration:
    """A complete fitted per-phase wall-clock calibration."""

    fits: dict = field(default_factory=dict)   # phase -> PhaseFit
    meta: dict = field(default_factory=dict)   # provenance (host, grid, ...)

    def __post_init__(self):
        missing = [p for p in PHASES if p not in self.fits]
        if missing:
            raise CalibrationError(
                f"calibration is missing phase fits for {missing}; "
                f"need all of {PHASES}")
        for p, f in self.fits.items():
            vals = (*f.coefs, f.offset)
            if not all(math.isfinite(v) and v >= 0.0 for v in vals):
                raise CalibrationError(
                    f"non-finite/negative factors for phase {p!r}: "
                    f"coefs={f.coefs!r} offset={f.offset!r}")

    def predict(self, terms: dict) -> dict:
        """Per-phase predicted seconds for one call, plus ``"total"``."""
        out = {p: self.fits[p].predict(terms[p]) for p in PHASES}
        out["total"] = sum(out[p] for p in PHASES)
        return out

    def seconds(self, terms: dict) -> float:
        total = sum(self.fits[p].predict(terms[p]) for p in PHASES)
        if not math.isfinite(total):
            raise CalibrationError(
                f"calibrated prediction is non-finite for terms {terms!r}")
        return total

    def median_abs_rel_err(self) -> float:
        """Worst phase's median |pred-measured|/measured from the fit."""
        return max(f.median_abs_rel_err for f in self.fits.values())

    # -- JSON round-trip ----------------------------------------------------

    def to_dict(self) -> dict:
        return {
            "format_version": FORMAT_VERSION,
            "phases": {p: {"coefs": list(f.coefs), "offset": f.offset,
                           "n_probes": f.n_probes,
                           "median_abs_rel_err": f.median_abs_rel_err}
                       for p, f in self.fits.items()},
            "meta": dict(self.meta),
        }

    @classmethod
    def from_dict(cls, d: dict) -> "Calibration":
        if not isinstance(d, dict):
            raise CalibrationError(
                f"calibration record must be a dict, got {type(d).__name__}")
        if d.get("format_version") != FORMAT_VERSION:
            raise CalibrationError(
                f"calibration format_version {d.get('format_version')!r} "
                f"!= {FORMAT_VERSION}; refit with this build")
        phases = d.get("phases")
        if not isinstance(phases, dict):
            raise CalibrationError("calibration record has no 'phases' map")
        try:
            fits = {p: PhaseFit(coefs=tuple(float(c) for c in f["coefs"]),
                                offset=float(f["offset"]),
                                n_probes=int(f["n_probes"]),
                                median_abs_rel_err=float(
                                    f["median_abs_rel_err"]))
                    for p, f in phases.items()}
        except (KeyError, TypeError, ValueError) as exc:
            raise CalibrationError(
                f"malformed calibration phase record: {exc!r}") from exc
        return cls(fits=fits, meta=dict(d.get("meta", {})))


_fits = 0


def fit_count() -> int:
    """Number of :func:`fit_calibration` runs in this process — the
    counter the warm-start CLI smoke pins to 0 for a store-loaded
    calibration (a fresh process must never silently re-fit)."""
    return _fits


def _nnls_fit(X: np.ndarray, y: np.ndarray) -> np.ndarray:
    """Least squares with all coefficients clamped >= 0: solve
    unconstrained, then iteratively freeze negative coefficients at 0
    and re-solve the rest (columns of ``X`` include the intercept)."""
    active = list(range(X.shape[1]))
    coefs = np.zeros(X.shape[1])
    for _ in range(X.shape[1] + 1):
        if not active:
            break
        sol, *_ = np.linalg.lstsq(X[:, active], y, rcond=None)
        if (sol >= 0).all():
            coefs[active] = sol
            break
        active = [a for a, s in zip(active, sol) if s >= 0]
    return coefs


def fit_calibration(probes: list[PhaseProbe],
                    meta: dict | None = None) -> Calibration:
    """Least-squares fit of per-phase coefficient/offset factors.

    Raises :class:`CalibrationError` on degenerate inputs — fewer than
    two probes, any gateless probe program (its kernel phase runs the
    reference fallback, a different backend), a zero-variance phase
    regressor (nothing to fit against), or non-finite measurements /
    regressors.  Coefficients and offsets are constrained ``>= 0`` so
    the model never predicts negative seconds.
    """
    global _fits
    if len(probes) < 2:
        raise CalibrationError(
            f"calibration needs >= 2 probes to fit coefs+offset, got "
            f"{len(probes)}; widen the workload x n_unit grid")
    gateless = [p.label for p in probes if p.n_gates <= 0]
    if gateless:
        raise CalibrationError(
            f"gateless probe program(s) {sorted(set(gateless))}: the "
            "kernel phase would measure the reference fallback, not the "
            "step loop; calibrate on graphs with gates")
    fits: dict[str, PhaseFit] = {}
    for phase in PHASES:
        arity = len(PHASE_REGRESSORS[phase])
        T = np.array([[float(v) for v in p.terms[phase]] for p in probes])
        y = np.array([float(p.measured[phase]) for p in probes])
        if T.shape != (len(probes), arity):
            raise CalibrationError(
                f"phase {phase!r} expects {arity} regressor(s) per probe, "
                f"got shape {T.shape}")
        if not (np.isfinite(T).all() and np.isfinite(y).all()):
            raise CalibrationError(
                f"non-finite regressor/measurement in phase {phase!r}: "
                f"terms={T.tolist()} measured={y.tolist()}")
        if (y < 0).any():
            raise CalibrationError(
                f"negative measured seconds in phase {phase!r}: {y.tolist()}")
        flat = [j for j in range(arity) if np.ptp(T[:, j]) == 0.0]
        if flat:
            names = [PHASE_REGRESSORS[phase][j] for j in flat]
            raise CalibrationError(
                f"zero-variance regressor(s) {names} for phase {phase!r}: "
                "the grid must vary the workload/n_unit axis this phase "
                "depends on")
        X = np.concatenate([T, np.ones((len(probes), 1))], axis=1)
        sol = _nnls_fit(X, y)
        coefs, offset = sol[:-1], float(sol[-1])
        pred = X @ sol
        if not np.isfinite(pred).all():
            raise CalibrationError(
                f"fit for phase {phase!r} produced non-finite predictions")
        with np.errstate(divide="ignore", invalid="ignore"):
            rel = np.where(y > 0, np.abs(pred - y) / np.where(y > 0, y, 1.0),
                           np.abs(pred - y))
        fits[phase] = PhaseFit(coefs=tuple(float(c) for c in coefs),
                               offset=offset, n_probes=len(probes),
                               median_abs_rel_err=float(np.median(rel)))
    _fits += 1
    return Calibration(fits=fits, meta=dict(meta or {}))


# ---------------------------------------------------------------------------
# the seconds-objective model the DSE descends
# ---------------------------------------------------------------------------

class WallClockModel:
    """Seconds-objective twin of :class:`~repro.core.cost_model.CostModel`.

    ``optimizer.binary_search(..., objective="wallclock")`` calls
    :meth:`network_seconds`; :meth:`network_cycles` delegates to the
    wrapped cycles model, so one object can serve both objectives (the
    compiler records both picks in the DSE provenance).

    Unlike eq. 2's pipelined ``max(dm, comp)``, the measured fused path
    runs its phases *sequentially* (one process, one device queue), so a
    module costs the *sum* of its calibrated phases, and a layer's
    ``n_copies`` structurally-like modules cost ``n_copies`` times that.

    Every phase regressor is constant or increasing in ``n_unit`` on the
    intervals where the ceil-staircase step count is flat (same
    structure as the cycles model), so ``optimizer.binary_search``'s
    plateau-edge enumeration stays exact for this objective too.
    """

    def __init__(self, calibration: Calibration,
                 model: CostModel | None = None):
        if not isinstance(calibration, Calibration):
            raise CalibrationError(
                f"WallClockModel needs a Calibration, got "
                f"{type(calibration).__name__}")
        self.calibration = calibration
        self.model = model or CostModel()

    def module_seconds(self, stats: FfclStats, n_unit: int,
                       n_input_vectors: int) -> float:
        terms = phase_terms(self.model, stats, n_unit, n_input_vectors)
        return self.calibration.seconds(terms)

    def network_seconds(self, layers, n_unit: int,
                        parallel_factor: int = 1) -> float:
        tot = 0.0
        for lw in normalize_layers(layers):
            tot += lw.n_copies * self.module_seconds(
                lw.stats, n_unit, lw.n_input_vectors)
        return tot / parallel_factor

    def network_cycles(self, layers, n_unit: int,
                       parallel_factor: int = 1) -> float:
        return self.model.network_cycles(layers, n_unit, parallel_factor)


# ---------------------------------------------------------------------------
# measurement helpers (lazy jax / scheduler imports)
# ---------------------------------------------------------------------------

def measure_program_phases(prog, n_input_vectors: int, reps: int = 3,
                           seed: int = 0, *,
                           interpret: bool = True) -> dict[str, float]:
    """Min-over-reps seconds per phase for one compiled program.

    Warms the phased runner first (trace + compile excluded), then takes
    the per-phase minimum over ``reps`` timed executions — the noise
    floor on a shared host, which is what the calibration should map the
    model regressors onto."""
    from repro.kernels.logic_dsp.ops import phased_infer_bits
    rng = np.random.default_rng(seed)
    bits = rng.integers(0, 2, size=(n_input_vectors, prog.n_inputs))
    bits = bits.astype(bool)
    phased_infer_bits(prog, bits, interpret=interpret)          # warm
    best = {p: math.inf for p in PHASES}
    for _ in range(max(1, reps)):
        _, phases = phased_infer_bits(prog, bits, interpret=interpret)
        for p in PHASES:
            best[p] = min(best[p], phases[p])
    return best


def default_probe_graphs(quick: bool = True, seed: int = 2024) -> dict:
    """The seeded calibration workload grid (shared by the benchmark
    harness, the CLI, and tests — same seed, same graphs)."""
    from repro.core.gate_ir import random_graph
    rng = np.random.default_rng(seed)
    if quick:
        shapes = [(16, 300, 12, 64), (24, 900, 16, 96), (32, 1800, 24, 128)]
    else:
        shapes = [(16, 300, 12, 64), (24, 900, 16, 96), (32, 1800, 24, 128),
                  (48, 3600, 32, 192), (64, 7200, 48, 256)]
    return {f"g{n_gates}": random_graph(rng, n_inputs, n_gates, n_outputs,
                                        locality=loc)
            for n_inputs, n_gates, n_outputs, loc in shapes}


def default_probe_units(quick: bool = True) -> tuple[int, ...]:
    """The seeded ``n_unit`` probe axis matching
    :func:`default_probe_graphs`.  Five points even in quick mode: with
    three the per-step vs slab-width split of the kernel fit is barely
    conditioned and the resulting picks drift outside the DSE gate."""
    return (8, 16, 32, 64, 128) if quick else (8, 16, 32, 64, 128, 256)


def collect_probes(graphs: dict, n_units, n_input_vectors: int = 1024,
                   model: CostModel | None = None, reps: int = 3,
                   *, interpret: bool = True) -> list[PhaseProbe]:
    """Compile and measure every (workload, n_unit) grid point.

    Probes compile with ``optimize="none"`` (the grid graphs are the
    workload — the fit must see exactly the closed-form stats the DSE
    will probe) and use ``FfclStats.from_graph`` regressors, the same
    eq. 23 path ``WallClockModel`` predicts with.

    All grid points are measured INTERLEAVED: every program is compiled
    and trace-warmed up front, then ``reps`` round-robin passes take one
    timed execution per point each, keeping the per-phase minimum.
    Measuring points sequentially (all reps of one point, then the next)
    lets slow host drift over the collection window masquerade as
    ``n_unit`` dependence and visibly destabilizes the fitted
    coefficients run-to-run.
    """
    from repro.core.scheduler import compile_graph
    from repro.core.spec import CompileSpec
    from repro.kernels.logic_dsp.ops import phased_infer_bits
    model = model or CostModel()
    rng = np.random.default_rng(0)
    grid = []
    for label, g in graphs.items():
        stats = FfclStats.from_graph(g)
        bits = rng.integers(0, 2, (n_input_vectors, g.n_inputs))
        bits = bits.astype(bool)
        for u in n_units:
            prog = compile_graph(g, CompileSpec(n_unit=int(u),
                                                optimize="none"))
            phased_infer_bits(prog, bits, interpret=interpret)    # warm
            grid.append((label, g, stats, int(u), prog, bits,
                         {p: math.inf for p in PHASES}))
    for _ in range(max(1, reps)):
        for _, _, _, _, prog, bits, best in grid:
            _, phases = phased_infer_bits(prog, bits, interpret=interpret)
            for p in PHASES:
                best[p] = min(best[p], phases[p])
    return [PhaseProbe(label=label, n_unit=u,
                       n_input_vectors=n_input_vectors, n_gates=g.n_gates,
                       terms=phase_terms(model, stats, u, n_input_vectors),
                       measured=dict(best))
            for label, g, stats, u, _, _, best in grid]
