"""NullaNet flow (paper §7): binarized NN -> per-neuron Boolean functions.

Pipeline (faithful to [Nazemi et al. 2019] / NullaNet Tiny as summarized in
the paper): train a DNN with binary activations; per neuron, form a Boolean
specification either by *input enumeration* (fanin <= ``ENUM_LIMIT``) or as
an *incompletely specified function* (ISF) sampled on the training set; run
two-level minimization; factor into 2-input gates -> LogicGraph -> the FFCL
compiler (scheduler.py). First/last layers stay full-precision (paper §8.3).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import espresso
from repro.core.gate_ir import LogicGraph
from repro.optim import adamw_init, adamw_update

ENUM_LIMIT = 14  # paper §7.1: enumeration applicable to <= ~14 inputs


# ---------------------------------------------------------------------------
# Binarized MLP (training substrate)
# ---------------------------------------------------------------------------

def _ste_sign01(y: jnp.ndarray) -> jnp.ndarray:
    """Binary {0,1} activation with tanh straight-through gradient."""
    soft = 0.5 * (jnp.tanh(y) + 1.0)
    hard = (y >= 0).astype(jnp.float32)
    return soft + jax.lax.stop_gradient(hard - soft)


@dataclass(frozen=True)
class BinaryMLPConfig:
    n_features: int
    hidden: tuple[int, ...]
    n_classes: int
    seed: int = 0


def init_binary_mlp(cfg: BinaryMLPConfig) -> dict:
    rng = np.random.default_rng(cfg.seed)
    sizes = [cfg.n_features, *cfg.hidden, cfg.n_classes]
    params = {}
    for i, (fin, fout) in enumerate(zip(sizes[:-1], sizes[1:])):
        params[f"w{i}"] = jnp.asarray(
            rng.normal(0, (2.0 / fin) ** 0.5, size=(fin, fout)),
            dtype=jnp.float32)
        params[f"b{i}"] = jnp.zeros((fout,), jnp.float32)
    return params


def binary_mlp_forward(params: dict, x01: jnp.ndarray, n_layers: int,
                       return_activations: bool = False,
                       activation: str = "sign"):
    """x01: {0,1} features. Hidden activations binarized; last layer linear.

    ``activation='relu'`` swaps the binarized hidden activations for ReLU
    (full-precision) — the float upper-bound baseline of the end-to-end
    accuracy-parity study (flow/report.py); it is never FFCL-convertible.
    """
    if activation not in ("sign", "relu"):
        raise ValueError(f"unknown activation {activation!r}; "
                         "use 'sign' or 'relu'")
    acts = [x01]
    h = 2.0 * x01.astype(jnp.float32) - 1.0   # +-1 encoding into the matmul
    for i in range(n_layers - 1):
        y = h @ params[f"w{i}"] + params[f"b{i}"]
        if activation == "relu":
            acts.append(jax.nn.relu(y))
            h = acts[-1]
        else:
            a01 = _ste_sign01(y)
            acts.append(a01)
            h = 2.0 * a01 - 1.0
    logits = h @ params[f"w{n_layers - 1}"] + params[f"b{n_layers - 1}"]
    if return_activations:
        return logits, acts
    return logits


def train_binary_mlp(cfg: BinaryMLPConfig, x: np.ndarray, y: np.ndarray,
                     steps: int = 300, batch: int = 256, lr: float = 2e-3,
                     log_every: int = 0, activation: str = "sign") -> dict:
    n_layers = len(cfg.hidden) + 1
    params = init_binary_mlp(cfg)
    state = adamw_init(params)
    x = jnp.asarray(x, jnp.float32)
    y = jnp.asarray(y, jnp.int32)

    def loss_fn(p, xb, yb):
        logits = binary_mlp_forward(p, xb, n_layers, activation=activation)
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(logp, yb[:, None], axis=1))

    @jax.jit
    def step_fn(p, s, xb, yb):
        loss, grads = jax.value_and_grad(loss_fn)(p, xb, yb)
        p, s = adamw_update(grads, s, p, lr=lr, weight_decay=0.0)
        return p, s, loss

    rng = np.random.default_rng(cfg.seed + 1)
    for t in range(steps):
        idx = rng.integers(0, x.shape[0], size=batch)
        params, state, loss = step_fn(params, state, x[idx], y[idx])
        if log_every and t % log_every == 0:
            print(f"step {t}: loss {float(loss):.4f}")
    return params


def mlp_accuracy(params: dict, cfg: BinaryMLPConfig, x: np.ndarray,
                 y: np.ndarray, activation: str = "sign") -> float:
    n_layers = len(cfg.hidden) + 1
    logits = binary_mlp_forward(params, jnp.asarray(x, jnp.float32), n_layers,
                                activation=activation)
    return float(jnp.mean(jnp.argmax(logits, -1) == jnp.asarray(y)))


# ---------------------------------------------------------------------------
# Boolean specification extraction
# ---------------------------------------------------------------------------

def neuron_isf(x_bits: np.ndarray, w: np.ndarray, b: float
               ) -> tuple[np.ndarray, np.ndarray]:
    """ISF of one neuron sampled on observed inputs (paper §7.1).

    x_bits: (N, fanin) {0,1}. Neuron fires iff (2x-1)@w + b >= 0.
    Returns deduplicated (X_on, X_off) minterm arrays.
    """
    x_bits = np.asarray(x_bits, dtype=np.uint8)
    acts = ((2.0 * x_bits - 1.0) @ np.asarray(w) + b) >= 0
    pats, idx = np.unique(x_bits, axis=0, return_index=True)
    out = acts[idx]
    return pats[out], pats[~out]


def neuron_enumerated(w: np.ndarray, b: float) -> tuple[np.ndarray, np.ndarray]:
    """Complete truth table by input enumeration (fanin <= ENUM_LIMIT)."""
    fanin = len(w)
    if fanin > ENUM_LIMIT:
        raise ValueError(f"enumeration limited to {ENUM_LIMIT} inputs")
    pats = ((np.arange(2 ** fanin)[:, None] >>
             np.arange(fanin)[None, :]) & 1).astype(np.uint8)
    acts = ((2.0 * pats - 1.0) @ np.asarray(w) + b) >= 0
    return pats[acts], pats[~acts]


def layer_to_graph(x_bits: np.ndarray, W: np.ndarray, b: np.ndarray,
                   mode: str = "auto", name: str = "layer",
                   optimize="default") -> LogicGraph:
    """Convert one binarized layer (all neurons, shared inputs) to a graph.

    mode: 'isf' | 'enum' | 'auto' (enum when fanin <= ENUM_LIMIT).
    optimize: gate-level optimization of the factored graph —
      ``"default"`` (the core/opt.py default pipeline), ``"none"`` (raw
      espresso factoring), or a :class:`~repro.core.opt.PassManager`.
    """
    fanin, n_neurons = W.shape
    if mode == "auto":
        mode = "enum" if fanin <= ENUM_LIMIT else "isf"
    cube_sets = []
    for j in range(n_neurons):
        if mode == "enum":
            x_on, x_off = neuron_enumerated(W[:, j], float(b[j]))
        else:
            x_on, x_off = neuron_isf(x_bits, W[:, j], float(b[j]))
        cubes = espresso.minimize(x_on, x_off)
        assert espresso.check_cover(cubes, x_on, x_off), \
            f"minimization broke neuron {j}"
        cube_sets.append(cubes)
    return espresso.sop_to_graph(cube_sets, n_inputs=fanin, name=name,
                                 optimize=optimize)


# ---------------------------------------------------------------------------
# End-to-end logic network
# ---------------------------------------------------------------------------

@dataclass
class LogicNetwork:
    """Hidden layers as FFCL graphs + full-precision output head."""

    graphs: list[LogicGraph]
    w_out: np.ndarray
    b_out: np.ndarray

    def predict(self, x_bits: np.ndarray, executor=None) -> np.ndarray:
        """executor(graph, bits)->bits; defaults to LogicGraph.evaluate."""
        h = np.asarray(x_bits, dtype=np.uint8)
        for g in self.graphs:
            run = executor or (lambda gr, xb: gr.evaluate(xb))
            h = run(g, h.astype(bool)).astype(np.uint8)
        logits = (2.0 * h - 1.0) @ self.w_out + self.b_out
        return np.argmax(logits, axis=-1)


def mlp_to_logic_network(params: dict, cfg: BinaryMLPConfig, x: np.ndarray,
                         mode: str = "auto") -> LogicNetwork:
    """Full NullaNet conversion of the hidden stack of a trained MLP.

    Thin wrapper over the flow conversion path (flow/convert.py, the
    single conversion code path): calibration activations come from the
    float64 hard forward — not the STE float32 training forward — so the
    ISF care-sets sample exactly the Boolean function the logic must
    reproduce (DESIGN.md §6.2). Graph-only (callers schedule at their own
    ``n_unit``); the flow's :class:`LogicClassifier` is the compiled form.
    """
    from repro.flow.classifier import hard_forward, input_bits  # no cycle
    from repro.flow.convert import layer_graph
    n_layers = len(cfg.hidden) + 1
    params_np = {k: np.asarray(v) for k, v in params.items()}
    acts, _ = hard_forward(params_np, input_bits(x), n_layers)
    graphs = [layer_graph(params_np[f"w{i}"], params_np[f"b{i}"], acts[i],
                          mode=mode, name=f"layer{i}")
              for i in range(n_layers - 1)]
    return LogicNetwork(graphs=graphs,
                        w_out=params_np[f"w{n_layers - 1}"],
                        b_out=params_np[f"b{n_layers - 1}"])
