"""Pass-based logic-optimization pipeline over :class:`LogicGraph`.

The NullaNet/espresso synthesis path emits graphs with duplicate AND/OR
cones, constant-fed gates, and dead fanin; eq. 23 charges every one of
them as scheduled sub-kernel work. This module is the gate-level
optimization layer (DESIGN.md §7) that shrinks ``n_gates`` — and with it
``n_steps``, the VMEM-resident address streams, and partition cone sizes —
before anything is levelized or scheduled.

Architecture: small single-purpose *passes*, each a semantics-preserving
graph rewrite that returns the rewritten graph **plus a wire remap**
(:class:`PassResult`), composed by a :class:`PassManager` that iterates
them to a fixed point on ``(n_gates, depth)``.

The wire-remap contract (every pass, and the composed pipeline):

  * ``remap`` has one entry per wire of the *input* graph;
  * constants and primary inputs always map to themselves (passes never
    add, drop, or reorder primary inputs);
  * ``remap[w] == v >= 0`` means new wire ``v`` computes exactly the
    Boolean function old wire ``w`` computed (on every input assignment);
  * ``remap[w] == -1`` means the wire was dropped (dead code) — nothing
    may reference it afterwards (``gate_ir.remap_wires`` raises instead
    of silently gathering a corrupt id);
  * output lists are remapped in order, so multi-output ordering and
    ``compose_graphs`` chaining survive any pipeline.

Passes (ABC's ``resyn``-family stand-ins, on the 9-opcode DSP library):

  * :class:`ConstantFold`      — absorb CONST0/CONST1 operands through
    every opcode (incl. NOP -> CONST0: a NOP gate's wire is always 0);
  * :class:`SimplifyIdentities`— COPY elimination, double-NOT, NOT-fusion
    into the negated opcodes (NAND/NOR/XNOR...), idempotence /
    annihilation of ``op(x, x)``;
  * :class:`StructuralHash`    — common-subexpression elimination: dedupe
    ``(op, a, b)`` up to commutativity;
  * :class:`DeadGateElim`      — drop gates outside every output cone;
  * :class:`Rebalance`         — rebuild single-fanout associative chains
    as balanced trees (depth, not gate count).

``PassManager.default()`` is the pipeline every synthesis consumer routes
through via the shared ``optimize=`` knob: on by default in
``nullanet.layer_to_graph``, ``flow/convert.py``, and the serving engine;
opt-in (default ``"none"``) on the raw primitives
``espresso.sop_to_graph`` and ``scheduler.compile_graph`` (which runs it
before levelization), whose defaults preserve the paper-exact factoring
and eq. 23 contracts. ``serve.ProgramCache`` keys compiled programs on
the *post-optimization* fingerprint so structurally-equal requests share
one cache entry.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Sequence

import numpy as np

from repro.core.gate_ir import (ASSOCIATIVE, COMMUTATIVE, CONST0, CONST1,
                                LogicGraph, OpCode, UNARY, remap_wires)

# (op, const_operand_value) -> what the gate reduces to when one operand
# is that constant: ('const', v) | ('pass',) keep the other operand |
# ('not',) negate the other operand.
_CONST_RULES = {
    (OpCode.AND, 0): ("const", 0), (OpCode.AND, 1): ("pass",),
    (OpCode.OR, 0): ("pass",), (OpCode.OR, 1): ("const", 1),
    (OpCode.XOR, 0): ("pass",), (OpCode.XOR, 1): ("not",),
    (OpCode.NAND, 0): ("const", 1), (OpCode.NAND, 1): ("not",),
    (OpCode.NOR, 0): ("not",), (OpCode.NOR, 1): ("const", 0),
    (OpCode.XNOR, 0): ("not",), (OpCode.XNOR, 1): ("pass",),
}

# op applied to (x, x) -> result (idempotence / annihilation / involution)
_IDEMPOTENT_RULES = {
    OpCode.AND: ("pass",), OpCode.OR: ("pass",),
    OpCode.XOR: ("const", 0), OpCode.XNOR: ("const", 1),
    OpCode.NAND: ("not",), OpCode.NOR: ("not",),
}

_NEGATED = {OpCode.AND: OpCode.NAND, OpCode.NAND: OpCode.AND,
            OpCode.OR: OpCode.NOR, OpCode.NOR: OpCode.OR,
            OpCode.XOR: OpCode.XNOR, OpCode.XNOR: OpCode.XOR,
            OpCode.NOT: OpCode.COPY, OpCode.COPY: OpCode.NOT}


@dataclass(frozen=True)
class PassResult:
    """A rewritten graph plus the old-wire -> new-wire map (see module
    docstring for the remap contract)."""

    graph: LogicGraph
    remap: np.ndarray          # (old.n_wires,) int64; -1 = dropped


def identity_remap(graph: LogicGraph) -> np.ndarray:
    """The do-nothing remap (constants + inputs + every gate in place)."""
    return np.arange(graph.n_wires, dtype=np.int64)


def compose_remaps(first: np.ndarray, then: np.ndarray) -> np.ndarray:
    """Remap of running ``first`` and ``then`` back-to-back: dropped (-1)
    wires stay dropped; live wires gather through both maps."""
    out = np.full(len(first), -1, dtype=np.int64)
    live = first >= 0
    out[live] = then[first[live]]
    return out


def _prefix_remap(graph: LogicGraph) -> np.ndarray:
    """Fresh remap with constants + primary inputs mapped to themselves
    and every gate still unmapped (-1)."""
    repl = np.full(graph.n_wires, -1, dtype=np.int64)
    repl[:graph.first_gate_wire] = np.arange(graph.first_gate_wire)
    return repl


class Pass:
    """One semantics-preserving rewrite. Subclasses implement :meth:`run`
    and must honour the wire-remap contract of the module docstring."""

    name = "pass"

    def run(self, graph: LogicGraph) -> PassResult:
        raise NotImplementedError

    def __call__(self, graph: LogicGraph) -> PassResult:
        return self.run(graph)

    def __repr__(self) -> str:
        return f"{type(self).__name__}()"


class ConstantFold(Pass):
    """Constant folding / propagation through all 9 opcodes.

    A gate whose operand resolved to CONST0/CONST1 is absorbed by the
    rules table; NOP gates fold to CONST0 outright (their wire is always
    0); folds cascade forward because operands are resolved through the
    running remap. Rules that negate the surviving operand emit a NOT
    gate (deduped per operand so a constant-heavy layer cannot fan out
    into a pile of identical inverters).
    """

    name = "const-fold"

    def run(self, graph: LogicGraph) -> PassResult:
        new = LogicGraph(graph.n_inputs, name=graph.name)
        repl = _prefix_remap(graph)
        nots: dict[int, int] = {}        # operand -> its NOT wire in `new`

        def emit_not(x: int) -> int:
            if x == CONST0:
                return CONST1
            if x == CONST1:
                return CONST0
            if x not in nots:
                nots[x] = new.add_gate(OpCode.NOT, x)
            return nots[x]

        base = graph.first_gate_wire
        for i, (op, a, b) in enumerate(graph.gates):
            op = OpCode(op)
            a, b = int(repl[a]), int(repl[b])
            if op == OpCode.NOP:            # NOP's wire is identically 0
                repl[base + i] = CONST0
                continue
            if op == OpCode.COPY:
                repl[base + i] = a
                continue
            if op == OpCode.NOT:
                repl[base + i] = emit_not(a)
                continue
            folded = None
            for x, y in ((a, b), (b, a)):
                if y in (CONST0, CONST1):
                    rule = _CONST_RULES[(op, y)]
                    if rule[0] == "const":
                        folded = CONST1 if rule[1] else CONST0
                    elif rule[0] == "pass":
                        folded = x
                    else:                    # 'not'
                        folded = emit_not(x)
                    break
            repl[base + i] = folded if folded is not None \
                else new.add_gate(op, a, b)
        new.set_outputs(remap_wires(repl, graph.outputs, new.n_wires,
                                    what="output"))
        return PassResult(new, repl)


class SimplifyIdentities(Pass):
    """Double-negation / identity simplification.

    ``COPY(x) -> x``; ``NOT(NOT(x)) -> x``; ``NOT(g(x, y))`` fuses into
    the negated opcode (``NOT(AND) -> NAND`` etc. — "technology mapping"
    onto the full DSP opcode set); ``op(x, x)`` collapses by idempotence
    (AND/OR), annihilation (XOR -> 0, XNOR -> 1), or negation
    (NAND/NOR -> NOT x). Fusion may leave the original inner gate with
    no remaining readers — :class:`DeadGateElim` collects it.
    """

    name = "simplify-identities"

    def run(self, graph: LogicGraph) -> PassResult:
        new = LogicGraph(graph.n_inputs, name=graph.name)
        repl = _prefix_remap(graph)
        new_def: dict[int, tuple[int, int, int]] = {}

        def emit(op: OpCode, a: int, b: int) -> int:
            w = new.add_gate(op, a, b if op not in UNARY else CONST0)
            new_def[w] = (int(op), a, b)
            return w

        def resolve(op: OpCode, a: int, b: int) -> int:
            if op == OpCode.COPY:
                return a
            if op == OpCode.NOT:
                if a == CONST0:
                    return CONST1
                if a == CONST1:
                    return CONST0
                if a in new_def:
                    dop, da, db = new_def[a]
                    dop = OpCode(dop)
                    if dop == OpCode.NOT:          # double negation
                        return da
                    if dop in _NEGATED:            # NOT fusion
                        return resolve(_NEGATED[dop], da, db)
                return emit(op, a, CONST0)
            if a == b:
                rule = _IDEMPOTENT_RULES.get(op)
                if rule is not None:
                    if rule[0] == "const":
                        return CONST1 if rule[1] else CONST0
                    if rule[0] == "pass":
                        return a
                    return resolve(OpCode.NOT, a, CONST0)
            return emit(op, a, b)

        base = graph.first_gate_wire
        for i, (op, a, b) in enumerate(graph.gates):
            repl[base + i] = resolve(OpCode(op), int(repl[a]), int(repl[b]))
        new.set_outputs(remap_wires(repl, graph.outputs, new.n_wires,
                                    what="output"))
        return PassResult(new, repl)


class StructuralHash(Pass):
    """Structural hashing / common-subexpression elimination.

    Canonicalizes each gate — commutative operands sorted, unary ``b``
    pinned to CONST0, NOP operands pinned to (CONST0, CONST0) since its
    result ignores them — and dedupes identical ``(op, a, b)`` keys onto
    one wire. Duplicate AND/OR cones (the espresso factoring's main
    residue across outputs) collapse bottom-up because operands are
    resolved through the running remap before hashing.
    """

    name = "structural-hash"

    def run(self, graph: LogicGraph) -> PassResult:
        new = LogicGraph(graph.n_inputs, name=graph.name)
        repl = _prefix_remap(graph)
        table: dict[tuple[int, int, int], int] = {}
        base = graph.first_gate_wire
        for i, (op, a, b) in enumerate(graph.gates):
            op = OpCode(op)
            a, b = int(repl[a]), int(repl[b])
            if op == OpCode.NOP:
                a = b = CONST0
            elif op in UNARY:
                b = CONST0
            elif op in COMMUTATIVE and a > b:
                a, b = b, a
            key = (int(op), a, b)
            if key not in table:
                table[key] = new.add_gate(op, a, b)
            repl[base + i] = table[key]
        new.set_outputs(remap_wires(repl, graph.outputs, new.n_wires,
                                    what="output"))
        return PassResult(new, repl)


class DeadGateElim(Pass):
    """Drop every gate not reachable backwards from an output cone."""

    name = "dead-gate-elim"

    def run(self, graph: LogicGraph) -> PassResult:
        live = np.zeros(graph.n_wires, dtype=bool)
        live[:graph.first_gate_wire] = True
        stack = [o for o in graph.outputs if graph.is_gate(o)]
        seen: set[int] = set()
        while stack:
            w = stack.pop()
            if w in seen:
                continue
            seen.add(w)
            live[w] = True
            op, a, b = graph.gate_of_wire(w)
            op = OpCode(op)
            if op == OpCode.NOP:        # result ignores BOTH operands
                continue
            if graph.is_gate(a):
                stack.append(a)
            if op not in UNARY and graph.is_gate(b):
                stack.append(b)
        new = LogicGraph(graph.n_inputs, name=graph.name)
        repl = _prefix_remap(graph)
        base = graph.first_gate_wire
        for i, (op, a, b) in enumerate(graph.gates):
            w = base + i
            if live[w]:
                op = OpCode(op)
                # ignored operands may reference dead gates (repl == -1):
                # pin them to CONST0 like the other passes (NOP ignores
                # both operands, NOT/COPY ignore b)
                na = CONST0 if op == OpCode.NOP else int(repl[a])
                nb = CONST0 if op == OpCode.NOP or op in UNARY \
                    else int(repl[b])
                repl[w] = new.add_gate(op, na, nb)
        new.set_outputs(remap_wires(repl, graph.outputs, new.n_wires,
                                    what="output"))
        return PassResult(new, repl)


class Rebalance(Pass):
    """Rebuild single-fanout associative same-op chains as min-depth trees.

    ``(((a&b)&c)&d)`` (depth 3) becomes the depth-2 balanced tree. The
    rebuild is *depth-aware*: leaves carry their logic level in the new
    graph, and the tree is built Huffman-style — always combining the two
    shallowest nodes — which is depth-optimal for the leaf multiset and
    therefore never deeper than the original tree (a naive pairwise
    rebuild can pair a deep leaf late and *increase* depth, which made
    the old fixed-point loop oscillate instead of converging). Only
    internal nodes with fanout 1 are absorbed, so gate count never grows;
    depth — eq. 23's level count — monotonically shrinks. Absorbed
    internal wires are dropped from the remap (-1): no later consumer
    exists by definition.
    """

    name = "rebalance"

    def run(self, graph: LogicGraph) -> PassResult:
        fanout = graph.fanout_counts()
        new = LogicGraph(graph.n_inputs, name=graph.name)
        repl = _prefix_remap(graph)
        base = graph.first_gate_wire
        lvl = [0] * graph.n_wires            # new-wire logic levels
        absorbed = np.zeros(graph.n_wires, dtype=bool)
        for op, a, b in graph.gates:
            op = OpCode(op)
            if op not in ASSOCIATIVE:
                continue
            for child in (a, b):
                if graph.is_gate(child) and fanout[child] == 1:
                    cop, _, _ = graph.gate_of_wire(child)
                    if OpCode(cop) == op:
                        absorbed[child] = True

        def emit(op: OpCode, a: int, b: int) -> int:
            if op in UNARY:
                b = CONST0        # the ignored operand may have been absorbed
            w = new.add_gate(op, a, b)
            if w >= len(lvl):
                lvl.extend([0] * (w + 1 - len(lvl)))
            lvl[w] = max(lvl[a], lvl[b]) + 1
            return w

        def collect(wire: int, op: OpCode, leaves: list[int]) -> None:
            # explicit stack (serial chains can be thousands of gates
            # deep — recursion would overflow on the serving path)
            stack = [wire]
            while stack:
                w = stack.pop()
                if graph.is_gate(w) and absorbed[w]:
                    gop, a, b = graph.gate_of_wire(w)
                    if OpCode(gop) == op:
                        stack.append(b)      # a pops first: left-to-right
                        stack.append(a)
                        continue
                leaves.append(w)

        def build(op: OpCode, leaves: list[int]) -> int:
            # (level, tiebreak, wire) min-heap; combining the two
            # shallowest nodes first is depth-optimal for the leaf set
            heap = [(lvl[int(repl[w])], k, int(repl[w]))
                    for k, w in enumerate(leaves)]
            heapq.heapify(heap)
            tie = len(heap)
            while len(heap) > 1:
                la, _, a = heapq.heappop(heap)
                lb, _, b = heapq.heappop(heap)
                w = emit(op, a, b)
                heapq.heappush(heap, (lvl[w], tie, w))
                tie += 1
            return heap[0][2]

        for i, (op, a, b) in enumerate(graph.gates):
            w = base + i
            if absorbed[w]:
                continue
            op = OpCode(op)
            if op in ASSOCIATIVE:
                leaves: list[int] = []
                collect(a, op, leaves)
                collect(b, op, leaves)
                repl[w] = build(op, leaves)
            else:
                repl[w] = emit(op, int(repl[a]), int(repl[b]))
        new.set_outputs(remap_wires(repl, graph.outputs, new.n_wires,
                                    what="output"))
        return PassResult(new, repl)


# ---------------------------------------------------------------------------
# the pipeline
# ---------------------------------------------------------------------------

@dataclass
class OptResult:
    """Composed result of a :class:`PassManager` run.

    ``remap`` composes every pass of every iteration, so it maps wires of
    the graph handed to :meth:`PassManager.run` directly onto the final
    graph under the same contract as a single :class:`PassResult`.
    """

    graph: LogicGraph
    remap: np.ndarray
    iterations: int
    pass_stats: list[dict] = field(default_factory=list)

    def summary(self) -> str:
        lines = [f"{s['pass']}: {s['gates_in']} -> {s['gates_out']} gates"
                 for s in self.pass_stats if s["gates_in"] != s["gates_out"]]
        return "; ".join(lines) or "fixed point (no change)"


class PassManager:
    """Iterate a pass list to a fixed point on ``(n_gates, depth)``.

    ``run`` composes each pass's wire remap, so callers that need to
    track where an old wire went (e.g. layer chaining, partition
    bookkeeping) read one map regardless of how many iterations fired.
    The manager is stateless across runs and safe to share.
    """

    def __init__(self, passes: Sequence[Pass], max_iters: int = 8,
                 name: str = "pipeline"):
        if max_iters < 1:
            raise ValueError("max_iters must be >= 1")
        self.passes = list(passes)
        self.max_iters = max_iters
        self.name = name

    @classmethod
    def default(cls, max_iters: int = 8) -> "PassManager":
        """The standard synthesis pipeline (ABC ``resyn2`` stand-in):
        fold constants, simplify identities, hash-cons, sweep dead gates,
        rebalance for depth, sweep again."""
        return cls([ConstantFold(), SimplifyIdentities(), StructuralHash(),
                    DeadGateElim(), Rebalance(), DeadGateElim()],
                   max_iters=max_iters, name="default")

    @property
    def cache_key(self) -> tuple:
        """Deterministic identity of the pipeline *configuration* — what
        the serving :class:`~repro.serve.ProgramCache` folds into its
        optimized-graph memo so engines with different pipelines sharing
        one cache never serve each other's rewrites. Passes are
        identified by their class (module + qualname), not just the
        ``name`` attribute, so two custom subclasses that forgot to
        override ``name`` cannot collide in the memo."""
        return (self.name,
                tuple((type(p).__module__, type(p).__qualname__, p.name)
                      for p in self.passes),
                self.max_iters)

    def run(self, graph: LogicGraph, *, certify: bool = False) -> OptResult:
        """Run the pipeline to a fixed point.

        ``certify=True`` (the ``verify="compile"/"full"`` path,
        core/verify.py) checks every individual pass's wire remap
        against the certificate — total and in-range on outputs,
        constants/inputs fixed — and raises
        ``ScheduleVerificationError`` naming the offending pass, so a
        broken rewrite is localized to the pass that emitted it instead
        of surfacing as a composed-map failure at the end."""
        from repro.core.levelize import levelize   # local import, no cycle
        cur = graph
        remap = identity_remap(graph)
        stats: list[dict] = []
        prev_key = None
        iters = 0
        for _ in range(self.max_iters):
            iters += 1
            fp_in = cur.fingerprint()
            for p in self.passes:
                before = cur.n_gates
                res = p.run(cur)
                if certify:
                    # lazy import: verify is a leaf module, but keep the
                    # zero-cost default path import-free
                    from repro.core.verify import (
                        ScheduleVerificationError, VerifyReport,
                        certify_remap)
                    diags = certify_remap(
                        cur, res.graph, res.remap,
                        label=f"{self.name}:{p.name}[iter {iters}]")
                    if diags:
                        raise ScheduleVerificationError(VerifyReport(
                            target=graph.name,
                            diagnostics=tuple(diags)))
                remap = compose_remaps(remap, res.remap)
                stats.append({"pass": p.name, "gates_in": before,
                              "gates_out": res.graph.n_gates})
                cur = res.graph
            # true fixed point (identical structure): an already-optimized
            # graph — e.g. a composed stack of optimized layers hitting
            # the serving pipeline — stops after ONE iteration instead of
            # paying a full confirmation rebuild; the (n_gates, depth)
            # guard below backstops count-stable structural churn.
            if cur.fingerprint() == fp_in:
                break
            key = (cur.n_gates, levelize(cur).depth)
            if key == prev_key:
                break
            prev_key = key
        return OptResult(graph=cur, remap=remap, iterations=iters,
                         pass_stats=stats)

    def optimize(self, graph: LogicGraph) -> LogicGraph:
        """Graph-only convenience over :meth:`run`."""
        return self.run(graph).graph

    # Two managers with the same configuration identity run the same
    # rewrites, so they compare (and hash) equal — what makes
    # ``CompileSpec(optimize="default")`` equal however the default
    # pipeline was spelled (core/spec.py normalizes on construction).
    def __eq__(self, other) -> bool:
        return (isinstance(other, PassManager)
                and self.cache_key == other.cache_key)

    def __hash__(self) -> int:
        return hash(self.cache_key)

    def __repr__(self) -> str:
        return (f"PassManager({self.name!r}, "
                f"passes={[p.name for p in self.passes]}, "
                f"max_iters={self.max_iters})")


def resolve_pipeline(optimize) -> PassManager | None:
    """Normalize the ``optimize=`` knob every consumer shares.

    ``"default"`` / ``True`` -> :meth:`PassManager.default`;
    ``"none"`` / ``None`` / ``False`` -> no optimization;
    a :class:`PassManager` instance passes through unchanged;
    a :class:`~repro.core.spec.CompileSpec` contributes its resolved
    ``pipeline`` (so graph-stage knobs like ``FfclStats.from_graph
    (optimized=spec)`` accept the one declarative target directly).
    """
    if optimize is None or optimize is False or optimize == "none":
        return None
    if optimize is True or optimize == "default":
        return PassManager.default()
    if isinstance(optimize, PassManager):
        return optimize
    from repro.core.spec import CompileSpec   # lazy: spec imports this module
    if isinstance(optimize, CompileSpec):
        return optimize.pipeline
    raise ValueError(
        f"optimize must be 'default', 'none', a PassManager, or a "
        f"CompileSpec; got {optimize!r}")
