"""Durable persistence of compiled logic programs (``ArtifactStore``).

The paper's deliverable is a *compiled artifact* — eq. 23-scheduled
address/opcode streams mapped onto the DSP fabric — yet until this module
every process recompiled from the gate IR on startup, paying the ~0.5 s
cold compile the 232x in-memory cache-hit speedup exists to hide.  The
store makes a :class:`~repro.core.compiler.CompiledArtifact` a durable,
shareable file-system object so a *fleet* of serving processes warms one
shared directory instead of each compiling its own copy (ROADMAP:
"Compiled-artifact persistence + fleet warm start"; the logic-served-NN
follow-up, arXiv 2304.06299, assumes exactly this artifact contract).

Layout (content-addressed; DESIGN.md §10)::

    <root>/objects/<kk>/<key>/manifest.json   # provenance + checksums
                             /arrays.npz      # schedule tables + graph
    <root>/aliases/<kk>/<akey>.json           # raw-identity -> key records
    <root>/tmp/...                            # staging (atomic writes)
    <root>/quarantine/...                     # failed-integrity entries

``key = store_key(fingerprint, spec)`` digests the *post-optimization*
graph fingerprint plus the canonical ``CompileSpec.to_dict()`` — the same
identity ``serve.ProgramCache`` keys on — so a store hit names exactly
one concrete program pipeline, and structurally-equal graphs from
different producers share one entry.

Integrity contract (the whole point — a persistence layer that can
silently serve a *wrong* program is worse than none):

  * every write is **atomic**: both files are staged in ``tmp/`` and
    published with one ``os.replace`` of the directory, so readers see
    either nothing or a complete entry — never a torn write.  Racing
    writers of the same key are benign: the loser's rename fails and is
    discarded (the contents are equivalent by content-addressing).
  * every read **verifies before trusting**: manifest-body checksum
    (any bit flip in the manifest fails), ``arrays.npz`` checksum (any
    truncation/flip of the tables fails), format-version equality (a
    future writer's entry is refused, never half-parsed), spec equality,
    and — the end-to-end check — the rebuilt graph's recomputed
    ``fingerprint()`` must equal the requested one.
  * failure is **loud and quarantining**: any mismatch raises
    :class:`~repro.core.errors.ArtifactIntegrityError` (a
    ``PermanentCompileError`` — retrying cannot fix a corrupt file) and
    the entry is moved to ``quarantine/`` so it can never be served
    again; callers (``ProgramCache``) fall back to a clean compile.

Alias records make warm starts skip the pass pipeline: the canonical
address uses the POST-optimization fingerprint, which a fresh process
can only compute by re-running the optimizer — the dominant cold-start
cost for ``optimize="default"`` specs.  ``save_alias`` records
``(raw fingerprint, spec as requested) -> canonical key`` so
``load_alias`` resolves a first-contact request straight to the
verified canonical entry.  The alias record itself is checksummed and
version-gated (any accidental flip fails loudly, same as the
manifest), but its *claim* — that the optimizer maps this raw graph to
that canonical entry — is trusted, not re-derived: re-deriving would
re-run the pipeline, which is exactly the cost being skipped.  The
canonical entry behind it is still verified end-to-end on every load.
"""
from __future__ import annotations

import io
import itertools
import json
import os
import shutil
import hashlib
from pathlib import Path

import numpy as np

from repro.core.calibrate import Calibration, CalibrationError
from repro.core.compiler import CompiledArtifact
from repro.core.errors import ArtifactIntegrityError
from repro.core.gate_ir import LogicGraph
from repro.core.scheduler import LogicProgram
from repro.core.spec import CompileSpec
from repro.core.verify import verify_artifact

#: On-disk format version.  Bump on ANY schema change (manifest keys,
#: array set, dtype contract): readers refuse entries whose version
#: differs — an old reader must never half-parse a future entry, and a
#: future reader must never guess at a past one.
FORMAT_VERSION = 1

_MANIFEST = "manifest.json"
_ARRAYS = "arrays.npz"

#: Process-wide staging sequence: staging paths must be unique across
#: every ``ArtifactStore`` instance in the process (pid alone is not
#: enough — racing instances over one root would collide at ``.0``).
_STAGE_SEQ = itertools.count()


def _digest(data: bytes) -> str:
    return hashlib.blake2b(data, digest_size=16).hexdigest()


def _canonical_json(obj: dict) -> bytes:
    """Canonical (sorted, minimal) JSON encoding — the checksummed form."""
    return json.dumps(obj, sort_keys=True, separators=(",", ":")).encode()


def store_key(fingerprint: str, spec: CompileSpec) -> str:
    """Content address of ``(post-opt graph, spec)`` — a stable hex
    digest of the fingerprint plus the canonical serialized spec.

    Mirrors ``ProgramCache.key_of``: the spec must be resolved (concrete
    ``n_unit``) and is keyed through ``to_dict()``, so only the named
    pipelines (``"none"``/``"default"``) are storable — a custom
    :class:`PassManager` has no declarative serial form and raises (from
    ``to_dict``) rather than colliding under a lossy key.
    """
    if not spec.resolved:
        raise ValueError(
            "store_key() requires a concrete n_unit; resolve "
            "n_unit='auto' first (LogicCompiler.resolve / ProgramCache)")
    return _digest(_canonical_json(
        {"fingerprint": fingerprint, "spec": spec.to_dict()}))


def alias_key(fingerprint: str, spec: CompileSpec) -> str:
    """Address of a raw-identity alias record: the PRE-optimization
    fingerprint plus the spec *as requested* (``n_unit="auto"`` and
    ``optimize="default"`` serialize as themselves here — resolution
    and pipeline effects live in the canonical entry it points at)."""
    return _digest(_canonical_json(
        {"alias_fp": fingerprint, "spec": spec.to_dict()}))


def _graph_payload(graph: LogicGraph) -> tuple[dict, dict]:
    """(arrays, meta) serialization of a :class:`LogicGraph`."""
    gates = (np.asarray(graph.gates, dtype=np.int64).reshape(-1, 3)
             if graph.gates else np.zeros((0, 3), dtype=np.int64))
    outputs = np.asarray(graph.outputs, dtype=np.int64)
    return ({"graph_gates": gates, "graph_outputs": outputs},
            {"n_inputs": graph.n_inputs, "name": graph.name})


def _graph_from_payload(arrays: dict, meta: dict) -> LogicGraph:
    # tolist() + map(tuple, ...) run the per-gate conversion in C — the
    # naive per-row python loop dominated verified-load wall-clock
    gates = list(map(tuple, arrays["graph_gates"].tolist()))
    return LogicGraph(n_inputs=int(meta["n_inputs"]), gates=gates,
                      outputs=arrays["graph_outputs"].tolist(),
                      name=str(meta["name"]))


class ArtifactStore:
    """Content-addressed, atomically-written store of compiled artifacts.

    One instance fronts one root directory; many processes may share the
    root concurrently (the atomic-rename publish protocol is the only
    coordination).  All counters are per-instance telemetry, not shared
    state.

    Args:
      root: store directory (created, with substructure, if missing).
      verify_on_load: when True, every loaded artifact additionally runs
        the static schedule verifier (core/verify.py, DESIGN.md §13)
        before being returned: checksums prove the *bytes* round-tripped,
        the verifier proves the *schedule* still computes the manifest's
        graph.  A verifier-rejected entry is treated exactly like a
        checksum failure — quarantined + ``ArtifactIntegrityError``.
    """

    def __init__(self, root: str | os.PathLike, *,
                 verify_on_load: bool = False):
        self.root = Path(root)
        self.verify_on_load = bool(verify_on_load)
        self._objects = self.root / "objects"
        self._aliases = self.root / "aliases"
        self._calibration = self.root / "calibration"
        self._tmp = self.root / "tmp"
        self._quarantine_dir = self.root / "quarantine"
        for d in (self._objects, self._aliases, self._calibration,
                  self._tmp, self._quarantine_dir):
            d.mkdir(parents=True, exist_ok=True)
        # telemetry (per-instance)
        self.saves = 0
        self.save_races = 0
        self.alias_saves = 0
        self.loads = 0
        self.misses = 0
        self.integrity_failures = 0
        self.quarantined = 0

    # -- paths ---------------------------------------------------------------

    def path_of(self, key: str) -> Path:
        """Directory an entry with ``key`` lives at (existing or not)."""
        return self._objects / key[:2] / key

    def _stage_path(self, key: str) -> Path:
        return self._tmp / f"{key}.{os.getpid()}.{next(_STAGE_SEQ)}"

    def alias_path_of(self, akey: str) -> Path:
        """File an alias record with ``akey`` lives at (existing or not)."""
        return self._aliases / akey[:2] / f"{akey}.json"

    def __contains__(self, key: str) -> bool:
        return (self.path_of(key) / _MANIFEST).is_file()

    def contains(self, fingerprint: str, spec: CompileSpec) -> bool:
        """True when an entry for ``(fingerprint, spec)`` is published
        (presence only — integrity is verified at :meth:`load` time)."""
        return store_key(fingerprint, spec) in self

    def keys(self) -> list[str]:
        """Keys of every published entry (sorted, for determinism)."""
        return sorted(p.name for shard in self._objects.iterdir()
                      for p in shard.iterdir()
                      if (p / _MANIFEST).is_file())

    # -- save ----------------------------------------------------------------

    def save(self, artifact: CompiledArtifact) -> str:
        """Persist ``artifact``; returns its store key.

        Idempotent and race-safe: an already-published key is left
        untouched (content addressing makes rewrites pointless), and a
        concurrent writer losing the publish rename discards its staging
        copy.  The artifact's spec must be serializable
        (``CompileSpec.to_dict()`` — named pipelines only).
        """
        fingerprint = artifact.graph.fingerprint()
        key = store_key(fingerprint, artifact.spec)
        final = self.path_of(key)
        if (final / _MANIFEST).is_file():
            return key

        arrays: dict[str, np.ndarray] = {
            "output_perm": np.asarray(artifact.output_perm, dtype=np.int64)}
        g_arrays, g_meta = _graph_payload(artifact.graph)
        arrays.update(g_arrays)
        prog_meta = []
        for i, prog in enumerate(artifact.programs):
            p_arrays, p_scalars = prog.to_payload()
            arrays.update({f"p{i}_{k}": v for k, v in p_arrays.items()})
            prog_meta.append(p_scalars)

        buf = io.BytesIO()
        np.savez(buf, **arrays)
        blob = buf.getvalue()

        payload = {
            "format_version": FORMAT_VERSION,
            "fingerprint": fingerprint,
            "spec": artifact.spec.to_dict(),
            "graph": g_meta,
            "programs": prog_meta,
            "compile_s": artifact.compile_s,
            "arrays": _ARRAYS,
            "arrays_checksum": _digest(blob),
        }
        manifest = {"payload": payload,
                    "checksum": _digest(_canonical_json(payload))}

        stage = self._stage_path(key)
        stage.mkdir(parents=True)
        try:
            self._write_file(stage / _ARRAYS, blob)
            self._write_file(stage / _MANIFEST,
                             json.dumps(manifest, indent=1).encode())
            final.parent.mkdir(parents=True, exist_ok=True)
            try:
                os.replace(stage, final)
            except OSError:
                # lost the publish race: an equivalent entry exists
                self.save_races += 1
                shutil.rmtree(stage, ignore_errors=True)
                return key
        except BaseException:
            shutil.rmtree(stage, ignore_errors=True)
            raise
        self.saves += 1
        return key

    @staticmethod
    def _write_file(path: Path, data: bytes) -> None:
        with open(path, "wb") as f:
            f.write(data)
            f.flush()
            os.fsync(f.fileno())

    # -- aliases -------------------------------------------------------------

    def save_alias(self, fingerprint: str, spec: CompileSpec,
                   target_key: str) -> str:
        """Record ``(raw fingerprint, requested spec) -> target_key`` so
        warm starts resolve first-contact requests without re-running
        the pass pipeline.  Atomic (staged file + ``os.replace``) and
        idempotent; returns the alias key."""
        akey = alias_key(fingerprint, spec)
        final = self.alias_path_of(akey)
        if final.is_file():
            return akey
        payload = {"format_version": FORMAT_VERSION,
                   "alias_fp": fingerprint, "spec": spec.to_dict(),
                   "target": target_key}
        record = {"payload": payload,
                  "checksum": _digest(_canonical_json(payload))}
        stage = self._stage_path(akey)
        try:
            self._write_file(stage, json.dumps(record, indent=1).encode())
            final.parent.mkdir(parents=True, exist_ok=True)
            os.replace(stage, final)    # files replace cleanly: last wins,
        except BaseException:           # and racing writers write equal bytes
            stage.unlink(missing_ok=True)
            raise
        self.alias_saves += 1
        return akey

    def load_alias(self, fingerprint: str, spec: CompileSpec
                   ) -> CompiledArtifact | None:
        """Verified load through the raw-identity alias for
        ``(fingerprint, spec)``.

        ``None`` on a clean miss — no alias record, or the record points
        at a canonical entry that is gone (quarantined by another
        process; the caller recompiles and republishes).  A corrupt
        alias record quarantines the record and raises; a corrupt
        canonical entry behind a valid alias fails exactly as
        :meth:`load` would."""
        akey = alias_key(fingerprint, spec)
        path = self.alias_path_of(akey)
        if not path.is_file():
            self.misses += 1
            return None
        try:
            payload = self._verified_manifest_bytes(
                path, f"alias record {akey}")
            if (payload.get("alias_fp") != fingerprint
                    or payload.get("spec") != spec.to_dict()):
                raise ArtifactIntegrityError(
                    f"alias record {akey}: names a different "
                    "(fingerprint, spec) than its address — moved or "
                    "tampered")
            target = payload["target"]
        except ArtifactIntegrityError as exc:
            self.integrity_failures += 1
            exc.quarantine_path = self._quarantine_path(path, akey)
            raise
        if not (self.path_of(target) / _MANIFEST).is_file():
            self.misses += 1
            return None
        artifact = self.load_key(target)
        self.loads += 1
        return artifact

    # -- calibrations --------------------------------------------------------

    def calibration_path_of(self, name: str = "default") -> Path:
        """File the calibration record ``name`` lives at (existing or
        not)."""
        if not name or "/" in name or name != name.strip() or name in (
                ".", ".."):
            raise ValueError(f"invalid calibration name {name!r}")
        return self._calibration / f"{name}.json"

    def save_calibration(self, calibration: Calibration,
                         name: str = "default") -> Path:
        """Persist a fitted wall-clock calibration (core/calibrate.py)
        under ``calibration/<name>.json`` — same checksummed-record +
        atomic-publish protocol as alias records, so a warm process
        loads the fleet's fit instead of re-measuring (the CLI smoke
        pins ``calibrate.fit_count() == 0`` on the load path).  Unlike
        content-addressed entries, calibrations are *named* and a
        re-save replaces the record (a re-fit on the same host should
        win)."""
        final = self.calibration_path_of(name)
        payload = {"format_version": FORMAT_VERSION, "name": name,
                   "calibration": calibration.to_dict()}
        record = {"payload": payload,
                  "checksum": _digest(_canonical_json(payload))}
        stage = self._stage_path(f"calib.{name}")
        try:
            self._write_file(stage, json.dumps(record, indent=1).encode())
            final.parent.mkdir(parents=True, exist_ok=True)
            os.replace(stage, final)
        except BaseException:
            stage.unlink(missing_ok=True)
            raise
        self.saves += 1
        return final

    def load_calibration(self, name: str = "default"
                         ) -> Calibration | None:
        """Verified load of the calibration record ``name``.

        ``None`` on a clean miss.  A present-but-invalid record —
        flipped bytes, version mismatch, malformed calibration payload —
        is quarantined and raises :class:`ArtifactIntegrityError`: a
        corrupt calibration silently steering the design-space search
        is exactly the failure mode the typed error exists to prevent.
        """
        path = self.calibration_path_of(name)
        if not path.is_file():
            self.misses += 1
            return None
        try:
            payload = self._verified_manifest_bytes(
                path, f"calibration record {name!r}")
            if payload.get("name") != name:
                raise ArtifactIntegrityError(
                    f"calibration record {name!r}: payload names "
                    f"{payload.get('name')!r} — moved or tampered")
            try:
                cal = Calibration.from_dict(payload["calibration"])
            except (CalibrationError, KeyError) as exc:
                raise ArtifactIntegrityError(
                    f"calibration record {name!r}: undecodable payload "
                    f"({exc})") from exc
        except ArtifactIntegrityError as exc:
            self.integrity_failures += 1
            exc.quarantine_path = self._quarantine_path(
                path, f"calib.{name}")
            raise
        self.loads += 1
        return cal

    # -- load ----------------------------------------------------------------

    def load(self, fingerprint: str, spec: CompileSpec
             ) -> CompiledArtifact | None:
        """Verified load of the entry for ``(fingerprint, spec)``.

        Returns ``None`` on a clean miss (no entry published).  Any
        *present-but-invalid* entry — truncated/flipped arrays, tampered
        manifest, version or fingerprint or spec mismatch — quarantines
        the entry and raises :class:`ArtifactIntegrityError`
        (``PermanentCompileError``): a corrupt store must never be
        mistaken for a miss silently, and must never serve a wrong
        program.
        """
        key = store_key(fingerprint, spec)
        path = self.path_of(key)
        if not (path / _MANIFEST).is_file():
            self.misses += 1
            return None
        try:
            artifact = self._verified_load(path, fingerprint, spec)
        except ArtifactIntegrityError as exc:
            self.integrity_failures += 1
            qpath = self.quarantine(key)
            exc.quarantine_path = qpath
            raise
        self.loads += 1
        return artifact

    def load_key(self, key: str) -> CompiledArtifact:
        """Verified load by bare key (fleet tooling / inspection): the
        fingerprint and spec are taken from the manifest, and the key is
        re-derived from them — a mismatch is corruption."""
        path = self.path_of(key)
        if not (path / _MANIFEST).is_file():
            raise KeyError(f"no store entry for key {key!r}")
        try:
            payload = self._verified_manifest(path)
            fingerprint = payload["fingerprint"]
            spec = CompileSpec.from_dict(payload["spec"])
            if store_key(fingerprint, spec) != key:
                raise ArtifactIntegrityError(
                    f"store entry {key}: manifest names key "
                    f"{store_key(fingerprint, spec)} (moved or tampered)")
            return self._verified_load(path, fingerprint, spec)
        except ArtifactIntegrityError as exc:
            self.integrity_failures += 1
            exc.quarantine_path = self.quarantine(key)
            raise

    def _verified_manifest(self, path: Path) -> dict:
        """Parse + self-check an entry's manifest; any anomaly is
        integrity."""
        return self._verified_manifest_bytes(path / _MANIFEST,
                                             f"store entry {path.name}")

    @staticmethod
    def _verified_manifest_bytes(file_path: Path, label: str) -> dict:
        """Shared record verification (entry manifests + alias records):
        json parse, payload checksum, format-version equality."""
        try:
            with open(file_path, "rb") as f:
                manifest = json.load(f)
            payload = manifest["payload"]
            claimed = manifest["checksum"]
        except (OSError, ValueError, KeyError, TypeError) as exc:
            raise ArtifactIntegrityError(
                f"{label}: unreadable manifest ({exc})") from exc
        actual = _digest(_canonical_json(payload))
        if actual != claimed:
            raise ArtifactIntegrityError(
                f"{label}: manifest checksum mismatch "
                f"(claimed {claimed}, actual {actual}) — manifest corrupt")
        version = payload.get("format_version")
        if version != FORMAT_VERSION:
            raise ArtifactIntegrityError(
                f"{label}: format-version {version!r} != "
                f"reader's {FORMAT_VERSION} — refusing to guess at the "
                "schema (re-precompile with this build)")
        return payload

    def _verified_load(self, path: Path, fingerprint: str,
                       spec: CompileSpec) -> CompiledArtifact:
        payload = self._verified_manifest(path)
        if payload["fingerprint"] != fingerprint:
            raise ArtifactIntegrityError(
                f"store entry {path.name}: manifest fingerprint "
                f"{payload['fingerprint']} != requested {fingerprint}")
        if payload["spec"] != spec.to_dict():
            raise ArtifactIntegrityError(
                f"store entry {path.name}: manifest spec {payload['spec']} "
                f"!= requested {spec.to_dict()}")
        try:
            with open(path / _ARRAYS, "rb") as f:
                blob = f.read()
        except OSError as exc:
            raise ArtifactIntegrityError(
                f"store entry {path.name}: unreadable arrays ({exc})"
            ) from exc
        actual = _digest(blob)
        if actual != payload["arrays_checksum"]:
            raise ArtifactIntegrityError(
                f"store entry {path.name}: arrays checksum mismatch "
                f"(claimed {payload['arrays_checksum']}, actual {actual}) "
                "— schedule tables truncated or corrupt")
        try:
            with np.load(io.BytesIO(blob), allow_pickle=False) as z:
                arrays = {k: z[k] for k in z.files}
            graph = _graph_from_payload(arrays, payload["graph"])
            programs = tuple(
                LogicProgram.from_payload(
                    {k: arrays[f"p{i}_{k}"]
                     for k in LogicProgram.ARRAY_FIELDS}, scalars)
                for i, scalars in enumerate(payload["programs"]))
            output_perm = arrays["output_perm"]
        except ArtifactIntegrityError:
            raise
        except Exception as exc:
            raise ArtifactIntegrityError(
                f"store entry {path.name}: undecodable payload ({exc})"
            ) from exc
        # the end-to-end check: the REBUILT graph must hash to the
        # requested identity — a consistent-but-wrong entry (e.g. a
        # collision or a tampered-and-rechecksummed file) still fails here
        rebuilt_fp = graph.fingerprint()
        if rebuilt_fp != fingerprint:
            raise ArtifactIntegrityError(
                f"store entry {path.name}: rebuilt graph fingerprint "
                f"{rebuilt_fp} != requested {fingerprint} — wrong program")
        artifact = CompiledArtifact(
            spec=CompileSpec.from_dict(payload["spec"]), graph=graph,
            programs=programs, output_perm=output_perm,
            compile_s=float(payload["compile_s"]))
        if self.verify_on_load:
            report = verify_artifact(artifact)
            if not report.ok:
                raise ArtifactIntegrityError(
                    f"store entry {path.name}: schedule verification "
                    f"failed — {report.summary()}")
        return artifact

    # -- quarantine ----------------------------------------------------------

    def quarantine(self, key: str) -> Path | None:
        """Move a (presumed corrupt) entry out of the serving namespace.

        The entry is renamed into ``quarantine/`` (kept for post-mortem,
        never loadable again); returns the new path, or ``None`` when the
        entry vanished first (another process already quarantined it).
        """
        return self._quarantine_path(self.path_of(key), key)

    def _quarantine_path(self, src: Path, label: str) -> Path | None:
        dst = (self._quarantine_dir
               / f"{label}.{os.getpid()}.{next(_STAGE_SEQ)}")
        try:
            os.replace(src, dst)
        except OSError:
            return None
        self.quarantined += 1
        return dst

    # -- telemetry -----------------------------------------------------------

    def stats(self) -> dict:
        return {"root": str(self.root), "entries": len(self.keys()),
                "saves": self.saves, "save_races": self.save_races,
                "alias_saves": self.alias_saves,
                "loads": self.loads, "misses": self.misses,
                "integrity_failures": self.integrity_failures,
                "quarantined": self.quarantined}
