"""Compile-failure taxonomy (consumed by the serving front door).

The compile path can fail three distinct ways, and a serving layer must
react differently to each — retry, reject, or crash loudly:

``CompileError``
    Base of every *classified* compilation failure.  Anything else
    escaping the compile path (``ValueError`` from spec/graph
    validation, a genuine bug) is deliberately NOT wrapped: validation
    errors are the caller's fault and bugs must stay loud.

``TransientCompileError``
    A failure expected to succeed on retry — resource pressure, a
    fault-injection hook (``serve.frontdoor.FaultPolicy``), an evicted
    artifact store entry mid-read.  ``retryable = True``: the front
    door retries these with bounded exponential backoff.

``PermanentCompileError``
    A failure retrying cannot fix (graph exceeds a hard fabric limit,
    unsupported opcode on a backend).  The front door sheds the request
    with a machine-readable ``compile_failed`` reason instead of
    burning its deadline on retries.

``ArtifactIntegrityError``
    A ``PermanentCompileError`` specific to the persistence layer
    (core/artifact_store.py): a store entry failed verification —
    checksum, format-version, fingerprint, or spec mismatch.  Loud by
    design (a silently-wrong compiled program is the worst possible
    failure); the store quarantines the entry and ``ProgramCache``
    falls back to a clean compile.

:func:`is_transient` is the one classification point: retry loops ask
it instead of isinstance-matching, so new retryable subclasses (or a
third-party exception taught to carry ``retryable = True``) slot in
without touching the retry code.
"""
from __future__ import annotations


class CompileError(RuntimeError):
    """A classified failure of the logic-compile path."""

    retryable: bool = False


class TransientCompileError(CompileError):
    """Compilation failed but is expected to succeed on retry."""

    retryable = True


class PermanentCompileError(CompileError):
    """Compilation failed and retrying cannot help."""

    retryable = False


class ArtifactIntegrityError(PermanentCompileError):
    """A persisted compiled artifact failed verification.

    Raised by :mod:`repro.core.artifact_store` on any checksum /
    format-version / fingerprint / spec mismatch.  Carries
    ``quarantine_path`` (set by the store) pointing at where the
    offending entry was moved for post-mortem, or ``None`` when another
    process quarantined it first."""

    quarantine_path = None


def is_transient(exc: BaseException) -> bool:
    """True when ``exc`` is a retryable compile failure."""
    return bool(getattr(exc, "retryable", False))
