"""Batch bit-packing (paper §5: 48-lane DSP SIMD -> 32-lane int32 words).

The DSP48 executes one opcode over 48 independent Boolean lanes; on TPU the
natural word is int32 on the VPU, so we pack 32 *samples* per word and keep a
word (lane) axis of width W = ceil(batch/32). A gate op on a (row, W) slab
processes 32*W samples in one VPU op row.

Layout: ``packed[w, j]`` bit ``k`` (LSB-first) = ``bits[j*32 + k, w]``.
"""
from __future__ import annotations

import numpy as np

WORD_BITS = 32


def packed_width(batch: int) -> int:
    return -(-batch // WORD_BITS)


def pack_bits(bits: np.ndarray) -> np.ndarray:
    """(batch, n_wires) bool -> (n_wires, W) int32, LSB-first within a word."""
    bits = np.asarray(bits).astype(np.uint8)
    batch, n = bits.shape
    w = packed_width(batch)
    pad = w * WORD_BITS - batch
    if pad:
        bits = np.concatenate(
            [bits, np.zeros((pad, n), dtype=np.uint8)], axis=0)
    # (W, 32, n) -> pack along the 32 axis
    chunks = bits.reshape(w, WORD_BITS, n)
    weights = (np.uint32(1) << np.arange(WORD_BITS, dtype=np.uint32))
    words = (chunks.astype(np.uint32) * weights[None, :, None]).sum(
        axis=1, dtype=np.uint32)
    return words.astype(np.int32).T.copy()  # (n, W)


def unpack_bits(words: np.ndarray, batch: int) -> np.ndarray:
    """(n_wires, W) int32 -> (batch, n_wires) bool."""
    words = np.asarray(words).astype(np.uint32)
    n, w = words.shape
    shifts = np.arange(WORD_BITS, dtype=np.uint32)
    bits = (words[:, :, None] >> shifts[None, None, :]) & np.uint32(1)
    bits = bits.reshape(n, w * WORD_BITS).T  # (W*32, n)
    return bits[:batch].astype(bool)
