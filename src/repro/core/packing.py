"""Batch bit-packing (paper §5: 48-lane DSP SIMD -> 32-lane int32 words).

The DSP48 executes one opcode over 48 independent Boolean lanes; on TPU the
natural word is int32 on the VPU, so we pack 32 *samples* per word and keep a
word (lane) axis of width W = ceil(batch/32). A gate op on a (row, W) slab
processes 32*W samples in one VPU op row.

Layout (also DESIGN.md §5 — the serving slot table is sized to exactly the
``32*W`` samples of one slab)::

    bits (batch, n_wires) bool          packed (n_wires, W) int32
                                        W = ceil(batch / 32)

              sample axis ->                      word axis ->
            s0 s1 ... s31 | s32 ... s63            w=0     w=1
    wire 0 [ b  b  ...  b |  b  ...  b ]   wire 0 [0x….  0x…. ]
    wire 1 [ b  b  ...  b |  b  ...  b ]   wire 1 [0x….  0x…. ]
      ...                           pack->   ...
    wire n [ b  b  ...  b |  b  ...  b ]   wire n [0x….  0x…. ]

    packed[n, w] bit k (LSB-first) == bits[w*32 + k, n]

A batch that is not a multiple of 32 pads its final word with zeros;
``unpack_bits`` slices the padding back off.

>>> import numpy as np
>>> bits = np.zeros((33, 2), dtype=bool)   # 33 samples -> W = 2 words
>>> bits[0, 0] = bits[32, 1] = True
>>> packed_width(33)
2
>>> w = pack_bits(bits)
>>> w.shape                                # (n_wires, W)
(2, 2)
>>> int(w[0, 0]), int(w[1, 1])   # samples 0/32 -> bit 0 of words 0/1
(1, 1)
>>> bool((unpack_bits(w, 33) == bits).all())
True
"""
from __future__ import annotations

import numpy as np

WORD_BITS = 32


def packed_width(batch: int) -> int:
    return -(-batch // WORD_BITS)


def pack_bits(bits: np.ndarray) -> np.ndarray:
    """(batch, n_wires) bool -> (n_wires, W) int32, LSB-first within a word."""
    bits = np.asarray(bits).astype(np.uint8)
    batch, n = bits.shape
    w = packed_width(batch)
    pad = w * WORD_BITS - batch
    if pad:
        bits = np.concatenate(
            [bits, np.zeros((pad, n), dtype=np.uint8)], axis=0)
    # (W, 32, n) -> pack along the 32 axis
    chunks = bits.reshape(w, WORD_BITS, n)
    weights = (np.uint32(1) << np.arange(WORD_BITS, dtype=np.uint32))
    words = (chunks.astype(np.uint32) * weights[None, :, None]).sum(
        axis=1, dtype=np.uint32)
    return words.astype(np.int32).T.copy()  # (n, W)


def unpack_bits(words: np.ndarray, batch: int) -> np.ndarray:
    """(n_wires, W) int32 -> (batch, n_wires) bool."""
    words = np.asarray(words).astype(np.uint32)
    n, w = words.shape
    shifts = np.arange(WORD_BITS, dtype=np.uint32)
    bits = (words[:, :, None] >> shifts[None, None, :]) & np.uint32(1)
    bits = bits.reshape(n, w * WORD_BITS).T  # (W*32, n)
    return bits[:batch].astype(bool)
