"""Analytical compute-cycle model (paper §6.2, eqs. (2)-(23)) on TPU terms.

The paper models FFCL execution as a two-stage pipeline — (i) data movement
(DDR->URAM->BRAM: input vectors, opcodes, addresses) and (ii) compute
(BRAM->DSP regs, logic op, DSP regs->BRAM) — overlapped by double buffering:

    n_cc,opt = (m + 1) * max(n_data_moves, n_compute)            (eq. 2)

TPU mapping of each memory tier (DESIGN.md §2):

    DDR banks           -> HBM          (819 GB/s/chip)
    URAM (global)       -> VMEM staging of the program streams
    BRAM (local)        -> VMEM data buffer rows
    DSP registers       -> VREGs
    48-lane SIMD        -> 32 samples/int32 word x W words per gate-op row

All terms are returned in *cycles* of the compute fabric clock so the
paper's equations carry over verbatim; ``seconds()`` divides by the clock.
The packing factors keep the paper's names: lambda_ (addresses per bus
beat), delta (input words per beat), zeta (opcodes per beat).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class TpuFabric:
    """Hardware constants for the cost model (TPU v5e, public numbers).

    peak_flops/hbm_bw/ici_bw are the roofline constants mandated for this
    project; VPU numbers are derived: 197 TFLOP/s bf16 over 4 MXUs of
    128x128x2 flops/cycle -> ~1.5 GHz core clock; the VPU issues one lane-op
    per (8,128) vreg slab per cycle.
    """

    clock_hz: float = 1.5e9
    vpu_sublanes: int = 8
    vpu_lanes: int = 128
    hbm_bw: float = 819e9           # bytes/s
    vmem_bw: float = 3.3e12         # bytes/s VMEM<->VREG (22 B/cycle/lane est)
    vmem_bytes: int = 64 * 2**20    # v5e ~128MiB/2 cores -> 64MiB/core budget
    ici_bw: float = 50e9            # bytes/s/link
    peak_flops: float = 197e12      # bf16
    dma_beat_bytes: int = 512       # HBM burst granule (paper: 512-bit AXI)
    # Fixed cost per sub-kernel step: the dependent gather->op->scatter chain
    # (VMEM load-use latency) + scalar-core loop control. This is the TPU
    # analogue of the paper's per-subkernel n_exe_logic_ops pipeline fill;
    # it is what makes FEW units expensive (many steps) and creates the
    # U-shaped latency of Fig. 6.
    step_overhead_cycles: float = 40.0
    # SIMD lanes per packed word (32 for int32 VPU words; 48 on the DSP48)
    simd_lanes: int = 32
    # per-step execute cycles for one unit's op (VPU: folded into the
    # word-throughput term; DSP48: 1 cycle, fully parallel across units)
    step_exe_cycles: float = 0.0

    @property
    def vpu_word_ops_per_cycle(self) -> int:
        """int32 bitwise ops per cycle (one vreg slab)."""
        return self.vpu_sublanes * self.vpu_lanes

    @property
    def hbm_bytes_per_cycle(self) -> float:
        return self.hbm_bw / self.clock_hz

    @property
    def vmem_bytes_per_cycle(self) -> float:
        return self.vmem_bw / self.clock_hz


@dataclass(frozen=True)
class FpgaFabric(TpuFabric):
    """Paper-faithful constants: Xilinx VU9P on AWS F1 (paper §8).

    250 MHz fabric clock; DSP48 executes a 48-lane bitwise op in 1 cycle
    with the step fully pipelined (the paper's dataflow engine: address
    fetch / execute / write-back overlap, eq. 20's terms ARE the pipeline
    stages, so no 40-cycle dependent-latency charge); BRAM moves lambda
    operands per cycle (dual-ported, eq. 8/16); 4 DDR4 banks ~17 GB/s each,
    3 dedicated to the address stream (eq. 6)."""

    clock_hz: float = 250e6
    simd_lanes: int = 48
    vpu_sublanes: int = 10 ** 7        # all DSPs execute in parallel
    step_overhead_cycles: float = 1.0
    step_exe_cycles: float = 1.0       # n_exe_logic_ops
    hbm_bw: float = 51e9               # 3 DDR banks for the dominant stream
    vmem_bw: float = 54e9              # BRAM: lambda*6B/cycle @ 250 MHz
    vmem_bytes: int = 8 * 2 ** 20      # ~345 x 36Kb BRAM usable
    peak_flops: float = 0.0            # n/a
    ici_bw: float = 0.0                # n/a
    dma_beat_bytes: int = 64           # 512-bit AXI beat


@dataclass(frozen=True)
class FfclStats:
    """Statistics of one compiled FFCL module the model needs (paper Table 1
    plus eq. 23 inputs).

    ``n_steps_scheduled``/``step_occupancy`` are only present when the stats
    come from a *compiled* program (:meth:`from_program`): with step fusion
    the scheduler emits fewer steps than eq. 23 predicts, and the model must
    charge the stream/loop terms for the steps that actually exist
    (DESIGN.md §3). Both are specific to the ``n_unit`` the program was
    compiled for — do not reuse such stats to probe other unit counts
    (the optimizer sweeps use :meth:`from_graph` stats, which stay on the
    closed-form eq. 23 path).
    """

    n_gates: int
    depth: int
    n_fanin: int                  # primary inputs
    n_outputs: int
    level_histogram: np.ndarray   # gates per level, shape (depth,)
    n_steps_scheduled: int | None = None   # actual (possibly fused) steps
    step_occupancy: np.ndarray | None = None  # (n_steps,) non-NOP units
    n_unit_scheduled: int | None = None    # the n_unit compiled for

    @staticmethod
    def from_program(prog) -> "FfclStats":
        occ = (prog.opcode != 0).sum(axis=1).astype(np.int64)
        if prog.n_steps:
            hist = np.bincount(prog.level_of_step - 1, weights=occ,
                               minlength=prog.depth)
        else:
            hist = np.zeros(prog.depth)
        return FfclStats(
            n_gates=prog.n_gates, depth=prog.depth, n_fanin=prog.n_inputs,
            n_outputs=prog.n_outputs,
            level_histogram=hist.astype(np.int64),
            n_steps_scheduled=prog.n_steps, step_occupancy=occ,
            n_unit_scheduled=prog.n_unit)

    @staticmethod
    def from_graph(graph, optimized=False) -> "FfclStats":
        """Closed-form (eq. 23-path) stats of a graph.

        ``optimized`` is the shared core/opt.py knob (``True`` /
        ``"default"`` for the default pass pipeline, a ``PassManager``
        for a custom one, a :class:`~repro.core.spec.CompileSpec` for
        its resolved pipeline, ``False`` / ``"none"`` for raw):
        design-space sweeps (``optimizer.sweep``/``binary_search``)
        should probe the post-optimization gate counts the scheduler
        will actually emit — probing raw synthesis output
        systematically overstates both the compute and address-stream
        terms of eq. 22.
        """
        from repro.core.levelize import levelize
        from repro.core.opt import resolve_pipeline
        pipeline = resolve_pipeline(optimized)
        if pipeline is not None:
            graph = pipeline.run(graph).graph
        lv = levelize(graph)
        return FfclStats(graph.n_gates, lv.depth, graph.n_inputs,
                         graph.n_outputs, lv.histogram())


@dataclass(frozen=True)
class LayerLoad:
    """One network layer's load for the whole-network cost equations.

    Replaces the untyped ``(stats, n_filters, n_input_vectors)`` tuples
    ``CostModel.network_cycles`` and the design-space searches
    (``optimizer.sweep``/``binary_search``) used to take:

      * ``stats``           — the representative FFCL module's
        :class:`FfclStats` (one filter / neuron of the layer);
      * ``n_copies``        — how many structurally-like modules run
        back-to-back with task pipelining (paper eq. 2's ``m``: the
        layer's filter count);
      * ``n_input_vectors`` — SIMD batch for the layer (conv patches x
        samples; sets the packed word width W).

    Iterable in that order, so legacy ``for stats, m, n_vec in layers``
    unpacking keeps working; the model-facing entry points also still
    accept raw tuples (:meth:`from_any`).
    """

    stats: FfclStats
    n_copies: int = 1
    n_input_vectors: int = 1

    def __post_init__(self):
        if self.n_copies < 1:
            raise ValueError(f"n_copies must be >= 1, got {self.n_copies}")
        if self.n_input_vectors < 1:
            raise ValueError(
                f"n_input_vectors must be >= 1, got {self.n_input_vectors}")

    def __iter__(self):
        yield self.stats
        yield self.n_copies
        yield self.n_input_vectors

    @staticmethod
    def from_any(obj) -> "LayerLoad":
        """Normalize a ``LayerLoad`` or a legacy 3-tuple."""
        if isinstance(obj, LayerLoad):
            return obj
        stats, n_copies, n_vec = obj
        return LayerLoad(stats=stats, n_copies=int(n_copies),
                         n_input_vectors=int(n_vec))


def normalize_layers(layers) -> list[LayerLoad]:
    """Tuple-accepting shim for every ``layers`` argument below."""
    return [LayerLoad.from_any(lw) for lw in layers]


def n_subkernels(stats: FfclStats, n_unit: int) -> int:
    """Sub-kernel step count: the actual scheduled count when the stats come
    from a compiled (possibly level-fused) program, else eq. 23's closed
    form — sum over levels of ceil(gates_l / n_unit).

    Program-derived stats are pinned to the unit count they were compiled
    for; probing a different ``n_unit`` with them is an error (use
    ``FfclStats.from_graph`` for design-space sweeps)."""
    if stats.n_steps_scheduled is not None:
        if stats.n_unit_scheduled is not None and \
                n_unit != stats.n_unit_scheduled:
            raise ValueError(
                f"stats were compiled for n_unit={stats.n_unit_scheduled}; "
                f"cannot probe n_unit={n_unit} with a scheduled step count")
        return int(stats.n_steps_scheduled)
    return int(np.ceil(stats.level_histogram / n_unit).sum())


@dataclass
class CostBreakdown:
    """Every term of eq. 22, in cycles."""

    n_read_inputs_opcode_mem: float
    n_read_addr_mem: float
    n_data_moves: float          # eq. 3/12: max of the two streams
    n_copy_mem_in: float         # eq. 18
    n_loop_subkernels: float     # eq. 20
    n_outputs_drain: float
    n_compute: float             # eq. 21
    n_total_pipelined: float     # eq. 2 with m modules
    m_modules: int
    n_unit: int
    bound: str = ""              # 'data_moves' | 'compute'

    def seconds(self, fabric: TpuFabric) -> float:
        return self.n_total_pipelined / fabric.clock_hz


class CostModel:
    """Paper §6.2 with TPU constants.

    Word width W = ceil(n_input_vectors / 32): the SIMD axis. A gate-op row
    is (1, W) int32 -> ceil(W / (8*128)) VPU cycles.
    """

    def __init__(self, fabric: TpuFabric | None = None):
        self.fabric = fabric or TpuFabric()
        f = self.fabric
        if isinstance(f, FpgaFabric):
            # paper Table 1: 512-bit AXI / 14-bit addr, 48-bit input word,
            # 6-bit opcode
            self.lambda_, self.delta, self.zeta = 36, 10, 85
        else:
            # re-derived for the TPU bus: addresses int32 (3 per unit),
            # opcodes int8, inputs int32 words.
            self.lambda_ = f.dma_beat_bytes // 4    # addresses per beat
            self.delta = f.dma_beat_bytes // 4      # input words per beat
            self.zeta = f.dma_beat_bytes            # opcodes per beat

    # -- helpers ---------------------------------------------------------
    def _w_words(self, n_input_vectors: int) -> int:
        return -(-n_input_vectors // self.fabric.simd_lanes)

    def _vpu_cycles_per_row(self, w_words: int) -> float:
        f = self.fabric
        return max(1.0, w_words / f.vpu_word_ops_per_cycle)

    # -- eq. 6/9: address-stream movement --------------------------------
    def n_read_addr_mem(self, stats: FfclStats, n_unit: int) -> float:
        nsk = n_subkernels(stats, n_unit)
        n_addresses = 3 * n_unit * nsk             # 2 reads + 1 write per unit
        hbm_cycles = (n_addresses * 4) / self.fabric.hbm_bytes_per_cycle
        # URAM->BRAM distribution halved by dual-porting (eq. 8) -> on TPU the
        # program stream is consumed straight from VMEM; charge VMEM copy:
        vmem_cycles = (n_addresses * 4) / self.fabric.vmem_bytes_per_cycle
        return hbm_cycles + 0.5 * vmem_cycles

    # -- eq. 11: inputs + opcodes ----------------------------------------
    def n_read_inputs_opcode_mem(self, stats: FfclStats, n_unit: int,
                                 n_input_vectors: int) -> float:
        w = self._w_words(n_input_vectors)
        nsk = n_subkernels(stats, n_unit)
        input_bytes = stats.n_fanin * w * 4
        opcode_bytes = nsk * n_unit * 1
        return (input_bytes + opcode_bytes) / self.fabric.hbm_bytes_per_cycle

    # -- eq. 12 ----------------------------------------------------------
    def n_data_moves(self, stats: FfclStats, n_unit: int,
                     n_input_vectors: int) -> float:
        return max(
            self.n_read_inputs_opcode_mem(stats, n_unit, n_input_vectors),
            self.n_read_addr_mem(stats, n_unit))

    # -- eqs. 14-20: compute loop ----------------------------------------
    def n_loop_subkernels(self, stats: FfclStats, n_unit: int,
                          n_input_vectors: int,
                          exact_occupancy: bool = False) -> float:
        """Gather operands, execute, scatter results, per sub-kernel step.

        ``exact_occupancy=False`` reproduces the paper's worst-case
        assumption (every step uses all n_unit units) -- the stated source
        of its <10% model error. ``True`` charges actual per-step occupancy:
        the scheduled ``step_occupancy`` profile when the stats come from a
        compiled program (what the simulator feeds in), else the per-level
        ceil/remainder approximation of the eq. 23 layout.
        """
        w = self._w_words(n_input_vectors)
        f = self.fabric

        def step_cost(units):
            # eq. 16 analogue: 2 operand-row gathers (VMEM->VREG) per unit,
            # eq. 19: 1 result-row scatter (half the gather traffic); the
            # opcode op runs at the fabric's word throughput (one (8,128)
            # slab/cycle on the VPU; 1 cycle across all DSP48s); plus the
            # fixed per-step overhead (see TpuFabric/FpgaFabric). Pure
            # arithmetic, so it vectorizes over an occupancy array.
            gather = 2 * units * w * 4 / f.vmem_bytes_per_cycle
            execute = f.step_exe_cycles + units * w / f.vpu_word_ops_per_cycle
            scatter = units * w * 4 / f.vmem_bytes_per_cycle
            return f.step_overhead_cycles + gather + execute + scatter

        if not exact_occupancy:
            nsk = n_subkernels(stats, n_unit)
            units = float(n_unit)
            if stats.n_steps_scheduled is not None and nsk:
                # fused-step extension: the scheduler packs steps densely,
                # so the mean scheduled occupancy (cost is linear in units)
                # replaces the paper's all-units worst case, which at low
                # occupancy overshoots the simulator far past the paper's
                # <10% bound. Closed form still — no occupancy profile.
                units = min(units, stats.n_gates / nsk)
            return nsk * step_cost(units)
        if stats.step_occupancy is not None:
            return float(np.sum(step_cost(
                stats.step_occupancy.astype(np.float64))))
        full = stats.level_histogram // n_unit
        rem = stats.level_histogram % n_unit
        return float((full * step_cost(n_unit)).sum()
                     + step_cost(rem[rem > 0].astype(np.float64)).sum())

    def n_compute(self, stats: FfclStats, n_unit: int, n_input_vectors: int,
                  exact_occupancy: bool = False) -> float:
        w = self._w_words(n_input_vectors)
        f = self.fabric
        # eq. 18: replicate the input rows into the VMEM buffer
        n_copy_mem_in = stats.n_fanin * w * 4 / f.vmem_bytes_per_cycle
        loop = self.n_loop_subkernels(stats, n_unit, n_input_vectors,
                                      exact_occupancy)
        n_outputs_drain = stats.n_outputs * w * 4 / f.hbm_bytes_per_cycle
        return n_copy_mem_in + loop + n_outputs_drain

    # -- eq. 2/22 ---------------------------------------------------------
    def breakdown(self, stats: FfclStats, n_unit: int, n_input_vectors: int,
                  m_modules: int = 1,
                  exact_occupancy: bool = False) -> CostBreakdown:
        w = self._w_words(n_input_vectors)
        f = self.fabric
        dm_in = self.n_read_inputs_opcode_mem(stats, n_unit, n_input_vectors)
        dm_addr = self.n_read_addr_mem(stats, n_unit)
        dm = max(dm_in, dm_addr)
        loop = self.n_loop_subkernels(stats, n_unit, n_input_vectors,
                                      exact_occupancy)
        copy_in = stats.n_fanin * w * 4 / f.vmem_bytes_per_cycle
        drain = stats.n_outputs * w * 4 / f.hbm_bytes_per_cycle
        comp = copy_in + loop + drain
        total = (m_modules + 1) * max(dm, comp)
        return CostBreakdown(
            n_read_inputs_opcode_mem=dm_in, n_read_addr_mem=dm_addr,
            n_data_moves=dm, n_copy_mem_in=copy_in, n_loop_subkernels=loop,
            n_outputs_drain=drain, n_compute=comp, n_total_pipelined=total,
            m_modules=m_modules, n_unit=n_unit,
            bound="data_moves" if dm >= comp else "compute")

    def total_cycles(self, stats: FfclStats, n_unit: int,
                     n_input_vectors: int, m_modules: int = 1) -> float:
        return self.breakdown(stats, n_unit, n_input_vectors,
                              m_modules).n_total_pipelined

    # -- paper §7.2 eq. 24: whole-network cost ---------------------------
    def network_cycles(self, layers: list[LayerLoad], n_unit: int,
                       parallel_factor: int = 1) -> float:
        """layers: :class:`LayerLoad` entries (legacy
        ``(stats, n_copies, n_input_vectors)`` tuples still accepted).

        Within a layer, the n_copies FFCL modules run back-to-back with
        task pipelining (§5.2.3): data movement of filter k+1 overlaps
        compute of filter k, so the layer costs
        (n_copies + 1) * max(dm, comp)  — eq. 2 with m = n_copies.
        Layers are sequential (§7.2); parallel compute kernels divide the
        total (eq. 25)."""
        tot = 0.0
        for lw in normalize_layers(layers):
            tot += self.total_cycles(lw.stats, n_unit, lw.n_input_vectors,
                                     m_modules=lw.n_copies)
        return tot / parallel_factor

    def network_cycles_parallel(self, layers, n_per: int, k: int) -> float:
        """Eq. 25 with bandwidth conservation: k concurrent compute kernels
        of n_per units each split every layer's filters, but their data-
        movement streams SHARE the fixed off-chip bandwidth, so each
        kernel's dm term stretches by k. Per layer (per kernel, all run
        in parallel):  (ceil(m/k) + 1) * max(k * dm, comp)."""
        tot = 0.0
        for lw in normalize_layers(layers):
            b = self.breakdown(lw.stats, n_per, lw.n_input_vectors,
                               m_modules=1)
            m_k = -(-lw.n_copies // k)
            tot += (m_k + 1) * max(k * b.n_data_moves, b.n_compute)
        return tot
