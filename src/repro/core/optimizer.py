"""Design-space exploration over the number of compute units (paper §7.2).

Latency vs n_unit is U-shaped (paper Fig. 6): more units shrink the compute
term (fewer sub-kernel steps) but grow the address-stream data-movement term
(3 addresses per unit per step, and padding waste). Eq. 26 minimizes total
cycles subject to n_unit <= N_max via binary search; we implement the same
search (on the discrete derivative) plus an exhaustive sweep for plots.

Network loads are :class:`~repro.core.cost_model.LayerLoad` values (legacy
``(stats, n_copies, n_input_vectors)`` tuples still accepted).  With the
:class:`~repro.core.spec.CompileSpec` API this search is no longer a
separate manual workflow: ``CompileSpec(n_unit="auto")`` routes every
compile path through :func:`binary_search` via
:class:`~repro.core.compiler.LogicCompiler`.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.cost_model import (CostModel, FfclStats, LayerLoad,
                                   normalize_layers)

__all__ = ["FfclStats", "LayerLoad", "SearchResult", "sweep",
           "binary_search"]


@dataclass
class SearchResult:
    best_n_unit: int
    best_cycles: float
    evaluations: list[tuple[int, float]]   # (n_unit, cycles) probes, in order


def _network_cost(model: CostModel, layers: list[LayerLoad],
                  n_unit: int, parallel_factor: int = 1) -> float:
    return model.network_cycles(layers, n_unit, parallel_factor)


def sweep(model: CostModel, layers, n_units: list[int],
          parallel_factor: int = 1) -> SearchResult:
    """Exhaustive probe of every candidate unit count (for plots)."""
    layers = normalize_layers(layers)
    if not n_units:
        raise ValueError("sweep needs at least one n_unit candidate")
    if min(n_units) < 1:
        raise ValueError(f"n_unit candidates must be >= 1, got {n_units!r}")
    evals = [(u, _network_cost(model, layers, u, parallel_factor))
             for u in n_units]
    best = min(evals, key=lambda t: t[1])
    return SearchResult(best[0], best[1], evals)


def binary_search(model: CostModel, layers, n_unit_max: int,
                  parallel_factor: int = 1,
                  n_unit_min: int = 1) -> SearchResult:
    """Binary search on the sign of the discrete derivative (paper §8.1).

    Assumes unimodal latency in n_unit (holds for the model: the compute
    term is ~1/n decreasing + ceil-steps, the address term is increasing).

    Degenerate ranges are handled without probing out of bounds: with
    ``n_unit_max <= n_unit_min + 2`` the search reduces to enumerating
    the (at most three) in-range candidates, and every probe — including
    the final candidate enumeration — lands in
    ``[n_unit_min, n_unit_max]`` and is recorded once in
    ``evaluations``.
    """
    layers = normalize_layers(layers)
    if n_unit_min < 1:
        raise ValueError(f"n_unit_min must be >= 1, got {n_unit_min}")
    if n_unit_max < n_unit_min:
        raise ValueError(
            f"empty search range: n_unit_max={n_unit_max} < "
            f"n_unit_min={n_unit_min}")
    evals: list[tuple[int, float]] = []
    memo: dict[int, float] = {}

    def cost(u: int) -> float:
        if u not in memo:
            memo[u] = _network_cost(model, layers, u, parallel_factor)
            evals.append((u, memo[u]))
        return memo[u]

    lo, hi = n_unit_min, n_unit_max
    while hi - lo > 2:
        mid = (lo + hi) // 2               # lo < mid, mid + 1 < hi here
        if cost(mid) <= cost(mid + 1):
            hi = mid + 1       # minimum is at mid or left of it
        else:
            lo = mid + 1
    cand = {u: cost(u) for u in range(lo, hi + 1)}
    best_u = min(cand, key=cand.get)
    return SearchResult(best_u, cand[best_u], evals)
