"""Design-space exploration over the number of compute units (paper §7.2).

Latency vs n_unit is U-shaped (paper Fig. 6): more units shrink the compute
term (fewer sub-kernel steps) but grow the address-stream data-movement term
(3 addresses per unit per step, and padding waste).  Eq. 26 minimizes total
cost subject to n_unit <= N_max; the paper does it by binary search on the
discrete derivative, which assumes the curve is unimodal.  It is NOT: the
step count sum_l ceil(gates_l / n_unit) is a staircase, so the total cost
is a descending sawtooth crossing an ascending line — full of local minima
(the committed BENCH snapshot caught the descent picking n_unit=20 at
150.7us modelled where the sweep best was n_unit=32 at 133.2us).

:func:`binary_search` is therefore *exact* now: within any interval of
``n_unit`` where every layer's per-level ``ceil(hist_l / n_unit)`` plateau
holds, every cost term is constant or strictly increasing in ``n_unit``
(address stream ~ 3*u*nsk, per-step gather/execute/scatter ~ u; the
calibrated wall-clock phases inherit the same structure from
:func:`~repro.core.calibrate.phase_terms`), so the global minimum always
lands on a plateau *left edge* ``u = ceil(h / k)``.  Enumerating those
edges — O(sum_l sqrt(gates_l)) probes, not the full range — and taking the
argmin reproduces the exhaustive sweep's pick exactly, ties included
(both resolve to the smallest minimizing ``n_unit``).

Both searches take ``objective="cycles"`` (default: the paper's modelled
cycles via ``model.network_cycles``) or ``objective="wallclock"`` (the
measurement-calibrated seconds of
:class:`~repro.core.calibrate.WallClockModel.network_seconds`; see
DESIGN.md §12).  Network loads are
:class:`~repro.core.cost_model.LayerLoad` values (legacy
``(stats, n_copies, n_input_vectors)`` tuples still accepted).  With the
:class:`~repro.core.spec.CompileSpec` API this search is no longer a
separate manual workflow: ``CompileSpec(n_unit="auto")`` routes every
compile path through :func:`binary_search` via
:class:`~repro.core.compiler.LogicCompiler`.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.cost_model import (FfclStats, LayerLoad, normalize_layers)

__all__ = ["FfclStats", "LayerLoad", "SearchResult", "sweep",
           "binary_search", "OBJECTIVES"]

#: Valid DSE objectives: modelled cycles (paper §7.2) or calibrated
#: wall-clock seconds (DESIGN.md §12).
OBJECTIVES = ("cycles", "wallclock")


@dataclass
class SearchResult:
    best_n_unit: int
    #: Best objective value: modelled cycles for ``objective="cycles"``,
    #: calibrated seconds for ``objective="wallclock"`` (the field name
    #: predates the objective knob and is kept for API stability).
    best_cycles: float
    evaluations: list[tuple[int, float]]   # (n_unit, cost) probes, in order
    objective: str = "cycles"
    #: The other objective's pick, when the caller resolved both
    #: (LogicCompiler records cycles+wallclock picks side by side in the
    #: DSE provenance).  ``compare=False``: provenance, not identity.
    alt: "SearchResult | None" = field(default=None, compare=False)


def _network_cost(model, layers: list[LayerLoad], n_unit: int,
                  parallel_factor: int = 1,
                  objective: str = "cycles") -> float:
    if objective == "wallclock":
        fn = getattr(model, "network_seconds", None)
        if fn is None:
            raise TypeError(
                "objective='wallclock' needs a model exposing "
                "network_seconds (core.calibrate.WallClockModel, built "
                f"from a fitted Calibration); got {type(model).__name__} "
                "— fit/load a calibration or use objective='cycles'")
        return fn(layers, n_unit, parallel_factor)
    return model.network_cycles(layers, n_unit, parallel_factor)


def _check_objective(objective: str) -> None:
    if objective not in OBJECTIVES:
        raise ValueError(
            f"unknown objective {objective!r}; use one of {OBJECTIVES}")


def sweep(model, layers, n_units: list[int], parallel_factor: int = 1,
          objective: str = "cycles") -> SearchResult:
    """Exhaustive probe of every candidate unit count (for plots)."""
    _check_objective(objective)
    layers = normalize_layers(layers)
    if not n_units:
        raise ValueError("sweep needs at least one n_unit candidate")
    if min(n_units) < 1:
        raise ValueError(f"n_unit candidates must be >= 1, got {n_units!r}")
    evals = [(u, _network_cost(model, layers, u, parallel_factor, objective))
             for u in n_units]
    best = min(evals, key=lambda t: t[1])
    return SearchResult(best[0], best[1], evals, objective=objective)


def _plateau_edges(h: int, lo: int, hi: int, out: set) -> None:
    """Add to ``out`` every u in (lo, hi] where ``ceil(h / u)`` steps down
    — the left edge of the k-step plateau is ``u = ceil(h / k)``; the
    distinct edges are enumerated in O(sqrt(h)) by jumping k to the next
    value that shrinks the edge."""
    k = 1
    while True:
        u = -(-h // k)                       # ceil(h / k)
        if u <= lo:
            break
        if u <= hi:
            out.add(u)
        if u == 1:
            break
        k = -(-h // (u - 1))                 # smallest k with ceil(h/k) < u


def _candidates(layers: list[LayerLoad], lo: int, hi: int) -> list[int]:
    """Every n_unit in [lo, hi] that can be a global minimum: the range
    bounds plus each layer's per-level plateau left edges."""
    cands = {lo, hi}
    for lw in layers:
        hist = np.asarray(lw.stats.level_histogram).ravel()
        for h in hist.tolist():
            if h and h > 0:
                _plateau_edges(int(h), lo, hi, cands)
    return sorted(cands)


def binary_search(model, layers, n_unit_max: int, parallel_factor: int = 1,
                  n_unit_min: int = 1,
                  objective: str = "cycles") -> SearchResult:
    """Exact minimization over ``n_unit in [n_unit_min, n_unit_max]``.

    Supersedes the paper's §8.1 descent on the discrete derivative,
    which assumed a unimodal curve: the ceil-staircase step count makes
    the cost a sawtooth with local minima, and the descent demonstrably
    parked in them (see the module docstring).  Instead, every candidate
    that can host the global minimum — the plateau left edges of each
    layer's ``ceil(hist_l / n_unit)`` plus the range bounds — is probed
    once, and the smallest minimizing unit count wins, which is exactly
    the exhaustive sweep's pick (ties included).  Probe count stays
    O(sum over levels of sqrt(gates)) — logarithmic-in-spirit, far below
    the full range — and every probe lands in
    ``[n_unit_min, n_unit_max]`` exactly once in ``evaluations``.
    """
    _check_objective(objective)
    layers = normalize_layers(layers)
    if n_unit_min < 1:
        raise ValueError(f"n_unit_min must be >= 1, got {n_unit_min}")
    if n_unit_max < n_unit_min:
        raise ValueError(
            f"empty search range: n_unit_max={n_unit_max} < "
            f"n_unit_min={n_unit_min}")
    evals: list[tuple[int, float]] = []
    best_u, best_c = None, None
    for u in _candidates(layers, n_unit_min, n_unit_max):
        c = _network_cost(model, layers, u, parallel_factor, objective)
        evals.append((u, c))
        if best_c is None or c < best_c:
            best_u, best_c = u, c
    return SearchResult(best_u, best_c, evals, objective=objective)
