"""Design-space exploration over the number of compute units (paper §7.2).

Latency vs n_unit is U-shaped (paper Fig. 6): more units shrink the compute
term (fewer sub-kernel steps) but grow the address-stream data-movement term
(3 addresses per unit per step, and padding waste). Eq. 26 minimizes total
cycles subject to n_unit <= N_max via binary search; we implement the same
search (on the discrete derivative) plus an exhaustive sweep for plots.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.cost_model import CostModel, FfclStats


@dataclass
class SearchResult:
    best_n_unit: int
    best_cycles: float
    evaluations: list[tuple[int, float]]   # (n_unit, cycles) probes, in order


def _network_cost(model: CostModel,
                  layers: list[tuple[FfclStats, int, int]],
                  n_unit: int, parallel_factor: int = 1) -> float:
    return model.network_cycles(layers, n_unit, parallel_factor)


def sweep(model: CostModel, layers: list[tuple[FfclStats, int, int]],
          n_units: list[int], parallel_factor: int = 1) -> SearchResult:
    evals = [(u, _network_cost(model, layers, u, parallel_factor))
             for u in n_units]
    best = min(evals, key=lambda t: t[1])
    return SearchResult(best[0], best[1], evals)


def binary_search(model: CostModel, layers: list[tuple[FfclStats, int, int]],
                  n_unit_max: int, parallel_factor: int = 1,
                  n_unit_min: int = 1) -> SearchResult:
    """Binary search on the sign of the discrete derivative (paper §8.1).

    Assumes unimodal latency in n_unit (holds for the model: the compute
    term is ~1/n decreasing + ceil-steps, the address term is increasing).
    """
    evals: list[tuple[int, float]] = []

    def cost(u: int) -> float:
        c = _network_cost(model, layers, u, parallel_factor)
        evals.append((u, c))
        return c

    lo, hi = n_unit_min, n_unit_max
    while hi - lo > 2:
        mid = (lo + hi) // 2
        if cost(mid) <= cost(mid + 1):
            hi = mid + 1       # minimum is at mid or left of it
        else:
            lo = mid + 1
    cand = {u: _network_cost(model, layers, u, parallel_factor)
            for u in range(lo, hi + 1)}
    best_u = min(cand, key=cand.get)
    return SearchResult(best_u, cand[best_u], evals)
