"""FFCL graph partitioning: split modules that exceed the on-chip budget.

The paper's §2 closes with: "by leveraging a hybrid implementation, i.e.,
mapping some FFCL modules to LUTs and others to DSPs, a high-performance
inference engine for ANY network on ANY FPGA device can be achieved." The
TPU analogue of the resource wall is the VMEM data buffer: a compiled
program needs `n_addr x W x 4` bytes resident; graphs from wide NullaNet
layers can exceed the per-core budget.

``partition(graph, max_outputs | budget)`` splits a multi-output FFCL into
sub-FFCLs by *output-cone clustering*: each output's transitive fanin cone
is computed, and outputs are greedily packed into clusters that maximize
cone overlap (shared gates are deduplicated inside a cluster but duplicated
across clusters — the classic area/latency trade the paper's LUT/DSP hybrid
makes). The resulting modules execute back-to-back on the same fabric with
task pipelining (simulator.py), exactly like the paper's multi-FFCL flow.

``execute_partitions`` re-assembles the full output vector and is tested
for exact equivalence against the unpartitioned graph. The serving engine
(serve/logic_engine.py) does the same re-assembly at the packed-word level:
``output_permutation`` maps the concatenation of per-partition output rows
back to the original output order, so a partitioned graph is served as a
pipelined sequence of programs over ONE packed input slab.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.gate_ir import CONST0, CONST1, LogicGraph, OpCode, UNARY
from repro.core.opt import resolve_pipeline
from repro.core.scheduler import LogicProgram, compile_graph
from repro.core.spec import CompileSpec, resolve_spec, _UNSET
from repro.core.verify import (ScheduleVerificationError, VerifyReport,
                               certify_remap)


def output_cones(graph: LogicGraph) -> list[set]:
    """Transitive-fanin gate set (wire ids) per output."""
    memo: dict[int, frozenset] = {}

    def cone(w: int) -> frozenset:
        if w in memo:
            return memo[w]
        if not graph.is_gate(w):
            memo[w] = frozenset()
            return memo[w]
        op, a, b = graph.gate_of_wire(w)
        s = {w} | set(cone(a))
        if OpCode(op) not in UNARY:
            s |= set(cone(b))
        memo[w] = frozenset(s)
        return memo[w]

    # iterative bottom-up to avoid recursion limits on deep graphs
    base = graph.first_gate_wire
    for i in range(graph.n_gates):
        w = base + i
        op, a, b = graph.gates[i]
        s = {w} | set(memo.get(a, frozenset()))
        if OpCode(op) not in UNARY:
            s |= set(memo.get(b, frozenset()))
        memo[w] = frozenset(s)
    return [set(memo.get(o, frozenset())) for o in graph.outputs]


@dataclass(frozen=True)
class Partition:
    graph: LogicGraph           # sub-FFCL (inputs = original inputs)
    output_indices: list        # positions in the original output vector


def _extract(graph: LogicGraph, out_idx: list[int]) -> LogicGraph:
    """Sub-graph computing the given outputs (gates outside the union of
    their cones dropped, topological order preserved)."""
    keep_outputs = [graph.outputs[i] for i in out_idx]
    live = set(keep_outputs)
    base = graph.first_gate_wire
    for i in range(graph.n_gates - 1, -1, -1):
        w = base + i
        if w in live:
            op, a, b = graph.gates[i]
            live.add(a)
            if OpCode(op) not in UNARY:
                live.add(b)
    sub = LogicGraph(graph.n_inputs, name=f"{graph.name}.part")
    repl = {CONST0: CONST0, CONST1: CONST1}
    for i in range(graph.n_inputs):
        repl[2 + i] = 2 + i
    for i in range(graph.n_gates):
        w = base + i
        if w in live:
            op, a, b = graph.gates[i]
            repl[w] = sub.add_gate(OpCode(op), repl[a], repl.get(b, CONST0))
    sub.set_outputs(repl[o] for o in keep_outputs)
    return sub


def partition(graph: LogicGraph, max_gates: int | CompileSpec, *,
              optimize=_UNSET) -> list[Partition]:
    """Greedy cone-overlap clustering under a per-partition gate budget.

    Each cluster's gate set is the union of its members' cones; a new
    output joins the cluster where it adds the fewest NEW gates, if the
    union stays <= max_gates; otherwise it seeds a new cluster.

    ``max_gates`` is either the bare budget (an int — partitioning's
    core argument, not deprecated) or a full
    :class:`~repro.core.spec.CompileSpec`, whose ``max_gates`` must be
    set and whose ``optimize`` pipeline runs on each extracted cluster
    cone: cross-cluster gate duplication re-exposes
    constant/CSE/dead-fanin slack *inside* a cluster that global
    optimization could not see, so per-cluster passes shrink the
    per-program buffer budget the partitioning exists to bound. Budget
    accounting stays on the raw cone sizes (optimization only shrinks a
    cluster, never grows it).

    The loose ``optimize=`` kwarg is the deprecated pre-spec
    convention (``DeprecationWarning``); pass a spec instead.
    """
    if isinstance(max_gates, CompileSpec):
        if optimize is not _UNSET:
            raise TypeError("partition: pass either a CompileSpec or the "
                            "legacy optimize= kwarg, not both")
        spec = max_gates
        if spec.max_gates is None:
            raise ValueError(
                "partition needs a budget: spec.max_gates must be set")
        max_gates, pipeline = spec.max_gates, spec.pipeline
        certify = spec.verify in ("compile", "full")
    else:
        certify = False
        import warnings
        from repro.core.spec import DEPRECATION_PREFIX
        if optimize is _UNSET:
            pipeline = None
        else:
            warnings.warn(
                f"{DEPRECATION_PREFIX}: partition(optimize=...) is "
                "deprecated; pass a CompileSpec as the budget argument",
                DeprecationWarning, stacklevel=2)
            pipeline = resolve_pipeline(optimize)
    if graph.n_outputs == 0:
        return []
    cones = output_cones(graph)
    order = np.argsort([-len(c) for c in cones], kind="stable")
    clusters: list[tuple[set, list]] = []   # (gate union, output indices)
    for oi in order:
        oi = int(oi)
        cone = cones[oi]
        best, best_new = None, None
        for ci, (union, members) in enumerate(clusters):
            new = len(cone - union)
            if len(union) + new <= max_gates and \
                    (best_new is None or new < best_new):
                best, best_new = ci, new
        if best is None:
            clusters.append((set(cone), [oi]))
        else:
            clusters[best][0].update(cone)
            clusters[best][1].append(oi)
    parts = []
    for _, members in clusters:
        sub = _extract(graph, members)
        if pipeline is not None:
            res = pipeline.run(sub)
            if certify:
                # per-cluster remap certificate (verify="compile"/"full",
                # DESIGN.md §13): the rewrite must map the cone's outputs
                # totally and in range before its program is trusted
                diags = certify_remap(sub, res.graph, res.remap,
                                      label=f"partition({sub.name})")
                if diags:
                    raise ScheduleVerificationError(VerifyReport(
                        target=sub.name, diagnostics=tuple(diags)))
            sub = res.graph
        parts.append(Partition(graph=sub, output_indices=members))
    return parts


def compile_partitions(parts: list[Partition],
                       spec: CompileSpec | int | None = None, *,
                       n_unit=_UNSET, alloc=_UNSET) -> list[LogicProgram]:
    """Schedule every sub-FFCL per ``spec``'s fabric/layout knobs.

    The optimize stage is stripped (``optimize="none"``): ``partition``
    already ran the pipeline per cluster, so re-running it here would be
    pure waste — and the pre-spec behaviour compiled parts raw, which
    this preserves exactly.  ``max_gates`` is likewise moot (the parts
    ARE the budget's product).  Legacy ``n_unit``/``alloc`` kwargs warn.
    """
    spec = resolve_spec(spec, caller="compile_partitions",
                        n_unit=n_unit, alloc=alloc)
    mono = spec.with_(optimize="none", max_gates=None)
    return [compile_graph(p.graph, mono) for p in parts]


def output_permutation(parts: list[Partition], n_outputs: int) -> np.ndarray:
    """Permutation ``perm`` with ``concat(part outputs)[perm] == original``.

    Row ``perm[oi]`` is the position of original output ``oi`` in the
    concatenation of the partitions' output vectors (in partition order).
    Every partition shares the full primary-input vector, so stacking the
    per-program ``(n_out_p, W)`` output slabs and gathering with ``perm``
    re-assembles the monolithic ``(n_outputs, W)`` result without
    unpacking — the word-level analogue of :func:`execute_partitions`.
    """
    perm = np.full(n_outputs, -1, dtype=np.int64)
    pos = 0
    for p in parts:
        for oi in p.output_indices:
            perm[oi] = pos
            pos += 1
    if pos != n_outputs or (perm < 0).any():
        raise ValueError("partitions do not cover every output exactly once")
    return perm


def mega_pipeline(programs, output_perm: np.ndarray,
                  mode: str = "parallel", name: str = "pipeline"):
    """Flatten a compiled pipeline into one single-launch
    :class:`~repro.core.scheduler.MegaProgram`.

    For ``mode="parallel"`` (a partitioned artifact) the partitions'
    concatenated output slabs are permuted back to the original output
    order *inside* the kernel — the word-level re-assembly
    :func:`output_permutation` describes stops being a separate host/XLA
    gather step and the whole pipeline becomes one ``pallas_call``.  For
    ``mode="chain"`` the permutation is necessarily identity (the last
    stage's outputs are the pipeline's) and stage handoff fuses instead.
    """
    from repro.core.scheduler import build_megaprogram
    if mode == "chain":
        return build_megaprogram(programs, mode="chain", name=name)
    return build_megaprogram(programs, mode="parallel",
                             output_perm=output_perm, name=name)


def execute_partitions(parts: list[Partition], inputs: np.ndarray,
                       executor=None) -> np.ndarray:
    """Run every sub-FFCL and reassemble the original output order."""
    n_out = sum(len(p.output_indices) for p in parts)
    out = np.zeros((inputs.shape[0], n_out), dtype=bool)
    for p in parts:
        run = executor or (lambda g, x: g.evaluate(x))
        sub_out = run(p.graph, inputs)
        for j, oi in enumerate(p.output_indices):
            out[:, oi] = sub_out[:, j]
    return out


def duplication_factor(graph: LogicGraph, parts: list[Partition]) -> float:
    """Total gates across partitions / original gates (the area cost of
    the split; the latency gain comes from pipelining + smaller buffers)."""
    if graph.n_gates == 0:
        return 1.0
    return sum(p.graph.n_gates for p in parts) / graph.n_gates
