"""Gate-level IR for fixed-function combinational logic (FFCL) modules.

The paper's compiler consumes a combinational netlist (Verilog), maps it to a
2-input gate library supported by the compute units (DSP48 bitwise ALU ops),
levelizes it, and schedules it. ``LogicGraph`` is that netlist: an int-indexed
DAG in topological order.

Wire numbering convention (matches the paper's Tables 2/3):
  wire 0      -> constant 0   (paper: data-vector index 0 = 0x0000)
  wire 1      -> constant 1   (paper: data-vector index 1 = 0xFFFF)
  wires 2..   -> primary inputs, then gates in creation (topological) order
"""
from __future__ import annotations

import enum
import hashlib
from dataclasses import dataclass, field
from typing import Iterable, Sequence

import numpy as np

CONST0 = 0
CONST1 = 1


class OpCode(enum.IntEnum):
    """Bitwise ops supported by a compute unit (paper §5: DSP48 logic unit)."""

    NOP = 0    # no operation (paper's NOP padding in sub-kernels)
    AND = 1
    OR = 2
    XOR = 3
    NAND = 4
    NOR = 5
    XNOR = 6
    NOT = 7    # unary: operand b ignored
    COPY = 8   # unary passthrough: used for buffer moves


# numpy-level semantics of each opcode on packed uint32/int32 words.
_OP_FNS = {
    OpCode.NOP: lambda a, b: a * 0,
    OpCode.AND: lambda a, b: a & b,
    OpCode.OR: lambda a, b: a | b,
    OpCode.XOR: lambda a, b: a ^ b,
    OpCode.NAND: lambda a, b: ~(a & b),
    OpCode.NOR: lambda a, b: ~(a | b),
    OpCode.XNOR: lambda a, b: ~(a ^ b),
    OpCode.NOT: lambda a, b: ~a,
    OpCode.COPY: lambda a, b: a,
}

COMMUTATIVE = {OpCode.AND, OpCode.OR, OpCode.XOR, OpCode.NAND, OpCode.NOR,
               OpCode.XNOR}
UNARY = {OpCode.NOT, OpCode.COPY}
# Dispatch-branch index of the generic (mixed-opcode) kernel path: branches
# 0..8 are the specialized per-opcode slab ops, 9 the 8-way chained select.
MIXED_DISPATCH = len(OpCode)
# (op, a==b) -> result expressed as ('wire', operand) or ('const', 0/1) or None
ASSOCIATIVE = {OpCode.AND, OpCode.OR, OpCode.XOR}


def apply_op(op: int, a, b):
    """Apply opcode ``op`` bitwise to packed words ``a``, ``b`` (numpy)."""
    return _OP_FNS[OpCode(op)](a, b)


@dataclass
class LogicGraph:
    """A combinational netlist over a 2-input gate library.

    ``gates[i] = (opcode, src_a, src_b)`` produces wire ``first_gate_wire + i``.
    Wires 0/1 are constants; wires 2..2+n_inputs-1 are primary inputs.
    """

    n_inputs: int
    gates: list[tuple[int, int, int]] = field(default_factory=list)
    outputs: list[int] = field(default_factory=list)
    name: str = "ffcl"

    # ---- structure ----
    @property
    def first_gate_wire(self) -> int:
        return 2 + self.n_inputs

    @property
    def n_gates(self) -> int:
        return len(self.gates)

    @property
    def n_wires(self) -> int:
        return 2 + self.n_inputs + self.n_gates

    @property
    def n_outputs(self) -> int:
        return len(self.outputs)

    def input_wire(self, i: int) -> int:
        if not 0 <= i < self.n_inputs:
            raise IndexError(f"input {i} out of range ({self.n_inputs})")
        return 2 + i

    def input_wires(self) -> list[int]:
        return list(range(2, 2 + self.n_inputs))

    def gate_of_wire(self, wire: int) -> tuple[int, int, int]:
        return self.gates[wire - self.first_gate_wire]

    def is_gate(self, wire: int) -> bool:
        return wire >= self.first_gate_wire

    # ---- construction ----
    def add_gate(self, op: OpCode | int, a: int, b: int = CONST0) -> int:
        """Append a gate; operands must already exist (topological order)."""
        op = OpCode(op)
        wire = self.n_wires
        if not (0 <= a < wire) or not (0 <= b < wire):
            raise ValueError(
                f"gate operands ({a},{b}) must precede wire {wire}")
        self.gates.append((int(op), a, b))
        self.__dict__.pop("_fingerprint_cache", None)
        return wire

    def set_outputs(self, outs: Iterable[int]) -> None:
        outs = list(outs)
        for o in outs:
            if not 0 <= o < self.n_wires:
                raise ValueError(f"output wire {o} does not exist")
        self.outputs = outs
        self.__dict__.pop("_fingerprint_cache", None)

    # ---- evaluation (the pure-python/numpy oracle for everything above) ----
    def evaluate(self, inputs: np.ndarray) -> np.ndarray:
        """Evaluate on a batch of boolean inputs.

        Args:
          inputs: bool/int array (batch, n_inputs).
        Returns:
          bool array (batch, n_outputs).
        """
        inputs = np.asarray(inputs)
        if inputs.ndim != 2 or inputs.shape[1] != self.n_inputs:
            raise ValueError(
                f"inputs must be (batch, {self.n_inputs}), got {inputs.shape}")
        batch = inputs.shape[0]
        vals = np.zeros((self.n_wires, batch), dtype=np.uint8)
        vals[CONST1] = 1
        vals[2:2 + self.n_inputs] = inputs.astype(np.uint8).T
        base = self.first_gate_wire
        for i, (op, a, b) in enumerate(self.gates):
            r = apply_op(op, vals[a].astype(np.int64), vals[b].astype(np.int64))
            vals[base + i] = (r & 1).astype(np.uint8)
        return vals[self.outputs].T.astype(bool)

    # ---- analysis ----
    def fingerprint(self) -> str:
        """Stable structural hash: two graphs with identical inputs, gate
        lists, and output wires share a fingerprint regardless of ``name``.

        This is the serving program-cache key (serve/logic_engine.py):
        repeat traffic for a structurally identical FFCL — e.g. the same
        NullaNet layer re-synthesized by another worker — reuses the
        compiled :class:`~repro.core.scheduler.LogicProgram` and its device
        arrays instead of recompiling.

        Memoized against the construction API: ``add_gate`` and
        ``set_outputs`` invalidate the cached digest, and a
        ``(n_inputs, n_gates, outputs)`` guard backstops it, so
        per-request hashing in the serving hot path is O(1) instead of
        O(n_gates). Mutating ``gates`` entries in place (e.g.
        ``g.gates[i] = ...``) bypasses both and would serve a stale
        fingerprint — build graphs through ``add_gate``/``set_outputs``
        only.
        """
        state = (self.n_inputs, self.n_gates, tuple(self.outputs))
        cached = getattr(self, "_fingerprint_cache", None)
        if cached is not None and cached[0] == state:
            return cached[1]
        h = hashlib.blake2b(digest_size=16)
        h.update(np.int64(self.n_inputs).tobytes())
        if self.gates:
            h.update(np.asarray(self.gates, dtype=np.int64).tobytes())
        h.update(b"|outputs|")
        h.update(np.asarray(self.outputs, dtype=np.int64).tobytes())
        fp = h.hexdigest()
        self._fingerprint_cache = (state, fp)
        return fp

    def fanout_counts(self) -> np.ndarray:
        fo = np.zeros(self.n_wires, dtype=np.int64)
        for op, a, b in self.gates:
            fo[a] += 1
            if OpCode(op) not in UNARY:
                fo[b] += 1
        for o in self.outputs:
            fo[o] += 1
        return fo

    def stats(self) -> dict:
        from repro.core.levelize import levelize  # local import, no cycle
        lv = levelize(self)
        return {
            "name": self.name,
            "n_inputs": self.n_inputs,
            "n_outputs": self.n_outputs,
            "n_gates": self.n_gates,
            "depth": int(lv.depth),
        }

    def copy(self) -> "LogicGraph":
        """Shallow structural copy. The memoized fingerprint carries over
        (structure is identical), so copying a served graph does not
        force an O(n_gates) rehash on the copy's first cache lookup."""
        g = LogicGraph(self.n_inputs, list(self.gates),
                       list(self.outputs), self.name)
        cached = getattr(self, "_fingerprint_cache", None)
        if cached is not None:
            g._fingerprint_cache = cached
        return g


def remap_wires(remap: Sequence[int] | np.ndarray, wires: Iterable[int],
                n_wires: int | None = None, *,
                what: str = "wire") -> list[int]:
    """Map wire ids through an old-wire -> new-wire ``remap``, validated.

    The optimization passes (core/opt.py) and any consumer applying their
    remaps (output lists, partition bookkeeping, layer chaining) go
    through here instead of raw fancy-indexing: a wire outside the
    remap's domain, a wire the rewrite dropped (``remap[w] == -1``), or a
    target at/after ``n_wires`` raises ``ValueError`` — instead of the
    silent corruption a negative index or a stale id would cause
    downstream (numpy happily gathers ``arr[-1]``).

    Args:
      remap: old-wire -> new-wire map; ``-1`` marks dropped wires.
      wires: old wire ids to translate.
      n_wires: when given, every translated id must be ``< n_wires`` —
        pass the new graph's wire count to catch out-of-range targets, or
        a gate's own new wire id to catch forward references (an operand
        that does not precede its gate).
      what: noun used in error messages (``"output"``, ``"operand"``...).
    """
    remap = np.asarray(remap, dtype=np.int64)
    out: list[int] = []
    for w in wires:
        w = int(w)
        if not 0 <= w < len(remap):
            raise ValueError(
                f"{what} {w} outside the remap domain [0, {len(remap)})")
        v = int(remap[w])
        if v < 0:
            raise ValueError(
                f"{what} {w} was dropped by the rewrite (remap is -1) "
                "but is still referenced")
        if n_wires is not None and v >= n_wires:
            raise ValueError(
                f"{what} {w} maps to wire {v}, which is out of range / a "
                f"forward reference (must be < {n_wires})")
        out.append(v)
    return out


def compose_graphs(graphs: Sequence["LogicGraph"],
                   name: str = "stacked") -> LogicGraph:
    """Feed graph k's outputs into graph k+1's primary inputs.

    The stages of a multi-layer NullaNet classifier (flow/) are per-layer
    :class:`LogicGraph` objects whose interface widths chain
    (``graphs[k].n_outputs == graphs[k+1].n_inputs``). Composing them
    yields ONE combinational graph computing the whole hidden stack —
    the artifact the serving engine executes so layer boundaries never
    leave the packed-word domain (and so the partitioner can split the
    stack by output cones rather than by layer).

    Stage k+1's input wire i is rewired to whatever wire produces stage
    k's output i — a constant, a primary input, or a gate — so degenerate
    stages (constant or pass-through outputs) compose exactly.
    """
    graphs = list(graphs)
    if not graphs:
        raise ValueError("compose_graphs needs at least one graph")
    out = LogicGraph(graphs[0].n_inputs, name=name)
    feed = [out.input_wire(i) for i in range(graphs[0].n_inputs)]
    for k, g in enumerate(graphs):
        if g.n_inputs != len(feed):
            raise ValueError(
                f"stage {k} expects {g.n_inputs} inputs, previous stage "
                f"produces {len(feed)}")
        repl = np.zeros(g.n_wires, dtype=np.int64)
        repl[CONST0], repl[CONST1] = CONST0, CONST1
        repl[2:g.first_gate_wire] = feed
        base = g.first_gate_wire
        for i, (op, a, b) in enumerate(g.gates):
            repl[base + i] = out.add_gate(op, int(repl[a]), int(repl[b]))
        feed = [int(repl[o]) for o in g.outputs]
    out.set_outputs(feed)
    return out


# ---------------------------------------------------------------------------
# Random graph generator (tests / benchmarks): well-formed DAGs with
# controllable size/shape, mirroring NullaNet-style FFCL statistics.
# ---------------------------------------------------------------------------

def random_graph(rng: np.random.Generator, n_inputs: int, n_gates: int,
                 n_outputs: int, unary_frac: float = 0.1,
                 locality: int = 64) -> LogicGraph:
    """Random topological DAG; operands biased toward recent wires."""
    g = LogicGraph(n_inputs=n_inputs, name="random")
    binary_ops = [OpCode.AND, OpCode.OR, OpCode.XOR, OpCode.NAND, OpCode.NOR,
                  OpCode.XNOR]
    for _ in range(n_gates):
        hi = g.n_wires
        lo = max(0, hi - locality)
        a = int(rng.integers(lo, hi))
        if rng.random() < unary_frac:
            g.add_gate(OpCode.NOT, a)
        else:
            b = int(rng.integers(lo, hi))
            g.add_gate(rng.choice(binary_ops), a, b)
    n_outputs = min(n_outputs, g.n_wires - 2)
    outs = rng.choice(np.arange(2, g.n_wires), size=n_outputs, replace=False)
    g.set_outputs(int(o) for o in outs)
    return g
