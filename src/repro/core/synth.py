"""Logic synthesis passes (ABC stand-in, paper §6.1).

The paper runs ``resyn; resyn2; resyn2rs; compress2rs; st; map; dch; map``
in ABC with two objectives: minimize total gate count and maximum logic depth
(both appear directly in the cycle-count model, eq. 23). ABC is unavailable
offline, so this module implements passes with the same objectives:

  * constant folding        (0/1 absorption, annihilation)
  * operand canonicalization + structural hashing (CSE)
  * algebraic rewrites      (double-NOT, idempotence, involution, NOT-fusion
                             into NAND/NOR/XNOR -- "technology mapping" onto
                             the full DSP opcode set)
  * dead-gate elimination   (unreachable from outputs)
  * associative tree rebalancing (depth reduction for AND/OR/XOR chains)

``optimize(graph)`` runs them to a fixed point and is semantics-preserving:
tests assert ``evaluate`` equality on random vectors and via hypothesis.
"""
from __future__ import annotations

import numpy as np

from repro.core.gate_ir import (ASSOCIATIVE, COMMUTATIVE, CONST0, CONST1,
                                LogicGraph, OpCode, UNARY)

# (op, const_operand_value, const_on_right) -> ('const', v) | ('pass', ) |
# ('not', )   -- what the gate reduces to when one operand is a constant.
_CONST_RULES = {
    (OpCode.AND, 0): ("const", 0), (OpCode.AND, 1): ("pass",),
    (OpCode.OR, 0): ("pass",), (OpCode.OR, 1): ("const", 1),
    (OpCode.XOR, 0): ("pass",), (OpCode.XOR, 1): ("not",),
    (OpCode.NAND, 0): ("const", 1), (OpCode.NAND, 1): ("not",),
    (OpCode.NOR, 0): ("not",), (OpCode.NOR, 1): ("const", 0),
    (OpCode.XNOR, 0): ("not",), (OpCode.XNOR, 1): ("pass",),
}

# op applied to (x, x) -> result
_IDEMPOTENT_RULES = {
    OpCode.AND: ("pass",), OpCode.OR: ("pass",),
    OpCode.XOR: ("const", 0), OpCode.XNOR: ("const", 1),
    OpCode.NAND: ("not",), OpCode.NOR: ("not",),
}

_NEGATED = {OpCode.AND: OpCode.NAND, OpCode.NAND: OpCode.AND,
            OpCode.OR: OpCode.NOR, OpCode.NOR: OpCode.OR,
            OpCode.XOR: OpCode.XNOR, OpCode.XNOR: OpCode.XOR,
            OpCode.NOT: OpCode.COPY, OpCode.COPY: OpCode.NOT}


def _rewrite_pass(graph: LogicGraph) -> LogicGraph:
    """One forward pass: const-fold + canonicalize + hash-cons + local rules.

    Builds a new graph; ``repl[w]`` maps old wire -> new wire.
    """
    new = LogicGraph(graph.n_inputs, name=graph.name)
    repl = np.zeros(graph.n_wires, dtype=np.int64)
    repl[CONST0], repl[CONST1] = CONST0, CONST1
    for i in range(graph.n_inputs):
        repl[2 + i] = 2 + i
    # hash-consing table over the *new* graph
    table: dict[tuple[int, int, int], int] = {}
    # definition of each new wire (for NOT-fusion lookups)
    new_def: dict[int, tuple[int, int, int]] = {}

    def emit(op: OpCode, a: int, b: int) -> int:
        if op in COMMUTATIVE and a > b:
            a, b = b, a
        if op in UNARY:
            b = CONST0
        key = (int(op), a, b)
        if key in table:
            return table[key]
        w = new.add_gate(op, a, b)
        table[key] = w
        new_def[w] = key
        return w

    def resolve(op: OpCode, a: int, b: int) -> int:
        # --- constant folding ---
        if op in UNARY:
            if op == OpCode.COPY:
                return a
            if a == CONST0:
                return CONST1
            if a == CONST1:
                return CONST0
            # NOT(NOT(x)) = x ; NOT(g(x,y)) = negated-g(x,y) (NOT fusion)
            if a in new_def:
                dop, da, db = new_def[a]
                dop = OpCode(dop)
                if dop == OpCode.NOT:
                    return da
                if dop in _NEGATED:
                    return resolve(_NEGATED[dop], da, db)
            return emit(op, a, b)
        # binary ops
        for x, y in ((a, b), (b, a)):
            if y in (CONST0, CONST1):
                rule = _CONST_RULES.get((op, y))
                if rule is None:
                    continue
                if rule[0] == "const":
                    return CONST1 if rule[1] else CONST0
                if rule[0] == "pass":
                    return x
                if rule[0] == "not":
                    return resolve(OpCode.NOT, x, CONST0)
        if a == b:
            rule = _IDEMPOTENT_RULES.get(op)
            if rule is not None:
                if rule[0] == "const":
                    return CONST1 if rule[1] else CONST0
                if rule[0] == "pass":
                    return a
                if rule[0] == "not":
                    return resolve(OpCode.NOT, a, CONST0)
        return emit(op, a, b)

    base = graph.first_gate_wire
    for i, (op, a, b) in enumerate(graph.gates):
        repl[base + i] = resolve(OpCode(op), int(repl[a]), int(repl[b]))
    new.set_outputs(int(repl[o]) for o in graph.outputs)
    return new


def dead_gate_elim(graph: LogicGraph) -> LogicGraph:
    """Remove gates not reachable (backwards) from any output."""
    live = np.zeros(graph.n_wires, dtype=bool)
    live[[CONST0, CONST1]] = True
    live[2:2 + graph.n_inputs] = True
    stack = [o for o in graph.outputs]
    seen = set()
    while stack:
        w = stack.pop()
        if w in seen:
            continue
        seen.add(w)
        live[w] = True
        if graph.is_gate(w):
            op, a, b = graph.gate_of_wire(w)
            stack.append(a)
            if OpCode(op) not in UNARY:
                stack.append(b)
    new = LogicGraph(graph.n_inputs, name=graph.name)
    repl = np.full(graph.n_wires, -1, dtype=np.int64)
    repl[:2 + graph.n_inputs] = np.arange(2 + graph.n_inputs)
    base = graph.first_gate_wire
    for i, (op, a, b) in enumerate(graph.gates):
        w = base + i
        if live[w]:
            repl[w] = new.add_gate(OpCode(op), int(repl[a]), int(repl[b]))
    new.set_outputs(int(repl[o]) for o in graph.outputs)
    return new


def _collect_chain(graph: LogicGraph, wire: int, op: OpCode, fanout: np.ndarray,
                   leaves: list[int]) -> None:
    """Collect leaves of a maximal single-fanout same-op tree rooted at wire."""
    if graph.is_gate(wire):
        gop, a, b = graph.gate_of_wire(wire)
        if OpCode(gop) == op and fanout[wire] == 1:
            _collect_chain(graph, a, op, fanout, leaves)
            _collect_chain(graph, b, op, fanout, leaves)
            return
    leaves.append(wire)


def rebalance(graph: LogicGraph) -> LogicGraph:
    """Rebuild associative same-op chains as balanced trees (depth cut).

    A chain ``(((a&b)&c)&d)`` has depth 3; the balanced tree has depth 2.
    Only single-fanout internal nodes are absorbed, so gate count never grows.
    """
    fanout = graph.fanout_counts()
    new = LogicGraph(graph.n_inputs, name=graph.name)
    repl = np.full(graph.n_wires, -1, dtype=np.int64)
    repl[:2 + graph.n_inputs] = np.arange(2 + graph.n_inputs)
    base = graph.first_gate_wire
    absorbed = np.zeros(graph.n_wires, dtype=bool)

    # mark internal nodes that will be absorbed into a parent's balanced tree
    for i, (op, a, b) in enumerate(graph.gates):
        op = OpCode(op)
        if op not in ASSOCIATIVE:
            continue
        for child in (a, b):
            if graph.is_gate(child) and fanout[child] == 1:
                cop, _, _ = graph.gate_of_wire(child)
                if OpCode(cop) == op:
                    absorbed[child] = True

    def build_balanced(op: OpCode, leaves: list[int]) -> int:
        nodes = [int(repl[w]) for w in leaves]
        while len(nodes) > 1:
            nxt = []
            for j in range(0, len(nodes) - 1, 2):
                nxt.append(new.add_gate(op, nodes[j], nodes[j + 1]))
            if len(nodes) % 2:
                nxt.append(nodes[-1])
            nodes = nxt
        return nodes[0]

    for i, (op, a, b) in enumerate(graph.gates):
        w = base + i
        if absorbed[w]:
            continue
        op = OpCode(op)
        if op in ASSOCIATIVE:
            leaves: list[int] = []
            _collect_chain(graph, a, op, fanout, leaves)
            _collect_chain(graph, b, op, fanout, leaves)
            if any(repl[x] < 0 for x in leaves):  # leaf was absorbed upstream
                leaves = [a, b]
            repl[w] = build_balanced(op, leaves)
        else:
            repl[w] = new.add_gate(op, int(repl[a]), int(repl[b]))
    new.set_outputs(int(repl[o]) for o in graph.outputs)
    return new


def optimize(graph: LogicGraph, max_iters: int = 8) -> LogicGraph:
    """Run all passes to a fixed point on (n_gates, depth)."""
    from repro.core.levelize import levelize
    cur = graph
    prev_key = None
    for _ in range(max_iters):
        cur = _rewrite_pass(cur)
        cur = dead_gate_elim(cur)
        cur = rebalance(cur)
        cur = dead_gate_elim(cur)
        key = (cur.n_gates, levelize(cur).depth)
        if key == prev_key:
            break
        prev_key = key
    return cur
