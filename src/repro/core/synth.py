"""Logic synthesis passes (ABC stand-in, paper §6.1) — legacy facade.

The paper runs ``resyn; resyn2; resyn2rs; compress2rs; st; map; dch; map``
in ABC with two objectives: minimize total gate count and maximum logic
depth (both appear directly in the cycle-count model, eq. 23). ABC is
unavailable offline; the rewrites live in **core/opt.py** as composable
passes with wire remaps (DESIGN.md §7):

  * constant folding        (:class:`~repro.core.opt.ConstantFold`)
  * structural hashing/CSE  (:class:`~repro.core.opt.StructuralHash`)
  * algebraic identities    (:class:`~repro.core.opt.SimplifyIdentities`:
                             double-NOT, idempotence, NOT-fusion into
                             NAND/NOR/XNOR — "technology mapping" onto
                             the full DSP opcode set)
  * dead-gate elimination   (:class:`~repro.core.opt.DeadGateElim`)
  * associative rebalancing (:class:`~repro.core.opt.Rebalance`)

This module keeps the original graph-in/graph-out names for callers that
don't need remaps; new code should use :class:`repro.core.opt.PassManager`
directly (or the ``optimize=`` knob on ``scheduler.compile_graph`` /
``nullanet.layer_to_graph`` / the flow and serving layers).

``optimize(graph)`` runs the default pipeline to a fixed point and is
semantics-preserving: tests assert ``evaluate`` equality on random
vectors and via hypothesis.
"""
from __future__ import annotations

from repro.core.gate_ir import LogicGraph
from repro.core.opt import (DeadGateElim, PassManager,
                            Rebalance as _Rebalance)


def dead_gate_elim(graph: LogicGraph) -> LogicGraph:
    """Remove gates not reachable (backwards) from any output."""
    return DeadGateElim().run(graph).graph


def rebalance(graph: LogicGraph) -> LogicGraph:
    """Rebuild associative same-op chains as balanced trees (depth cut).

    A chain ``(((a&b)&c)&d)`` has depth 3; the balanced tree has depth 2.
    Only single-fanout internal nodes are absorbed, so gate count never
    grows.
    """
    return _Rebalance().run(graph).graph


def optimize(graph: LogicGraph, max_iters: int = 8) -> LogicGraph:
    """Run the default pass pipeline to a fixed point on (n_gates, depth)."""
    return PassManager.default(max_iters=max_iters).run(graph).graph
